use sqlsq::runtime::Executor;
use sqlsq::data::rng::Pcg32;

fn main() {
    let mut ex = Executor::open(std::path::Path::new("artifacts")).unwrap();
    let mut rng = Pcg32::seeded(1);
    for n in [50usize, 200, 600] {
        let mut v: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup();
        let mut d = vec![v[0]];
        for i in 1..v.len() { d.push(v[i] - v[i-1]); }
        // warm (compile)
        let _ = ex.lasso_solve(&v, &d, 0.02, 0.0, 1, 0.0).unwrap();
        let t0 = std::time::Instant::now();
        let sol = ex.lasso_solve(&v, &d, 0.02, 0.0, 125, 1e-6).unwrap();
        println!("n={n}: calls={} converged={} total={:?} per_call={:?}",
            sol.calls, sol.converged, t0.elapsed(), t0.elapsed()/sol.calls as u32);
    }
}
