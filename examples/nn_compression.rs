//! End-to-end driver (DESIGN E11): NN compression served from
//! **quantized compute** — the forward pass runs straight off packed
//! index planes, never materializing a dense weight matrix.
//!
//! 1. Train the paper's 784-256-128-64-10 MLP on the procedural digit
//!    corpus (or load the cached weights) — the §4.1 substrate.
//! 2. Quantize every layer into a `QMatrix` residual cascade
//!    (`Mlp::quantize_weights`): quantize at the first bit width,
//!    re-quantize the residual at the next, until the norm tolerance.
//! 3. Serve inference from the packed planes (`QuantizedMlp::infer`) and
//!    compare dense vs quantized accuracy and weight bytes per config —
//!    the accuracy-vs-bits trade the cascade buys.
//! 4. Cross-check the contract: with a single-level cascade the f64
//!    quantized logits are bit-for-bit the dense logits on the decoded
//!    weights.
//!
//! ```bash
//! cargo run --release --example nn_compression
//! ```

use sqlsq::eval::workloads;
use sqlsq::nn::train::to_matrix;
use sqlsq::linalg::matrix::Matrix;
use sqlsq::quant::tensor::Grouping;
use sqlsq::quant::{QuantMethod, QuantOptions};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. substrate: the trained network -----------------------------
    let nn = workloads::nn_workload(None)?;
    let dense_bytes: usize = (0..nn.mlp.layers.len())
        .map(|li| nn.mlp.layer_weights(li).len() * 8)
        .sum();
    println!(
        "MLP 784-256-128-64-10 ({} params, {} weight bytes dense): train acc {:.4}, test acc {:.4}",
        nn.mlp.param_count(),
        dense_bytes,
        nn.train_acc,
        nn.test_acc
    );
    let (train_x, train_y) = to_matrix(&nn.train);
    let (test_x, test_y) = to_matrix(&nn.test);

    // --- 2./3. cascade configs: accuracy/bytes served off the planes ----
    let opts = QuantOptions { kmeans_restarts: 2, ..Default::default() };
    let configs: &[(&str, &[u32], f64)] = &[
        ("1 level, 2-bit", &[2], 0.0),
        ("1 level, 4-bit", &[4], 0.0),
        ("cascade 4+2", &[4, 2], 0.0),
        ("cascade 4+2+2", &[4, 2, 2], 0.0),
        ("cascade 4+2+2, tol 2%", &[4, 2, 2], 0.02),
    ];
    println!("\n== quantized forward pass (per-column cascades, kmeans levels) ==");
    println!(
        "{:<22} {:>7} {:>12} {:>8} {:>10} {:>10} {:>10}",
        "config", "levels", "bytes", "ratio", "max_err", "train_acc", "test_acc"
    );
    for &(name, bits, tol) in configs {
        let t0 = Instant::now();
        let qnet =
            nn.mlp.quantize_weights(Grouping::PerColumn, QuantMethod::KMeans, &opts, bits, tol)?;
        let build = t0.elapsed();
        let tr = qnet.accuracy(&train_x, &train_y)?;
        let te = qnet.accuracy(&test_x, &test_y)?;
        println!(
            "{:<22} {:>7} {:>12} {:>7.1}x {:>10.2e} {:>10.4} {:>10.4}   (built in {build:.2?})",
            name,
            qnet.weights.iter().map(|w| w.num_levels()).max().unwrap_or(0),
            qnet.weight_bytes(),
            qnet.dense_weight_bytes() as f64 / qnet.weight_bytes() as f64,
            qnet.max_layer_error(&nn.mlp),
            tr,
            te
        );
    }
    println!(
        "(dense reference: train {:.4}, test {:.4} — the cascade rows converge toward it \
         as cumulative bits grow)",
        nn.train_acc, nn.test_acc
    );

    // --- 4. the bitwise contract ----------------------------------------
    let qnet =
        nn.mlp.quantize_weights(Grouping::PerColumn, QuantMethod::KMeans, &opts, &[4], 0.0)?;
    let mut decoded = nn.mlp.clone();
    for (li, qw) in qnet.weights.iter().enumerate() {
        decoded.set_layer_weights(li, qw.decode().data())?;
    }
    let probe_x: &Matrix = &test_x;
    let quantized_logits = qnet.infer(probe_x)?;
    let dense_logits = decoded.infer(probe_x)?;
    let identical = quantized_logits
        .data()
        .iter()
        .zip(dense_logits.data())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "\nsingle-level packed forward vs decoded-dense forward over {} test rows: {}",
        probe_x.rows(),
        if identical { "bit-for-bit identical" } else { "MISMATCH (contract violated!)" }
    );
    if !identical {
        return Err("single-level quantized forward must be bitwise dense".into());
    }
    Ok(())
}
