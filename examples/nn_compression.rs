//! End-to-end driver (DESIGN E11): the full system on a real small
//! workload, proving all layers compose.
//!
//! 1. Train the paper's 784-256-128-64-10 MLP on the procedural digit
//!    corpus (or load the cached weights) — the §4.1 substrate.
//! 2. Start the coordinator with the `auto` engine: runtime-capable jobs
//!    are served by the **AOT JAX/Pallas artifacts on PJRT**, the rest by
//!    the native engines.
//! 3. Quantize EVERY layer of the network through the service, sweeping
//!    the value count; evaluate post-quantization accuracy (Figure 1/2
//!    end to end).
//! 4. Report serving throughput/latency from the coordinator metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example nn_compression
//! ```

use sqlsq::config::{Config, Engine};
use sqlsq::coordinator::Coordinator;
use sqlsq::eval::workloads;
use sqlsq::quant::{QuantMethod, QuantOptions};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. substrate: the trained network -----------------------------
    let nn = workloads::nn_workload(None)?;
    println!(
        "MLP 784-256-128-64-10 ({} params): train acc {:.4}, test acc {:.4}",
        nn.mlp.param_count(),
        nn.train_acc,
        nn.test_acc
    );

    // --- 2. the serving layer ------------------------------------------
    let engine = if std::path::Path::new("artifacts/manifest.json").exists() {
        Engine::Auto
    } else {
        eprintln!("note: artifacts/ missing — run `make artifacts` for the PJRT path; using native");
        Engine::Native
    };
    let coord = Coordinator::start(Config { engine, ..Default::default() })?;

    // --- 3. quantize every layer through the coordinator ----------------
    println!("\n== per-layer quantization through the coordinator ==");
    println!(
        "{:<7} {:>10} {:>7} {:>9} {:>10} {:>10} {:>9}",
        "layer", "method", "k", "achieved", "train_acc", "test_acc", "engine"
    );
    for k in [4usize, 8, 16, 32] {
        for li in 0..4 {
            let weights = nn.mlp.layer_weights(li).to_vec();
            // The l1+LS method (Algorithm 1) through the service; the
            // runtime lane serves it when the unique-count fits a bucket.
            let lambda = sqlsq::eval::figures::lambda_for_count(&weights, k);
            let res = coord.quantize_blocking(
                weights,
                QuantMethod::L1LeastSquare,
                QuantOptions { lambda1: lambda, ..Default::default() },
            )?;
            let out = res.outcome.map_err(|e| format!("layer {li}: {e}"))?;
            // The coordinator returns the compact codebook; materialize at
            // this edge to patch the layer.
            let values = out.materialize();
            let (tr, te) =
                workloads::accuracy_with_layer(&nn.mlp, li, &values, &nn.train, &nn.test)?;
            println!(
                "{:<7} {:>10} {:>7} {:>9} {:>10.4} {:>10.4} {:>9}",
                format!("L{li}"),
                "l1_ls",
                k,
                out.distinct_values(),
                tr,
                te,
                res.served_by.label()
            );
        }
    }

    // Full-network compression: quantize all layers at once, k=32 each.
    println!("\n== whole-network quantization (all four layers, k=32) ==");
    let mut compressed = nn.mlp.clone();
    for li in 0..4 {
        let weights = nn.mlp.layer_weights(li).to_vec();
        let res = coord.quantize_blocking(
            weights,
            QuantMethod::ClusterLs,
            QuantOptions { target_values: 32, ..Default::default() },
        )?;
        let out = res.outcome.map_err(|e| format!("layer {li}: {e}"))?;
        println!("  L{li}: {}", out.compression().summary());
        compressed.set_layer_weights(li, &out.materialize())?;
    }
    let tr = sqlsq::nn::train::evaluate(&compressed, &nn.train)?;
    let te = sqlsq::nn::train::evaluate(&compressed, &nn.test)?;
    println!(
        "32 shared values/layer (~{:.1}x weight-bits compression): train {:.4} (Δ{:+.4}), test {:.4} (Δ{:+.4})",
        64.0 / 5.0, // f64 mantissa-ish vs 5-bit index — illustrative
        tr,
        tr - nn.train_acc,
        te,
        te - nn.test_acc
    );

    // --- 4. throughput under a burst ------------------------------------
    println!("\n== serving burst: 120 mixed quantization jobs ==");
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..120 {
        let li = i % 4;
        let weights = nn.mlp.layer_weights(li).to_vec();
        let method = [QuantMethod::L1LeastSquare, QuantMethod::KMeans, QuantMethod::ClusterLs]
            [i % 3];
        let (_, rx) = coord.submit(
            weights,
            method,
            QuantOptions { target_values: 16, lambda1: 0.01, seed: i as u64, ..Default::default() },
        )?;
        rxs.push(rx);
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let snap = coord.shutdown();
    println!("{ok}/120 ok in {wall:.2?}  ({:.1} jobs/s)", 120.0 / wall.as_secs_f64());
    println!("metrics: {}", snap.summary());
    Ok(())
}
