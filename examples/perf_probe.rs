//! §Perf probe: CD epoch consumption + quality vs tolerance. Feeds
//! EXPERIMENTS.md §Perf.

use sqlsq::data::rng::Pcg32;
use sqlsq::linalg::stats::l2_loss;
use sqlsq::quant::{lasso, refit, unique::UniqueDecomp, vmatrix::VBasis};

fn main() {
    let mut rng = Pcg32::seeded(1);
    let data: Vec<f64> = (0..640).map(|_| rng.normal_with(0.0, 0.15)).collect();
    let u = UniqueDecomp::new(&data).unwrap();
    let b = VBasis::new(&u.values);

    println!("== tolerance sweep (m=640) ==");
    for lambda in [1e-4, 1e-3, 1e-2] {
        // Reference: very tight tolerance, big budget.
        let tight = lasso::LassoConfig {
            lambda1: lambda,
            tol: 1e-13,
            max_epochs: 20_000,
            support_patience: 0, // true norm-convergence reference
            ..Default::default()
        };
        let ref_sol = lasso::solve(&b, &u.values, &tight, None).unwrap();
        let ref_refit = refit::refit_fast(&b, &u.values, &ref_sol.support(), None).unwrap();
        let ref_loss = l2_loss(&ref_refit.reconstruction, &u.values);

        for tol in [1e-6f64, 1e-7, 1e-8, 1e-10] {
            let cfg = lasso::LassoConfig { lambda1: lambda, tol, ..Default::default() };
            let t0 = std::time::Instant::now();
            let sol = lasso::solve(&b, &u.values, &cfg, None).unwrap();
            let dt = t0.elapsed();
            let re = refit::refit_fast(&b, &u.values, &sol.support(), None).unwrap();
            let loss = l2_loss(&re.reconstruction, &u.values);
            println!(
                "λ={lambda:.0e} tol={tol:.0e}: epochs={:<5} nnz={:<4} (ref {:<4}) \
                 refit_loss={loss:.6e} (ref {ref_loss:.6e}) time={dt:?}",
                sol.epochs,
                sol.nnz(),
                ref_sol.nnz()
            );
        }
    }
}
