//! Quickstart for the unified request/response API — the front door for
//! new code, and the migration target for every legacy `quantize_*` call.
//!
//! ```bash
//! cargo run --release --example request_api
//! ```
//!
//! Responses are **codebook-first**: you get a few shared levels plus one
//! small index per element (the compact payload a serving edge ships),
//! and the full-length vector only materializes if you ask for it.

use sqlsq::data::rng::Pcg32;
use sqlsq::linalg::matrix::Matrix;
use sqlsq::quant::tensor::Grouping;
use sqlsq::quant::{QuantMethod, QuantRequest, Quantizer};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rng = Pcg32::seeded(42);
    let mut data = Vec::new();
    for center in [0.1f64, 0.35, 0.6, 0.9] {
        for _ in 0..60 {
            data.push(center + rng.normal_with(0.0, 0.015));
        }
    }
    let quantizer = Quantizer::new();

    // 1. One-shot, codebook-first (the default output form). The owned
    //    vector moves into the request — no copy.
    let req = QuantRequest::vector(data.clone())
        .method(QuantMethod::ClusterLs)
        .target_count(4);
    let item = quantizer.run(&req)?.into_single()?;
    let cb = item.codebook_f64();
    println!(
        "one-shot   : {} values -> {} levels, {} bits/index, {:.1}x vs dense f32, loss {:.3e}",
        cb.indices.len(),
        cb.k(),
        cb.bits_per_index(),
        cb.compression_ratio_f32(),
        item.l2_loss()
    );
    // Full vectors are lazy — only built when you need one.
    let full = item.materialize_f64();
    assert_eq!(full.len(), data.len());

    // 2. A λ sweep: one prepared input, warm starts along the grid.
    let lambdas: Vec<f64> = (0..5).map(|i| 1e-4 * 10f64.powi(i)).collect();
    let sweep = QuantRequest::vector(data.clone())
        .method(QuantMethod::L1LeastSquare)
        .sweep(lambdas.clone());
    let resp = quantizer.run(&sweep)?;
    for (r, lambda) in resp.items.iter().zip(&lambdas) {
        let it = r.as_ref().expect("sweep items all succeed");
        println!(
            "sweep      : λ={lambda:>8.1e} -> {:>3} levels, loss {:.3e}",
            it.distinct_values(),
            it.l2_loss()
        );
    }

    // 3. A batch on the f32 fast lane — results stay single-precision
    //    (no early widening), failures would be isolated per slot.
    let batch: Vec<Vec<f32>> = (0..4)
        .map(|s| {
            let mut r = Pcg32::seeded(100 + s);
            (0..256).map(|_| r.uniform(0.0, 1.0) as f32).collect()
        })
        .collect();
    let breq = QuantRequest::batch_f32(batch).method(QuantMethod::KMeans).target_count(8);
    let bresp = quantizer.run(&breq)?;
    let ok = bresp.items.iter().filter(|r| r.is_ok()).count();
    println!(
        "batch f32  : {}/{} slots ok, total loss {:.3e}",
        ok,
        bresp.len(),
        bresp.total_l2_loss()
    );

    // 4. Matrix grouping: per-row codebooks (NN layer style), fanned
    //    across the batch executor.
    let m = Matrix::from_fn(8, 64, |_, _| rng.normal_with(0.0, 1.0));
    let mreq = QuantRequest::matrix(m, Grouping::PerRow)
        .method(QuantMethod::KMeansExact)
        .target_count(4);
    let mresp = quantizer.run(&mreq)?;
    println!(
        "matrix     : {} per-row codebooks, prepare+solve {:?}",
        mresp.len(),
        mresp.timings().prepare + mresp.timings().solve
    );

    // Migration cheat sheet (old -> new):
    //   quantize(&w, m, &o)              -> QuantRequest::vector(w).method(m).options(o)
    //   quantize_f32(&w, m, &o)          -> QuantRequest::vector_f32(w)...
    //   quantize_batch(&ws, m, &o)       -> QuantRequest::batch(ws)...
    //   quantize_sweep(&prep, m, λs, &o) -> QuantRequest::vector(w)...sweep(λs)
    //   quantize_matrix(&mat, m, &o, g)  -> QuantRequest::matrix(mat, g)...
    //   coord.submit(w, m, o)            -> coord.submit_request(QuantRequest::vector(w)...)
    Ok(())
}
