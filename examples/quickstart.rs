//! Quickstart: quantize one vector with every method in the library and
//! compare information loss, achieved value counts, and runtime.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sqlsq::data::rng::Pcg32;
use sqlsq::linalg::stats;
use sqlsq::quant::{self, QuantMethod, QuantOptions};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A vector with visible cluster structure: 4 value groups + repeats.
    let mut rng = Pcg32::seeded(42);
    let mut data = Vec::new();
    for center in [0.1f64, 0.35, 0.6, 0.9] {
        for _ in 0..60 {
            data.push(center + rng.normal_with(0.0, 0.015));
        }
    }
    println!(
        "input: {} values, {} distinct, range [{:.3}, {:.3}]\n",
        data.len(),
        stats::distinct_count_exact(&data),
        stats::min(&data),
        stats::max(&data)
    );

    println!(
        "{:<16} {:>9} {:>9} {:>12} {:>10}",
        "method", "requested", "achieved", "l2_loss", "time"
    );
    println!("{}", "-".repeat(62));
    for method in QuantMethod::ALL {
        let opts = QuantOptions {
            target_values: 4,
            lambda1: 0.05,       // used by the λ-taking methods
            lambda2: 2e-4,       // used by l1_l2
            seed: 7,
            ..Default::default()
        };
        let t0 = Instant::now();
        let out = quant::quantize(&data, method, &opts)?;
        let dt = t0.elapsed();
        println!(
            "{:<16} {:>9} {:>9} {:>12.6} {:>10.2?}",
            method.id(),
            if method.takes_target_count() { "4".to_string() } else { format!("λ={}", opts.lambda1) },
            out.distinct_values(),
            out.l2_loss,
            dt
        );
    }

    // The headline API in three lines:
    let out = quant::quantize(
        &data,
        QuantMethod::ClusterLs,
        &QuantOptions { target_values: 4, ..Default::default() },
    )?;
    println!(
        "\ncluster_ls levels: {:?}",
        out.levels.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    Ok(())
}
