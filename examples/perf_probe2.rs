//! §Perf probe 2: k-means cost breakdown on large-m inputs (the
//! nn_compression burst bottleneck).

use sqlsq::cluster::kmeans::{kmeans_1d, KMeansConfig};
use sqlsq::data::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(1);
    let mut data: Vec<f64> = (0..200_000).map(|_| rng.normal_with(0.0, 0.1)).collect();
    // The quantize() path always clusters sorted unique values; do the same.
    data.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (label, tol, restarts) in [
        ("tol=1e-10,T=10", 1e-10, 10usize),
        ("tol=1e-6, T=10", 1e-6, 10),
        ("tol=1e-5, T=10", 1e-5, 10),
        ("tol=1e-5, T=3", 1e-5, 3),
    ] {
        let t0 = std::time::Instant::now();
        let r = kmeans_1d(
            &data,
            None,
            &KMeansConfig { k: 16, tol, restarts, ..Default::default() },
        )
        .unwrap();
        println!(
            "{label}: iters={} inertia={:.6} time={:?}",
            r.iterations,
            r.inertia,
            t0.elapsed()
        );
    }
}
