//! Staged-pipeline demo: prepare a 10k-element vector once, then sweep a
//! 16-point λ grid with warm starts — versus 16 independent one-shot
//! `quantize` calls that redo the prepare stage every time.
//!
//! ```bash
//! cargo run --release --example lambda_sweep
//! ```

use sqlsq::data::rng::Pcg32;
use sqlsq::eval::workloads::lambda_grid;
use sqlsq::quant::{self, PreparedInput, PreparedInputF32, QuantMethod, QuantOptions};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 10k values quantized to a 512-level raster so repeats occur — the
    // NN-weight shape the batch/sweep API is built for.
    let mut rng = Pcg32::seeded(3);
    let data: Vec<f64> =
        (0..10_000).map(|_| (rng.uniform(0.0, 1.0) * 512.0).round() / 512.0).collect();
    let lambdas = lambda_grid(1e-4, 1e-1, 16)?;
    let opts = QuantOptions::default();
    let method = QuantMethod::L1LeastSquare;

    // --- one-shot baseline: prepare + solve per λ -----------------------
    let t0 = Instant::now();
    let mut one_shot = Vec::new();
    for &lambda in &lambdas {
        one_shot.push(quant::quantize(
            &data,
            method,
            &QuantOptions { lambda1: lambda, ..opts.clone() },
        )?);
    }
    let t_one_shot = t0.elapsed();

    // --- staged pipeline: prepare once, warm-started sweep --------------
    let t1 = Instant::now();
    let prep = PreparedInput::new(&data)?;
    let swept = quant::quantize_sweep(&prep, method, &lambdas, &opts)?;
    let t_sweep = t1.elapsed();

    println!(
        "{:>12} {:>10} {:>14} | {:>10} {:>14}",
        "lambda1", "1shot lvl", "1shot loss", "sweep lvl", "sweep loss"
    );
    for ((a, b), &lambda) in one_shot.iter().zip(&swept).zip(&lambdas) {
        println!(
            "{lambda:>12.4e} {:>10} {:>14.6e} | {:>10} {:>14.6e}",
            a.distinct_values(),
            a.l2_loss,
            b.distinct_values(),
            b.l2_loss
        );
    }
    println!("\n16 one-shot calls : {t_one_shot:?}");
    println!("prepared sweep    : {t_sweep:?}");
    println!(
        "speedup           : {:.2}x",
        t_one_shot.as_secs_f64() / t_sweep.as_secs_f64().max(1e-12)
    );

    // --- f32 fast lane over the same sweep ------------------------------
    // Narrowing stays untimed: the lane's intended clients (f32 NN
    // weights) never pay it, and the batch_sweep bench measures the same
    // way.
    let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    let t_f32 = Instant::now();
    let prep32 = PreparedInputF32::from_vec(data32)?;
    let swept32 = quant::quantize_sweep_f32(&prep32, method, &lambdas, &opts)?;
    let t_sweep32 = t_f32.elapsed();
    let loss64: f64 = swept.iter().map(|o| o.l2_loss).sum();
    let loss32: f64 = swept32.iter().map(|o| o.l2_loss).sum();
    println!(
        "\nf32-lane sweep    : {t_sweep32:?} ({:.2}x vs f64 sweep)",
        t_sweep.as_secs_f64() / t_sweep32.as_secs_f64().max(1e-12)
    );
    println!(
        "total grid loss   : f64 {loss64:.6e} vs f32 {loss32:.6e} (rel delta {:.2e})",
        (loss32 - loss64).abs() / loss64.max(1e-12)
    );

    // --- batch API over many vectors ------------------------------------
    let inputs: Vec<Vec<f64>> = (0..16)
        .map(|i| {
            let mut r = Pcg32::seeded(100 + i);
            (0..2000).map(|_| (r.uniform(0.0, 1.0) * 256.0).round() / 256.0).collect()
        })
        .collect();
    let t2 = Instant::now();
    let batch_opts = QuantOptions { target_values: 16, ..Default::default() };
    let batch = quant::quantize_batch(&inputs, QuantMethod::ClusterLs, &batch_opts);
    let ok = batch.iter().filter(|r| r.is_ok()).count();
    println!(
        "\nbatch of {}       : {ok} ok in {:?} (scoped-thread fan-out)",
        inputs.len(),
        t2.elapsed()
    );
    Ok(())
}
