//! Serving demo: the coordinator under load — batching, backpressure
//! (bounded queue + load shedding), the runtime lane, and the metrics
//! surface.
//!
//! ```bash
//! cargo run --release --example serve_quant
//! ```

use sqlsq::config::{Config, Engine};
use sqlsq::coordinator::Coordinator;
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{QuantMethod, QuantOptions};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let engine = if std::path::Path::new("artifacts/manifest.json").exists() {
        Engine::Auto
    } else {
        Engine::Native
    };

    // --- steady-state load through the blocking API ---------------------
    let cfg = Config { engine, workers: 4, max_batch: 16, ..Default::default() };
    println!("coordinator: {} workers, engine {:?}", cfg.workers, cfg.engine);
    let coord = Coordinator::start(cfg)?;

    let mut rng = Pcg32::seeded(1);
    let n_jobs = 300;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_jobs {
        let n = [50usize, 200, 600][i % 3];
        let data: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
        let method = [
            QuantMethod::L1LeastSquare,
            QuantMethod::KMeans,
            QuantMethod::ClusterLs,
            QuantMethod::Gmm,
        ][i % 4];
        let (_, rx) = coord.submit(
            data,
            method,
            QuantOptions { target_values: 8, lambda1: 0.02, seed: i as u64, ..Default::default() },
        )?;
        rxs.push(rx);
    }
    let mut ok = 0usize;
    let mut native = 0usize;
    let mut runtime = 0usize;
    for rx in rxs {
        let r = rx.recv()?;
        if r.is_ok() {
            ok += 1;
        }
        match r.served_by {
            sqlsq::coordinator::ServedBy::Native => native += 1,
            sqlsq::coordinator::ServedBy::Runtime => runtime += 1,
        }
    }
    let wall = t0.elapsed();
    println!(
        "steady state: {ok}/{n_jobs} ok in {wall:.2?} ({:.1} jobs/s; {native} native, {runtime} runtime)",
        n_jobs as f64 / wall.as_secs_f64()
    );
    println!("metrics: {}", coord.metrics().summary());
    coord.shutdown();

    // --- overload: tiny queue + try_submit = load shedding ---------------
    println!("\noverload demo: queue_capacity=4, non-blocking submits");
    let coord = Coordinator::start(Config {
        engine: Engine::Native,
        workers: 1,
        queue_capacity: 4,
        max_batch: 2,
        ..Default::default()
    })?;
    let mut accepted = 0usize;
    let mut shed = 0usize;
    let mut rxs = Vec::new();
    for i in 0..200 {
        let data: Vec<f64> = (0..400).map(|_| rng.uniform(0.0, 1.0)).collect();
        match coord.try_submit(
            data,
            QuantMethod::IterativeL1,
            QuantOptions { target_values: 4, lambda1: 1e-4, seed: i, ..Default::default() },
        ) {
            Ok((_, rx)) => {
                accepted += 1;
                rxs.push(rx);
            }
            Err(_) => shed += 1,
        }
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let snap = coord.shutdown();
    println!("accepted {accepted}, shed {shed} (rejected={})", snap.rejected);
    println!("metrics: {}", snap.summary());
    assert_eq!(snap.rejected as usize, shed);
    Ok(())
}
