//! Image quantization (paper §4.2, Figures 5–6): quantize a digit image
//! with the l1 family, k-means, cluster-LS and l0; render before/after as
//! ASCII; report clamped l2 loss, achieved counts and runtime.
//!
//! ```bash
//! cargo run --release --example image_quantization
//! ```

use sqlsq::data::synth_digits;
use sqlsq::eval::workloads;
use sqlsq::quant::{self, QuantMethod, QuantOptions};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let image = workloads::digit_image();
    println!("original digit (784 px, [0,1]):\n{}", synth_digits::to_ascii(&image));

    let k = 4;
    println!("== quantizing to {k} values ==\n");
    for method in [
        QuantMethod::KMeans,
        QuantMethod::ClusterLs,
        QuantMethod::IterativeL1,
        QuantMethod::L0,
    ] {
        let opts = QuantOptions {
            target_values: k,
            lambda1: 1e-4,
            clamp: Some((0.0, 1.0)), // eq 21: image values must stay in [0,1]
            seed: 1,
            ..Default::default()
        };
        let t0 = Instant::now();
        let out = quant::quantize(&image, method, &opts)?;
        let dt = t0.elapsed();
        println!(
            "{} — achieved {} values, l2 loss {:.4}, clamped {}, {:.2?}{}",
            method.id(),
            out.distinct_values(),
            out.l2_loss,
            out.clamped,
            dt,
            if out.diag.unstable { "  [UNSTABLE — the paper's l0 caveat]" } else { "" }
        );
        println!("{}", synth_digits::to_ascii(&out.values));
    }

    // The paper's l0 non-universality: sweep requested counts and show the
    // achieved ones.
    println!("== l0 non-universality (requested -> achieved) ==");
    for l in [2usize, 8, 32, 101] {
        let opts = QuantOptions {
            target_values: l,
            clamp: Some((0.0, 1.0)),
            ..Default::default()
        };
        let out = quant::quantize(&image, QuantMethod::L0, &opts)?;
        println!(
            "  l={l:<4} -> {} values{}",
            out.distinct_values(),
            if out.diag.unstable { "  (flagged unstable)" } else { "" }
        );
    }
    Ok(())
}
