//! Deterministic pseudo-random number generation.
//!
//! The paper's experiments depend on randomness in three places: k-means
//! initialization (the instability the paper criticizes), synthetic data
//! generation (§4.3), and MLP weight initialization (§4.1). To make every
//! experiment in this repository bit-reproducible we use our own
//! [PCG-XSH-RR 64/32](https://www.pcg-random.org/) generator seeded
//! explicitly everywhere — no global RNG, no OS entropy.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64.
///
/// Small, fast, and statistically strong enough for simulation workloads.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second normal variate from the Box-Muller transform.
    cached_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.
    ///
    /// Different `stream` values yield independent sequences for the same
    /// seed — used to decorrelate e.g. data generation from k-means init.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
            cached_normal: None,
        };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        let hi = self.next_u32() as u64;
        let lo = self.next_u32() as u64;
        (hi << 32) | lo
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` using Lemire's rejection method
    /// (unbiased).
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        // 64-bit multiply-shift rejection (Lemire 2019): accept iff the low
        // half of the 128-bit product clears the bias threshold.
        let threshold = n.wrapping_neg() % n;
        loop {
            let m = self.next_u64() as u128 * n as u128;
            if m as u64 >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal variate via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal variate with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from the (unnormalized, non-negative) weight vector.
    ///
    /// Used by k-means++ seeding. Returns `None` if all weights are zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return None;
        }
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return Some(i);
            }
        }
        // Floating-point slack: return the last positive-weight index.
        weights.iter().rposition(|&w| w > 0.0)
    }

    /// Derive a child generator with a decorrelated stream.
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(2).wrapping_add(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be decorrelated, {same} collisions");
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..1000 {
            let x = rng.uniform(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut rng = Pcg32::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(12);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gen_range_unbiased_small() {
        let mut rng = Pcg32::seeded(13);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.gen_range(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Pcg32::seeded(14);
        for n in [1usize, 2, 3, 7, 100, 1_000_000] {
            for _ in 0..100 {
                assert!(rng.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg32::seeded(15);
        let w = [0.0, 3.0, 1.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[3], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn weighted_index_all_zero_is_none() {
        let mut rng = Pcg32::seeded(16);
        assert!(rng.weighted_index(&[0.0, 0.0]).is_none());
        assert!(rng.weighted_index(&[]).is_none());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Pcg32::seeded(18);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
