//! Synthetic 1-d data generators for the paper's §4.3 experiments.
//!
//! Three distributions, 500 samples each, constrained to `[0, 100]`
//! (Figure 7): a Mixture of Gaussians, a Uniform, and a single Gaussian.
//! "In practice, these three types of distributions could describe most
//! cases of 1-d data characteristics."

use super::rng::Pcg32;

/// The three §4.3 source distributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SynthKind {
    /// Mixture of Gaussians (three well-separated modes).
    MixtureOfGaussians,
    /// Uniform over the full range.
    Uniform,
    /// Single mid-range Gaussian.
    SingleGaussian,
}

impl SynthKind {
    /// All three kinds, in the order Figure 7/8 plots them.
    pub const ALL: [SynthKind; 3] = [
        SynthKind::MixtureOfGaussians,
        SynthKind::Uniform,
        SynthKind::SingleGaussian,
    ];

    /// Human-readable label used in reports.
    pub fn label(self) -> &'static str {
        match self {
            SynthKind::MixtureOfGaussians => "mixture-of-gaussians",
            SynthKind::Uniform => "uniform",
            SynthKind::SingleGaussian => "single-gaussian",
        }
    }
}

/// A component of a 1-d Gaussian mixture.
#[derive(Debug, Clone, Copy)]
pub struct MixComponent {
    /// Component mean.
    pub mean: f64,
    /// Component standard deviation.
    pub std: f64,
    /// Mixing weight (need not be normalized).
    pub weight: f64,
}

/// Parameters for the synthetic generators. Defaults follow Figure 7:
/// range `[0, 100]`, 500 samples.
#[derive(Debug, Clone)]
pub struct SynthParams {
    /// Inclusive lower bound of the value range.
    pub lo: f64,
    /// Inclusive upper bound of the value range.
    pub hi: f64,
    /// Number of samples to draw.
    pub n: usize,
    /// Mixture components (MixtureOfGaussians only).
    pub components: Vec<MixComponent>,
    /// Mean/std of the single Gaussian, as fractions of the range.
    pub gaussian_mean_frac: f64,
    /// Std of the single Gaussian as a fraction of the range width.
    pub gaussian_std_frac: f64,
}

impl Default for SynthParams {
    fn default() -> Self {
        SynthParams {
            lo: 0.0,
            hi: 100.0,
            n: 500,
            components: vec![
                MixComponent { mean: 15.0, std: 5.0, weight: 0.4 },
                MixComponent { mean: 50.0, std: 7.0, weight: 0.3 },
                MixComponent { mean: 85.0, std: 4.0, weight: 0.3 },
            ],
            gaussian_mean_frac: 0.5,
            gaussian_std_frac: 0.15,
        }
    }
}

/// Draw `params.n` samples of the given kind, clamped into
/// `[params.lo, params.hi]` by resampling (rejection), so the constraint
/// "samples are constrained in the range [0, 100]" holds without the
/// boundary atoms a hard clamp would create.
pub fn sample(kind: SynthKind, params: &SynthParams, rng: &mut Pcg32) -> Vec<f64> {
    let mut out = Vec::with_capacity(params.n);
    let weights: Vec<f64> = params.components.iter().map(|c| c.weight).collect();
    while out.len() < params.n {
        let x = match kind {
            SynthKind::Uniform => rng.uniform(params.lo, params.hi),
            SynthKind::SingleGaussian => {
                let mean = params.lo + params.gaussian_mean_frac * (params.hi - params.lo);
                let std = params.gaussian_std_frac * (params.hi - params.lo);
                rng.normal_with(mean, std)
            }
            SynthKind::MixtureOfGaussians => {
                let c = rng
                    .weighted_index(&weights)
                    .expect("mixture must have positive weights");
                let comp = params.components[c];
                rng.normal_with(comp.mean, comp.std)
            }
        };
        if x >= params.lo && x <= params.hi {
            out.push(x);
        }
    }
    out
}

/// Histogram of `data` with `bins` equal-width bins over `[lo, hi]`.
/// Used to render Figure 7.
pub fn histogram(data: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in data {
        if x < lo || x > hi {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(kind: SynthKind) -> Vec<f64> {
        let mut rng = Pcg32::seeded(7);
        sample(kind, &SynthParams::default(), &mut rng)
    }

    #[test]
    fn sample_counts_and_range() {
        for kind in SynthKind::ALL {
            let xs = gen(kind);
            assert_eq!(xs.len(), 500);
            assert!(xs.iter().all(|&x| (0.0..=100.0).contains(&x)), "{kind:?}");
        }
    }

    #[test]
    fn uniform_covers_range() {
        let xs = gen(SynthKind::Uniform);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(lo < 10.0 && hi > 90.0, "lo={lo} hi={hi}");
    }

    #[test]
    fn single_gaussian_concentrated() {
        let xs = gen(SynthKind::SingleGaussian);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 50.0).abs() < 3.0, "mean={mean}");
        let frac_mid = xs.iter().filter(|&&x| (20.0..=80.0).contains(&x)).count() as f64
            / xs.len() as f64;
        assert!(frac_mid > 0.9);
    }

    #[test]
    fn mixture_is_multimodal() {
        let xs = gen(SynthKind::MixtureOfGaussians);
        let h = histogram(&xs, 0.0, 100.0, 10);
        // Modes near bins 1, 5, 8; the valley bins must be sparse relative
        // to the mode bins.
        assert!(h[1] > h[3], "hist={h:?}");
        assert!(h[5] > h[3] || h[4] > h[3], "hist={h:?}");
        assert!(h[8] > h[6], "hist={h:?}");
    }

    #[test]
    fn histogram_sums_to_len() {
        let xs = gen(SynthKind::Uniform);
        let h = histogram(&xs, 0.0, 100.0, 17);
        assert_eq!(h.iter().sum::<usize>(), xs.len());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Pcg32::seeded(9);
        let mut r2 = Pcg32::seeded(9);
        let p = SynthParams::default();
        assert_eq!(
            sample(SynthKind::MixtureOfGaussians, &p, &mut r1),
            sample(SynthKind::MixtureOfGaussians, &p, &mut r2)
        );
    }
}
