//! Data substrates (S15–S16): deterministic RNG, the paper's synthetic 1-d
//! distributions (§4.3), and the procedural digit-image corpus substituted
//! for MNIST (DESIGN §2).

pub mod distributions;
pub mod rng;
pub mod synth_digits;
