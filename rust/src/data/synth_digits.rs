//! Procedural digit-image corpus — the MNIST substitute (DESIGN §2).
//!
//! The paper's §4.1/§4.2 experiments need (a) a 28×28 grayscale digit
//! corpus in `[0,1]` to train a 784-256-128-64-10 MLP on, and (b) single
//! digit images to quantize. MNIST itself is not available in this offline
//! environment, so we render digits procedurally: each digit class is a set
//! of stroke polylines in a unit box, drawn with an anti-aliased
//! distance-field pen, under random affine jitter (shift/scale/rotation),
//! stroke-width variation and additive Gaussian pixel noise.
//!
//! Why the substitution preserves the experiments: the quantization results
//! depend on the *value distribution* of images (smooth strokes over a dark
//! background, values in `[0,1]` with a large zero mass) and on the MLP
//! last-layer weight distribution that training induces — both of which
//! this corpus reproduces. Nothing in the paper depends on MNIST-specific
//! label semantics.

use super::rng::Pcg32;

/// Image side length (MNIST-compatible 28×28).
pub const SIDE: usize = 28;
/// Pixels per image.
pub const PIXELS: usize = SIDE * SIDE;
/// Number of classes.
pub const CLASSES: usize = 10;

/// A rendered digit.
#[derive(Debug, Clone)]
pub struct DigitImage {
    /// Row-major 28×28 grayscale in `[0,1]`.
    pub pixels: Vec<f64>,
    /// Class label 0–9.
    pub label: usize,
}

/// A labeled dataset.
#[derive(Debug, Clone, Default)]
pub struct DigitDataset {
    /// The images.
    pub images: Vec<DigitImage>,
}

impl DigitDataset {
    /// Number of images.
    pub fn len(&self) -> usize {
        self.images.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// Stroke templates per digit, as polylines in the unit square
/// (x →, y ↓). Hand-tuned to be legible and mutually distinguishable.
fn strokes(digit: usize) -> Vec<Vec<(f64, f64)>> {
    match digit {
        0 => vec![vec![
            (0.50, 0.12),
            (0.74, 0.22),
            (0.80, 0.50),
            (0.74, 0.78),
            (0.50, 0.88),
            (0.26, 0.78),
            (0.20, 0.50),
            (0.26, 0.22),
            (0.50, 0.12),
        ]],
        1 => vec![vec![(0.38, 0.26), (0.54, 0.12), (0.54, 0.88)]],
        2 => vec![vec![
            (0.24, 0.28),
            (0.36, 0.14),
            (0.62, 0.13),
            (0.76, 0.28),
            (0.72, 0.46),
            (0.30, 0.72),
            (0.22, 0.88),
            (0.80, 0.88),
        ]],
        3 => vec![vec![
            (0.24, 0.18),
            (0.58, 0.13),
            (0.74, 0.28),
            (0.58, 0.46),
            (0.42, 0.48),
            (0.58, 0.50),
            (0.76, 0.66),
            (0.60, 0.86),
            (0.24, 0.82),
        ]],
        4 => vec![
            vec![(0.62, 0.88), (0.62, 0.12), (0.22, 0.62), (0.80, 0.62)],
        ],
        5 => vec![vec![
            (0.74, 0.13),
            (0.30, 0.13),
            (0.27, 0.46),
            (0.58, 0.42),
            (0.76, 0.58),
            (0.70, 0.82),
            (0.40, 0.89),
            (0.24, 0.80),
        ]],
        6 => vec![vec![
            (0.68, 0.14),
            (0.40, 0.26),
            (0.26, 0.52),
            (0.28, 0.78),
            (0.52, 0.89),
            (0.72, 0.76),
            (0.70, 0.56),
            (0.50, 0.48),
            (0.30, 0.58),
        ]],
        7 => vec![vec![(0.22, 0.14), (0.78, 0.14), (0.46, 0.88)]],
        8 => vec![
            vec![
                (0.50, 0.12),
                (0.70, 0.22),
                (0.68, 0.40),
                (0.50, 0.48),
                (0.32, 0.40),
                (0.30, 0.22),
                (0.50, 0.12),
            ],
            vec![
                (0.50, 0.48),
                (0.74, 0.60),
                (0.72, 0.80),
                (0.50, 0.89),
                (0.28, 0.80),
                (0.26, 0.60),
                (0.50, 0.48),
            ],
        ],
        9 => vec![vec![
            (0.70, 0.42),
            (0.50, 0.52),
            (0.30, 0.44),
            (0.28, 0.24),
            (0.48, 0.12),
            (0.70, 0.20),
            (0.72, 0.42),
            (0.68, 0.72),
            (0.50, 0.88),
        ]],
        _ => panic!("digit out of range: {digit}"),
    }
}

/// Distance from point `p` to segment `(a, b)`.
fn seg_dist(p: (f64, f64), a: (f64, f64), b: (f64, f64)) -> f64 {
    let (px, py) = p;
    let (ax, ay) = a;
    let (bx, by) = b;
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 <= 1e-18 {
        0.0
    } else {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Jitter parameters for one rendering.
#[derive(Debug, Clone, Copy)]
struct Jitter {
    dx: f64,
    dy: f64,
    scale: f64,
    rot: f64,
    width: f64,
    noise: f64,
}

impl Jitter {
    fn sample(rng: &mut Pcg32) -> Jitter {
        // Aggressive enough that a well-trained MLP lands in the high-90s
        // rather than at 100% — mirroring the paper's 98.9%/97.5% regime so
        // the quantization-accuracy cliffs (Fig 1/2) are informative.
        Jitter {
            dx: rng.uniform(-0.12, 0.12),
            dy: rng.uniform(-0.12, 0.12),
            scale: rng.uniform(0.72, 1.22),
            rot: rng.uniform(-0.35, 0.35),
            width: rng.uniform(0.028, 0.068),
            noise: 0.12,
        }
    }

    /// Canonical rendering (no jitter) for the Fig 5/6 image experiments.
    fn none() -> Jitter {
        Jitter { dx: 0.0, dy: 0.0, scale: 1.0, rot: 0.0, width: 0.05, noise: 0.0 }
    }

    fn apply(&self, (x, y): (f64, f64)) -> (f64, f64) {
        // Rotate/scale about the box center, then translate.
        let (cx, cy) = (0.5, 0.5);
        let (ux, uy) = (x - cx, y - cy);
        let (c, s) = (self.rot.cos(), self.rot.sin());
        (
            cx + self.scale * (c * ux - s * uy) + self.dx,
            cy + self.scale * (s * ux + c * uy) + self.dy,
        )
    }
}

fn render(digit: usize, jit: Jitter, rng: Option<&mut Pcg32>) -> Vec<f64> {
    let polys: Vec<Vec<(f64, f64)>> = strokes(digit)
        .into_iter()
        .map(|poly| poly.into_iter().map(|p| jit.apply(p)).collect())
        .collect();

    let mut px = vec![0.0f64; PIXELS];
    let inv = 1.0 / SIDE as f64;
    for row in 0..SIDE {
        for col in 0..SIDE {
            let p = ((col as f64 + 0.5) * inv, (row as f64 + 0.5) * inv);
            let mut dmin = f64::INFINITY;
            for poly in &polys {
                for seg in poly.windows(2) {
                    dmin = dmin.min(seg_dist(p, seg[0], seg[1]));
                }
            }
            // Anti-aliased pen: full ink inside the stroke core, smooth
            // falloff over one pixel.
            let inner = jit.width;
            let outer = jit.width + inv;
            let v = if dmin <= inner {
                1.0
            } else if dmin >= outer {
                0.0
            } else {
                1.0 - (dmin - inner) / (outer - inner)
            };
            px[row * SIDE + col] = v;
        }
    }
    if let Some(rng) = rng {
        if jit.noise > 0.0 {
            for v in &mut px {
                *v = (*v + rng.normal_with(0.0, jit.noise)).clamp(0.0, 1.0);
            }
        }
    }
    px
}

/// Render a jittered digit.
pub fn render_digit(digit: usize, rng: &mut Pcg32) -> DigitImage {
    let jit = Jitter::sample(rng);
    DigitImage { pixels: render(digit, jit, Some(rng)), label: digit }
}

/// Render the canonical (jitter-free, noise-free) digit used by the image
/// quantization experiments (Fig 5/6).
pub fn canonical_digit(digit: usize) -> DigitImage {
    DigitImage { pixels: render(digit, Jitter::none(), None), label: digit }
}

/// Generate a balanced dataset of `n` images (labels cycle 0–9).
pub fn generate(n: usize, seed: u64) -> DigitDataset {
    let mut rng = Pcg32::new(seed, 31);
    let images = (0..n).map(|i| render_digit(i % CLASSES, &mut rng)).collect();
    DigitDataset { images }
}

/// ASCII rendering for reports/examples (darker = denser glyph).
pub fn to_ascii(pixels: &[f64]) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let mut s = String::with_capacity((SIDE + 1) * SIDE);
    for row in 0..SIDE {
        for col in 0..SIDE {
            let v = pixels[row * SIDE + col].clamp(0.0, 1.0);
            let idx = ((v * (RAMP.len() - 1) as f64).round() as usize).min(RAMP.len() - 1);
            s.push(RAMP[idx] as char);
        }
        s.push('\n');
    }
    s
}

/// Write a binary PGM (P5, 8-bit) for external viewing.
pub fn to_pgm(pixels: &[f64]) -> Vec<u8> {
    let mut out = format!("P5\n{SIDE} {SIDE}\n255\n").into_bytes();
    out.extend(pixels.iter().map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_digits_in_range() {
        let mut rng = Pcg32::seeded(1);
        for d in 0..CLASSES {
            let img = render_digit(d, &mut rng);
            assert_eq!(img.pixels.len(), PIXELS);
            assert_eq!(img.label, d);
            assert!(img.pixels.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn digits_have_ink_and_background() {
        for d in 0..CLASSES {
            let img = canonical_digit(d);
            let ink = img.pixels.iter().filter(|&&v| v > 0.5).count();
            let bg = img.pixels.iter().filter(|&&v| v < 0.1).count();
            assert!(ink > 20, "digit {d} has too little ink ({ink})");
            assert!(bg > PIXELS / 2, "digit {d} has too little background ({bg})");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Canonical renderings must differ pairwise by a sizable l2 margin
        // (sanity for trainability).
        let imgs: Vec<_> = (0..CLASSES).map(canonical_digit).collect();
        for a in 0..CLASSES {
            for b in (a + 1)..CLASSES {
                let d2: f64 = imgs[a]
                    .pixels
                    .iter()
                    .zip(&imgs[b].pixels)
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(d2 > 4.0, "digits {a} and {b} too similar (d²={d2:.2})");
            }
        }
    }

    #[test]
    fn jitter_produces_variety_with_bounded_drift() {
        let mut rng = Pcg32::seeded(2);
        let canon = canonical_digit(3);
        let l2 = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let samples: Vec<_> = (0..5).map(|_| render_digit(3, &mut rng)).collect();
        for img in &samples {
            // Bounded drift: still recognizably a stroke image near the
            // canonical glyph (noise floor alone is ~784·0.04² ≈ 1.3).
            let d = l2(&img.pixels, &canon.pixels);
            assert!(d < 300.0, "jittered 3 unreasonably far from canonical ({d:.1})");
        }
        // Variety: jittered renderings differ from each other.
        let d01 = l2(&samples[0].pixels, &samples[1].pixels);
        assert!(d01 > 0.5, "jitter produced near-identical images ({d01:.3})");
    }

    #[test]
    fn generate_is_balanced_and_deterministic() {
        let a = generate(50, 9);
        let b = generate(50, 9);
        assert_eq!(a.len(), 50);
        for d in 0..CLASSES {
            assert_eq!(a.images.iter().filter(|i| i.label == d).count(), 5);
        }
        for (x, y) in a.images.iter().zip(&b.images) {
            assert_eq!(x.pixels, y.pixels);
        }
        let c = generate(50, 10);
        assert_ne!(a.images[0].pixels, c.images[0].pixels);
    }

    #[test]
    fn ascii_and_pgm_shapes() {
        let img = canonical_digit(0);
        let a = to_ascii(&img.pixels);
        assert_eq!(a.lines().count(), SIDE);
        let p = to_pgm(&img.pixels);
        assert!(p.len() > PIXELS);
        assert!(p.starts_with(b"P5\n28 28\n255\n"));
    }

    #[test]
    fn seg_dist_basics() {
        assert!((seg_dist((0.0, 1.0), (0.0, 0.0), (1.0, 0.0)) - 1.0).abs() < 1e-12);
        assert!((seg_dist((2.0, 0.0), (0.0, 0.0), (1.0, 0.0)) - 1.0).abs() < 1e-12);
        assert!(seg_dist((0.5, 0.0), (0.0, 0.0), (1.0, 0.0)) < 1e-12);
        // Degenerate segment = point distance.
        assert!((seg_dist((3.0, 4.0), (0.0, 0.0), (0.0, 0.0)) - 5.0).abs() < 1e-12);
    }
}
