//! Agglomerative 1-d quantization (paper ref [11]: Xiang & Joy 1994 used
//! agglomerative clustering for color quantization).
//!
//! Classic bottom-up scheme on the value axis: start with every distinct
//! value as its own cluster and repeatedly merge the adjacent pair with the
//! minimal Ward cost `W₁W₂/(W₁+W₂)·(μ₁−μ₂)²` until `k` clusters remain.
//! In 1-d only adjacent merges can be optimal, so the pair scan is exact.
//! Deterministic — no seeds, no restarts — which makes it a useful contrast
//! to the randomness-dependence the paper critiques in k-means.
//!
//! Implementation delegates the merge loop to
//! [`crate::quant::merge::merge_to_target`] over the sorted values.

use crate::quant::merge::merge_to_target;
use crate::{Error, Result};

/// Agglomerative result.
#[derive(Debug, Clone)]
pub struct AgglomResult {
    /// Final cluster representatives (sorted, weighted means).
    pub centroids: Vec<f64>,
    /// Cluster index per input point (original order).
    pub assignment: Vec<usize>,
    /// Weighted within-cluster sum of squares.
    pub inertia: f64,
}

/// Weighted agglomerative clustering of 1-d data down to `k` clusters.
pub fn agglomerative_1d(data: &[f64], weights: Option<&[f64]>, k: usize) -> Result<AgglomResult> {
    if data.is_empty() {
        return Err(Error::InvalidInput("agglomerative: empty data".into()));
    }
    if k == 0 {
        return Err(Error::InvalidParam("agglomerative: k must be ≥ 1".into()));
    }
    if let Some(w) = weights {
        if w.len() != data.len() {
            return Err(Error::InvalidInput("agglomerative: weights length mismatch".into()));
        }
    }
    let n = data.len();
    // Sort once; merge_to_target works on a piecewise-constant vector over
    // the sorted axis, which "all-distinct" trivially is.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| data[a].partial_cmp(&data[b]).unwrap());
    let sorted: Vec<f64> = order.iter().map(|&i| data[i]).collect();
    let sorted_w: Option<Vec<f64>> = weights.map(|w| order.iter().map(|&i| w[i]).collect());

    let merged = merge_to_target(&sorted, sorted_w.as_deref(), k);

    // Extract centroids + assignment.
    let mut centroids: Vec<f64> = merged.clone();
    centroids.dedup();
    let mut assignment = vec![0usize; n];
    for (pos, &orig) in order.iter().enumerate() {
        let c = centroids
            .binary_search_by(|p| p.partial_cmp(&merged[pos]).unwrap())
            .unwrap_or_else(|e| e.min(centroids.len() - 1));
        assignment[orig] = c;
    }
    let mut inertia = 0.0;
    for i in 0..n {
        let w = weights.map_or(1.0, |ws| ws[i]);
        inertia += w * (data[i] - centroids[assignment[i]]).powi(2);
    }
    Ok(AgglomResult { centroids, assignment, inertia })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::kmeans::{kmeans_1d, KMeansConfig};
    use crate::data::rng::Pcg32;

    #[test]
    fn merges_tight_groups_first() {
        let data = vec![1.0, 1.01, 5.0, 9.0, 9.02];
        let r = agglomerative_1d(&data, None, 3).unwrap();
        assert_eq!(r.centroids.len(), 3);
        assert!((r.centroids[0] - 1.005).abs() < 1e-9);
        assert!((r.centroids[1] - 5.0).abs() < 1e-9);
        assert!((r.centroids[2] - 9.01).abs() < 1e-9);
        assert_eq!(r.assignment, vec![0, 0, 1, 2, 2]);
    }

    #[test]
    fn deterministic_no_seed_dependence() {
        let mut rng = Pcg32::seeded(1);
        let data: Vec<f64> = (0..200).map(|_| rng.uniform(0.0, 50.0)).collect();
        let a = agglomerative_1d(&data, None, 8).unwrap();
        let b = agglomerative_1d(&data, None, 8).unwrap();
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn competitive_with_kmeans() {
        let mut rng = Pcg32::seeded(2);
        let data: Vec<f64> = (0..300)
            .map(|i| rng.normal_with((i % 4) as f64 * 10.0, 0.6))
            .collect();
        let ag = agglomerative_1d(&data, None, 4).unwrap();
        let km = kmeans_1d(&data, None, &KMeansConfig { k: 4, ..Default::default() }).unwrap();
        assert!(ag.inertia <= km.inertia * 2.0, "ag {} vs km {}", ag.inertia, km.inertia);
    }

    #[test]
    fn weighted_merging() {
        let data = vec![0.0, 1.0, 10.0];
        let r = agglomerative_1d(&data, Some(&[100.0, 1.0, 1.0]), 2).unwrap();
        // 0 and 1 merge (closest); mean pulled hard toward 0.
        assert!(r.centroids[0] < 0.05, "{:?}", r.centroids);
        assert_eq!(r.centroids.len(), 2);
    }

    #[test]
    fn k_geq_distinct_is_lossless() {
        let data = vec![3.0, 1.0, 2.0, 1.0];
        let r = agglomerative_1d(&data, None, 5).unwrap();
        assert!(r.inertia < 1e-12);
        assert_eq!(r.centroids.len(), 3);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(agglomerative_1d(&[], None, 2).is_err());
        assert!(agglomerative_1d(&[1.0], None, 0).is_err());
        assert!(agglomerative_1d(&[1.0], Some(&[1.0, 2.0]), 1).is_err());
    }
}
