//! Mixture-of-Gaussians (EM) quantization — the paper's second baseline.
//!
//! Follows the soft weight-sharing lineage the paper cites ([15] Nowlan &
//! Hinton 1992, [16] Ullrich et al. 2017): fit a k-component 1-d GMM to the
//! values by EM, then quantize each value to the mean of its
//! maximum-responsibility component ("the membership should be computed by
//! taking argmax").
//!
//! Numerically careful: responsibilities in log-space, variance floors, and
//! component-collapse repair (a component whose weight underflows is
//! re-seeded at the point with the worst likelihood).

use crate::data::rng::Pcg32;
use crate::{Error, Result};

/// Configuration for [`gmm_1d`].
#[derive(Debug, Clone)]
pub struct GmmConfig {
    /// Number of mixture components.
    pub k: usize,
    /// EM iteration budget.
    pub max_iters: usize,
    /// Convergence threshold on mean log-likelihood improvement.
    pub tol: f64,
    /// RNG seed (initialization).
    pub seed: u64,
}

impl Default for GmmConfig {
    fn default() -> Self {
        GmmConfig { k: 8, max_iters: 200, tol: 1e-9, seed: 0 }
    }
}

/// Fitted mixture + hard assignments.
#[derive(Debug, Clone)]
pub struct GmmResult {
    /// Component means (sorted ascending).
    pub means: Vec<f64>,
    /// Component standard deviations (aligned with `means`).
    pub stds: Vec<f64>,
    /// Mixing weights (aligned, sum to 1).
    pub weights: Vec<f64>,
    /// Argmax-responsibility component per input point.
    pub assignment: Vec<usize>,
    /// Final mean log-likelihood.
    pub log_likelihood: f64,
    /// EM iterations consumed.
    pub iterations: usize,
    /// Converged within budget?
    pub converged: bool,
}

#[inline]
fn log_gauss(x: f64, mean: f64, var: f64) -> f64 {
    let d = x - mean;
    -0.5 * (d * d / var + var.ln() + (2.0 * std::f64::consts::PI).ln())
}

#[inline]
fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m.is_infinite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Fit a weighted 1-d GMM by EM. `point_weights` carries value
/// multiplicities (same convention as k-means).
pub fn gmm_1d(data: &[f64], point_weights: Option<&[f64]>, cfg: &GmmConfig) -> Result<GmmResult> {
    if data.is_empty() {
        return Err(Error::InvalidInput("gmm: empty data".into()));
    }
    if cfg.k == 0 {
        return Err(Error::InvalidParam("gmm: k must be ≥ 1".into()));
    }
    let n = data.len();
    let ones;
    let pw: &[f64] = match point_weights {
        Some(w) => {
            if w.len() != n {
                return Err(Error::InvalidInput("gmm: weights length mismatch".into()));
            }
            w
        }
        None => {
            ones = vec![1.0; n];
            &ones
        }
    };
    let total_w: f64 = pw.iter().sum();
    let k = cfg.k.min(n);

    // Initialization: k-means++-style spread means, global variance.
    let mut rng = Pcg32::new(cfg.seed, 77);
    let gmean = data.iter().zip(pw).map(|(x, w)| x * w).sum::<f64>() / total_w;
    let gvar = data
        .iter()
        .zip(pw)
        .map(|(x, w)| w * (x - gmean) * (x - gmean))
        .sum::<f64>()
        / total_w;
    let span = crate::linalg::stats::max(data) - crate::linalg::stats::min(data);
    let var_floor = (1e-6 * span * span).max(1e-12);

    let mut means: Vec<f64> = {
        let first = rng.weighted_index(pw).unwrap_or(0);
        let mut ms = vec![data[first]];
        let mut d2: Vec<f64> = data.iter().map(|&x| (x - data[first]).powi(2)).collect();
        while ms.len() < k {
            let idx = rng.weighted_index(&d2).unwrap_or_else(|| rng.gen_range(n));
            ms.push(data[idx]);
            for i in 0..n {
                d2[i] = d2[i].min((data[i] - data[idx]).powi(2));
            }
        }
        ms
    };
    let mut vars = vec![gvar.max(var_floor); k];
    let mut mix = vec![1.0 / k as f64; k];

    let mut resp = vec![0.0f64; n * k]; // responsibilities, row-major [n][k]
    let mut prev_ll = f64::NEG_INFINITY;
    let mut iterations = 0usize;
    let mut converged = false;
    let mut logp = vec![0.0f64; k];

    for _ in 0..cfg.max_iters {
        iterations += 1;
        // E-step (log-space).
        let mut ll = 0.0;
        for i in 0..n {
            for c in 0..k {
                logp[c] = mix[c].max(1e-300).ln() + log_gauss(data[i], means[c], vars[c]);
            }
            let lse = log_sum_exp(&logp);
            ll += pw[i] * lse;
            for c in 0..k {
                resp[i * k + c] = (logp[c] - lse).exp();
            }
        }
        ll /= total_w;

        // M-step (weighted by point multiplicities).
        for c in 0..k {
            let mut nk = 0.0;
            let mut sx = 0.0;
            for i in 0..n {
                let r = pw[i] * resp[i * k + c];
                nk += r;
                sx += r * data[i];
            }
            if nk < 1e-12 * total_w {
                // Collapse repair: re-seed at the point worst explained.
                let worst = (0..n)
                    .max_by(|&a, &b| {
                        let la = (0..k)
                            .map(|cc| mix[cc].max(1e-300).ln() + log_gauss(data[a], means[cc], vars[cc]))
                            .fold(f64::NEG_INFINITY, f64::max);
                        let lb = (0..k)
                            .map(|cc| mix[cc].max(1e-300).ln() + log_gauss(data[b], means[cc], vars[cc]))
                            .fold(f64::NEG_INFINITY, f64::max);
                        lb.partial_cmp(&la).unwrap() // min likelihood = max badness
                    })
                    .unwrap_or(0);
                means[c] = data[worst];
                vars[c] = gvar.max(var_floor);
                mix[c] = 1.0 / k as f64;
                continue;
            }
            means[c] = sx / nk;
            let mut sv = 0.0;
            for i in 0..n {
                let r = pw[i] * resp[i * k + c];
                sv += r * (data[i] - means[c]) * (data[i] - means[c]);
            }
            vars[c] = (sv / nk).max(var_floor);
            mix[c] = nk / total_w;
        }
        // Renormalize mixing weights (repair may have broken the simplex).
        let ms: f64 = mix.iter().sum();
        for m in &mut mix {
            *m /= ms;
        }

        if (ll - prev_ll).abs() < cfg.tol {
            prev_ll = ll;
            converged = true;
            break;
        }
        prev_ll = ll;
    }

    // Hard assignment by argmax responsibility against final params.
    let mut assignment = vec![0usize; n];
    for i in 0..n {
        let mut best = f64::NEG_INFINITY;
        for c in 0..k {
            let lp = mix[c].max(1e-300).ln() + log_gauss(data[i], means[c], vars[c]);
            if lp > best {
                best = lp;
                assignment[i] = c;
            }
        }
    }

    // Sort components by mean, remapping everything.
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| means[a].partial_cmp(&means[b]).unwrap());
    let inv: Vec<usize> = {
        let mut inv = vec![0; k];
        for (new, &old) in order.iter().enumerate() {
            inv[old] = new;
        }
        inv
    };
    let means_s: Vec<f64> = order.iter().map(|&i| means[i]).collect();
    let stds_s: Vec<f64> = order.iter().map(|&i| vars[i].sqrt()).collect();
    let mix_s: Vec<f64> = order.iter().map(|&i| mix[i]).collect();
    for a in &mut assignment {
        *a = inv[*a];
    }

    Ok(GmmResult {
        means: means_s,
        stds: stds_s,
        weights: mix_s,
        assignment,
        log_likelihood: prev_ll,
        iterations,
        converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bimodal(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|i| {
                if i % 2 == 0 {
                    rng.normal_with(0.0, 0.5)
                } else {
                    rng.normal_with(10.0, 0.5)
                }
            })
            .collect()
    }

    #[test]
    fn recovers_two_modes() {
        let data = bimodal(400, 1);
        let r = gmm_1d(&data, None, &GmmConfig { k: 2, ..Default::default() }).unwrap();
        assert!((r.means[0] - 0.0).abs() < 0.3, "means={:?}", r.means);
        assert!((r.means[1] - 10.0).abs() < 0.3);
        assert!((r.weights[0] - 0.5).abs() < 0.1);
        assert!(r.stds[0] < 1.0 && r.stds[1] < 1.0);
    }

    #[test]
    fn assignment_separates_modes() {
        let data = bimodal(200, 2);
        let r = gmm_1d(&data, None, &GmmConfig { k: 2, ..Default::default() }).unwrap();
        for (i, &x) in data.iter().enumerate() {
            if x < 5.0 {
                assert_eq!(r.assignment[i], 0, "x={x}");
            } else {
                assert_eq!(r.assignment[i], 1, "x={x}");
            }
        }
    }

    #[test]
    fn means_sorted_weights_normalized() {
        let mut rng = Pcg32::seeded(3);
        let data: Vec<f64> = (0..300).map(|_| rng.uniform(0.0, 50.0)).collect();
        let r = gmm_1d(&data, None, &GmmConfig { k: 6, ..Default::default() }).unwrap();
        assert!(r.means.windows(2).all(|p| p[0] <= p[1]));
        assert!((r.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(r.assignment.iter().all(|&a| a < r.means.len()));
    }

    #[test]
    fn weighted_pulls_means() {
        let vals = [0.0, 1.0, 9.0, 10.0];
        let heavy_low = gmm_1d(
            &vals,
            Some(&[50.0, 50.0, 1.0, 1.0]),
            &GmmConfig { k: 2, ..Default::default() },
        )
        .unwrap();
        // Low cluster dominates the mixture weight.
        assert!(heavy_low.weights[0] > 0.8, "weights={:?}", heavy_low.weights);
    }

    #[test]
    fn loglik_non_decreasing_overall() {
        let data = bimodal(100, 4);
        let short = gmm_1d(&data, None, &GmmConfig { k: 3, max_iters: 2, ..Default::default() })
            .unwrap();
        let long = gmm_1d(&data, None, &GmmConfig { k: 3, max_iters: 100, ..Default::default() })
            .unwrap();
        assert!(long.log_likelihood >= short.log_likelihood - 1e-6);
    }

    #[test]
    fn deterministic_per_seed() {
        let data = bimodal(100, 5);
        let cfg = GmmConfig { k: 3, seed: 9, ..Default::default() };
        let a = gmm_1d(&data, None, &cfg).unwrap();
        let b = gmm_1d(&data, None, &cfg).unwrap();
        assert_eq!(a.means, b.means);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(gmm_1d(&[], None, &GmmConfig::default()).is_err());
        assert!(gmm_1d(&[1.0], None, &GmmConfig { k: 0, ..Default::default() }).is_err());
        assert!(gmm_1d(&[1.0], Some(&[1.0, 2.0]), &GmmConfig::default()).is_err());
    }

    #[test]
    fn degenerate_identical_points() {
        let r = gmm_1d(&[2.0; 20], None, &GmmConfig { k: 3, ..Default::default() }).unwrap();
        // All means collapse to 2.0; must not NaN.
        for m in &r.means {
            assert!((m - 2.0).abs() < 1e-6);
            assert!(m.is_finite());
        }
    }
}
