//! Data-transformation clustering — the paper's third baseline ([9], Azimi
//! et al., "A novel clustering algorithm based on data transformation
//! approaches", Expert Systems with Applications 76, 2017).
//!
//! Reimplemented from the citation (the original code is not available in
//! this environment — see DESIGN §2): the method reshapes the data with a
//! smooth monotone transformation before clustering so that dense regions
//! spread out, clusters in the *transformed* space, and maps the result
//! back. We use the paper family's logistic/power transform pipeline:
//!
//! 1. min-max normalize to `[0, 1]`;
//! 2. apply the monotone transform `T(x) = x^γ` with `γ` chosen from the
//!    data skewness (γ < 1 stretches the low tail, γ > 1 the high tail);
//! 3. logistic-center: `L(x) = 1 / (1 + e^{−s(x − x̄)})` with slope `s`
//!    matched to the normalized spread;
//! 4. k-means (Lloyd, k-means++, restarts) in the transformed space;
//! 5. assignment is carried back; representative values are computed in the
//!    *original* space as cluster means (inverse-transforming centroids
//!    directly would bias them — this matches how transformation-based
//!    clustering is used for quantization).
//!
//! The expected experimental signature (paper §4): ≈ k-means on
//! neural-network weight matrices (near-symmetric data, transform ≈
//! affine), *worse* than k-means on the skewed/multimodal synthetic data —
//! the transform distorts distances exactly where geometry matters.

use super::kmeans::{kmeans_1d, KMeansConfig};
use crate::linalg::stats;
use crate::{Error, Result};

/// Configuration for [`data_transform_cluster`].
#[derive(Debug, Clone)]
pub struct DataTransformConfig {
    /// Number of clusters.
    pub k: usize,
    /// Restarts for the inner k-means.
    pub restarts: usize,
    /// Lloyd iteration budget.
    pub max_iters: usize,
    /// RNG seed.
    pub seed: u64,
    /// Logistic slope multiplier (paper-family default 4).
    pub logistic_slope: f64,
}

impl Default for DataTransformConfig {
    fn default() -> Self {
        DataTransformConfig { k: 8, restarts: 10, max_iters: 300, seed: 0, logistic_slope: 4.0 }
    }
}

/// Result: assignments plus original-space representatives.
#[derive(Debug, Clone)]
pub struct DataTransformResult {
    /// Cluster representative values in the ORIGINAL space (sorted).
    pub centroids: Vec<f64>,
    /// Cluster index per input point.
    pub assignment: Vec<usize>,
    /// Inertia measured in the original space.
    pub inertia: f64,
    /// The γ exponent chosen from skewness (diagnostic).
    pub gamma: f64,
    /// Inner k-means Lloyd iterations.
    pub iterations: usize,
}

/// Sample skewness (Fisher-Pearson); 0 for degenerate data.
fn skewness(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 3.0 {
        return 0.0;
    }
    let m = stats::mean(xs);
    let s = stats::std_dev(xs);
    if s <= 1e-300 {
        return 0.0;
    }
    xs.iter().map(|x| ((x - m) / s).powi(3)).sum::<f64>() / n
}

/// The forward transform pipeline (normalize → power → centered logistic).
pub fn transform(xs: &[f64], gamma: f64, slope: f64) -> Vec<f64> {
    let lo = stats::min(xs);
    let hi = stats::max(xs);
    let span = (hi - lo).max(1e-300);
    let norm: Vec<f64> = xs.iter().map(|&x| ((x - lo) / span).clamp(0.0, 1.0)).collect();
    let powed: Vec<f64> = norm.iter().map(|&x| x.powf(gamma)).collect();
    let center = stats::mean(&powed);
    powed
        .iter()
        .map(|&x| 1.0 / (1.0 + (-slope * (x - center)).exp()))
        .collect()
}

/// Pick γ from skewness: right-skew (tail high) → γ < 1 compresses the
/// tail; left-skew → γ > 1. Clamped to a sane range.
pub fn gamma_from_skewness(skew: f64) -> f64 {
    (1.0 + 0.35 * skew).clamp(0.4, 2.5)
}

/// Run transformation-based clustering on weighted 1-d data.
pub fn data_transform_cluster(
    data: &[f64],
    weights: Option<&[f64]>,
    cfg: &DataTransformConfig,
) -> Result<DataTransformResult> {
    if data.is_empty() {
        return Err(Error::InvalidInput("data_transform: empty data".into()));
    }
    if cfg.k == 0 {
        return Err(Error::InvalidParam("data_transform: k must be ≥ 1".into()));
    }

    let gamma = gamma_from_skewness(skewness(data));
    let transformed = transform(data, gamma, cfg.logistic_slope);

    let km = kmeans_1d(
        &transformed,
        weights,
        &KMeansConfig {
            k: cfg.k,
            restarts: cfg.restarts,
            max_iters: cfg.max_iters,
            tol: 1e-10,
            seed: cfg.seed,
            ..Default::default()
        },
    )?;

    // Representatives in the ORIGINAL space: weighted mean per cluster.
    let kk = km.centroids.len();
    let mut sums = vec![0.0; kk];
    let mut wsum = vec![0.0; kk];
    for (i, &a) in km.assignment.iter().enumerate() {
        let w = weights.map_or(1.0, |ws| ws[i]);
        sums[a] += w * data[i];
        wsum[a] += w;
    }
    let mut reps: Vec<(f64, usize)> = (0..kk)
        .map(|c| {
            let v = if wsum[c] > 0.0 { sums[c] / wsum[c] } else { f64::NAN };
            (v, c)
        })
        .filter(|(v, _)| v.is_finite())
        .collect();
    reps.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let centroids: Vec<f64> = reps.iter().map(|&(v, _)| v).collect();
    // Remap assignment to the sorted, filtered representative order.
    let mut remap = vec![usize::MAX; kk];
    for (new, &(_, old)) in reps.iter().enumerate() {
        remap[old] = new;
    }
    let assignment: Vec<usize> = km
        .assignment
        .iter()
        .map(|&a| {
            let r = remap[a];
            if r == usize::MAX {
                // Cluster got no original-space mass (cannot happen for
                // non-empty clusters) — fall back to nearest representative.
                super::kmeans::assign_sorted(data[0], &centroids)
            } else {
                r
            }
        })
        .collect();

    let mut inertia = 0.0;
    for (i, &a) in assignment.iter().enumerate() {
        let w = weights.map_or(1.0, |ws| ws[i]);
        inertia += w * (data[i] - centroids[a]) * (data[i] - centroids[a]);
    }

    Ok(DataTransformResult {
        centroids,
        assignment,
        inertia,
        gamma,
        iterations: km.iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;

    #[test]
    fn transform_is_monotone() {
        let mut rng = Pcg32::seeded(1);
        let mut xs: Vec<f64> = (0..50).map(|_| rng.uniform(-3.0, 8.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for gamma in [0.5, 1.0, 2.0] {
            let t = transform(&xs, gamma, 4.0);
            for p in t.windows(2) {
                assert!(p[0] <= p[1] + 1e-12, "transform must preserve order");
            }
            assert!(t.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn gamma_clamps() {
        assert_eq!(gamma_from_skewness(100.0), 2.5);
        assert_eq!(gamma_from_skewness(-100.0), 0.4);
        assert!((gamma_from_skewness(0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewness_signs() {
        // Right-skewed: long high tail.
        let right = [1.0, 1.1, 1.2, 1.0, 1.1, 9.0];
        assert!(skewness(&right) > 0.5);
        let left = [9.0, 8.9, 8.8, 9.0, 8.9, 1.0];
        assert!(skewness(&left) < -0.5);
    }

    #[test]
    fn clusters_separated_data() {
        let data: Vec<f64> = vec![1.0, 1.1, 0.9, 5.0, 5.1, 4.9, 9.0, 9.1, 8.9];
        let r = data_transform_cluster(
            &data,
            None,
            &DataTransformConfig { k: 3, ..Default::default() },
        )
        .unwrap();
        assert_eq!(r.centroids.len(), 3);
        assert!((r.centroids[0] - 1.0).abs() < 0.2);
        assert!((r.centroids[2] - 9.0).abs() < 0.2);
        assert!(r.inertia < 0.5);
    }

    #[test]
    fn centroids_in_original_range() {
        let mut rng = Pcg32::seeded(2);
        let data: Vec<f64> = (0..200).map(|_| rng.uniform(0.0, 100.0)).collect();
        let r = data_transform_cluster(
            &data,
            None,
            &DataTransformConfig { k: 8, ..Default::default() },
        )
        .unwrap();
        for &c in &r.centroids {
            assert!((0.0..=100.0).contains(&c), "centroid {c} out of range");
        }
        assert!(r.centroids.windows(2).all(|p| p[0] <= p[1]));
    }

    #[test]
    fn worse_or_equal_on_skewed_synthetic() {
        // The documented signature: on skewed multimodal data the transform
        // distorts geometry, so plain k-means should win (or tie).
        let mut rng = Pcg32::seeded(3);
        let mut data = Vec::new();
        for _ in 0..150 {
            data.push(rng.normal_with(5.0, 1.0));
        }
        for _ in 0..50 {
            data.push(rng.normal_with(80.0, 3.0));
        }
        let km = kmeans_1d(
            &data,
            None,
            &KMeansConfig { k: 6, seed: 1, ..Default::default() },
        )
        .unwrap();
        let dt = data_transform_cluster(
            &data,
            None,
            &DataTransformConfig { k: 6, seed: 1, ..Default::default() },
        )
        .unwrap();
        assert!(dt.inertia >= km.inertia * 0.95, "dt={} km={}", dt.inertia, km.inertia);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Pcg32::seeded(4);
        let data: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let cfg = DataTransformConfig { k: 4, seed: 5, ..Default::default() };
        let a = data_transform_cluster(&data, None, &cfg).unwrap();
        let b = data_transform_cluster(&data, None, &cfg).unwrap();
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(data_transform_cluster(&[], None, &DataTransformConfig::default()).is_err());
        assert!(data_transform_cluster(
            &[1.0],
            None,
            &DataTransformConfig { k: 0, ..Default::default() }
        )
        .is_err());
    }
}
