//! Clustering substrates (S10–S13): the baselines the paper compares
//! against, plus the exact 1-d DP k-means ablation.

pub mod agglomerative;
pub mod data_transform;
pub mod fuzzy_cmeans;
pub mod gmm;
pub mod kmeans;
pub mod kmeans_dp;
