//! Clustering substrates (S10–S13): the baselines the paper's §4
//! experiments compare the sparse-least-square quantizers against, plus
//! two exact ablations. All of them operate on the **unique values** of
//! the input (the prepare stage's decomposition), optionally weighted by
//! multiplicity, and are surfaced as [`crate::quant::QuantMethod`]
//! variants through the solver table in `quant::pipeline`:
//!
//! * [`kmeans`] — Lloyd's with k-means++ seeding and multi-restart
//!   (the paper's principal baseline; `assign_sorted` is the shared
//!   1-d nearest-centroid primitive).
//! * [`gmm`] — 1-d Mixture-of-Gaussians via EM with variance flooring;
//!   quantization assigns each value to its max-posterior mean.
//! * [`data_transform`] — the data-transformation clustering of Azimi
//!   et al. (2017), the paper's third baseline.
//! * [`kmeans_dp`] — **exact** 1-d k-means by dynamic programming over
//!   prefix sums (ablation: how far is Lloyd's from optimal).
//! * [`agglomerative`] — bottom-up Ward merging (extension baseline).
//! * [`fuzzy_cmeans`] — fuzzy c-means with hard final assignment
//!   (extension baseline).
//!
//! The k-means partition is also the seed of the paper's Algorithm 3
//! (`quant::cluster_ls`): cluster first, then solve the exact
//! least-squares value per cluster.

pub mod agglomerative;
pub mod data_transform;
pub mod fuzzy_cmeans;
pub mod gmm;
pub mod kmeans;
pub mod kmeans_dp;
