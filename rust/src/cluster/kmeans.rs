//! 1-d k-means (Lloyd's algorithm) — the paper's primary baseline.
//!
//! Deliberately faithful to the practice the paper critiques (§1, §4):
//! k-means++ initialization, `T` restarts with different seeds keeping the
//! best inertia ("usually 5 to 10 times"), heuristic Lloyd iterations, and
//! *observable* pathologies — empty-cluster events are counted and surfaced
//! so the evaluation harness can reproduce the paper's claim that bad
//! initializations produce empty/out-of-range clusters.
//!
//! Supports per-point multiplicity weights so quantization can cluster the
//! unique values `ŵ` weighted by their counts (equivalent to clustering the
//! full vector, at `O(m)` instead of `O(n)`).

use crate::data::rng::Pcg32;
use crate::{Error, Result};

/// Initialization strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KMeansInit {
    /// k-means++ D² sampling (the robust default).
    #[default]
    KMeansPP,
    /// Classic naive init: centroids drawn uniformly from
    /// `[μ − 2.5σ, μ + 2.5σ]` of the data. This is the "bad random
    /// initialization" the paper's claim 1 critiques — it can place
    /// centroids outside the data range, and with repair disabled an empty
    /// cluster keeps its out-of-range value (§4.2's observation).
    RandomValues,
}

/// Configuration for [`kmeans_1d`].
#[derive(Debug, Clone)]
pub struct KMeansConfig {
    /// Number of clusters `k ≥ 1`.
    pub k: usize,
    /// Restarts with fresh init seeds; best inertia wins.
    pub restarts: usize,
    /// Lloyd iteration budget per restart.
    pub max_iters: usize,
    /// Convergence threshold on the largest centroid move.
    pub tol: f64,
    /// Base RNG seed.
    pub seed: u64,
    /// Initialization strategy.
    pub init: KMeansInit,
    /// Repair empty clusters by re-seeding at the farthest point. Disable
    /// to reproduce the paper's empty/out-of-range-cluster pathology.
    pub repair_empty: bool,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        KMeansConfig {
            k: 8,
            restarts: 10,
            max_iters: 300,
            tol: 1e-10,
            seed: 0,
            init: KMeansInit::KMeansPP,
            repair_empty: true,
        }
    }
}

/// Result of a k-means run.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// Final centroids, sorted ascending.
    pub centroids: Vec<f64>,
    /// Cluster index per input point (into `centroids`).
    pub assignment: Vec<usize>,
    /// Weighted within-cluster sum of squares.
    pub inertia: f64,
    /// Total Lloyd iterations across all restarts.
    pub iterations: usize,
    /// Empty-cluster repair events across all restarts (paper claim 1).
    pub empty_cluster_events: usize,
    /// Whether the winning restart converged within budget.
    pub converged: bool,
}

/// Assign each point to the nearest of the *sorted* centroids via midpoint
/// bisection — O(log k) per point instead of O(k).
#[inline]
pub fn assign_sorted(x: f64, centroids: &[f64]) -> usize {
    debug_assert!(!centroids.is_empty());
    // partition_point gives the first centroid > x; nearest is it or the
    // previous one.
    let i = centroids.partition_point(|&c| c < x);
    if i == 0 {
        0
    } else if i == centroids.len() {
        centroids.len() - 1
    } else if (x - centroids[i - 1]) <= (centroids[i] - x) {
        i - 1
    } else {
        i
    }
}

/// k-means++ seeding (weighted D² sampling).
fn kmeanspp_init(data: &[f64], weights: &[f64], k: usize, rng: &mut Pcg32) -> Vec<f64> {
    let n = data.len();
    let first = rng.weighted_index(weights).unwrap_or(0);
    let mut centroids = vec![data[first]];
    let mut d2: Vec<f64> = data
        .iter()
        .zip(weights)
        .map(|(&x, &w)| w * (x - data[first]) * (x - data[first]))
        .collect();
    while centroids.len() < k {
        let idx = match rng.weighted_index(&d2) {
            Some(i) => i,
            // All remaining distances zero (fewer distinct points than k):
            // duplicate an arbitrary point; Lloyd will report empties.
            None => rng.gen_range(n),
        };
        let c = data[idx];
        centroids.push(c);
        for i in 0..n {
            let nd = weights[i] * (data[i] - c) * (data[i] - c);
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centroids
}

/// Merge-pass assignment for SORTED data against sorted centroids:
/// O(m + k) instead of O(m log k). Fills `assignment` and the per-cluster
/// accumulators; returns the weighted inertia of this assignment.
#[allow(clippy::too_many_arguments)]
fn assign_sorted_merge(
    data: &[f64],
    weights: &[f64],
    centroids: &[f64],
    assignment: &mut [usize],
    sums: &mut [f64],
    wsum: &mut [f64],
) -> f64 {
    let k = centroids.len();
    let mut c = 0usize;
    let mut inertia = 0.0;
    for (i, (&x, &w)) in data.iter().zip(weights).enumerate() {
        // Advance the centroid cursor while the next centroid is closer.
        while c + 1 < k && (x - centroids[c + 1]).abs() <= (x - centroids[c]).abs() {
            c += 1;
        }
        assignment[i] = c;
        sums[c] += w * x;
        wsum[c] += w;
        let d = x - centroids[c];
        inertia += w * d * d;
    }
    inertia
}

struct LloydOutcome {
    centroids: Vec<f64>,
    assignment: Vec<usize>,
    inertia: f64,
    iterations: usize,
    empty_events: usize,
    converged: bool,
}

fn lloyd(
    data: &[f64],
    weights: &[f64],
    mut centroids: Vec<f64>,
    cfg: &KMeansConfig,
    data_sorted: bool,
) -> LloydOutcome {
    let n = data.len();
    let k = centroids.len();
    let mut assignment = vec![0usize; n];
    let mut sums = vec![0.0f64; k];
    let mut wsum = vec![0.0f64; k];
    let mut empty_events = 0usize;
    let mut iterations = 0usize;
    let mut converged = false;
    let mut prev_inertia = f64::INFINITY;

    for _ in 0..cfg.max_iters {
        iterations += 1;
        // Assignment step (centroids kept sorted). Sorted inputs take the
        // O(m + k) merge pass (§Perf) which also yields the inertia for
        // the relative-improvement stop.
        sums.fill(0.0);
        wsum.fill(0.0);
        let iter_inertia = if data_sorted {
            assign_sorted_merge(data, weights, &centroids, &mut assignment, &mut sums, &mut wsum)
        } else {
            let mut acc = 0.0;
            for i in 0..n {
                let a = assign_sorted(data[i], &centroids);
                assignment[i] = a;
                sums[a] += weights[i] * data[i];
                wsum[a] += weights[i];
                let d = data[i] - centroids[a];
                acc += weights[i] * d * d;
            }
            acc
        };
        // Update step + (optional) empty-cluster repair.
        let mut max_move = 0.0f64;
        for c in 0..k {
            if wsum[c] > 0.0 {
                let nc = sums[c] / wsum[c];
                max_move = max_move.max((nc - centroids[c]).abs());
                centroids[c] = nc;
            } else {
                empty_events += 1;
                if !cfg.repair_empty {
                    // Paper pathology: the empty cluster keeps whatever
                    // (possibly out-of-range) value init gave it.
                    continue;
                }
                // Repair: move to the point farthest from its centroid.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = weights[a] * (data[a] - centroids[assignment[a]]).powi(2);
                        let db = weights[b] * (data[b] - centroids[assignment[b]]).powi(2);
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap_or(0);
                max_move = f64::INFINITY; // force another iteration
                centroids[c] = data[far];
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if max_move < cfg.tol {
            converged = true;
            break;
        }
        // Relative-inertia stop (sklearn-style): Lloyd's tail oscillation
        // can keep centroid moves above any absolute tol long after the
        // objective has converged (§Perf).
        if max_move.is_finite()
            && (prev_inertia - iter_inertia).abs() <= 1e-6 * iter_inertia.max(1e-300)
        {
            converged = true;
            break;
        }
        prev_inertia = iter_inertia;
    }

    // Final assignment + inertia against the final centroids.
    let mut inertia = 0.0;
    for i in 0..n {
        let a = assign_sorted(data[i], &centroids);
        assignment[i] = a;
        inertia += weights[i] * (data[i] - centroids[a]) * (data[i] - centroids[a]);
    }
    LloydOutcome { centroids, assignment, inertia, iterations, empty_events, converged }
}

/// Weighted 1-d k-means with k-means++ init and multi-restart.
pub fn kmeans_1d(data: &[f64], weights: Option<&[f64]>, cfg: &KMeansConfig) -> Result<KMeansResult> {
    if data.is_empty() {
        return Err(Error::InvalidInput("kmeans: empty data".into()));
    }
    if cfg.k == 0 {
        return Err(Error::InvalidParam("kmeans: k must be ≥ 1".into()));
    }
    if cfg.restarts == 0 {
        return Err(Error::InvalidParam("kmeans: restarts must be ≥ 1".into()));
    }
    let ones;
    let weights = match weights {
        Some(w) => {
            if w.len() != data.len() {
                return Err(Error::InvalidInput("kmeans: weights length mismatch".into()));
            }
            w
        }
        None => {
            ones = vec![1.0; data.len()];
            &ones
        }
    };
    let k = cfg.k.min(data.len());
    let data_sorted = data.windows(2).all(|p| p[0] <= p[1]);

    let mut best: Option<LloydOutcome> = None;
    let mut total_iters = 0usize;
    let mut total_empty = 0usize;
    for t in 0..cfg.restarts {
        let mut rng = Pcg32::new(cfg.seed, 1000 + t as u64);
        let init = match cfg.init {
            KMeansInit::KMeansPP => kmeanspp_init(data, weights, k, &mut rng),
            KMeansInit::RandomValues => {
                let mean = crate::linalg::stats::weighted_mean(data, weights);
                let var = data
                    .iter()
                    .zip(weights)
                    .map(|(&x, &w)| w * (x - mean) * (x - mean))
                    .sum::<f64>()
                    / weights.iter().sum::<f64>().max(1e-300);
                let s = var.sqrt();
                let mut c: Vec<f64> =
                    (0..k).map(|_| rng.uniform(mean - 2.5 * s, mean + 2.5 * s)).collect();
                c.sort_by(|a, b| a.partial_cmp(b).unwrap());
                c
            }
        };
        let out = lloyd(data, weights, init, cfg, data_sorted);
        total_iters += out.iterations;
        total_empty += out.empty_events;
        if best.as_ref().map_or(true, |b| out.inertia < b.inertia) {
            best = Some(out);
        }
    }
    let best = best.unwrap();
    Ok(KMeansResult {
        centroids: best.centroids,
        assignment: best.assignment,
        inertia: best.inertia,
        iterations: total_iters,
        empty_cluster_events: total_empty,
        converged: best.converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_sorted_correct() {
        let c = [0.0, 1.0, 10.0];
        assert_eq!(assign_sorted(-5.0, &c), 0);
        assert_eq!(assign_sorted(0.4, &c), 0);
        assert_eq!(assign_sorted(0.6, &c), 1);
        assert_eq!(assign_sorted(5.0, &c), 1);
        assert_eq!(assign_sorted(6.0, &c), 2);
        assert_eq!(assign_sorted(99.0, &c), 2);
    }

    #[test]
    fn assign_matches_linear_scan() {
        let mut rng = Pcg32::seeded(1);
        let mut c: Vec<f64> = (0..7).map(|_| rng.uniform(-5.0, 5.0)).collect();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for _ in 0..500 {
            let x = rng.uniform(-6.0, 6.0);
            let fast = assign_sorted(x, &c);
            let slow = (0..c.len())
                .min_by(|&a, &b| {
                    ((x - c[a]).abs()).partial_cmp(&(x - c[b]).abs()).unwrap()
                })
                .unwrap();
            assert!(
                ((x - c[fast]).abs() - (x - c[slow]).abs()).abs() < 1e-12,
                "x={x} fast={fast} slow={slow}"
            );
        }
    }

    #[test]
    fn three_obvious_clusters() {
        let data: Vec<f64> = vec![0.9, 1.0, 1.1, 4.9, 5.0, 5.1, 9.0, 9.1, 8.9];
        let r = kmeans_1d(&data, None, &KMeansConfig { k: 3, ..Default::default() }).unwrap();
        assert_eq!(r.centroids.len(), 3);
        assert!((r.centroids[0] - 1.0).abs() < 1e-6);
        assert!((r.centroids[1] - 5.0).abs() < 1e-6);
        assert!((r.centroids[2] - 9.0).abs() < 1e-6);
        assert!(r.inertia < 0.1);
        assert!(r.converged);
    }

    #[test]
    fn centroids_sorted_and_assignment_valid() {
        let mut rng = Pcg32::seeded(2);
        let data: Vec<f64> = (0..200).map(|_| rng.normal_with(0.0, 3.0)).collect();
        let r = kmeans_1d(&data, None, &KMeansConfig { k: 8, ..Default::default() }).unwrap();
        assert!(r.centroids.windows(2).all(|p| p[0] <= p[1]));
        assert!(r.assignment.iter().all(|&a| a < r.centroids.len()));
        assert_eq!(r.assignment.len(), data.len());
    }

    #[test]
    fn weighted_equals_expanded() {
        // Clustering values with multiplicity weights must match clustering
        // the expanded vector.
        let vals = [1.0, 2.0, 10.0, 11.0];
        let w = [3.0, 1.0, 1.0, 3.0];
        let mut expanded = Vec::new();
        for (v, c) in vals.iter().zip(&w) {
            for _ in 0..(*c as usize) {
                expanded.push(*v);
            }
        }
        let cfg = KMeansConfig { k: 2, ..Default::default() };
        let a = kmeans_1d(&vals, Some(&w), &cfg).unwrap();
        let b = kmeans_1d(&expanded, None, &cfg).unwrap();
        for (x, y) in a.centroids.iter().zip(&b.centroids) {
            assert!((x - y).abs() < 1e-9);
        }
        assert!((a.inertia - b.inertia).abs() < 1e-9);
    }

    #[test]
    fn k_clamped_to_distinct_points() {
        let data = [1.0, 1.0, 2.0];
        let r = kmeans_1d(&data, None, &KMeansConfig { k: 10, ..Default::default() }).unwrap();
        assert!(r.centroids.len() <= 10);
        assert!(r.inertia < 1e-12);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Pcg32::seeded(3);
        let data: Vec<f64> = (0..100).map(|_| rng.next_f64() * 10.0).collect();
        let cfg = KMeansConfig { k: 5, seed: 7, ..Default::default() };
        let a = kmeans_1d(&data, None, &cfg).unwrap();
        let b = kmeans_1d(&data, None, &cfg).unwrap();
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn more_restarts_never_worse() {
        let mut rng = Pcg32::seeded(4);
        let data: Vec<f64> = (0..300)
            .map(|i| rng.normal_with((i % 5) as f64 * 8.0, 0.4))
            .collect();
        let one = kmeans_1d(
            &data,
            None,
            &KMeansConfig { k: 5, restarts: 1, seed: 11, ..Default::default() },
        )
        .unwrap();
        let ten = kmeans_1d(
            &data,
            None,
            &KMeansConfig { k: 5, restarts: 10, seed: 11, ..Default::default() },
        )
        .unwrap();
        assert!(ten.inertia <= one.inertia + 1e-9);
    }

    #[test]
    fn rejects_bad_config() {
        assert!(kmeans_1d(&[], None, &KMeansConfig::default()).is_err());
        assert!(kmeans_1d(&[1.0], None, &KMeansConfig { k: 0, ..Default::default() }).is_err());
        assert!(
            kmeans_1d(&[1.0], Some(&[1.0, 2.0]), &KMeansConfig::default()).is_err()
        );
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Pcg32::seeded(5);
        let data: Vec<f64> = (0..200).map(|_| rng.uniform(0.0, 100.0)).collect();
        let mut prev = f64::INFINITY;
        for k in [2, 4, 8, 16, 32] {
            let r = kmeans_1d(
                &data,
                None,
                &KMeansConfig { k, seed: 3, ..Default::default() },
            )
            .unwrap();
            assert!(r.inertia <= prev + 1e-9, "k={k}: inertia rose");
            prev = r.inertia;
        }
    }
}
