//! Fuzzy c-means clustering (paper §2 discussion, refs [13][14]).
//!
//! The paper excludes fuzzy c-means from its experiments, citing Wen &
//! Celebi 2011: "it will take longer time than k-means (hard c-means), yet
//! the performance [is] not significantly better." We implement it anyway
//! as an ablation so that claim is *measured* here rather than assumed —
//! see `benches/ablations.rs`.
//!
//! Standard FCM with fuzzifier `f`: memberships
//! `u_ic = 1 / Σ_j (|x_i − v_c| / |x_i − v_j|)^{2/(f−1)}`, centroids
//! `v_c = Σ_i w_i u_ic^f x_i / Σ_i w_i u_ic^f`. Hard assignment at the end
//! by argmax membership ("the membership should be computed by taking
//! argmax", §2).

use crate::data::rng::Pcg32;
use crate::{Error, Result};

/// Configuration for [`fuzzy_cmeans_1d`].
#[derive(Debug, Clone)]
pub struct FcmConfig {
    /// Number of clusters.
    pub k: usize,
    /// Fuzzifier `f > 1` (2.0 is the universal default).
    pub fuzzifier: f64,
    /// Iteration budget.
    pub max_iters: usize,
    /// Convergence threshold on the largest centroid move.
    pub tol: f64,
    /// RNG seed for initialization.
    pub seed: u64,
}

impl Default for FcmConfig {
    fn default() -> Self {
        FcmConfig { k: 8, fuzzifier: 2.0, max_iters: 300, tol: 1e-9, seed: 0 }
    }
}

/// FCM result.
#[derive(Debug, Clone)]
pub struct FcmResult {
    /// Final centroids (sorted ascending).
    pub centroids: Vec<f64>,
    /// Argmax-membership assignment per point.
    pub assignment: Vec<usize>,
    /// Weighted hard inertia (against argmax assignment).
    pub inertia: f64,
    /// Iterations consumed.
    pub iterations: usize,
    /// Converged within budget?
    pub converged: bool,
}

/// Weighted 1-d fuzzy c-means.
pub fn fuzzy_cmeans_1d(data: &[f64], weights: Option<&[f64]>, cfg: &FcmConfig) -> Result<FcmResult> {
    if data.is_empty() {
        return Err(Error::InvalidInput("fcm: empty data".into()));
    }
    if cfg.k == 0 {
        return Err(Error::InvalidParam("fcm: k must be ≥ 1".into()));
    }
    if cfg.fuzzifier <= 1.0 {
        return Err(Error::InvalidParam("fcm: fuzzifier must be > 1".into()));
    }
    let n = data.len();
    let ones;
    let pw: &[f64] = match weights {
        Some(w) => {
            if w.len() != n {
                return Err(Error::InvalidInput("fcm: weights length mismatch".into()));
            }
            w
        }
        None => {
            ones = vec![1.0; n];
            &ones
        }
    };
    let k = cfg.k.min(n);
    let exp = 2.0 / (cfg.fuzzifier - 1.0);

    // k-means++-style spread init (deterministic per seed).
    let mut rng = Pcg32::new(cfg.seed, 404);
    let mut centroids = {
        let first = rng.weighted_index(pw).unwrap_or(0);
        let mut cs = vec![data[first]];
        let mut d2: Vec<f64> = data.iter().map(|&x| (x - data[first]).powi(2)).collect();
        while cs.len() < k {
            let idx = rng.weighted_index(&d2).unwrap_or_else(|| rng.gen_range(n));
            cs.push(data[idx]);
            for i in 0..n {
                d2[i] = d2[i].min((data[i] - data[idx]).powi(2));
            }
        }
        cs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        cs
    };

    let mut u = vec![0.0f64; n * k];
    let mut iterations = 0usize;
    let mut converged = false;
    for _ in 0..cfg.max_iters {
        iterations += 1;
        // Membership update.
        for i in 0..n {
            // Exact-hit handling: membership 1 on the coincident centroid.
            if let Some(hit) = centroids.iter().position(|&c| (data[i] - c).abs() < 1e-300) {
                for c in 0..k {
                    u[i * k + c] = if c == hit { 1.0 } else { 0.0 };
                }
                continue;
            }
            let inv: Vec<f64> = (0..k)
                .map(|c| 1.0 / (data[i] - centroids[c]).abs().powf(exp))
                .collect();
            let s: f64 = inv.iter().sum();
            for c in 0..k {
                u[i * k + c] = inv[c] / s;
            }
        }
        // Centroid update.
        let mut max_move = 0.0f64;
        for c in 0..k {
            let (mut num, mut den) = (0.0, 0.0);
            for i in 0..n {
                let uf = u[i * k + c].powf(cfg.fuzzifier) * pw[i];
                num += uf * data[i];
                den += uf;
            }
            if den > 0.0 {
                let nc = num / den;
                max_move = max_move.max((nc - centroids[c]).abs());
                centroids[c] = nc;
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if max_move < cfg.tol {
            converged = true;
            break;
        }
    }

    // Hard assignment by nearest centroid (≡ argmax membership for FCM).
    let mut assignment = vec![0usize; n];
    let mut inertia = 0.0;
    for i in 0..n {
        let a = crate::cluster::kmeans::assign_sorted(data[i], &centroids);
        assignment[i] = a;
        inertia += pw[i] * (data[i] - centroids[a]).powi(2);
    }
    Ok(FcmResult { centroids, assignment, inertia, iterations, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::kmeans::{kmeans_1d, KMeansConfig};

    fn three_groups(seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        let mut v = Vec::new();
        for c in [1.0, 5.0, 9.0] {
            for _ in 0..40 {
                v.push(c + rng.normal_with(0.0, 0.2));
            }
        }
        v
    }

    #[test]
    fn finds_separated_clusters() {
        let data = three_groups(1);
        let r = fuzzy_cmeans_1d(&data, None, &FcmConfig { k: 3, ..Default::default() }).unwrap();
        assert!((r.centroids[0] - 1.0).abs() < 0.2, "{:?}", r.centroids);
        assert!((r.centroids[1] - 5.0).abs() < 0.2);
        assert!((r.centroids[2] - 9.0).abs() < 0.2);
        assert!(r.converged);
    }

    #[test]
    fn comparable_to_kmeans_not_better() {
        // The Wen & Celebi claim the paper leans on: inertia ≈ k-means.
        let data = three_groups(2);
        let fcm = fuzzy_cmeans_1d(&data, None, &FcmConfig { k: 3, ..Default::default() }).unwrap();
        let km = kmeans_1d(&data, None, &KMeansConfig { k: 3, ..Default::default() }).unwrap();
        assert!(fcm.inertia <= km.inertia * 1.5, "fcm {} vs km {}", fcm.inertia, km.inertia);
        assert!(km.inertia <= fcm.inertia * 1.5);
    }

    #[test]
    fn exact_centroid_hit_is_stable() {
        let data = vec![1.0, 1.0, 1.0, 5.0];
        let r = fuzzy_cmeans_1d(&data, None, &FcmConfig { k: 2, ..Default::default() }).unwrap();
        assert!(r.centroids.iter().all(|c| c.is_finite()));
        assert!((r.centroids[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn weighted_shifts_centroids() {
        let data = vec![0.0, 10.0];
        let r = fuzzy_cmeans_1d(
            &data,
            Some(&[99.0, 1.0]),
            &FcmConfig { k: 1, ..Default::default() },
        )
        .unwrap();
        assert!(r.centroids[0] < 1.0, "heavy point should dominate: {:?}", r.centroids);
    }

    #[test]
    fn rejects_bad_params() {
        assert!(fuzzy_cmeans_1d(&[], None, &FcmConfig::default()).is_err());
        assert!(
            fuzzy_cmeans_1d(&[1.0], None, &FcmConfig { k: 0, ..Default::default() }).is_err()
        );
        assert!(fuzzy_cmeans_1d(
            &[1.0],
            None,
            &FcmConfig { fuzzifier: 1.0, ..Default::default() }
        )
        .is_err());
        assert!(fuzzy_cmeans_1d(&[1.0], Some(&[1.0, 2.0]), &FcmConfig::default()).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let data = three_groups(3);
        let cfg = FcmConfig { k: 4, seed: 9, ..Default::default() };
        let a = fuzzy_cmeans_1d(&data, None, &cfg).unwrap();
        let b = fuzzy_cmeans_1d(&data, None, &cfg).unwrap();
        assert_eq!(a.centroids, b.centroids);
    }
}
