//! Exact 1-d k-means by dynamic programming (ablation upper bound).
//!
//! 1-d k-means is not NP-hard: optimal clusters are contiguous intervals of
//! the sorted data, so the global optimum is computable by DP over segment
//! boundaries. We use the divide-and-conquer optimization (the row-minimum
//! argmins of the DP layer are monotone), giving O(k·n·log n).
//!
//! This is *not* in the paper — it is the ablation DESIGN §5/E-index calls
//! for: it bounds how much of k-means' loss gap vs the proposed methods is
//! due to Lloyd's heuristic rather than the clustering objective itself.

use crate::{Error, Result};

/// Exact weighted 1-d k-means result.
#[derive(Debug, Clone)]
pub struct DpKMeansResult {
    /// Optimal centroids (sorted ascending — contiguity makes this natural).
    pub centroids: Vec<f64>,
    /// Cluster index per input point (original order).
    pub assignment: Vec<usize>,
    /// Globally optimal weighted within-cluster sum of squares.
    pub inertia: f64,
}

struct Prefix {
    /// prefix weight sums
    w: Vec<f64>,
    /// prefix Σ w·x
    wx: Vec<f64>,
    /// prefix Σ w·x²
    wxx: Vec<f64>,
}

impl Prefix {
    fn new(xs: &[f64], ws: &[f64]) -> Self {
        let n = xs.len();
        let (mut w, mut wx, mut wxx) =
            (Vec::with_capacity(n + 1), Vec::with_capacity(n + 1), Vec::with_capacity(n + 1));
        w.push(0.0);
        wx.push(0.0);
        wxx.push(0.0);
        for i in 0..n {
            w.push(w[i] + ws[i]);
            wx.push(wx[i] + ws[i] * xs[i]);
            wxx.push(wxx[i] + ws[i] * xs[i] * xs[i]);
        }
        Prefix { w, wx, wxx }
    }

    /// Weighted SSE of the segment [i, j] (inclusive, 0-based).
    #[inline]
    fn cost(&self, i: usize, j: usize) -> f64 {
        let w = self.w[j + 1] - self.w[i];
        if w <= 0.0 {
            return 0.0;
        }
        let wx = self.wx[j + 1] - self.wx[i];
        let wxx = self.wxx[j + 1] - self.wxx[i];
        // Σw x² − (Σw x)²/Σw, clamped against round-off.
        (wxx - wx * wx / w).max(0.0)
    }

    /// Weighted mean of [i, j].
    #[inline]
    fn mean(&self, i: usize, j: usize) -> f64 {
        let w = self.w[j + 1] - self.w[i];
        if w <= 0.0 {
            0.0
        } else {
            (self.wx[j + 1] - self.wx[i]) / w
        }
    }
}

/// Fill one DP layer with divide & conquer over the monotone argmin.
/// `cur[i] = min_{j ≤ i} prev[j−1] + cost(j, i)` for i in [lo, hi],
/// with the optimal j known to lie in [opt_lo, opt_hi].
#[allow(clippy::too_many_arguments)]
fn dnc(
    prefix: &Prefix,
    prev: &[f64],
    cur: &mut [f64],
    cut: &mut [usize],
    lo: usize,
    hi: usize,
    opt_lo: usize,
    opt_hi: usize,
) {
    if lo > hi {
        return;
    }
    let mid = (lo + hi) / 2;
    let mut best = f64::INFINITY;
    let mut best_j = opt_lo;
    let j_hi = opt_hi.min(mid);
    for j in opt_lo..=j_hi {
        let base = if j == 0 { f64::INFINITY } else { prev[j - 1] };
        // j == 0 means "no previous cluster", only valid in layer 1 which is
        // handled separately; guard with INFINITY here.
        let c = if j == 0 { f64::INFINITY } else { base + prefix.cost(j, mid) };
        if c < best {
            best = c;
            best_j = j;
        }
    }
    cur[mid] = best;
    cut[mid] = best_j;
    if mid > lo {
        dnc(prefix, prev, cur, cut, lo, mid - 1, opt_lo, best_j);
    }
    if mid < hi {
        dnc(prefix, prev, cur, cut, mid + 1, hi, best_j, opt_hi);
    }
}

/// Globally optimal weighted 1-d k-means.
pub fn kmeans_dp(data: &[f64], weights: Option<&[f64]>, k: usize) -> Result<DpKMeansResult> {
    if data.is_empty() {
        return Err(Error::InvalidInput("kmeans_dp: empty data".into()));
    }
    if k == 0 {
        return Err(Error::InvalidParam("kmeans_dp: k must be ≥ 1".into()));
    }
    let n = data.len();
    let ones;
    let ws: &[f64] = match weights {
        Some(w) => {
            if w.len() != n {
                return Err(Error::InvalidInput("kmeans_dp: weights length mismatch".into()));
            }
            w
        }
        None => {
            ones = vec![1.0; n];
            &ones
        }
    };

    // Sort by value, remembering original positions.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| data[a].partial_cmp(&data[b]).unwrap());
    let xs: Vec<f64> = order.iter().map(|&i| data[i]).collect();
    let sw: Vec<f64> = order.iter().map(|&i| ws[i]).collect();
    let k = k.min(n);

    let prefix = Prefix::new(&xs, &sw);

    // Layer 1: one cluster covering [0, i].
    let mut prev: Vec<f64> = (0..n).map(|i| prefix.cost(0, i)).collect();
    // cuts[t][i]: start index of the last cluster in the optimal t+1-cluster
    // solution of [0, i].
    let mut cuts: Vec<Vec<usize>> = vec![vec![0; n]];

    for _t in 2..=k {
        let mut cur = vec![f64::INFINITY; n];
        let mut cut = vec![0usize; n];
        dnc(&prefix, &prev, &mut cur, &mut cut, 0, n - 1, 1, n - 1);
        cuts.push(cut);
        prev = cur;
    }

    // Backtrack segment boundaries.
    let mut boundaries = Vec::with_capacity(k);
    let mut end = n - 1;
    for t in (0..k).rev() {
        let start = cuts[t][end];
        boundaries.push((start, end));
        if start == 0 {
            break;
        }
        end = start - 1;
    }
    boundaries.reverse();

    let centroids: Vec<f64> = boundaries.iter().map(|&(s, e)| prefix.mean(s, e)).collect();
    let inertia = prev[n - 1].min(prefix.cost(0, n - 1)); // k=1 edge
    // Assignment back in original order.
    let mut assignment = vec![0usize; n];
    for (c, &(s, e)) in boundaries.iter().enumerate() {
        for idx in s..=e {
            assignment[order[idx]] = c;
        }
    }
    Ok(DpKMeansResult { centroids, assignment, inertia })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::kmeans::{kmeans_1d, KMeansConfig};
    use crate::data::rng::Pcg32;

    #[test]
    fn trivial_cases() {
        let r = kmeans_dp(&[5.0], None, 1).unwrap();
        assert_eq!(r.centroids, vec![5.0]);
        assert_eq!(r.inertia, 0.0);

        let r = kmeans_dp(&[1.0, 2.0], None, 2).unwrap();
        assert_eq!(r.centroids.len(), 2);
        assert!(r.inertia < 1e-12);
    }

    #[test]
    fn separated_clusters_exact() {
        let data = [0.0, 0.1, 10.0, 10.1, 20.0, 20.1];
        let r = kmeans_dp(&data, None, 3).unwrap();
        assert!((r.centroids[0] - 0.05).abs() < 1e-9);
        assert!((r.centroids[1] - 10.05).abs() < 1e-9);
        assert!((r.centroids[2] - 20.05).abs() < 1e-9);
        assert!((r.inertia - 0.015).abs() < 1e-9);
    }

    #[test]
    fn never_worse_than_lloyd() {
        let mut rng = Pcg32::seeded(10);
        for k in [2usize, 4, 8, 13] {
            let data: Vec<f64> = (0..150).map(|_| rng.uniform(0.0, 100.0)).collect();
            let dp = kmeans_dp(&data, None, k).unwrap();
            let ll = kmeans_1d(
                &data,
                None,
                &KMeansConfig { k, restarts: 10, seed: 1, ..Default::default() },
            )
            .unwrap();
            assert!(
                dp.inertia <= ll.inertia + 1e-6,
                "k={k}: DP {} > Lloyd {}",
                dp.inertia,
                ll.inertia
            );
        }
    }

    #[test]
    fn assignment_respects_original_order() {
        let data = [9.0, 1.0, 8.5, 1.2];
        let r = kmeans_dp(&data, None, 2).unwrap();
        assert_eq!(r.assignment[0], r.assignment[2]); // 9.0, 8.5 together
        assert_eq!(r.assignment[1], r.assignment[3]); // 1.0, 1.2 together
        assert_ne!(r.assignment[0], r.assignment[1]);
    }

    #[test]
    fn weighted_matches_expanded() {
        let vals = [1.0, 2.0, 8.0];
        let w = [4.0, 1.0, 2.0];
        let mut expanded = Vec::new();
        for (v, c) in vals.iter().zip(&w) {
            for _ in 0..(*c as usize) {
                expanded.push(*v);
            }
        }
        let a = kmeans_dp(&vals, Some(&w), 2).unwrap();
        let b = kmeans_dp(&expanded, None, 2).unwrap();
        assert!((a.inertia - b.inertia).abs() < 1e-9);
        for (x, y) in a.centroids.iter().zip(&b.centroids) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn matches_brute_force_small() {
        // Exhaustive check on all 2-cluster splits of a small sorted array.
        let data = [0.3, 1.1, 1.4, 4.0, 4.2, 9.9];
        let dp = kmeans_dp(&data, None, 2).unwrap();
        let mut best = f64::INFINITY;
        for split in 1..data.len() {
            let sse = |xs: &[f64]| {
                let m = xs.iter().sum::<f64>() / xs.len() as f64;
                xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            };
            best = best.min(sse(&data[..split]) + sse(&data[split..]));
        }
        assert!((dp.inertia - best).abs() < 1e-9, "dp={} brute={}", dp.inertia, best);
    }

    #[test]
    fn k_geq_n_zero_loss() {
        let data = [3.0, 1.0, 2.0];
        let r = kmeans_dp(&data, None, 10).unwrap();
        assert!(r.inertia < 1e-12);
        assert_eq!(r.centroids.len(), 3);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(kmeans_dp(&[], None, 2).is_err());
        assert!(kmeans_dp(&[1.0], None, 0).is_err());
        assert!(kmeans_dp(&[1.0], Some(&[1.0, 2.0]), 1).is_err());
    }
}
