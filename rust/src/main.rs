//! sqlsq CLI entry point. See `cli.rs` for the command surface.
fn main() {
    std::process::exit(sqlsq::cli::run());
}
