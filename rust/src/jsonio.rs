//! Minimal JSON reader/writer (S24) **and the serve wire format**.
//!
//! serde is not available in this offline environment (DESIGN §2); this
//! module implements exactly the subset the system needs — UTF-8 text,
//! the six JSON value kinds, `\uXXXX` escapes, no trailing commas, no
//! comments — for three consumers: the AOT `manifest.json` shared with
//! the Python compile path, the experiment reports, and the serve
//! protocol below.
//!
//! # Serve wire format
//!
//! Quantization results cross process boundaries in one of two JSON
//! forms, emitted by `sqlsq quantize|sweep --output codebook|values` and
//! produced/parsed by [`codebook_to_json`] / [`codebook_from_json`] /
//! [`values_to_json`] / [`values_from_json`].
//!
//! **Codebook form** (the compact payload a serving edge should ship —
//! a few shared levels plus one small index per element):
//!
//! ```json
//! {
//!   "levels":  [0.1, 0.5, 0.9],
//!   "indices": [0, 0, 1, 2, 1, 0],
//!   "lambda":  0.01,
//!   "stats":   { "bits_per_value": 18.67, "index_entropy": 1.46, ... }
//! }
//! ```
//!
//! Field by field:
//!
//! * `levels` — array of numbers, the distinct quantization levels,
//!   sorted ascending. Length `k ≥ 1`.
//! * `indices` — array of non-negative integers `< k`, one per original
//!   element, in input order. Element `i` decodes to
//!   `levels[indices[i]]`.
//! * optional extra fields added by the producer (the CLI sweep adds
//!   `lambda`, the λ grid point; `stats` carries the compression
//!   accounting of [`stats_to_json`]). Consumers must ignore fields they
//!   don't know.
//!
//! **Packed-codebook form** (the bit-packed index plane of
//! [`crate::quant::PackedCodebook`], emitted by
//! [`packed_codebook_to_json`] / parsed by [`packed_codebook_from_json`]):
//!
//! ```json
//! {
//!   "levels":     [0.1, 0.5, 0.9],
//!   "bits":       2,
//!   "len":        6,
//!   "packed_hex": "9001"
//! }
//! ```
//!
//! * `levels` — as in the codebook form (sorted ascending, `k ≥ 1`).
//! * `bits` — integer `0..=32`: fixed bits per index, `⌈log₂ k⌉`. A
//!   single-level plane (`k = 1`) carries no index information and is
//!   emitted with `bits = 0` and an empty `packed_hex`; decoders also
//!   accept the legacy `bits = 1` encoding for `k = 1`. `bits = 0` with
//!   `k > 1` is rejected (it would silently decode everything to
//!   `levels[0]`).
//! * `len` — integer: number of encoded elements `n`.
//! * `packed_hex` — lowercase hex string of exactly `⌈n·bits / 8⌉` bytes
//!   (`2·⌈n·bits/8⌉` hex digits): the index plane packed LSB-first into
//!   little-endian bytes — index `i` occupies plane bits
//!   `[i·bits, (i+1)·bits)`, and plane bit `b` is bit `b mod 8` of byte
//!   `b / 8`. Producers emit the final byte's pad bits as zero; decoders
//!   ignore them. (Hex rather than a JSON number array: packed words
//!   exceed the integer range a JSON f64 can carry exactly.)
//! * unknown fields are ignored, as in the codebook form.
//!
//! Decoders do **not** require `bits == ⌈log₂ k⌉` (a producer may choose
//! a wider plane), but every unpacked index must be `< k`.
//!
//! **Values form** (the dense fallback for consumers that want the
//! full-length vector):
//!
//! ```json
//! { "values": [0.1, 0.1, 0.5, 0.9, 0.5, 0.1] }
//! ```
//!
//! * `values` — array of numbers, the materialized quantized vector,
//!   input order, length `n`.
//!
//! A worked round trip:
//!
//! ```
//! use sqlsq::jsonio::{codebook_from_json, codebook_to_json, parse};
//! use sqlsq::quant::Codebook;
//!
//! let cb = Codebook::from_values(&[0.1, 0.1, 0.9, 0.5, 0.9]).unwrap();
//! let wire = codebook_to_json(&cb, vec![]).to_string();
//! assert_eq!(wire, r#"{"indices":[0,0,2,1,2],"levels":[0.1,0.5,0.9]}"#);
//! let back = codebook_from_json(&parse(&wire).unwrap()).unwrap();
//! assert_eq!(back.decode(), vec![0.1, 0.1, 0.9, 0.5, 0.9]);
//! ```
//!
//! The number encoding is JSON's (f64); the f32 lane's levels widen
//! exactly when serialized, so a wire round trip is lossless for both
//! lanes. Producers emit keys in deterministic (sorted) order.

use crate::quant::tensor::Grouping;
use crate::quant::{Codebook, CompressionStats, PackedCodebook, PackedIndices, QMatrix};
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64, like JavaScript).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::InvalidInput(format!(
            "json: trailing garbage at byte {}",
            p.pos
        )));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::InvalidInput(format!("json: {msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Serve wire format (see the module docs for the field-by-field spec)
// ---------------------------------------------------------------------

/// Serialize a codebook into the wire's **codebook form**:
/// `{"levels":[..],"indices":[..]}` plus any `extra` producer fields
/// (e.g. the sweep's `("lambda", Json::Num(λ))`, or `("stats", ..)` from
/// [`stats_to_json`]).
pub fn codebook_to_json(cb: &Codebook, extra: Vec<(&str, Json)>) -> Json {
    let mut fields = extra;
    fields.push(("levels", Json::Arr(cb.levels.iter().map(|&v| Json::Num(v)).collect())));
    fields.push((
        "indices",
        Json::Arr(cb.indices.iter().map(|&i| Json::Num(i as f64)).collect()),
    ));
    Json::obj(fields)
}

/// Parse the wire's codebook form back into a [`Codebook`]. Validates the
/// protocol invariants — `levels` non-empty and sorted ascending, every
/// index a non-negative integer `< levels.len()` — and ignores unknown
/// fields, per the wire contract.
pub fn codebook_from_json(j: &Json) -> Result<Codebook> {
    let bad = |msg: &str| Error::InvalidInput(format!("codebook wire: {msg}"));
    let levels: Vec<f64> = j
        .get("levels")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'levels' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| bad("non-numeric level")))
        .collect::<Result<_>>()?;
    if levels.is_empty() {
        return Err(bad("'levels' must be non-empty"));
    }
    if levels.windows(2).any(|w| !(w[0] < w[1])) {
        return Err(bad("'levels' must be sorted strictly ascending"));
    }
    let indices: Vec<u32> = j
        .get("indices")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'indices' array"))?
        .iter()
        .map(|v| {
            let i = v.as_usize().ok_or_else(|| bad("index not a non-negative integer"))?;
            if i >= levels.len() {
                return Err(bad("index out of range of 'levels'"));
            }
            Ok(i as u32)
        })
        .collect::<Result<_>>()?;
    Ok(Codebook { levels, indices })
}

/// Serialize a materialized vector into the wire's **values form**:
/// `{"values":[..]}` plus any `extra` producer fields.
pub fn values_to_json(values: &[f64], extra: Vec<(&str, Json)>) -> Json {
    let mut fields = extra;
    fields.push(("values", Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())));
    Json::obj(fields)
}

/// Parse the wire's values form back into the full-length vector.
pub fn values_from_json(j: &Json) -> Result<Vec<f64>> {
    let bad = |msg: &str| Error::InvalidInput(format!("values wire: {msg}"));
    j.get("values")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'values' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| bad("non-numeric value")))
        .collect()
}

/// Serialize compression accounting as the wire's optional `stats`
/// object (all fields numeric, names matching [`CompressionStats`]).
/// `bits_per_index` is kept alongside the newer
/// `bits_per_idx_stored`/`bits_per_idx_packed` pair — it has always meant
/// the packed width and existing consumers read it.
pub fn stats_to_json(s: &CompressionStats) -> Json {
    Json::obj(vec![
        ("n", Json::Num(s.n as f64)),
        ("levels_achieved", Json::Num(s.levels_achieved as f64)),
        ("levels_requested", Json::Num(s.levels_requested as f64)),
        ("bits_per_index", Json::Num(s.bits_per_index as f64)),
        ("bits_per_idx_stored", Json::Num(s.bits_per_idx_stored as f64)),
        ("bits_per_idx_packed", Json::Num(s.bits_per_idx_packed as f64)),
        ("bits_per_value", Json::Num(s.bits_per_value)),
        ("index_entropy", Json::Num(s.index_entropy)),
        ("entropy_coded_bytes", Json::Num(s.entropy_coded_bytes as f64)),
        ("compact_bytes", Json::Num(s.compact_bytes as f64)),
        ("dense_bytes", Json::Num(s.dense_bytes as f64)),
        ("byte_ratio", Json::Num(s.byte_ratio)),
    ])
}

fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(s, "{b:02x}");
    }
    s
}

fn hex_decode(text: &str) -> Result<Vec<u8>> {
    if text.len() % 2 != 0 {
        return Err(Error::InvalidInput("packed wire: odd hex length".into()));
    }
    let digit = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(Error::InvalidInput(format!(
                "packed wire: bad hex digit '{}'",
                c as char
            ))),
        }
    };
    text.as_bytes()
        .chunks_exact(2)
        .map(|p| Ok(digit(p[0])? << 4 | digit(p[1])?))
        .collect()
}

/// Serialize a packed codebook into the wire's **packed-codebook form**:
/// `{"levels":[..],"bits":b,"len":n,"packed_hex":".."}` plus any `extra`
/// producer fields (see the module docs for the byte-level layout).
pub fn packed_codebook_to_json(cb: &PackedCodebook, extra: Vec<(&str, Json)>) -> Json {
    let idx = &cb.indices;
    let nbytes = idx.packed_bytes();
    let mut bytes = Vec::with_capacity(nbytes);
    'outer: for w in idx.words() {
        for b in w.to_le_bytes() {
            if bytes.len() == nbytes {
                break 'outer;
            }
            bytes.push(b);
        }
    }
    let mut fields = extra;
    fields.push(("levels", Json::Arr(cb.levels.iter().map(|&v| Json::Num(v)).collect())));
    fields.push(("bits", Json::Num(f64::from(idx.bits()))));
    fields.push(("len", Json::Num(idx.len() as f64)));
    fields.push(("packed_hex", Json::Str(hex_encode(&bytes))));
    Json::obj(fields)
}

/// Parse the wire's packed-codebook form back into a [`PackedCodebook`].
/// Validates the protocol invariants — `levels` non-empty and sorted
/// ascending, `bits ∈ 0..=32` with `bits = 0` only for a single-level
/// plane (and `bits = 1` still accepted there: the legacy `k = 1`
/// encoding), `packed_hex` exactly `⌈len·bits / 8⌉` bytes, every unpacked
/// index `< levels.len()` — and ignores unknown fields.
pub fn packed_codebook_from_json(j: &Json) -> Result<PackedCodebook> {
    let bad = |msg: &str| Error::InvalidInput(format!("packed codebook wire: {msg}"));
    let levels: Vec<f64> = j
        .get("levels")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'levels' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| bad("non-numeric level")))
        .collect::<Result<_>>()?;
    if levels.is_empty() {
        return Err(bad("'levels' must be non-empty"));
    }
    if levels.windows(2).any(|w| !(w[0] < w[1])) {
        return Err(bad("'levels' must be sorted strictly ascending"));
    }
    let bits = j
        .get("bits")
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("missing integer 'bits'"))? as u32;
    let len = j
        .get("len")
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("missing integer 'len'"))?;
    let hex = j
        .get("packed_hex")
        .and_then(Json::as_str)
        .ok_or_else(|| bad("missing string 'packed_hex'"))?;
    let bytes = hex_decode(hex)?;
    if bits > 32 {
        return Err(bad(&format!("'bits' must be in 0..=32, got {bits}")));
    }
    if bits == 0 && levels.len() > 1 {
        // A 0-bit plane decodes every element to levels[0]; accepting it
        // for a multi-level codebook would silently discard information.
        return Err(bad(&format!(
            "'bits' is 0 but there are {} levels — a zero-bit plane is only \
             valid for a single-level codebook",
            levels.len()
        )));
    }
    let want_bytes = (len * bits as usize).div_ceil(8);
    if bytes.len() != want_bytes {
        return Err(bad(&format!(
            "'packed_hex' is {} bytes, expected {want_bytes} for {len} × {bits}-bit indices",
            bytes.len()
        )));
    }
    let mut words = vec![0u64; (len * bits as usize).div_ceil(64)];
    for (i, &b) in bytes.iter().enumerate() {
        words[i / 8] |= u64::from(b) << ((i % 8) * 8);
    }
    let indices = PackedIndices::from_raw(words, bits, len)?;
    if indices.unpack().iter().any(|&i| (i as usize) >= levels.len()) {
        return Err(bad("unpacked index out of range of 'levels'"));
    }
    Ok(PackedCodebook { levels, indices })
}

fn grouping_to_str(g: Grouping) -> &'static str {
    match g {
        Grouping::PerTensor => "per_tensor",
        Grouping::PerRow => "per_row",
        Grouping::PerColumn => "per_column",
    }
}

fn grouping_from_str(s: &str) -> Result<Grouping> {
    match s {
        "per_tensor" => Ok(Grouping::PerTensor),
        "per_row" => Ok(Grouping::PerRow),
        "per_column" => Ok(Grouping::PerColumn),
        other => Err(Error::InvalidInput(format!(
            "qmatrix wire: unknown grouping '{other}' (per_tensor|per_row|per_column)"
        ))),
    }
}

/// Serialize a quantized-compute matrix into the wire's **qmatrix form**:
/// `{"rows":r,"cols":c,"grouping":"per_column","groups":[[plane,..],..]}`
/// where each plane is a packed-codebook form ([`packed_codebook_to_json`]).
/// Groups are emitted in [`Grouping`] order (row-major flat / rows /
/// columns); within a group, planes are in cascade-level order. `extra`
/// producer fields ride along at the top level.
pub fn qmatrix_to_json(qm: &QMatrix, extra: Vec<(&str, Json)>) -> Json {
    let mut fields = extra;
    fields.push(("rows", Json::Num(qm.rows() as f64)));
    fields.push(("cols", Json::Num(qm.cols() as f64)));
    fields.push(("grouping", Json::Str(grouping_to_str(qm.grouping()).into())));
    fields.push((
        "groups",
        Json::Arr(
            qm.groups()
                .iter()
                .map(|planes| {
                    Json::Arr(
                        planes.iter().map(|cb| packed_codebook_to_json(cb, vec![])).collect(),
                    )
                })
                .collect(),
        ),
    ));
    Json::obj(fields)
}

/// Parse the wire's qmatrix form back into a [`QMatrix`]. Each plane goes
/// through [`packed_codebook_from_json`]'s invariants, then
/// [`QMatrix::from_parts`] revalidates the assembled shape (group count vs
/// grouping, plane coverage, packed widths, index ranges) — wire data can
/// never build a `QMatrix` whose matvec would fault. Unknown fields are
/// ignored.
pub fn qmatrix_from_json(j: &Json) -> Result<QMatrix> {
    let bad = |msg: &str| Error::InvalidInput(format!("qmatrix wire: {msg}"));
    let rows = j
        .get("rows")
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("missing integer 'rows'"))?;
    let cols = j
        .get("cols")
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("missing integer 'cols'"))?;
    let grouping = grouping_from_str(
        j.get("grouping")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing string 'grouping'"))?,
    )?;
    let groups: Vec<Vec<PackedCodebook>> = j
        .get("groups")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'groups' array"))?
        .iter()
        .map(|g| {
            g.as_arr()
                .ok_or_else(|| bad("each group must be an array of planes"))?
                .iter()
                .map(packed_codebook_from_json)
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<_>>()?;
    QMatrix::from_parts(rows, cols, grouping, groups)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::Str("lasso_cd_m64".into())),
            ("shape", Json::Arr(vec![Json::Num(64.0), Json::Num(2.0)])),
            ("ok", Json::Bool(true)),
            ("x", Json::Num(1.5)),
        ]);
        let text = j.to_string();
        assert_eq!(parse(&text).unwrap(), j);
        let pretty = j.to_pretty();
        assert_eq!(parse(&pretty).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "version": 1,
          "artifacts": [
            {"name": "lasso_cd_m64", "file": "lasso_cd_m64.hlo.txt",
             "inputs": [{"shape": [64], "dtype": "float32"}],
             "meta": {"kind": "lasso_cd", "m": 64, "epochs_per_call": 8}}
          ]
        }"#;
        let j = parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("meta").unwrap().get("m").unwrap().as_usize(), Some(64));
    }

    #[test]
    fn helpers() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Str("x".into()).as_f64(), None);
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Num(1.0).as_bool(), None);
    }

    #[test]
    fn codebook_wire_roundtrip_with_extras() {
        let cb = Codebook::from_values(&[0.5, -1.0, 0.5, 2.0]).unwrap();
        let j = codebook_to_json(&cb, vec![("lambda", Json::Num(0.01))]);
        let text = j.to_string();
        let parsed = parse(&text).unwrap();
        assert_eq!(parsed.get("lambda").unwrap().as_f64(), Some(0.01));
        let back = codebook_from_json(&parsed).unwrap();
        assert_eq!(back.levels, cb.levels);
        assert_eq!(back.indices, cb.indices);
        assert_eq!(back.decode(), vec![0.5, -1.0, 0.5, 2.0]);
    }

    #[test]
    fn codebook_wire_rejects_protocol_violations() {
        let bad = |t: &str| codebook_from_json(&parse(t).unwrap());
        assert!(bad(r#"{"indices":[0]}"#).is_err(), "missing levels");
        assert!(bad(r#"{"levels":[],"indices":[]}"#).is_err(), "empty levels");
        assert!(bad(r#"{"levels":[2.0,1.0],"indices":[0]}"#).is_err(), "unsorted");
        assert!(bad(r#"{"levels":[1.0,1.0],"indices":[0]}"#).is_err(), "duplicate level");
        assert!(bad(r#"{"levels":[1.0],"indices":[1]}"#).is_err(), "index out of range");
        assert!(bad(r#"{"levels":[1.0],"indices":[0.5]}"#).is_err(), "fractional index");
        assert!(bad(r#"{"levels":[1.0],"indices":[-1]}"#).is_err(), "negative index");
        // Unknown fields are ignored, per the wire contract.
        assert!(bad(r#"{"levels":[1.0],"indices":[0],"future":true}"#).is_ok());
    }

    #[test]
    fn values_wire_roundtrip() {
        let vals = vec![0.25, 0.25, 1.0];
        let j = values_to_json(&vals, vec![]);
        assert_eq!(values_from_json(&parse(&j.to_string()).unwrap()).unwrap(), vals);
        assert!(values_from_json(&parse("{}").unwrap()).is_err());
    }

    #[test]
    fn stats_wire_carries_all_fields() {
        let cb = Codebook::from_values(&(0..64).map(|i| (i % 4) as f64).collect::<Vec<_>>())
            .unwrap();
        let s = cb.stats(4);
        let j = stats_to_json(&s);
        assert_eq!(j.get("levels_achieved").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("n").unwrap().as_usize(), Some(64));
        assert_eq!(j.get("bits_per_value").unwrap().as_f64(), Some(s.bits_per_value));
        assert_eq!(j.get("byte_ratio").unwrap().as_f64(), Some(s.byte_ratio));
        // Stored vs packed index widths (the dense codebook stores u32;
        // `bits_per_index` keeps its historical packed meaning).
        assert_eq!(j.get("bits_per_idx_stored").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("bits_per_idx_packed").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("bits_per_index").unwrap().as_usize(), Some(2));
        // The entropy-coded size model rides along (achievable coded
        // bytes from the index entropy; never above the packed size).
        assert_eq!(
            j.get("entropy_coded_bytes").unwrap().as_usize(),
            Some(s.entropy_coded_bytes)
        );
        assert!(s.entropy_coded_bytes <= s.compact_bytes);
        // Round-trips through text.
        assert!(parse(&j.to_string()).is_ok());
    }

    #[test]
    fn packed_codebook_wire_matches_spec_example() {
        let cb = Codebook {
            levels: vec![0.1, 0.5, 0.9],
            indices: vec![0, 0, 1, 2, 1, 0],
        }
        .pack();
        let j = packed_codebook_to_json(&cb, vec![]);
        assert_eq!(j.get("bits").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("len").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("packed_hex").unwrap().as_str(), Some("9001"));
    }

    fn demo_qmatrix() -> QMatrix {
        // 3×2, per-column, a 2-level cascade on column 0 and a single
        // level on column 1 (ragged, like an early-stopped group).
        let plane = |levels: Vec<f64>, idx: Vec<u32>| Codebook { levels, indices: idx }.pack();
        QMatrix::from_parts(
            3,
            2,
            Grouping::PerColumn,
            vec![
                vec![
                    plane(vec![-1.0, 1.0], vec![0, 1, 0]),
                    plane(vec![-0.25, 0.0, 0.25], vec![2, 0, 1]),
                ],
                vec![plane(vec![0.5], vec![0, 0, 0])],
            ],
        )
        .unwrap()
    }

    #[test]
    fn qmatrix_wire_roundtrip_preserves_planes_and_matvec() {
        let qm = demo_qmatrix();
        let j = qmatrix_to_json(&qm, vec![("method", Json::Str("kmeans".into()))]);
        let parsed = parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("method").unwrap().as_str(), Some("kmeans"));
        let back = qmatrix_from_json(&parsed).unwrap();
        assert_eq!(back, qm);
        let x = [0.3, -0.7, 1.1];
        for (a, b) in back.matvec(&x).iter().zip(qm.matvec(&x)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn qmatrix_wire_rejects_shape_violations() {
        let qm = demo_qmatrix();
        let good = qmatrix_to_json(&qm, vec![]).to_string();
        assert!(qmatrix_from_json(&parse(&good).unwrap()).is_ok());
        let bad = |t: &str| qmatrix_from_json(&parse(t).unwrap());
        assert!(bad(r#"{"rows":3,"cols":2,"groups":[]}"#).is_err(), "missing grouping");
        assert!(
            bad(r#"{"rows":3,"cols":2,"grouping":"per_banana","groups":[]}"#).is_err(),
            "unknown grouping"
        );
        // Group count must match the grouping over the declared shape.
        let wrong_count = good.replacen(r#""cols": 2"#, r#""cols": 3"#, 1);
        let wrong_count = wrong_count.replacen(r#""cols":2"#, r#""cols":3"#, 1);
        assert!(bad(&wrong_count).is_err(), "2 groups for per_column over 3 cols");
        // Plane length must cover the group.
        let wrong_rows = good.replacen(r#""rows": 3"#, r#""rows": 4"#, 1);
        let wrong_rows = wrong_rows.replacen(r#""rows":3"#, r#""rows":4"#, 1);
        assert!(bad(&wrong_rows).is_err(), "3-element planes for 4-row columns");
    }

    #[test]
    fn packed_codebook_wire_roundtrip() {
        for k in [1usize, 2, 3, 255, 256, 257, 300] {
            let values: Vec<f64> = (0..700).map(|i| ((i * 11) % k) as f64).collect();
            let packed = Codebook::from_values(&values).unwrap().pack();
            let j = packed_codebook_to_json(&packed, vec![("lambda", Json::Num(0.5))]);
            let parsed = parse(&j.to_string()).unwrap();
            assert_eq!(parsed.get("lambda").unwrap().as_f64(), Some(0.5));
            let back = packed_codebook_from_json(&parsed).unwrap();
            assert_eq!(back, packed, "k={k}");
            assert_eq!(back.decode(), values, "k={k}");
        }
    }

    #[test]
    fn packed_codebook_wire_rejects_protocol_violations() {
        let bad = |t: &str| packed_codebook_from_json(&parse(t).unwrap());
        let ok = r#"{"levels":[1.0,2.0],"bits":1,"len":2,"packed_hex":"02"}"#;
        assert!(bad(ok).is_ok());
        assert!(bad(r#"{"bits":1,"len":0,"packed_hex":""}"#).is_err(), "missing levels");
        assert!(
            bad(r#"{"levels":[],"bits":1,"len":0,"packed_hex":""}"#).is_err(),
            "empty levels"
        );
        assert!(
            bad(r#"{"levels":[2.0,1.0],"bits":1,"len":0,"packed_hex":""}"#).is_err(),
            "unsorted levels"
        );
        assert!(
            bad(r#"{"levels":[1.0],"bits":33,"len":0,"packed_hex":""}"#).is_err(),
            "bits too wide"
        );
        assert!(
            bad(r#"{"levels":[1.0,2.0],"bits":0,"len":4,"packed_hex":""}"#).is_err(),
            "zero-bit plane is only valid for a single level"
        );
        // The k=1 degenerate plane: bits=0 with no payload bytes parses
        // (the modern encoding), as does the legacy 1-bit form.
        let zero = bad(r#"{"levels":[1.5],"bits":0,"len":4,"packed_hex":""}"#).unwrap();
        assert_eq!(zero.decode(), vec![1.5; 4]);
        assert_eq!(zero.bits_per_index(), 0);
        let legacy = bad(r#"{"levels":[1.5],"bits":1,"len":4,"packed_hex":"00"}"#).unwrap();
        assert_eq!(legacy.decode(), vec![1.5; 4]);
        assert_eq!(legacy.bits_per_index(), 1, "legacy width preserved as parsed");
        assert!(
            bad(r#"{"levels":[1.0],"bits":1,"len":9,"packed_hex":"00"}"#).is_err(),
            "plane too short"
        );
        assert!(
            bad(r#"{"levels":[1.0],"bits":1,"len":2,"packed_hex":"0"}"#).is_err(),
            "odd hex"
        );
        assert!(
            bad(r#"{"levels":[1.0],"bits":1,"len":2,"packed_hex":"zz"}"#).is_err(),
            "bad hex digit"
        );
        assert!(
            bad(r#"{"levels":[1.0],"bits":1,"len":2,"packed_hex":"02"}"#).is_err(),
            "unpacked index out of range"
        );
        // Unknown fields are ignored, per the wire contract.
        assert!(
            bad(r#"{"levels":[1.0,2.0],"bits":1,"len":2,"packed_hex":"03","future":1}"#)
                .is_ok()
        );
    }
}
