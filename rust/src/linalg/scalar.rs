//! Element-precision abstraction for the quantization hot path.
//!
//! The paper's headline workload — neural-network weights — arrives in
//! single precision, and the coordinate-descent kernel is memory-bound
//! (O(m) flops per epoch over O(m) memory), so running it in `f32` halves
//! the bytes moved per epoch. [`Scalar`] is the small closed trait that
//! lets `UniqueDecomp`, `VBasis`, the CD solvers and the staged pipeline
//! be generic over the element type while keeping the `f64` lane
//! bit-for-bit identical to the historical implementation: every trait
//! operation maps 1:1 onto the intrinsic `f64` operation it replaced.
//!
//! ## Precision contract
//!
//! * **f64 lane** — the reference. `TOL_FLOOR` is 0, so configured
//!   tolerances apply verbatim and results are bitwise-reproducible.
//! * **f32 lane** — inputs are narrowed once at the lane boundary; all
//!   prepare/solve arithmetic runs in `f32`; outputs widen back at the
//!   end. Convergence thresholds are floored at [`Scalar::TOL_FLOOR`]
//!   (`1e-6`, matching the PJRT runtime's single-precision floor):
//!   an `f32` coordinate move below that is indistinguishable from
//!   rounding noise, so chasing the f64 default of `1e-10` would burn
//!   epochs until the support-patience stop with no accuracy to show for
//!   it. The lane is intended for O(1)-scaled data (NN weights, pixel
//!   intensities); for values spanning more than ~6 decades of magnitude
//!   stay on f64.

use std::fmt::{Debug, Display};
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// A floating-point element type the quantization pipeline can run on.
///
/// Implemented for `f32` and `f64` only; the trait is deliberately closed
/// (sealed by convention — solvers assume IEEE-754 semantics such as
/// exact negation, signed zero equality and `max` ignoring NaN).
pub trait Scalar:
    Copy
    + Default
    + PartialOrd
    + PartialEq
    + Debug
    + Display
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + Send
    + Sync
    + 'static
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Machine epsilon of the lane.
    const EPSILON: Self;
    /// Positive infinity.
    const INFINITY: Self;
    /// Lane floor applied to CD convergence tolerances (`tol.max(floor)`):
    /// `0.0` for f64 (configured tolerances apply verbatim), `1e-6` for
    /// f32 (see the module docs' precision contract).
    const TOL_FLOOR: f64;
    /// Whether reduction kernels ([`crate::linalg::kernels`]) must keep
    /// the exact left-to-right accumulation order. `true` on the f64
    /// reference lane (reassociating a sum changes the rounding sequence
    /// and would break the bitwise contract); `false` on the f32 lane,
    /// whose results are tolerance-gated, so reductions may split across
    /// independent accumulators for instruction-level parallelism /
    /// vectorization. Still deterministic on both lanes: the association
    /// order is a pure function of the slice length.
    const STRICT_ACCUMULATION: bool;
    /// Stable lane id ("f32" / "f64") for diagnostics.
    const ID: &'static str;

    /// Narrow/convert from `f64` (exact for the f64 lane).
    fn from_f64(x: f64) -> Self;
    /// Widen/convert to `f64` (exact for both lanes).
    fn to_f64(self) -> f64;
    /// Convert a count; exact for every count the pipeline can produce
    /// (f32 is exact up to 2^24 distinct values).
    fn from_usize(n: usize) -> Self;
    /// Absolute value.
    fn abs(self) -> Self;
    /// IEEE-754 maximum (NaN-ignoring, like `f64::max`).
    fn max(self, other: Self) -> Self;
    /// IEEE-754 minimum (NaN-ignoring, like `f64::min`).
    fn min(self, other: Self) -> Self;
    /// Neither NaN nor infinite.
    fn is_finite(self) -> bool;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f64::EPSILON;
    const INFINITY: Self = f64::INFINITY;
    const TOL_FLOOR: f64 = 0.0;
    const STRICT_ACCUMULATION: bool = true;
    const ID: &'static str = "f64";

    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn from_usize(n: usize) -> Self {
        n as f64
    }
    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline]
    fn max(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline]
    fn min(self, other: Self) -> Self {
        f64::min(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f32::EPSILON;
    const INFINITY: Self = f32::INFINITY;
    const TOL_FLOOR: f64 = 1e-6;
    const STRICT_ACCUMULATION: bool = false;
    const ID: &'static str = "f32";

    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn from_usize(n: usize) -> Self {
        n as f32
    }
    #[inline]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline]
    fn max(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline]
    fn min(self, other: Self) -> Self {
        f32::min(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Scalar>(xs: &[f64]) {
        for &x in xs {
            let t = T::from_f64(x);
            // Widening back must be the identity on the lane's own grid.
            assert_eq!(T::from_f64(t.to_f64()).to_f64(), t.to_f64());
        }
    }

    #[test]
    fn conversions_roundtrip_on_lane_grid() {
        let xs = [0.0, -0.0, 1.0, -2.5, 0.125, 1e-3, 1e6];
        roundtrip::<f64>(&xs);
        roundtrip::<f32>(&xs);
    }

    #[test]
    fn f64_lane_ops_are_the_intrinsics() {
        assert_eq!(f64::from_f64(0.1).to_bits(), 0.1f64.to_bits());
        assert_eq!(Scalar::max(1.0f64, 2.0), 2.0);
        assert_eq!(Scalar::min(1.0f64, 2.0), 1.0);
        assert_eq!(Scalar::abs(-3.5f64), 3.5);
        assert_eq!(f64::from_usize(7), 7.0);
        assert_eq!(f64::TOL_FLOOR, 0.0);
        assert!(f64::STRICT_ACCUMULATION, "f64 is the bitwise lane");
        assert!(!f32::STRICT_ACCUMULATION, "f32 reductions may reassociate");
        assert_eq!(f64::ID, "f64");
    }

    #[test]
    fn f32_lane_constants() {
        assert_eq!(f32::ZERO, 0.0f32);
        assert_eq!(f32::ONE, 1.0f32);
        assert!(f32::TOL_FLOOR > 0.0);
        assert_eq!(f32::ID, "f32");
        assert!(f32::INFINITY.to_f64().is_infinite());
        assert!(!f32::INFINITY.is_finite());
        assert!(Scalar::is_finite(1.5f32));
    }

    #[test]
    fn f32_counts_exact_to_2_pow_24() {
        assert_eq!(f32::from_usize(1 << 24).to_f64(), (1u64 << 24) as f64);
    }
}
