//! Cholesky factorization and SPD solves.
//!
//! The least-square refits (paper eq 9 and eq 20) are solved through the
//! normal equations `(XᵀX) β = Xᵀw`. `XᵀX` is symmetric positive
//! (semi-)definite, so Cholesky is the right tool; a tiny diagonal jitter
//! retry handles the semi-definite edge cases that arise when the support
//! selects nearly-identical columns.

use super::matrix::Matrix;
use crate::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix.
    pub fn factor(a: &Matrix) -> Result<Self> {
        if a.rows() != a.cols() {
            return Err(Error::Linalg(format!(
                "cholesky needs a square matrix, got {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(Error::Linalg(format!(
                            "matrix not positive definite at pivot {i} (s={s})"
                        )));
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Solve `A x = b` via forward + backward substitution.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.l.rows();
        if b.len() != n {
            return Err(Error::Linalg(format!(
                "solve dimension mismatch: {} vs {}",
                b.len(),
                n
            )));
        }
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[(i, k)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        // Backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        Ok(x)
    }
}

/// Solve the SPD system `A x = b`, retrying with growing diagonal jitter if
/// `A` is only positive semi-definite (rank-deficient supports).
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    match Cholesky::factor(a) {
        Ok(ch) => ch.solve(b),
        Err(_) => {
            // Jitter scaled to the matrix magnitude.
            let scale = (0..a.rows()).map(|i| a[(i, i)].abs()).fold(0.0, f64::max).max(1e-12);
            let mut jitter = 1e-12 * scale;
            for _ in 0..8 {
                let mut aj = a.clone();
                for i in 0..a.rows() {
                    aj[(i, i)] += jitter;
                }
                if let Ok(ch) = Cholesky::factor(&aj) {
                    return ch.solve(b);
                }
                jitter *= 100.0;
            }
            Err(Error::Linalg(
                "solve_spd: matrix not PD even after jitter".into(),
            ))
        }
    }
}

/// Solve the least-square problem `min ‖w − X β‖²` through the normal
/// equations. `x` is `m × h` with `h ≤ m`.
pub fn least_squares(x: &Matrix, w: &[f64]) -> Result<Vec<f64>> {
    if w.len() != x.rows() {
        return Err(Error::Linalg(format!(
            "least_squares: {} rows vs {} targets",
            x.rows(),
            w.len()
        )));
    }
    let gram = x.gram();
    let rhs = x.t_matvec(w)?;
    solve_spd(&gram, &rhs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize) -> Matrix {
        // A = B Bᵀ + n·I is SPD for any B.
        let b = Matrix::from_fn(n, n, |i, j| ((i * 7 + j * 3) as f64).sin());
        let mut a = b.matmul(&b.transpose()).unwrap();
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(6);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l().matmul(&ch.l().transpose()).unwrap();
        assert!(a.max_abs_diff(&rec) < 1e-9);
    }

    #[test]
    fn solve_roundtrip() {
        let a = spd(8);
        let x_true: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let b = a.matvec(&x_true).unwrap();
        let x = Cholesky::factor(&a).unwrap().solve(&b).unwrap();
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-8, "{xs} vs {xt}");
        }
    }

    #[test]
    fn rejects_non_pd() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]).unwrap(); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        assert!(Cholesky::factor(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn solve_spd_handles_semidefinite() {
        // Rank-1 PSD matrix.
        let a = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let x = solve_spd(&a, &[2.0, 2.0]).unwrap();
        // Any solution with x0 + x1 ≈ 2 is acceptable.
        assert!((x[0] + x[1] - 2.0).abs() < 1e-3, "x={x:?}");
    }

    #[test]
    fn least_squares_exact_fit() {
        // Overdetermined but consistent system.
        let x = Matrix::from_vec(4, 2, vec![1.0, 0.0, 1.0, 1.0, 1.0, 2.0, 1.0, 3.0]).unwrap();
        let beta_true = [0.5, 2.0];
        let w: Vec<f64> = (0..4).map(|i| beta_true[0] + beta_true[1] * i as f64).collect();
        let beta = least_squares(&x, &w).unwrap();
        assert!((beta[0] - 0.5).abs() < 1e-9);
        assert!((beta[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn least_squares_regression_line() {
        // Noisy line: slope must be near 1 with intercept near 0.
        let n = 50;
        let x = Matrix::from_fn(n, 2, |i, j| if j == 0 { 1.0 } else { i as f64 });
        let w: Vec<f64> = (0..n)
            .map(|i| i as f64 + if i % 2 == 0 { 0.1 } else { -0.1 })
            .collect();
        let beta = least_squares(&x, &w).unwrap();
        assert!((beta[1] - 1.0).abs() < 1e-3, "slope {}", beta[1]);
    }
}
