//! Dense linear-algebra substrate (S14): matrices, Cholesky/SPD solves,
//! and the scalar statistics used across solvers and the eval harness.

pub mod cholesky;
pub mod kernels;
pub mod matrix;
pub mod scalar;
pub mod stats;
