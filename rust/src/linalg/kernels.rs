//! Vectorization-friendly kernels for the CD hot path (S24).
//!
//! Every request the quantizer serves bottoms out in a handful of slice
//! primitives: suffix/dot reductions and residual updates inside the
//! coordinate-descent epoch loop (`quant::lasso`), segment fills in the
//! support refit (`quant::refit`), gathers through the unique
//! decomposition's inverse map in the compact finalize
//! (`quant::api::finish_compact_parts`), and ⌈log₂ k⌉-bit index planes
//! for the packed codebook (`quant::codebook::PackedIndices`). This
//! module is that floor, written once, chunked, and generic over
//! [`Scalar`].
//!
//! ## The bitwise-f64 contract
//!
//! The f64 lane is the repository's bitwise reference
//! (`tests/api_equivalence.rs`, `quant::types::finalize`): kernel results
//! must be **bit-for-bit identical** to the scalar loops they replaced.
//! Floating-point addition is not associative, so on the f64 lane every
//! reduction here ([`sum`], [`dot`], [`nrm2`], the suffix sum inside
//! [`shrink_axpy`], [`gather_sq_loss`]) keeps a **single accumulator in
//! strict left-to-right order** — chunking is pure loop unrolling and
//! never reassociates. The throughput win on f64 therefore comes from the
//! element-wise kernels (which autovectorize freely: [`axpy`], [`sub`],
//! [`sub_scalar`], [`scatter_levels`], the gathers and the bit packers)
//! and from the call structure (fused passes, cached column norms, no
//! per-coordinate recomputation) — not from reordering f64 sums.
//!
//! On the f32 lane results are tolerance-gated, not bitwise
//! ([`Scalar::STRICT_ACCUMULATION`] is `false`), so reductions split the
//! slice across [`LANES`] independent accumulators: the FP add chains run
//! in parallel (or vectorize outright) instead of serializing on add
//! latency. The association order is still a pure function of the slice
//! length, so f32 results remain deterministic run-to-run.
//!
//! Per-kernel measurements live in `benches/hotpath.rs`, which emits
//! `BENCH_hotpath.json` (scalar-reference vs kernel, both lanes, across
//! sizes).

use super::scalar::Scalar;

/// Unroll width for strict (order-preserving) loops.
const CHUNK: usize = 8;
/// Independent accumulators used by reassociating (f32-lane) reductions.
const LANES: usize = 4;

// ---------------------------------------------------------------------
// Reductions
// ---------------------------------------------------------------------

/// Strict left-to-right sum — the exact legacy association order.
#[inline]
fn sum_strict<T: Scalar>(xs: &[T]) -> T {
    let mut acc = T::ZERO;
    let mut chunks = xs.chunks_exact(CHUNK);
    for ch in chunks.by_ref() {
        for &x in ch {
            acc += x;
        }
    }
    for &x in chunks.remainder() {
        acc += x;
    }
    acc
}

/// Multi-accumulator sum (reassociates; f32 lane only). The partials
/// combine pairwise, then the remainder folds in left-to-right.
#[inline]
fn sum_lanes<T: Scalar>(xs: &[T]) -> T {
    let mut a = [T::ZERO; LANES];
    let mut chunks = xs.chunks_exact(LANES);
    for ch in chunks.by_ref() {
        a[0] += ch[0];
        a[1] += ch[1];
        a[2] += ch[2];
        a[3] += ch[3];
    }
    let mut acc = (a[0] + a[1]) + (a[2] + a[3]);
    for &x in chunks.remainder() {
        acc += x;
    }
    acc
}

/// `Σ xs[i]`. Strict order on lanes with the bitwise contract
/// ([`Scalar::STRICT_ACCUMULATION`]); multi-accumulator otherwise.
#[inline]
pub fn sum<T: Scalar>(xs: &[T]) -> T {
    if T::STRICT_ACCUMULATION {
        sum_strict(xs)
    } else {
        sum_lanes(xs)
    }
}

/// Strict left-to-right dot product.
#[inline]
fn dot_strict<T: Scalar>(a: &[T], b: &[T]) -> T {
    let mut acc = T::ZERO;
    let mut pa = a.chunks_exact(CHUNK);
    let mut pb = b.chunks_exact(CHUNK);
    for (ca, cb) in pa.by_ref().zip(pb.by_ref()) {
        for (&x, &y) in ca.iter().zip(cb) {
            acc += x * y;
        }
    }
    for (&x, &y) in pa.remainder().iter().zip(pb.remainder()) {
        acc += x * y;
    }
    acc
}

/// Multi-accumulator dot product (reassociates; f32 lane only).
#[inline]
fn dot_lanes<T: Scalar>(a: &[T], b: &[T]) -> T {
    let mut acc4 = [T::ZERO; LANES];
    let mut pa = a.chunks_exact(LANES);
    let mut pb = b.chunks_exact(LANES);
    for (ca, cb) in pa.by_ref().zip(pb.by_ref()) {
        acc4[0] += ca[0] * cb[0];
        acc4[1] += ca[1] * cb[1];
        acc4[2] += ca[2] * cb[2];
        acc4[3] += ca[3] * cb[3];
    }
    let mut acc = (acc4[0] + acc4[1]) + (acc4[2] + acc4[3]);
    for (&x, &y) in pa.remainder().iter().zip(pb.remainder()) {
        acc += x * y;
    }
    acc
}

/// `Σ a[i]·b[i]` over equal-length slices. Strict order on the f64 lane.
#[inline]
pub fn dot<T: Scalar>(a: &[T], b: &[T]) -> T {
    debug_assert_eq!(a.len(), b.len(), "dot: length mismatch");
    if T::STRICT_ACCUMULATION {
        dot_strict(a, b)
    } else {
        dot_lanes(a, b)
    }
}

/// Euclidean norm `‖xs‖₂`. The squared sum follows the lane's
/// accumulation rule; the square root is taken in f64 and narrowed back,
/// so the f64 lane is exact.
#[inline]
pub fn nrm2<T: Scalar>(xs: &[T]) -> T {
    let ss = dot(xs, xs);
    T::from_f64(ss.to_f64().sqrt())
}

// ---------------------------------------------------------------------
// Element-wise updates (no reduction — autovectorize on both lanes)
// ---------------------------------------------------------------------

/// `y[i] += a · x[i]` over equal-length slices.
#[inline]
pub fn axpy<T: Scalar>(a: T, x: &[T], y: &mut [T]) {
    debug_assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `out[i] = a[i] − b[i]` over equal-length slices (the residual build
/// `r = ŵ − Vα` of the structured CD epoch).
#[inline]
pub fn sub<T: Scalar>(a: &[T], b: &[T], out: &mut [T]) {
    debug_assert_eq!(a.len(), out.len(), "sub: length mismatch");
    debug_assert_eq!(b.len(), out.len(), "sub: length mismatch");
    for ((o, &ai), &bi) in out.iter_mut().zip(a).zip(b) {
        *o = ai - bi;
    }
}

/// `y[i] −= c` — the rank-one residual correction of a CD coordinate
/// update over the difference basis (every covered row moves by the same
/// amount).
#[inline]
pub fn sub_scalar<T: Scalar>(y: &mut [T], c: T) {
    for yi in y {
        *yi -= c;
    }
}

/// Soft-thresholding operator `S_λ(x)` (paper §3.3).
#[inline]
pub fn shrink<T: Scalar>(x: T, lambda: T) -> T {
    if x > lambda {
        x - lambda
    } else if x < -lambda {
        x + lambda
    } else {
        T::ZERO
    }
}

/// Fused CD coordinate update over a residual suffix `r = r[j..]`
/// (`quant::lasso::solve_dense`'s inner loop): suffix-sum the residual
/// (lane accumulation rule), soft-threshold the coordinate, and apply the
/// residual correction in one kernel call. Returns `(new_alpha, delta)`;
/// the residual is only touched when `delta ≠ 0`, exactly like the legacy
/// loop. Arithmetic sequence on the f64 lane is bit-identical to the
/// historical two-loop form:
///
/// ```text
/// suffix = Σ r_i;  ρ = suffix·d_j + c_j·α_j;
/// α_j' = S_{λ₁}(ρ)/denom;  r_i −= d_j·(α_j' − α_j)
/// ```
#[inline]
pub fn shrink_axpy<T: Scalar>(
    r: &mut [T],
    dj: T,
    cj: T,
    alpha_j: T,
    lambda1: T,
    denom: T,
) -> (T, T) {
    let suffix = sum(r);
    let rho = suffix * dj + cj * alpha_j;
    let new = shrink(rho, lambda1) / denom;
    let delta = new - alpha_j;
    if delta != T::ZERO {
        sub_scalar(r, dj * delta);
    }
    (new, delta)
}

// ---------------------------------------------------------------------
// Level-space finalize: scatters and gathers
// ---------------------------------------------------------------------

/// Fill a segment with one level value (the piecewise-constant scatter of
/// the support refit: every row of a segment takes the segment's level).
#[inline]
pub fn scatter_levels<T: Scalar>(dst: &mut [T], level: T) {
    for d in dst {
        *d = level;
    }
}

/// Gather `levels[indices[i]]` — codebook decode.
#[inline]
pub fn gather_levels<T: Scalar>(levels: &[T], indices: &[u32]) -> Vec<T> {
    indices.iter().map(|&i| levels[i as usize]).collect()
}

/// Gather `table[idx[i]]` for `u32` tables — the compact finalize's
/// per-element index build through the unique decomposition's inverse map.
#[inline]
pub fn gather_indices(table: &[u32], idx: &[usize]) -> Vec<u32> {
    idx.iter().map(|&j| table[j]).collect()
}

/// Histogram of an index stream over `k` levels (index entropy, level
/// occupancy). Panics if an index is out of range — codebook indices are
/// validated at construction.
#[inline]
pub fn gather_counts(indices: &[u32], k: usize) -> Vec<usize> {
    let mut counts = vec![0usize; k];
    for &i in indices {
        counts[i as usize] += 1;
    }
    counts
}

/// Squared-l2 loss between the original vector and its level-space
/// reconstruction, gathered through the inverse map:
/// `Σ (original[i] − level_values[inverse[i]])²`, accumulated in f64 in
/// input order on **both** lanes — this is the compact finalize's loss
/// and must stay bit-identical to the historical full-vector path
/// (`quant::types::finalize`), so it never reassociates.
#[inline]
pub fn gather_sq_loss<T: Scalar>(original: &[T], inverse: &[usize], level_values: &[T]) -> f64 {
    debug_assert_eq!(original.len(), inverse.len(), "gather_sq_loss: length mismatch");
    let mut l2 = 0.0f64;
    for (o, &j) in original.iter().zip(inverse) {
        let d = (*o - level_values[j]).to_f64();
        l2 += d * d;
    }
    l2
}

// ---------------------------------------------------------------------
// ⌈log₂ k⌉-bit index planes
// ---------------------------------------------------------------------

/// Fixed-width bits per index for a `k`-level codebook: `⌈log₂ k⌉`,
/// minimum 1 (`k = 1` still needs one bit per the wire convention).
#[inline]
pub fn bits_per_index_for(k: usize) -> u32 {
    (usize::BITS - (k - 1).leading_zeros()).max(1)
}

/// Packed-plane bits per index for a `k`-level codebook: `⌈log₂ k⌉`, and
/// **zero** when `k ≤ 1`. A single-level plane carries no information —
/// every index is 0 — so its packed form needs no index bits at all;
/// [`bits_per_index_for`]'s minimum of one bit is a dense-form
/// convention, and using it for packed accounting overreported constant
/// groups by one bit per element.
#[inline]
pub fn packed_bits_for(k: usize) -> u32 {
    if k <= 1 {
        0
    } else {
        usize::BITS - (k - 1).leading_zeros()
    }
}

/// Pack `bits`-wide indices (0 ≤ bits ≤ 32) into a tight little-endian
/// `u64` plane: index `i` occupies bits `[i·bits, (i+1)·bits)` counted
/// LSB-first, straddling word boundaries. Values wider than `bits` are
/// masked (callers derive `bits` from `k`, so in-range indices are
/// unchanged). `bits = 0` is the degenerate single-level plane: no words
/// at all ([`packed_bits_for`]).
pub fn pack_indices(indices: &[u32], bits: u32) -> Vec<u64> {
    assert!(bits <= 32, "pack_indices: bits must be in 0..=32, got {bits}");
    if bits == 0 {
        return Vec::new();
    }
    let bits = bits as usize;
    let mask = (1u64 << bits) - 1;
    let total_bits = indices.len() * bits;
    let mut words = vec![0u64; total_bits.div_ceil(64)];
    let mut bitpos = 0usize;
    for &idx in indices {
        let v = u64::from(idx) & mask;
        let w = bitpos / 64;
        let off = bitpos % 64;
        words[w] |= v << off;
        if off + bits > 64 {
            words[w + 1] |= v >> (64 - off);
        }
        bitpos += bits;
    }
    words
}

/// Unpack `len` `bits`-wide indices from a plane produced by
/// [`pack_indices`]. Exact inverse for in-range indices; a `bits = 0`
/// plane unpacks to `len` zeros (every element maps to the single level).
pub fn unpack_indices(words: &[u64], bits: u32, len: usize) -> Vec<u32> {
    assert!(bits <= 32, "unpack_indices: bits must be in 0..=32, got {bits}");
    if bits == 0 {
        return vec![0; len];
    }
    let bits = bits as usize;
    let mask = (1u64 << bits) - 1;
    debug_assert!(
        words.len() * 64 >= len * bits,
        "unpack_indices: plane too short for {len} × {bits}-bit indices"
    );
    (0..len)
        .map(|i| {
            let bitpos = i * bits;
            let w = bitpos / 64;
            let off = bitpos % 64;
            let mut v = words[w] >> off;
            if off + bits > 64 {
                v |= words[w + 1] << (64 - off);
            }
            (v & mask) as u32
        })
        .collect()
}

// ---------------------------------------------------------------------
// Quantized compute: matvec straight off a packed index plane
// ---------------------------------------------------------------------

/// Sequential reader over a packed index plane (the [`pack_indices`]
/// layout). The quantized-compute kernels stream indices through this
/// cursor instead of materializing a `Vec<u32>` — one shift/mask pair per
/// element, no allocation, memory traffic proportional to `bits`, not 32.
pub struct PackedIter<'a> {
    words: &'a [u64],
    bits: usize,
    mask: u64,
    bitpos: usize,
    remaining: usize,
}

impl<'a> PackedIter<'a> {
    /// Cursor over the first `len` `bits`-wide indices of `words`. A
    /// `bits = 0` plane (single-level codebook) yields `len` zeros.
    pub fn new(words: &'a [u64], bits: u32, len: usize) -> PackedIter<'a> {
        assert!(bits <= 32, "PackedIter: bits must be in 0..=32, got {bits}");
        debug_assert!(
            words.len() * 64 >= len * bits as usize,
            "PackedIter: plane too short for {len} × {bits}-bit indices"
        );
        PackedIter {
            words,
            bits: bits as usize,
            mask: if bits == 0 { 0 } else { (1u64 << bits) - 1 },
            bitpos: 0,
            remaining: len,
        }
    }
}

impl Iterator for PackedIter<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        if self.bits == 0 {
            return Some(0);
        }
        let w = self.bitpos / 64;
        let off = self.bitpos % 64;
        let mut v = self.words[w] >> off;
        if off + self.bits > 64 {
            v |= self.words[w + 1] << (64 - off);
        }
        self.bitpos += self.bits;
        Some((v & self.mask) as u32)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for PackedIter<'_> {}

/// `acc[idx[i]] += x[i]` with the indices read straight off a packed
/// plane — the per-level partial-sum gather of the quantized matvec.
/// `acc` must have one slot per codebook level. Combining the slots as
/// `Σ acc[k]·levels[k]` afterwards turns n multiplies into k, at the cost
/// of reassociating the sum — so this is the fast-lane building block
/// ([`matvec_levels`] routes the f32 lane through it) and a public
/// primitive for cascade callers that keep accumulators across planes.
#[inline]
pub fn accum_by_index<T: Scalar>(acc: &mut [T], x: &[T], words: &[u64], bits: u32) {
    let idx = PackedIter::new(words, bits, x.len());
    for (&xi, i) in x.iter().zip(idx) {
        acc[i as usize] += xi;
    }
}

/// `Σ x[i] · levels[idx[i]]` with `idx` read straight off a packed plane —
/// one output element of a quantized matvec `y = x·W` when the plane holds
/// a column's indices. The dense column is never materialized.
///
/// Lane dispatch follows the module contract: on the strict
/// ([`Scalar::STRICT_ACCUMULATION`]) f64 lane a single accumulator runs
/// left-to-right and skips zero inputs exactly like the dense matmul's
/// `a == 0.0` fast path, so the result is **bit-identical** to
/// decode-then-`Matrix::matmul` — including on signed-zero edges, where
/// adding the skipped `±0.0` products could flip a zero sum's sign. On
/// the f32 lane the sum reassociates per level via [`accum_by_index`]
/// (n adds + k multiplies instead of n of each), combined with [`dot`].
/// `scratch` is the caller-owned k-slot accumulator buffer
/// (cleared/resized here; untouched on the strict lane).
pub fn matvec_levels<T: Scalar>(
    x: &[T],
    levels: &[T],
    words: &[u64],
    bits: u32,
    scratch: &mut Vec<T>,
) -> T {
    if T::STRICT_ACCUMULATION {
        let idx = PackedIter::new(words, bits, x.len());
        let mut acc = T::ZERO;
        for (&xi, i) in x.iter().zip(idx) {
            if xi == T::ZERO {
                continue;
            }
            acc += xi * levels[i as usize];
        }
        acc
    } else {
        scratch.clear();
        scratch.resize(levels.len(), T::ZERO);
        accum_by_index(scratch, x, words, bits);
        dot(scratch, levels)
    }
}

/// Row-major quantized GEMV: the plane holds `x.len() × y.len()` indices
/// row-major, and each row `i` contributes `y[j] += x[i]·levels[idx(i,j)]`.
/// The level table is pre-scaled once per row (`k` multiplies into the
/// caller-owned `scaled` buffer), then every entry costs one gather + add.
/// The per-element arithmetic — `x[i]·levels[idx]` multiplied first, then
/// added in `i` order, with zero rows skipped like the dense matmul's
/// `a == 0.0` fast path (their `±0.0` products could flip a signed-zero
/// `y` entry) — is exactly the dense ikj matmul sequence, so this kernel
/// is **bitwise identical to decode-then-`Matrix::matmul` on both lanes**.
pub fn matvec_rowmajor_levels<T: Scalar>(
    y: &mut [T],
    x: &[T],
    levels: &[T],
    words: &[u64],
    bits: u32,
    scaled: &mut Vec<T>,
) {
    let mut idx = PackedIter::new(words, bits, x.len() * y.len());
    for &xi in x {
        if xi == T::ZERO {
            for _ in 0..y.len() {
                let _ = idx.next();
            }
            continue;
        }
        scaled.clear();
        scaled.extend(levels.iter().map(|&l| xi * l));
        for yj in y.iter_mut() {
            let i = idx.next().expect("matvec_rowmajor_levels: plane exhausted");
            *yj += scaled[i as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize) -> Vec<f64> {
        (0..n).map(|i| (i as f64 * 0.37).sin() * 3.0 + 0.1).collect()
    }

    #[test]
    fn f64_sum_and_dot_are_bitwise_sequential() {
        for n in [0usize, 1, 3, 7, 8, 9, 17, 64, 65, 100] {
            let a = seq(n);
            let b: Vec<f64> = a.iter().map(|x| x * 1.7 - 0.3).collect();
            let mut s_ref = 0.0f64;
            for &x in &a {
                s_ref += x;
            }
            assert_eq!(sum(&a).to_bits(), s_ref.to_bits(), "sum n={n}");
            let mut d_ref = 0.0f64;
            for (&x, &y) in a.iter().zip(&b) {
                d_ref += x * y;
            }
            assert_eq!(dot(&a, &b).to_bits(), d_ref.to_bits(), "dot n={n}");
        }
    }

    #[test]
    fn f32_reductions_track_f64_reference() {
        for n in [1usize, 5, 16, 33, 1000] {
            let a64 = seq(n);
            let a32: Vec<f32> = a64.iter().map(|&x| x as f32).collect();
            let ref64: f64 = a32.iter().map(|&x| f64::from(x)).sum();
            let got = f64::from(sum(&a32));
            assert!(
                (got - ref64).abs() <= 1e-4 * ref64.abs().max(1.0),
                "f32 sum n={n}: {got} vs {ref64}"
            );
        }
    }

    #[test]
    fn nrm2_matches_manual() {
        let a = seq(37);
        let ss: f64 = a.iter().map(|x| x * x).sum::<f64>();
        assert!((nrm2(&a) - ss.sqrt()).abs() < 1e-12);
        assert_eq!(nrm2::<f64>(&[]), 0.0);
    }

    #[test]
    fn elementwise_kernels_match_loops() {
        let a = seq(19);
        let b: Vec<f64> = a.iter().map(|x| x * 0.5).collect();
        let mut y = b.clone();
        axpy(2.5, &a, &mut y);
        for ((yi, &ai), &bi) in y.iter().zip(&a).zip(&b) {
            assert_eq!(yi.to_bits(), (bi + 2.5 * ai).to_bits());
        }
        let mut out = vec![0.0; a.len()];
        sub(&a, &b, &mut out);
        for ((o, &ai), &bi) in out.iter().zip(&a).zip(&b) {
            assert_eq!(o.to_bits(), (ai - bi).to_bits());
        }
        let mut z = a.clone();
        sub_scalar(&mut z, 0.25);
        for (zi, &ai) in z.iter().zip(&a) {
            assert_eq!(zi.to_bits(), (ai - 0.25).to_bits());
        }
    }

    #[test]
    fn shrink_matches_cases() {
        assert_eq!(shrink(3.0, 1.0), 2.0);
        assert_eq!(shrink(-3.0, 1.0), -2.0);
        assert_eq!(shrink(0.5, 1.0), 0.0);
        assert_eq!(shrink(1.0f32, 1.0f32), 0.0f32);
    }

    #[test]
    fn shrink_axpy_matches_legacy_two_loop_form() {
        let base = seq(23);
        let (dj, cj, alpha_j, lambda1) = (0.3f64, 0.3 * 0.3 * 23.0, 0.8, 0.05);
        let denom = cj;
        // Legacy form: separate suffix loop, then separate update loop.
        let mut r_ref = base.clone();
        let mut suffix = 0.0f64;
        for ri in &r_ref {
            suffix += *ri;
        }
        let rho = suffix * dj + cj * alpha_j;
        let new_ref = shrink(rho, lambda1) / denom;
        let delta_ref = new_ref - alpha_j;
        if delta_ref != 0.0 {
            for ri in &mut r_ref {
                *ri -= dj * delta_ref;
            }
        }
        let mut r = base.clone();
        let (new, delta) = shrink_axpy(&mut r, dj, cj, alpha_j, lambda1, denom);
        assert_eq!(new.to_bits(), new_ref.to_bits());
        assert_eq!(delta.to_bits(), delta_ref.to_bits());
        for (x, y) in r.iter().zip(&r_ref) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn scatter_and_gathers() {
        let mut buf = vec![0.0f64; 9];
        scatter_levels(&mut buf[2..7], 1.5);
        assert_eq!(buf, vec![0.0, 0.0, 1.5, 1.5, 1.5, 1.5, 1.5, 0.0, 0.0]);

        let levels = [(-1.0), 0.5, 2.0];
        let idx = [2u32, 0, 1, 2];
        assert_eq!(gather_levels(&levels, &idx), vec![2.0, -1.0, 0.5, 2.0]);
        assert_eq!(gather_indices(&[7, 8, 9], &[2, 0, 0]), vec![9, 7, 7]);
        assert_eq!(gather_counts(&idx, 3), vec![1, 1, 2]);

        let original = [1.0, 2.0, 3.0];
        let inverse = [0usize, 1, 2];
        let lv = [1.5, 1.5, 3.0];
        let want = 0.25 + 0.25 + 0.0;
        assert_eq!(gather_sq_loss(&original, &inverse, &lv), want);
    }

    #[test]
    fn bits_per_index_for_steps() {
        assert_eq!(bits_per_index_for(1), 1);
        assert_eq!(bits_per_index_for(2), 1);
        assert_eq!(bits_per_index_for(3), 2);
        assert_eq!(bits_per_index_for(256), 8);
        assert_eq!(bits_per_index_for(257), 9);
        assert_eq!(bits_per_index_for(65536), 16);
    }

    #[test]
    fn packed_bits_for_is_zero_at_k1_then_tracks_ceil_log2() {
        // The honest packed width: a constant group needs no index bits.
        assert_eq!(packed_bits_for(0), 0);
        assert_eq!(packed_bits_for(1), 0);
        assert_eq!(packed_bits_for(2), 1);
        assert_eq!(packed_bits_for(3), 2);
        assert_eq!(packed_bits_for(256), 8);
        assert_eq!(packed_bits_for(257), 9);
        for k in 2..=1024 {
            assert_eq!(packed_bits_for(k), bits_per_index_for(k), "k={k}");
        }
    }

    #[test]
    fn zero_bit_plane_packs_to_nothing_and_unpacks_to_zeros() {
        // k = 1 degenerate plane: no words stored, every index reads 0.
        let words = pack_indices(&[0u32; 9], 0);
        assert!(words.is_empty());
        assert_eq!(unpack_indices(&words, 0, 9), vec![0u32; 9]);
        let streamed: Vec<u32> = PackedIter::new(&words, 0, 9).collect();
        assert_eq!(streamed, vec![0u32; 9]);
        // Non-zero inputs are masked away, mirroring the bits>0 contract.
        assert!(pack_indices(&[3u32, 1], 0).is_empty());
        assert_eq!(unpack_indices(&[], 0, 0), Vec::<u32>::new());
    }

    #[test]
    fn pack_unpack_roundtrip_straddles_words() {
        for bits in [1u32, 2, 3, 9, 16, 17, 32] {
            let modulus = if bits == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << bits };
            let indices: Vec<u32> =
                (0..131u64).map(|i| ((i * 2_654_435_761) % modulus) as u32).collect();
            let words = pack_indices(&indices, bits);
            assert_eq!(words.len(), (indices.len() * bits as usize).div_ceil(64));
            assert_eq!(unpack_indices(&words, bits, indices.len()), indices, "bits={bits}");
        }
        assert!(pack_indices(&[], 5).is_empty());
        assert!(unpack_indices(&[], 5, 0).is_empty());
    }

    #[test]
    fn pack_masks_out_of_range_values() {
        let words = pack_indices(&[5u32], 2); // 5 = 0b101 → masked to 0b01
        assert_eq!(unpack_indices(&words, 2, 1), vec![1]);
    }

    #[test]
    fn packed_iter_matches_unpack() {
        for bits in [1u32, 3, 7, 13, 32] {
            let modulus = if bits == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << bits };
            let indices: Vec<u32> =
                (0..97u64).map(|i| ((i * 2_654_435_761) % modulus) as u32).collect();
            let words = pack_indices(&indices, bits);
            let streamed: Vec<u32> = PackedIter::new(&words, bits, indices.len()).collect();
            assert_eq!(streamed, unpack_indices(&words, bits, indices.len()), "bits={bits}");
        }
        assert_eq!(PackedIter::new(&[], 5, 0).count(), 0);
    }

    #[test]
    fn matvec_levels_f64_is_bitwise_dense_column_sequence() {
        for n in [0usize, 1, 7, 8, 33, 130] {
            let mut x = seq(n);
            if n > 2 {
                x[n / 2] = 0.0; // exercise the dense-matmul zero-skip
            }
            let levels = [-1.25f64, 0.5, 2.0, 3.75, 5.5];
            let indices: Vec<u32> = (0..n as u64).map(|i| ((i * 7) % 5) as u32).collect();
            let bits = bits_per_index_for(levels.len());
            let words = pack_indices(&indices, bits);
            let dense = gather_levels(&levels, &indices);
            // Matrix::matmul's per-column sequence: multiply-then-add in
            // input order, zero inputs skipped.
            let mut want = 0.0f64;
            for (&xi, &wi) in x.iter().zip(&dense) {
                if xi != 0.0 {
                    want += xi * wi;
                }
            }
            let mut scratch = Vec::new();
            let got = matvec_levels(&x, &levels, &words, bits, &mut scratch);
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
            assert!(scratch.is_empty(), "strict lane must not touch scratch");
        }
    }

    #[test]
    fn matvec_levels_f32_tracks_reference() {
        let n = 257usize;
        let x: Vec<f32> = seq(n).iter().map(|&v| v as f32).collect();
        let levels = [-1.25f32, 0.5, 2.0, 3.75];
        let indices: Vec<u32> = (0..n as u64).map(|i| ((i * 5) % 4) as u32).collect();
        let bits = bits_per_index_for(levels.len());
        let words = pack_indices(&indices, bits);
        let ref64: f64 = x
            .iter()
            .zip(&indices)
            .map(|(&xi, &i)| f64::from(xi) * f64::from(levels[i as usize]))
            .sum();
        let mut scratch = Vec::new();
        let got = f64::from(matvec_levels(&x, &levels, &words, bits, &mut scratch));
        assert!((got - ref64).abs() <= 1e-3 * ref64.abs().max(1.0), "{got} vs {ref64}");
        assert_eq!(scratch.len(), levels.len());
    }

    #[test]
    fn accum_by_index_builds_per_level_sums() {
        let x = [1.0f64, 2.0, 4.0, 8.0];
        let indices = [1u32, 0, 1, 2];
        let words = pack_indices(&indices, 2);
        let mut acc = vec![0.0f64; 3];
        accum_by_index(&mut acc, &x, &words, 2);
        assert_eq!(acc, vec![2.0, 5.0, 8.0]);
    }

    #[test]
    fn matvec_rowmajor_levels_is_bitwise_ikj() {
        let (rows, cols) = (9usize, 6usize);
        let mut x = seq(rows);
        x[4] = 0.0; // a skipped row in the middle must still advance the plane
        let levels = [-2.0f64, 0.25, 1.0, 3.5];
        let indices: Vec<u32> = (0..(rows * cols) as u64).map(|i| ((i * 11) % 4) as u32).collect();
        let bits = bits_per_index_for(levels.len());
        let words = pack_indices(&indices, bits);
        // ikj dense reference over the decoded matrix, with matmul's zero-skip.
        let w = gather_levels(&levels, &indices);
        let mut y_ref = vec![0.0f64; cols];
        for i in 0..rows {
            if x[i] == 0.0 {
                continue;
            }
            for j in 0..cols {
                y_ref[j] += x[i] * w[i * cols + j];
            }
        }
        let mut y = vec![0.0f64; cols];
        let mut scaled = Vec::new();
        matvec_rowmajor_levels(&mut y, &x, &levels, &words, bits, &mut scaled);
        for (a, b) in y.iter().zip(&y_ref) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn zero_inputs_are_skipped_like_dense_matmul() {
        // x = 0 against a negative level: naively accumulating the 0·(-1)
        // product yields -0.0, while Matrix::matmul's `a == 0.0` skip leaves
        // +0.0. The kernels must side with matmul — that is the bitwise
        // decode-then-dense contract.
        let words = pack_indices(&[0u32], 1);
        let mut scratch = Vec::new();
        let got = matvec_levels(&[0.0f64], &[-1.0f64, 1.0], &words, 1, &mut scratch);
        assert_eq!(got.to_bits(), 0.0f64.to_bits());
        let mut y = vec![0.0f64];
        let mut scaled = Vec::new();
        matvec_rowmajor_levels(&mut y, &[0.0f64], &[-1.0f64, 1.0], &words, 1, &mut scaled);
        assert_eq!(y[0].to_bits(), 0.0f64.to_bits());
    }
}
