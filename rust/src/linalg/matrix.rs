//! Dense row-major matrix substrate.
//!
//! The paper's solvers need only a small set of dense operations (normal
//! equations for the least-square refits, matmuls for the MLP). We keep the
//! type deliberately small and allocation-transparent; hot paths use the
//! `*_into` variants to avoid allocating in loops.

use crate::{Error, Result};

/// Dense row-major `rows × cols` matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::InvalidInput(format!(
                "matrix {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw row-major data.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i` as a slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Extract column `j` as a new vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transpose into a new matrix.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// `self @ other` into a freshly allocated matrix.
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.rows {
            return Err(Error::InvalidInput(format!(
                "matmul shape mismatch: {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out)?;
        Ok(out)
    }

    /// `out = self @ other`, reusing `out`'s buffer. ikj loop order keeps
    /// the inner loop streaming over contiguous rows of `other` — this is
    /// the MLP trainer's hot path.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) -> Result<()> {
        if self.cols != other.rows || out.rows != self.rows || out.cols != other.cols {
            return Err(Error::InvalidInput(format!(
                "matmul_into shape mismatch: {}x{} @ {}x{} -> {}x{}",
                self.rows, self.cols, other.rows, other.cols, out.rows, out.cols
            )));
        }
        out.data.fill(0.0);
        for i in 0..self.rows {
            let a_row = &self.data[i * self.cols..(i + 1) * self.cols];
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        Ok(())
    }

    /// `self @ x` for a vector `x`.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.cols {
            return Err(Error::InvalidInput(format!(
                "matvec shape mismatch: {}x{} @ {}",
                self.rows,
                self.cols,
                x.len()
            )));
        }
        Ok((0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect())
    }

    /// `selfᵀ @ x` for a vector `x` (no transpose materialization).
    pub fn t_matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.rows {
            return Err(Error::InvalidInput(format!(
                "t_matvec shape mismatch: ({}x{})ᵀ @ {}",
                self.rows,
                self.cols,
                x.len()
            )));
        }
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += a * xi;
            }
        }
        Ok(out)
    }

    /// Gram matrix `selfᵀ @ self` (symmetric, computed on the upper triangle
    /// then mirrored).
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for i in 0..self.rows {
            let row = self.row(i);
            for a in 0..n {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for b in a..n {
                    g[(a, b)] += ra * row[b];
                }
            }
        }
        for a in 0..n {
            for b in 0..a {
                g[(a, b)] = g[(b, a)];
            }
        }
        g
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij − b_ij| between two same-shape matrices.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than the naive fold
    // and keeps results deterministic (fixed association order).
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x` (BLAS axpy).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Squared l2 distance between two equal-length slices.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(Matrix::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let c = a.matmul(&Matrix::eye(3)).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn matvec_and_transpose() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]).unwrap();
        assert_eq!(a.matvec(&[1.0, 1.0, 1.0]).unwrap(), vec![3.0, 3.0]);
        assert_eq!(a.t_matvec(&[1.0, 1.0]).unwrap(), vec![1.0, 3.0, 2.0]);
        let at = a.transpose();
        assert_eq!(at.rows(), 3);
        assert_eq!(at[(2, 0)], 2.0);
    }

    #[test]
    fn t_matvec_matches_transpose_matvec() {
        let a = Matrix::from_fn(4, 3, |i, j| (i as f64 + 1.0) * (j as f64 - 1.0));
        let x = vec![0.5, -1.0, 2.0, 3.0];
        let fast = a.t_matvec(&x).unwrap();
        let slow = a.transpose().matvec(&x).unwrap();
        for (f, s) in fast.iter().zip(&slow) {
            assert!((f - s).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_matches_explicit() {
        let a = Matrix::from_fn(5, 3, |i, j| ((i + 2 * j) as f64).sin());
        let g = a.gram();
        let g2 = a.transpose().matmul(&a).unwrap();
        assert!(g.max_abs_diff(&g2) < 1e-12);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f64> = (0..17).map(|i| i as f64 * 0.3).collect();
        let b: Vec<f64> = (0..17).map(|i| (i as f64).cos()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_works() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, vec![10.5, 21.0]);
    }

    #[test]
    fn sq_dist_works() {
        assert_eq!(sq_dist(&[0.0, 3.0], &[4.0, 0.0]), 25.0);
    }
}
