//! Scalar statistics helpers shared by solvers, baselines and the
//! evaluation harness (information-loss metrics of §4).

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Weighted mean with non-negative weights. Returns 0.0 if total weight is 0.
pub fn weighted_mean(xs: &[f64], ws: &[f64]) -> f64 {
    debug_assert_eq!(xs.len(), ws.len());
    let tw: f64 = ws.iter().sum();
    if tw <= 0.0 {
        return 0.0;
    }
    xs.iter().zip(ws).map(|(x, w)| x * w).sum::<f64>() / tw
}

/// Population variance. Returns 0.0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Standard deviation (population).
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Squared l2 norm of the difference — the paper's information-loss metric
/// (`‖w − w*‖₂²`).
pub fn l2_loss(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// l2 norm of the difference.
pub fn l2_dist(a: &[f64], b: &[f64]) -> f64 {
    l2_loss(a, b).sqrt()
}

/// Minimum of a slice (NaN-free input assumed). Panics on empty.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum of a slice (NaN-free input assumed). Panics on empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Count of distinct values after rounding to `decimals` (used to report
/// achieved quantization amounts in the presence of f64 round-off).
pub fn distinct_count(xs: &[f64], decimals: i32) -> usize {
    let scale = 10f64.powi(decimals);
    let mut keys: Vec<i64> = xs.iter().map(|&x| (x * scale).round() as i64).collect();
    keys.sort_unstable();
    keys.dedup();
    keys.len()
}

/// Exact distinct count via bit pattern (treats -0.0 == 0.0, folds NaNs).
pub fn distinct_count_exact(xs: &[f64]) -> usize {
    let mut keys: Vec<u64> = xs
        .iter()
        .map(|&x| if x == 0.0 { 0u64 } else { x.to_bits() })
        .collect();
    keys.sort_unstable();
    keys.dedup();
    keys.len()
}

/// Simple percentile (nearest-rank) on unsorted data; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
    s[rank.min(s.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
    }

    #[test]
    fn weighted_mean_works() {
        assert_eq!(weighted_mean(&[1.0, 3.0], &[1.0, 3.0]), 2.5);
        assert_eq!(weighted_mean(&[1.0, 3.0], &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn l2_loss_works() {
        assert_eq!(l2_loss(&[1.0, 2.0], &[1.0, 0.0]), 4.0);
        assert_eq!(l2_dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }

    #[test]
    fn min_max_work() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }

    #[test]
    fn distinct_counts() {
        let xs = [1.0, 1.0 + 1e-12, 2.0, 2.0, -0.0, 0.0];
        assert_eq!(distinct_count(&xs, 6), 3);
        assert_eq!(distinct_count_exact(&xs), 4);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
