//! Configuration system (S23).
//!
//! A deliberately small key=value config format (TOML-subset; serde is not
//! available offline — DESIGN §2). Files look like:
//!
//! ```text
//! # comment
//! workers = 4
//! queue_capacity = 256
//! artifacts_dir = "artifacts"
//! engine = "native"        # native | runtime | auto
//! seed = 42
//! ```
//!
//! Values are overridable via `SQLSQ_*` environment variables
//! (`SQLSQ_WORKERS=8`) and `--key value` CLI flags; precedence is
//! CLI > env > file > default.

use crate::runtime::BackendKind;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which engine the coordinator routes jobs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Pure-Rust engines only.
    #[default]
    Native,
    /// AOT/PJRT runtime only (errors if the artifact is missing).
    Runtime,
    /// Runtime where a bucket fits, native fallback otherwise.
    Auto,
}

impl Engine {
    /// Parse from the config string.
    pub fn parse(s: &str) -> Result<Engine> {
        match s {
            "native" => Ok(Engine::Native),
            "runtime" => Ok(Engine::Runtime),
            "auto" => Ok(Engine::Auto),
            _ => Err(Error::Config(format!("unknown engine '{s}'"))),
        }
    }
}

/// Eviction policy for the coordinator's serve-path result cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CachePolicy {
    /// Bounded LRU over compact result bytes (the default).
    #[default]
    Lru,
    /// Caching disabled: every admitted request solves.
    Off,
}

impl CachePolicy {
    /// Parse from the config string.
    pub fn parse(s: &str) -> Result<CachePolicy> {
        match s {
            "lru" => Ok(CachePolicy::Lru),
            "off" => Ok(CachePolicy::Off),
            _ => Err(Error::Config(format!("unknown cache policy '{s}' (lru|off)"))),
        }
    }

    /// Stable string id.
    pub fn id(self) -> &'static str {
        match self {
            CachePolicy::Lru => "lru",
            CachePolicy::Off => "off",
        }
    }
}

/// Full system configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Worker threads in the coordinator pool.
    pub workers: usize,
    /// Runtime-lane threads (each owns its backend — for PJRT, a client
    /// + compiled-artifact cache).
    pub runtime_lanes: usize,
    /// Which backend runtime lanes open (`pjrt` needs `make artifacts`;
    /// `shadow` replays the kernels natively and needs none).
    pub runtime_backend: BackendKind,
    /// Sub-lanes a runtime lane fans one drained batch across (1 =
    /// serial). Only effective for backends with Send sub-handles
    /// (shadow); PJRT lanes stay serial and scale via `runtime_lanes`.
    pub runtime_fanout: usize,
    /// Bounded job-queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// Max jobs per batch drained at once.
    pub max_batch: usize,
    /// Threads a worker fans one drained batch across (1 = serial; each
    /// worker hands chunks of its batch to scoped helper threads).
    pub batch_fanout: usize,
    /// Max microseconds the batcher waits to fill a batch.
    pub batch_wait_us: u64,
    /// Artifact directory for the PJRT runtime.
    pub artifacts_dir: PathBuf,
    /// Engine routing policy.
    pub engine: Engine,
    /// Serve-path result-cache policy (`lru` caches identical requests,
    /// `off` disables the cache entirely).
    pub cache_policy: CachePolicy,
    /// Result-cache capacity in compact-result bytes (LRU bound; only
    /// meaningful when `cache_policy` is `lru`).
    pub cache_capacity_bytes: usize,
    /// Whether the result cache is shared across tenants (`true`, the
    /// default: any tenant's exact resubmit hits any other's entry) or
    /// partitioned per tenant id (`false`: a tenant only ever hits its
    /// own entries — the tenant id salts the cache fingerprint and the
    /// full-key verification).
    pub cache_shared: bool,
    /// Global RNG seed.
    pub seed: u64,
    /// Directory for experiment reports.
    pub report_dir: PathBuf,
}

impl Default for Config {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map_or(4, |n| n.get());
        let workers = cores.min(8);
        // Spare cores beyond the worker pool, so a fully busy pool never
        // oversubscribes: 1 (serial) on hosts where workers already cover
        // every core, up to 4 on wide machines. Sizes both the native
        // batch fan-out and the runtime-lane fan-out.
        let spare_fanout = (cores / workers).clamp(1, 4);
        Config {
            workers,
            runtime_lanes: 2,
            runtime_backend: BackendKind::default(),
            runtime_fanout: spare_fanout,
            queue_capacity: 1024,
            max_batch: 32,
            batch_fanout: spare_fanout,
            batch_wait_us: 200,
            artifacts_dir: PathBuf::from("artifacts"),
            engine: Engine::Native,
            cache_policy: CachePolicy::Lru,
            cache_capacity_bytes: 32 << 20,
            cache_shared: true,
            seed: 0,
            report_dir: PathBuf::from("reports"),
        }
    }
}

impl Config {
    /// Parse the key=value file format.
    pub fn parse_str(text: &str) -> Result<Config> {
        let mut map = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", ln + 1))
            })?;
            map.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        let mut cfg = Config::default();
        cfg.apply_map(&map)?;
        Ok(cfg)
    }

    /// Load from a file, then apply `SQLSQ_*` env overrides.
    pub fn load(path: Option<&Path>) -> Result<Config> {
        let mut cfg = match path {
            Some(p) => Self::parse_str(&std::fs::read_to_string(p)?)?,
            None => Config::default(),
        };
        cfg.apply_env()?;
        Ok(cfg)
    }

    /// Apply one key.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let parse_usize = |v: &str| -> Result<usize> {
            v.parse().map_err(|_| Error::Config(format!("bad number '{v}' for {key}")))
        };
        match key {
            "workers" => {
                self.workers = parse_usize(value)?;
                if self.workers == 0 {
                    return Err(Error::Config("workers must be ≥ 1".into()));
                }
            }
            "runtime_lanes" => {
                self.runtime_lanes = parse_usize(value)?.max(1);
            }
            "runtime_backend" => self.runtime_backend = BackendKind::parse(value)?,
            "runtime_fanout" => {
                self.runtime_fanout = parse_usize(value)?.max(1);
            }
            "queue_capacity" => {
                self.queue_capacity = parse_usize(value)?;
                if self.queue_capacity == 0 {
                    return Err(Error::Config("queue_capacity must be ≥ 1".into()));
                }
            }
            "max_batch" => {
                self.max_batch = parse_usize(value)?.max(1);
            }
            "batch_fanout" => {
                self.batch_fanout = parse_usize(value)?.max(1);
            }
            "batch_wait_us" => {
                self.batch_wait_us = parse_usize(value)? as u64;
            }
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "report_dir" => self.report_dir = PathBuf::from(value),
            "engine" => self.engine = Engine::parse(value)?,
            "cache_policy" => self.cache_policy = CachePolicy::parse(value)?,
            "cache_capacity_bytes" => {
                self.cache_capacity_bytes = parse_usize(value)?;
                if self.cache_capacity_bytes == 0 {
                    return Err(Error::Config(
                        "cache_capacity_bytes must be ≥ 1 (use cache_policy = \"off\" to \
                         disable caching)"
                            .into(),
                    ));
                }
            }
            "cache_shared" => {
                self.cache_shared = match value {
                    "true" => true,
                    "false" => false,
                    _ => {
                        return Err(Error::Config(format!(
                            "bad cache_shared '{value}' (true|false)"
                        )))
                    }
                };
            }
            "seed" => {
                self.seed = value
                    .parse()
                    .map_err(|_| Error::Config(format!("bad seed '{value}'")))?;
            }
            _ => return Err(Error::Config(format!("unknown config key '{key}'"))),
        }
        Ok(())
    }

    fn apply_map(&mut self, map: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in map {
            self.set(k, v)?;
        }
        Ok(())
    }

    fn apply_env(&mut self) -> Result<()> {
        for key in [
            "workers",
            "runtime_lanes",
            "runtime_backend",
            "runtime_fanout",
            "queue_capacity",
            "max_batch",
            "batch_fanout",
            "batch_wait_us",
            "artifacts_dir",
            "report_dir",
            "engine",
            "cache_policy",
            "cache_capacity_bytes",
            "cache_shared",
            "seed",
        ] {
            let env_key = format!("SQLSQ_{}", key.to_uppercase());
            if let Ok(v) = std::env::var(&env_key) {
                self.set(key, &v)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.workers >= 1);
        assert!(c.queue_capacity >= 1);
        assert_eq!(c.engine, Engine::Native);
    }

    #[test]
    fn parses_file_format() {
        let c = Config::parse_str(
            r#"
            # comment
            workers = 3
            engine = "auto"   # inline comment
            artifacts_dir = "custom/dir"
            seed = 99
            "#,
        )
        .unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.engine, Engine::Auto);
        assert_eq!(c.artifacts_dir, PathBuf::from("custom/dir"));
        assert_eq!(c.seed, 99);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Config::parse_str("workers").is_err());
        assert!(Config::parse_str("workers = zero").is_err());
        assert!(Config::parse_str("workers = 0").is_err());
        assert!(Config::parse_str("nonsense = 1").is_err());
        assert!(Engine::parse("gpu").is_err());
    }

    #[test]
    fn batch_fanout_parse_and_floor() {
        let c = Config::parse_str("batch_fanout = 6").unwrap();
        assert_eq!(c.batch_fanout, 6);
        let c0 = Config::parse_str("batch_fanout = 0").unwrap();
        assert_eq!(c0.batch_fanout, 1, "floored to 1");
        assert!(Config::default().batch_fanout >= 1);
    }

    #[test]
    fn runtime_lanes_parse_and_floor() {
        let c = Config::parse_str("runtime_lanes = 3").unwrap();
        assert_eq!(c.runtime_lanes, 3);
        let c0 = Config::parse_str("runtime_lanes = 0").unwrap();
        assert_eq!(c0.runtime_lanes, 1, "floored to 1");
    }

    #[test]
    fn runtime_backend_and_fanout_parse() {
        let c = Config::parse_str("runtime_backend = \"shadow\"\nruntime_fanout = 3").unwrap();
        assert_eq!(c.runtime_backend, BackendKind::Shadow);
        assert_eq!(c.runtime_fanout, 3);
        assert_eq!(Config::default().runtime_backend, BackendKind::Pjrt);
        assert!(Config::default().runtime_fanout >= 1);
        let c0 = Config::parse_str("runtime_fanout = 0").unwrap();
        assert_eq!(c0.runtime_fanout, 1, "floored to 1");
        assert!(Config::parse_str("runtime_backend = \"tpu\"").is_err());
    }

    #[test]
    fn cache_policy_and_capacity_parse() {
        let c = Config::parse_str("cache_policy = \"off\"").unwrap();
        assert_eq!(c.cache_policy, CachePolicy::Off);
        let c = Config::parse_str("cache_capacity_bytes = 4096").unwrap();
        assert_eq!(c.cache_capacity_bytes, 4096);
        assert_eq!(c.cache_policy, CachePolicy::Lru, "LRU caching is on by default");
        assert!(Config::default().cache_capacity_bytes >= 1 << 20);
        assert!(Config::parse_str("cache_policy = \"fifo\"").is_err());
        assert!(Config::parse_str("cache_capacity_bytes = 0").is_err());
        assert_eq!(CachePolicy::parse("lru").unwrap().id(), "lru");
        assert_eq!(CachePolicy::parse("off").unwrap().id(), "off");
    }

    #[test]
    fn cache_shared_parse_and_default() {
        assert!(Config::default().cache_shared, "cache is shared by default");
        let c = Config::parse_str("cache_shared = false").unwrap();
        assert!(!c.cache_shared);
        let c = Config::parse_str("cache_shared = true").unwrap();
        assert!(c.cache_shared);
        assert!(Config::parse_str("cache_shared = maybe").is_err());
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::default();
        c.set("engine", "runtime").unwrap();
        assert_eq!(c.engine, Engine::Runtime);
        c.set("queue_capacity", "7").unwrap();
        assert_eq!(c.queue_capacity, 7);
    }
}
