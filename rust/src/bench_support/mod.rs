//! Benchmark harness (S21) — the offline substitute for criterion
//! (DESIGN §2).
//!
//! Criterion-like measurement loop: warmup, timed samples, robust stats
//! (median/mean/stddev/min), per-iteration auto-scaling so fast closures
//! are timed in batches, and a `black_box` to defeat dead-code
//! elimination. Bench binaries (`rust/benches/*.rs`, `harness = false`)
//! print one table row per case and can dump CSV for EXPERIMENTS.md.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-exported black box for benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Measurement configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Number of timed samples.
    pub samples: usize,
    /// Target wall-clock per sample (iterations auto-scale to reach it).
    pub sample_target: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            samples: 20,
            sample_target: Duration::from_millis(20),
        }
    }
}

/// Summary statistics for one benchmark case (all in seconds/iteration).
#[derive(Debug, Clone)]
pub struct Stats {
    /// Case label.
    pub name: String,
    /// Median time per iteration.
    pub median: f64,
    /// Mean time per iteration.
    pub mean: f64,
    /// Standard deviation across samples.
    pub stddev: f64,
    /// Fastest sample.
    pub min: f64,
    /// Iterations per sample used.
    pub iters_per_sample: u64,
}

impl Stats {
    /// Human row: `name  median  ±stddev  (min)`.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} ±{:>10} (min {:>12}) x{}",
            self.name,
            fmt_time(self.median),
            fmt_time(self.stddev),
            fmt_time(self.min),
            self.iters_per_sample
        )
    }

    /// CSV row: `name,median_s,mean_s,stddev_s,min_s`.
    pub fn csv(&self) -> String {
        format!(
            "{},{:.9},{:.9},{:.9},{:.9}",
            self.name, self.median, self.mean, self.stddev, self.min
        )
    }
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run one benchmark case.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> Stats {
    // Warmup + iteration calibration.
    let warm_start = Instant::now();
    let mut calib_iters = 0u64;
    while warm_start.elapsed() < cfg.warmup {
        f();
        calib_iters += 1;
    }
    let per_iter = cfg.warmup.as_secs_f64() / calib_iters.max(1) as f64;
    let iters_per_sample =
        ((cfg.sample_target.as_secs_f64() / per_iter.max(1e-12)).ceil() as u64).max(1);

    let mut samples = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var =
        samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / samples.len() as f64;
    Stats {
        name: name.to_string(),
        median,
        mean,
        stddev: var.sqrt(),
        min: samples[0],
        iters_per_sample,
    }
}

/// A suite accumulates rows, prints them, and optionally writes CSV.
pub struct Suite {
    title: String,
    cfg: BenchConfig,
    rows: Vec<Stats>,
}

impl Suite {
    /// New suite with the default config.
    pub fn new(title: &str) -> Suite {
        Self::with_config(title, BenchConfig::default())
    }

    /// New suite with a custom config.
    pub fn with_config(title: &str, cfg: BenchConfig) -> Suite {
        println!("\n== {title} ==");
        Suite { title: title.to_string(), cfg, rows: Vec::new() }
    }

    /// Run and record one case.
    pub fn case<F: FnMut()>(&mut self, name: &str, f: F) -> &Stats {
        let stats = bench(name, &self.cfg, f);
        println!("{}", stats.row());
        self.rows.push(stats);
        self.rows.last().unwrap()
    }

    /// All recorded rows.
    pub fn rows(&self) -> &[Stats] {
        &self.rows
    }

    /// Write `reports/bench_<slug>.csv`.
    pub fn write_csv(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let path = dir.join(format!("bench_{slug}.csv"));
        let mut text = String::from("name,median_s,mean_s,stddev_s,min_s\n");
        for r in &self.rows {
            text.push_str(&r.csv());
            text.push('\n');
        }
        std::fs::write(&path, text)?;
        Ok(path)
    }
}

/// Fast config for CI / smoke runs (used by `cargo bench -- --quick` via
/// env var `SQLSQ_BENCH_QUICK=1`).
pub fn active_config() -> BenchConfig {
    if std::env::var("SQLSQ_BENCH_QUICK").is_ok() {
        BenchConfig {
            warmup: Duration::from_millis(20),
            samples: 5,
            sample_target: Duration::from_millis(2),
        }
    } else {
        BenchConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            samples: 3,
            sample_target: Duration::from_millis(1),
        }
    }

    #[test]
    fn measures_something_positive() {
        let s = bench("noop-ish", &quick(), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.median > 0.0);
        assert!(s.min <= s.median);
        assert!(s.iters_per_sample >= 1);
    }

    #[test]
    fn slower_work_measures_slower() {
        let cfg = quick();
        let fast = bench("fast", &cfg, || {
            black_box((0..10u64).sum::<u64>());
        });
        let slow = bench("slow", &cfg, || {
            black_box((0..100_000u64).map(|x| x.wrapping_mul(x)).sum::<u64>());
        });
        assert!(slow.median > fast.median * 5.0, "fast={} slow={}", fast.median, slow.median);
    }

    #[test]
    fn rows_and_csv() {
        let mut suite = Suite::with_config("Test Suite", quick());
        suite.case("a", || {
            black_box(1 + 1);
        });
        assert_eq!(suite.rows().len(), 1);
        let dir = std::env::temp_dir().join("sqlsq_bench_test");
        let path = suite.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("name,median_s"));
        assert!(text.lines().count() == 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
