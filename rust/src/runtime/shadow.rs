//! ShadowBackend: a deterministic, artifact-free replay of the runtime.
//!
//! Re-implements the three Pallas kernel families
//! (`python/compile/kernels/{lasso_cd,kmeans,gmm,mlp}.py`) natively in
//! f32 and drives them through the *same* shared control flow as the
//! PJRT executor ([`super::backend`]'s `drive_*` helpers): identical
//! shape-bucket selection, identical inert padding, identical
//! iterations-per-call granularity and convergence tests.
//!
//! ## Fidelity contract
//!
//! * **f32 boundary** — every kernel computes in single precision, like
//!   the artifacts; callers widen outputs back to f64 exactly where the
//!   runtime lane does.
//! * **Padding inertness** — inputs are padded to the same shape buckets
//!   with the same inert rows (weight 0 / diff 0 / sentinel components),
//!   so padding bugs reproduce under test, not just on PJRT.
//! * **Iterations per call** — one "call" fuses `EPOCHS_PER_CALL` (8) CD
//!   epochs / `LLOYD_ITERS_PER_CALL` (4) Lloyd steps / `EM_ITERS_PER_CALL`
//!   (4) EM steps, mirroring `python/compile/model.py`, so convergence
//!   and early-stop behave call-for-call like the artifact path.
//!
//! The shadow is *deterministic* (fixed summation order, no threads
//! inside a kernel), so batch fan-out across sub-handles is bitwise
//! reproducible. It is **not** bitwise-identical to XLA (different f32
//! summation schedules); integration tests that compare against PJRT
//! keep their tolerance-based asserts.
//!
//! All state is an immutable `Arc` — the shadow's analogue of the PJRT
//! [`super::artifact::ArtifactCache`] — so [`ShadowBackend::clone`]
//! hands out cheap `Send` sub-executors for intra-lane fan-out.

use super::backend::{self, ExecutorBackend, RuntimeInfo, RuntimeLasso};
use super::buckets;
use crate::{Error, Result};
use std::sync::{Arc, Mutex};
use std::thread::ThreadId;

/// Shape buckets and fusion factors mirroring `python/compile/aot.py` /
/// `model.py`. These are the shapes the real artifact set is lowered
/// for; the shadow accepts exactly the same requests.
#[derive(Debug, Clone)]
pub struct ShadowBuckets {
    /// Lasso `m` buckets.
    pub lasso: Vec<usize>,
    /// (m, k) kmeans buckets.
    pub kmeans: Vec<(usize, usize)>,
    /// (m, k) gmm buckets.
    pub gmm: Vec<(usize, usize)>,
    /// MLP artifact batch rows.
    pub mlp_batch: usize,
    /// CD epochs fused per lasso call.
    pub epochs_per_call: usize,
    /// Lloyd steps fused per kmeans call.
    pub lloyd_iters_per_call: usize,
    /// EM steps fused per gmm call.
    pub em_iters_per_call: usize,
}

impl Default for ShadowBuckets {
    fn default() -> Self {
        ShadowBuckets {
            lasso: vec![64, 256, 1024],
            kmeans: vec![(256, 8), (256, 32), (1024, 8), (1024, 64)],
            gmm: vec![(256, 8), (1024, 32)],
            mlp_batch: 64,
            epochs_per_call: 8,
            lloyd_iters_per_call: 4,
            em_iters_per_call: 4,
        }
    }
}

/// One recorded kernel call (test/diagnostic surface): which kernel
/// family ran, and on which OS thread — the fan-out assertions read the
/// thread ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CallRecord {
    /// Kernel family ("lasso_cd" | "kmeans" | "gmm" | "mlp").
    pub kernel: &'static str,
    /// OS thread the call executed on.
    pub thread: ThreadId,
}

#[derive(Debug)]
struct ShadowState {
    buckets: ShadowBuckets,
    /// When set, every kernel call fails with this message (failure
    /// injection for fallback/metrics tests).
    fail: Option<String>,
    /// When true, every kernel call appends a [`CallRecord`].
    capturing: bool,
    capture: Mutex<Vec<CallRecord>>,
}

/// Deterministic native replay backend. Cloning yields a cheap handle
/// onto the same shared state (sub-executor for fan-out).
#[derive(Debug, Clone)]
pub struct ShadowBackend {
    state: Arc<ShadowState>,
}

impl Default for ShadowBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ShadowBackend {
    fn from_state(buckets: ShadowBuckets, fail: Option<String>, capturing: bool) -> Self {
        ShadowBackend {
            state: Arc::new(ShadowState {
                buckets,
                fail,
                capturing,
                capture: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Default-bucket shadow backend (mirrors the real artifact set).
    pub fn new() -> Self {
        Self::from_state(ShadowBuckets::default(), None, false)
    }

    /// Shadow backend with a custom bucket table.
    pub fn with_buckets(buckets: ShadowBuckets) -> Self {
        Self::from_state(buckets, None, false)
    }

    /// Shadow backend that records every kernel call (and its thread id)
    /// for test assertions; read the log with [`ShadowBackend::calls`].
    pub fn with_capture() -> Self {
        Self::from_state(ShadowBuckets::default(), None, true)
    }

    /// Failure-injection backend: capability probing works, but every
    /// kernel call errors with `msg` — exercises the Auto-policy native
    /// fallback and the strict-policy error surface.
    pub fn failing(msg: &str) -> Self {
        Self::from_state(ShadowBuckets::default(), Some(msg.to_string()), false)
    }

    /// Snapshot of the recorded kernel calls (empty unless built with
    /// [`ShadowBackend::with_capture`]).
    pub fn calls(&self) -> Vec<CallRecord> {
        self.state.capture.lock().unwrap().clone()
    }

    /// Number of distinct OS threads the recorded calls ran on.
    pub fn distinct_call_threads(&self) -> usize {
        let ids: std::collections::HashSet<ThreadId> =
            self.calls().iter().map(|c| c.thread).collect();
        ids.len()
    }

    fn enter(&self, kernel: &'static str) -> Result<()> {
        if self.state.capturing {
            self.state
                .capture
                .lock()
                .unwrap()
                .push(CallRecord { kernel, thread: std::thread::current().id() });
        }
        match &self.state.fail {
            Some(msg) => Err(Error::Runtime(format!("shadow backend (injected): {msg}"))),
            None => Ok(()),
        }
    }
}

impl ExecutorBackend for ShadowBackend {
    fn backend_id(&self) -> &'static str {
        "shadow"
    }

    fn platform(&self) -> String {
        "shadow".to_string()
    }

    fn max_lasso_m(&self) -> usize {
        self.state.buckets.lasso.iter().copied().max().unwrap_or(0)
    }

    fn lasso_epochs_per_call(&self) -> usize {
        self.state.buckets.epochs_per_call
    }

    fn info(&self) -> RuntimeInfo {
        RuntimeInfo {
            max_lasso_m: self.max_lasso_m(),
            kmeans_buckets: self.state.buckets.kmeans.clone(),
            gmm_buckets: self.state.buckets.gmm.clone(),
        }
    }

    fn lasso_solve(
        &mut self,
        w: &[f32],
        d: &[f32],
        lambda1: f32,
        lambda2: f32,
        max_calls: usize,
        tol: f32,
    ) -> Result<RuntimeLasso> {
        // Dim validation lives in the shared driver (`drive_lasso`).
        let m = w.len();
        let bucket = buckets::pick(&self.state.buckets.lasso, m).ok_or_else(|| {
            Error::Runtime(format!("no lasso bucket fits m={m} (max {})", self.max_lasso_m()))
        })?;
        let epochs = self.state.buckets.epochs_per_call;
        let this = self.clone();
        let step = |wp: &[f32], dp: &[f32], cwp: &[f32], lam: &[f32; 2], alpha: &[f32]| {
            this.enter("lasso_cd")?;
            let mut a = alpha.to_vec();
            for _ in 0..epochs {
                lasso_cd_epoch(wp, dp, cwp, lam[0], lam[1], &mut a);
            }
            Ok(a)
        };
        backend::drive_lasso(w, d, lambda1, lambda2, max_calls, tol, bucket, step)
    }

    fn kmeans_lloyd(
        &mut self,
        points: &[f32],
        weights: &[f32],
        centroids: &[f32],
        min_calls: usize,
    ) -> Result<Vec<f32>> {
        let m = points.len();
        let k = centroids.len();
        let (bm, bk) = self
            .state
            .buckets
            .kmeans
            .iter()
            .copied()
            .filter(|&(bm, bk)| bm >= m && bk >= k)
            .min()
            .ok_or_else(|| Error::Runtime(format!("no kmeans bucket fits m={m}, k={k}")))?;
        let iters = self.state.buckets.lloyd_iters_per_call;
        let this = self.clone();
        backend::drive_kmeans(points, weights, centroids, min_calls, bm, bk, |pts, cw, cen| {
            this.enter("kmeans")?;
            let mut c = cen.to_vec();
            for _ in 0..iters {
                c = kmeans_step(pts, cw, &c);
            }
            Ok(c)
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn gmm_em(
        &mut self,
        points: &[f32],
        weights: &[f32],
        means: &[f32],
        variances: &[f32],
        mix: &[f32],
        var_floor: f32,
        calls: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = points.len();
        let k = means.len();
        let (bm, bk) = self
            .state
            .buckets
            .gmm
            .iter()
            .copied()
            .filter(|&(bm, bk)| bm >= m && bk >= k)
            .min()
            .ok_or_else(|| Error::Runtime(format!("no gmm bucket fits m={m}, k={k}")))?;
        let iters = self.state.buckets.em_iters_per_call;
        let this = self.clone();
        backend::drive_gmm(
            points,
            weights,
            means,
            variances,
            mix,
            var_floor,
            calls,
            bm,
            bk,
            |pts, cw, mu, var, pi, floor| {
                this.enter("gmm")?;
                let mut state = (mu.to_vec(), var.to_vec(), pi.to_vec());
                for _ in 0..iters {
                    state = gmm_em_step(pts, cw, &state.0, &state.1, &state.2, floor[0]);
                }
                Ok(state)
            },
        )
    }

    fn mlp_forward(
        &mut self,
        x: &[f32],
        rows: usize,
        in_dim: usize,
        out_dim: usize,
        params: &[(&[f32], &[f32])],
    ) -> Result<Vec<f32>> {
        if params.len() != 4 {
            return Err(Error::InvalidInput("mlp_forward: need 4 layers".into()));
        }
        // Validate the layer chain like the manifest shapes would.
        let mut dim = in_dim;
        for (i, (w, b)) in params.iter().enumerate() {
            let out = b.len();
            if w.len() != dim * out {
                return Err(Error::InvalidInput(format!(
                    "mlp_forward: layer {i} weight is {} elements, expected {dim}×{out}",
                    w.len()
                )));
            }
            dim = out;
        }
        if dim != out_dim {
            return Err(Error::InvalidInput("mlp_forward: out_dim mismatch".into()));
        }
        let batch = self.state.buckets.mlp_batch;
        let this = self.clone();
        backend::drive_mlp(x, rows, in_dim, out_dim, batch, |xb| {
            this.enter("mlp")?;
            let mut h = xb.to_vec();
            let mut din = in_dim;
            for (i, (w, b)) in params.iter().enumerate() {
                h = dense(&h, batch, din, w, b, i + 1 < params.len());
                din = b.len();
            }
            Ok(h)
        })
    }

    fn try_sub_handle(&self) -> Option<Box<dyn ExecutorBackend + Send>> {
        Some(Box::new(self.clone()))
    }
}

// ---------------------------------------------------------------------------
// f32 kernel replays (direct translations of the Pallas kernel bodies).
// ---------------------------------------------------------------------------

fn sign(x: f32) -> f32 {
    // jnp.sign semantics: sign(0) = 0 (f32::signum(0) would be ±1).
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// One weighted Gauss-Seidel CD epoch in the O(m) suffix-scalar form
/// (descending pass), mirroring `kernels/lasso_cd.py::_epoch_body`.
/// Padded rows have `cw = 0` (never enter a suffix sum); padded
/// coordinates have `d = 0` (the `c_j > 0` guard skips them).
fn lasso_cd_epoch(w: &[f32], d: &[f32], cw: &[f32], lam1: f32, lam2: f32, alpha: &mut [f32]) {
    let m = w.len();
    // Residual at epoch start: r = w − cumsum(d ⊙ α).
    let mut r = vec![0.0f32; m];
    let mut rec = 0.0f32;
    for i in 0..m {
        rec += d[i] * alpha[i];
        r[i] = w[i] - rec;
    }
    // Suffix weight sums W_j = Σ_{i≥j} cw_i (column norms).
    let mut wsuf = vec![0.0f32; m];
    let mut acc = 0.0f32;
    for j in (0..m).rev() {
        acc += cw[j];
        wsuf[j] = acc;
    }
    // Descending pass with the lazy suffix scalar s = Σ_{i≥j} cw_i r_i.
    let mut s = 0.0f32;
    for jj in 0..m {
        let j = m - 1 - jj;
        s += cw[j] * r[j];
        let dj = d[j];
        let cj = dj * dj * wsuf[j];
        // Unstable negative-l2 denominator falls back to the plain-l1
        // rule per coordinate. Deliberately the kernel's exact `> 0`
        // test (`jnp.where(denom > 0, denom, cj)` in lasso_cd.py), NOT
        // the native solver's relative-epsilon guard — the shadow's
        // fidelity target is the artifact, epsilon-regime included.
        let mut denom = cj - 2.0 * lam2;
        if denom <= 0.0 {
            denom = cj;
        }
        let rho = dj * s + cj * alpha[j];
        let shrunk = sign(rho) * (rho.abs() - lam1).max(0.0);
        let mut new = shrunk / if denom > 0.0 { denom } else { 1.0 };
        // Guard: skip null columns (padding / d_j = 0).
        if cj <= 0.0 {
            new = alpha[j];
        }
        let delta = new - alpha[j];
        // Update the suffix scalar for the residual change on rows i ≥ j.
        s -= dj * delta * wsuf[j];
        alpha[j] = new;
    }
}

/// One full Lloyd step (assign + weighted accumulate + empty-cluster
/// hold + sort), mirroring `kernels/kmeans.py::kmeans_step`. Weight-0
/// (padding) points fall out of every accumulator.
fn kmeans_step(pts: &[f32], cw: &[f32], cen: &[f32]) -> Vec<f32> {
    let k = cen.len();
    let mut sums = vec![0.0f32; k];
    let mut wsums = vec![0.0f32; k];
    for (i, &x) in pts.iter().enumerate() {
        // argmin with first-wins ties (jnp.argmin semantics).
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (c, &mu) in cen.iter().enumerate() {
            let d = (x - mu) * (x - mu);
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        sums[best] += cw[i] * x;
        wsums[best] += cw[i];
    }
    let mut new: Vec<f32> = (0..k)
        .map(|c| if wsums[c] > 0.0 { sums[c] / wsums[c] } else { cen[c] })
        .collect();
    new.sort_by(f32::total_cmp);
    new
}

const LOG2PI: f32 = 1.837_877_1;

/// One full EM step (log-space E-step + sufficient statistics + M-step
/// finalization + sort-by-mean), mirroring `kernels/gmm.py`. Weight-0
/// points and ≈0-mass components (padding) keep their parameters.
fn gmm_em_step(
    pts: &[f32],
    cw: &[f32],
    mu: &[f32],
    var: &[f32],
    pi: &[f32],
    var_floor: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let k = mu.len();
    let mut n = vec![0.0f32; k];
    let mut sx = vec![0.0f32; k];
    let mut sxx = vec![0.0f32; k];
    let log_pi: Vec<f32> = pi.iter().map(|&p| p.max(1e-30).ln()).collect();
    let log_var: Vec<f32> = var.iter().map(|&v| v.ln()).collect();
    let mut logp = vec![0.0f32; k];
    for (i, &x) in pts.iter().enumerate() {
        if cw[i] == 0.0 {
            continue; // responsibilities scale by cw — exactly 0 mass
        }
        let mut maxlp = f32::NEG_INFINITY;
        for c in 0..k {
            let d = x - mu[c];
            let lp = -0.5 * (d * d / var[c] + log_var[c] + LOG2PI) + log_pi[c];
            logp[c] = lp;
            maxlp = maxlp.max(lp);
        }
        // logsumexp over components.
        let mut sum = 0.0f32;
        for c in 0..k {
            sum += (logp[c] - maxlp).exp();
        }
        let lse = maxlp + sum.ln();
        for c in 0..k {
            let r = (logp[c] - lse).exp() * cw[i];
            n[c] += r;
            sx[c] += r * x;
            sxx[c] += r * x * x;
        }
    }
    // M-step finalization: underflowed components keep their parameters.
    let total: f32 = n.iter().sum();
    let mut new_mu = vec![0.0f32; k];
    let mut new_var = vec![0.0f32; k];
    let mut new_pi = vec![0.0f32; k];
    for c in 0..k {
        let ok = n[c] > 1e-12 * total.max(1e-30);
        if ok {
            new_mu[c] = sx[c] / n[c];
            new_var[c] = (sxx[c] / n[c] - new_mu[c] * new_mu[c]).max(var_floor);
            new_pi[c] = n[c] / total.max(1e-30);
        } else {
            new_mu[c] = mu[c];
            new_var[c] = var[c];
            new_pi[c] = pi[c];
        }
    }
    let pi_sum: f32 = new_pi.iter().sum();
    if pi_sum > 0.0 {
        for p in &mut new_pi {
            *p /= pi_sum;
        }
    }
    // Keep means sorted with variances/weights permuted alongside
    // (stable argsort, like jnp.argsort).
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| new_mu[a].total_cmp(&new_mu[b]));
    (
        order.iter().map(|&c| new_mu[c]).collect(),
        order.iter().map(|&c| new_var[c]).collect(),
        order.iter().map(|&c| new_pi[c]).collect(),
    )
}

/// Fused dense layer `relu(x @ w + b)` over a row-major batch,
/// mirroring `kernels/mlp.py::dense_ref`.
fn dense(x: &[f32], rows: usize, in_dim: usize, w: &[f32], b: &[f32], relu: bool) -> Vec<f32> {
    let out_dim = b.len();
    let mut z = vec![0.0f32; rows * out_dim];
    for r in 0..rows {
        let xr = &x[r * in_dim..(r + 1) * in_dim];
        let zr = &mut z[r * out_dim..(r + 1) * out_dim];
        zr.copy_from_slice(b);
        for (i, &xi) in xr.iter().enumerate() {
            if xi == 0.0 {
                continue; // zero-padded rows stay b, then relu — cheap skip
            }
            let wrow = &w[i * out_dim..(i + 1) * out_dim];
            for (o, &wv) in wrow.iter().enumerate() {
                zr[o] += xi * wv;
            }
        }
        if relu {
            for v in zr {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;
    use crate::quant::{self, unique::UniqueDecomp, vmatrix::VBasis};

    fn sample(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| rng.uniform(0.0, 1.0)).collect()
    }

    #[test]
    fn reports_default_buckets() {
        let ex = ShadowBackend::new();
        assert_eq!(ex.max_lasso_m(), 1024);
        assert_eq!(ex.lasso_epochs_per_call(), 8);
        assert_eq!(ex.platform(), "shadow");
        assert_eq!(ex.backend_id(), "shadow");
        let info = ex.info();
        assert!(info.fits(crate::quant::QuantMethod::KMeans, 1000, 64));
        assert!(!info.fits(crate::quant::QuantMethod::KMeans, 2000, 8));
    }

    #[test]
    fn lasso_matches_native_structured_solver_per_epoch() {
        // Same contract the PJRT artifact is tested against
        // (integration_runtime.rs): one call = epochs_per_call native
        // epochs, α within f32 tolerance of the f64 solver.
        let data = sample(11, 60);
        let u = UniqueDecomp::new(&data).unwrap();
        let basis = VBasis::new(&u.values);
        let w32: Vec<f32> = u.values.iter().map(|&x| x as f32).collect();
        let d32: Vec<f32> = basis.diffs().iter().map(|&x| x as f32).collect();

        let mut ex = ShadowBackend::new();
        let epc = ex.lasso_epochs_per_call();
        let rt = ex.lasso_solve(&w32, &d32, 0.05, 0.0, 1, 0.0).unwrap();
        assert_eq!(rt.calls, 1);

        let cfg = quant::lasso::LassoConfig {
            lambda1: 0.05,
            max_epochs: epc,
            tol: 0.0,
            ..Default::default()
        };
        let native = quant::lasso::solve(&basis, &u.values, &cfg, None).unwrap();
        assert_eq!(native.epochs, epc);
        for (i, (a32, a64)) in rt.alpha.iter().zip(&native.alpha).enumerate() {
            assert!(
                (*a32 as f64 - a64).abs() < 5e-3,
                "α[{i}]: shadow {a32} vs native {a64}"
            );
        }
    }

    #[test]
    fn lasso_padding_is_inert() {
        // The same data solved through two different buckets (256 via the
        // picker, 1024 via a custom table) must agree bitwise: pads are
        // provably inert.
        let data = sample(3, 80); // 80 distinct uniform draws ⇒ m = 80
        let u = UniqueDecomp::new(&data).unwrap();
        assert!(u.m() <= 256);
        let basis = VBasis::new(&u.values);
        let w32: Vec<f32> = u.values.iter().map(|&x| x as f32).collect();
        let d32: Vec<f32> = basis.diffs().iter().map(|&x| x as f32).collect();
        let mut small = ShadowBackend::new(); // picks the smallest fitting bucket
        let mut big = ShadowBackend::with_buckets(ShadowBuckets {
            lasso: vec![1024],
            ..ShadowBuckets::default()
        });
        let a = small.lasso_solve(&w32, &d32, 0.02, 0.0, 10, 1e-6).unwrap();
        let b = big.lasso_solve(&w32, &d32, 0.02, 0.0, 10, 1e-6).unwrap();
        assert_eq!(a.calls, b.calls);
        for (x, y) in a.alpha.iter().zip(&b.alpha) {
            assert_eq!(x.to_bits(), y.to_bits(), "padding changed a coefficient");
        }
    }

    #[test]
    fn kmeans_finds_tight_groups() {
        let mut data = Vec::new();
        let mut rng = Pcg32::seeded(5);
        for c in [0.1f64, 0.5, 0.9] {
            for _ in 0..40 {
                data.push(c + rng.uniform(-0.01, 0.01));
            }
        }
        let pts: Vec<f32> = data.iter().map(|&x| x as f32).collect();
        let cw = vec![1.0f32; pts.len()];
        let cen0 = vec![0.2f32, 0.6, 0.8];
        let mut ex = ShadowBackend::new();
        let cen = ex.kmeans_lloyd(&pts, &cw, &cen0, 10).unwrap();
        assert_eq!(cen.len(), 3);
        assert!((cen[0] - 0.1).abs() < 0.02, "{cen:?}");
        assert!((cen[1] - 0.5).abs() < 0.02, "{cen:?}");
        assert!((cen[2] - 0.9).abs() < 0.02, "{cen:?}");
    }

    #[test]
    fn gmm_finds_separated_modes() {
        let mut rng = Pcg32::seeded(6);
        let mut pts = Vec::new();
        for c in [10.0f32, 90.0] {
            for _ in 0..128 {
                pts.push(c + rng.normal_with(0.0, 1.0) as f32);
            }
        }
        let cw = vec![1.0f32; pts.len()];
        let mu0 = vec![30.0f32, 60.0];
        let var0 = vec![200.0f32, 200.0];
        let pi0 = vec![0.5f32, 0.5];
        let mut ex = ShadowBackend::new();
        let (mu, var, pi) = ex.gmm_em(&pts, &cw, &mu0, &var0, &pi0, 1e-4, 10).unwrap();
        assert!((mu[0] - 10.0).abs() < 1.0, "mu={mu:?}");
        assert!((mu[1] - 90.0).abs() < 1.0, "mu={mu:?}");
        assert!(var[0] < 5.0 && var[1] < 5.0, "var={var:?}");
        assert!((pi[0] - 0.5).abs() < 0.05, "pi={pi:?}");
        assert!((pi.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn mlp_forward_matches_native_infer() {
        let mlp = crate::nn::mlp::Mlp::paper_arch(3);
        let mut rows = Vec::new();
        for d in 0..4 {
            rows.push(crate::data::synth_digits::canonical_digit(d).pixels);
        }
        let rows_n = rows.len();
        let x32: Vec<f32> = rows.iter().flatten().map(|&v| v as f32).collect();
        let params32: Vec<(Vec<f32>, Vec<f32>)> = mlp
            .layers
            .iter()
            .map(|l| {
                (
                    l.w.data().iter().map(|&v| v as f32).collect(),
                    l.b.iter().map(|&v| v as f32).collect(),
                )
            })
            .collect();
        let params_ref: Vec<(&[f32], &[f32])> =
            params32.iter().map(|(w, b)| (w.as_slice(), b.as_slice())).collect();
        let mut ex = ShadowBackend::new();
        let logits = ex.mlp_forward(&x32, rows_n, 784, 10, &params_ref).unwrap();
        assert_eq!(logits.len(), rows_n * 10);

        let mut xm = crate::linalg::matrix::Matrix::zeros(rows_n, 784);
        for (i, r) in rows.iter().enumerate() {
            xm.row_mut(i).copy_from_slice(r);
        }
        let native = mlp.infer(&xm).unwrap();
        for i in 0..rows_n {
            for j in 0..10 {
                let a = logits[i * 10 + j] as f64;
                let b = native[(i, j)];
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "logit[{i},{j}]: shadow {a} vs native {b}"
                );
            }
        }
    }

    #[test]
    fn failure_injection_errors_every_kernel() {
        let mut ex = ShadowBackend::failing("boom");
        let w = vec![0.1f32, 0.4];
        let d = vec![0.1f32, 0.3];
        let err = ex.lasso_solve(&w, &d, 0.01, 0.0, 2, 1e-6).unwrap_err();
        assert!(err.to_string().contains("boom"), "err: {err}");
        assert!(ex.kmeans_lloyd(&w, &d, &w, 1).is_err());
        // Capability probing still works — Auto routes jobs here, and
        // the per-call failure triggers the fallback.
        assert!(ex.max_lasso_m() > 0);
    }

    #[test]
    fn capture_records_calls_and_threads() {
        let probe = ShadowBackend::with_capture();
        let mut ex = probe.clone(); // sub-handle shares the log
        let w = vec![0.1f32, 0.4, 0.9];
        let d = vec![0.1f32, 0.3, 0.5];
        ex.lasso_solve(&w, &d, 0.01, 0.0, 2, 0.0).unwrap();
        let calls = probe.calls();
        assert!(!calls.is_empty());
        assert!(calls.iter().all(|c| c.kernel == "lasso_cd"));
        assert_eq!(probe.distinct_call_threads(), 1);
    }

    #[test]
    fn empty_or_mismatched_inputs_error_instead_of_degenerate_sentinels() {
        // Empty points would give a -inf sentinel (pads sorting first);
        // the shared drivers must reject them for every backend.
        let mut ex = ShadowBackend::new();
        assert!(ex.kmeans_lloyd(&[], &[], &[0.5], 1).is_err());
        assert!(ex.gmm_em(&[], &[], &[0.5], &[1.0], &[1.0], 1e-6, 1).is_err());
        let pts = [0.1f32, 0.9];
        assert!(ex.kmeans_lloyd(&pts, &[1.0], &[0.5], 1).is_err(), "weights mismatch");
        assert!(ex.lasso_solve(&[], &[], 0.01, 0.0, 1, 1e-6).is_err());
    }

    #[test]
    fn oversize_requests_fail_with_bucket_errors() {
        let mut ex = ShadowBackend::new();
        let w = vec![0.5f32; 2000];
        let d = vec![0.1f32; 2000];
        let err = ex.lasso_solve(&w, &d, 0.01, 0.0, 2, 1e-6).unwrap_err();
        assert!(err.to_string().contains("no lasso bucket"), "err: {err}");
        let pts = vec![0.5f32; 100];
        let cw = vec![1.0f32; 100];
        let cen = vec![0.5f32; 80]; // k too large for every bucket
        assert!(ex.kmeans_lloyd(&pts, &cw, &cen, 1).is_err());
    }
}
