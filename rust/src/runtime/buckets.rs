//! Shape-bucket selection.
//!
//! AOT executables are compiled for fixed shapes; a request of size `m`
//! runs on the smallest bucket that fits, padded with inert rows. This is
//! the same trick serving systems use for batch/sequence dims.

/// Pick the smallest bucket ≥ `m`. Returns `None` if `m` exceeds all
/// buckets (caller falls back to the native engine).
pub fn pick(buckets: &[usize], m: usize) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= m).min()
}

/// Pad `xs` to `len` with `fill`.
pub fn pad(xs: &[f32], len: usize, fill: f32) -> Vec<f32> {
    debug_assert!(xs.len() <= len);
    let mut out = Vec::with_capacity(len);
    out.extend_from_slice(xs);
    out.resize(len, fill);
    out
}

/// Padding plan for the lasso artifact: rows repeat the last value with
/// zero weight, coordinates get zero diff so they can never activate.
pub struct LassoPadding {
    /// Padded `w` (last value repeated).
    pub w: Vec<f32>,
    /// Padded diffs (0 in the pad region).
    pub d: Vec<f32>,
    /// Row weights (1 real, 0 pad).
    pub cw: Vec<f32>,
    /// Padded α (0 in the pad region).
    pub alpha: Vec<f32>,
}

/// Build the lasso padding plan.
pub fn pad_lasso(w: &[f32], d: &[f32], alpha: &[f32], bucket: usize) -> LassoPadding {
    let last = *w.last().expect("non-empty w");
    let m = w.len();
    LassoPadding {
        w: pad(w, bucket, last),
        d: pad(d, bucket, 0.0),
        cw: {
            let mut cw = vec![1.0f32; m];
            cw.resize(bucket, 0.0);
            cw
        },
        alpha: pad(alpha, bucket, 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_smallest_fitting() {
        let b = [64usize, 256, 1024];
        assert_eq!(pick(&b, 1), Some(64));
        assert_eq!(pick(&b, 64), Some(64));
        assert_eq!(pick(&b, 65), Some(256));
        assert_eq!(pick(&b, 1024), Some(1024));
        assert_eq!(pick(&b, 1025), None);
        assert_eq!(pick(&[], 1), None);
    }

    #[test]
    fn pad_preserves_prefix() {
        let p = pad(&[1.0, 2.0], 4, 9.0);
        assert_eq!(p, vec![1.0, 2.0, 9.0, 9.0]);
    }

    #[test]
    fn lasso_padding_plan() {
        let p = pad_lasso(&[1.0, 3.0], &[1.0, 2.0], &[1.0, 1.0], 4);
        assert_eq!(p.w, vec![1.0, 3.0, 3.0, 3.0]);
        assert_eq!(p.d, vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(p.cw, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(p.alpha, vec![1.0, 1.0, 0.0, 0.0]);
    }
}
