//! Offline shim for the `xla_extension` PJRT bindings.
//!
//! The build must work fully offline with zero external crates
//! (DESIGN §2), but [`super::artifact`] is written against the real
//! `xla` crate surface (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`, mirroring /opt/xla-example/load_hlo). This
//! module provides that exact surface so the runtime lane *compiles and
//! degrades cleanly* everywhere:
//!
//! * `PjRtClient::cpu()` succeeds (it is only a handle), so manifests are
//!   still parsed, buckets indexed, and capability routing works;
//! * `HloModuleProto::from_text_file` still surfaces missing/unreadable
//!   artifact files as errors naming the path (the failure-injection
//!   tests rely on this);
//! * `PjRtClient::compile` — the first point that needs a real XLA — fails
//!   with a recognizable "offline stub" error, which `Engine::Auto`
//!   converts into a per-job native fallback and `Engine::Runtime`
//!   surfaces loudly.
//!
//! A real deployment replaces this module with the `xla_extension`
//! bindings (same paths, same signatures); nothing outside this file
//! changes. CI-grade coverage of the runtime serve path does not need it:
//! the [`super::shadow::ShadowBackend`] replays the kernels natively.

const STUB_MSG: &str =
    "PJRT unavailable: built with the offline xla shim (runtime/xla.rs); \
     link the real xla_extension bindings or serve with the shadow backend";

/// Error type matching the real bindings' surface (Display only).
#[derive(Debug)]
pub struct XlaError(String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

/// PJRT client handle. Creation succeeds so that opening an artifact
/// directory (manifest parse + bucket indexing) works offline; only
/// compilation requires the real bindings.
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client. Always succeeds in the shim.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient)
    }

    /// Platform name (diagnostics). The shim is honest about itself.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile an HLO computation — the first operation that genuinely
    /// needs XLA, and therefore the shim's failure point.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError(STUB_MSG.to_string()))
    }
}

/// Parsed HLO module proto (text interchange format).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Read an HLO text file. I/O failures surface the path (missing
    /// artifacts must fail with a message naming the file); content is
    /// not parsed — the real parse happens in the real bindings.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        std::fs::read_to_string(path).map_err(|e| XlaError(format!("{path}: {e}")))?;
        Ok(HloModuleProto)
    }
}

/// An XLA computation built from a module proto.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host-side tensor literal.
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dims.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    /// Unpack a tuple literal. Unreachable in the shim (nothing compiles,
    /// so nothing executes), kept for signature parity.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError(STUB_MSG.to_string()))
    }

    /// Copy out as a typed vector. Unreachable in the shim.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError(STUB_MSG.to_string()))
    }
}

/// A compiled executable. Never constructed by the shim (`compile`
/// fails), but the type must exist for the cache signatures.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments. Unreachable in the shim.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError(STUB_MSG.to_string()))
    }
}

/// A device buffer returned by `execute`. Unreachable in the shim.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer back to the host. Unreachable in the shim.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError(STUB_MSG.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_opens_but_compile_fails_loudly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub");
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("offline xla shim"), "err: {err}");
    }

    #[test]
    fn missing_hlo_file_names_the_path() {
        let err = HloModuleProto::from_text_file("/no/such/file.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("/no/such/file.hlo.txt"), "err: {err}");
    }
}
