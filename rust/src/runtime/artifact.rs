//! Artifact registry: manifest parsing + lazy compile + executable cache.
//!
//! The Python AOT pipeline writes `artifacts/manifest.json` describing each
//! lowered graph (name, HLO file, input shapes/dtypes, semantic metadata).
//! The registry loads the manifest, validates it, and compiles executables
//! on first use — compile once, execute many (DESIGN §9).

use super::xla;
use crate::jsonio::{self, Json};
use crate::{Error, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// One tensor input declared in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Dimensions (row-major).
    pub shape: Vec<usize>,
    /// Dtype name (currently always "float32").
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Stable name, e.g. `lasso_cd_m256`.
    pub name: String,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Semantic metadata (kind, bucket dims, iters per call).
    pub meta: HashMap<String, Json>,
}

impl ArtifactSpec {
    /// Metadata field as usize.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }

    /// Metadata field as str.
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|j| j.as_str())
    }
}

/// Parsed manifest + compiled-executable cache.
pub struct Registry {
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Registry {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let specs = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT cpu client: {e}")))?;
        Ok(Registry { dir: dir.to_path_buf(), specs, client, cache: HashMap::new() })
    }

    /// All artifact specs.
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Find a spec by name.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| s.name == name)
    }

    /// Names of artifacts of a given kind, with their `m` bucket.
    pub fn buckets_of_kind(&self, kind: &str) -> Vec<(String, usize)> {
        buckets_of_kind(&self.specs, kind)
    }

    /// The PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling + caching on first use) the executable for `name`.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self
                .spec(name)
                .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?;
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` with f32 vector inputs shaped per the
    /// manifest. Returns the flattened f32 outputs (tuple elements in
    /// order).
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let spec = self
            .spec(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs given, manifest declares {}",
                inputs.len(),
                spec.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, ts) in inputs.iter().zip(&spec.inputs) {
            if data.len() != ts.elements() {
                return Err(Error::Runtime(format!(
                    "{name}: input has {} elements, spec {:?} needs {}",
                    data.len(),
                    ts.shape,
                    ts.elements()
                )));
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
            let lit = lit
                .reshape(&dims)
                .map_err(|e| Error::Runtime(format!("{name}: reshape: {e}")))?;
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("{name}: execute: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("{name}: to_literal: {e}")))?;
        // Lowered with return_tuple=True: unwrap the tuple.
        let elems = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("{name}: tuple: {e}")))?;
        elems
            .into_iter()
            .map(|l| {
                l.to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("{name}: to_vec: {e}")))
            })
            .collect()
    }
}

/// Shared compiled-artifact state for one runtime lane: the manifest,
/// the PJRT client and the compiled-executable cache behind an `Rc`, so
/// sub-executors on the same lane thread compile/load each artifact
/// **once** and share the executables ([`super::Executor::fork`]).
///
/// PJRT handles are `Rc`-based (not Send), so an `ArtifactCache` never
/// crosses threads — cross-thread batch fan-out needs a backend whose
/// shared state is Send ([`super::ShadowBackend`]). This type is the
/// split between *compiled-artifact state* (here) and *execution state*
/// (bucket indexes + padding/convergence driving, in the executor).
pub struct ArtifactCache {
    inner: Rc<RefCell<Registry>>,
}

impl ArtifactCache {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<ArtifactCache> {
        Ok(Self::from_registry(Registry::open(dir)?))
    }

    /// Wrap an already-open registry.
    pub fn from_registry(registry: Registry) -> ArtifactCache {
        ArtifactCache { inner: Rc::new(RefCell::new(registry)) }
    }

    /// Cheap same-thread handle sharing the compiled-executable cache.
    pub fn handle(&self) -> ArtifactCache {
        ArtifactCache { inner: Rc::clone(&self.inner) }
    }

    /// The PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.inner.borrow().platform()
    }

    /// Snapshot of the artifact specs (open-time bucket indexing).
    pub fn specs(&self) -> Vec<ArtifactSpec> {
        self.inner.borrow().specs().to_vec()
    }

    /// Metadata field of one artifact as usize.
    pub fn meta_usize(&self, name: &str, key: &str) -> Option<usize> {
        self.inner.borrow().spec(name).and_then(|s| s.meta_usize(key))
    }

    /// Execute artifact `name` (compiling + caching on first use).
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.inner.borrow_mut().execute_f32(name, inputs)
    }
}

/// Names of artifacts of a given kind, with their `m` bucket — the one
/// filter shared by the registry surface and the executor's open-time
/// bucket indexing.
pub fn buckets_of_kind(specs: &[ArtifactSpec], kind: &str) -> Vec<(String, usize)> {
    specs
        .iter()
        .filter(|s| s.meta_str("kind") == Some(kind))
        .filter_map(|s| s.meta_usize("m").map(|m| (s.name.clone(), m)))
        .collect()
}

/// (name, m, k) buckets of a given kind (kmeans/gmm shapes).
pub fn mk_buckets_of_kind(specs: &[ArtifactSpec], kind: &str) -> Vec<(String, usize, usize)> {
    specs
        .iter()
        .filter(|s| s.meta_str("kind") == Some(kind))
        .filter_map(|s| Some((s.name.clone(), s.meta_usize("m")?, s.meta_usize("k")?)))
        .collect()
}

/// Load and parse `manifest.json` from an artifact directory without
/// creating a PJRT client (cheap capability probing; Send-safe).
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        Error::Runtime(format!(
            "cannot read {} (run `make artifacts` first): {e}",
            manifest_path.display()
        ))
    })?;
    parse_manifest(&text)
}

/// Parse `manifest.json` text into artifact specs.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let j = jsonio::parse(text)?;
    let version = j
        .get("version")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| Error::Runtime("manifest: missing version".into()))?;
    if version != 1 {
        return Err(Error::Runtime(format!("manifest: unsupported version {version}")));
    }
    let arts = j
        .get("artifacts")
        .and_then(|a| a.as_arr())
        .ok_or_else(|| Error::Runtime("manifest: missing artifacts".into()))?;
    let mut specs = Vec::with_capacity(arts.len());
    for a in arts {
        let name = a
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| Error::Runtime("manifest: artifact missing name".into()))?
            .to_string();
        let file = a
            .get("file")
            .and_then(|v| v.as_str())
            .ok_or_else(|| Error::Runtime(format!("manifest: {name} missing file")))?
            .to_string();
        if file.contains("..") || file.starts_with('/') {
            return Err(Error::Runtime(format!("manifest: {name}: suspicious path {file}")));
        }
        let inputs = a
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Runtime(format!("manifest: {name} missing inputs")))?
            .iter()
            .map(|i| -> Result<TensorSpec> {
                let shape = i
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| Error::Runtime(format!("manifest: {name}: bad shape")))?
                    .iter()
                    .map(|d| {
                        d.as_usize()
                            .ok_or_else(|| Error::Runtime(format!("manifest: {name}: bad dim")))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let dtype = i
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("float32")
                    .to_string();
                if dtype != "float32" {
                    return Err(Error::Runtime(format!(
                        "manifest: {name}: unsupported dtype {dtype}"
                    )));
                }
                Ok(TensorSpec { shape, dtype })
            })
            .collect::<Result<Vec<_>>>()?;
        let meta = match a.get("meta") {
            Some(Json::Obj(m)) => m.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            _ => HashMap::new(),
        };
        specs.push(ArtifactSpec { name, file, inputs, meta });
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "lasso_cd_m64", "file": "lasso_cd_m64.hlo.txt",
         "inputs": [
            {"shape": [64], "dtype": "float32"},
            {"shape": [64], "dtype": "float32"},
            {"shape": [64], "dtype": "float32"},
            {"shape": [2], "dtype": "float32"},
            {"shape": [64], "dtype": "float32"}],
         "meta": {"kind": "lasso_cd", "m": 64, "epochs_per_call": 8}},
        {"name": "kmeans_m256_k8", "file": "kmeans_m256_k8.hlo.txt",
         "inputs": [
            {"shape": [256], "dtype": "float32"},
            {"shape": [256], "dtype": "float32"},
            {"shape": [8], "dtype": "float32"}],
         "meta": {"kind": "kmeans", "m": 256, "k": 8, "iters_per_call": 4}}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let specs = parse_manifest(SAMPLE).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "lasso_cd_m64");
        assert_eq!(specs[0].inputs.len(), 5);
        assert_eq!(specs[0].inputs[3].shape, vec![2]);
        assert_eq!(specs[0].meta_usize("epochs_per_call"), Some(8));
        assert_eq!(specs[1].meta_str("kind"), Some("kmeans"));
        assert_eq!(specs[1].inputs[2].elements(), 8);
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest(r#"{"version": 2, "artifacts": []}"#).is_err());
        assert!(parse_manifest(
            r#"{"version": 1, "artifacts": [{"name": "x", "file": "../evil", "inputs": []}]}"#
        )
        .is_err());
        assert!(parse_manifest(
            r#"{"version": 1, "artifacts": [{"name": "x", "file": "f",
                "inputs": [{"shape": [4], "dtype": "int8"}]}]}"#
        )
        .is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Written by `make artifacts`; validate when available.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let specs = parse_manifest(&text).unwrap();
            assert!(specs.iter().any(|s| s.name.starts_with("lasso_cd_m")));
            assert!(specs.iter().any(|s| s.name.starts_with("kmeans_m")));
            assert!(specs.iter().any(|s| s.name.starts_with("mlp_fwd_b")));
        }
    }
}
