//! Execution backends for the runtime lane.
//!
//! [`ExecutorBackend`] abstracts the three kernel families (lasso_cd,
//! kmeans, gmm) plus the batched MLP forward behind typed calls, so the
//! coordinator's runtime lane is written once against the trait and
//! served by either:
//!
//! * [`super::Executor`] — the real PJRT path (AOT HLO artifacts,
//!   compile-once per lane via [`super::artifact::ArtifactCache`]);
//! * [`super::ShadowBackend`] — a deterministic native replay of the
//!   artifact kernels with the runtime's exact f32 / shape-bucket padding
//!   / iterations-per-call semantics. No PJRT, no artifacts — the CI
//!   stand-in that puts the whole serve path under test.
//!
//! The bucket-padding plans and the call-chaining convergence loops live
//! here as shared drivers (`drive_*`): both backends run the *identical*
//! control flow — bucket fit, inert padding, per-call convergence and
//! early-stop tests — and differ only in what one "artifact call" does.
//! That shared control flow is the shadow backend's fidelity contract.

use super::{artifact, buckets};
use crate::quant::QuantMethod;
use crate::{Error, Result};
use std::path::Path;

/// Result of a runtime LASSO solve.
#[derive(Debug, Clone)]
pub struct RuntimeLasso {
    /// Final coefficients (unpadded, length = original m).
    pub alpha: Vec<f32>,
    /// Artifact calls made (each = `epochs_per_call` CD epochs).
    pub calls: usize,
    /// Converged before the call budget?
    pub converged: bool,
}

/// Which backend implementation a runtime lane opens.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// AOT artifacts on the PJRT runtime (needs `make artifacts`).
    #[default]
    Pjrt,
    /// Deterministic native replay of the artifact kernels (no
    /// artifacts needed; the CI/testing backend).
    Shadow,
}

impl BackendKind {
    /// Parse from config/CLI strings.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "shadow" => Ok(BackendKind::Shadow),
            _ => Err(Error::Config(format!("unknown runtime backend '{s}' (pjrt|shadow)"))),
        }
    }

    /// Stable string id.
    pub fn id(self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Shadow => "shadow",
        }
    }
}

/// Bucket metadata for capability routing (no PJRT client involved).
#[derive(Debug, Clone, Default)]
pub struct RuntimeInfo {
    /// Largest lasso `m` bucket.
    pub max_lasso_m: usize,
    /// Available (m, k) kmeans buckets.
    pub kmeans_buckets: Vec<(usize, usize)>,
    /// Available (m, k) gmm buckets.
    pub gmm_buckets: Vec<(usize, usize)>,
}

impl RuntimeInfo {
    /// Probe a manifest on disk (Send-safe; used by the router). Shares
    /// the manifest filters with the executor's bucket indexing so
    /// routing capability can never diverge from execution.
    pub fn probe(dir: &Path) -> Result<RuntimeInfo> {
        let specs = artifact::load_manifest(dir)?;
        let drop_name = |b: Vec<(String, usize, usize)>| -> Vec<(usize, usize)> {
            b.into_iter().map(|(_, m, k)| (m, k)).collect()
        };
        Ok(RuntimeInfo {
            max_lasso_m: artifact::buckets_of_kind(&specs, "lasso_cd")
                .iter()
                .map(|&(_, m)| m)
                .max()
                .unwrap_or(0),
            kmeans_buckets: drop_name(artifact::mk_buckets_of_kind(&specs, "kmeans")),
            gmm_buckets: drop_name(artifact::mk_buckets_of_kind(&specs, "gmm")),
        })
    }

    /// Does any bucket fit this (method, m, k) request?
    pub fn fits(&self, method: QuantMethod, m: usize, k: usize) -> bool {
        match method {
            QuantMethod::L1 | QuantMethod::L1LeastSquare => m <= self.max_lasso_m,
            QuantMethod::KMeans => self
                .kmeans_buckets
                .iter()
                .any(|&(bm, bk)| m <= bm && k <= bk),
            QuantMethod::Gmm => self
                .gmm_buckets
                .iter()
                .any(|&(bm, bk)| m <= bm && k <= bk),
            _ => false,
        }
    }
}

/// Typed execution surface of a runtime lane.
///
/// Implementations own whatever compiled/cached state they need; the
/// coordinator only sees these calls. Methods take `&mut self` because
/// the PJRT implementation caches compiled executables on first use.
pub trait ExecutorBackend {
    /// Stable backend id ("pjrt" | "shadow"), for logs and metrics.
    fn backend_id(&self) -> &'static str;

    /// Platform name (diagnostics).
    fn platform(&self) -> String;

    /// Largest lasso bucket available (capability probe).
    fn max_lasso_m(&self) -> usize;

    /// Epochs fused into one lasso call.
    fn lasso_epochs_per_call(&self) -> usize;

    /// Capability table for routing (bucket fits).
    fn info(&self) -> RuntimeInfo;

    /// Run CD-LASSO until convergence: repeated calls of
    /// `lasso_epochs_per_call` epochs each, until the max α move falls
    /// under `tol` or `max_calls` is exhausted.
    fn lasso_solve(
        &mut self,
        w: &[f32],
        d: &[f32],
        lambda1: f32,
        lambda2: f32,
        max_calls: usize,
        tol: f32,
    ) -> Result<RuntimeLasso>;

    /// Run `min_calls` fused-Lloyd calls; returns centroids truncated to
    /// the real k.
    fn kmeans_lloyd(
        &mut self,
        points: &[f32],
        weights: &[f32],
        centroids: &[f32],
        min_calls: usize,
    ) -> Result<Vec<f32>>;

    /// Run `calls` fused-EM calls; returns (means, variances, weights)
    /// truncated to the real k.
    #[allow(clippy::too_many_arguments)]
    fn gmm_em(
        &mut self,
        points: &[f32],
        weights: &[f32],
        means: &[f32],
        variances: &[f32],
        mix: &[f32],
        var_floor: f32,
        calls: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;

    /// Forward a row-major `rows × in_dim` batch through the MLP;
    /// `params` are (w, b) pairs. Rows are chunked/padded to the
    /// backend's batch size.
    fn mlp_forward(
        &mut self,
        x: &[f32],
        rows: usize,
        in_dim: usize,
        out_dim: usize,
        params: &[(&[f32], &[f32])],
    ) -> Result<Vec<f32>>;

    /// Cheap per-thread sub-executor sharing this backend's compiled
    /// state, for intra-lane batch fan-out. `None` means handles are
    /// thread-pinned (PJRT: `Rc`-based, not Send) and the lane serves
    /// its batches serially.
    fn try_sub_handle(&self) -> Option<Box<dyn ExecutorBackend + Send>>;
}

/// Open a backend of the given kind. The shadow backend ignores the
/// artifact directory — it needs none.
pub fn open_backend(kind: BackendKind, dir: &Path) -> Result<Box<dyn ExecutorBackend>> {
    match kind {
        BackendKind::Pjrt => Ok(Box::new(super::Executor::open(dir)?)),
        BackendKind::Shadow => Ok(Box::new(super::ShadowBackend::new())),
    }
}

// ---------------------------------------------------------------------------
// Shared call drivers: padding + convergence control flow, identical for
// every backend. One "call" is whatever the backend fuses per artifact
// dispatch (epochs_per_call CD epochs, iters_per_call Lloyd/EM steps).
// ---------------------------------------------------------------------------

/// Drive CD-LASSO over a raw step function. `call(w, d, cw, lam, alpha)`
/// runs one fused call on padded inputs and returns the new padded α.
#[allow(clippy::too_many_arguments)]
pub(crate) fn drive_lasso<F>(
    w: &[f32],
    d: &[f32],
    lambda1: f32,
    lambda2: f32,
    max_calls: usize,
    tol: f32,
    bucket: usize,
    mut call: F,
) -> Result<RuntimeLasso>
where
    F: FnMut(&[f32], &[f32], &[f32], &[f32; 2], &[f32]) -> Result<Vec<f32>>,
{
    let m = w.len();
    // All dim checks live here, once, for every backend.
    if m == 0 || d.len() != m || bucket < m {
        return Err(Error::InvalidInput("lasso_solve: bad dims".into()));
    }
    let alpha0 = vec![1.0f32; m];
    let buckets::LassoPadding { w: wp, d: dp, cw: cwp, alpha: mut alpha } =
        buckets::pad_lasso(w, d, &alpha0, bucket);
    let lam = [lambda1, lambda2];
    let mut calls = 0usize;
    let mut converged = false;
    // Support-stability early stop, mirroring the native solver (§Perf):
    // only the zero pattern matters downstream.
    let mut last_sig = 0u64;
    let mut stable = 0usize;
    while calls < max_calls {
        calls += 1;
        let new_alpha = call(&wp, &dp, &cwp, &lam, &alpha)?;
        let max_move = alpha
            .iter()
            .zip(&new_alpha)
            .zip(&dp)
            .map(|((a, b), dd)| ((a - b) * dd).abs())
            .fold(0.0f32, f32::max);
        alpha = new_alpha;
        if max_move < tol {
            converged = true;
            break;
        }
        let mut sig = 0xcbf29ce484222325u64;
        for (i, &a) in alpha.iter().enumerate() {
            if a.abs() > 1e-7 {
                sig = (sig ^ i as u64).wrapping_mul(0x100000001b3);
            }
        }
        if sig == last_sig {
            stable += 1;
            // Each call is epochs_per_call epochs; 2 stable calls ≈ the
            // native patience.
            if stable >= 2 {
                converged = true;
                break;
            }
        } else {
            last_sig = sig;
            stable = 0;
        }
    }
    alpha.truncate(m);
    Ok(RuntimeLasso { alpha, calls, converged })
}

/// Sentinel value far above the data range, so no real point selects a
/// padded component and sorting keeps pads last. One min/max pass;
/// callers guarantee `points` is non-empty.
fn sentinel_above(points: &[f32]) -> f32 {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &p in points {
        lo = lo.min(p);
        hi = hi.max(p);
    }
    hi + (hi - lo).max(1.0) * 10.0
}

/// Point weights padded to the bucket with zero-weight (inert) rows;
/// real weights can be multiplicities.
fn pad_weights(weights: &[f32], bm: usize) -> Vec<f32> {
    let mut cw = weights.to_vec();
    cw.resize(bm, 0.0);
    cw
}

/// Drive fused-Lloyd calls with sentinel padding. `call(pts, cw, cen)`
/// runs one fused call and returns the new padded centroid vector.
pub(crate) fn drive_kmeans<F>(
    points: &[f32],
    weights: &[f32],
    centroids: &[f32],
    min_calls: usize,
    bm: usize,
    bk: usize,
    mut call: F,
) -> Result<Vec<f32>>
where
    F: FnMut(&[f32], &[f32], &[f32]) -> Result<Vec<f32>>,
{
    let k = centroids.len();
    // Empty points would make the sentinel degenerate (-inf pads sorting
    // *first*); mismatched weights would mis-weight real rows.
    if points.is_empty() || weights.len() != points.len() {
        return Err(Error::InvalidInput("kmeans_lloyd: bad dims".into()));
    }
    let pts = buckets::pad(points, bm, 0.0);
    let cw = pad_weights(weights, bm);
    let sentinel = sentinel_above(points);
    // Distinct pads (sentinel, sentinel+1, …) so sort order is stable;
    // every Lloyd step keeps empty pad clusters at their value ≥
    // sentinel, so the spacing survives across calls.
    let mut cen = buckets::pad(centroids, bk, sentinel);
    for (i, c) in cen.iter_mut().enumerate().skip(k) {
        *c = sentinel + (i - k) as f32;
    }
    for _ in 0..min_calls.max(1) {
        cen = call(&pts, &cw, &cen)?;
    }
    // Real centroids are the k smallest (sentinels sort last).
    cen.truncate(k);
    Ok(cen)
}

/// Drive fused-EM calls with sentinel padding. `call(pts, cw, mu, var,
/// pi, floor)` runs one fused call and returns the new padded
/// (means, variances, weights).
#[allow(clippy::too_many_arguments)]
pub(crate) fn drive_gmm<F>(
    points: &[f32],
    weights: &[f32],
    means: &[f32],
    variances: &[f32],
    mix: &[f32],
    var_floor: f32,
    calls: usize,
    bm: usize,
    bk: usize,
    mut call: F,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>
where
    F: FnMut(
        &[f32],
        &[f32],
        &[f32],
        &[f32],
        &[f32],
        &[f32; 1],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>,
{
    let k = means.len();
    // Same degenerate-sentinel guard as [`drive_kmeans`], plus the
    // component-parameter dims — once, for every backend.
    if points.is_empty()
        || weights.len() != points.len()
        || variances.len() != k
        || mix.len() != k
    {
        return Err(Error::InvalidInput("gmm_em: bad dims".into()));
    }
    // Pad points with weight 0; pad components with zero mixing weight
    // and a far-away sentinel mean so sorting keeps them last.
    let pts = buckets::pad(points, bm, 0.0);
    let cw = pad_weights(weights, bm);
    let sentinel = sentinel_above(points);
    let mut mu = means.to_vec();
    let mut var = variances.to_vec();
    let mut pi = mix.to_vec();
    for i in k..bk {
        mu.push(sentinel + (i - k) as f32);
        var.push(1.0);
        pi.push(0.0);
    }
    let floor = [var_floor];
    for _ in 0..calls.max(1) {
        let (nmu, nvar, npi) = call(&pts, &cw, &mu, &var, &pi, &floor)?;
        mu = nmu;
        var = nvar;
        pi = npi;
    }
    mu.truncate(k);
    var.truncate(k);
    pi.truncate(k);
    // Renormalize over the real components (pads carried ≈0 mass).
    let total: f32 = pi.iter().sum();
    if total > 0.0 {
        for p in &mut pi {
            *p /= total;
        }
    }
    Ok((mu, var, pi))
}

/// Drive the MLP forward in batch-sized chunks. `call(xb)` forwards one
/// zero-padded `batch × in_dim` chunk and returns `batch × out_dim`
/// logits.
pub(crate) fn drive_mlp<F>(
    x: &[f32],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    batch: usize,
    mut call: F,
) -> Result<Vec<f32>>
where
    F: FnMut(&[f32]) -> Result<Vec<f32>>,
{
    if x.len() != rows * in_dim {
        return Err(Error::InvalidInput("mlp_forward: x dims".into()));
    }
    let mut logits = Vec::with_capacity(rows * out_dim);
    let mut row = 0usize;
    while row < rows {
        let take = (rows - row).min(batch);
        let mut xb = vec![0.0f32; batch * in_dim];
        xb[..take * in_dim].copy_from_slice(&x[row * in_dim..(row + take) * in_dim]);
        let out = call(&xb)?;
        if out.len() < take * out_dim {
            return Err(Error::Runtime("mlp call returned a short batch".into()));
        }
        logits.extend_from_slice(&out[..take * out_dim]);
        row += take;
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse_roundtrip() {
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("shadow").unwrap(), BackendKind::Shadow);
        assert!(BackendKind::parse("gpu").is_err());
        assert_eq!(BackendKind::Shadow.id(), "shadow");
        assert_eq!(BackendKind::default(), BackendKind::Pjrt);
    }

    #[test]
    fn runtime_info_fit_logic() {
        let info = RuntimeInfo {
            max_lasso_m: 256,
            kmeans_buckets: vec![(256, 8), (1024, 64)],
            gmm_buckets: vec![(256, 8)],
        };
        assert!(info.fits(QuantMethod::L1, 256, 0));
        assert!(!info.fits(QuantMethod::L1, 257, 0));
        assert!(info.fits(QuantMethod::KMeans, 300, 32));
        assert!(!info.fits(QuantMethod::KMeans, 2000, 8));
        assert!(!info.fits(QuantMethod::KMeans, 100, 100));
        assert!(info.fits(QuantMethod::Gmm, 100, 8));
        assert!(!info.fits(QuantMethod::Gmm, 1000, 8));
        assert!(!info.fits(QuantMethod::ClusterLs, 10, 2));
    }

    #[test]
    fn drive_lasso_pads_and_truncates() {
        // A step that returns α unchanged converges by support stability
        // after two stable calls.
        let w = [0.1f32, 0.4, 0.9];
        let d = [0.1f32, 0.3, 0.5];
        let sol = drive_lasso(&w, &d, 0.0, 0.0, 10, 0.0, 8, |wp, dp, cwp, _lam, alpha| {
            assert_eq!(wp.len(), 8);
            assert_eq!(dp.len(), 8);
            assert_eq!(cwp[..3], [1.0, 1.0, 1.0]);
            assert_eq!(cwp[3..], [0.0; 5]);
            Ok(alpha.to_vec())
        })
        .unwrap();
        assert_eq!(sol.alpha.len(), 3);
        assert!(sol.converged);
        assert!(sol.calls <= 3);
    }

    #[test]
    fn drive_kmeans_keeps_sentinels_last() {
        let pts = [0.0f32, 0.5, 1.0];
        let wts = [1.0f32, 1.0, 1.0];
        let cen0 = [0.2f32, 0.8];
        let cen = drive_kmeans(&pts, &wts, &cen0, 2, 4, 4, |p, cw, c| {
            assert_eq!(p.len(), 4);
            assert_eq!(cw[3], 0.0);
            // Pads sit above the data range.
            assert!(c[2] > 1.0 && c[3] > 1.0);
            Ok(c.to_vec())
        })
        .unwrap();
        assert_eq!(cen, vec![0.2, 0.8]);
    }

    #[test]
    fn drive_mlp_chunks_and_unpads() {
        // Identity-ish call: echo the first out_dim entries per row.
        let x: Vec<f32> = (0..6).map(|i| i as f32).collect(); // 3 rows × 2
        let out = drive_mlp(&x, 3, 2, 1, 2, |xb| {
            assert_eq!(xb.len(), 4); // batch 2 × in_dim 2
            Ok(vec![xb[0], xb[2]])
        })
        .unwrap();
        assert_eq!(out, vec![0.0, 2.0, 4.0]);
    }
}
