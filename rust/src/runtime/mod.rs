//! PJRT runtime (S18): load AOT-compiled JAX/Pallas artifacts and execute
//! them from the Rust request path.
//!
//! The flow mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. HLO
//! *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! Python runs once at `make artifacts`; after that the binary is
//! self-contained. Because `m = |unique(w)|` is data-dependent, executables
//! are compiled per **shape bucket** ([`buckets`]) and inputs are padded
//! with provably-inert rows (weight 0 / diff 0 — see the kernel docs and
//! the padding tests on both sides of the language boundary).

pub mod artifact;
pub mod buckets;
pub mod executor;

pub use artifact::{ArtifactSpec, Registry};
pub use executor::Executor;
