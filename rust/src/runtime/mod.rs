//! Runtime lane (S18): execute the paper's kernels from the Rust
//! request path, behind a pluggable [`ExecutorBackend`].
//!
//! Two backends implement the same typed surface (lasso_cd epochs,
//! fused Lloyd steps, fused EM steps, batched MLP forward):
//!
//! * **[`Executor`] (pjrt)** — loads AOT-compiled JAX/Pallas artifacts
//!   (HLO *text*; jax ≥ 0.5 emits 64-bit-id protos that xla_extension
//!   0.5.1 rejects, so the text parser reassigns ids) and executes them
//!   via PJRT, mirroring /opt/xla-example/load_hlo: `PjRtClient::cpu()`
//!   → `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!   Python runs once at `make artifacts`; after that the binary is
//!   self-contained. Compiled-artifact state (client + executable cache)
//!   lives in a per-lane [`ArtifactCache`]; same-thread sub-executors
//!   share it via [`Executor::fork`]. PJRT handles are `Rc`-based (not
//!   Send), so PJRT lanes serve their batches serially and scale with
//!   `runtime_lanes`. This build links the offline [`mod@xla`] shim —
//!   capability probing works everywhere, artifact *execution* needs the
//!   real `xla_extension` bindings dropped in place of that one file.
//! * **[`ShadowBackend`] (shadow)** — a deterministic native replay of
//!   the same kernels with the runtime's exact semantics: **f32
//!   arithmetic end to end**, **identical shape-bucket padding** (inert
//!   rows: weight 0 / diff 0 / sentinel components), and **identical
//!   iterations-per-call fusion** (8 CD epochs, 4 Lloyd steps, 4 EM
//!   steps per call). It needs no artifacts and is Send, so the
//!   coordinator fans one drained batch across `runtime_fanout` scoped
//!   sub-lanes via [`ExecutorBackend::try_sub_handle`]. This is how the
//!   whole runtime serve path (batching, routing, fallback, widening,
//!   metrics) runs under `cargo test -q` with no PJRT present — see
//!   `tests/integration_runtime_batch.rs`.
//!
//! Because `m = |unique(w)|` is data-dependent, executables are compiled
//! per **shape bucket** ([`buckets`]) and inputs are padded with
//! provably-inert rows (see the kernel docs and the padding tests on
//! both sides of the language boundary); the shadow backend reuses the
//! very same padding plans, so padding bugs are caught artifact-free.

pub mod artifact;
pub mod backend;
pub mod buckets;
pub mod executor;
pub mod shadow;
pub mod xla;

pub use artifact::{ArtifactCache, ArtifactSpec, Registry};
pub use backend::{open_backend, BackendKind, ExecutorBackend, RuntimeInfo, RuntimeLasso};
pub use executor::Executor;
pub use shadow::{CallRecord, ShadowBackend, ShadowBuckets};
