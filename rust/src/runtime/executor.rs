//! Typed execution layer over the artifact registry.
//!
//! The executor owns the registry and exposes the three kernel families as
//! typed calls with automatic shape-bucketing, padding and unpadding. The
//! Rust side drives convergence (one artifact call = a fixed number of
//! inner iterations, see `model.py`), so a single compiled executable
//! serves every λ, warm start and iteration budget.

use super::artifact::Registry;
use super::buckets;
use crate::{Error, Result};
use std::path::Path;

/// Typed runtime front-end.
pub struct Executor {
    registry: Registry,
    lasso_buckets: Vec<(String, usize)>,
    kmeans_buckets: Vec<(String, usize, usize)>, // (name, m, k)
    gmm_buckets: Vec<(String, usize, usize)>,    // (name, m, k)
    mlp_batch: Option<(String, usize)>,
}

/// Result of a runtime LASSO solve.
#[derive(Debug, Clone)]
pub struct RuntimeLasso {
    /// Final coefficients (unpadded, length = original m).
    pub alpha: Vec<f32>,
    /// Artifact calls made (each = `epochs_per_call` CD epochs).
    pub calls: usize,
    /// Converged before the call budget?
    pub converged: bool,
}

impl Executor {
    /// Open the artifact directory and index the buckets.
    pub fn open(dir: &Path) -> Result<Executor> {
        let registry = Registry::open(dir)?;
        let mut lasso_buckets = registry.buckets_of_kind("lasso_cd");
        lasso_buckets.sort_by_key(|&(_, m)| m);
        let mut kmeans_buckets: Vec<(String, usize, usize)> = registry
            .specs()
            .iter()
            .filter(|s| s.meta_str("kind") == Some("kmeans"))
            .filter_map(|s| {
                Some((s.name.clone(), s.meta_usize("m")?, s.meta_usize("k")?))
            })
            .collect();
        kmeans_buckets.sort_by_key(|&(_, m, k)| (m, k));
        let mut gmm_buckets: Vec<(String, usize, usize)> = registry
            .specs()
            .iter()
            .filter(|s| s.meta_str("kind") == Some("gmm"))
            .filter_map(|s| {
                Some((s.name.clone(), s.meta_usize("m")?, s.meta_usize("k")?))
            })
            .collect();
        gmm_buckets.sort_by_key(|&(_, m, k)| (m, k));
        let mlp_batch = registry
            .specs()
            .iter()
            .find(|s| s.meta_str("kind") == Some("mlp_fwd"))
            .and_then(|s| Some((s.name.clone(), s.meta_usize("batch")?)));
        Ok(Executor { registry, lasso_buckets, kmeans_buckets, gmm_buckets, mlp_batch })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.registry.platform()
    }

    /// Largest lasso bucket available (capability probe).
    pub fn max_lasso_m(&self) -> usize {
        self.lasso_buckets.iter().map(|&(_, m)| m).max().unwrap_or(0)
    }

    /// Epochs fused into one lasso artifact call.
    pub fn lasso_epochs_per_call(&self) -> usize {
        self.lasso_buckets
            .first()
            .and_then(|(n, _)| self.registry.spec(n))
            .and_then(|s| s.meta_usize("epochs_per_call"))
            .unwrap_or(1)
    }

    /// Run CD-LASSO on the runtime until convergence: repeated artifact
    /// calls, each `epochs_per_call` epochs, until the max α move falls
    /// under `tol` or `max_calls` is exhausted.
    pub fn lasso_solve(
        &mut self,
        w: &[f32],
        d: &[f32],
        lambda1: f32,
        lambda2: f32,
        max_calls: usize,
        tol: f32,
    ) -> Result<RuntimeLasso> {
        let m = w.len();
        if m == 0 || d.len() != m {
            return Err(Error::InvalidInput("lasso_solve: bad dims".into()));
        }
        let (name, bucket) = self
            .lasso_buckets
            .iter()
            .find(|&&(_, b)| b >= m)
            .cloned()
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no lasso bucket fits m={m} (max {})",
                    self.max_lasso_m()
                ))
            })?;
        let alpha0 = vec![1.0f32; m];
        let pad = buckets::pad_lasso(w, d, &alpha0, bucket);
        let lam = [lambda1, lambda2];
        let mut alpha = pad.alpha;
        let mut calls = 0usize;
        let mut converged = false;
        // Support-stability early stop, mirroring the native solver
        // (§Perf): only the zero pattern matters downstream.
        let mut last_sig = 0u64;
        let mut stable = 0usize;
        while calls < max_calls {
            calls += 1;
            let out = self.registry.execute_f32(
                &name,
                &[&pad.w, &pad.d, &pad.cw, &lam, &alpha],
            )?;
            let new_alpha = out
                .into_iter()
                .next()
                .ok_or_else(|| Error::Runtime("lasso artifact returned no output".into()))?;
            let max_move = alpha
                .iter()
                .zip(&new_alpha)
                .zip(&pad.d)
                .map(|((a, b), dd)| ((a - b) * dd).abs())
                .fold(0.0f32, f32::max);
            alpha = new_alpha;
            if max_move < tol {
                converged = true;
                break;
            }
            let mut sig = 0xcbf29ce484222325u64;
            for (i, &a) in alpha.iter().enumerate() {
                if a.abs() > 1e-7 {
                    sig = (sig ^ i as u64).wrapping_mul(0x100000001b3);
                }
            }
            if sig == last_sig {
                stable += 1;
                // Each call is epochs_per_call epochs; 2 stable calls ≈ the
                // native patience.
                if stable >= 2 {
                    converged = true;
                    break;
                }
            } else {
                last_sig = sig;
                stable = 0;
            }
        }
        alpha.truncate(m);
        Ok(RuntimeLasso { alpha, calls, converged })
    }

    /// Run `iters` Lloyd iterations on the runtime. `centroids` length must
    /// match an available k bucket after padding points to an m bucket.
    pub fn kmeans_lloyd(
        &mut self,
        points: &[f32],
        weights: &[f32],
        centroids: &[f32],
        min_calls: usize,
    ) -> Result<Vec<f32>> {
        let m = points.len();
        let k = centroids.len();
        if weights.len() != m {
            return Err(Error::InvalidInput("kmeans_lloyd: weights mismatch".into()));
        }
        let (name, bm, bk) = self
            .kmeans_buckets
            .iter()
            .find(|&&(_, bm, bk)| bm >= m && bk >= k)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("no kmeans bucket fits m={m}, k={k}")))?;
        // Pad points with weight 0; pad centroids far above the data range
        // so no real point selects them and sorting keeps them last.
        let pts = buckets::pad(points, bm, 0.0);
        let cw = {
            let mut cw = vec![1.0f32; m];
            // Real weights can be multiplicities.
            cw.copy_from_slice(weights);
            cw.resize(bm, 0.0);
            cw
        };
        let span = points.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
            - points.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        let sentinel = points.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
            + span.max(1.0) * 10.0;
        let mut cen = buckets::pad(centroids, bk, sentinel);
        for call in 0..min_calls.max(1) {
            // Sentinel spacing: keep pads distinct so sort order is stable.
            for (i, c) in cen.iter_mut().enumerate().skip(k) {
                if !c.is_finite() || *c < sentinel {
                    *c = sentinel + (i - k) as f32;
                }
            }
            let out = self.registry.execute_f32(&name, &[&pts, &cw, &cen])?;
            cen = out
                .into_iter()
                .next()
                .ok_or_else(|| Error::Runtime("kmeans artifact returned no output".into()))?;
            let _ = call;
        }
        // Real centroids are the k smallest (sentinels sort last).
        cen.truncate(k);
        Ok(cen)
    }

    /// Run `calls × EM_ITERS_PER_CALL` EM iterations on the runtime.
    /// Returns (means, variances, weights) truncated to the real k.
    pub fn gmm_em(
        &mut self,
        points: &[f32],
        weights: &[f32],
        means: &[f32],
        variances: &[f32],
        mix: &[f32],
        var_floor: f32,
        calls: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = points.len();
        let k = means.len();
        if weights.len() != m || variances.len() != k || mix.len() != k {
            return Err(Error::InvalidInput("gmm_em: dim mismatch".into()));
        }
        let (name, bm, bk) = self
            .gmm_buckets
            .iter()
            .find(|&&(_, bm, bk)| bm >= m && bk >= k)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("no gmm bucket fits m={m}, k={k}")))?;
        // Pad points with weight 0; pad components with zero mixing weight
        // and a far-away sentinel mean so sorting keeps them last.
        let pts = buckets::pad(points, bm, 0.0);
        let cw = {
            let mut c = weights.to_vec();
            c.resize(bm, 0.0);
            c
        };
        let span = points.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
            - points.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        let sentinel = points.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
            + span.max(1.0) * 10.0;
        let mut mu = means.to_vec();
        let mut var = variances.to_vec();
        let mut pi = mix.to_vec();
        for i in k..bk {
            mu.push(sentinel + (i - k) as f32);
            var.push(1.0);
            pi.push(0.0);
        }
        let floor = [var_floor];
        for _ in 0..calls.max(1) {
            let out = self
                .registry
                .execute_f32(&name, &[&pts, &cw, &mu, &var, &pi, &floor])?;
            let mut it = out.into_iter();
            mu = it.next().ok_or_else(|| Error::Runtime("gmm: no means".into()))?;
            var = it.next().ok_or_else(|| Error::Runtime("gmm: no vars".into()))?;
            pi = it.next().ok_or_else(|| Error::Runtime("gmm: no weights".into()))?;
        }
        mu.truncate(k);
        var.truncate(k);
        pi.truncate(k);
        // Renormalize over the real components (pads carried ≈0 mass).
        let total: f32 = pi.iter().sum();
        if total > 0.0 {
            for p in &mut pi {
                *p /= total;
            }
        }
        Ok((mu, var, pi))
    }

    /// Forward a batch through the MLP artifact. `x` is row-major
    /// `rows × in_dim`; `params` are (w, b) pairs. Rows are chunked/padded
    /// to the artifact batch.
    pub fn mlp_forward(
        &mut self,
        x: &[f32],
        rows: usize,
        in_dim: usize,
        out_dim: usize,
        params: &[(&[f32], &[f32])],
    ) -> Result<Vec<f32>> {
        let (name, batch) = self
            .mlp_batch
            .clone()
            .ok_or_else(|| Error::Runtime("no mlp artifact in manifest".into()))?;
        if x.len() != rows * in_dim {
            return Err(Error::InvalidInput("mlp_forward: x dims".into()));
        }
        if params.len() != 4 {
            return Err(Error::InvalidInput("mlp_forward: need 4 layers".into()));
        }
        let mut logits = Vec::with_capacity(rows * out_dim);
        let mut row = 0usize;
        while row < rows {
            let take = (rows - row).min(batch);
            let mut xb = vec![0.0f32; batch * in_dim];
            xb[..take * in_dim].copy_from_slice(&x[row * in_dim..(row + take) * in_dim]);
            let inputs: Vec<&[f32]> = {
                let mut v: Vec<&[f32]> = vec![&xb];
                for (w, b) in params {
                    v.push(w);
                    v.push(b);
                }
                v
            };
            let out = self.registry.execute_f32(&name, &inputs)?;
            let out0 = out
                .into_iter()
                .next()
                .ok_or_else(|| Error::Runtime("mlp artifact returned no output".into()))?;
            logits.extend_from_slice(&out0[..take * out_dim]);
            row += take;
        }
        Ok(logits)
    }
}
