//! PJRT execution backend: typed calls over the artifact cache.
//!
//! The executor indexes the shape buckets once at open and exposes the
//! kernel families as typed calls with automatic shape-bucketing,
//! padding and unpadding (the shared `drive_*` helpers in
//! [`super::backend`]). The Rust side drives convergence (one artifact
//! call = a fixed number of inner iterations, see `model.py`), so a
//! single compiled executable serves every λ, warm start and iteration
//! budget.
//!
//! Compiled-artifact state lives in an [`ArtifactCache`] shared by
//! same-thread sub-executors ([`Executor::fork`]): compile/load once,
//! execute from every fork. PJRT handles are `Rc`-based (not Send), so
//! forks never cross threads — [`ExecutorBackend::try_sub_handle`]
//! returns `None` and the coordinator keeps PJRT lanes serial, scaling
//! them with `runtime_lanes` instead (each lane owns its own cache).

use super::artifact::ArtifactCache;
use super::backend::{self, ExecutorBackend, RuntimeInfo, RuntimeLasso};
use crate::{Error, Result};
use std::path::Path;

/// Typed runtime front-end over the PJRT artifact cache.
pub struct Executor {
    cache: ArtifactCache,
    lasso_buckets: Vec<(String, usize)>,
    kmeans_buckets: Vec<(String, usize, usize)>, // (name, m, k)
    gmm_buckets: Vec<(String, usize, usize)>,    // (name, m, k)
    mlp_batch: Option<(String, usize)>,
    epochs_per_call: usize,
}

impl Executor {
    /// Open the artifact directory and index the buckets.
    pub fn open(dir: &Path) -> Result<Executor> {
        Self::with_cache(ArtifactCache::open(dir)?)
    }

    /// Build an executor over an existing (possibly shared) cache.
    pub fn with_cache(cache: ArtifactCache) -> Result<Executor> {
        let specs = cache.specs();
        let mut lasso_buckets = super::artifact::buckets_of_kind(&specs, "lasso_cd");
        lasso_buckets.sort_by_key(|&(_, m)| m);
        let mut kmeans_buckets = super::artifact::mk_buckets_of_kind(&specs, "kmeans");
        kmeans_buckets.sort_by_key(|&(_, m, k)| (m, k));
        let mut gmm_buckets = super::artifact::mk_buckets_of_kind(&specs, "gmm");
        gmm_buckets.sort_by_key(|&(_, m, k)| (m, k));
        let mlp_batch = specs
            .iter()
            .find(|s| s.meta_str("kind") == Some("mlp_fwd"))
            .and_then(|s| Some((s.name.clone(), s.meta_usize("batch")?)));
        let epochs_per_call = lasso_buckets
            .first()
            .and_then(|(n, _)| cache.meta_usize(n, "epochs_per_call"))
            .unwrap_or(1);
        Ok(Executor {
            cache,
            lasso_buckets,
            kmeans_buckets,
            gmm_buckets,
            mlp_batch,
            epochs_per_call,
        })
    }

    /// Same-thread sub-executor sharing this executor's compiled
    /// artifacts (the cache is `Rc`-shared; nothing recompiles).
    pub fn fork(&self) -> Executor {
        Executor {
            cache: self.cache.handle(),
            lasso_buckets: self.lasso_buckets.clone(),
            kmeans_buckets: self.kmeans_buckets.clone(),
            gmm_buckets: self.gmm_buckets.clone(),
            mlp_batch: self.mlp_batch.clone(),
            epochs_per_call: self.epochs_per_call,
        }
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.cache.platform()
    }

    /// Largest lasso bucket available (capability probe).
    pub fn max_lasso_m(&self) -> usize {
        self.lasso_buckets.iter().map(|&(_, m)| m).max().unwrap_or(0)
    }

    /// Epochs fused into one lasso artifact call.
    pub fn lasso_epochs_per_call(&self) -> usize {
        self.epochs_per_call
    }

    /// Run CD-LASSO on the runtime until convergence: repeated artifact
    /// calls, each `epochs_per_call` epochs, until the max α move falls
    /// under `tol` or `max_calls` is exhausted.
    pub fn lasso_solve(
        &mut self,
        w: &[f32],
        d: &[f32],
        lambda1: f32,
        lambda2: f32,
        max_calls: usize,
        tol: f32,
    ) -> Result<RuntimeLasso> {
        // Dim validation lives in the shared driver (`drive_lasso`).
        let m = w.len();
        let (name, bucket) = self
            .lasso_buckets
            .iter()
            .find(|&&(_, b)| b >= m)
            .cloned()
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no lasso bucket fits m={m} (max {})",
                    self.max_lasso_m()
                ))
            })?;
        let cache = &self.cache;
        let step = |wp: &[f32], dp: &[f32], cwp: &[f32], lam: &[f32; 2], alpha: &[f32]| {
            let out = cache.execute_f32(&name, &[wp, dp, cwp, lam, alpha])?;
            out.into_iter()
                .next()
                .ok_or_else(|| Error::Runtime("lasso artifact returned no output".into()))
        };
        backend::drive_lasso(w, d, lambda1, lambda2, max_calls, tol, bucket, step)
    }

    /// Run `iters` Lloyd iterations on the runtime. `centroids` length
    /// must match an available k bucket after padding points to an m
    /// bucket.
    pub fn kmeans_lloyd(
        &mut self,
        points: &[f32],
        weights: &[f32],
        centroids: &[f32],
        min_calls: usize,
    ) -> Result<Vec<f32>> {
        let m = points.len();
        let k = centroids.len();
        let (name, bm, bk) = self
            .kmeans_buckets
            .iter()
            .find(|&&(_, bm, bk)| bm >= m && bk >= k)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("no kmeans bucket fits m={m}, k={k}")))?;
        let cache = &self.cache;
        backend::drive_kmeans(points, weights, centroids, min_calls, bm, bk, |pts, cw, cen| {
            let out = cache.execute_f32(&name, &[pts, cw, cen])?;
            out.into_iter()
                .next()
                .ok_or_else(|| Error::Runtime("kmeans artifact returned no output".into()))
        })
    }

    /// Run `calls × EM_ITERS_PER_CALL` EM iterations on the runtime.
    /// Returns (means, variances, weights) truncated to the real k.
    #[allow(clippy::too_many_arguments)]
    pub fn gmm_em(
        &mut self,
        points: &[f32],
        weights: &[f32],
        means: &[f32],
        variances: &[f32],
        mix: &[f32],
        var_floor: f32,
        calls: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = points.len();
        let k = means.len();
        let (name, bm, bk) = self
            .gmm_buckets
            .iter()
            .find(|&&(_, bm, bk)| bm >= m && bk >= k)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("no gmm bucket fits m={m}, k={k}")))?;
        let cache = &self.cache;
        backend::drive_gmm(
            points,
            weights,
            means,
            variances,
            mix,
            var_floor,
            calls,
            bm,
            bk,
            |pts, cw, mu, var, pi, floor| {
                let out = cache.execute_f32(&name, &[pts, cw, mu, var, pi, floor])?;
                let mut it = out.into_iter();
                let mu = it.next().ok_or_else(|| Error::Runtime("gmm: no means".into()))?;
                let var = it.next().ok_or_else(|| Error::Runtime("gmm: no vars".into()))?;
                let pi = it.next().ok_or_else(|| Error::Runtime("gmm: no weights".into()))?;
                Ok((mu, var, pi))
            },
        )
    }

    /// Forward a batch through the MLP artifact. `x` is row-major
    /// `rows × in_dim`; `params` are (w, b) pairs. Rows are
    /// chunked/padded to the artifact batch.
    pub fn mlp_forward(
        &mut self,
        x: &[f32],
        rows: usize,
        in_dim: usize,
        out_dim: usize,
        params: &[(&[f32], &[f32])],
    ) -> Result<Vec<f32>> {
        let (name, batch) = self
            .mlp_batch
            .clone()
            .ok_or_else(|| Error::Runtime("no mlp artifact in manifest".into()))?;
        if params.len() != 4 {
            return Err(Error::InvalidInput("mlp_forward: need 4 layers".into()));
        }
        let cache = &self.cache;
        backend::drive_mlp(x, rows, in_dim, out_dim, batch, |xb| {
            let inputs: Vec<&[f32]> = {
                let mut v: Vec<&[f32]> = vec![xb];
                for (w, b) in params {
                    v.push(w);
                    v.push(b);
                }
                v
            };
            let out = cache.execute_f32(&name, &inputs)?;
            out.into_iter()
                .next()
                .ok_or_else(|| Error::Runtime("mlp artifact returned no output".into()))
        })
    }
}

impl ExecutorBackend for Executor {
    fn backend_id(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        Executor::platform(self)
    }

    fn max_lasso_m(&self) -> usize {
        Executor::max_lasso_m(self)
    }

    fn lasso_epochs_per_call(&self) -> usize {
        Executor::lasso_epochs_per_call(self)
    }

    fn info(&self) -> RuntimeInfo {
        RuntimeInfo {
            max_lasso_m: Executor::max_lasso_m(self),
            kmeans_buckets: self.kmeans_buckets.iter().map(|&(_, m, k)| (m, k)).collect(),
            gmm_buckets: self.gmm_buckets.iter().map(|&(_, m, k)| (m, k)).collect(),
        }
    }

    fn lasso_solve(
        &mut self,
        w: &[f32],
        d: &[f32],
        lambda1: f32,
        lambda2: f32,
        max_calls: usize,
        tol: f32,
    ) -> Result<RuntimeLasso> {
        Executor::lasso_solve(self, w, d, lambda1, lambda2, max_calls, tol)
    }

    fn kmeans_lloyd(
        &mut self,
        points: &[f32],
        weights: &[f32],
        centroids: &[f32],
        min_calls: usize,
    ) -> Result<Vec<f32>> {
        Executor::kmeans_lloyd(self, points, weights, centroids, min_calls)
    }

    #[allow(clippy::too_many_arguments)]
    fn gmm_em(
        &mut self,
        points: &[f32],
        weights: &[f32],
        means: &[f32],
        variances: &[f32],
        mix: &[f32],
        var_floor: f32,
        calls: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        Executor::gmm_em(self, points, weights, means, variances, mix, var_floor, calls)
    }

    fn mlp_forward(
        &mut self,
        x: &[f32],
        rows: usize,
        in_dim: usize,
        out_dim: usize,
        params: &[(&[f32], &[f32])],
    ) -> Result<Vec<f32>> {
        Executor::mlp_forward(self, x, rows, in_dim, out_dim, params)
    }

    fn try_sub_handle(&self) -> Option<Box<dyn ExecutorBackend + Send>> {
        // PJRT handles are Rc-based and thread-pinned; same-thread forks
        // exist ([`Executor::fork`]) but cannot back scoped fan-out.
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantMethod;
    use std::path::PathBuf;

    /// A manifest the stub PJRT client can open (compile stays lazy, so
    /// no HLO files are needed until execute).
    const MANIFEST: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "lasso_cd_m64", "file": "lasso_cd_m64.hlo.txt",
         "inputs": [
            {"shape": [64], "dtype": "float32"},
            {"shape": [64], "dtype": "float32"},
            {"shape": [64], "dtype": "float32"},
            {"shape": [2], "dtype": "float32"},
            {"shape": [64], "dtype": "float32"}],
         "meta": {"kind": "lasso_cd", "m": 64, "epochs_per_call": 8}},
        {"name": "kmeans_m256_k8", "file": "kmeans_m256_k8.hlo.txt",
         "inputs": [
            {"shape": [256], "dtype": "float32"},
            {"shape": [256], "dtype": "float32"},
            {"shape": [8], "dtype": "float32"}],
         "meta": {"kind": "kmeans", "m": 256, "k": 8, "iters_per_call": 4}}
      ]
    }"#;

    fn manifest_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sqlsq_executor_test_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
        dir
    }

    #[test]
    fn fork_shares_the_artifact_cache_and_buckets() {
        let dir = manifest_dir("fork");
        let mut ex = Executor::open(&dir).unwrap();
        let mut sub = ex.fork();
        // Same bucket tables and capabilities.
        assert_eq!(sub.max_lasso_m(), ex.max_lasso_m());
        assert_eq!(sub.lasso_epochs_per_call(), 8);
        let info = ExecutorBackend::info(&ex);
        assert!(info.fits(QuantMethod::L1, 64, 0));
        assert!(info.fits(QuantMethod::KMeans, 200, 8));
        assert!(!info.fits(QuantMethod::Gmm, 10, 2), "no gmm artifact in this manifest");
        // Both handles drive the *same* registry: identical behavior at
        // the (lazily failing) execute boundary, through either handle.
        let w = vec![0.5f32; 8];
        let d = vec![0.1f32; 8];
        let e1 = ex.lasso_solve(&w, &d, 0.01, 0.0, 1, 0.0).unwrap_err().to_string();
        let e2 = sub.lasso_solve(&w, &d, 0.01, 0.0, 1, 0.0).unwrap_err().to_string();
        assert_eq!(e1, e2, "fork must hit the same cache/registry");
        assert!(e1.contains("lasso_cd_m64"), "err: {e1}");
        // PJRT forks are same-thread only: no Send sub-handles.
        assert!(ex.try_sub_handle().is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn artifact_cache_handle_is_shared_not_cloned() {
        let dir = manifest_dir("cache_handle");
        let cache = ArtifactCache::open(&dir).unwrap();
        let handle = cache.handle();
        assert_eq!(cache.platform(), handle.platform());
        assert_eq!(cache.specs().len(), 2);
        assert_eq!(handle.meta_usize("lasso_cd_m64", "epochs_per_call"), Some(8));
        // Executors built over both handles agree on buckets.
        let a = Executor::with_cache(cache).unwrap();
        let b = Executor::with_cache(handle).unwrap();
        assert_eq!(a.max_lasso_m(), b.max_lasso_m());
        std::fs::remove_dir_all(dir).ok();
    }
}
