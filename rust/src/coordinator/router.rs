//! Engine routing: native Rust engines vs the AOT/PJRT runtime lane.
//!
//! Policy ([`crate::config::Engine`]):
//! * `Native`  — everything runs on the pure-Rust engines.
//! * `Runtime` — runtime-capable methods *must* run on the runtime
//!   (missing bucket ⇒ the job fails, surfacing artifact gaps loudly);
//! * `Auto`    — runtime when a bucket fits, native fallback otherwise
//!   (the serving default).
//!
//! PJRT handles are `Rc`-based and **not Send**, so the [`Router`] itself
//! never holds a backend: it only routes using the capability table
//! ([`RuntimeInfo`]) — parsed from the manifest for the PJRT backend, or
//! taken from the shadow backend's static bucket table (no artifacts
//! needed). Runtime-lane threads construct their own
//! [`crate::runtime::ExecutorBackend`] at startup ([`super::server`])
//! and call [`dispatch_runtime`].
//!
//! Runtime-capable methods: `L1`/`L1LeastSquare` (artifact CD epochs +
//! native refit), `KMeans` (artifact Lloyd steps + native seeding) and
//! `Gmm` (artifact EM steps + native max-posterior assignment).
//! Everything else always runs natively — their inner loops are
//! data-dependent control flow the AOT graph cannot express.
//!
//! ## Compact results
//!
//! Every dispatcher here — native and runtime — returns the **compact**
//! result form: a [`quant::QuantItem`] / lane-erased [`quant::Item`]
//! carrying a [`quant::Codebook`] (a few shared levels + one `u32` index
//! per element), not a materialized full-length vector. The runtime
//! dispatchers finalize straight from the unique decomposition's inverse
//! map (`api::finish_compact_parts`), so even the f64 runtime boundary
//! never round-trips a full vector between solve and response; full
//! vectors exist only where an edge explicitly materializes one
//! ([`super::job::JobOutput::materialize`]). Losses are accumulated in the
//! exact legacy arithmetic order, so compact results stay bitwise-identical
//! to the historical full-vector path (`types::finalize`, kept as the
//! independent regression anchor).

use super::job::Payload;
use crate::config::Engine;
use crate::quant::{
    self, api, refit, unique::UniqueDecomp, vmatrix::VBasis, QuantDiag, QuantItem,
    QuantMethod, QuantOptions,
};
use crate::runtime::{BackendKind, ExecutorBackend, ShadowBackend};
use crate::{Error, Result};
use std::path::Path;
use std::sync::Arc;

pub use crate::runtime::RuntimeInfo;

/// Send-safe routing state shared by all workers.
pub struct Router {
    policy: Engine,
    info: Option<RuntimeInfo>,
}

impl Router {
    /// Build a router for the given backend kind. For `Pjrt` the
    /// capability table is probed from the manifest on disk (under
    /// `Auto`, probe failure degrades to native-only routing; under
    /// `Runtime` it is a hard error). The shadow backend needs no
    /// artifacts — its bucket table is static.
    pub fn new(policy: Engine, artifacts_dir: &Path, backend: BackendKind) -> Result<Router> {
        let info = match (policy, backend) {
            (Engine::Native, _) => None,
            (_, BackendKind::Shadow) => Some(ShadowBackend::new().info()),
            (Engine::Runtime, BackendKind::Pjrt) => Some(RuntimeInfo::probe(artifacts_dir)?),
            (Engine::Auto, BackendKind::Pjrt) => match RuntimeInfo::probe(artifacts_dir) {
                Ok(i) => Some(i),
                Err(e) => {
                    eprintln!("router: runtime unavailable, auto-falling back to native: {e}");
                    None
                }
            },
        };
        Ok(Router { policy, info })
    }

    /// Build a router from an explicit capability table. Use when the
    /// lane backends come from an injected [`super::server::BackendFactory`]
    /// whose buckets differ from the stock tables (pass
    /// `backend.info()`), so routing never disagrees with the backend
    /// that actually serves the jobs.
    pub fn with_info(policy: Engine, info: RuntimeInfo) -> Router {
        let info = match policy {
            Engine::Native => None,
            _ => Some(info),
        };
        Router { policy, info }
    }

    /// The active policy.
    pub fn policy(&self) -> Engine {
        self.policy
    }

    /// Can this method run on the runtime at all?
    pub fn runtime_capable(method: QuantMethod) -> bool {
        matches!(
            method,
            QuantMethod::L1
                | QuantMethod::L1LeastSquare
                | QuantMethod::KMeans
                | QuantMethod::Gmm
        )
    }

    /// Should this job go to the runtime lane? `m` may be an upper bound
    /// (vector length) at admission time.
    pub fn routes_to_runtime(&self, method: QuantMethod, m: usize, k: usize) -> bool {
        if self.policy == Engine::Native || !Self::runtime_capable(method) {
            return false;
        }
        match (&self.info, self.policy) {
            (Some(_), Engine::Runtime) => true, // must try; fails loudly if unfit
            (Some(info), Engine::Auto) => info.fits(method, m, k),
            _ => false,
        }
    }

    /// Serve a job on the native engines; the payload's precision picks
    /// the lane (f32 payloads run the single-precision fast path and stay
    /// narrow in the result). Payloads are shared, so dispatch clones an
    /// `Arc`, never the data — the prepare stage reads the submitted
    /// buffer. `weights` are admission-normalized per-element importance
    /// weights (`None` = unweighted, the common path). The result is the
    /// **compact** lane-erased item (codebook + indices); edges
    /// materialize full vectors lazily.
    pub fn dispatch_native(
        &self,
        data: &Payload,
        weights: Option<&[f64]>,
        method: QuantMethod,
        opts: &QuantOptions,
    ) -> Result<quant::Item> {
        match data {
            Payload::F64(v) => quant::api::run_shared_f64_weighted(
                Arc::clone(v),
                weights,
                method,
                opts,
                quant::OutputForm::Codebook,
            ),
            Payload::F32(v) => Ok(quant::Item::F32(quant::api::run_shared_f32_weighted(
                Arc::clone(v),
                weights,
                method,
                opts,
                quant::OutputForm::Codebook,
            )?)),
        }
    }

    /// [`Router::dispatch_native`] over an owned payload: the shared
    /// buffer enters the request-API core without a copy on either lane.
    /// Per-stage (prepare/solve) wall times ride on the returned item
    /// ([`quant::Item::timings`]) for the metrics surface.
    pub fn dispatch_native_timed_owned(
        &self,
        data: Payload,
        weights: Option<&[f64]>,
        method: QuantMethod,
        opts: &QuantOptions,
    ) -> Result<quant::Item> {
        match data {
            Payload::F64(v) => quant::api::run_shared_f64_weighted(
                v,
                weights,
                method,
                opts,
                quant::OutputForm::Codebook,
            ),
            Payload::F32(v) => Ok(quant::Item::F32(quant::api::run_shared_f32_weighted(
                v,
                weights,
                method,
                opts,
                quant::OutputForm::Codebook,
            )?)),
        }
    }
}

/// Runtime-lane dispatch (called only from a lane thread — or one of its
/// scoped sub-lanes — that owns the backend handle). Returns the compact
/// item: the per-level solve finalizes through the unique decomposition's
/// inverse map without materializing an intermediate full vector.
pub fn dispatch_runtime(
    ex: &mut dyn ExecutorBackend,
    data: &[f64],
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<QuantItem> {
    match method {
        QuantMethod::L1 | QuantMethod::L1LeastSquare => runtime_lasso(
            ex,
            data,
            opts,
            matches!(method, QuantMethod::L1LeastSquare),
        ),
        QuantMethod::KMeans => runtime_kmeans(ex, data, opts),
        QuantMethod::Gmm => runtime_gmm(ex, data, opts),
        other => Err(Error::Runtime(format!(
            "method {:?} is not runtime-capable",
            other
        ))),
    }
}

/// L1 on the runtime: artifact CD epochs (f32) + native f64 refit/recovery.
fn runtime_lasso(
    ex: &mut dyn ExecutorBackend,
    data: &[f64],
    opts: &QuantOptions,
    with_refit: bool,
) -> Result<QuantItem> {
    let u = UniqueDecomp::new(data)?;
    let basis = VBasis::new(&u.values);
    let w32: Vec<f32> = u.values.iter().map(|&x| x as f32).collect();
    let d32: Vec<f32> = basis.diffs().iter().map(|&x| x as f32).collect();
    let epochs_per_call = ex.lasso_epochs_per_call();
    let max_calls = (opts.max_epochs / epochs_per_call.max(1)).max(1);
    // f32 tolerance floor: the artifact computes in single precision.
    let tol = (opts.tol as f32).max(1e-6);
    let sol = ex.lasso_solve(&w32, &d32, opts.lambda1 as f32, opts.lambda2 as f32, max_calls, tol)?;

    // Support extraction with an f32-scale threshold; null columns
    // (d_j = 0, possible at j = 0 when v_0 = 0) are never support.
    let support: Vec<usize> = sol
        .alpha
        .iter()
        .enumerate()
        .filter(|&(i, &a)| a.abs() > 1e-7 && d32[i] != 0.0)
        .map(|(i, _)| i)
        .collect();
    let diag = QuantDiag {
        iterations: sol.calls * epochs_per_call,
        converged: sol.converged,
        lambda1: opts.lambda1,
        nnz: support.len(),
        unstable: false,
        empty_cluster_events: 0,
    };
    let levels = if with_refit {
        refit::refit_fast(&basis, &u.values, &support, None)?.reconstruction
    } else {
        // Reconstruct from the runtime α in f64.
        let alpha64: Vec<f64> = sol.alpha.iter().map(|&a| a as f64).collect();
        basis.apply(&alpha64)
    };
    api::finish_compact_parts(data, &u, &levels, opts.clamp, diag)
}

/// k-means on the runtime: deterministic quantile seeding, artifact Lloyd
/// steps, native assignment.
fn runtime_kmeans(
    ex: &mut dyn ExecutorBackend,
    data: &[f64],
    opts: &QuantOptions,
) -> Result<QuantItem> {
    let u = UniqueDecomp::new(data)?;
    let pts32: Vec<f32> = u.values.iter().map(|&x| x as f32).collect();
    let cw32: Vec<f32> = u.counts.iter().map(|&c| c as f32).collect();
    let k = opts.target_values.min(u.m()).max(1);
    let mut cen: Vec<f32> = (0..k)
        .map(|i| {
            let pos = (i as f64 + 0.5) / k as f64 * (u.m() as f64 - 1.0);
            u.values[pos.round() as usize] as f32
        })
        .collect();
    cen.dedup();
    while cen.len() < k {
        let last = *cen.last().unwrap();
        cen.push(last + 1e-3);
    }
    let calls = (opts.max_iters / 4).max(1).min(50);
    let cen = ex.kmeans_lloyd(&pts32, &cw32, &cen, calls)?;
    let cen64: Vec<f64> = cen.iter().map(|&c| c as f64).collect();
    let levels: Vec<f64> = u
        .values
        .iter()
        .map(|&v| cen64[crate::cluster::kmeans::assign_sorted(v, &cen64)])
        .collect();
    let diag = QuantDiag {
        iterations: calls * 4,
        converged: true,
        lambda1: 0.0,
        nnz: k,
        unstable: false,
        empty_cluster_events: 0,
    };
    api::finish_compact_parts(data, &u, &levels, opts.clamp, diag)
}

/// GMM on the runtime: deterministic quantile seeding, artifact EM steps,
/// native max-posterior assignment.
fn runtime_gmm(
    ex: &mut dyn ExecutorBackend,
    data: &[f64],
    opts: &QuantOptions,
) -> Result<QuantItem> {
    let u = UniqueDecomp::new(data)?;
    let pts32: Vec<f32> = u.values.iter().map(|&x| x as f32).collect();
    let cw32: Vec<f32> = u.counts.iter().map(|&c| c as f32).collect();
    let k = opts.target_values.min(u.m()).max(1);
    let mut mu: Vec<f32> = (0..k)
        .map(|i| {
            let pos = (i as f64 + 0.5) / k as f64 * (u.m() as f64 - 1.0);
            u.values[pos.round() as usize] as f32
        })
        .collect();
    mu.dedup();
    while mu.len() < k {
        let last = *mu.last().unwrap();
        mu.push(last + 1e-3);
    }
    let gmean = crate::linalg::stats::weighted_mean(&u.values, &u.weights());
    let gvar: f64 = u
        .values
        .iter()
        .zip(&u.counts)
        .map(|(&x, &c)| c as f64 * (x - gmean) * (x - gmean))
        .sum::<f64>()
        / u.counts.iter().sum::<usize>().max(1) as f64;
    let span = crate::linalg::stats::max(&u.values) - crate::linalg::stats::min(&u.values);
    let var_floor = ((1e-6 * span * span).max(1e-12)) as f32;
    let var = vec![(gvar.max(var_floor as f64)) as f32; k];
    let pi = vec![1.0 / k as f32; k];
    let calls = (opts.max_iters / 4).max(1).min(50);
    let (mu, var, pi) = ex.gmm_em(&pts32, &cw32, &mu, &var, &pi, var_floor, calls)?;

    // Native max-posterior hard assignment over the unique values.
    let levels: Vec<f64> = u
        .values
        .iter()
        .map(|&x| {
            let mut best = 0usize;
            let mut best_lp = f64::NEG_INFINITY;
            for c in 0..k {
                let m = mu[c] as f64;
                let v = (var[c] as f64).max(1e-12);
                let p = (pi[c] as f64).max(1e-30);
                let d = x - m;
                let lp = p.ln() - 0.5 * (d * d / v + v.ln());
                if lp > best_lp {
                    best_lp = lp;
                    best = c;
                }
            }
            mu[best] as f64
        })
        .collect();
    let diag = QuantDiag {
        iterations: calls * 4,
        converged: true,
        lambda1: 0.0,
        nnz: k,
        unstable: false,
        empty_cluster_events: 0,
    };
    api::finish_compact_parts(data, &u, &levels, opts.clamp, diag)
}

/// Equivalence check used by integration tests and the self-check CLI:
/// native vs runtime Algorithm 1 on the same data. Returns (native loss,
/// runtime loss).
pub fn check_lasso_equivalence(
    ex: &mut dyn ExecutorBackend,
    data: &[f64],
    lambda1: f64,
) -> Result<(f64, f64)> {
    let opts = QuantOptions { lambda1, ..Default::default() };
    let rt = runtime_lasso(ex, data, &opts, true)?;
    let nat = quant::quantize(data, QuantMethod::L1LeastSquare, &opts)?;
    Ok((nat.l2_loss, rt.l2_loss))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_policy_never_routes_runtime() {
        let r = Router::new(Engine::Native, Path::new("/nonexistent"), BackendKind::Pjrt).unwrap();
        assert!(!r.routes_to_runtime(QuantMethod::L1, 10, 4));
        let data = vec![1.0, 2.0, 3.0, 4.0];
        let out = r
            .dispatch_native(
                &data.into(),
                None,
                QuantMethod::KMeans,
                &QuantOptions { target_values: 2, ..Default::default() },
            )
            .unwrap();
        assert!(out.distinct_values() <= 2);
    }

    #[test]
    fn f32_payloads_dispatch_on_the_native_f32_lane() {
        let r = Router::new(Engine::Native, Path::new("/nonexistent"), BackendKind::Pjrt).unwrap();
        let data32 = vec![0.1f32, 0.2, 0.3, 0.2, 0.1, 0.9];
        let opts = QuantOptions { lambda1: 0.05, ..Default::default() };
        let via_router = r
            .dispatch_native(&data32.clone().into(), None, QuantMethod::L1LeastSquare, &opts)
            .unwrap();
        assert_eq!(via_router.precision(), quant::Precision::F32, "stays narrow");
        let direct =
            quant::quantize_f32(&data32, QuantMethod::L1LeastSquare, &opts).unwrap().widen();
        assert_eq!(via_router.materialize_f64(), direct.values);
        assert_eq!(via_router.l2_loss().to_bits(), direct.l2_loss.to_bits());
    }

    #[test]
    fn runtime_capability_table() {
        assert!(Router::runtime_capable(QuantMethod::L1));
        assert!(Router::runtime_capable(QuantMethod::L1LeastSquare));
        assert!(Router::runtime_capable(QuantMethod::KMeans));
        assert!(Router::runtime_capable(QuantMethod::Gmm));
        assert!(!Router::runtime_capable(QuantMethod::L0));
        assert!(!Router::runtime_capable(QuantMethod::ClusterLs));
    }

    #[test]
    fn auto_policy_with_missing_artifacts_falls_back() {
        let r = Router::new(Engine::Auto, Path::new("/nonexistent"), BackendKind::Pjrt).unwrap();
        assert!(!r.routes_to_runtime(QuantMethod::L1, 10, 4));
    }

    #[test]
    fn runtime_policy_with_missing_artifacts_errors_at_open() {
        let r = Router::new(Engine::Runtime, Path::new("/nonexistent"), BackendKind::Pjrt);
        assert!(r.is_err());
    }

    #[test]
    fn shadow_backend_routes_without_artifacts() {
        // The shadow backend's capability table is static: no manifest on
        // disk, yet Auto routes runtime-capable jobs to the lane.
        let r = Router::new(Engine::Auto, Path::new("/nonexistent"), BackendKind::Shadow).unwrap();
        assert!(r.routes_to_runtime(QuantMethod::L1, 500, 4));
        assert!(r.routes_to_runtime(QuantMethod::KMeans, 500, 8));
        assert!(!r.routes_to_runtime(QuantMethod::L1, 5000, 4), "over every bucket");
        assert!(!r.routes_to_runtime(QuantMethod::ClusterLs, 10, 2), "not capable");
        // Strict policy also opens fine with no artifact dir.
        let strict =
            Router::new(Engine::Runtime, Path::new("/nonexistent"), BackendKind::Shadow).unwrap();
        assert!(strict.routes_to_runtime(QuantMethod::Gmm, 100, 8));
    }

    #[test]
    fn shadow_dispatch_runtime_produces_valid_outputs() {
        // Per-job runtime dispatch over the shadow backend: the reference
        // the batch integration tests compare against.
        let mut ex = ShadowBackend::new();
        let data: Vec<f64> = (0..120).map(|i| ((i * 37) % 97) as f64 / 97.0).collect();
        for method in [QuantMethod::L1LeastSquare, QuantMethod::KMeans, QuantMethod::Gmm] {
            let opts = QuantOptions { lambda1: 0.02, target_values: 8, ..Default::default() };
            let out = dispatch_runtime(&mut ex, &data, method, &opts).unwrap();
            // Compact-native: codebook + one index per input element.
            assert_eq!(out.codebook.len(), data.len(), "{method:?}");
            assert_eq!(out.materialize().len(), data.len(), "{method:?}");
            assert!(out.l2_loss.is_finite());
            if method != QuantMethod::L1LeastSquare {
                assert!(out.distinct_values() <= 8, "{method:?}");
            }
        }
        // Non-runtime-capable methods are rejected loudly.
        assert!(dispatch_runtime(&mut ex, &data, QuantMethod::L0, &QuantOptions::default())
            .is_err());
    }

    #[test]
    fn probe_real_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let info = RuntimeInfo::probe(&dir).unwrap();
            assert!(info.max_lasso_m >= 1024);
            assert!(!info.kmeans_buckets.is_empty());
        }
    }
}
