//! The coordinator: admission, batching, worker pool, runtime lane,
//! metrics, graceful shutdown (S19).
//!
//! Topology:
//!
//! ```text
//!      submit_request()/try_submit_request()   (QuantRequest front door;
//!                   │                            legacy submit*/try_submit*
//!            result cache ──► hit/joined         are shims over it)
//!                   │         (respond directly;
//!                   │  (bounded   never queued)
//!                   │   queue = backpressure)
//!        ┌──────────┴───────────┐
//!   native queue           runtime queue        (router decides per job)
//!        │                      │
//!   N worker threads       R runtime-lane threads (each owns a PJRT
//!        │                      │                   client + exe cache)
//!        └──────────┬───────────┘
//!       finish(): cache insert ──► respond channels + metrics
//! ```
//!
//! The result cache ([`super::cache::ResultCache`], `Config::cache_policy`)
//! sits at admission: an exact content-fingerprint hit answers from the
//! cached compact item without entering a queue (bitwise-identical to a
//! cold solve; `ServedBy::Cache`), a duplicate of an in-flight solve
//! parks until the leader's `finish` publishes (single-flight), and a
//! miss carries a [`super::cache::CacheTicket`] through the queue so
//! `finish` inserts the result.
//!
//! Results flow back **compact**: a worker's finalize builds the
//! codebook (levels + `u32` indices) and [`JobResult`] carries exactly
//! that ([`super::job::JobOutput`]) — the respond channels never move a
//! materialized full-length vector, on either the native or the runtime
//! lane. Edges that need full values decode lazily
//! ([`super::job::JobOutput::materialize`]).
//!
//! Runtime lanes each open their own [`ExecutorBackend`] via a backend
//! factory (PJRT handles are `Rc`-based, not Send; per-lane artifact
//! caches keep lanes independent — §Perf row 7: 2 lanes ≈ 2.2×
//! mixed-burst throughput). Backends with Send sub-handles (the shadow
//! backend) additionally fan one drained batch across
//! `Config::runtime_fanout` scoped sub-lanes, exactly like the native
//! workers' `batch_fanout`. Workers drain *batches* from the queue
//! (`max_batch`, `batch_wait_us`) so bursts of small jobs pay one
//! wakeup. A lane whose backend fails to open runs *degraded*: counted
//! in [`Metrics`], and under `Engine::Auto` its pops are served natively
//! instead of erroring job by job.

use super::cache::{Admit, ResultCache};
use super::job::{Job, JobId, JobOutput, JobResult, Payload, ServedBy};
use super::metrics::{Metrics, Snapshot};
use super::queue::{BoundedQueue, TryPush};
use super::router::Router;
use crate::config::{CachePolicy, Config, Engine};
use crate::quant::api::{self, NormWeights, Plan, QuantRequest, RequestInput};
use crate::quant::{Item, Precision, QuantMethod, QuantOptions};
use crate::runtime::{open_backend, ExecutorBackend};
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Constructs one runtime-lane backend; called *on* the lane thread (the
/// result never crosses threads), so non-Send backends are fine. The
/// argument is the lane index. Injectable for tests — failing factories
/// and instrumented shadow backends exercise the degradation/fan-out
/// paths without artifacts.
pub type BackendFactory =
    Arc<dyn Fn(usize) -> Result<Box<dyn ExecutorBackend>> + Send + Sync>;

/// Convert a typed request into coordinator job parts. The coordinator
/// serves single-vector one-shot (or target-count) requests; sweep plans
/// and batch/matrix inputs are rejected — submit their units as
/// individual requests, or run them in-process via
/// [`crate::quant::Quantizer`] (which serves sweep, batch and the
/// combined batch×sweep plan with scoped-thread fan-out).
fn request_job_parts(
    req: QuantRequest,
) -> Result<(Payload, QuantMethod, QuantOptions, Option<Arc<[f64]>>)> {
    if matches!(req.plan, Plan::Sweep { .. }) {
        return Err(Error::Coordinator(
            "coordinator jobs are one-shot; run λ sweeps in-process via quant::Quantizer".into(),
        ));
    }
    let opts = req.effective_options();
    api::validate_entropy_budget(&opts)?;
    // Weight validation happens at admission — a malformed weighted
    // request is refused before a job id or queue slot exists. Cascade
    // plans reject weights exactly as the in-process facade does.
    let weights = match req.normalized_weights()? {
        None => None,
        Some(_) if matches!(req.plan, Plan::Cascade { .. }) => {
            return Err(Error::InvalidInput(
                "cascade: per-element importance weights are not supported (cascade levels \
                 re-quantize residuals, which have no per-element identity)"
                    .into(),
            ))
        }
        Some(NormWeights::Vector(w)) => Some(w),
        // Batch-form weights only pair with batch inputs, which the
        // shape check below rejects.
        Some(NormWeights::Batch(_)) => None,
    };
    let payload = match req.input {
        RequestInput::VectorF64(w) => Payload::F64(w),
        RequestInput::VectorF32(w) => Payload::F32(w),
        _ => {
            return Err(Error::Coordinator(
                "coordinator jobs take a single vector; submit batch/matrix groups as \
                 individual requests"
                    .into(),
            ))
        }
    };
    Ok((payload, req.method, opts, weights))
}

/// Wrap a legacy (payload, method, opts) submission as a typed request —
/// the shim the historical `submit*` surface rides through. The shared
/// payload moves into the request unchanged; no data copy.
fn request_from_payload(data: Payload, method: QuantMethod, opts: QuantOptions) -> QuantRequest {
    let req = match data {
        Payload::F64(v) => QuantRequest::shared(v),
        Payload::F32(v) => QuantRequest::shared_f32(v),
    };
    req.method(method).options(opts)
}

/// Admission verdict: either the job must be queued, or the result cache
/// already answered (exact hit) / will answer (parked duplicate of an
/// in-flight solve) through the returned receiver.
enum Admission<'a> {
    /// Queue the job (a miss carries its leader ticket inside).
    Enqueue(Job, mpsc::Receiver<JobResult>, &'a BoundedQueue<Job>),
    /// Served (or adopted) by the cache — nothing to queue.
    Served(JobId, mpsc::Receiver<JobResult>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    native_q: Arc<BoundedQueue<Job>>,
    runtime_q: Arc<BoundedQueue<Job>>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    cache: Option<Arc<ResultCache>>,
    next_id: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
    cfg: Config,
}

/// Complete a job: publish to the result cache when the job carried a
/// leader ticket (storing the compact item and draining duplicate
/// submitters), then wrap the engine's **compact** item as the result
/// payload (no materialization — full vectors are an edge concern),
/// stamp metrics, and respond.
fn finish(metrics: &Metrics, mut job: Job, outcome: Result<Item>, served_by: ServedBy) {
    if let Some(mut ticket) = job.cache.take() {
        ticket.complete(&outcome, served_by);
    }
    let latency = job.submitted.elapsed();
    let levels_requested = job.opts.target_values;
    let outcome = outcome
        .map(|item| JobOutput::new(item, levels_requested))
        .map_err(|e| e.to_string());
    metrics.on_complete(outcome.is_ok(), latency, served_by == ServedBy::Runtime);
    // Receiver may have hung up (fire-and-forget submit); ignore.
    let _ = job.respond.send(JobResult { id: job.id, outcome, latency, served_by });
}

/// Serve one job natively, recording prepare/solve stage timings. The
/// payload is taken out of the job so the prepare stage can own the buffer
/// (no second copy of the input); the payload's precision picks the lane.
fn serve_one_native(router: &Router, metrics: &Metrics, mut job: Job) {
    let data = std::mem::take(&mut job.data);
    let weights = job.weights.take();
    let outcome = match router.dispatch_native_timed_owned(
        data,
        weights.as_deref(),
        job.method,
        &job.opts,
    ) {
        Ok(item) => {
            let t = item.timings();
            metrics.on_stage(t.prepare, t.solve);
            Ok(item)
        }
        Err(e) => Err(e),
    };
    finish(metrics, job, outcome, ServedBy::Native);
}

/// Chunked batch fan-out shared by the native workers and the runtime
/// lanes: the first chunk runs on the calling thread via `serve_local`,
/// the rest are handed to scoped helper threads, each paired with one
/// element of `helpers` (per-thread lane state — `()` for native lanes,
/// a backend sub-handle for runtime lanes). Empty `helpers` ⇒ serial.
/// Jobs are independent — each owns its response channel — so
/// intra-batch completion order does not matter.
fn fan_out_batch<C: Send>(
    mut batch: Vec<Job>,
    helpers: Vec<C>,
    mut serve_local: impl FnMut(Job),
    serve_helper: impl Fn(&mut C, Job) + Send + Sync,
) {
    if helpers.is_empty() {
        for job in batch.drain(..) {
            serve_local(job);
        }
        return;
    }
    let lanes = helpers.len() + 1;
    let chunk = batch.len().div_ceil(lanes);
    let mut chunks: Vec<Vec<Job>> = Vec::with_capacity(lanes);
    while !batch.is_empty() {
        let take = chunk.min(batch.len());
        chunks.push(batch.drain(..take).collect());
    }
    std::thread::scope(|s| {
        let mut it = chunks.into_iter();
        // The draining worker serves the first chunk itself; the rest are
        // handed off to scoped helper threads.
        let local = it.next();
        let serve_helper = &serve_helper;
        for (mut ctx, handed_off) in helpers.into_iter().zip(it) {
            s.spawn(move || {
                for job in handed_off {
                    serve_helper(&mut ctx, job);
                }
            });
        }
        if let Some(own) = local {
            for job in own {
                serve_local(job);
            }
        }
    });
}

/// Serve a drained batch natively, fanning the jobs across up to `fanout`
/// scoped threads (chunked hand-off).
fn serve_batch_native(router: &Router, metrics: &Metrics, batch: Vec<Job>, fanout: usize) {
    metrics.on_batch(batch.len());
    let lanes = fanout.max(1).min(batch.len().max(1));
    fan_out_batch(
        batch,
        vec![(); lanes.saturating_sub(1)],
        |job| serve_one_native(router, metrics, job),
        |_, job| serve_one_native(router, metrics, job),
    );
}

/// Serve one job on a runtime backend. `Auto` falls back to native on
/// runtime errors; `Runtime` propagates them. `ServedBy` reports the
/// engine that actually produced the result.
fn serve_one_runtime(
    backend: &mut dyn ExecutorBackend,
    router: &Router,
    metrics: &Metrics,
    job: Job,
) {
    let rt_outcome = match &job.data {
        Payload::F64(v) => super::router::dispatch_runtime(backend, v, job.method, &job.opts),
        data @ Payload::F32(_) => {
            // The runtime boundary is f64; f32 payloads normally never
            // route here (admission keeps them native), but widen
            // defensively if one does.
            let wide = data.to_f64_vec();
            super::router::dispatch_runtime(backend, &wide, job.method, &job.opts)
        }
    };
    match rt_outcome {
        // The runtime lane's f64 boundary hands back a compact item too —
        // no intermediate full-vector round trip.
        Ok(out) => finish(metrics, job, Ok(Item::F64(out)), ServedBy::Runtime),
        Err(e) => {
            if router.policy() == Engine::Auto {
                let outcome = router.dispatch_native(
                    &job.data,
                    job.weights.as_deref(),
                    job.method,
                    &job.opts,
                );
                finish(metrics, job, outcome, ServedBy::Native);
            } else {
                finish(metrics, job, Err(e), ServedBy::Runtime);
            }
        }
    }
}

/// Runtime-lane batch service. When the backend hands out Send
/// sub-handles (shared compiled state), the drained batch fans across up
/// to `fanout` scoped sub-lanes exactly like [`serve_batch_native`];
/// thread-pinned backends (PJRT) serve serially. Jobs are independent —
/// each owns its response channel — and every backend is deterministic
/// per job, so fanned results are bitwise-identical to the serial path.
///
/// Public (with [`BackendFactory`] and the job types) so integration
/// tests and benches can drive the lane logic directly — artifact-free
/// via the shadow backend.
pub fn serve_batch_runtime(
    backend: &mut dyn ExecutorBackend,
    router: &Router,
    metrics: &Metrics,
    batch: Vec<Job>,
    fanout: usize,
) {
    metrics.on_batch(batch.len());
    let lanes = fanout.max(1).min(batch.len().max(1));
    // One sub-handle per helper lane; the draining lane thread keeps the
    // primary handle and serves the first chunk itself. Thread-pinned
    // backends yield no sub-handles ⇒ serial.
    let subs: Vec<Box<dyn ExecutorBackend + Send>> =
        (1..lanes).map_while(|_| backend.try_sub_handle()).collect();
    fan_out_batch(
        batch,
        subs,
        |job| serve_one_runtime(backend, router, metrics, job),
        |sub, job| serve_one_runtime(sub.as_mut(), router, metrics, job),
    );
}

/// Degraded runtime lane (its backend failed to open). Under `Auto` the
/// lane reroutes its pops to the native engines — same fan-out as a
/// native worker — so queued runtime jobs still complete; under the
/// strict `Runtime` policy each job fails loudly.
fn serve_batch_degraded(router: &Router, metrics: &Metrics, batch: Vec<Job>, fanout: usize) {
    if router.policy() == Engine::Auto {
        serve_batch_native(router, metrics, batch, fanout);
        return;
    }
    metrics.on_batch(batch.len());
    for job in batch {
        finish(
            metrics,
            job,
            Err(Error::Runtime("runtime lane has no executor".into())),
            ServedBy::Runtime,
        );
    }
}

impl Coordinator {
    /// Start workers per `cfg`, opening runtime lanes with the backend
    /// selected by `cfg.runtime_backend`.
    pub fn start(cfg: Config) -> Result<Coordinator> {
        let kind = cfg.runtime_backend;
        let dir = cfg.artifacts_dir.clone();
        let factory: BackendFactory = Arc::new(move |_lane| open_backend(kind, &dir));
        Self::start_with_backend_factory(cfg, factory)
    }

    /// Start workers per `cfg` with an injected runtime-backend factory
    /// (called once per lane, on the lane thread). This is the seam the
    /// runtime integration tests use: instrumented, failing, or
    /// custom-bucket backends — no artifacts required.
    ///
    /// Routing uses the stock capability table for `cfg.runtime_backend`;
    /// if the factory's backends have *different* buckets, use
    /// [`Coordinator::start_with_backend_factory_and_info`] with
    /// `backend.info()` so admission routing matches the lanes.
    pub fn start_with_backend_factory(cfg: Config, factory: BackendFactory) -> Result<Coordinator> {
        Self::start_with_backend_factory_and_info(cfg, factory, None)
    }

    /// [`Coordinator::start_with_backend_factory`] with an explicit
    /// routing capability table ([`crate::runtime::RuntimeInfo`]) —
    /// `None` derives it from `cfg.runtime_backend` (manifest probe for
    /// PJRT, stock bucket table for shadow).
    pub fn start_with_backend_factory_and_info(
        cfg: Config,
        factory: BackendFactory,
        info: Option<crate::runtime::RuntimeInfo>,
    ) -> Result<Coordinator> {
        let router = Arc::new(match info {
            Some(i) => Router::with_info(cfg.engine, i),
            None => Router::new(cfg.engine, &cfg.artifacts_dir, cfg.runtime_backend)?,
        });
        let metrics = Arc::new(Metrics::new());
        let native_q = Arc::new(BoundedQueue::new(cfg.queue_capacity));
        let runtime_q = Arc::new(BoundedQueue::new(cfg.queue_capacity));

        let mut workers = Vec::new();
        let batch_wait = Duration::from_micros(cfg.batch_wait_us);
        for wi in 0..cfg.workers {
            let q = Arc::clone(&native_q);
            let r = Arc::clone(&router);
            let m = Arc::clone(&metrics);
            let max_batch = cfg.max_batch;
            let fanout = cfg.batch_fanout;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sqlsq-worker-{wi}"))
                    .spawn(move || {
                        while let Some(batch) =
                            q.pop_batch(max_batch, Duration::from_millis(50), batch_wait)
                        {
                            serve_batch_native(&r, &m, batch, fanout);
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        // Runtime lanes (only when the policy can ever use them). Each
        // lane constructs its own backend on its own thread: PJRT handles
        // are not Send, and per-lane artifact caches let lanes scale
        // independently. A lane whose backend fails to open runs
        // degraded (counted in metrics; Auto reroutes its pops native).
        if cfg.engine != Engine::Native {
            for li in 0..cfg.runtime_lanes.max(1) {
                let q = Arc::clone(&runtime_q);
                let r = Arc::clone(&router);
                let m = Arc::clone(&metrics);
                let max_batch = cfg.max_batch;
                let rt_fanout = cfg.runtime_fanout;
                let native_fanout = cfg.batch_fanout;
                let factory = Arc::clone(&factory);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("sqlsq-runtime-lane-{li}"))
                        .spawn(move || {
                            let mut backend = match factory(li) {
                                Ok(b) => Some(b),
                                Err(e) => {
                                    eprintln!("runtime lane {li}: backend unavailable: {e}");
                                    m.on_lane_degraded();
                                    None
                                }
                            };
                            while let Some(batch) =
                                q.pop_batch(max_batch, Duration::from_millis(50), batch_wait)
                            {
                                match backend.as_mut() {
                                    Some(b) => {
                                        serve_batch_runtime(b.as_mut(), &r, &m, batch, rt_fanout)
                                    }
                                    None => serve_batch_degraded(&r, &m, batch, native_fanout),
                                }
                            }
                        })
                        .expect("spawn runtime lane"),
                );
            }
        }

        let cache = match cfg.cache_policy {
            CachePolicy::Lru => Some(Arc::new(ResultCache::new(cfg.cache_capacity_bytes))),
            CachePolicy::Off => None,
        };
        Ok(Coordinator {
            native_q,
            runtime_q,
            router,
            metrics,
            cache,
            next_id: AtomicU64::new(1),
            workers,
            cfg,
        })
    }

    fn make_job(
        &self,
        data: Payload,
        method: QuantMethod,
        opts: QuantOptions,
        weights: Option<Arc<[f64]>>,
    ) -> (Job, mpsc::Receiver<JobResult>, bool) {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Route by distinct-count upper bound (len) — cheap admission-time
        // heuristic; the lane falls back per job under Auto when unfit.
        // f32 requests always stay native — whether the payload itself is
        // f32 or the caller asked for the f32 lane via opts.precision —
        // because the PJRT boundary is f64 and the native f32 lane *is*
        // their fast path (runtime dispatch never consults precision).
        // Weighted and entropy-budgeted jobs also stay native: the AOT
        // artifacts bake the unweighted objective and no merge pass.
        let to_runtime = self.cfg.engine != Engine::Native
            && matches!(data, Payload::F64(_))
            && opts.precision == Precision::F64
            && weights.is_none()
            && opts.entropy_budget.is_none()
            && self
                .router
                .routes_to_runtime(method, data.len().max(1), opts.target_values);
        (
            Job {
                id,
                data,
                method,
                opts,
                weights,
                submitted: Instant::now(),
                respond: tx,
                cache: None,
            },
            rx,
            to_runtime,
        )
    }

    /// Shared admission path for both submit front doors: validate the
    /// request shape, build the job, consult the result cache, and pick
    /// the queue. The push strategy (blocking vs shedding) stays at the
    /// call site; cache hits and joined duplicates never reach a queue.
    ///
    /// `tenant` partitions the result cache when `Config::cache_shared`
    /// is off; under the default shared policy it is ignored at the
    /// cache so all tenants benefit from each other's exact hits.
    fn admit_request(&self, req: QuantRequest, tenant: Option<&str>) -> Result<Admission<'_>> {
        let (data, method, opts, weights) = request_job_parts(req)?;
        let (mut job, rx, to_runtime) = self.make_job(data, method, opts, weights);
        if let Some(cache) = &self.cache {
            let cache_tenant = if self.cfg.cache_shared { None } else { tenant };
            match cache.admit(
                &self.metrics,
                job.id,
                cache_tenant,
                &job.data,
                job.method,
                &job.opts,
                job.weights.as_deref(),
                &job.respond,
                job.submitted,
            ) {
                // Hit: the result is already in the channel. Joined: it
                // arrives when the in-flight leader finishes. Either way
                // the job itself is dropped here (the waiter/hit holds
                // its own sender clone).
                Admit::Hit | Admit::Joined => return Ok(Admission::Served(job.id, rx)),
                Admit::Solve(ticket) => job.cache = ticket,
            }
        }
        let q = if to_runtime { &self.runtime_q } else { &self.native_q };
        Ok(Admission::Enqueue(job, rx, q.as_ref()))
    }

    /// **The typed front door**: blocking submit of a single-vector
    /// [`QuantRequest`] (applies backpressure). Returns the job id and
    /// the result receiver. Every legacy `submit*` variant below is a
    /// thin shim over this; shared request inputs enter the serve path
    /// without copying. Sweep plans and batch/matrix inputs are rejected
    /// — submit their units individually, or run them in-process via
    /// [`crate::quant::Quantizer`].
    pub fn submit_request(
        &self,
        req: QuantRequest,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.submit_request_as(req, None)
    }

    /// [`Coordinator::submit_request`] on behalf of a named tenant — the
    /// network front end's blocking door. The tenant id partitions the
    /// result cache when `Config::cache_shared` is off; it never affects
    /// routing or the solve itself. Errs with [`Error::Shutdown`] once
    /// the queues are closed ([`Coordinator::begin_drain`] / shutdown).
    pub fn submit_request_as(
        &self,
        req: QuantRequest,
        tenant: Option<&str>,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        match self.admit_request(req, tenant)? {
            Admission::Served(id, rx) => {
                self.metrics.on_submit();
                Ok((id, rx))
            }
            Admission::Enqueue(job, rx, q) => {
                let id = job.id;
                if !q.push(job) {
                    return Err(Error::Shutdown("coordinator queues are closed".into()));
                }
                self.metrics.on_submit();
                Ok((id, rx))
            }
        }
    }

    /// Non-blocking typed submit; `Err` when the queue is full (load
    /// shedding). The `try_` twin of [`Coordinator::submit_request`].
    pub fn try_submit_request(
        &self,
        req: QuantRequest,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.try_submit_request_as(req, None)
    }

    /// [`Coordinator::try_submit_request`] on behalf of a named tenant —
    /// the network front end's shedding door. The error distinguishes the
    /// two refusal modes so callers can react correctly:
    ///
    /// * [`Error::Saturated`] — the queue is full right now. Transient;
    ///   retry after a backoff (the server maps this to a SHED response
    ///   with a retry-after hint).
    /// * [`Error::Shutdown`] — the queues are closed (draining or shut
    ///   down). Permanent for this handle; the server maps this to
    ///   connection refusal.
    pub fn try_submit_request_as(
        &self,
        req: QuantRequest,
        tenant: Option<&str>,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        match self.admit_request(req, tenant)? {
            Admission::Served(id, rx) => {
                self.metrics.on_submit();
                Ok((id, rx))
            }
            Admission::Enqueue(job, rx, q) => {
                let id = job.id;
                match q.try_push(job) {
                    TryPush::Ok => {
                        self.metrics.on_submit();
                        Ok((id, rx))
                    }
                    // The shed job drops here; its leader ticket's Drop
                    // releases the cache reservation (parked duplicates
                    // fail instead of hanging).
                    TryPush::Full(_) => {
                        self.metrics.on_reject();
                        Err(Error::Saturated("queue full".into()))
                    }
                    TryPush::Closed(_) => {
                        Err(Error::Shutdown("coordinator queues are closed".into()))
                    }
                }
            }
        }
    }

    /// Submit a typed request and wait for the result (convenience).
    /// [`JobResult::codebook`] exposes the compact payload view.
    pub fn quantize_blocking_request(&self, req: QuantRequest) -> Result<JobResult> {
        let (_, rx) = self.submit_request(req)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("worker dropped the job".into()))
    }

    /// Blocking submit of a typed payload (applies backpressure).
    ///
    /// **Legacy**: thin shim over [`Coordinator::submit_request`].
    pub fn submit_payload(
        &self,
        data: Payload,
        method: QuantMethod,
        opts: QuantOptions,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.submit_request(request_from_payload(data, method, opts))
    }

    /// Blocking submit of f64 data (the historical API).
    ///
    /// **Legacy**: thin shim over [`Coordinator::submit_request`].
    pub fn submit(
        &self,
        data: Vec<f64>,
        method: QuantMethod,
        opts: QuantOptions,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.submit_payload(Payload::F64(data.into()), method, opts)
    }

    /// Blocking submit of f32 data; served by the native f32 lane without
    /// up-front widening.
    ///
    /// **Legacy**: thin shim over [`Coordinator::submit_request`].
    pub fn submit_f32(
        &self,
        data: Vec<f32>,
        method: QuantMethod,
        opts: QuantOptions,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.submit_payload(Payload::F32(data.into()), method, opts)
    }

    /// Non-blocking submit of a typed payload; `Err` when the queue is
    /// full (load shedding).
    ///
    /// **Legacy**: thin shim over [`Coordinator::try_submit_request`].
    pub fn try_submit_payload(
        &self,
        data: Payload,
        method: QuantMethod,
        opts: QuantOptions,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.try_submit_request(request_from_payload(data, method, opts))
    }

    /// Non-blocking submit of f64 data (the historical API).
    ///
    /// **Legacy**: thin shim over [`Coordinator::try_submit_request`].
    pub fn try_submit(
        &self,
        data: Vec<f64>,
        method: QuantMethod,
        opts: QuantOptions,
    ) -> Result<(JobId, mpsc::Receiver<JobResult>)> {
        self.try_submit_payload(Payload::F64(data.into()), method, opts)
    }

    /// Submit and wait for the result (convenience).
    ///
    /// **Legacy**: thin shim over [`Coordinator::quantize_blocking_request`].
    pub fn quantize_blocking(
        &self,
        data: Vec<f64>,
        method: QuantMethod,
        opts: QuantOptions,
    ) -> Result<JobResult> {
        self.quantize_blocking_request(request_from_payload(
            Payload::F64(data.into()),
            method,
            opts,
        ))
    }

    /// Submit f32 data and wait for the result (convenience).
    ///
    /// **Legacy**: thin shim over [`Coordinator::quantize_blocking_request`].
    pub fn quantize_blocking_f32(
        &self,
        data: Vec<f32>,
        method: QuantMethod,
        opts: QuantOptions,
    ) -> Result<JobResult> {
        self.quantize_blocking_request(request_from_payload(
            Payload::F32(data.into()),
            method,
            opts,
        ))
    }

    /// Current metrics snapshot.
    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    /// Current queue depths (native, runtime).
    pub fn queue_depths(&self) -> (usize, usize) {
        (self.native_q.len(), self.runtime_q.len())
    }

    /// Begin graceful drain without consuming the handle: close both
    /// queues so new submissions are refused with [`Error::Shutdown`],
    /// while the workers keep draining everything already queued
    /// (`BoundedQueue` drains-then-stops on close). Idempotent; call
    /// [`Coordinator::shutdown`] afterwards to join the workers — every
    /// job accepted before the drain still completes and responds.
    pub fn begin_drain(&self) {
        self.native_q.close();
        self.runtime_q.close();
    }

    /// Graceful shutdown: close queues, drain, join workers.
    pub fn shutdown(mut self) -> Snapshot {
        self.native_q.close();
        self.runtime_q.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.native_q.close();
        self.runtime_q.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> Config {
        Config {
            workers: 2,
            queue_capacity: 64,
            max_batch: 8,
            batch_wait_us: 100,
            engine: Engine::Native,
            ..Default::default()
        }
    }

    fn sample(seed: u64) -> Vec<f64> {
        let mut rng = crate::data::rng::Pcg32::seeded(seed);
        (0..50).map(|_| rng.uniform(0.0, 10.0)).collect()
    }

    #[test]
    fn submit_and_receive() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let res = c
            .quantize_blocking(
                sample(1),
                QuantMethod::KMeans,
                QuantOptions { target_values: 4, ..Default::default() },
            )
            .unwrap();
        assert!(res.is_ok());
        let out = res.outcome.unwrap();
        assert!(out.distinct_values() <= 4);
        let snap = c.shutdown();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn many_jobs_all_complete() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let mut rxs = Vec::new();
        for i in 0..40 {
            let method = match i % 4 {
                0 => QuantMethod::KMeans,
                1 => QuantMethod::L1,
                2 => QuantMethod::ClusterLs,
                _ => QuantMethod::L1LeastSquare,
            };
            let (_, rx) = c
                .submit(
                    sample(i),
                    method,
                    QuantOptions { target_values: 5, lambda1: 0.05, ..Default::default() },
                )
                .unwrap();
            rxs.push(rx);
        }
        let mut ok = 0;
        for rx in rxs {
            let r = rx.recv().unwrap();
            if r.is_ok() {
                ok += 1;
            }
        }
        assert_eq!(ok, 40);
        let snap = c.shutdown();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.failed, 0);
        assert!(snap.batches <= 40, "batching should group at least sometimes");
    }

    #[test]
    fn invalid_jobs_fail_cleanly() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let res = c
            .quantize_blocking(vec![], QuantMethod::KMeans, QuantOptions::default())
            .unwrap();
        assert!(!res.is_ok());
        let res2 = c
            .quantize_blocking(vec![f64::NAN, 1.0], QuantMethod::L1, QuantOptions::default())
            .unwrap();
        assert!(!res2.is_ok());
        let snap = c.shutdown();
        assert_eq!(snap.failed, 2);
    }

    #[test]
    fn try_submit_sheds_load_when_full() {
        // 1 slow-ish worker, capacity 2 ⇒ some rejects under a burst.
        let cfg = Config {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            batch_wait_us: 0,
            engine: Engine::Native,
            ..Default::default()
        };
        let c = Coordinator::start(cfg).unwrap();
        let mut accepted = 0;
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..50 {
            match c.try_submit(
                sample(i),
                QuantMethod::IterativeL1,
                QuantOptions { target_values: 3, lambda1: 1e-4, ..Default::default() },
            ) {
                Ok((_, rx)) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(_) => rejected += 1,
            }
        }
        assert!(accepted > 0);
        for rx in rxs {
            let _ = rx.recv();
        }
        let snap = c.shutdown();
        assert_eq!(snap.submitted, accepted);
        assert_eq!(snap.rejected, rejected);
        assert_eq!(snap.completed + snap.failed, accepted);
    }

    #[test]
    fn full_queue_sheds_with_saturated_error() {
        // No workers draining? Can't do that — workers always start. Use
        // capacity 1 with a single slow worker and flood: the refusals
        // must be the *transient* variant, never Shutdown.
        let cfg = Config {
            workers: 1,
            queue_capacity: 1,
            max_batch: 1,
            batch_wait_us: 0,
            engine: Engine::Native,
            ..Default::default()
        };
        let c = Coordinator::start(cfg).unwrap();
        let mut saw_saturated = false;
        let mut rxs = Vec::new();
        for i in 0..64 {
            match c.try_submit(
                sample(300 + i),
                QuantMethod::IterativeL1,
                QuantOptions { target_values: 3, lambda1: 1e-4, ..Default::default() },
            ) {
                Ok((_, rx)) => rxs.push(rx),
                Err(Error::Saturated(_)) => saw_saturated = true,
                Err(e) => panic!("full queue must shed with Saturated, got {e}"),
            }
        }
        assert!(saw_saturated, "a 64-burst against capacity 1 must shed at least once");
        for rx in rxs {
            let _ = rx.recv();
        }
        c.shutdown();
    }

    #[test]
    fn drained_coordinator_refuses_with_shutdown_error() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (_, rx) = c
                .submit(
                    sample(400 + i),
                    QuantMethod::KMeans,
                    QuantOptions { target_values: 3, ..Default::default() },
                )
                .unwrap();
            rxs.push(rx);
        }
        c.begin_drain();
        // Both doors must now refuse with the permanent variant. The
        // blocking door must not block.
        let opts = QuantOptions { target_values: 3, ..Default::default() };
        match c.try_submit(sample(500), QuantMethod::KMeans, opts.clone()) {
            Err(Error::Shutdown(_)) => {}
            other => panic!("try_submit after drain must be Shutdown, got {other:?}"),
        }
        match c.submit(sample(501), QuantMethod::KMeans, opts) {
            Err(Error::Shutdown(_)) => {}
            other => panic!("submit after drain must be Shutdown, got {other:?}"),
        }
        // Everything accepted before the drain still completes.
        for rx in rxs {
            assert!(rx.recv().is_ok(), "drain must flush accepted jobs");
        }
        let snap = c.shutdown();
        assert_eq!(snap.completed + snap.failed, 6);
    }

    #[test]
    fn tenant_id_is_invisible_when_cache_is_shared() {
        // Default cache_shared=true: two tenants share exact hits.
        let c = Coordinator::start(test_cfg()).unwrap();
        let data = sample(31);
        let opts = QuantOptions { target_values: 4, seed: 9, ..Default::default() };
        let req = |d: &Vec<f64>| {
            QuantRequest::vector(d.clone()).method(QuantMethod::KMeans).options(opts.clone())
        };
        let (_, rx_a) = c.submit_request_as(req(&data), Some("alice")).unwrap();
        let a = rx_a.recv().unwrap();
        let (_, rx_b) = c.submit_request_as(req(&data), Some("bob")).unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(b.served_by, ServedBy::Cache, "shared cache serves across tenants");
        assert_eq!(
            a.outcome.unwrap().materialize(),
            b.outcome.unwrap().materialize(),
            "hit is bitwise"
        );
        let snap = c.shutdown();
        assert_eq!(snap.cache_hits, 1);
    }

    #[test]
    fn partitioned_tenants_never_share_cache_entries() {
        let cfg = Config { cache_shared: false, ..test_cfg() };
        let c = Coordinator::start(cfg).unwrap();
        let data = sample(32);
        let opts = QuantOptions { target_values: 4, seed: 9, ..Default::default() };
        let req = |d: &Vec<f64>| {
            QuantRequest::vector(d.clone()).method(QuantMethod::KMeans).options(opts.clone())
        };
        let (_, rx_a) = c.submit_request_as(req(&data), Some("alice")).unwrap();
        assert!(rx_a.recv().unwrap().is_ok());
        // Same bytes, different tenant: must solve again, not hit.
        let (_, rx_b) = c.submit_request_as(req(&data), Some("bob")).unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(b.served_by, ServedBy::Native, "partitioned tenants must not share");
        // Same tenant resubmits: now it hits its own partition.
        let (_, rx_a2) = c.submit_request_as(req(&data), Some("alice")).unwrap();
        let a2 = rx_a2.recv().unwrap();
        assert_eq!(a2.served_by, ServedBy::Cache, "a tenant still hits its own entries");
        let snap = c.shutdown();
        assert_eq!(snap.cache_hits, 1);
        assert_eq!(snap.stage_samples, 2, "exactly two engine solves ran");
    }

    #[test]
    fn shutdown_drains_pending_jobs() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let mut rxs = Vec::new();
        for i in 0..10 {
            let (_, rx) = c
                .submit(
                    sample(100 + i),
                    QuantMethod::KMeans,
                    QuantOptions { target_values: 3, ..Default::default() },
                )
                .unwrap();
            rxs.push(rx);
        }
        let snap = c.shutdown();
        assert_eq!(snap.completed + snap.failed, 10, "shutdown must drain the queue");
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn batch_fanout_parallel_results_match_direct_calls() {
        // One worker + wide batches + fan-out 4 forces the parallel path.
        let cfg = Config {
            workers: 1,
            queue_capacity: 128,
            max_batch: 16,
            batch_wait_us: 3000,
            batch_fanout: 4,
            engine: Engine::Native,
            ..Default::default()
        };
        let c = Coordinator::start(cfg).unwrap();
        let mut jobs = Vec::new();
        for i in 0..32u64 {
            let data = sample(200 + i);
            let opts = QuantOptions { target_values: 4, seed: i, ..Default::default() };
            let (_, rx) = c.submit(data.clone(), QuantMethod::KMeans, opts.clone()).unwrap();
            jobs.push((data, opts, rx));
        }
        for (data, opts, rx) in jobs {
            let got = rx.recv().unwrap().outcome.unwrap();
            let direct = crate::quant::quantize(&data, QuantMethod::KMeans, &opts).unwrap();
            assert_eq!(got.materialize(), direct.values, "fan-out changed a result");
        }
        let snap = c.shutdown();
        assert_eq!(snap.completed, 32);
        // Every native job records prepare/solve stage timings.
        assert_eq!(snap.stage_samples, 32);
        assert!(snap.mean_prepare_us >= 0.0 && snap.mean_solve_us >= 0.0);
    }

    #[test]
    fn f32_payloads_serve_on_the_native_f32_lane() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let data32: Vec<f32> = sample(9).iter().map(|&x| x as f32).collect();
        let opts = QuantOptions { lambda1: 0.05, ..Default::default() };
        let res = c
            .quantize_blocking_f32(data32.clone(), QuantMethod::L1LeastSquare, opts.clone())
            .unwrap();
        assert!(res.is_ok());
        assert_eq!(res.served_by, ServedBy::Native);
        let got = res.outcome.unwrap();
        assert_eq!(got.precision(), Precision::F32, "result stays narrow until the edge");
        let direct = crate::quant::quantize_f32(&data32, QuantMethod::L1LeastSquare, &opts)
            .unwrap()
            .widen();
        assert_eq!(got.materialize(), direct.values);
        assert_eq!(got.l2_loss().to_bits(), direct.l2_loss.to_bits());
        let snap = c.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.stage_samples, 1, "f32 jobs must record stage timings too");
    }

    #[test]
    fn request_front_door_matches_legacy_submit() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let data = sample(11);
        let opts = QuantOptions { target_values: 4, seed: 5, ..Default::default() };
        let via_req = c
            .quantize_blocking_request(
                QuantRequest::vector(data.clone())
                    .method(QuantMethod::KMeans)
                    .options(opts.clone()),
            )
            .unwrap()
            .outcome
            .unwrap();
        let via_legacy = c
            .quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone())
            .unwrap()
            .outcome
            .unwrap();
        let direct = crate::quant::quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        assert_eq!(via_req.materialize(), via_legacy.materialize());
        assert_eq!(via_req.materialize(), direct.values);
        assert_eq!(via_req.l2_loss().to_bits(), direct.l2_loss.to_bits());
        c.shutdown();
    }

    #[test]
    fn non_job_shaped_requests_are_rejected_at_submit() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let sweep = QuantRequest::vector(sample(12)).sweep(vec![1e-3, 1e-2]);
        assert!(c.submit_request(sweep).is_err());
        let batch = QuantRequest::batch(vec![sample(13)]);
        assert!(c.try_submit_request(batch).is_err());
        c.shutdown();
    }

    #[test]
    fn job_result_ships_compact_codebook() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let res = c
            .quantize_blocking_request(
                QuantRequest::vector(sample(14)).method(QuantMethod::KMeans).target_count(4),
            )
            .unwrap();
        let cb = res.codebook().expect("successful jobs expose a codebook");
        assert!(cb.k() <= 4);
        let out = res.outcome.unwrap();
        assert_eq!(cb.decode(), out.materialize());
        // Compression accounting rides on the result.
        let stats = out.compression();
        assert_eq!(stats.levels_requested, 4);
        assert!(stats.levels_achieved <= 4);
        assert!(stats.byte_ratio > 1.0);
        c.shutdown();
    }

    #[test]
    fn identical_resubmit_is_served_from_cache_bitwise() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let data = sample(21);
        let opts = QuantOptions { target_values: 4, seed: 7, ..Default::default() };
        let cold = c
            .quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone())
            .unwrap();
        assert_eq!(cold.served_by, ServedBy::Native);
        let hit = c
            .quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone())
            .unwrap();
        assert_eq!(hit.served_by, ServedBy::Cache, "identical resubmit must hit");
        let (a, b) = (cold.outcome.unwrap(), hit.outcome.unwrap());
        assert_eq!(a.materialize(), b.materialize(), "hit is bitwise-identical");
        assert_eq!(a.l2_loss().to_bits(), b.l2_loss().to_bits());
        assert_eq!(a.compression().compact_bytes, b.compression().compact_bytes);
        let snap = c.shutdown();
        assert_eq!(snap.cache_hits, 1);
        assert_eq!(snap.cache_misses, 1);
        assert!(snap.cache_bytes_saved > 0);
        assert_eq!(snap.completed, 2, "a hit still counts as a completed job");
        assert_eq!(snap.stage_samples, 1, "exactly one engine solve ran");
    }

    #[test]
    fn cache_off_policy_solves_every_submit() {
        let cfg = Config { cache_policy: CachePolicy::Off, ..test_cfg() };
        let c = Coordinator::start(cfg).unwrap();
        let data = sample(22);
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        for _ in 0..2 {
            let res = c
                .quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone())
                .unwrap();
            assert_eq!(res.served_by, ServedBy::Native);
        }
        let snap = c.shutdown();
        assert_eq!(snap.stage_samples, 2, "cache off: every submit solves");
        assert_eq!(snap.cache_hits, 0);
        assert_eq!(snap.cache_misses, 0);
    }

    #[test]
    fn weighted_requests_serve_natively_and_match_the_facade() {
        use crate::quant::Quantizer;
        let c = Coordinator::start(test_cfg()).unwrap();
        let data = sample(60);
        let wts: Vec<f64> = (0..data.len()).map(|i| 0.5 + (i % 7) as f64).collect();
        let opts = QuantOptions { target_values: 4, seed: 3, ..Default::default() };
        let mk = || {
            QuantRequest::vector(data.clone())
                .method(QuantMethod::KMeans)
                .options(opts.clone())
                .weights(wts.clone())
        };
        let via_coord = c.quantize_blocking_request(mk()).unwrap();
        assert_eq!(via_coord.served_by, ServedBy::Native);
        let got = via_coord.outcome.unwrap();
        let direct = Quantizer::new().run(&mk()).unwrap().into_single().unwrap();
        assert_eq!(got.materialize(), direct.materialize_f64(), "weighted serve is bitwise");
        assert_eq!(got.l2_loss().to_bits(), direct.l2_loss().to_bits());

        // An identical weighted resubmit hits the cache.
        let hit = c.quantize_blocking_request(mk()).unwrap();
        assert_eq!(hit.served_by, ServedBy::Cache, "weighted resubmit must hit");
        assert_eq!(hit.outcome.unwrap().materialize(), got.materialize());

        // A uniform-weighted submit is normalized away at admission: it
        // runs — and caches — exactly as the unweighted job.
        let plain = QuantRequest::vector(data.clone())
            .method(QuantMethod::KMeans)
            .options(opts.clone());
        let cold = c.quantize_blocking_request(plain).unwrap();
        assert_eq!(cold.served_by, ServedBy::Native);
        let uniform = QuantRequest::vector(data.clone())
            .method(QuantMethod::KMeans)
            .options(opts.clone())
            .weights(vec![2.5; data.len()]);
        let aliased = c.quantize_blocking_request(uniform).unwrap();
        assert_eq!(
            aliased.served_by,
            ServedBy::Cache,
            "uniform weights must share the unweighted cache entry"
        );
        assert_eq!(
            aliased.outcome.unwrap().materialize(),
            cold.outcome.unwrap().materialize()
        );
        c.shutdown();
    }

    #[test]
    fn malformed_weighted_requests_are_refused_at_admission() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let data = sample(61);
        let base = || QuantRequest::vector(data.clone()).method(QuantMethod::KMeans);
        // Length mismatch, NaN, negative, zero-sum: all refused before a
        // job exists (no queue slot, no id burned into the metrics).
        for bad in [
            vec![1.0; data.len() - 1],
            {
                let mut w = vec![1.0; data.len()];
                w[3] = f64::NAN;
                w
            },
            {
                let mut w = vec![1.0; data.len()];
                w[0] = -1.0;
                w
            },
            vec![0.0; data.len()],
        ] {
            match c.submit_request(base().weights(bad)) {
                Err(Error::InvalidInput(_)) => {}
                other => panic!("malformed weights must refuse with InvalidInput, got {other:?}"),
            }
        }
        // A bad entropy budget is refused the same way.
        match c.submit_request(base().entropy_budget(f64::NAN)) {
            Err(Error::InvalidParam(_)) => {}
            other => panic!("NaN entropy budget must refuse with InvalidParam, got {other:?}"),
        }
        let snap = c.shutdown();
        assert_eq!(snap.submitted, 0, "refused requests never count as submissions");
    }

    #[test]
    fn entropy_budget_requests_match_the_facade_through_the_coordinator() {
        use crate::quant::Quantizer;
        let c = Coordinator::start(test_cfg()).unwrap();
        let data = sample(62);
        let mk = || {
            QuantRequest::vector(data.clone())
                .method(QuantMethod::KMeans)
                .target_count(6)
                .entropy_budget(1.0)
        };
        let via_coord = c.quantize_blocking_request(mk()).unwrap().outcome.unwrap();
        let direct = Quantizer::new().run(&mk()).unwrap().into_single().unwrap();
        assert_eq!(via_coord.materialize(), direct.materialize_f64());
        assert_eq!(via_coord.l2_loss().to_bits(), direct.l2_loss().to_bits());
        let stats = via_coord.compression();
        assert!(
            stats.index_entropy <= 1.0 + 1e-9,
            "budget respected through the serve path: {}",
            stats.index_entropy
        );
        c.shutdown();
    }

    #[test]
    fn results_match_direct_engine_calls() {
        let c = Coordinator::start(test_cfg()).unwrap();
        let data = sample(7);
        let opts = QuantOptions { target_values: 4, seed: 3, ..Default::default() };
        let via_coord = c
            .quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone())
            .unwrap()
            .outcome
            .unwrap();
        let direct = crate::quant::quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        assert_eq!(via_coord.materialize(), direct.values);
        c.shutdown();
    }
}
