//! Cross-request result cache on the serve path.
//!
//! The coordinator keys every admitted single-vector job by a content
//! [`Fingerprint`] of `(input bytes, precision lane, method, options,
//! importance weights)` — weights arrive admission-normalized, so a
//! uniform-weighted submit shares the unweighted key it solves
//! identically to.
//! An exact hit returns the cached compact [`Item`] — bitwise-identical
//! to a cold solve — straight into the submitter's respond channel,
//! without the job ever entering a queue. A duplicate of an *in-flight*
//! solve parks as a waiter and receives the leader's result when it
//! finishes (single-flight: N concurrent identical submits run exactly
//! one solve).
//!
//! Correctness before speed:
//!
//! * **Collision-proof.** The fingerprint only routes the lookup; every
//!   hit additionally verifies the full key — payload element bit
//!   patterns, method, and all option fields bit-for-bit
//!   ([`crate::quant::api::opts_bits_eq`]). A 128-bit collision degrades
//!   to a miss, never a wrong answer.
//! * **Bitwise-invisible.** The cached value is the compact item the
//!   engine's finalize built; a hit re-wraps it with the request's own
//!   `levels_requested`, exactly as `server::finish` would. Only
//!   [`JobResult::served_by`] (reported as [`ServedBy::Cache`]) and the
//!   latency differ from a cold solve.
//! * **Bounded.** Ready entries are LRU-evicted by their compact byte
//!   cost once the configured capacity is exceeded. In-flight
//!   reservations hold no bytes and are never evicted.
//! * **Leader-abandonment safe.** The admission reservation is tied to a
//!   [`CacheTicket`] carried by the job; if the leader never completes
//!   (queue closed, load shed, worker panic) the ticket's `Drop` removes
//!   the reservation and fails the parked waiters, so duplicates never
//!   hang on a solve that will not happen.
//!
//! Errors are not cached: a failed solve drops the reservation (waiters
//! receive the same error), and the next identical submit solves again.

use super::job::{JobId, JobOutput, JobResult, Payload, ServedBy};
use super::metrics::Metrics;
use crate::quant::api::{opts_bits_eq, Fingerprint};
use crate::quant::{Item, QuantMethod, QuantOptions};
use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Bit-exact payload equality (the hit-verification arm of the key):
/// element bit patterns, so `-0.0` ≠ `0.0` and NaN payloads never alias.
fn payload_bits_eq(a: &Payload, b: &Payload) -> bool {
    match (a, b) {
        (Payload::F64(x), Payload::F64(y)) => {
            x.len() == y.len() && x.iter().zip(y.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (Payload::F32(x), Payload::F32(y)) => {
            x.len() == y.len() && x.iter().zip(y.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        _ => false,
    }
}

/// Bit-exact importance-weight equality (same contract as
/// [`payload_bits_eq`]): admission hands the cache *normalized* weights
/// (uniform dropped to `None`), so an unweighted submit and a
/// uniform-weighted submit share one key — exactly mirroring the solve
/// path, which serves them bitwise-identically.
fn weights_bits_eq(a: Option<&[f64]>, b: Option<&[f64]>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => {
            x.len() == y.len() && x.iter().zip(y.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        _ => false,
    }
}

/// The full admission key, retained per entry so hits verify it
/// bit-for-bit. The payload (and any weight vector) is an `Arc` clone —
/// no data copy. `tenant` is the cache-partition label (`None` under
/// the default shared policy): it salts the fingerprint *and*
/// participates in the verification arm, so partitioned tenants can
/// never serve each other's entries even through a 128-bit collision.
#[derive(Debug, Clone)]
struct CacheKey {
    tenant: Option<Box<str>>,
    data: Payload,
    method: QuantMethod,
    opts: QuantOptions,
    weights: Option<Arc<[f64]>>,
}

impl CacheKey {
    fn bits_eq(
        &self,
        tenant: Option<&str>,
        data: &Payload,
        method: QuantMethod,
        opts: &QuantOptions,
        weights: Option<&[f64]>,
    ) -> bool {
        self.tenant.as_deref() == tenant
            && self.method == method
            && opts_bits_eq(&self.opts, opts)
            && payload_bits_eq(&self.data, data)
            && weights_bits_eq(self.weights.as_deref(), weights)
    }
}

/// A parked duplicate submitter, delivered when the leader finishes.
#[derive(Debug)]
struct Waiter {
    id: JobId,
    respond: mpsc::Sender<JobResult>,
    submitted: Instant,
    levels_requested: usize,
}

#[derive(Debug)]
enum Slot {
    /// A solve for this key is in flight; duplicates park here.
    InFlight { key: CacheKey, waiters: Vec<Waiter> },
    /// A finished compact result.
    Ready {
        key: CacheKey,
        item: Item,
        solve_time: Duration,
        cost_bytes: usize,
        stamp: u64,
    },
}

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<Fingerprint, Slot>,
    /// Monotone LRU clock; touched on insert and on every hit.
    clock: u64,
    /// Total compact bytes held by `Ready` entries.
    ready_bytes: usize,
}

/// The coordinator's serve-path result cache (see the module docs).
#[derive(Debug)]
pub struct ResultCache {
    inner: Mutex<Inner>,
    capacity_bytes: usize,
}

/// Admission verdict for one submitted job.
#[derive(Debug)]
pub enum Admit {
    /// Miss: the caller is the leader. Attach the ticket (if any) to the
    /// job; `server::finish` completes it. `None` means this request is
    /// not cacheable right now (a live fingerprint collision) — solve
    /// without publishing.
    Solve(Option<CacheTicket>),
    /// Exact hit: the cached result was already sent into the respond
    /// channel. Do not enqueue.
    Hit,
    /// Duplicate of an in-flight solve: parked as a waiter; the result
    /// arrives when the leader finishes. Do not enqueue.
    Joined,
}

impl ResultCache {
    /// New empty cache bounded to `capacity_bytes` of compact results.
    pub fn new(capacity_bytes: usize) -> ResultCache {
        ResultCache { inner: Mutex::new(Inner::default()), capacity_bytes }
    }

    /// Admission-time lookup, called with the job's identity before it is
    /// queued. Exactly one of three things happens under the lock: the
    /// hit is delivered, the duplicate parks, or the miss reserves the
    /// key (single-flight) and returns the leader's ticket.
    ///
    /// `tenant` is the cache-partition label (`None` = the shared
    /// partition): the coordinator passes it only when
    /// `Config::cache_shared` is off, so partitioned tenants fingerprint
    /// — and verify — disjointly.
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        self: &Arc<Self>,
        metrics: &Arc<Metrics>,
        id: JobId,
        tenant: Option<&str>,
        data: &Payload,
        method: QuantMethod,
        opts: &QuantOptions,
        weights: Option<&[f64]>,
        respond: &mpsc::Sender<JobResult>,
        submitted: Instant,
    ) -> Admit {
        let fp = match data {
            Payload::F64(v) => Fingerprint::vector_f64_weighted(v, weights, method, opts),
            Payload::F32(v) => Fingerprint::vector_f32_weighted(v, weights, method, opts),
        };
        let fp = match tenant {
            Some(t) => fp.with_tenant(t),
            None => fp,
        };
        // Classify under a short immutable borrow, then act: matching on
        // `get_mut` would pin the map borrow across arms that need to
        // insert (NLL problem case).
        enum Lookup {
            HitReady,
            JoinInFlight,
            CollideInFlight,
            CollideReady,
            Vacant,
        }
        let mut g = self.inner.lock().expect("cache lock");
        g.clock += 1;
        let now = g.clock;
        let look = match g.map.get(&fp) {
            Some(Slot::Ready { key, .. })
                if key.bits_eq(tenant, data, method, opts, weights) =>
            {
                Lookup::HitReady
            }
            Some(Slot::Ready { .. }) => Lookup::CollideReady,
            Some(Slot::InFlight { key, .. })
                if key.bits_eq(tenant, data, method, opts, weights) =>
            {
                Lookup::JoinInFlight
            }
            Some(Slot::InFlight { .. }) => Lookup::CollideInFlight,
            None => Lookup::Vacant,
        };
        match look {
            Lookup::HitReady => {
                let (item, solve_saved, bytes_saved) = match g.map.get_mut(&fp) {
                    Some(Slot::Ready { item, solve_time, cost_bytes, stamp, .. }) => {
                        *stamp = now;
                        (item.clone(), *solve_time, *cost_bytes)
                    }
                    _ => unreachable!("classified Ready under the same lock"),
                };
                drop(g);
                let latency = submitted.elapsed();
                metrics.on_cache_hit(bytes_saved, solve_saved, latency);
                let _ = respond.send(JobResult {
                    id,
                    outcome: Ok(JobOutput::new(item, opts.target_values)),
                    latency,
                    served_by: ServedBy::Cache,
                });
                Admit::Hit
            }
            Lookup::JoinInFlight => {
                if let Some(Slot::InFlight { waiters, .. }) = g.map.get_mut(&fp) {
                    waiters.push(Waiter {
                        id,
                        respond: respond.clone(),
                        submitted,
                        levels_requested: opts.target_values,
                    });
                }
                Admit::Joined
            }
            Lookup::CollideInFlight => {
                // Live fingerprint collision with a different key: the
                // slot is busy and its waiters must not be orphaned.
                // Solve without caching (astronomically rare).
                drop(g);
                metrics.on_cache_miss();
                Admit::Solve(None)
            }
            Lookup::CollideReady => {
                // Ready entry under a colliding fingerprint: the new key
                // takes the slot (it is about to be the hotter one).
                if let Some(Slot::Ready { cost_bytes, .. }) = g.map.remove(&fp) {
                    g.ready_bytes -= cost_bytes;
                }
                self.reserve(&mut g, fp, tenant, data, method, opts, weights);
                drop(g);
                metrics.on_cache_miss();
                Admit::Solve(Some(self.ticket(metrics, fp)))
            }
            Lookup::Vacant => {
                self.reserve(&mut g, fp, tenant, data, method, opts, weights);
                drop(g);
                metrics.on_cache_miss();
                Admit::Solve(Some(self.ticket(metrics, fp)))
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn reserve(
        &self,
        g: &mut Inner,
        fp: Fingerprint,
        tenant: Option<&str>,
        data: &Payload,
        method: QuantMethod,
        opts: &QuantOptions,
        weights: Option<&[f64]>,
    ) {
        let key = CacheKey {
            tenant: tenant.map(Box::from),
            data: data.clone(),
            method,
            opts: opts.clone(),
            weights: weights.map(Arc::from),
        };
        g.map.insert(fp, Slot::InFlight { key, waiters: Vec::new() });
    }

    fn ticket(self: &Arc<Self>, metrics: &Arc<Metrics>, fp: Fingerprint) -> CacheTicket {
        CacheTicket { cache: Arc::clone(self), metrics: Arc::clone(metrics), fp, done: false }
    }

    /// (ready entries, in-flight reservations, ready compact bytes) —
    /// test/diagnostic visibility.
    pub fn stats(&self) -> (usize, usize, usize) {
        let g = self.inner.lock().expect("cache lock");
        let ready = g
            .map
            .values()
            .filter(|s| matches!(s, Slot::Ready { .. }))
            .count();
        (ready, g.map.len() - ready, g.ready_bytes)
    }
}

/// The leader's obligation to publish its outcome (held inside the job
/// while it rides the queue). Completing on success inserts the compact
/// result and drains waiters; completing on failure (or dropping the
/// ticket without completing — queue closed, shed, panic) removes the
/// reservation and fails the waiters, so duplicates never hang.
#[derive(Debug)]
pub struct CacheTicket {
    cache: Arc<ResultCache>,
    metrics: Arc<Metrics>,
    fp: Fingerprint,
    done: bool,
}

impl CacheTicket {
    /// Publish the leader's outcome. Called by `server::finish` exactly
    /// once per cached-leader job, before the leader's own respond.
    pub(crate) fn complete(&mut self, outcome: &crate::Result<Item>, served_by: ServedBy) {
        self.done = true;
        let mut g = self.cache.inner.lock().expect("cache lock");
        let (key, waiters) = match g.map.remove(&self.fp) {
            Some(Slot::InFlight { key, waiters }) => (key, waiters),
            Some(other) => {
                // Not our reservation (collision replaced it) — restore.
                g.map.insert(self.fp, other);
                return;
            }
            None => return,
        };
        match outcome {
            Ok(item) => {
                let cost_bytes = item.compression(key.opts.target_values).compact_bytes;
                let t = item.timings();
                let solve_time = t.prepare + t.solve;
                g.clock += 1;
                let stamp = g.clock;
                g.ready_bytes += cost_bytes;
                g.map.insert(
                    self.fp,
                    Slot::Ready { key, item: item.clone(), solve_time, cost_bytes, stamp },
                );
                // LRU eviction by compact bytes; never the entry just
                // inserted (a result larger than the whole capacity still
                // serves its own waiters and is evicted by the next
                // insert).
                while g.ready_bytes > self.cache.capacity_bytes {
                    let victim = g
                        .map
                        .iter()
                        .filter_map(|(fp, s)| match s {
                            Slot::Ready { stamp, .. } if *fp != self.fp => Some((*stamp, *fp)),
                            _ => None,
                        })
                        .min_by_key(|(stamp, _)| *stamp)
                        .map(|(_, fp)| fp);
                    match victim {
                        Some(fp) => {
                            if let Some(Slot::Ready { cost_bytes, .. }) = g.map.remove(&fp) {
                                g.ready_bytes -= cost_bytes;
                            }
                        }
                        None => break,
                    }
                }
                drop(g);
                for w in waiters {
                    let latency = w.submitted.elapsed();
                    self.metrics.on_cache_hit(cost_bytes, solve_time, latency);
                    let _ = w.respond.send(JobResult {
                        id: w.id,
                        outcome: Ok(JobOutput::new(item.clone(), w.levels_requested)),
                        latency,
                        served_by: ServedBy::Cache,
                    });
                }
            }
            Err(e) => {
                drop(g);
                let msg = e.to_string();
                for w in waiters {
                    let latency = w.submitted.elapsed();
                    self.metrics.on_complete(false, latency, served_by == ServedBy::Runtime);
                    let _ = w.respond.send(JobResult {
                        id: w.id,
                        outcome: Err(msg.clone()),
                        latency,
                        served_by,
                    });
                }
            }
        }
    }
}

impl Drop for CacheTicket {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        // Leader abandoned before solving: release the reservation and
        // fail parked duplicates rather than leaving them waiting.
        let Ok(mut g) = self.cache.inner.lock() else { return };
        let waiters = match g.map.remove(&self.fp) {
            Some(Slot::InFlight { waiters, .. }) => waiters,
            Some(other) => {
                g.map.insert(self.fp, other);
                return;
            }
            None => return,
        };
        drop(g);
        for w in waiters {
            let latency = w.submitted.elapsed();
            self.metrics.on_complete(false, latency, false);
            let _ = w.respond.send(JobResult {
                id: w.id,
                outcome: Err("cache leader abandoned before solving".into()),
                latency,
                served_by: ServedBy::Cache,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{QuantRequest, Quantizer};

    fn solved(data: &[f64], method: QuantMethod, opts: &QuantOptions) -> Item {
        let req = QuantRequest::vector(data.to_vec()).method(method).options(opts.clone());
        Quantizer::new().run(&req).unwrap().into_single().unwrap()
    }

    fn payload(seed: u64) -> Payload {
        let mut rng = crate::data::rng::Pcg32::seeded(seed);
        Payload::F64((0..40).map(|_| rng.uniform(0.0, 1.0)).collect::<Vec<_>>().into())
    }

    fn admit(
        cache: &Arc<ResultCache>,
        metrics: &Arc<Metrics>,
        id: JobId,
        data: &Payload,
        opts: &QuantOptions,
    ) -> (Admit, mpsc::Receiver<JobResult>) {
        admit_as(cache, metrics, id, None, data, opts)
    }

    fn admit_as(
        cache: &Arc<ResultCache>,
        metrics: &Arc<Metrics>,
        id: JobId,
        tenant: Option<&str>,
        data: &Payload,
        opts: &QuantOptions,
    ) -> (Admit, mpsc::Receiver<JobResult>) {
        let (tx, rx) = mpsc::channel();
        let verdict = cache.admit(
            metrics,
            id,
            tenant,
            data,
            QuantMethod::KMeans,
            opts,
            None,
            &tx,
            Instant::now(),
        );
        (verdict, rx)
    }

    fn admit_weighted(
        cache: &Arc<ResultCache>,
        metrics: &Arc<Metrics>,
        id: JobId,
        data: &Payload,
        weights: Option<&[f64]>,
        opts: &QuantOptions,
    ) -> (Admit, mpsc::Receiver<JobResult>) {
        let (tx, rx) = mpsc::channel();
        let verdict = cache.admit(
            metrics,
            id,
            None,
            data,
            QuantMethod::KMeans,
            opts,
            weights,
            &tx,
            Instant::now(),
        );
        (verdict, rx)
    }

    #[test]
    fn miss_then_hit_round_trip_is_bitwise_and_counted() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let metrics = Arc::new(Metrics::new());
        let data = payload(1);
        let opts = QuantOptions { target_values: 4, ..Default::default() };

        let (verdict, _rx1) = admit(&cache, &metrics, 1, &data, &opts);
        let Admit::Solve(Some(mut ticket)) = verdict else {
            panic!("first admit must be a leader miss")
        };
        let Payload::F64(v) = &data else { unreachable!() };
        let item = solved(v, QuantMethod::KMeans, &opts);
        ticket.complete(&Ok(item.clone()), ServedBy::Native);
        assert_eq!(cache.stats().0, 1, "one ready entry");

        let (verdict, rx2) = admit(&cache, &metrics, 2, &data, &opts);
        assert!(matches!(verdict, Admit::Hit), "second identical admit hits");
        let res = rx2.try_recv().expect("hit delivers synchronously");
        assert_eq!(res.served_by, ServedBy::Cache);
        let out = res.outcome.unwrap();
        let got = out.item().as_f64().unwrap();
        let want = item.as_f64().unwrap();
        assert_eq!(got.codebook.levels, want.codebook.levels);
        assert_eq!(got.codebook.indices, want.codebook.indices);
        assert_eq!(got.l2_loss.to_bits(), want.l2_loss.to_bits());
        let snap = metrics.snapshot();
        assert_eq!(snap.cache_hits, 1);
        assert_eq!(snap.cache_misses, 1);
        assert!(snap.cache_bytes_saved > 0);
    }

    #[test]
    fn in_flight_duplicates_park_and_drain_single_flight() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let metrics = Arc::new(Metrics::new());
        let data = payload(2);
        let opts = QuantOptions { target_values: 3, ..Default::default() };

        let (verdict, _rx_leader) = admit(&cache, &metrics, 1, &data, &opts);
        let Admit::Solve(Some(mut ticket)) = verdict else { panic!("leader miss") };
        let (v2, rx2) = admit(&cache, &metrics, 2, &data, &opts);
        let (v3, rx3) = admit(&cache, &metrics, 3, &data, &opts);
        assert!(matches!(v2, Admit::Joined) && matches!(v3, Admit::Joined));
        assert!(rx2.try_recv().is_err(), "waiters get nothing until the leader finishes");

        let Payload::F64(v) = &data else { unreachable!() };
        let item = solved(v, QuantMethod::KMeans, &opts);
        ticket.complete(&Ok(item.clone()), ServedBy::Native);
        for (id, rx) in [(2u64, rx2), (3, rx3)] {
            let res = rx.try_recv().expect("drained on complete");
            assert_eq!(res.id, id);
            assert_eq!(res.served_by, ServedBy::Cache);
            let got = res.outcome.unwrap();
            assert_eq!(
                got.item().as_f64().unwrap().codebook.indices,
                item.as_f64().unwrap().codebook.indices
            );
        }
        assert_eq!(metrics.snapshot().cache_hits, 2, "both waiters count as hits");
    }

    #[test]
    fn abandoned_leader_fails_waiters_and_releases_the_key() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let metrics = Arc::new(Metrics::new());
        let data = payload(3);
        let opts = QuantOptions::default();

        let (verdict, _rx1) = admit(&cache, &metrics, 1, &data, &opts);
        let Admit::Solve(Some(ticket)) = verdict else { panic!("leader miss") };
        let (v2, rx2) = admit(&cache, &metrics, 2, &data, &opts);
        assert!(matches!(v2, Admit::Joined));
        drop(ticket); // queue closed / shed / panic
        let res = rx2.try_recv().expect("waiter fails instead of hanging");
        assert!(res.outcome.is_err());
        assert_eq!(cache.stats(), (0, 0, 0), "reservation released");
        // The key is free again: the next submit leads a fresh solve.
        let (v3, _rx3) = admit(&cache, &metrics, 3, &data, &opts);
        assert!(matches!(v3, Admit::Solve(Some(_))));
    }

    #[test]
    fn failed_solves_are_not_cached_and_propagate_to_waiters() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let metrics = Arc::new(Metrics::new());
        let data = payload(4);
        let opts = QuantOptions::default();

        let (verdict, _rx1) = admit(&cache, &metrics, 1, &data, &opts);
        let Admit::Solve(Some(mut ticket)) = verdict else { panic!("leader miss") };
        let (v2, rx2) = admit(&cache, &metrics, 2, &data, &opts);
        assert!(matches!(v2, Admit::Joined));
        ticket.complete(
            &Err(crate::Error::InvalidInput("boom".into())),
            ServedBy::Native,
        );
        let res = rx2.try_recv().expect("waiter gets the leader's error");
        assert!(res.outcome.is_err());
        assert_eq!(cache.stats(), (0, 0, 0), "errors are not cached");
        let (v3, _rx3) = admit(&cache, &metrics, 3, &data, &opts);
        assert!(matches!(v3, Admit::Solve(Some(_))), "next submit solves again");
    }

    #[test]
    fn lru_eviction_is_bounded_by_compact_bytes_and_never_serves_evicted() {
        let metrics = Arc::new(Metrics::new());
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        // Capacity for roughly one entry: each 40-element k≤4 compact
        // item is 40 u32 packed at ≤2 bits + 4 levels ≈ 10 + 32 bytes.
        let cache = Arc::new(ResultCache::new(64));
        let a = payload(10);
        let b = payload(11);
        for (id, p) in [(1u64, &a), (2, &b)] {
            let (verdict, _rx) = admit(&cache, &metrics, id, p, &opts);
            let Admit::Solve(Some(mut t)) = verdict else { panic!("miss") };
            let Payload::F64(v) = p else { unreachable!() };
            t.complete(&Ok(solved(v, QuantMethod::KMeans, &opts)), ServedBy::Native);
        }
        let (ready, inflight, bytes) = cache.stats();
        assert_eq!(inflight, 0);
        assert!(ready <= 1 && bytes <= 64, "capacity churn evicted the older entry");
        // The survivor (b, most recent) still hits; the evicted key (a)
        // misses and re-reserves — an evicted entry is never served.
        let (vb, _rxb) = admit(&cache, &metrics, 3, &b, &opts);
        assert!(matches!(vb, Admit::Hit));
        let (va, _rxa) = admit(&cache, &metrics, 4, &a, &opts);
        assert!(matches!(va, Admit::Solve(Some(_))));
    }

    #[test]
    fn tenant_partitions_fingerprint_and_verify_disjointly() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let metrics = Arc::new(Metrics::new());
        let data = payload(6);
        let opts = QuantOptions { target_values: 4, ..Default::default() };

        let (va, _rxa) = admit_as(&cache, &metrics, 1, Some("alice"), &data, &opts);
        let Admit::Solve(Some(mut ta)) = va else { panic!("alice leads a miss") };
        let Payload::F64(v) = &data else { unreachable!() };
        ta.complete(&Ok(solved(v, QuantMethod::KMeans, &opts)), ServedBy::Native);

        // Same bytes, other tenant: distinct partition ⇒ a fresh miss.
        let (vb, _rxb) = admit_as(&cache, &metrics, 2, Some("bob"), &data, &opts);
        assert!(matches!(vb, Admit::Solve(Some(_))), "bob must not see alice's entry");
        // The shared (None) partition is distinct from both.
        let (vs, _rxs) = admit_as(&cache, &metrics, 3, None, &data, &opts);
        assert!(matches!(vs, Admit::Solve(Some(_))), "shared partition is its own");
        // Alice herself still hits her own partition.
        let (va2, rxa2) = admit_as(&cache, &metrics, 4, Some("alice"), &data, &opts);
        assert!(matches!(va2, Admit::Hit));
        assert_eq!(rxa2.try_recv().unwrap().served_by, ServedBy::Cache);
    }

    #[test]
    fn weighted_requests_key_disjointly_from_unweighted_and_from_other_weights() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let metrics = Arc::new(Metrics::new());
        let data = payload(7);
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let wn: Vec<f64> = (0..data.len()).map(|i| 0.5 + (i % 5) as f64).collect();

        // Unweighted solve lands in the cache.
        let (v1, _rx1) = admit_weighted(&cache, &metrics, 1, &data, None, &opts);
        let Admit::Solve(Some(mut t1)) = v1 else { panic!("unweighted leader miss") };
        let Payload::F64(v) = &data else { unreachable!() };
        t1.complete(&Ok(solved(v, QuantMethod::KMeans, &opts)), ServedBy::Native);

        // Same bytes with non-uniform weights: a distinct key ⇒ miss.
        let (v2, _rx2) = admit_weighted(&cache, &metrics, 2, &data, Some(&wn), &opts);
        let Admit::Solve(Some(mut t2)) = v2 else {
            panic!("weighted submit must not hit the unweighted entry")
        };
        t2.complete(&Ok(solved(v, QuantMethod::KMeans, &opts)), ServedBy::Native);

        // Exact weighted resubmit hits its own entry.
        let (v3, rx3) = admit_weighted(&cache, &metrics, 3, &data, Some(&wn), &opts);
        assert!(matches!(v3, Admit::Hit), "identical weighted resubmit hits");
        assert_eq!(rx3.try_recv().unwrap().served_by, ServedBy::Cache);

        // One weight bit different ⇒ miss.
        let mut wn2 = wn.clone();
        wn2[0] = f64::from_bits(wn2[0].to_bits() ^ 1);
        let (v4, _rx4) = admit_weighted(&cache, &metrics, 4, &data, Some(&wn2), &opts);
        assert!(matches!(v4, Admit::Solve(Some(_))), "weight bits are part of the key");

        // The unweighted entry is still intact and hit separately.
        let (v5, rx5) = admit_weighted(&cache, &metrics, 5, &data, None, &opts);
        assert!(matches!(v5, Admit::Hit));
        assert_eq!(rx5.try_recv().unwrap().served_by, ServedBy::Cache);
    }

    #[test]
    fn near_identical_keys_do_not_alias() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let metrics = Arc::new(Metrics::new());
        let data = payload(5);
        let opts = QuantOptions { target_values: 4, seed: 1, ..Default::default() };
        let (verdict, _rx) = admit(&cache, &metrics, 1, &data, &opts);
        let Admit::Solve(Some(mut t)) = verdict else { panic!("miss") };
        let Payload::F64(v) = &data else { unreachable!() };
        t.complete(&Ok(solved(v, QuantMethod::KMeans, &opts)), ServedBy::Native);

        // Same data, one option bit different ⇒ distinct key ⇒ miss.
        let opts2 = QuantOptions { seed: 2, ..opts.clone() };
        let (v2, _rx2) = admit(&cache, &metrics, 2, &data, &opts2);
        assert!(matches!(v2, Admit::Solve(Some(_))));
        // Same options, one payload bit different ⇒ miss.
        let Payload::F64(v) = &data else { unreachable!() };
        let mut flipped: Vec<f64> = v.to_vec();
        flipped[0] = f64::from_bits(flipped[0].to_bits() ^ 1);
        let (v3, _rx3) = admit(&cache, &metrics, 3, &Payload::F64(flipped.into()), &opts);
        assert!(matches!(v3, Admit::Solve(Some(_))));
    }
}
