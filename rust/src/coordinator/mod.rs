//! L3 coordinator (S19): job admission with backpressure, batching worker
//! pool, native/runtime routing, metrics. See `server.rs` for the
//! topology diagram.

pub mod cache;
pub mod job;
pub mod metrics;
pub mod queue;
pub mod router;
pub mod server;

pub use cache::{Admit, CacheTicket, ResultCache};
pub use job::{Job, JobId, JobOutput, JobResult, Payload, ServedBy};
pub use metrics::{Metrics, Snapshot};
pub use router::Router;
pub use server::{BackendFactory, Coordinator};
