//! Coordinator metrics: lock-free counters + a log₂ latency histogram.
//!
//! One [`Metrics`] instance is shared by every worker and runtime lane;
//! all updates are single `fetch_add`s (wait-free, `Relaxed` — counters
//! are independent, no cross-counter ordering is promised), so the hot
//! serve path never takes a lock to record. [`Metrics::snapshot`]
//! produces an immutable [`Snapshot`] for reports; under concurrent
//! updates it is a *consistent-enough* read (each counter atomically,
//! not the set), which is the usual tradeoff for serving telemetry.
//!
//! What is tracked, and who records it:
//!
//! * admission — `on_submit` / `on_reject` (the submit front doors);
//! * completion — `on_complete` (ok/failed, latency into the power-of-two
//!   histogram, native-vs-runtime engine), recorded by `finish` in
//!   `server.rs` for every job exactly once;
//! * batching — `on_batch` per drained batch (mean batch size falls out);
//! * result cache — `on_cache_miss` at admission, `on_cache_hit` when a
//!   request completes from the cache (exact hit at admission, or a
//!   parked duplicate drained when its leader finishes) with the compact
//!   bytes and prepare+solve time the hit saved; hits count toward
//!   `completed` and the latency histogram but not `served_native` /
//!   `served_runtime` — no engine ran;
//! * pipeline stages — `on_stage` with the prepare/solve wall times the
//!   compact finalize reports on each item (native lane only; the
//!   runtime lane's phases are artifact calls, not prepare/solve);
//! * degraded lanes — `on_lane_degraded` when a runtime lane's backend
//!   fails to open.
//!
//! Latency percentiles come from the histogram's upper bucket bounds —
//! cheap, monotone, and accurate to a factor of two, which is enough to
//! spot regressions in a serve run's p95/p99.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 32; // 1µs … ~4000s in powers of two

/// Shared metrics sink. All methods are thread-safe and wait-free.
#[derive(Debug, Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    rejected: AtomicU64,
    served_native: AtomicU64,
    served_runtime: AtomicU64,
    batches: AtomicU64,
    batch_jobs: AtomicU64,
    lanes_degraded: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_bytes_saved: AtomicU64,
    cache_solve_saved_us: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
    stage_prepare_ns: AtomicU64,
    stage_solve_ns: AtomicU64,
    stage_samples: AtomicU64,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Jobs accepted into the queue.
    pub submitted: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs that returned an error.
    pub failed: u64,
    /// Jobs refused at admission (queue full, non-blocking submit).
    pub rejected: u64,
    /// Jobs served by the native engine.
    pub served_native: u64,
    /// Jobs served by the PJRT runtime.
    pub served_runtime: u64,
    /// Batches drained by workers.
    pub batches: u64,
    /// Mean batch size.
    pub mean_batch: f64,
    /// Runtime lanes that failed to open their backend and run degraded
    /// (under `Auto` their pops are rerouted to the native engines).
    pub lanes_degraded: u64,
    /// Mean latency (µs).
    pub mean_latency_us: f64,
    /// Approximate latency percentiles (µs): p50, p95, p99.
    pub p50_us: u64,
    /// p95.
    pub p95_us: u64,
    /// p99.
    pub p99_us: u64,
    /// Requests served from the result cache (exact hits + drained
    /// duplicate waiters) — completed without running a solve.
    pub cache_hits: u64,
    /// Requests that missed the result cache (includes admissions while
    /// caching is on that later got shed; disabled caching records
    /// neither hits nor misses).
    pub cache_misses: u64,
    /// Hit rate over cache-visible traffic: hits / (hits + misses).
    pub cache_hit_rate: f64,
    /// Compact result bytes served from cache instead of re-solved.
    pub cache_bytes_saved: u64,
    /// Prepare+solve wall time (µs) the cache saved — the original
    /// solve's stage cost, credited once per hit.
    pub cache_solve_saved_us: u64,
    /// Jobs with recorded per-stage (prepare/solve) timings.
    pub stage_samples: u64,
    /// Mean prepare-stage time (µs) across those jobs.
    pub mean_prepare_us: f64,
    /// Mean solve-stage time (µs) across those jobs.
    pub mean_solve_us: f64,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count an admission.
    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a refused admission.
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a drained batch of `n` jobs.
    pub fn on_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_jobs.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Count a runtime lane whose backend failed to open (the lane keeps
    /// running degraded; see `server::serve_batch_degraded`).
    pub fn on_lane_degraded(&self) {
        self.lanes_degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Record per-stage pipeline timings (prepare vs solve) for one job.
    pub fn on_stage(&self, prepare: Duration, solve: Duration) {
        let p = prepare.as_nanos().min(u64::MAX as u128) as u64;
        let s = solve.as_nanos().min(u64::MAX as u128) as u64;
        self.stage_prepare_ns.fetch_add(p, Ordering::Relaxed);
        self.stage_solve_ns.fetch_add(s, Ordering::Relaxed);
        self.stage_samples.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a completion with its latency and serving engine.
    pub fn on_complete(&self, ok: bool, latency: Duration, runtime: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        if runtime {
            self.served_runtime.fetch_add(1, Ordering::Relaxed);
        } else {
            self.served_native.fetch_add(1, Ordering::Relaxed);
        }
        self.record_latency(latency);
    }

    /// Count a request served from the result cache: a completion with
    /// its own latency, plus the solve work it skipped (`bytes_saved` =
    /// the compact result payload, `solve_saved` = the original solve's
    /// prepare+solve wall time). Neither engine counter moves — no
    /// engine ran.
    pub fn on_cache_hit(&self, bytes_saved: usize, solve_saved: Duration, latency: Duration) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
        self.cache_bytes_saved.fetch_add(bytes_saved as u64, Ordering::Relaxed);
        let saved_us = solve_saved.as_micros().min(u64::MAX as u128) as u64;
        self.cache_solve_saved_us.fetch_add(saved_us, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.record_latency(latency);
    }

    /// Count a request that missed the result cache (it will solve).
    pub fn on_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    fn record_latency(&self, latency: Duration) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate percentile from the histogram (upper bucket bound).
    fn percentile(&self, counts: &[u64; BUCKETS], total: u64, p: f64) -> u64 {
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> Snapshot {
        let mut counts = [0u64; BUCKETS];
        let mut total = 0u64;
        for (c, a) in counts.iter_mut().zip(&self.latency_us) {
            *c = a.load(Ordering::Relaxed);
            total += *c;
        }
        let batches = self.batches.load(Ordering::Relaxed);
        let batch_jobs = self.batch_jobs.load(Ordering::Relaxed);
        let cache_hits = self.cache_hits.load(Ordering::Relaxed);
        let cache_misses = self.cache_misses.load(Ordering::Relaxed);
        let stage_samples = self.stage_samples.load(Ordering::Relaxed);
        let stage_mean_us = |total_ns: &AtomicU64| {
            if stage_samples > 0 {
                total_ns.load(Ordering::Relaxed) as f64 / stage_samples as f64 / 1000.0
            } else {
                0.0
            }
        };
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            served_native: self.served_native.load(Ordering::Relaxed),
            served_runtime: self.served_runtime.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches > 0 { batch_jobs as f64 / batches as f64 } else { 0.0 },
            lanes_degraded: self.lanes_degraded.load(Ordering::Relaxed),
            mean_latency_us: if total > 0 {
                self.latency_sum_us.load(Ordering::Relaxed) as f64 / total as f64
            } else {
                0.0
            },
            p50_us: self.percentile(&counts, total, 0.50),
            p95_us: self.percentile(&counts, total, 0.95),
            p99_us: self.percentile(&counts, total, 0.99),
            cache_hits,
            cache_misses,
            cache_hit_rate: if cache_hits + cache_misses > 0 {
                cache_hits as f64 / (cache_hits + cache_misses) as f64
            } else {
                0.0
            },
            cache_bytes_saved: self.cache_bytes_saved.load(Ordering::Relaxed),
            cache_solve_saved_us: self.cache_solve_saved_us.load(Ordering::Relaxed),
            stage_samples,
            mean_prepare_us: stage_mean_us(&self.stage_prepare_ns),
            mean_solve_us: stage_mean_us(&self.stage_solve_ns),
        }
    }
}

impl Snapshot {
    /// JSON form of the snapshot — the final-metrics payload the network
    /// server emits on graceful drain, and the shape `BENCH_serve_load`
    /// embeds. Counters become JSON numbers (all counters here fit f64's
    /// 2⁵³ integer range in any realistic run).
    pub fn to_json(&self) -> crate::jsonio::Json {
        use crate::jsonio::Json;
        Json::obj(vec![
            ("submitted", Json::Num(self.submitted as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("served_native", Json::Num(self.served_native as f64)),
            ("served_runtime", Json::Num(self.served_runtime as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("mean_batch", Json::Num(self.mean_batch)),
            ("lanes_degraded", Json::Num(self.lanes_degraded as f64)),
            ("mean_latency_us", Json::Num(self.mean_latency_us)),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p95_us", Json::Num(self.p95_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("cache_hit_rate", Json::Num(self.cache_hit_rate)),
            ("cache_bytes_saved", Json::Num(self.cache_bytes_saved as f64)),
            ("cache_solve_saved_us", Json::Num(self.cache_solve_saved_us as f64)),
            ("stage_samples", Json::Num(self.stage_samples as f64)),
            ("mean_prepare_us", Json::Num(self.mean_prepare_us)),
            ("mean_solve_us", Json::Num(self.mean_solve_us)),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} rejected={} native={} runtime={} \
             batches={} mean_batch={:.1} degraded_lanes={} \
             cache(hit/miss)={}/{} cache_rate={:.2} saved={}B/{}µs \
             lat(mean/p50/p95/p99 µs)={:.0}/{}/{}/{} \
             stages(prep/solve mean µs)={:.1}/{:.1}",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            self.served_native,
            self.served_runtime,
            self.batches,
            self.mean_batch,
            self.lanes_degraded,
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate,
            self.cache_bytes_saved,
            self.cache_solve_saved_us,
            self.mean_latency_us,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_prepare_us,
            self.mean_solve_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_batch(2);
        m.on_complete(true, Duration::from_micros(100), false);
        m.on_complete(false, Duration::from_micros(300), true);
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.served_native, 1);
        assert_eq!(s.served_runtime, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        assert!((s.mean_latency_us - 200.0).abs() < 1e-9);
        assert_eq!(s.lanes_degraded, 0);
        m.on_lane_degraded();
        assert_eq!(m.snapshot().lanes_degraded, 1);
        assert!(m.snapshot().summary().contains("degraded_lanes=1"));
    }

    #[test]
    fn stage_timings_average() {
        let m = Metrics::new();
        m.on_stage(Duration::from_micros(10), Duration::from_micros(90));
        m.on_stage(Duration::from_micros(30), Duration::from_micros(110));
        let s = m.snapshot();
        assert_eq!(s.stage_samples, 2);
        assert!((s.mean_prepare_us - 20.0).abs() < 1e-9);
        assert!((s.mean_solve_us - 100.0).abs() < 1e-9);
        assert!(s.summary().contains("stages("));
    }

    #[test]
    fn cache_counters_accumulate_without_touching_engine_counters() {
        let m = Metrics::new();
        m.on_cache_miss();
        m.on_cache_hit(120, Duration::from_micros(900), Duration::from_micros(4));
        m.on_cache_hit(120, Duration::from_micros(900), Duration::from_micros(6));
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 2);
        assert_eq!(s.cache_misses, 1);
        assert!((s.cache_hit_rate - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.cache_bytes_saved, 240);
        assert_eq!(s.cache_solve_saved_us, 1800);
        // Hits complete without an engine: completed moves, served_* do
        // not, and the hit latencies land in the histogram.
        assert_eq!(s.completed, 2);
        assert_eq!(s.served_native, 0);
        assert_eq!(s.served_runtime, 0);
        assert!((s.mean_latency_us - 5.0).abs() < 1e-9);
        assert!(s.summary().contains("cache(hit/miss)=2/1"));
        // Zero traffic ⇒ rate 0, not NaN.
        assert_eq!(Metrics::new().snapshot().cache_hit_rate, 0.0);
    }

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.on_complete(true, Duration::from_micros(i + 1), false);
        }
        let s = m.snapshot();
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us);
        assert!(s.p50_us >= 256 && s.p50_us <= 1024, "p50={}", s.p50_us);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.mean_latency_us, 0.0);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn snapshot_to_json_round_trips_the_counters() {
        let m = Metrics::new();
        m.on_submit();
        m.on_complete(true, Duration::from_micros(100), false);
        let s = m.snapshot();
        let j = s.to_json();
        let parsed = crate::jsonio::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("submitted").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(parsed.get("completed").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(
            parsed.get("p50_us").and_then(|v| v.as_f64()),
            Some(s.p50_us as f64)
        );
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.on_submit();
                        m.on_complete(true, Duration::from_micros(50), false);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.submitted, 8000);
        assert_eq!(s.completed, 8000);
    }
}
