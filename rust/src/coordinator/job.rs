//! Job types flowing through the coordinator.

use crate::quant::{Codebook, Precision, QuantMethod, QuantOptions, QuantOutput};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Monotonically increasing job identifier.
pub type JobId = u64;

/// A quantization payload in its submitted precision, behind shared
/// storage: submitting an owned `Vec` moves the buffer once into the
/// `Arc`, and an already-shared request input ([`crate::quant::api`])
/// enters the serve path with **zero** copies — the prepare stage reads
/// the same allocation the client holds.
///
/// f32 payloads are served by the native f32 lane end to end — no up-front
/// widening at admission or dispatch; only the final per-level output is
/// widened into the f64 [`QuantOutput`] result surface. The runtime (PJRT)
/// lane's boundary is f64, so f32 payloads always route native.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Double-precision data (the historical submit path).
    F64(Arc<[f64]>),
    /// Single-precision data (NN-weight fast path).
    F32(Arc<[f32]>),
}

impl Payload {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Payload::F64(v) => v.len(),
            Payload::F32(v) => v.len(),
        }
    }

    /// True when the payload holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The payload's lane.
    pub fn precision(&self) -> Precision {
        match self {
            Payload::F64(_) => Precision::F64,
            Payload::F32(_) => Precision::F32,
        }
    }

    /// Widen to f64 (the runtime-lane boundary; a copy for f64 payloads).
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match self {
            Payload::F64(v) => v.to_vec(),
            Payload::F32(v) => v.iter().map(|&x| f64::from(x)).collect(),
        }
    }
}

impl Default for Payload {
    fn default() -> Self {
        Payload::F64(Vec::new().into())
    }
}

impl From<Vec<f64>> for Payload {
    fn from(v: Vec<f64>) -> Self {
        Payload::F64(v.into())
    }
}

impl From<Vec<f32>> for Payload {
    fn from(v: Vec<f32>) -> Self {
        Payload::F32(v.into())
    }
}

impl From<Arc<[f64]>> for Payload {
    fn from(v: Arc<[f64]>) -> Self {
        Payload::F64(v)
    }
}

impl From<Arc<[f32]>> for Payload {
    fn from(v: Arc<[f32]>) -> Self {
        Payload::F32(v)
    }
}

/// Which engine actually served a job (reported in results/metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServedBy {
    /// Pure-Rust native engine.
    Native,
    /// AOT artifact on the PJRT runtime.
    Runtime,
}

impl ServedBy {
    /// Stable label for reports.
    pub fn label(self) -> &'static str {
        match self {
            ServedBy::Native => "native",
            ServedBy::Runtime => "runtime",
        }
    }
}

/// A quantization request.
#[derive(Debug)]
pub struct Job {
    /// Unique id.
    pub id: JobId,
    /// The vector to quantize, in its submitted precision.
    pub data: Payload,
    /// Algorithm to run.
    pub method: QuantMethod,
    /// Algorithm options.
    pub opts: QuantOptions,
    /// Submission timestamp (for queue + service latency).
    pub submitted: Instant,
    /// Response channel (capacity 1).
    pub respond: mpsc::Sender<JobResult>,
}

/// A completed (or failed) job.
#[derive(Debug)]
pub struct JobResult {
    /// The job id.
    pub id: JobId,
    /// Quantization output or error text.
    pub outcome: Result<QuantOutput, String>,
    /// Submit-to-complete latency.
    pub latency: Duration,
    /// Engine that served the job.
    pub served_by: ServedBy,
}

impl JobResult {
    /// True when the job succeeded.
    pub fn is_ok(&self) -> bool {
        self.outcome.is_ok()
    }

    /// Compact view of a successful outcome: the codebook (levels + `u32`
    /// indices) — the wire format a serving edge ships instead of the
    /// full-length vector. `None` when the job failed.
    ///
    /// Derived from the full values at the response edge — a fresh
    /// O(n log n) sort per call, not cached — because the job result
    /// still carries the full vector (the runtime/PJRT lane's boundary is
    /// full-length f64). Call it once per result; carrying the native
    /// lane's already-built codebook through `JobResult` is a recorded
    /// ROADMAP follow-up.
    pub fn codebook(&self) -> Option<Codebook> {
        let out = self.outcome.as_ref().ok()?;
        Codebook::from_output(out).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn served_by_labels() {
        assert_eq!(ServedBy::Native.label(), "native");
        assert_eq!(ServedBy::Runtime.label(), "runtime");
    }

    #[test]
    fn job_result_codebook_is_compact() {
        let res = JobResult {
            id: 1,
            outcome: Ok(QuantOutput {
                values: vec![1.0, 2.0, 1.0],
                levels: vec![1.0, 2.0],
                l2_loss: 0.0,
                clamped: 0,
                diag: Default::default(),
            }),
            latency: Duration::ZERO,
            served_by: ServedBy::Native,
        };
        let cb = res.codebook().expect("ok outcome has a codebook");
        assert_eq!(cb.levels, vec![1.0, 2.0]);
        assert_eq!(cb.indices, vec![0, 1, 0]);
        let failed = JobResult {
            id: 2,
            outcome: Err("boom".into()),
            latency: Duration::ZERO,
            served_by: ServedBy::Native,
        };
        assert!(failed.codebook().is_none());
    }

    #[test]
    fn payload_precision_and_len() {
        let p64: Payload = vec![1.0f64, 2.0].into();
        let p32: Payload = vec![1.0f32, 2.0, 3.0].into();
        assert_eq!(p64.precision(), Precision::F64);
        assert_eq!(p32.precision(), Precision::F32);
        assert_eq!(p64.len(), 2);
        assert_eq!(p32.len(), 3);
        assert!(!p64.is_empty());
        assert!(Payload::default().is_empty());
        assert_eq!(p32.to_f64_vec(), vec![1.0f64, 2.0, 3.0]);
        assert_eq!(p64.to_f64_vec(), vec![1.0f64, 2.0]);
    }
}
