//! Job types flowing through the coordinator.

use crate::quant::{QuantMethod, QuantOptions, QuantOutput};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Monotonically increasing job identifier.
pub type JobId = u64;

/// Which engine actually served a job (reported in results/metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServedBy {
    /// Pure-Rust native engine.
    Native,
    /// AOT artifact on the PJRT runtime.
    Runtime,
}

impl ServedBy {
    /// Stable label for reports.
    pub fn label(self) -> &'static str {
        match self {
            ServedBy::Native => "native",
            ServedBy::Runtime => "runtime",
        }
    }
}

/// A quantization request.
#[derive(Debug)]
pub struct Job {
    /// Unique id.
    pub id: JobId,
    /// The vector to quantize.
    pub data: Vec<f64>,
    /// Algorithm to run.
    pub method: QuantMethod,
    /// Algorithm options.
    pub opts: QuantOptions,
    /// Submission timestamp (for queue + service latency).
    pub submitted: Instant,
    /// Response channel (capacity 1).
    pub respond: mpsc::Sender<JobResult>,
}

/// A completed (or failed) job.
#[derive(Debug)]
pub struct JobResult {
    /// The job id.
    pub id: JobId,
    /// Quantization output or error text.
    pub outcome: Result<QuantOutput, String>,
    /// Submit-to-complete latency.
    pub latency: Duration,
    /// Engine that served the job.
    pub served_by: ServedBy,
}

impl JobResult {
    /// True when the job succeeded.
    pub fn is_ok(&self) -> bool {
        self.outcome.is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn served_by_labels() {
        assert_eq!(ServedBy::Native.label(), "native");
        assert_eq!(ServedBy::Runtime.label(), "runtime");
    }
}
