//! Job types flowing through the coordinator.
//!
//! The serve path is **codebook-native**: a completed job's result
//! ([`JobOutput`], inside [`JobResult::outcome`]) holds the compact
//! lane-erased [`quant::Item`] — a [`Codebook`] of shared levels plus one
//! `u32` index per element — not a materialized full-length vector. The
//! heavy-traffic lane therefore moves O(n·u32 + k·levels) per job instead
//! of O(n·f64); full vectors exist only where an edge explicitly asks
//! ([`JobOutput::materialize`] / [`JobOutput::into_output64`], an O(n)
//! table lookup). Compression accounting rides along
//! ([`JobOutput::compression`]).

use crate::quant::{
    self, Codebook, CompressionStats, Precision, QuantDiag, QuantMethod, QuantOptions,
    QuantOutput,
};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Monotonically increasing job identifier.
pub type JobId = u64;

/// A quantization payload in its submitted precision, behind shared
/// storage: submitting an owned `Vec` moves the buffer once into the
/// `Arc`, and an already-shared request input ([`crate::quant::api`])
/// enters the serve path with **zero** copies — the prepare stage reads
/// the same allocation the client holds.
///
/// f32 payloads are served by the native f32 lane end to end — no up-front
/// widening at admission or dispatch; only the final per-level output is
/// widened into the f64 [`QuantOutput`] result surface. The runtime (PJRT)
/// lane's boundary is f64, so f32 payloads always route native.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Double-precision data (the historical submit path).
    F64(Arc<[f64]>),
    /// Single-precision data (NN-weight fast path).
    F32(Arc<[f32]>),
}

impl Payload {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Payload::F64(v) => v.len(),
            Payload::F32(v) => v.len(),
        }
    }

    /// True when the payload holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The payload's lane.
    pub fn precision(&self) -> Precision {
        match self {
            Payload::F64(_) => Precision::F64,
            Payload::F32(_) => Precision::F32,
        }
    }

    /// Widen to f64 (the runtime-lane boundary; a copy for f64 payloads).
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match self {
            Payload::F64(v) => v.to_vec(),
            Payload::F32(v) => v.iter().map(|&x| f64::from(x)).collect(),
        }
    }
}

impl Default for Payload {
    fn default() -> Self {
        Payload::F64(Vec::new().into())
    }
}

impl From<Vec<f64>> for Payload {
    fn from(v: Vec<f64>) -> Self {
        Payload::F64(v.into())
    }
}

impl From<Vec<f32>> for Payload {
    fn from(v: Vec<f32>) -> Self {
        Payload::F32(v.into())
    }
}

impl From<Arc<[f64]>> for Payload {
    fn from(v: Arc<[f64]>) -> Self {
        Payload::F64(v)
    }
}

impl From<Arc<[f32]>> for Payload {
    fn from(v: Arc<[f32]>) -> Self {
        Payload::F32(v)
    }
}

/// Which engine actually served a job (reported in results/metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServedBy {
    /// Pure-Rust native engine.
    Native,
    /// AOT artifact on the PJRT runtime.
    Runtime,
    /// The serve-path result cache: no engine ran — a previously solved
    /// identical request's compact item was returned
    /// ([`super::cache::ResultCache`]).
    Cache,
}

impl ServedBy {
    /// Stable label for reports.
    pub fn label(self) -> &'static str {
        match self {
            ServedBy::Native => "native",
            ServedBy::Runtime => "runtime",
            ServedBy::Cache => "cache",
        }
    }
}

/// A quantization request.
#[derive(Debug)]
pub struct Job {
    /// Unique id.
    pub id: JobId,
    /// The vector to quantize, in its submitted precision.
    pub data: Payload,
    /// Algorithm to run.
    pub method: QuantMethod,
    /// Algorithm options.
    pub opts: QuantOptions,
    /// Per-element importance weights, already normalized by admission
    /// (validated against the payload length; uniform vectors dropped to
    /// `None` so they serve — and cache — exactly as unweighted jobs).
    /// Weighted jobs always run on the native lane.
    pub weights: Option<Arc<[f64]>>,
    /// Submission timestamp (for queue + service latency).
    pub submitted: Instant,
    /// Response channel (capacity 1).
    pub respond: mpsc::Sender<JobResult>,
    /// Result-cache leader ticket, when this job's admission reserved a
    /// cache slot: `server::finish` completes it (publishing the compact
    /// result and draining duplicate submitters); dropping the job
    /// without finishing cancels the reservation so duplicates fail
    /// instead of hanging. `None` when caching is off or the request
    /// bypassed the cache.
    pub cache: Option<super::cache::CacheTicket>,
}

/// A successful job's result payload: the compact lane-erased item the
/// engine produced, plus the level count the job requested (for
/// achieved-vs-requested compression accounting).
///
/// This is the codebook-native form — no materialized full vector. Edges
/// that need one call [`JobOutput::materialize`] (an O(n) decode), or
/// [`JobOutput::into_output64`] for the full legacy [`QuantOutput`]
/// surface; both are bitwise-identical to what the pre-compact serve path
/// returned.
#[derive(Debug, Clone)]
pub struct JobOutput {
    item: quant::Item,
    levels_requested: usize,
}

impl JobOutput {
    /// Wrap an engine result with the job's requested level count.
    pub(crate) fn new(item: quant::Item, levels_requested: usize) -> JobOutput {
        JobOutput { item, levels_requested }
    }

    /// The compact lane-erased result (codebook + indices, loss, diag,
    /// stage timings).
    pub fn item(&self) -> &quant::Item {
        &self.item
    }

    /// The compact wire payload on the f64 surface (f32 levels widen;
    /// indices are shared unchanged). Cheap: the codebook was built by
    /// the engine's finalize — no per-call re-derivation.
    pub fn codebook(&self) -> Codebook {
        self.item.codebook_f64()
    }

    /// Materialize the full-length quantized vector on the f64 surface —
    /// the lazy **edge** operation (O(n) table lookup through the
    /// codebook). The serve path itself never does this.
    pub fn materialize(&self) -> Vec<f64> {
        self.item.materialize_f64()
    }

    /// Convert into the legacy full-vector [`QuantOutput`] (materializes;
    /// f32 results widen exactly as the historical result surface did).
    pub fn into_output64(self) -> QuantOutput {
        self.item.into_output64()
    }

    /// Squared-l2 information loss (lane input, accumulated in f64).
    pub fn l2_loss(&self) -> f64 {
        self.item.l2_loss()
    }

    /// Number of values moved by the hard-sigmoid clamp.
    pub fn clamped(&self) -> usize {
        self.item.clamped()
    }

    /// Solver diagnostics.
    pub fn diag(&self) -> &QuantDiag {
        self.item.diag()
    }

    /// Achieved number of distinct values.
    pub fn distinct_values(&self) -> usize {
        self.item.distinct_values()
    }

    /// The lane the job was served on.
    pub fn precision(&self) -> Precision {
        self.item.precision()
    }

    /// The level count the job requested (`QuantOptions::target_values`).
    pub fn levels_requested(&self) -> usize {
        self.levels_requested
    }

    /// Compression accounting for this result (bits/value, index entropy,
    /// achieved-vs-requested levels, compact-vs-dense bytes).
    pub fn compression(&self) -> CompressionStats {
        self.item.compression(self.levels_requested)
    }
}

/// A completed (or failed) job.
#[derive(Debug)]
pub struct JobResult {
    /// The job id.
    pub id: JobId,
    /// Compact quantization result or error text.
    pub outcome: Result<JobOutput, String>,
    /// Submit-to-complete latency.
    pub latency: Duration,
    /// Engine that served the job.
    pub served_by: ServedBy,
}

impl JobResult {
    /// True when the job succeeded.
    pub fn is_ok(&self) -> bool {
        self.outcome.is_ok()
    }

    /// Compact view of a successful outcome: the codebook (levels + `u32`
    /// indices) — the wire form a serving edge ships instead of the
    /// full-length vector. `None` when the job failed.
    ///
    /// Since the codebook-native refactor this is a cheap accessor over
    /// the stored compact item (the engine finalize built it); the old
    /// derive-at-edge O(n log n) re-encode is gone.
    pub fn codebook(&self) -> Option<Codebook> {
        Some(self.outcome.as_ref().ok()?.codebook())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn served_by_labels() {
        assert_eq!(ServedBy::Native.label(), "native");
        assert_eq!(ServedBy::Runtime.label(), "runtime");
        assert_eq!(ServedBy::Cache.label(), "cache");
    }

    #[test]
    fn job_result_codebook_is_compact_and_materializes_at_the_edge() {
        use crate::quant::{QuantMethod, QuantRequest, Quantizer};
        let data = vec![1.0, 2.0, 1.0, 2.0, 1.0];
        let req = QuantRequest::vector(data.clone())
            .method(QuantMethod::KMeans)
            .target_count(2);
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        let res = JobResult {
            id: 1,
            outcome: Ok(JobOutput::new(item, 2)),
            latency: Duration::ZERO,
            served_by: ServedBy::Native,
        };
        let cb = res.codebook().expect("ok outcome has a codebook");
        assert_eq!(cb.levels, vec![1.0, 2.0]);
        assert_eq!(cb.indices, vec![0, 1, 0, 1, 0]);
        let out = res.outcome.as_ref().unwrap();
        assert_eq!(out.materialize(), data, "edge decode reproduces the vector");
        assert_eq!(out.distinct_values(), 2);
        assert_eq!(out.levels_requested(), 2);
        let stats = out.compression();
        assert_eq!(stats.levels_achieved, 2);
        assert_eq!(stats.levels_requested, 2);
        assert_eq!(stats.n, data.len());
        let legacy = res.outcome.unwrap().into_output64();
        assert_eq!(legacy.values, data);
        let failed = JobResult {
            id: 2,
            outcome: Err("boom".into()),
            latency: Duration::ZERO,
            served_by: ServedBy::Native,
        };
        assert!(failed.codebook().is_none());
    }

    #[test]
    fn payload_precision_and_len() {
        let p64: Payload = vec![1.0f64, 2.0].into();
        let p32: Payload = vec![1.0f32, 2.0, 3.0].into();
        assert_eq!(p64.precision(), Precision::F64);
        assert_eq!(p32.precision(), Precision::F32);
        assert_eq!(p64.len(), 2);
        assert_eq!(p32.len(), 3);
        assert!(!p64.is_empty());
        assert!(Payload::default().is_empty());
        assert_eq!(p32.to_f64_vec(), vec![1.0f64, 2.0, 3.0]);
        assert_eq!(p64.to_f64_vec(), vec![1.0f64, 2.0]);
    }
}
