//! Bounded MPMC job queue with blocking backpressure and batch drain.
//!
//! tokio is unavailable offline (DESIGN §2); this is the std-only
//! equivalent the coordinator needs: a `Mutex<VecDeque>` + two `Condvar`s.
//! `push` blocks when full (backpressure), `try_push` refuses instead,
//! `pop_batch` waits for the first item then drains up to `max` — the
//! batcher in one primitive.
//!
//! Design notes, in serve-path terms:
//!
//! * **Backpressure vs shedding** is the *caller's* choice, not the
//!   queue's: `Coordinator::submit_request` uses the blocking
//!   [`BoundedQueue::push`] (a full queue slows producers down),
//!   `try_submit_request` uses [`BoundedQueue::try_push`] and turns
//!   [`TryPush::Full`] into a counted rejection (load shedding).
//! * **Batching lives in the pop**: [`BoundedQueue::pop_batch`] waits up
//!   to `first_wait` for one item, then lingers at most `fill_wait`
//!   (`Config::batch_wait_us`) for stragglers so bursts of small jobs
//!   pay one worker wakeup. A returned batch is never empty, even with
//!   multiple consumers racing through the linger window.
//! * **Shutdown is drain-then-stop**: [`BoundedQueue::close`] makes
//!   producers fail fast while consumers keep popping until the queue is
//!   empty, which is what lets `Coordinator::shutdown` complete every
//!   admitted job. Items are moved, never cloned or dropped — the
//!   property-tested invariant (`tests/property_queue.rs`: no loss, no
//!   duplication under concurrent submit/drain).
//!
//! The queue is payload-agnostic; since the codebook-native refactor the
//! jobs it carries hold `Arc`-shared inputs on the way in and compact
//! codebook results on the way out, so nothing here ever copies vector
//! data.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Result of a non-blocking push.
#[derive(Debug, PartialEq, Eq)]
pub enum TryPush<T> {
    /// Accepted.
    Ok,
    /// Queue full — value returned to the caller.
    Full(T),
    /// Queue closed — value returned to the caller.
    Closed(T),
}

struct Inner<T> {
    deque: VecDeque<T>,
    capacity: usize,
    closed: bool,
}

/// Bounded blocking queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> BoundedQueue<T> {
    /// Create with the given capacity (≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        BoundedQueue {
            inner: Mutex::new(Inner { deque: VecDeque::new(), capacity, closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking push; returns `false` if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return false;
            }
            if g.deque.len() < g.capacity {
                g.deque.push_back(item);
                self.not_empty.notify_one();
                return true;
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking push.
    pub fn try_push(&self, item: T) -> TryPush<T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return TryPush::Closed(item);
        }
        if g.deque.len() >= g.capacity {
            return TryPush::Full(item);
        }
        g.deque.push_back(item);
        self.not_empty.notify_one();
        TryPush::Ok
    }

    /// Wait (bounded by `first_wait`) for at least one item, then drain up
    /// to `max` items, waiting at most `fill_wait` more for stragglers.
    /// Returns `None` once the queue is closed *and* empty; a returned
    /// batch is never empty (`1 ≤ len ≤ max`), even with multiple
    /// consumers racing through the linger window.
    pub fn pop_batch(
        &self,
        max: usize,
        first_wait: Duration,
        fill_wait: Duration,
    ) -> Option<Vec<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            // Phase 1: wait for the first item.
            while g.deque.is_empty() {
                if g.closed {
                    return None;
                }
                let (ng, timeout) = self.not_empty.wait_timeout(g, first_wait).unwrap();
                g = ng;
                if timeout.timed_out() && g.deque.is_empty() {
                    if g.closed {
                        return None;
                    }
                    // Spurious/empty timeout: keep waiting (callers loop).
                    continue;
                }
            }
            // Phase 2: optionally linger to fill the batch.
            if g.deque.len() < max && !fill_wait.is_zero() && !g.closed {
                let (ng, _) = self.not_empty.wait_timeout(g, fill_wait).unwrap();
                g = ng;
                // Another consumer may have drained everything while we
                // lingered (the wait releases the lock): go back to
                // waiting instead of serving an empty batch.
                if g.deque.is_empty() {
                    continue;
                }
            }
            let take = g.deque.len().min(max);
            let batch: Vec<T> = g.deque.drain(..take).collect();
            if !batch.is_empty() {
                self.not_full.notify_all();
            }
            return Some(batch);
        }
    }

    /// Current depth (diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().deque.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: producers fail fast, consumers drain what remains.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    const SHORT: Duration = Duration::from_millis(20);

    #[test]
    fn push_pop_roundtrip() {
        let q = BoundedQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        let batch = q.pop_batch(10, SHORT, Duration::ZERO).unwrap();
        assert_eq!(batch, vec![1, 2]);
    }

    #[test]
    fn try_push_full() {
        let q = BoundedQueue::new(1);
        assert_eq!(q.try_push(1), TryPush::Ok);
        assert_eq!(q.try_push(2), TryPush::Full(2));
    }

    #[test]
    fn close_rejects_producers_drains_consumers() {
        let q = BoundedQueue::new(4);
        q.push(1);
        q.close();
        assert!(!q.push(2));
        assert_eq!(q.try_push(3), TryPush::Closed(3));
        assert_eq!(q.pop_batch(10, SHORT, Duration::ZERO), Some(vec![1]));
        assert_eq!(q.pop_batch(10, SHORT, Duration::ZERO), None);
    }

    #[test]
    fn batch_respects_max() {
        let q = BoundedQueue::new(10);
        for i in 0..7 {
            q.push(i);
        }
        let b = q.pop_batch(3, SHORT, Duration::ZERO).unwrap();
        assert_eq!(b, vec![0, 1, 2]);
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn blocking_push_unblocks_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0);
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            let start = Instant::now();
            assert!(q2.push(1)); // blocks until the consumer drains
            start.elapsed()
        });
        std::thread::sleep(Duration::from_millis(30));
        let b = q.pop_batch(1, SHORT, Duration::ZERO).unwrap();
        assert_eq!(b, vec![0]);
        let waited = t.join().unwrap();
        assert!(waited >= Duration::from_millis(20), "push did not block ({waited:?})");
    }

    #[test]
    fn concurrent_producers_consumers_preserve_items() {
        let q = Arc::new(BoundedQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..100 {
                        assert!(q.push(p * 1000 + i));
                    }
                })
            })
            .collect();
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) =
                    q.pop_batch(16, Duration::from_millis(100), Duration::ZERO)
                {
                    seen.extend(batch);
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen.len(), 400);
        seen.dedup();
        assert_eq!(seen.len(), 400, "duplicate or lost items");
    }
}
