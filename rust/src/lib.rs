//! # sqlsq — Scalar Quantization as Sparse Least Square Optimization
//!
//! Full-system reproduction of Wang et al., *"Scalar Quantization as Sparse
//! Least Square Optimization"* (2018). The library recasts scalar
//! quantization — replacing the `m` distinct values of a vector with `p ≤ m`
//! shared values — as sparse least-square optimization over a structured
//! lower-triangular difference basis `V`, and implements:
//!
//! * the paper's algorithms: `l1` LASSO quantization (eq 6), `l1` + exact
//!   least-square refit (Algorithm 1), `l1 + negative-l2` relaxation
//!   (eq 13/15), `l0` best-subset quantization (eq 16), iterative-`λ`
//!   quantization to a target value count (Algorithm 2), and cluster-based
//!   least-square quantization (Algorithm 3);
//! * every baseline the paper compares against: k-means (Lloyd + k-means++ +
//!   restarts), Mixture-of-Gaussians (EM) quantization, and the
//!   data-transformation clustering of Azimi et al. (2017);
//! * every substrate the experiments need: a dense-linalg kernel set, a
//!   deterministic RNG + the paper's three synthetic data distributions, a
//!   procedural digit-image corpus (MNIST substitute), and a from-scratch
//!   MLP (784-256-128-64-10) with an SGD trainer;
//! * the serving layer: a PJRT runtime that loads AOT-compiled JAX/Pallas
//!   artifacts (HLO text) and a thread-pool coordinator with batching,
//!   routing, backpressure and metrics;
//! * the evaluation harness regenerating every figure of the paper (§4).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod bench_support;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod jsonio;
pub mod linalg;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod testkit;

/// Library-wide error type. Display/Error are implemented by hand —
/// thiserror (like every other external crate) is unavailable offline
/// (DESIGN §2), and the build must be dependency-free.
#[derive(Debug)]
pub enum Error {
    /// Input vector was empty or otherwise unusable.
    InvalidInput(String),
    /// An algorithm parameter was out of its valid domain.
    InvalidParam(String),
    /// An iterative solver failed to converge within its budget.
    NoConvergence(String),
    /// A linear system was singular / not positive definite.
    Linalg(String),
    /// PJRT / artifact runtime failure.
    Runtime(String),
    /// Coordinator failure (worker panicked, malformed request, ...).
    Coordinator(String),
    /// Admission refused because a bounded queue is full — transient
    /// backpressure. Retryable: the network front end maps this to a
    /// SHED response with a retry-after hint, never a hard failure.
    Saturated(String),
    /// The coordinator is draining or closed — permanent for this
    /// handle. The network front end maps this to connection refusal.
    Shutdown(String),
    /// Configuration / CLI parsing failure.
    Config(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidInput(m) => write!(f, "invalid input: {m}"),
            Error::InvalidParam(m) => write!(f, "invalid parameter: {m}"),
            Error::NoConvergence(m) => write!(f, "no convergence: {m}"),
            Error::Linalg(m) => write!(f, "linear algebra failure: {m}"),
            Error::Runtime(m) => write!(f, "runtime failure: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator failure: {m}"),
            Error::Saturated(m) => write!(f, "saturated: {m}"),
            Error::Shutdown(m) => write!(f, "shutting down: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
