//! # sqlsq — Scalar Quantization as Sparse Least Square Optimization
//!
//! Full-system reproduction of Wang et al., *"Scalar Quantization as Sparse
//! Least Square Optimization"* (2018). The library recasts scalar
//! quantization — replacing the `m` distinct values of a vector with `p ≤ m`
//! shared values — as sparse least-square optimization over a structured
//! lower-triangular difference basis `V`, and implements:
//!
//! * the paper's algorithms: `l1` LASSO quantization (eq 6), `l1` + exact
//!   least-square refit (Algorithm 1), `l1 + negative-l2` relaxation
//!   (eq 13/15), `l0` best-subset quantization (eq 16), iterative-`λ`
//!   quantization to a target value count (Algorithm 2), and cluster-based
//!   least-square quantization (Algorithm 3);
//! * every baseline the paper compares against: k-means (Lloyd + k-means++ +
//!   restarts), Mixture-of-Gaussians (EM) quantization, and the
//!   data-transformation clustering of Azimi et al. (2017);
//! * every substrate the experiments need: a dense-linalg kernel set, a
//!   deterministic RNG + the paper's three synthetic data distributions, a
//!   procedural digit-image corpus (MNIST substitute), and a from-scratch
//!   MLP (784-256-128-64-10) with an SGD trainer;
//! * the serving layer: a PJRT runtime that loads AOT-compiled JAX/Pallas
//!   artifacts (HLO text) and a thread-pool coordinator with batching,
//!   routing, backpressure and metrics;
//! * the evaluation harness regenerating every figure of the paper (§4).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod bench_support;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod jsonio;
pub mod linalg;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod testkit;

/// Library-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Input vector was empty or otherwise unusable.
    #[error("invalid input: {0}")]
    InvalidInput(String),
    /// An algorithm parameter was out of its valid domain.
    #[error("invalid parameter: {0}")]
    InvalidParam(String),
    /// An iterative solver failed to converge within its budget.
    #[error("no convergence: {0}")]
    NoConvergence(String),
    /// A linear system was singular / not positive definite.
    #[error("linear algebra failure: {0}")]
    Linalg(String),
    /// PJRT / artifact runtime failure.
    #[error("runtime failure: {0}")]
    Runtime(String),
    /// Coordinator failure (queue closed, worker panicked, ...).
    #[error("coordinator failure: {0}")]
    Coordinator(String),
    /// Configuration / CLI parsing failure.
    #[error("config error: {0}")]
    Config(String),
    /// I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
