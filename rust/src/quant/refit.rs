//! Least-square refit on the LASSO support (paper eq 7–10).
//!
//! After the l1 stage selects a support `S = {j : α_j ≠ 0}`, Algorithm 1
//! re-solves the unpenalized least squares restricted to the support
//! columns `V*` (eq 8), analytically via the normal equations (eq 9), and
//! scatters the result back into a full-length α* (eq 10).
//!
//! ## The O(m) fast path
//!
//! `V_S β` is a piecewise-constant vector whose level can only change at
//! support indices. Minimizing `‖ŵ − V_S β‖²` over β is therefore exactly
//! the problem of choosing one constant per segment:
//!
//! * segment `[0, s_0)` is pinned at level 0 (no column covers it),
//! * each segment `[s_t, s_{t+1})` takes its free level — optimally the
//!   (weighted) mean of `ŵ` over the segment.
//!
//! This closed form costs O(m) and is algebraically identical to the
//! normal-equation solve; [`refit_normal_eq`] keeps the paper's explicit
//! eq 9 path as the oracle, and the two are cross-checked in tests and in
//! the property suite.

use super::vmatrix::VBasis;
use crate::linalg::cholesky::least_squares;
use crate::linalg::kernels;
use crate::linalg::scalar::Scalar;
use crate::{Error, Result};

/// Result of a support refit (lane-generic; `Refit<f64>` is the default).
#[derive(Debug, Clone)]
pub struct Refit<T: Scalar = f64> {
    /// Full-length α* (eq 10): optimal coefficients scattered onto the
    /// support, zeros elsewhere.
    pub alpha: Vec<T>,
    /// The reconstruction `w* = V α*` (eq 11) at unique-value level.
    pub reconstruction: Vec<T>,
}

fn validate_support<T: Scalar>(support: &[usize], basis: &VBasis<T>) -> Result<()> {
    let m = basis.m();
    if support.windows(2).any(|p| p[0] >= p[1]) {
        return Err(Error::InvalidInput("refit: support must be sorted strictly ascending".into()));
    }
    if let Some(&last) = support.last() {
        if last >= m {
            return Err(Error::InvalidInput(format!(
                "refit: support index {last} out of range (m={m})"
            )));
        }
    }
    if let Some(&z) = support.iter().find(|&&j| basis.diffs()[j] == T::ZERO) {
        return Err(Error::InvalidInput(format!(
            "refit: support index {z} has zero diff (null column)"
        )));
    }
    Ok(())
}

/// O(m) segment-mean refit. `weights` optionally weights each unique value
/// by its multiplicity (exact LS on the *full* vector rather than the
/// unique one — the paper's eq 8 uses unweighted ŵ, so `None` reproduces
/// the paper). Lane-generic: the f32 instantiation is the refit stage of
/// the single-precision fast path.
pub fn refit_fast<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    support: &[usize],
    weights: Option<&[T]>,
) -> Result<Refit<T>> {
    let m = basis.m();
    if w.len() != m {
        return Err(Error::InvalidInput(format!(
            "refit: basis dim {m} vs target dim {}",
            w.len()
        )));
    }
    validate_support(support, basis)?;
    if let Some(ws) = weights {
        if ws.len() != m {
            return Err(Error::InvalidInput("refit: weights length mismatch".into()));
        }
    }

    let mut alpha = vec![T::ZERO; m];
    let mut reconstruction = vec![T::ZERO; m];
    if support.is_empty() {
        // No columns: reconstruction is identically zero.
        return Ok(Refit { alpha, reconstruction });
    }

    let d = basis.diffs();
    let mut prev_level = T::ZERO;
    for (t, &s) in support.iter().enumerate() {
        let seg_end = support.get(t + 1).copied().unwrap_or(m);
        // Optimal level on [s, seg_end): (weighted) mean of ŵ there.
        // Unweighted, the legacy loop accumulated `1·w[i]` (bitwise `w[i]`)
        // and counted in ONE-steps (equal to `from_usize` on the f64 lane),
        // so the kernel reductions reproduce it exactly.
        let (num, den) = match weights {
            None => (kernels::sum(&w[s..seg_end]), T::from_usize(seg_end - s)),
            Some(ws) => {
                (kernels::dot(&ws[s..seg_end], &w[s..seg_end]), kernels::sum(&ws[s..seg_end]))
            }
        };
        let level = if den > T::ZERO { num / den } else { prev_level };
        debug_assert!(d[s] != T::ZERO, "support column with zero diff");
        alpha[s] = (level - prev_level) / d[s];
        kernels::scatter_levels(&mut reconstruction[s..seg_end], level);
        prev_level = level;
    }
    Ok(Refit { alpha, reconstruction })
}

/// Explicit normal-equation refit (paper eq 9):
/// `α̂* = (V*ᵀV*)⁻¹ V*ᵀ ŵ` via Cholesky. O(m·h + h³). Oracle for
/// [`refit_fast`].
pub fn refit_normal_eq(basis: &VBasis, w: &[f64], support: &[usize]) -> Result<Refit> {
    let m = basis.m();
    if w.len() != m {
        return Err(Error::InvalidInput(format!(
            "refit: basis dim {m} vs target dim {}",
            w.len()
        )));
    }
    validate_support(support, basis)?;
    let mut alpha = vec![0.0; m];
    if support.is_empty() {
        return Ok(Refit { alpha, reconstruction: vec![0.0; m] });
    }
    let vs = basis.dense_support(support);
    let beta = least_squares(&vs, w)?;
    for (&s, &b) in support.iter().zip(&beta) {
        alpha[s] = b;
    }
    let reconstruction = basis.apply_support(support, &beta);
    Ok(Refit { alpha, reconstruction })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;
    use crate::linalg::stats::l2_loss;

    fn random_basis(m: usize, seed: u64) -> (VBasis, Vec<f64>) {
        let mut rng = Pcg32::seeded(seed);
        let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(-2.0, 6.0)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        let basis = VBasis::new(&v);
        (basis, v)
    }

    #[test]
    fn full_support_is_exact() {
        let (b, v) = random_basis(24, 1);
        let support: Vec<usize> = (0..b.m()).collect();
        let r = refit_fast(&b, &v, &support, None).unwrap();
        assert!(l2_loss(&r.reconstruction, &v) < 1e-18);
        for a in &r.alpha {
            assert!((a - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn fast_matches_normal_eq() {
        for seed in [2u64, 3, 4, 5] {
            let (b, v) = random_basis(40, seed);
            let mut rng = Pcg32::seeded(seed + 100);
            let support: Vec<usize> =
                (0..b.m()).filter(|_| rng.next_f64() < 0.3).collect();
            if support.is_empty() {
                continue;
            }
            let fast = refit_fast(&b, &v, &support, None).unwrap();
            let slow = refit_normal_eq(&b, &v, &support).unwrap();
            for (f, s) in fast.reconstruction.iter().zip(&slow.reconstruction) {
                assert!((f - s).abs() < 1e-7, "{f} vs {s}");
            }
            for (f, s) in fast.alpha.iter().zip(&slow.alpha) {
                assert!((f - s).abs() < 1e-6, "{f} vs {s}");
            }
        }
    }

    #[test]
    fn refit_never_increases_loss() {
        // eq 8 optimality: the refit reconstruction must beat (or tie) any
        // other reconstruction with the same support, in particular the raw
        // LASSO one.
        let (b, v) = random_basis(64, 6);
        let cfg = crate::quant::lasso::LassoConfig { lambda1: 1.0, ..Default::default() };
        let sol = crate::quant::lasso::solve(&b, &v, &cfg, None).unwrap();
        let support = sol.support();
        if support.is_empty() {
            return;
        }
        let raw_loss = l2_loss(&b.apply(&sol.alpha), &v);
        let refit = refit_fast(&b, &v, &support, None).unwrap();
        let refit_loss = l2_loss(&refit.reconstruction, &v);
        assert!(refit_loss <= raw_loss + 1e-12, "{refit_loss} > {raw_loss}");
    }

    #[test]
    fn empty_support_reconstructs_zero() {
        let (b, v) = random_basis(8, 7);
        let r = refit_fast(&b, &v, &[], None).unwrap();
        assert!(r.reconstruction.iter().all(|&x| x == 0.0));
        let r2 = refit_normal_eq(&b, &v, &[]).unwrap();
        assert!(r2.reconstruction.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prefix_before_first_support_is_zero() {
        let (b, v) = random_basis(10, 8);
        let r = refit_fast(&b, &v, &[3, 7], None).unwrap();
        for i in 0..3 {
            assert_eq!(r.reconstruction[i], 0.0);
        }
        // Distinct levels: {0, seg1, seg2} at most.
        let distinct = crate::linalg::stats::distinct_count_exact(&r.reconstruction);
        assert!(distinct <= 3);
    }

    #[test]
    fn weighted_refit_uses_multiplicities() {
        let b = VBasis::new(&[1.0, 2.0, 10.0]);
        let w = [1.0, 2.0, 10.0];
        // One segment covering everything; weights concentrate on the last.
        let unweighted = refit_fast(&b, &w, &[0], None).unwrap();
        let weighted = refit_fast(&b, &w, &[0], Some(&[1.0, 1.0, 98.0])).unwrap();
        let u_level = unweighted.reconstruction[0];
        let w_level = weighted.reconstruction[0];
        assert!((u_level - 13.0 / 3.0).abs() < 1e-12);
        assert!(w_level > 9.0, "weighted level should pull toward 10, got {w_level}");
    }

    #[test]
    fn rejects_bad_support() {
        let (b, v) = random_basis(8, 9);
        assert!(refit_fast(&b, &v, &[2, 2], None).is_err());
        assert!(refit_fast(&b, &v, &[3, 1], None).is_err());
        assert!(refit_fast(&b, &v, &[b.m()], None).is_err());
    }

    #[test]
    fn reconstruction_matches_v_alpha() {
        // eq 11 consistency: reconstruction == V α*.
        let (b, v) = random_basis(20, 10);
        let r = refit_fast(&b, &v, &[0, 4, 11], None).unwrap();
        let via_alpha = b.apply(&r.alpha);
        for (x, y) in r.reconstruction.iter().zip(&via_alpha) {
            assert!((x - y).abs() < 1e-9);
        }
    }
}
