//! Unique-value decomposition (paper §3.2 pre-processing).
//!
//! Every algorithm in the paper first computes `ŵ = unique(w)` and operates
//! on the sorted distinct values, recovering the full vector by indexing at
//! the end. This module provides that decomposition plus the inverse map,
//! and keeps per-value multiplicities so weighted variants (exact LS on the
//! full vector rather than the unique one) are possible.
//!
//! Generic over the element precision ([`Scalar`]): the default `f64`
//! instantiation is the reference lane; `UniqueDecomp<f32>` feeds the
//! single-precision fast path (see `linalg::scalar` for the contract).

use crate::linalg::scalar::Scalar;
use crate::{Error, Result};

/// Sorted unique decomposition of a vector.
#[derive(Debug, Clone)]
pub struct UniqueDecomp<T: Scalar = f64> {
    /// Sorted distinct values `ŵ` (ascending).
    pub values: Vec<T>,
    /// For each element of the original vector, its index into `values`.
    pub inverse: Vec<usize>,
    /// Multiplicity of each distinct value in the original vector.
    pub counts: Vec<usize>,
}

impl<T: Scalar> UniqueDecomp<T> {
    /// Decompose `w` into sorted distinct values + inverse index.
    ///
    /// Rejects empty input and non-finite values — quantizing NaN/Inf is
    /// meaningless and k-means baselines would silently corrupt on them.
    pub fn new(w: &[T]) -> Result<Self> {
        if w.is_empty() {
            return Err(Error::InvalidInput("cannot quantize an empty vector".into()));
        }
        if let Some(bad) = w.iter().find(|x| !x.is_finite()) {
            return Err(Error::InvalidInput(format!(
                "non-finite value in input: {bad}"
            )));
        }
        // Sort index pairs by value; ties broken by original index for
        // determinism.
        let mut order: Vec<usize> = (0..w.len()).collect();
        order.sort_by(|&a, &b| w[a].partial_cmp(&w[b]).unwrap().then(a.cmp(&b)));

        let mut values = Vec::new();
        let mut counts = Vec::new();
        let mut inverse = vec![0usize; w.len()];
        for &idx in &order {
            let x = w[idx];
            // Normalize -0.0 to 0.0 so the two collapse to one level.
            let x = if x == T::ZERO { T::ZERO } else { x };
            if values.last().map_or(true, |&last: &T| last != x) {
                values.push(x);
                counts.push(0);
            }
            let level = values.len() - 1;
            inverse[idx] = level;
            counts[level] += 1;
        }
        Ok(UniqueDecomp { values, inverse, counts })
    }

    /// Number of distinct values `m`.
    pub fn m(&self) -> usize {
        self.values.len()
    }

    /// Length of the original vector.
    pub fn len(&self) -> usize {
        self.inverse.len()
    }

    /// True if the original vector was empty (cannot happen post-`new`).
    pub fn is_empty(&self) -> bool {
        self.inverse.is_empty()
    }

    /// Reconstruct a full-length vector from per-level values.
    ///
    /// `level_values` assigns a (possibly shared) value to each of the `m`
    /// levels; the output has the original vector's length and ordering.
    pub fn recover(&self, level_values: &[T]) -> Result<Vec<T>> {
        if level_values.len() != self.m() {
            return Err(Error::InvalidInput(format!(
                "recover: expected {} level values, got {}",
                self.m(),
                level_values.len()
            )));
        }
        Ok(self.inverse.iter().map(|&i| level_values[i]).collect())
    }

    /// Multiplicities as lane-precision weights (for weighted least
    /// squares).
    pub fn weights(&self) -> Vec<T> {
        self.counts.iter().map(|&c| T::from_usize(c)).collect()
    }

    /// Fold per-element importance weights into per-level weights: level
    /// `j` receives `Σ user[i]` over the elements that map to it. The
    /// accumulation runs in original element order and in lane precision,
    /// so both lanes are deterministic. Replaces the multiplicity counts
    /// in every weighted solver — with `user ≡ 1` the result equals
    /// [`UniqueDecomp::weights`].
    pub fn fold_importance(&self, user: &[f64]) -> Result<Vec<T>> {
        if user.len() != self.len() {
            return Err(Error::InvalidInput(format!(
                "importance weights: expected {} entries, got {}",
                self.len(),
                user.len()
            )));
        }
        let mut folded = vec![T::ZERO; self.m()];
        for (i, &level) in self.inverse.iter().enumerate() {
            folded[level] = folded[level] + T::from_f64(user[i]);
        }
        Ok(folded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_decomposition() {
        let w = [3.0, 1.0, 2.0, 1.0, 3.0];
        let u = UniqueDecomp::new(&w).unwrap();
        assert_eq!(u.values, vec![1.0, 2.0, 3.0]);
        assert_eq!(u.counts, vec![2, 1, 2]);
        assert_eq!(u.inverse, vec![2, 0, 1, 0, 2]);
        assert_eq!(u.m(), 3);
        assert_eq!(u.len(), 5);
    }

    #[test]
    fn recover_identity() {
        let w = [0.5, -1.25, 3.0, 0.5, 0.0, 3.0];
        let u = UniqueDecomp::new(&w).unwrap();
        let rec = u.recover(&u.values).unwrap();
        assert_eq!(rec, w.to_vec());
    }

    #[test]
    fn recover_with_shared_values() {
        let w = [1.0, 2.0, 3.0];
        let u = UniqueDecomp::new(&w).unwrap();
        let rec = u.recover(&[1.5, 1.5, 3.0]).unwrap();
        assert_eq!(rec, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn recover_wrong_len_rejected() {
        let u = UniqueDecomp::new(&[1.0, 2.0]).unwrap();
        assert!(u.recover(&[1.0]).is_err());
    }

    #[test]
    fn rejects_empty_and_nonfinite() {
        assert!(UniqueDecomp::<f64>::new(&[]).is_err());
        assert!(UniqueDecomp::new(&[1.0, f64::NAN]).is_err());
        assert!(UniqueDecomp::new(&[f64::INFINITY]).is_err());
        assert!(UniqueDecomp::<f32>::new(&[]).is_err());
        assert!(UniqueDecomp::new(&[1.0f32, f32::NAN]).is_err());
    }

    #[test]
    fn f32_lane_decomposes_like_f64() {
        let w64 = [3.0f64, 1.0, 2.0, 1.0, 3.0];
        let w32: Vec<f32> = w64.iter().map(|&x| x as f32).collect();
        let u64d = UniqueDecomp::new(&w64).unwrap();
        let u32d = UniqueDecomp::new(&w32).unwrap();
        assert_eq!(u32d.inverse, u64d.inverse);
        assert_eq!(u32d.counts, u64d.counts);
        assert_eq!(u32d.values, vec![1.0f32, 2.0, 3.0]);
        assert_eq!(u32d.weights(), vec![2.0f32, 1.0, 2.0]);
    }

    #[test]
    fn negative_zero_folds() {
        let u = UniqueDecomp::new(&[-0.0, 0.0]).unwrap();
        assert_eq!(u.m(), 1);
    }

    #[test]
    fn values_sorted_ascending() {
        let w = [5.0, -2.0, 7.5, 0.0, -2.0, 5.0, 1.0];
        let u = UniqueDecomp::new(&w).unwrap();
        for pair in u.values.windows(2) {
            assert!(pair[0] < pair[1]);
        }
        assert_eq!(u.counts.iter().sum::<usize>(), w.len());
    }

    #[test]
    fn fold_importance_sums_per_level_and_matches_counts_for_unit_weights() {
        let w = [3.0, 1.0, 2.0, 1.0, 3.0];
        let u = UniqueDecomp::new(&w).unwrap();
        let folded = u.fold_importance(&[0.5, 2.0, 1.0, 3.0, 0.25]).unwrap();
        assert_eq!(folded, vec![5.0, 1.0, 0.75]);
        let unit = u.fold_importance(&[1.0; 5]).unwrap();
        assert_eq!(unit, u.weights());
        assert!(u.fold_importance(&[1.0; 4]).is_err());
    }

    #[test]
    fn single_value_vector() {
        let u = UniqueDecomp::new(&[2.0; 10]).unwrap();
        assert_eq!(u.m(), 1);
        assert_eq!(u.recover(&[9.0]).unwrap(), vec![9.0; 10]);
    }
}
