//! Iterative l1 quantization to a target value count (paper Algorithm 2).
//!
//! The plain l1 methods take a penalty λ₁, not a value count. Algorithm 2
//! closes the gap: start from a small λ₁⁰, set Δλ = λ₁⁰, and at iteration t
//! solve the LASSO with λ₁ᵗ = λ₁⁰ + (t−1)Δλ **warm-started from the
//! previous α\***, refitting on the support each round (steps 6–9), until
//! `‖α‖₀ ≤ l`.
//!
//! The paper notes the method "could be sensitive to the change of λ₁, in
//! practice it might fail to optimize to exact l values but provide l̂ < l
//! values instead" — the overshoot is reported rather than hidden. An
//! optional geometric λ growth (`accelerate`) is provided as an extension
//! for large inputs where the paper's arithmetic schedule needs thousands
//! of rounds; it is off by default to stay paper-faithful.

use super::lasso::{self, LassoConfig};
use super::refit;
use super::vmatrix::VBasis;
use crate::linalg::scalar::Scalar;
use crate::{Error, Result};

/// Configuration for Algorithm 2.
#[derive(Debug, Clone)]
pub struct IterativeConfig {
    /// Target number of non-zeros `l` (≥ 1).
    pub target_nnz: usize,
    /// Starting penalty λ₁⁰ (also the arithmetic increment Δλ).
    pub lambda_start: f64,
    /// Maximum λ-growth iterations.
    pub max_steps: usize,
    /// Inner CD configuration (λ₁ is overwritten per step).
    pub cd: LassoConfig,
    /// Extension: multiply Δλ by this factor each step (1.0 = paper's
    /// arithmetic schedule).
    pub accelerate: f64,
}

impl Default for IterativeConfig {
    fn default() -> Self {
        IterativeConfig {
            target_nnz: 16,
            lambda_start: 1e-3,
            max_steps: 500,
            cd: LassoConfig::default(),
            accelerate: 1.0,
        }
    }
}

/// Output of Algorithm 2 (lane-generic; `IterativeSolution<f64>` is the
/// default).
#[derive(Debug, Clone)]
pub struct IterativeSolution<T: Scalar = f64> {
    /// Refitted sparse coefficients (α* of the final round).
    pub alpha: Vec<T>,
    /// Achieved `‖α‖₀ ≤ target` (may undershoot — see module docs).
    pub nnz: usize,
    /// Final λ₁ used.
    pub lambda1: f64,
    /// λ-growth steps taken.
    pub steps: usize,
    /// Total CD epochs across all steps.
    pub epochs: usize,
    /// False if the budget ran out before reaching the target.
    pub reached_target: bool,
}

/// Run Algorithm 2.
pub fn solve_iterative<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    cfg: &IterativeConfig,
) -> Result<IterativeSolution<T>> {
    solve_iterative_warm(basis, w, cfg, None)
}

/// Run Algorithm 2 with an optional warm start for the *first* inner CD
/// solve (λ-sweep pipelines seed this with the previous grid point's α;
/// later rounds warm-start from the refit as usual). `None` reproduces
/// [`solve_iterative`] exactly. Allocating wrapper over
/// [`solve_iterative_ws`].
pub fn solve_iterative_warm<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    cfg: &IterativeConfig,
    warm_init: Option<&[T]>,
) -> Result<IterativeSolution<T>> {
    let mut ws = lasso::Workspace::default();
    solve_iterative_ws(basis, w, cfg, warm_init, &mut ws)
}

/// [`solve_iterative_warm`] with a caller-owned CD [`lasso::Workspace`]:
/// the λ ladder runs hundreds of inner solves, and a shared workspace
/// removes their per-solve buffer allocations. Bitwise-identical to the
/// allocating wrappers. The λ schedule itself is always computed in f64 so
/// both precision lanes walk the same penalty grid.
pub fn solve_iterative_ws<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    cfg: &IterativeConfig,
    warm_init: Option<&[T]>,
    ws: &mut lasso::Workspace<T>,
) -> Result<IterativeSolution<T>> {
    solve_iterative_weighted_ws(basis, w, None, cfg, warm_init, ws)
}

/// [`solve_iterative_ws`] generalized to an optional per-level importance
/// vector: every inner CD solve and every refit minimizes the weighted
/// objective Σⱼ Wⱼ(ŵⱼ − (Vα)ⱼ)². `importance = None` takes the *exact*
/// unweighted code path ([`lasso::solve_ws`] / unweighted refit), so the
/// unweighted ladder stays bitwise-identical to every prior release.
pub fn solve_iterative_weighted_ws<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    importance: Option<&[T]>,
    cfg: &IterativeConfig,
    warm_init: Option<&[T]>,
    ws: &mut lasso::Workspace<T>,
) -> Result<IterativeSolution<T>> {
    if w.len() != basis.m() {
        return Err(Error::InvalidInput(format!(
            "iterative: basis dim {} vs target dim {}",
            basis.m(),
            w.len()
        )));
    }
    if cfg.target_nnz == 0 {
        return Err(Error::InvalidParam("iterative: target_nnz must be ≥ 1".into()));
    }
    if cfg.lambda_start <= 0.0 {
        return Err(Error::InvalidParam("iterative: lambda_start must be > 0".into()));
    }
    if cfg.accelerate < 1.0 {
        return Err(Error::InvalidParam("iterative: accelerate must be ≥ 1".into()));
    }

    if let Some(a) = warm_init {
        if a.len() != basis.m() {
            return Err(Error::InvalidInput(format!(
                "iterative: warm start dim {} vs {}",
                a.len(),
                basis.m()
            )));
        }
    }

    let mut lambda = cfg.lambda_start;
    let mut dlambda = cfg.lambda_start;
    let mut warm: Option<Vec<T>> = warm_init.map(|a| a.to_vec());
    let mut epochs = 0usize;
    let mut steps = 0usize;

    // Track the best (feasible-or-not) solution so an over-aggressive final
    // step cannot lose a good intermediate.
    let mut last_alpha: Vec<T> = vec![T::ONE; basis.m()];
    let mut last_nnz = basis.m();
    let mut last_levels = basis.m();
    let mut last_lambda = 0.0;

    while steps < cfg.max_steps {
        steps += 1;
        let cd_cfg = LassoConfig { lambda1: lambda, ..cfg.cd.clone() };
        let sol = match importance {
            Some(imp) => lasso::solve_ws_weighted(basis, w, imp, &cd_cfg, warm.as_deref(), ws)?,
            None => lasso::solve_ws(basis, w, &cd_cfg, warm.as_deref(), ws)?,
        };
        epochs += sol.epochs;

        // Steps 7–9: refit on the support, put α* back (eq 10), and carry
        // it as the next warm start.
        let support = sol.support();
        let refitted = if support.is_empty() {
            sol.alpha.clone()
        } else {
            refit::refit_fast(basis, w, &support, importance)?.alpha
        };
        let nnz = refitted.iter().filter(|&&a| a != T::ZERO).count();
        // Distinct OUTPUT levels (includes the implicit 0-prefix when
        // index 0 is off the support) — the user-facing count.
        let levels = super::l0::level_count(&support);

        last_alpha = refitted.clone();
        last_nnz = nnz;
        last_levels = levels;
        last_lambda = lambda;

        if levels <= cfg.target_nnz && nnz > 0 {
            return Ok(IterativeSolution {
                alpha: refitted,
                nnz,
                lambda1: lambda,
                steps,
                epochs,
                reached_target: true,
            });
        }
        if nnz == 0 {
            // λ overshot to emptiness; stop with whatever we had.
            break;
        }
        warm = Some(refitted);
        dlambda *= cfg.accelerate;
        lambda += dlambda;
    }

    Ok(IterativeSolution {
        alpha: last_alpha,
        nnz: last_nnz,
        lambda1: last_lambda,
        steps,
        epochs,
        reached_target: last_levels <= cfg.target_nnz && last_nnz > 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;

    fn random_basis(m: usize, seed: u64) -> (VBasis, Vec<f64>) {
        let mut rng = Pcg32::seeded(seed);
        let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        let b = VBasis::new(&v);
        (b, v)
    }

    #[test]
    fn reaches_target_counts() {
        let (b, v) = random_basis(64, 1);
        for l in [4usize, 8, 16, 32] {
            let sol = solve_iterative(
                &b,
                &v,
                &IterativeConfig { target_nnz: l, ..Default::default() },
            )
            .unwrap();
            assert!(sol.reached_target, "l={l}");
            assert!(sol.nnz <= l && sol.nnz > 0, "l={l} nnz={}", sol.nnz);
        }
    }

    #[test]
    fn lambda_grows_arithmetically_when_not_accelerated() {
        let (b, v) = random_basis(32, 2);
        let sol = solve_iterative(
            &b,
            &v,
            &IterativeConfig {
                target_nnz: 4,
                lambda_start: 1e-3,
                ..Default::default()
            },
        )
        .unwrap();
        // λ_final = steps · λ_start under the arithmetic schedule.
        assert!((sol.lambda1 - sol.steps as f64 * 1e-3).abs() < 1e-9);
    }

    #[test]
    fn accelerated_uses_fewer_steps() {
        let (b, v) = random_basis(96, 3);
        let slow = solve_iterative(
            &b,
            &v,
            &IterativeConfig { target_nnz: 4, lambda_start: 1e-4, ..Default::default() },
        )
        .unwrap();
        let fast = solve_iterative(
            &b,
            &v,
            &IterativeConfig {
                target_nnz: 4,
                lambda_start: 1e-4,
                accelerate: 1.5,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(fast.reached_target);
        assert!(fast.steps <= slow.steps);
    }

    #[test]
    fn tiny_budget_reports_failure_honestly() {
        let (b, v) = random_basis(64, 4);
        let sol = solve_iterative(
            &b,
            &v,
            &IterativeConfig {
                target_nnz: 2,
                lambda_start: 1e-9,
                max_steps: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(!sol.reached_target);
        assert!(sol.nnz > 2);
        assert_eq!(sol.steps, 3);
    }

    #[test]
    fn solution_is_refitted() {
        // The returned α must coincide with the refit of its own support.
        let (b, v) = random_basis(48, 5);
        let sol = solve_iterative(
            &b,
            &v,
            &IterativeConfig { target_nnz: 8, ..Default::default() },
        )
        .unwrap();
        let support: Vec<usize> = sol
            .alpha
            .iter()
            .enumerate()
            .filter(|(_, &a)| a != 0.0)
            .map(|(i, _)| i)
            .collect();
        let re = crate::quant::refit::refit_fast(&b, &v, &support, None).unwrap();
        for (a, b2) in sol.alpha.iter().zip(&re.alpha) {
            assert!((a - b2).abs() < 1e-9);
        }
    }

    #[test]
    fn warm_none_is_identical_to_plain() {
        let (basis, v) = random_basis(48, 7);
        let cfg = IterativeConfig { target_nnz: 6, ..Default::default() };
        let plain = solve_iterative(&basis, &v, &cfg).unwrap();
        let warm = solve_iterative_warm(&basis, &v, &cfg, None).unwrap();
        assert_eq!(plain.alpha, warm.alpha);
        assert_eq!(plain.steps, warm.steps);
        assert_eq!(plain.epochs, warm.epochs);
    }

    #[test]
    fn weighted_none_is_identical_to_plain() {
        let (basis, v) = random_basis(48, 9);
        let cfg = IterativeConfig { target_nnz: 6, ..Default::default() };
        let plain = solve_iterative(&basis, &v, &cfg).unwrap();
        let mut ws = lasso::Workspace::default();
        let weighted = solve_iterative_weighted_ws(&basis, &v, None, &cfg, None, &mut ws).unwrap();
        assert_eq!(plain.alpha, weighted.alpha);
        assert_eq!(plain.steps, weighted.steps);
        assert_eq!(plain.epochs, weighted.epochs);
    }

    #[test]
    fn weighted_ladder_reaches_target_and_refits_weighted() {
        let (basis, v) = random_basis(64, 10);
        let mut rng = Pcg32::seeded(110);
        let imp: Vec<f64> = (0..basis.m()).map(|_| rng.uniform(0.1, 4.0)).collect();
        let cfg = IterativeConfig { target_nnz: 8, ..Default::default() };
        let mut ws = lasso::Workspace::default();
        let sol =
            solve_iterative_weighted_ws(&basis, &v, Some(&imp), &cfg, None, &mut ws).unwrap();
        assert!(sol.reached_target);
        assert!(sol.nnz <= 8 && sol.nnz > 0);
        // The returned α must coincide with the *weighted* refit of its own
        // support — the ladder's inner refit is importance-aware.
        let support: Vec<usize> = sol
            .alpha
            .iter()
            .enumerate()
            .filter(|(_, &a)| a != 0.0)
            .map(|(i, _)| i)
            .collect();
        let re = crate::quant::refit::refit_fast(&basis, &v, &support, Some(&imp)).unwrap();
        for (a, b2) in sol.alpha.iter().zip(&re.alpha) {
            assert!((a - b2).abs() < 1e-9);
        }
    }

    #[test]
    fn warm_start_rejects_bad_dim() {
        let (basis, v) = random_basis(16, 8);
        let cfg = IterativeConfig::default();
        assert!(solve_iterative_warm(&basis, &v, &cfg, Some(&[1.0])).is_err());
    }

    #[test]
    fn rejects_bad_params() {
        let (b, v) = random_basis(8, 6);
        assert!(solve_iterative(
            &b,
            &v,
            &IterativeConfig { target_nnz: 0, ..Default::default() }
        )
        .is_err());
        assert!(solve_iterative(
            &b,
            &v,
            &IterativeConfig { lambda_start: 0.0, ..Default::default() }
        )
        .is_err());
        assert!(solve_iterative(
            &b,
            &v,
            &IterativeConfig { accelerate: 0.5, ..Default::default() }
        )
        .is_err());
    }
}
