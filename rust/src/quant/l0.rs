//! l0 best-subset quantization (paper eq 16).
//!
//! ```text
//! min_α ‖ŵ − Vα‖²   subject to  ‖α‖₀ < l
//! ```
//!
//! Exact l0 is NP-hard [43]; the paper uses "L0Learn" (Hazimeh & Mazumder
//! 2018, [38]): coordinate descent with *hard* thresholding on the
//! penalized form `½‖ŵ − Vα‖² + λ₀‖α‖₀`, improved by local combinatorial
//! swaps, swept over λ₀. The constrained form is recovered by searching λ₀
//! for the largest support not exceeding the bound.
//!
//! The paper's two observed failure modes are deliberately surfaced rather
//! than papered over (§4.2, Fig 6):
//!
//! * **non-universality** — not every support size is achievable by any λ₀
//!   (the nnz-vs-λ₀ map has jumps); the solver returns the best achievable
//!   size ≤ the bound and flags when it undershoots;
//! * **failure at large l** — like the R package (which supports l ≤ 100),
//!   the solver gives up beyond [`L0Config::max_support`] and reports
//!   `unstable`.

use super::refit;
use super::vmatrix::VBasis;
use crate::{Error, Result};

/// Configuration for the l0 solver.
#[derive(Debug, Clone)]
pub struct L0Config {
    /// Upper bound `l` on the number of non-zeros (paper's "amount of
    /// quantization values").
    pub max_nnz: usize,
    /// CD epoch budget per λ₀ probe.
    pub max_epochs: usize,
    /// Convergence tolerance per probe.
    pub tol: f64,
    /// Local combinatorial swap sweeps after CD (L0Learn's "local search").
    pub swap_sweeps: usize,
    /// λ₀ bisection steps.
    pub search_steps: usize,
    /// Hard cap mirroring the reference package's l ≤ 100 limitation.
    pub max_support: usize,
}

impl Default for L0Config {
    fn default() -> Self {
        L0Config {
            max_nnz: 16,
            max_epochs: 200,
            tol: 1e-10,
            swap_sweeps: 2,
            search_steps: 40,
            max_support: 100,
        }
    }
}

/// l0 solver output.
#[derive(Debug, Clone)]
pub struct L0Solution {
    /// Sparse coefficients after support refit.
    pub alpha: Vec<f64>,
    /// Achieved support size (may be `< max_nnz` — non-universality).
    pub nnz: usize,
    /// λ₀ that produced the accepted solution.
    pub lambda0: f64,
    /// Total CD epochs across all probes.
    pub epochs: usize,
    /// True when the requested size was not achievable (undershoot) or the
    /// request exceeded `max_support`.
    pub unstable: bool,
}

/// One hard-thresholding CD pass to (approximate) stationarity for a fixed
/// λ₀. Returns (alpha, epochs).
fn cd_hard(basis: &VBasis, w: &[f64], lambda0: f64, cfg: &L0Config) -> (Vec<f64>, usize) {
    let m = basis.m();
    let d = basis.diffs();
    let mut alpha = vec![1.0; m];
    // Null columns (d_j = 0) must never enter the support.
    for (a, dj) in alpha.iter_mut().zip(d) {
        if *dj == 0.0 {
            *a = 0.0;
        }
    }
    let mut rec = vec![0.0; m];
    let mut r = vec![0.0; m];
    let mut epochs = 0;

    for _ in 0..cfg.max_epochs {
        epochs += 1;
        basis.apply_into(&alpha, &mut rec);
        for i in 0..m {
            r[i] = w[i] - rec[i];
        }
        let mut s = 0.0;
        let mut max_move = 0.0f64;
        for j in (0..m).rev() {
            s += r[j];
            let dj = d[j];
            if dj == 0.0 {
                continue;
            }
            let cj = basis.col_norm_sq(j);
            let rho = dj * s + cj * alpha[j];
            // Keep the coordinate iff the loss reduction ρ²/(2c) beats the
            // λ₀ support price.
            let cand = rho / cj;
            let new = if rho * rho / (2.0 * cj) > lambda0 { cand } else { 0.0 };
            let delta = new - alpha[j];
            if delta != 0.0 {
                alpha[j] = new;
                s -= (m - j) as f64 * dj * delta;
                max_move = max_move.max((dj * delta).abs());
            }
        }
        if max_move < cfg.tol {
            break;
        }
    }
    (alpha, epochs)
}

/// Number of distinct *levels* a support generates: one per support index,
/// plus the implicit 0-level prefix when index 0 is not in the support
/// (the `[0, s_0)` segment is pinned at 0 — see refit.rs). The paper's
/// `‖α‖₀ < l` counts non-zeros; the library's contract is on distinct
/// output values, so the bound must use this count.
pub fn level_count(support: &[usize]) -> usize {
    match support.first() {
        None => 1, // all-zero reconstruction: a single level
        Some(0) => support.len(),
        Some(_) => support.len() + 1,
    }
}

/// Squared LS loss of a support after optimal refit.
fn support_loss(basis: &VBasis, w: &[f64], support: &[usize]) -> f64 {
    match refit::refit_fast(basis, w, support, None) {
        Ok(r) => w
            .iter()
            .zip(&r.reconstruction)
            .map(|(a, b)| (a - b) * (a - b))
            .sum(),
        Err(_) => f64::INFINITY,
    }
}

/// Local combinatorial improvement: for each support index, try swapping it
/// for the best non-support index; keep strictly improving swaps that do
/// not blow the `max_levels` budget (swapping index 0 out would add the
/// implicit 0-prefix level).
fn local_swaps(basis: &VBasis, w: &[f64], support: &mut Vec<usize>, sweeps: usize, max_levels: usize) {
    let m = basis.m();
    let d = basis.diffs();
    for _ in 0..sweeps {
        let mut improved = false;
        let mut base = support_loss(basis, w, support);
        for pos in 0..support.len() {
            let old = support[pos];
            let mut best_loss = base;
            let mut best_j = old;
            for j in 0..m {
                if d[j] == 0.0 || support.binary_search(&j).is_ok() {
                    continue;
                }
                let mut cand = support.clone();
                cand[pos] = j;
                cand.sort_unstable();
                if level_count(&cand) > max_levels {
                    continue;
                }
                let loss = support_loss(basis, w, &cand);
                if loss < best_loss - 1e-15 {
                    best_loss = loss;
                    best_j = j;
                }
            }
            if best_j != old {
                support[pos] = best_j;
                support.sort_unstable();
                base = best_loss;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
}

/// Solve the constrained l0 problem by λ₀ bisection + local search + refit.
pub fn solve_l0(basis: &VBasis, w: &[f64], cfg: &L0Config) -> Result<L0Solution> {
    if w.len() != basis.m() {
        return Err(Error::InvalidInput(format!(
            "l0: basis dim {} vs target dim {}",
            basis.m(),
            w.len()
        )));
    }
    if cfg.max_nnz == 0 {
        return Err(Error::InvalidParam("l0: max_nnz must be ≥ 1".into()));
    }
    let m = basis.m();
    let mut total_epochs = 0usize;

    // Reproduce the reference package's hard support limit.
    if cfg.max_nnz > cfg.max_support {
        return Ok(L0Solution {
            alpha: vec![0.0; m],
            nnz: 0,
            lambda0: f64::NAN,
            epochs: 0,
            unstable: true,
        });
    }

    // λ₀ bracket: at λ_hi every coordinate is dropped; at λ_lo ≈ 0 the
    // support is full. Max loss reduction of one coordinate is bounded by
    // ½‖w‖² so λ_hi = ‖w‖² suffices.
    let wsq: f64 = w.iter().map(|x| x * x).sum();
    let mut lo = 0.0f64;
    let mut hi = wsq.max(1e-12);
    let mut best: Option<(Vec<usize>, f64)> = None; // (support, lambda0)

    for _ in 0..cfg.search_steps {
        let mid = 0.5 * (lo + hi);
        let (alpha, ep) = cd_hard(basis, w, mid, cfg);
        total_epochs += ep;
        let support: Vec<usize> = alpha
            .iter()
            .enumerate()
            .filter(|(_, &a)| a != 0.0)
            .map(|(i, _)| i)
            .collect();
        // Feasibility is on distinct OUTPUT levels, which includes the
        // implicit 0-prefix when index 0 is absent.
        if !support.is_empty() && level_count(&support) <= cfg.max_nnz {
            // Remember the densest feasible support seen.
            let denser = best.as_ref().map_or(true, |(s, _)| support.len() > s.len());
            if denser {
                best = Some((support, mid));
            }
            hi = mid; // try smaller λ for a denser support
        } else if support.is_empty() {
            hi = mid; // overshot to emptiness: come back down
        } else {
            lo = mid;
        }
        if hi - lo < 1e-14 * wsq.max(1.0) {
            break;
        }
    }

    let (mut support, lambda0) = match best {
        Some(b) => b,
        None => {
            // Not even nnz=1 found — the paper's "could not find any
            // non-trivial solution" failure (§4.1 on the NN weights).
            return Ok(L0Solution {
                alpha: vec![0.0; m],
                nnz: 0,
                lambda0: f64::NAN,
                epochs: total_epochs,
                unstable: true,
            });
        }
    };

    local_swaps(basis, w, &mut support, cfg.swap_sweeps, cfg.max_nnz);
    let refit = refit::refit_fast(basis, w, &support, None)?;
    let nnz = support.len();
    Ok(L0Solution {
        alpha: refit.alpha,
        nnz,
        lambda0,
        epochs: total_epochs,
        // Undershooting the requested level count is the paper's
        // "non-universality".
        unstable: level_count(&support) < cfg.max_nnz,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;
    use crate::linalg::stats::l2_loss;

    fn random_basis(m: usize, seed: u64) -> (VBasis, Vec<f64>) {
        let mut rng = Pcg32::seeded(seed);
        let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 10.0)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        let b = VBasis::new(&v);
        (b, v)
    }

    #[test]
    fn respects_support_bound() {
        let (b, v) = random_basis(48, 1);
        for l in [2usize, 4, 8, 16] {
            let sol = solve_l0(&b, &v, &L0Config { max_nnz: l, ..Default::default() }).unwrap();
            assert!(sol.nnz <= l, "l={l} got nnz={}", sol.nnz);
            assert!(sol.nnz > 0);
        }
    }

    #[test]
    fn loss_decreases_with_budget() {
        let (b, v) = random_basis(48, 2);
        let mut prev = f64::INFINITY;
        for l in [2usize, 4, 8, 16, 32] {
            let sol = solve_l0(&b, &v, &L0Config { max_nnz: l, ..Default::default() }).unwrap();
            let loss = l2_loss(&b.apply(&sol.alpha), &v);
            assert!(loss <= prev + 1e-9, "l={l}: loss rose {prev} -> {loss}");
            prev = loss;
        }
    }

    #[test]
    fn exceeding_package_limit_fails_like_the_paper() {
        let (b, v) = random_basis(32, 3);
        let sol = solve_l0(
            &b,
            &v,
            &L0Config { max_nnz: 101, ..Default::default() },
        )
        .unwrap();
        assert!(sol.unstable);
        assert_eq!(sol.nnz, 0);
    }

    #[test]
    fn obvious_two_level_signal() {
        // Values in two tight groups: nnz=2 should capture nearly all mass.
        let v = vec![1.0, 1.01, 1.02, 9.0, 9.01, 9.02];
        let b = VBasis::new(&v);
        let sol = solve_l0(&b, &v, &L0Config { max_nnz: 2, ..Default::default() }).unwrap();
        assert_eq!(sol.nnz, 2);
        let loss = l2_loss(&b.apply(&sol.alpha), &v);
        assert!(loss < 1e-3, "loss={loss}");
    }

    #[test]
    fn swaps_never_hurt() {
        let (b, v) = random_basis(40, 4);
        let no_swaps =
            solve_l0(&b, &v, &L0Config { max_nnz: 6, swap_sweeps: 0, ..Default::default() })
                .unwrap();
        let with_swaps =
            solve_l0(&b, &v, &L0Config { max_nnz: 6, swap_sweeps: 3, ..Default::default() })
                .unwrap();
        let l_no = l2_loss(&b.apply(&no_swaps.alpha), &v);
        let l_yes = l2_loss(&b.apply(&with_swaps.alpha), &v);
        assert!(l_yes <= l_no + 1e-9, "swaps hurt: {l_no} -> {l_yes}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let (b, v) = random_basis(8, 5);
        assert!(solve_l0(&b, &v[..4], &L0Config::default()).is_err());
        assert!(solve_l0(&b, &v, &L0Config { max_nnz: 0, ..Default::default() }).is_err());
    }
}
