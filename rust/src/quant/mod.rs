//! The paper's quantization algorithms and the unified [`quantize`] API.
//!
//! Pipeline shared by every method (paper §3.1–§3.2):
//!
//! 1. `ŵ = unique(w)` — [`unique::UniqueDecomp`];
//! 2. build the difference basis `V` — [`vmatrix::VBasis`];
//! 3. run the method-specific solver;
//! 4. recover the full-length vector by indexing;
//! 5. optionally clamp with the hard sigmoid (eq 21) and compute the l2
//!    information loss.

pub mod cluster_ls;
pub mod codebook;
pub mod hard_sigmoid;
pub mod iterative;
pub mod l0;
pub mod lasso;
pub mod merge;
pub mod refit;
pub mod tensor;
pub mod tv_exact;
pub mod types;
pub mod unique;
pub mod vmatrix;

pub use types::{QuantDiag, QuantMethod, QuantOptions, QuantOutput};

use crate::cluster::data_transform::{data_transform_cluster, DataTransformConfig};
use crate::cluster::gmm::{gmm_1d, GmmConfig};
use crate::cluster::kmeans::{assign_sorted, KMeansConfig};
use crate::cluster::kmeans_dp::kmeans_dp;
use crate::Result;
use unique::UniqueDecomp;
use vmatrix::VBasis;

/// Quantize `w` with the chosen method. This is the library's main entry
/// point; the coordinator's native engine and the CLI both route here.
pub fn quantize(w: &[f64], method: QuantMethod, opts: &QuantOptions) -> Result<QuantOutput> {
    let u = UniqueDecomp::new(w)?;
    let basis = VBasis::new(&u.values);
    let counts = u.weights();

    let (level_values, diag) = match method {
        QuantMethod::L1 => run_l1(&basis, &u, opts, false)?,
        QuantMethod::L1LeastSquare => run_l1(&basis, &u, opts, true)?,
        QuantMethod::L1L2 => run_l1l2(&basis, &u, opts)?,
        QuantMethod::L0 => run_l0(&basis, &u, opts)?,
        QuantMethod::IterativeL1 => run_iterative(&basis, &u, opts)?,
        QuantMethod::ClusterLs => run_cluster_ls(&basis, &u, opts)?,
        QuantMethod::KMeans => run_kmeans(&basis, &counts, opts)?,
        QuantMethod::Gmm => run_gmm(&basis, &counts, opts)?,
        QuantMethod::DataTransform => run_data_transform(&basis, &counts, opts)?,
        QuantMethod::KMeansExact => run_kmeans_exact(&basis, &counts, opts)?,
        QuantMethod::TvExact => run_tv_exact(&basis, &u, opts)?,
        QuantMethod::Agglomerative => run_agglomerative(&basis, &counts, opts)?,
        QuantMethod::FuzzyCMeans => run_fcm(&basis, &counts, opts)?,
    };

    let full = u.recover(&level_values)?;
    Ok(types::finalize(w, full, opts.clamp, diag))
}

fn lasso_cfg(opts: &QuantOptions) -> lasso::LassoConfig {
    lasso::LassoConfig {
        lambda1: opts.lambda1,
        lambda2: 0.0,
        max_epochs: opts.max_epochs,
        tol: opts.tol,
        ..Default::default()
    }
}

fn run_l1(
    basis: &VBasis,
    u: &UniqueDecomp,
    opts: &QuantOptions,
    with_refit: bool,
) -> Result<(Vec<f64>, QuantDiag)> {
    let sol = lasso::solve(basis, &u.values, &lasso_cfg(opts), None)?;
    let diag = QuantDiag {
        iterations: sol.epochs,
        converged: sol.converged,
        lambda1: opts.lambda1,
        nnz: sol.nnz(),
        unstable: sol.unstable,
        empty_cluster_events: 0,
    };
    if with_refit {
        let support = sol.support();
        let r = refit::refit_fast(basis, &u.values, &support, None)?;
        Ok((r.reconstruction, diag))
    } else {
        Ok((basis.apply(&sol.alpha), diag))
    }
}

fn run_l1l2(basis: &VBasis, u: &UniqueDecomp, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = lasso::LassoConfig { lambda2: opts.lambda2, ..lasso_cfg(opts) };
    let sol = lasso::solve(basis, &u.values, &cfg, None)?;
    let diag = QuantDiag {
        iterations: sol.epochs,
        converged: sol.converged,
        lambda1: opts.lambda1,
        nnz: sol.nnz(),
        unstable: sol.unstable,
        empty_cluster_events: 0,
    };
    // Fig 4 compares l1 vs l1+l2 without the LS refit; honor opts.refit
    // for users who want Algorithm-1 style output.
    if opts.refit {
        let r = refit::refit_fast(basis, &u.values, &sol.support(), None)?;
        Ok((r.reconstruction, diag))
    } else {
        Ok((basis.apply(&sol.alpha), diag))
    }
}

fn run_l0(basis: &VBasis, u: &UniqueDecomp, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = l0::L0Config {
        max_nnz: opts.target_values,
        max_epochs: opts.max_epochs,
        tol: opts.tol,
        ..Default::default()
    };
    let sol = l0::solve_l0(basis, &u.values, &cfg)?;
    let diag = QuantDiag {
        iterations: sol.epochs,
        converged: !sol.unstable,
        lambda1: sol.lambda0,
        nnz: sol.nnz,
        unstable: sol.unstable,
        empty_cluster_events: 0,
    };
    Ok((basis.apply(&sol.alpha), diag))
}

fn run_iterative(
    basis: &VBasis,
    u: &UniqueDecomp,
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = iterative::IterativeConfig {
        target_nnz: opts.target_values,
        lambda_start: opts.lambda1.max(1e-9),
        max_steps: opts.max_lambda_steps,
        cd: lasso_cfg(opts),
        accelerate: 1.0,
    };
    let sol = iterative::solve_iterative(basis, &u.values, &cfg)?;
    let diag = QuantDiag {
        iterations: sol.epochs,
        converged: sol.reached_target,
        lambda1: sol.lambda1,
        nnz: sol.nnz,
        unstable: !sol.reached_target,
        empty_cluster_events: 0,
    };
    let mut rec = basis.apply(&sol.alpha);
    if !sol.reached_target {
        // The λ path can jump past the requested count (paper: "might fail
        // to optimize to exact l values"). Enforce the library's contract
        // with a Ward merge of the surplus levels.
        rec = merge::merge_to_target(&rec, None, opts.target_values);
    }
    Ok((rec, diag))
}

fn run_cluster_ls(
    basis: &VBasis,
    u: &UniqueDecomp,
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = cluster_ls::ClusterLsConfig {
        l: opts.target_values,
        kmeans: KMeansConfig {
            k: opts.target_values,
            restarts: opts.kmeans_restarts,
            max_iters: opts.max_iters,
            tol: 1e-10,
            seed: opts.seed,
            ..Default::default()
        },
        // Weighted: the paper's eq 19 is written over ŵ unweighted, but its
        // experimental claim (Alg 3 ≥ k-means on the full-vector loss) only
        // holds when multiplicities weight both the partition and the LS
        // values; the paper-literal unweighted variant stays available via
        // ClusterLsConfig. See EXPERIMENTS.md Fig 5 notes.
        weighted: true,
    };
    let counts = u.weights();
    let sol = cluster_ls::solve_cluster_ls(basis, &u.values, Some(&counts), &cfg)?;
    let diag = QuantDiag {
        iterations: sol.iterations,
        converged: true,
        lambda1: 0.0,
        nnz: sol.levels.len(),
        unstable: false,
        empty_cluster_events: sol.empty_cluster_events,
    };
    Ok((sol.reconstruction, diag))
}

fn run_kmeans(
    basis: &VBasis,
    counts: &[f64],
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = KMeansConfig {
        k: opts.target_values,
        restarts: opts.kmeans_restarts,
        max_iters: opts.max_iters,
        tol: 1e-10,
        seed: opts.seed,
        ..Default::default()
    };
    let (rec, iters, empty) = cluster_ls::kmeans_quantize_levels(basis, Some(counts), &cfg)?;
    let diag = QuantDiag {
        iterations: iters,
        converged: true,
        lambda1: 0.0,
        nnz: opts.target_values,
        unstable: empty > 0,
        empty_cluster_events: empty,
    };
    Ok((rec, diag))
}

fn run_kmeans_exact(
    basis: &VBasis,
    counts: &[f64],
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let r = kmeans_dp(basis.values(), Some(counts), opts.target_values)?;
    let rec: Vec<f64> = basis
        .values()
        .iter()
        .zip(&r.assignment)
        .map(|(_, &a)| r.centroids[a])
        .collect();
    let diag = QuantDiag {
        iterations: 1,
        converged: true,
        lambda1: 0.0,
        nnz: r.centroids.len(),
        unstable: false,
        empty_cluster_events: 0,
    };
    Ok((rec, diag))
}

fn run_tv_exact(
    basis: &VBasis,
    u: &UniqueDecomp,
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let rec = tv_exact::solve_tv_exact(basis, &u.values, opts.lambda1)?;
    let nnz = {
        // Count level jumps (α support) for diagnostics.
        let mut prev = 0.0;
        let mut c = 0usize;
        for (&x, &d) in rec.iter().zip(basis.diffs()) {
            if d != 0.0 && (x - prev).abs() > 1e-12 {
                c += 1;
            }
            prev = x;
        }
        c
    };
    let diag = QuantDiag {
        iterations: 1, // exact, single pass
        converged: true,
        lambda1: opts.lambda1,
        nnz,
        unstable: false,
        empty_cluster_events: 0,
    };
    Ok((rec, diag))
}

fn run_agglomerative(
    basis: &VBasis,
    counts: &[f64],
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let r = crate::cluster::agglomerative::agglomerative_1d(
        basis.values(),
        Some(counts),
        opts.target_values,
    )?;
    let rec: Vec<f64> = basis
        .values()
        .iter()
        .zip(&r.assignment)
        .map(|(_, &a)| r.centroids[a])
        .collect();
    let diag = QuantDiag {
        iterations: basis.m().saturating_sub(r.centroids.len()),
        converged: true,
        lambda1: 0.0,
        nnz: r.centroids.len(),
        unstable: false,
        empty_cluster_events: 0,
    };
    Ok((rec, diag))
}

fn run_fcm(
    basis: &VBasis,
    counts: &[f64],
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = crate::cluster::fuzzy_cmeans::FcmConfig {
        k: opts.target_values,
        max_iters: opts.max_iters,
        seed: opts.seed,
        ..Default::default()
    };
    let r = crate::cluster::fuzzy_cmeans::fuzzy_cmeans_1d(basis.values(), Some(counts), &cfg)?;
    let rec: Vec<f64> = r.assignment.iter().map(|&a| r.centroids[a]).collect();
    let diag = QuantDiag {
        iterations: r.iterations,
        converged: r.converged,
        lambda1: 0.0,
        nnz: r.centroids.len(),
        unstable: false,
        empty_cluster_events: 0,
    };
    Ok((rec, diag))
}

fn run_gmm(basis: &VBasis, counts: &[f64], opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = GmmConfig {
        k: opts.target_values,
        max_iters: opts.max_iters,
        tol: 1e-9,
        seed: opts.seed,
    };
    let r = gmm_1d(basis.values(), Some(counts), &cfg)?;
    let rec: Vec<f64> = r.assignment.iter().map(|&a| r.means[a]).collect();
    let diag = QuantDiag {
        iterations: r.iterations,
        converged: r.converged,
        lambda1: 0.0,
        nnz: r.means.len(),
        unstable: false,
        empty_cluster_events: 0,
    };
    Ok((rec, diag))
}

fn run_data_transform(
    basis: &VBasis,
    counts: &[f64],
    opts: &QuantOptions,
) -> Result<(Vec<f64>, QuantDiag)> {
    let cfg = DataTransformConfig {
        k: opts.target_values,
        restarts: opts.kmeans_restarts,
        max_iters: opts.max_iters,
        seed: opts.seed,
        ..Default::default()
    };
    let r = data_transform_cluster(basis.values(), Some(counts), &cfg)?;
    let rec: Vec<f64> = basis
        .values()
        .iter()
        .map(|&v| r.centroids[assign_sorted(v, &r.centroids)])
        .collect();
    let diag = QuantDiag {
        iterations: r.iterations,
        converged: true,
        lambda1: 0.0,
        nnz: r.centroids.len(),
        unstable: false,
        empty_cluster_events: 0,
    };
    Ok((rec, diag))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> Vec<f64> {
        // 40 values in 4 tight groups, with repeats.
        let mut v = Vec::new();
        for (center, n) in [(0.1, 10), (0.35, 10), (0.6, 10), (0.9, 10)] {
            for i in 0..n {
                v.push(center + 0.002 * (i as f64));
            }
        }
        v.push(0.1); // repeat
        v
    }

    #[test]
    fn all_methods_produce_valid_output() {
        let data = sample_data();
        for method in QuantMethod::ALL {
            let opts = QuantOptions {
                lambda1: 0.01,
                lambda2: 4e-5,
                target_values: 4,
                ..Default::default()
            };
            let out = quantize(&data, method, &opts)
                .unwrap_or_else(|e| panic!("{method:?} failed: {e}"));
            assert_eq!(out.values.len(), data.len(), "{method:?}");
            assert!(out.l2_loss.is_finite(), "{method:?}");
            assert!(out.distinct_values() >= 1, "{method:?}");
            assert!(
                out.distinct_values() <= data.len(),
                "{method:?}: {} distinct",
                out.distinct_values()
            );
        }
    }

    #[test]
    fn count_methods_respect_target() {
        let data = sample_data();
        for method in [
            QuantMethod::KMeans,
            QuantMethod::ClusterLs,
            QuantMethod::IterativeL1,
            QuantMethod::L0,
            QuantMethod::KMeansExact,
            QuantMethod::Gmm,
            QuantMethod::DataTransform,
        ] {
            let opts = QuantOptions { target_values: 4, lambda1: 1e-4, ..Default::default() };
            let out = quantize(&data, method, &opts).unwrap();
            assert!(
                out.distinct_values() <= 4,
                "{method:?} produced {} values",
                out.distinct_values()
            );
        }
    }

    #[test]
    fn four_groups_quantize_cleanly() {
        let data = sample_data();
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let out = quantize(&data, QuantMethod::ClusterLs, &opts).unwrap();
        assert_eq!(out.distinct_values(), 4);
        // Loss per element should be tiny (groups are 0.02 wide).
        assert!(out.l2_loss / (data.len() as f64) < 1e-4, "loss={}", out.l2_loss);
    }

    #[test]
    fn l1_ls_beats_or_ties_plain_l1() {
        let data = sample_data();
        let opts = QuantOptions { lambda1: 0.02, ..Default::default() };
        let plain = quantize(&data, QuantMethod::L1, &opts).unwrap();
        let ls = quantize(&data, QuantMethod::L1LeastSquare, &opts).unwrap();
        assert!(ls.l2_loss <= plain.l2_loss + 1e-12);
    }

    #[test]
    fn quantized_values_preserve_multiplicity_structure() {
        let data = sample_data();
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let out = quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        // Equal inputs must map to equal outputs.
        for i in 0..data.len() {
            for j in 0..data.len() {
                if data[i] == data[j] {
                    assert_eq!(out.values[i], out.values[j]);
                }
            }
        }
    }

    #[test]
    fn clamp_applies() {
        let data = vec![-0.2, 0.5, 1.3, 0.5];
        let opts = QuantOptions {
            target_values: 3,
            clamp: Some((0.0, 1.0)),
            ..Default::default()
        };
        let out = quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        assert!(out.values.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn empty_input_rejected() {
        assert!(quantize(&[], QuantMethod::L1, &QuantOptions::default()).is_err());
    }

    #[test]
    fn data_containing_zero_min_value_works_for_all_methods() {
        // Regression: v_0 = 0 makes d_0 = 0 (a null column in V); the
        // digit image hits this (background pixels are exactly 0).
        let mut data = sample_data();
        data.push(0.0);
        data.push(0.0);
        for method in QuantMethod::ALL {
            let opts = QuantOptions {
                lambda1: 0.01,
                lambda2: 4e-5,
                target_values: 4,
                ..Default::default()
            };
            let out = quantize(&data, method, &opts)
                .unwrap_or_else(|e| panic!("{method:?} failed on zero-min data: {e}"));
            assert_eq!(out.values.len(), data.len(), "{method:?}");
            assert!(out.l2_loss.is_finite(), "{method:?}");
        }
    }
}
