//! The paper's quantization algorithms behind a staged two-stage pipeline.
//!
//! Every method (paper §3.1–§3.2) factors into the same two stages:
//!
//! 1. **Prepare** — `ŵ = unique(w)` ([`unique::UniqueDecomp`]), the
//!    difference basis `V` ([`vmatrix::VBasis`]), multiplicity weights and
//!    cached prefix/suffix sums. This is a full sort of the input and is
//!    method-independent, so it is built once per vector as a
//!    [`PreparedInput`] and reused across methods, λ grids and repeat
//!    requests.
//! 2. **Solve** — the method-specific solver, one [`QuantSolver`] impl per
//!    [`QuantMethod`], resolved through the registration table in
//!    [`pipeline`]. Solvers produce per-level values; full-length recovery
//!    (indexing through the decomposition), the optional hard-sigmoid
//!    clamp (eq 21) and the l2 information loss live in
//!    [`PreparedInput::finish`].
//!
//! Entry points, from highest to lowest level:
//!
//! * **[`api`] — the unified request/response front door.** Build a
//!   [`QuantRequest`] (vector / batch / matrix input; one-shot,
//!   target-count or λ-sweep plan — sweeps compose with batch/matrix
//!   inputs as the batch×sweep plan, B groups × K λs in one request;
//!   precision lane; output form) and hand it to [`Quantizer::run`].
//!   Responses are codebook-first: each item carries a [`Codebook`]
//!   (levels + `u32` indices), materializes the full vector only on
//!   demand, and exposes compression accounting ([`CompressionStats`]:
//!   bits/value, index entropy, achieved-vs-requested levels,
//!   compact-vs-dense bytes). **This is the API for new code.**
//! * [`quantize`] — the legacy one-shot wrapper (prepare + solve), now a
//!   thin shim over the api core; kept source- and bitwise-compatible.
//! * [`quantize_batch`] — many vectors, one method, fanned across scoped
//!   threads; results are bitwise-identical to per-call [`quantize`].
//! * [`quantize_sweep`] — a λ grid over ONE prepared input, amortizing the
//!   prepare stage and warm-starting lasso/iterative solves along the
//!   path; [`quantize_sweep_with`] exposes the cold (bitwise-reference)
//!   variant.
//! * [`quantize_prepared`] / [`quantize_timed`] — the raw staged calls;
//!   `quantize_timed` reports per-stage wall times for the coordinator's
//!   prepare-vs-solve metrics.
//!
//! Every entry point exists on two precision lanes: the default f64
//! reference lane and an f32 fast lane ([`Precision`],
//! [`quantize_f32`]/[`quantize_batch_f32`]/[`quantize_sweep_f32`],
//! [`PreparedInputF32`]) that halves memory traffic on NN-weight-shaped
//! workloads; the request API keeps f32 results narrow until a caller
//! explicitly widens. See [`pipeline`] for lane selection and the
//! precision contract.

pub mod api;
pub mod cluster_ls;
pub mod codebook;
pub mod hard_sigmoid;
pub mod iterative;
pub mod l0;
pub mod lasso;
pub mod merge;
pub mod pipeline;
pub mod qmatrix;
pub mod refit;
pub mod tensor;
pub mod tv_exact;
pub mod types;
pub mod unique;
pub mod vmatrix;

pub use api::{
    validate_entropy_budget, validate_weights, weights_are_uniform, Fingerprint, Item,
    OutputForm, Plan, QuantItem, QuantRequest, QuantResponse, Quantizer, RequestWeights,
};
pub use merge::index_entropy_bits;
pub use codebook::{Codebook, CodebookF32, CompressionStats, PackedCodebook, PackedIndices};
pub use qmatrix::{CascadeLevel, QMatrix};
pub use pipeline::{
    quantize_batch, quantize_batch_f32, quantize_f32, quantize_prepared, quantize_prepared_f32,
    quantize_sweep, quantize_sweep_f32, quantize_sweep_f32_with, quantize_sweep_with,
    quantize_timed, solver_for, LaneSolve, PreparedInput, PreparedInputF32, QuantSolver,
    StageTimings, SweepState,
};
pub use types::{
    Precision, QuantDiag, QuantMethod, QuantOptions, QuantOutput, QuantOutputF32, QuantOutputT,
};

use crate::Result;

/// Quantize `w` with the chosen method: the historical one-shot entry
/// point the coordinator's native engine and the CLI route through.
///
/// [`QuantOptions::precision`] selects the lane: the default `F64` is the
/// bitwise-stable reference path; `F32` narrows the input once, runs the
/// whole pipeline in single precision (the NN-weight fast path) and widens
/// the output at the end. Callers holding f32 data should use
/// [`quantize_f32`] directly and skip both conversions.
///
/// **Legacy**: thin shim over the [`api`] core ([`Quantizer::run`] with a
/// single-vector one-shot request), bitwise-identical to the pre-redesign
/// implementation. New code should build a [`QuantRequest`] — it avoids
/// the slice copy (owned/shared inputs) and returns the compact
/// codebook-first response.
pub fn quantize(w: &[f64], method: QuantMethod, opts: &QuantOptions) -> Result<QuantOutput> {
    Ok(api::run_shared_f64(std::sync::Arc::from(w), method, opts, OutputForm::Codebook)?
        .into_output64())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> Vec<f64> {
        // 40 values in 4 tight groups, with repeats.
        let mut v = Vec::new();
        for (center, n) in [(0.1, 10), (0.35, 10), (0.6, 10), (0.9, 10)] {
            for i in 0..n {
                v.push(center + 0.002 * (i as f64));
            }
        }
        v.push(0.1); // repeat
        v
    }

    #[test]
    fn all_methods_produce_valid_output() {
        let data = sample_data();
        for method in QuantMethod::ALL {
            let opts = QuantOptions {
                lambda1: 0.01,
                lambda2: 4e-5,
                target_values: 4,
                ..Default::default()
            };
            let out = quantize(&data, method, &opts)
                .unwrap_or_else(|e| panic!("{method:?} failed: {e}"));
            assert_eq!(out.values.len(), data.len(), "{method:?}");
            assert!(out.l2_loss.is_finite(), "{method:?}");
            assert!(out.distinct_values() >= 1, "{method:?}");
            assert!(
                out.distinct_values() <= data.len(),
                "{method:?}: {} distinct",
                out.distinct_values()
            );
        }
    }

    #[test]
    fn count_methods_respect_target() {
        let data = sample_data();
        for method in [
            QuantMethod::KMeans,
            QuantMethod::ClusterLs,
            QuantMethod::IterativeL1,
            QuantMethod::L0,
            QuantMethod::KMeansExact,
            QuantMethod::Gmm,
            QuantMethod::DataTransform,
        ] {
            let opts = QuantOptions { target_values: 4, lambda1: 1e-4, ..Default::default() };
            let out = quantize(&data, method, &opts).unwrap();
            assert!(
                out.distinct_values() <= 4,
                "{method:?} produced {} values",
                out.distinct_values()
            );
        }
    }

    #[test]
    fn four_groups_quantize_cleanly() {
        let data = sample_data();
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let out = quantize(&data, QuantMethod::ClusterLs, &opts).unwrap();
        assert_eq!(out.distinct_values(), 4);
        // Loss per element should be tiny (groups are 0.02 wide).
        assert!(out.l2_loss / (data.len() as f64) < 1e-4, "loss={}", out.l2_loss);
    }

    #[test]
    fn l1_ls_beats_or_ties_plain_l1() {
        let data = sample_data();
        let opts = QuantOptions { lambda1: 0.02, ..Default::default() };
        let plain = quantize(&data, QuantMethod::L1, &opts).unwrap();
        let ls = quantize(&data, QuantMethod::L1LeastSquare, &opts).unwrap();
        assert!(ls.l2_loss <= plain.l2_loss + 1e-12);
    }

    #[test]
    fn quantized_values_preserve_multiplicity_structure() {
        let data = sample_data();
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let out = quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        // Equal inputs must map to equal outputs.
        for i in 0..data.len() {
            for j in 0..data.len() {
                if data[i] == data[j] {
                    assert_eq!(out.values[i], out.values[j]);
                }
            }
        }
    }

    #[test]
    fn clamp_applies() {
        let data = vec![-0.2, 0.5, 1.3, 0.5];
        let opts = QuantOptions {
            target_values: 3,
            clamp: Some((0.0, 1.0)),
            ..Default::default()
        };
        let out = quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        assert!(out.values.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn empty_input_rejected() {
        assert!(quantize(&[], QuantMethod::L1, &QuantOptions::default()).is_err());
    }

    #[test]
    fn data_containing_zero_min_value_works_for_all_methods() {
        // Regression: v_0 = 0 makes d_0 = 0 (a null column in V); the
        // digit image hits this (background pixels are exactly 0).
        let mut data = sample_data();
        data.push(0.0);
        data.push(0.0);
        for method in QuantMethod::ALL {
            let opts = QuantOptions {
                lambda1: 0.01,
                lambda2: 4e-5,
                target_values: 4,
                ..Default::default()
            };
            let out = quantize(&data, method, &opts)
                .unwrap_or_else(|e| panic!("{method:?} failed on zero-min data: {e}"));
            assert_eq!(out.values.len(), data.len(), "{method:?}");
            assert!(out.l2_loss.is_finite(), "{method:?}");
        }
    }
}
