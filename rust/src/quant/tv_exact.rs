//! Exact solver for the paper's l1 objective via dynamic programming
//! (extension / ablation — DESIGN §5).
//!
//! Observation: under the difference basis, eq 6 is exactly a **weighted
//! fused-lasso / total-variation** problem in the reconstruction `x = Vα`:
//!
//! ```text
//! min_x  ½ Σ_i c_i (x_i − ŵ_i)²  +  λ Σ_j |x_j − x_{j−1}| / d_j
//! ```
//!
//! (with `x_{−1} := 0`, so the `j = 0` term penalizes the base level —
//! the paper's α₀ — and a null first column `d_0 = 0` pins `x_0 = 0`).
//!
//! Unlike the coordinate-descent path, this is solvable **exactly** in
//! O(m) by Johnson's dynamic-programming algorithm (N. Johnson, JCGS 2013;
//! the `tf_dp` routine in glmgen): a forward pass maintains the derivative
//! of the Bellman "message" — a monotone piecewise-linear function stored
//! as a knot deque that each step clips at ±λ_t — and a backward pass
//! recovers the solution from the stored clip positions.
//!
//! This gives the repo an exact oracle for the CD solver (property-tested:
//! CD's objective converges to the DP optimum) and an ablation data point:
//! how much of the paper's information loss is the *objective*, and how
//! much is CD truncation.

use super::vmatrix::VBasis;
use crate::{Error, Result};

/// One knot of the message derivative: at position `x`, the slope of the
/// derivative increases by `da` and the intercept by `db` (derivative is
/// `Σ_{knots left of x} (da·x + db)` plus the running affine part).
#[derive(Debug, Clone, Copy)]
struct Knot {
    x: f64,
    da: f64,
    db: f64,
}

/// Exact weighted fused-lasso via forward clipping + backtracking.
///
/// * `w` — targets (sorted unique values ŵ).
/// * `cw` — per-point quadratic weights (multiplicities; ≥ 0, not all 0).
/// * `edge` — `edge[j]` is the l1 penalty on `|x_j − x_{j−1}|` with
///   `x_{−1} = 0`; `edge[0] = f64::INFINITY` pins `x_0 = 0`.
///
/// Returns the optimal `x`.
pub fn fused_lasso(w: &[f64], cw: &[f64], edge: &[f64]) -> Result<Vec<f64>> {
    let m = w.len();
    if m == 0 {
        return Err(Error::InvalidInput("fused_lasso: empty input".into()));
    }
    if cw.len() != m || edge.len() != m {
        return Err(Error::InvalidInput("fused_lasso: length mismatch".into()));
    }
    if cw.iter().any(|&c| c < 0.0) || cw.iter().all(|&c| c == 0.0) {
        return Err(Error::InvalidInput("fused_lasso: bad weights".into()));
    }

    // The message derivative after point t, BEFORE clipping at ±edge[t+1]:
    //   f'(x) = asum·x + bsum + Σ_{knots with knot.x < x} (da·x + db)
    // clipped to the interval [lo_x, hi_x] outside of which it equals
    // ∓edge (the clip value of the previous step).
    //
    // We re-derive the classic two-ended clipping with a Vec used as a
    // deque (indices lo..hi).
    let mut knots: Vec<Knot> = Vec::with_capacity(2 * m);
    // Active window [lo, hi) into `knots`.
    let mut lo = 0usize;
    let mut hi = 0usize;
    // Affine part of the derivative accumulated from quadratic terms that
    // are always active.
    let mut asum;
    let mut bsum;
    // Clip positions per step for the backward pass.
    let mut neg_pos = vec![f64::NEG_INFINITY; m]; // where f' = −edge_next
    let mut pos_pos = vec![f64::INFINITY; m]; // where f' = +edge_next

    // Step 0: message is ½c₀(x−w₀)² + edge₀·|x| (base anchored at 0).
    // Its derivative: c₀(x−w₀) + edge₀·sign(x).
    if edge[0].is_infinite() {
        // x₀ pinned to 0: derivative irrelevant; encode as the quadratic
        // c₀(x−0)·BIG — simpler: treat x₀ as free with a huge anchor.
        asum = cw[0] + 1e18;
        bsum = -cw[0] * w[0];
    } else {
        asum = cw[0];
        bsum = -cw[0] * w[0];
        if edge[0] > 0.0 {
            // |x| kink at 0: slope jumps by 2·edge₀ at x=0; derivative
            // offset −edge₀ for x<0.
            bsum -= edge[0];
            knots.push(Knot { x: 0.0, da: 0.0, db: 2.0 * edge[0] });
            hi = 1;
        }
    }

    // Derivative evaluation helpers over the active window.
    let _eval = |knots: &[Knot], lo: usize, upto: usize, asum: f64, bsum: f64, x: f64| -> f64 {
        let mut v = asum * x + bsum;
        for k in &knots[lo..upto] {
            if k.x < x {
                v += k.da * x + k.db;
            } else {
                break;
            }
        }
        v
    };

    for t in 0..m - 1 {
        let lam = edge[t + 1];
        if !lam.is_finite() {
            return Err(Error::InvalidParam("fused_lasso: interior edge must be finite".into()));
        }
        // --- clip the current derivative at −lam (left) and +lam (right).
        // Left clip: find x⁻ with f'(x⁻) = −lam.
        // Walk knots from the left accumulating the affine form.
        let mut a = asum;
        let mut b = bsum;
        let mut i = lo;
        let mut xneg = f64::NEG_INFINITY;
        loop {
            let next_x = if i < hi { knots[i].x } else { f64::INFINITY };
            // Solve a·x + b = −lam on (prev knot, next_x).
            if a > 0.0 {
                let cand = (-lam - b) / a;
                if cand <= next_x {
                    xneg = cand;
                    break;
                }
            }
            if i >= hi {
                break;
            }
            a += knots[i].da;
            b += knots[i].db;
            i += 1;
        }
        let left_keep = i; // knots before index i are consumed by the clip
        let (la, lb) = (a, b);

        // Right clip: find x⁺ with f'(x⁺) = +lam, walking from the right.
        let mut a2 = asum;
        let mut b2 = bsum;
        for k in &knots[lo..hi] {
            a2 += k.da;
            b2 += k.db;
        }
        let mut j = hi;
        let mut xpos = f64::INFINITY;
        loop {
            let prev_x = if j > lo { knots[j - 1].x } else { f64::NEG_INFINITY };
            if a2 > 0.0 {
                let cand = (lam - b2) / a2;
                if cand >= prev_x {
                    xpos = cand;
                    break;
                }
            }
            if j <= lo {
                break;
            }
            j -= 1;
            a2 -= knots[j].da;
            b2 -= knots[j].db;
        }
        let right_keep = j;
        let (ra, rb) = (a2, b2);

        neg_pos[t] = xneg;
        pos_pos[t] = xpos;

        // --- rebuild the message: clipped function + new quadratic term.
        // The clipped derivative is:
        //   −lam                      for x < xneg
        //   (affine/knot form)        for xneg ≤ x ≤ xpos
        //   +lam                      for x > xpos
        // Represent it with two synthetic boundary knots.
        let kept: Vec<Knot> = knots[left_keep.min(right_keep).max(lo)..right_keep.max(left_keep.min(right_keep).max(lo))]
            .to_vec();
        // NOTE: kept range is [left_keep, right_keep) when left_keep <=
        // right_keep; when the clips cross (xneg > xpos cannot happen for
        // monotone f'), the middle is empty.
        let kept = if left_keep <= right_keep { knots[left_keep..right_keep].to_vec() } else { kept };

        knots.clear();
        // Left boundary: derivative jumps from −lam to the affine form at
        // xneg. Encode: start flat −lam (asum=0,bsum=−lam), knot at xneg
        // switching on (la·x + lb) − (−lam).
        let new_cw = cw[t + 1];
        let new_w = w[t + 1];
        asum = new_cw; // new quadratic term derivative slope
        bsum = -new_cw * new_w - lam; // flat −lam tail + new term intercept
        if xneg.is_finite() {
            knots.push(Knot { x: xneg, da: la, db: lb + lam });
        } else {
            // No left clip (f' everywhere > −lam as x→−∞ impossible when
            // a>0; only if message already flat) — fall back: activate
            // affine immediately.
            bsum += lam; // undo tail
            asum += la;
            bsum += lb;
        }
        for k in kept {
            knots.push(k);
        }
        if xpos.is_finite() {
            // At xpos the affine form (ra·x + rb) switches off, replaced by
            // flat +lam.
            knots.push(Knot { x: xpos, da: -ra, db: lam - rb });
        }
        lo = 0;
        hi = knots.len();
    }

    // Final minimization: solve f'(x) = 0 on the last message.
    let mut a = asum;
    let mut b = bsum;
    let mut i = lo;
    let mut xstar = if a > 0.0 { -b / a } else { 0.0 };
    loop {
        let next_x = if i < hi { knots[i].x } else { f64::INFINITY };
        if a > 0.0 {
            let cand = -b / a;
            if cand <= next_x {
                xstar = cand;
                break;
            }
        }
        if i >= hi {
            break;
        }
        a += knots[i].da;
        b += knots[i].db;
        i += 1;
    }

    // Backward pass: clamp into the successive clip windows.
    let mut x = vec![0.0; m];
    x[m - 1] = xstar;
    for t in (0..m - 1).rev() {
        x[t] = x[t + 1].clamp(
            if neg_pos[t].is_finite() { neg_pos[t] } else { x[t + 1] },
            if pos_pos[t].is_finite() { pos_pos[t] } else { x[t + 1] },
        );
    }
    if edge[0].is_infinite() {
        x[0] = 0.0;
    }
    Ok(x)
}

/// Solve the paper's eq-6 objective exactly: returns the optimal
/// reconstruction over the unique values (same objective the CD solver
/// optimizes, ½-scaled LS, λ‖α‖₁).
pub fn solve_tv_exact(basis: &VBasis, w: &[f64], lambda: f64) -> Result<Vec<f64>> {
    if lambda < 0.0 {
        return Err(Error::InvalidParam("tv_exact: λ must be ≥ 0".into()));
    }
    let m = basis.m();
    if w.len() != m {
        return Err(Error::InvalidInput("tv_exact: dim mismatch".into()));
    }
    let d = basis.diffs();
    let cw = vec![1.0; m];
    let edge: Vec<f64> = d
        .iter()
        .map(|&dj| if dj == 0.0 { f64::INFINITY } else { lambda / dj.abs() })
        .collect();
    fused_lasso(w, &cw, &edge)
}

/// The eq-6 objective value of a reconstruction (½LS + λ‖α‖₁ with α
/// recovered from the level jumps).
pub fn objective_of_reconstruction(basis: &VBasis, w: &[f64], x: &[f64], lambda: f64) -> f64 {
    let d = basis.diffs();
    let mut ls = 0.0;
    let mut l1 = 0.0;
    let mut prev = 0.0;
    for i in 0..w.len() {
        ls += (w[i] - x[i]) * (w[i] - x[i]);
        if d[i] != 0.0 {
            l1 += ((x[i] - prev) / d[i]).abs();
        }
        prev = x[i];
    }
    0.5 * ls + lambda * l1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;
    use crate::quant::lasso;

    fn random_basis(m: usize, seed: u64) -> (VBasis, Vec<f64>) {
        let mut rng = Pcg32::seeded(seed);
        let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(0.5, 5.0)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        let b = VBasis::new(&v);
        (b, v)
    }

    #[test]
    fn zero_lambda_interpolates() {
        let (b, v) = random_basis(32, 1);
        let x = solve_tv_exact(&b, &v, 0.0).unwrap();
        for (xi, vi) in x.iter().zip(&v) {
            assert!((xi - vi).abs() < 1e-9, "{xi} vs {vi}");
        }
    }

    #[test]
    fn huge_lambda_flattens() {
        let (b, v) = random_basis(24, 2);
        let x = solve_tv_exact(&b, &v, 1e6).unwrap();
        let distinct = crate::linalg::stats::distinct_count_exact(&x);
        assert!(distinct <= 2, "distinct={distinct} x={x:?}");
    }

    #[test]
    fn never_worse_than_cd() {
        // The DP optimum must match or beat converged CD on the shared
        // objective.
        for seed in [3u64, 4, 5, 6] {
            let (b, v) = random_basis(60, seed);
            for lambda in [0.01, 0.1, 1.0] {
                let x = solve_tv_exact(&b, &v, lambda).unwrap();
                let exact_obj = objective_of_reconstruction(&b, &v, &x, lambda);
                let cfg = lasso::LassoConfig {
                    lambda1: lambda,
                    max_epochs: 5000,
                    tol: 1e-12,
                    support_patience: 0,
                    ..Default::default()
                };
                let sol = lasso::solve(&b, &v, &cfg, None).unwrap();
                let cd_obj =
                    objective_of_reconstruction(&b, &v, &b.apply(&sol.alpha), lambda);
                assert!(
                    exact_obj <= cd_obj + 1e-6 * (1.0 + cd_obj),
                    "seed={seed} λ={lambda}: exact {exact_obj} > CD {cd_obj}"
                );
            }
        }
    }

    #[test]
    fn matches_cd_closely_when_cd_converges() {
        let (b, v) = random_basis(40, 7);
        let lambda = 0.2;
        let x = solve_tv_exact(&b, &v, lambda).unwrap();
        let cfg = lasso::LassoConfig {
            lambda1: lambda,
            max_epochs: 20_000,
            tol: 1e-13,
            support_patience: 0,
            ..Default::default()
        };
        let sol = lasso::solve(&b, &v, &cfg, None).unwrap();
        let cd = b.apply(&sol.alpha);
        let exact_obj = objective_of_reconstruction(&b, &v, &x, lambda);
        let cd_obj = objective_of_reconstruction(&b, &v, &cd, lambda);
        assert!((exact_obj - cd_obj).abs() < 1e-4 * (1.0 + cd_obj), "{exact_obj} vs {cd_obj}");
    }

    #[test]
    fn pinned_base_when_first_diff_zero() {
        // v starts at exactly 0 → d₀ = 0 → x₀ must be 0.
        let v = vec![0.0, 1.0, 1.1, 3.0];
        let b = VBasis::new(&v);
        let x = solve_tv_exact(&b, &v, 0.05).unwrap();
        assert_eq!(x[0], 0.0);
    }

    #[test]
    fn rejects_bad_inputs() {
        let (b, v) = random_basis(8, 9);
        assert!(solve_tv_exact(&b, &v, -1.0).is_err());
        assert!(solve_tv_exact(&b, &v[..4], 0.1).is_err());
        assert!(fused_lasso(&[], &[], &[]).is_err());
        assert!(fused_lasso(&[1.0], &[0.0], &[0.1]).is_err());
    }

    #[test]
    fn monotone_sparsity_in_lambda() {
        let (b, v) = random_basis(48, 10);
        let mut prev = usize::MAX;
        for lambda in [0.01, 0.1, 1.0, 10.0, 100.0] {
            let x = solve_tv_exact(&b, &v, lambda).unwrap();
            let distinct = crate::linalg::stats::distinct_count(&x, 9);
            assert!(
                distinct <= prev.saturating_add(1),
                "λ={lambda}: distinct went {prev} -> {distinct}"
            );
            prev = distinct;
        }
    }
}
