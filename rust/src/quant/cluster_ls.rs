//! Cluster-based least-square quantization (paper Algorithm 3, eq 17–20).
//!
//! The general target (eq 17) jointly optimizes a one-hot membership matrix
//! `E` and per-cluster values. The paper's approximation: obtain `E` by
//! k-means on the unique values, then solve the remaining least squares for
//! the values analytically (eq 19–20) over the cumulative matrix `V̂*`
//! filled with the base value `v = mean(ŵ)`.
//!
//! "From the perspective of clustering methods, algorithm 3 could be viewed
//! as an improvement of k-means clustering quantization … it alternatively
//! computes the value of the cluster that produces the smallest least
//! square distance from the original" — i.e. the cluster representative is
//! the LS-optimal level for the chosen partition, instead of whatever the
//! final Lloyd centroid happened to be.
//!
//! Two solver paths are provided and cross-checked:
//!
//! * [`solve_cluster_ls`] — O(m) fast path: 1-d clusters of sorted values
//!   are contiguous segments, so the LS values are (weighted) segment
//!   means;
//! * [`solve_cluster_ls_normal_eq`] — the paper's literal eq 20
//!   `α = (V̂*ᵀV̂*)⁻¹ V̂*ᵀ ŵ` over the materialized cumulative matrix.

use super::vmatrix::VBasis;
use crate::cluster::kmeans::{assign_sorted, kmeans_1d, KMeansConfig};
use crate::linalg::cholesky::least_squares;
use crate::linalg::matrix::Matrix;
use crate::linalg::stats;
use crate::{Error, Result};

/// Configuration for Algorithm 3.
#[derive(Debug, Clone)]
pub struct ClusterLsConfig {
    /// Desired number of distinct values `l`.
    pub l: usize,
    /// Inner k-means settings.
    pub kmeans: KMeansConfig,
    /// Weight the LS by value multiplicities (extension; the paper's eq 19
    /// is unweighted over ŵ, which `false` reproduces).
    pub weighted: bool,
}

impl Default for ClusterLsConfig {
    fn default() -> Self {
        ClusterLsConfig { l: 16, kmeans: KMeansConfig::default(), weighted: false }
    }
}

/// Output of Algorithm 3.
#[derive(Debug, Clone)]
pub struct ClusterLsSolution {
    /// Per-level reconstruction (length m, piecewise constant over the
    /// cluster segments).
    pub reconstruction: Vec<f64>,
    /// The LS-optimal cluster values (sorted ascending).
    pub levels: Vec<f64>,
    /// Segment boundaries: `boundaries[c]` is the first level index of
    /// cluster `c` (ascending, `boundaries[0] == 0`).
    pub boundaries: Vec<usize>,
    /// Lloyd iterations consumed by the inner k-means.
    pub iterations: usize,
    /// Empty-cluster repair events in the inner k-means.
    pub empty_cluster_events: usize,
}

/// Derive contiguous segment boundaries on the *sorted* unique values from
/// a k-means model: the midpoints between adjacent sorted centroids cut the
/// value axis into `k` intervals.
fn boundaries_from_centroids(values: &[f64], centroids: &[f64]) -> Vec<usize> {
    let mut boundaries = vec![0usize];
    let mut prev = 0usize;
    for c in 1..centroids.len() {
        let mid = 0.5 * (centroids[c - 1] + centroids[c]);
        // First index with value >= mid.
        let idx = values.partition_point(|&v| v < mid).max(prev);
        if idx > prev && idx < values.len() {
            boundaries.push(idx);
            prev = idx;
        }
    }
    boundaries
}

/// Fast-path Algorithm 3.
pub fn solve_cluster_ls(
    basis: &VBasis,
    w: &[f64],
    counts: Option<&[f64]>,
    cfg: &ClusterLsConfig,
) -> Result<ClusterLsSolution> {
    let m = basis.m();
    if w.len() != m {
        return Err(Error::InvalidInput(format!(
            "cluster_ls: basis dim {m} vs target dim {}",
            w.len()
        )));
    }
    if cfg.l == 0 {
        return Err(Error::InvalidParam("cluster_ls: l must be ≥ 1".into()));
    }

    // Step 2: k-means with l clusters on the unique values.
    let km_cfg = KMeansConfig { k: cfg.l.min(m), ..cfg.kmeans.clone() };
    let km = kmeans_1d(basis.values(), if cfg.weighted { counts } else { None }, &km_cfg)?;

    // Steps 3–4: membership matrix E, expressed as contiguous segments of
    // the sorted values.
    let boundaries = boundaries_from_centroids(basis.values(), &km.centroids);

    // Step 5: LS-optimal value per cluster = (weighted) segment mean.
    let mut levels = Vec::with_capacity(boundaries.len());
    let mut reconstruction = vec![0.0; m];
    for (c, &start) in boundaries.iter().enumerate() {
        let end = boundaries.get(c + 1).copied().unwrap_or(m);
        let (mut num, mut den) = (0.0, 0.0);
        for i in start..end {
            let wt = if cfg.weighted { counts.map_or(1.0, |cs| cs[i]) } else { 1.0 };
            num += wt * w[i];
            den += wt;
        }
        let level = if den > 0.0 { num / den } else { 0.0 };
        levels.push(level);
        for r in &mut reconstruction[start..end] {
            *r = level;
        }
    }

    Ok(ClusterLsSolution {
        reconstruction,
        levels,
        boundaries,
        iterations: km.iterations,
        empty_cluster_events: km.empty_cluster_events,
    })
}

/// Paper-literal eq 19–20: build `V̂*` (cumulative one-hot columns filled
/// with `v = mean(ŵ)`) and solve the normal equations. Oracle for the fast
/// path; O(m·l²).
pub fn solve_cluster_ls_normal_eq(
    basis: &VBasis,
    w: &[f64],
    cfg: &ClusterLsConfig,
) -> Result<ClusterLsSolution> {
    let m = basis.m();
    if w.len() != m {
        return Err(Error::InvalidInput("cluster_ls: dim mismatch".into()));
    }
    let km_cfg = KMeansConfig { k: cfg.l.min(m), ..cfg.kmeans.clone() };
    let km = kmeans_1d(basis.values(), None, &km_cfg)?;
    let boundaries = boundaries_from_centroids(basis.values(), &km.centroids);
    let l = boundaries.len();

    // Cluster index per level (E of eq 18, via the contiguous segments).
    let cluster_of = |i: usize| -> usize {
        match boundaries.binary_search(&i) {
            Ok(c) => c,
            Err(c) => c - 1,
        }
    };

    // V̂*: row i has `v` in columns 0..=cluster_of(i) (the paper's
    // cumulative lower-staircase with base value v = mean(ŵ)).
    let v_base = stats::mean(w);
    let vh = Matrix::from_fn(m, l, |i, j| if j <= cluster_of(i) { v_base } else { 0.0 });
    let alpha = least_squares(&vh, w)?;

    // w* = V̂* α (eq at Algorithm 3 step 6).
    let reconstruction = vh.matvec(&alpha)?;
    let mut levels: Vec<f64> = boundaries
        .iter()
        .map(|&s| reconstruction[s])
        .collect();
    levels.sort_by(|a, b| a.partial_cmp(b).unwrap());

    Ok(ClusterLsSolution {
        reconstruction,
        levels,
        boundaries,
        iterations: km.iterations,
        empty_cluster_events: km.empty_cluster_events,
    })
}

/// Plain k-means quantization of the unique values (the baseline Algorithm
/// 3 improves on): each level is replaced by its cluster's *centroid*
/// (weighted by multiplicities, as conventional quantizers cluster the full
/// vector).
pub fn kmeans_quantize_levels(
    basis: &VBasis,
    counts: Option<&[f64]>,
    cfg: &KMeansConfig,
) -> Result<(Vec<f64>, usize, usize)> {
    let km = kmeans_1d(basis.values(), counts, cfg)?;
    let rec: Vec<f64> = basis
        .values()
        .iter()
        .map(|&v| km.centroids[assign_sorted(v, &km.centroids)])
        .collect();
    Ok((rec, km.iterations, km.empty_cluster_events))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;
    use crate::linalg::stats::l2_loss;

    fn random_basis(m: usize, seed: u64) -> (VBasis, Vec<f64>) {
        let mut rng = Pcg32::seeded(seed);
        let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 100.0)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        let b = VBasis::new(&v);
        (b, v)
    }

    #[test]
    fn produces_exactly_l_levels_for_separated_data() {
        let v = vec![1.0, 1.1, 5.0, 5.1, 9.0, 9.1];
        let b = VBasis::new(&v);
        let sol = solve_cluster_ls(&b, &v, None, &ClusterLsConfig { l: 3, ..Default::default() })
            .unwrap();
        assert_eq!(sol.levels.len(), 3);
        assert!((sol.levels[0] - 1.05).abs() < 1e-9);
        assert!((sol.levels[2] - 9.05).abs() < 1e-9);
    }

    #[test]
    fn fast_matches_normal_eq() {
        for seed in [1u64, 2, 3] {
            let (b, v) = random_basis(40, seed);
            let cfg = ClusterLsConfig {
                l: 7,
                kmeans: KMeansConfig { seed, ..Default::default() },
                ..Default::default()
            };
            let fast = solve_cluster_ls(&b, &v, None, &cfg).unwrap();
            let slow = solve_cluster_ls_normal_eq(&b, &v, &cfg).unwrap();
            assert_eq!(fast.boundaries, slow.boundaries);
            for (f, s) in fast.reconstruction.iter().zip(&slow.reconstruction) {
                assert!((f - s).abs() < 1e-6, "{f} vs {s}");
            }
        }
    }

    #[test]
    fn never_worse_than_kmeans_on_unique_values() {
        // The paper's headline for Algorithm 3: LS values are optimal for
        // the chosen partition, so (unweighted) loss over ŵ can only match
        // or beat plain unweighted k-means quantization with the same
        // partition source.
        for seed in [4u64, 5, 6, 7] {
            let (b, v) = random_basis(64, seed);
            let km_cfg = KMeansConfig { k: 9, seed, ..Default::default() };
            let cls = solve_cluster_ls(
                &b,
                &v,
                None,
                &ClusterLsConfig { l: 9, kmeans: km_cfg.clone(), ..Default::default() },
            )
            .unwrap();
            let (km_rec, _, _) = kmeans_quantize_levels(&b, None, &km_cfg).unwrap();
            let ls_loss = l2_loss(&cls.reconstruction, &v);
            let km_loss = l2_loss(&km_rec, &v);
            assert!(
                ls_loss <= km_loss + 1e-9,
                "seed={seed}: cluster_ls {ls_loss} > kmeans {km_loss}"
            );
        }
    }

    #[test]
    fn reconstruction_piecewise_constant_on_segments() {
        let (b, v) = random_basis(32, 8);
        let sol = solve_cluster_ls(&b, &v, None, &ClusterLsConfig { l: 5, ..Default::default() })
            .unwrap();
        for (c, &start) in sol.boundaries.iter().enumerate() {
            let end = sol.boundaries.get(c + 1).copied().unwrap_or(b.m());
            for i in start..end {
                assert_eq!(sol.reconstruction[i], sol.reconstruction[start]);
            }
        }
    }

    #[test]
    fn weighted_mode_shifts_levels() {
        let v = vec![0.0, 1.0, 2.0];
        let b = VBasis::new(&v);
        let counts = vec![1.0, 1.0, 100.0];
        let cfg1 = ClusterLsConfig { l: 1, ..Default::default() };
        let unweighted = solve_cluster_ls(&b, &v, Some(&counts), &cfg1).unwrap();
        let cfgw = ClusterLsConfig { l: 1, weighted: true, ..Default::default() };
        let weighted = solve_cluster_ls(&b, &v, Some(&counts), &cfgw).unwrap();
        assert!((unweighted.levels[0] - 1.0).abs() < 1e-9);
        assert!(weighted.levels[0] > 1.8, "weighted level {}", weighted.levels[0]);
    }

    #[test]
    fn boundaries_start_at_zero_and_ascend() {
        let (b, v) = random_basis(50, 9);
        let sol = solve_cluster_ls(&b, &v, None, &ClusterLsConfig { l: 8, ..Default::default() })
            .unwrap();
        assert_eq!(sol.boundaries[0], 0);
        assert!(sol.boundaries.windows(2).all(|p| p[0] < p[1]));
        assert!(*sol.boundaries.last().unwrap() < b.m());
    }

    #[test]
    fn l_geq_m_is_lossless() {
        let (b, v) = random_basis(12, 10);
        let sol = solve_cluster_ls(
            &b,
            &v,
            None,
            &ClusterLsConfig { l: 100, ..Default::default() },
        )
        .unwrap();
        assert!(l2_loss(&sol.reconstruction, &v) < 1e-9);
    }

    #[test]
    fn rejects_bad_params() {
        let (b, v) = random_basis(8, 11);
        assert!(
            solve_cluster_ls(&b, &v, None, &ClusterLsConfig { l: 0, ..Default::default() })
                .is_err()
        );
        assert!(solve_cluster_ls(
            &b,
            &v[..3],
            None,
            &ClusterLsConfig::default()
        )
        .is_err());
    }
}
