//! The structured difference basis `V` (paper §3.2).
//!
//! For sorted distinct values `v_0 < v_1 < … < v_{m−1}` the paper defines
//! the lower-triangular matrix (0-indexed here; `v_{-1} := 0`):
//!
//! ```text
//! V[i][j] = d_j  for j ≤ i,  0 otherwise,   where  d_j = v_j − v_{j−1}
//! ```
//!
//! so `(Vα)_i = Σ_{j≤i} d_j α_j` is a prefix sum: reconstruction is a
//! *piecewise-constant* vector whose level only changes at indices `j` with
//! `α_j ≠ 0` — sparsity of `α` is exactly value sharing. Setting `α = 𝟙`
//! reproduces `v` with zero loss, which is the paper's preferred CD starting
//! point (§3.2.1).
//!
//! **The key performance fact** (DESIGN §3): `V` never needs to be
//! materialized. All the solver primitives have closed forms:
//!
//! * `Vα`          — O(m) prefix sum of `d ⊙ α`;
//! * `Vᵀr`         — O(m) suffix sums: `(Vᵀr)_j = d_j · Σ_{i≥j} r_i`;
//! * `(VᵀV)_{jk}`  — `d_j d_k · (m − max(j,k))` (the paper's eq 12);
//! * `‖V_{·j}‖²`   — `d_j² · (m − j)`.
//!
//! The dense counterparts live here too and are used (a) to cross-check the
//! structured forms in tests and (b) as the "naïve" baseline the §Perf
//! benchmarks compare against.

use crate::linalg::matrix::Matrix;
use crate::linalg::scalar::Scalar;

/// Structured representation of the difference basis for a sorted value
/// vector. Generic over the element precision ([`Scalar`]); the default
/// `f64` instantiation is the reference lane and `VBasis<f32>` carries the
/// single-precision fast path.
#[derive(Debug, Clone)]
pub struct VBasis<T: Scalar = f64> {
    /// The sorted distinct values `v` (ascending).
    v: Vec<T>,
    /// First differences `d_j = v_j − v_{j−1}` with `d_0 = v_0`.
    d: Vec<T>,
}

impl<T: Scalar> VBasis<T> {
    /// Build from sorted distinct values. Debug-asserts strict ascending
    /// order (guaranteed by [`crate::quant::unique::UniqueDecomp`]).
    pub fn new(values: &[T]) -> Self {
        debug_assert!(values.windows(2).all(|p| p[0] < p[1]), "values must be sorted strictly ascending");
        let mut d = Vec::with_capacity(values.len());
        let mut prev = T::ZERO;
        for &x in values {
            d.push(x - prev);
            prev = x;
        }
        VBasis { v: values.to_vec(), d }
    }

    /// Dimension `m`.
    pub fn m(&self) -> usize {
        self.v.len()
    }

    /// The original sorted values.
    pub fn values(&self) -> &[T] {
        &self.v
    }

    /// First differences `d` (`d_0 = v_0`).
    pub fn diffs(&self) -> &[T] {
        &self.d
    }

    /// `Vα` — O(m) prefix-sum reconstruction.
    pub fn apply(&self, alpha: &[T]) -> Vec<T> {
        debug_assert_eq!(alpha.len(), self.m());
        let mut out = Vec::with_capacity(self.m());
        let mut acc = T::ZERO;
        for (dj, aj) in self.d.iter().zip(alpha) {
            acc += *dj * *aj;
            out.push(acc);
        }
        out
    }

    /// `Vα` written into a caller-provided buffer (hot-path variant).
    pub fn apply_into(&self, alpha: &[T], out: &mut [T]) {
        debug_assert_eq!(alpha.len(), self.m());
        debug_assert_eq!(out.len(), self.m());
        let mut acc = T::ZERO;
        for ((o, dj), aj) in out.iter_mut().zip(&self.d).zip(alpha) {
            acc += *dj * *aj;
            *o = acc;
        }
    }

    /// `Vᵀ r` — O(m) via suffix sums.
    pub fn t_apply(&self, r: &[T]) -> Vec<T> {
        debug_assert_eq!(r.len(), self.m());
        let mut out = vec![T::ZERO; self.m()];
        let mut suffix = T::ZERO;
        for j in (0..self.m()).rev() {
            suffix += r[j];
            out[j] = self.d[j] * suffix;
        }
        out
    }

    /// Gram entry `(VᵀV)_{jk} = d_j d_k (m − max(j,k))` — paper eq 12.
    #[inline]
    pub fn gram_entry(&self, j: usize, k: usize) -> T {
        let m = self.m();
        self.d[j] * self.d[k] * T::from_usize(m - j.max(k))
    }

    /// Squared column norm `‖V_{·j}‖² = d_j² (m − j)`.
    #[inline]
    pub fn col_norm_sq(&self, j: usize) -> T {
        let m = self.m();
        self.d[j] * self.d[j] * T::from_usize(m - j)
    }

    /// Weighted squared column norm `Σ_{i≥j} c_i d_j²` for per-row weights
    /// `c` (multiplicity-weighted variants).
    pub fn col_norm_sq_weighted(&self, j: usize, suffix_weight: &[T]) -> T {
        self.d[j] * self.d[j] * suffix_weight[j]
    }

    /// Fill `out` with every squared column norm ([`Self::col_norm_sq`]),
    /// one pass, no allocation. The CD solvers cache these once per solve
    /// instead of recomputing `d_j²(m−j)` for every coordinate of every
    /// epoch; each entry is the same pure expression as `col_norm_sq(j)`,
    /// so caching is bitwise-neutral.
    pub fn col_norms_into(&self, out: &mut [T]) {
        let m = self.m();
        debug_assert_eq!(out.len(), m);
        for (j, (o, &dj)) in out.iter_mut().zip(&self.d).enumerate() {
            *o = dj * dj * T::from_usize(m - j);
        }
    }

    /// Reconstruction from a sparse support: `V_{·S} β` where `support` is
    /// sorted ascending. O(m + |S|).
    pub fn apply_support(&self, support: &[usize], beta: &[T]) -> Vec<T> {
        debug_assert_eq!(support.len(), beta.len());
        debug_assert!(support.windows(2).all(|p| p[0] < p[1]));
        let m = self.m();
        let mut out = vec![T::ZERO; m];
        let mut acc = T::ZERO;
        let mut s = 0;
        for (i, o) in out.iter_mut().enumerate() {
            if s < support.len() && support[s] == i {
                acc += self.d[support[s]] * beta[s];
                s += 1;
            }
            *o = acc;
        }
        out
    }
}

/// Dense materializations exist only on the f64 reference lane — they feed
/// the `Matrix`-based oracles and the naïve §Perf baselines, which are
/// double-precision by design.
impl VBasis<f64> {
    /// Materialize the dense `m × m` matrix. For tests and the naïve
    /// baseline only — O(m²) memory.
    pub fn dense(&self) -> Matrix {
        let m = self.m();
        Matrix::from_fn(m, m, |i, j| if j <= i { self.d[j] } else { 0.0 })
    }

    /// Dense `m × h` sub-matrix of the support columns (eq 7's `V*`), for
    /// the naïve refit path and tests.
    pub fn dense_support(&self, support: &[usize]) -> Matrix {
        let m = self.m();
        Matrix::from_fn(m, support.len(), |i, jj| {
            let j = support[jj];
            if j <= i {
                self.d[j]
            } else {
                0.0
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn basis() -> VBasis {
        VBasis::new(&[-1.5, 0.25, 1.0, 4.0, 9.5])
    }

    #[test]
    fn diffs_match_definition() {
        let b = basis();
        assert_eq!(b.diffs()[0], -1.5);
        assert!((b.diffs()[1] - 1.75).abs() < 1e-15);
        assert!((b.diffs()[4] - 5.5).abs() < 1e-15);
    }

    #[test]
    fn all_ones_reconstructs_values() {
        let b = basis();
        let rec = b.apply(&vec![1.0; b.m()]);
        for (r, v) in rec.iter().zip(b.values()) {
            assert!((r - v).abs() < 1e-12);
        }
    }

    #[test]
    fn apply_matches_dense() {
        let b = basis();
        let alpha = [0.3, -1.0, 0.0, 2.0, 0.7];
        let fast = b.apply(&alpha);
        let slow = b.dense().matvec(&alpha).unwrap();
        for (f, s) in fast.iter().zip(&slow) {
            assert!((f - s).abs() < 1e-12);
        }
    }

    #[test]
    fn apply_into_matches_apply() {
        let b = basis();
        let alpha = [1.0, 0.5, 0.0, -2.0, 3.0];
        let mut buf = vec![0.0; b.m()];
        b.apply_into(&alpha, &mut buf);
        assert_eq!(buf, b.apply(&alpha));
    }

    #[test]
    fn t_apply_matches_dense() {
        let b = basis();
        let r = [0.1, -0.4, 2.0, 0.0, 1.0];
        let fast = b.t_apply(&r);
        let slow = b.dense().t_matvec(&r).unwrap();
        for (f, s) in fast.iter().zip(&slow) {
            assert!((f - s).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_matches_dense() {
        let b = basis();
        let g = b.dense().gram();
        for j in 0..b.m() {
            for k in 0..b.m() {
                assert!(
                    (b.gram_entry(j, k) - g[(j, k)]).abs() < 1e-12,
                    "gram mismatch at ({j},{k})"
                );
            }
            assert!((b.col_norm_sq(j) - g[(j, j)]).abs() < 1e-12);
        }
    }

    #[test]
    fn sparsity_is_value_sharing() {
        let b = basis();
        // Zero out α_2: levels 1 and 2 must share a value.
        let mut alpha = vec![1.0; b.m()];
        alpha[2] = 0.0;
        let rec = b.apply(&alpha);
        assert_eq!(rec[1], rec[2]);
        assert_ne!(rec[0], rec[1]);
        assert_ne!(rec[2], rec[3]);
    }

    #[test]
    fn apply_support_matches_dense_support() {
        let b = basis();
        let support = [0usize, 2, 4];
        let beta = [1.2, -0.5, 0.9];
        let fast = b.apply_support(&support, &beta);
        let slow = b.dense_support(&support).matvec(&beta).unwrap();
        for (f, s) in fast.iter().zip(&slow) {
            assert!((f - s).abs() < 1e-12);
        }
    }

    #[test]
    fn support_excluding_zero_starts_at_zero() {
        let b = basis();
        let rec = b.apply_support(&[2, 3], &[1.0, 1.0]);
        assert_eq!(rec[0], 0.0);
        assert_eq!(rec[1], 0.0);
        assert_ne!(rec[2], 0.0);
    }

    #[test]
    fn negative_values_handled() {
        let b = VBasis::new(&[-5.0, -2.0, -1.0]);
        let rec = b.apply(&[1.0, 1.0, 1.0]);
        assert!((rec[0] + 5.0).abs() < 1e-12);
        assert!((rec[2] + 1.0).abs() < 1e-12);
    }
}
