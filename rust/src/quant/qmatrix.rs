//! Quantized-compute matrices: matvec/GEMV straight off packed index
//! planes (S25).
//!
//! The serve path's payload has been codebook-native since PR 5 — levels
//! plus an index map — but downstream compute still decoded to dense
//! first. [`QMatrix`] closes that gap, following the lm-nslsqr shape: a
//! matrix stored as per-group ([`Grouping`]) [`PackedCodebook`] planes
//! that **computes** `y = x·W` directly on the ⌈log₂ k⌉-bit indices
//! ([`crate::linalg::kernels::matvec_levels`] /
//! [`crate::linalg::kernels::matvec_rowmajor_levels`]), so the dense
//! matrix is never materialized and memory traffic scales with the packed
//! bits, not 64 bits per entry.
//!
//! On top sits the **residual cascade** ([`QMatrix::residual_levels`],
//! the constructor for [`crate::quant::api::Plan::Cascade`]): quantize at
//! `2^bits[0]` levels, re-quantize the residual at `2^bits[1]`, …, until
//! the relative Frobenius norm of the residual reaches `norm_tol`. Each
//! level adds one packed plane per group; reconstruction (and matvec) sum
//! the planes. Accounting folds through [`CompressionStats::stack`]
//! within a group (per-index bits add — the cascade-honest rule) and
//! [`CompressionStats::aggregate`] across groups.
//!
//! ## The bitwise contract (f64 lane)
//!
//! A single-level f64 `matvec` is **bit-for-bit identical** to
//! decode-then-dense (`x` as a 1×rows matrix times [`QMatrix::decode`],
//! via `Matrix::matmul`'s ikj loop): per-column groups reduce with a
//! strict single accumulator in row order, and per-row/per-tensor groups
//! multiply `x[i]·levels[idx]` first and add in row order — both exactly
//! the dense arithmetic sequence. A multi-level f64 matvec is bitwise
//! equal to summing the *per-level* dense matvecs in cascade order (the
//! planes are separate summands; summing the decoded matrices first would
//! reassociate). The f32 lane reassociates per level
//! ([`crate::linalg::kernels::accum_by_index`]) and is tolerance-gated.
//!
//! `cargo bench --bench qmatvec` races the packed path against dense
//! decode-then-matvec and emits `BENCH_qmatvec.json` (throughput vs bits,
//! plus the cascade's error-vs-cumulative-bits series).

use super::api::{self, OutputForm};
use super::codebook::{CompressionStats, PackedCodebook};
use super::pipeline::batch_map;
use super::tensor::Grouping;
use super::types::{QuantMethod, QuantOptions};
use crate::linalg::kernels;
use crate::linalg::matrix::Matrix;
use crate::linalg::scalar::Scalar;
use crate::{Error, Result};
use std::sync::Arc;

/// A scalar-quantized matrix stored as per-group packed codebook planes
/// that computes matvec/GEMV without materializing the dense matrix.
///
/// Shape is `rows × cols` acting on the right of a row vector
/// (`y = x·W`, `x.len() == rows`, `y.len() == cols`) — the `nn::mlp`
/// forward convention. Groups follow [`Grouping`]: one plane set for the
/// whole matrix (row-major), one per row, or one per column; each group
/// holds one [`PackedCodebook`] per cascade level.
///
/// ```
/// use sqlsq::linalg::matrix::Matrix;
/// use sqlsq::quant::{tensor::Grouping, QMatrix, QuantMethod, QuantOptions};
///
/// let w = Matrix::from_fn(64, 8, |i, j| ((i * 7 + j) % 5) as f64 * 0.1);
/// // 2-bit base plane, then a 2-bit plane over the residual.
/// let qm = QMatrix::residual_levels(
///     &w, Grouping::PerColumn, QuantMethod::KMeans,
///     &QuantOptions::default(), &[2, 2], 0.0,
/// ).unwrap();
/// let y = qm.matvec(&vec![1.0; 64]); // straight off the packed planes
/// assert_eq!(y.len(), 8);
/// // Cascade accounting STACKS: the planes cover the same elements, so
/// // packed index bits add per level instead of taking the max.
/// assert!(qm.stats().bits_per_idx_packed > 2);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct QMatrix<T: Scalar = f64> {
    rows: usize,
    cols: usize,
    grouping: Grouping,
    groups: Vec<Vec<PackedCodebook<T>>>,
}

/// Per-level build record of a residual cascade ([`QMatrix::residual_levels`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CascadeLevel {
    /// Index bit-width this level was quantized at (`2^bits` target levels).
    pub bits: u32,
    /// Cumulative packed index bits per element through this level.
    pub cum_bits: u32,
    /// Relative Frobenius residual norm after subtracting this level.
    pub rel_error: f64,
}

impl<T: Scalar> QMatrix<T> {
    /// Rebuild from raw parts (the jsonio decode path), validating shape:
    /// non-degenerate dims, the group count implied by the grouping, a
    /// non-empty plane list per group, every plane covering the group's
    /// element count, the packed width matching `⌈log₂ k⌉`, and every
    /// index in range — so `matvec` never faults on wire data.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        grouping: Grouping,
        groups: Vec<Vec<PackedCodebook<T>>>,
    ) -> Result<QMatrix<T>> {
        if rows == 0 || cols == 0 {
            return Err(Error::InvalidInput("qmatrix: empty matrix".into()));
        }
        let want_groups = match grouping {
            Grouping::PerTensor => 1,
            Grouping::PerRow => rows,
            Grouping::PerColumn => cols,
        };
        if groups.len() != want_groups {
            return Err(Error::InvalidInput(format!(
                "qmatrix: {} groups, expected {want_groups} for {grouping:?} over {rows}×{cols}",
                groups.len()
            )));
        }
        let group_len = match grouping {
            Grouping::PerTensor => rows * cols,
            Grouping::PerRow => cols,
            Grouping::PerColumn => rows,
        };
        for (g, planes) in groups.iter().enumerate() {
            if planes.is_empty() {
                return Err(Error::InvalidInput(format!(
                    "qmatrix: group {g} has no levels"
                )));
            }
            for (l, cb) in planes.iter().enumerate() {
                if cb.k() == 0 {
                    return Err(Error::InvalidInput(format!(
                        "qmatrix: group {g} level {l} has an empty codebook"
                    )));
                }
                if cb.len() != group_len {
                    return Err(Error::InvalidInput(format!(
                        "qmatrix: group {g} level {l} covers {} elements, expected {group_len}",
                        cb.len()
                    )));
                }
                // Accept the honest packed width (0 bits at k = 1) and,
                // for backward compatibility, the legacy 1-bit
                // single-level planes older wire payloads carry.
                if cb.indices.bits() != kernels::packed_bits_for(cb.k())
                    && !(cb.k() == 1 && cb.indices.bits() == 1)
                {
                    return Err(Error::InvalidInput(format!(
                        "qmatrix: group {g} level {l} packs {} bits for k={}",
                        cb.indices.bits(),
                        cb.k()
                    )));
                }
                if cb.indices.unpack().into_iter().any(|i| i as usize >= cb.k()) {
                    return Err(Error::InvalidInput(format!(
                        "qmatrix: group {g} level {l} has an index out of range"
                    )));
                }
            }
        }
        Ok(QMatrix { rows, cols, grouping, groups })
    }

    /// Input dimension (`x.len()` for [`QMatrix::matvec`]).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Output dimension.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The grouping the planes were built under.
    pub fn grouping(&self) -> Grouping {
        self.grouping
    }

    /// The per-group cascade planes, group-major (the jsonio encode path).
    pub fn groups(&self) -> &[Vec<PackedCodebook<T>>] {
        &self.groups
    }

    /// Number of cascade levels (the maximum across groups — groups that
    /// hit the norm tolerance early carry fewer planes).
    pub fn num_levels(&self) -> usize {
        self.groups.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// `y = x·W` computed directly on the packed planes; the dense matrix
    /// is never materialized. See the module docs for the per-lane bitwise
    /// contract. Panics on a length mismatch, like the dense matrix ops.
    pub fn matvec(&self, x: &[T]) -> Vec<T> {
        assert_eq!(
            x.len(),
            self.rows,
            "QMatrix::matvec: x has {} elements, matrix has {} rows",
            x.len(),
            self.rows
        );
        let mut y = vec![T::ZERO; self.cols];
        let mut scratch: Vec<T> = Vec::new();
        match self.grouping {
            Grouping::PerColumn => {
                for (j, planes) in self.groups.iter().enumerate() {
                    let mut acc = T::ZERO;
                    for cb in planes {
                        acc += kernels::matvec_levels(
                            x,
                            &cb.levels,
                            cb.indices.words(),
                            cb.indices.bits(),
                            &mut scratch,
                        );
                    }
                    y[j] = acc;
                }
            }
            Grouping::PerRow => {
                for (i, planes) in self.groups.iter().enumerate() {
                    for cb in planes {
                        kernels::matvec_rowmajor_levels(
                            &mut y,
                            &x[i..i + 1],
                            &cb.levels,
                            cb.indices.words(),
                            cb.indices.bits(),
                            &mut scratch,
                        );
                    }
                }
            }
            Grouping::PerTensor => {
                for cb in &self.groups[0] {
                    kernels::matvec_rowmajor_levels(
                        &mut y,
                        x,
                        &cb.levels,
                        cb.indices.words(),
                        cb.indices.bits(),
                        &mut scratch,
                    );
                }
            }
        }
        y
    }

    /// BLAS-shaped GEMV over the packed planes:
    /// `y ← α·(x·W) + β·y` (`β = 0` overwrites, so `y` may start
    /// uninitialized in the BLAS sense).
    pub fn gemv(&self, alpha: T, x: &[T], beta: T, y: &mut [T]) {
        assert_eq!(
            y.len(),
            self.cols,
            "QMatrix::gemv: y has {} elements, matrix has {} cols",
            y.len(),
            self.cols
        );
        let t = self.matvec(x);
        if beta == T::ZERO {
            for (yi, ti) in y.iter_mut().zip(t) {
                *yi = alpha * ti;
            }
        } else {
            for (yi, ti) in y.iter_mut().zip(t) {
                *yi = alpha * ti + beta * *yi;
            }
        }
    }

    /// Materialize the reconstruction row-major (sum of the decoded
    /// cascade planes) — the edge decode; compute paths never call this.
    pub fn decode_flat(&self) -> Vec<T> {
        let mut flat = vec![T::ZERO; self.rows * self.cols];
        match self.grouping {
            Grouping::PerTensor => {
                for cb in &self.groups[0] {
                    for (d, v) in flat.iter_mut().zip(cb.decode()) {
                        *d += v;
                    }
                }
            }
            Grouping::PerRow => {
                for (i, planes) in self.groups.iter().enumerate() {
                    let row = &mut flat[i * self.cols..(i + 1) * self.cols];
                    for cb in planes {
                        for (d, v) in row.iter_mut().zip(cb.decode()) {
                            *d += v;
                        }
                    }
                }
            }
            Grouping::PerColumn => {
                for (j, planes) in self.groups.iter().enumerate() {
                    for cb in planes {
                        for (i, v) in cb.decode().into_iter().enumerate() {
                            flat[i * self.cols + j] += v;
                        }
                    }
                }
            }
        }
        flat
    }

    /// Compression accounting: cascade planes within a group **stack**
    /// (per-index bits add over the same elements —
    /// [`CompressionStats::stack`]), then the groups aggregate as parallel
    /// payloads ([`CompressionStats::aggregate`]). `levels_requested` per
    /// plane is its achieved count (the cascade targets bits, not one
    /// level count).
    pub fn stats(&self) -> CompressionStats {
        let per_group: Vec<CompressionStats> = self
            .groups
            .iter()
            .map(|planes| {
                let mut it = planes.iter().map(|cb| cb.stats(cb.k()));
                let first = it.next().expect("from_parts/residual_levels: no empty groups");
                it.fold(first, |acc, s| acc.stack(&s))
            })
            .collect();
        CompressionStats::aggregate(per_group.iter()).expect("qmatrix has at least one group")
    }

    /// Compact payload bytes (packed index planes + f32 level tables,
    /// summed over groups and levels) — `stats().compact_bytes`.
    pub fn compact_bytes(&self) -> usize {
        self.stats().compact_bytes
    }
}

impl QMatrix<f64> {
    /// Quantize `m` into a single-level `QMatrix` at `2^bits` target
    /// levels per group — [`QMatrix::residual_levels`] with one level and
    /// no tolerance.
    pub fn quantize(
        m: &Matrix,
        grouping: Grouping,
        method: QuantMethod,
        opts: &QuantOptions,
        bits: u32,
    ) -> Result<QMatrix<f64>> {
        Self::residual_levels(m, grouping, method, opts, &[bits], 0.0)
    }

    /// Build a multi-level residual cascade over `m`: each group (per the
    /// grouping) quantizes at `2^bit_list[0]` levels, re-quantizes its
    /// residual at `2^bit_list[1]`, …, stopping early once its relative l2
    /// residual norm reaches `norm_tol` (so the matrix-wide Frobenius
    /// criterion also holds: if every group is within `norm_tol`
    /// relatively, so is the whole matrix). Groups fan across the batch
    /// executor; the solve lane follows `opts.precision` (an f32-lane
    /// solve widens into the f64 planes — use [`QMatrix::to_f32`] for f32
    /// *compute*). Pair with a count-taking method
    /// ([`QuantMethod::takes_target_count`]) so the bit widths are honored.
    pub fn residual_levels(
        m: &Matrix,
        grouping: Grouping,
        method: QuantMethod,
        opts: &QuantOptions,
        bit_list: &[u32],
        norm_tol: f64,
    ) -> Result<QMatrix<f64>> {
        let groups = api::matrix_groups(m, grouping)?;
        let per = batch_map(&groups, |w| {
            api::cascade_shared_f64(
                Arc::clone(w),
                method,
                bit_list,
                norm_tol,
                opts,
                OutputForm::Codebook,
            )
        });
        let mut built = Vec::with_capacity(per.len());
        for res in per {
            let items = res?;
            let planes: Vec<PackedCodebook<f64>> =
                items.iter().map(|it| it.codebook_f64().pack()).collect();
            built.push(planes);
        }
        QMatrix::from_parts(m.rows(), m.cols(), grouping, built)
    }

    /// Build the cascade and report each level's cumulative index bits
    /// (the requested widths, summed) and relative Frobenius error — the
    /// error-vs-bits series the qmatvec bench plots. The trace is
    /// truncated like the planes themselves when `norm_tol` stops every
    /// group early.
    pub fn residual_levels_traced(
        m: &Matrix,
        grouping: Grouping,
        method: QuantMethod,
        opts: &QuantOptions,
        bit_list: &[u32],
        norm_tol: f64,
    ) -> Result<(QMatrix<f64>, Vec<CascadeLevel>)> {
        let qm = Self::residual_levels(m, grouping, method, opts, bit_list, norm_tol)?;
        let mut trace = Vec::new();
        let mut cum_bits = 0u32;
        for (l, &bits) in bit_list.iter().enumerate().take(qm.num_levels()) {
            cum_bits += bits;
            let prefix = QMatrix {
                rows: qm.rows,
                cols: qm.cols,
                grouping: qm.grouping,
                groups: qm
                    .groups
                    .iter()
                    .map(|planes| planes.iter().take(l + 1).cloned().collect())
                    .collect(),
            };
            trace.push(CascadeLevel { bits, cum_bits, rel_error: prefix.approx_error(m) });
        }
        Ok((qm, trace))
    }

    /// Materialize the dense reconstruction (sum of the decoded planes).
    pub fn decode(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.decode_flat())
            .expect("decode_flat emits rows*cols elements")
    }

    /// Relative Frobenius approximation error
    /// `‖original − decode()‖_F / ‖original‖_F` (absolute norm when the
    /// original is all zeros). Panics on a shape mismatch.
    pub fn approx_error(&self, original: &Matrix) -> f64 {
        assert_eq!(
            (original.rows(), original.cols()),
            (self.rows, self.cols),
            "QMatrix::approx_error: shape mismatch"
        );
        let recon = self.decode_flat();
        let diff: Vec<f64> =
            original.data().iter().zip(&recon).map(|(&a, &b)| a - b).collect();
        let base = kernels::nrm2(original.data());
        let err = kernels::nrm2(&diff);
        if base == 0.0 {
            err
        } else {
            err / base
        }
    }

    /// Batched quantized forward: `A·W` for a row-major batch `A`
    /// (`a.cols() == rows`), one packed matvec per input row — the
    /// `nn::mlp` serving shape.
    pub fn matmul(&self, a: &Matrix) -> Matrix {
        assert_eq!(
            a.cols(),
            self.rows,
            "QMatrix::matmul: a has {} cols, matrix has {} rows",
            a.cols(),
            self.rows
        );
        let mut out = Matrix::zeros(a.rows(), self.cols);
        for i in 0..a.rows() {
            let y = self.matvec(a.row(i));
            out.row_mut(i).copy_from_slice(&y);
        }
        out
    }

    /// Narrow to an f32 compute lane: levels narrow once, index planes are
    /// shared bit-for-bit. The f32 `matvec` then runs the per-level
    /// multi-accumulator path.
    pub fn to_f32(&self) -> QMatrix<f32> {
        QMatrix {
            rows: self.rows,
            cols: self.cols,
            grouping: self.grouping,
            groups: self
                .groups
                .iter()
                .map(|planes| {
                    planes
                        .iter()
                        .map(|cb| PackedCodebook {
                            levels: cb.levels.iter().map(|&l| l as f32).collect(),
                            indices: cb.indices.clone(),
                        })
                        .collect()
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;

    fn demo_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| {
            let center = [0.1, 0.35, 0.6, 0.9][(rng.next_u32() % 4) as usize];
            (center + rng.normal() * 0.01).clamp(-1.0, 1.0)
        })
    }

    fn opts() -> QuantOptions {
        QuantOptions { kmeans_restarts: 2, ..QuantOptions::default() }
    }

    #[test]
    fn single_level_matvec_is_bitwise_decode_then_dense() {
        let m = demo_matrix(17, 9, 3);
        let x: Vec<f64> = (0..17).map(|i| ((i as f64) * 0.71).cos()).collect();
        for grouping in [Grouping::PerTensor, Grouping::PerRow, Grouping::PerColumn] {
            let qm =
                QMatrix::quantize(&m, grouping, QuantMethod::KMeans, &opts(), 2).unwrap();
            let dense = qm.decode();
            let x_row = Matrix::from_vec(1, 17, x.clone()).unwrap();
            let want = x_row.matmul(&dense).unwrap();
            let got = qm.matvec(&x);
            assert_eq!(got.len(), 9);
            for (a, b) in got.iter().zip(want.row(0)) {
                assert_eq!(a.to_bits(), b.to_bits(), "grouping {grouping:?}");
            }
        }
    }

    #[test]
    fn multi_level_matvec_is_bitwise_per_level_sum() {
        let m = demo_matrix(12, 7, 5);
        let x: Vec<f64> = (0..12).map(|i| ((i as f64) * 0.31).sin()).collect();
        let qm = QMatrix::residual_levels(
            &m,
            Grouping::PerColumn,
            QuantMethod::KMeans,
            &opts(),
            &[2, 2],
            0.0,
        )
        .unwrap();
        // Uniform level counts (norm_tol = 0), so every group carries
        // every plane.
        assert!(qm.groups().iter().all(|p| p.len() == qm.num_levels()));
        // Reference: per-level decode-then-dense matvecs summed in level
        // order — the documented multi-level contract.
        let mut want = vec![0.0f64; 7];
        for l in 0..qm.num_levels() {
            let level_only = QMatrix::from_parts(
                12,
                7,
                Grouping::PerColumn,
                qm.groups().iter().map(|p| vec![p[l].clone()]).collect(),
            )
            .unwrap();
            let dense = level_only.decode();
            let yl = Matrix::from_vec(1, 12, x.clone()).unwrap().matmul(&dense).unwrap();
            for (w, v) in want.iter_mut().zip(yl.row(0)) {
                *w += v;
            }
        }
        let got = qm.matvec(&x);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f32_lane_tracks_f64_within_tolerance() {
        let m = demo_matrix(40, 11, 7);
        let x: Vec<f64> = (0..40).map(|i| ((i as f64) * 0.17).sin()).collect();
        let qm = QMatrix::residual_levels(
            &m,
            Grouping::PerColumn,
            QuantMethod::KMeans,
            &opts(),
            &[3, 2],
            0.0,
        )
        .unwrap();
        let q32 = qm.to_f32();
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let y64 = qm.matvec(&x);
        let y32 = q32.matvec(&x32);
        for (a, b) in y64.iter().zip(&y32) {
            let scale = a.abs().max(1.0);
            assert!(
                (a - f64::from(*b)).abs() <= 1e-3 * scale,
                "f32 lane diverged: {a} vs {b}"
            );
        }
    }

    #[test]
    fn cascade_error_is_monotonically_non_increasing() {
        let m = demo_matrix(20, 10, 11);
        let (qm, trace) = QMatrix::residual_levels_traced(
            &m,
            Grouping::PerColumn,
            QuantMethod::KMeans,
            &opts(),
            &[1, 2, 3],
            0.0,
        )
        .unwrap();
        assert_eq!(trace.len(), qm.num_levels());
        let mut prev = f64::INFINITY;
        let mut prev_bits = 0;
        for level in &trace {
            assert!(
                level.rel_error <= prev + 1e-12,
                "error grew: {} after {}",
                level.rel_error,
                prev
            );
            assert!(level.cum_bits > prev_bits, "cumulative bits must grow");
            prev = level.rel_error;
            prev_bits = level.cum_bits;
        }
        assert!(qm.approx_error(&m) <= trace[0].rel_error + 1e-12);
    }

    #[test]
    fn norm_tol_stops_groups_early() {
        // Each column has ≤2 distinct values, so a 1-bit level is exact
        // and any positive tolerance stops every group after one plane.
        let m = Matrix::from_fn(10, 4, |i, j| ((i + j) % 2) as f64);
        let qm = QMatrix::residual_levels(
            &m,
            Grouping::PerColumn,
            QuantMethod::KMeans,
            &opts(),
            &[1, 1, 1],
            1e-9,
        )
        .unwrap();
        assert_eq!(qm.num_levels(), 1);
        assert!(qm.approx_error(&m) <= 1e-12);
    }

    #[test]
    fn k1_constant_matrix_roundtrips() {
        let m = Matrix::from_fn(6, 5, |_, _| 0.75);
        let qm =
            QMatrix::quantize(&m, Grouping::PerTensor, QuantMethod::KMeans, &opts(), 1)
                .unwrap();
        assert!(qm.groups()[0][0].k() <= 2);
        assert!(qm.approx_error(&m) <= 1e-12);
        let y = qm.matvec(&[1.0; 6]);
        for v in y {
            assert!((v - 4.5).abs() < 1e-9);
        }
    }

    #[test]
    fn gemv_applies_alpha_beta() {
        let m = demo_matrix(8, 3, 13);
        let qm =
            QMatrix::quantize(&m, Grouping::PerColumn, QuantMethod::KMeans, &opts(), 2)
                .unwrap();
        let x: Vec<f64> = (0..8).map(|i| i as f64 * 0.1).collect();
        let base = qm.matvec(&x);
        let mut y = vec![1.0f64; 3];
        qm.gemv(2.0, &x, 0.5, &mut y);
        for (yi, bi) in y.iter().zip(&base) {
            assert_eq!(yi.to_bits(), (2.0 * bi + 0.5).to_bits());
        }
        let mut y0 = vec![f64::NAN; 3];
        qm.gemv(1.0, &x, 0.0, &mut y0);
        for (yi, bi) in y0.iter().zip(&base) {
            assert_eq!(yi.to_bits(), bi.to_bits(), "β=0 must overwrite");
        }
    }

    #[test]
    fn stats_stack_bits_across_levels() {
        let m = demo_matrix(30, 6, 17);
        let qm = QMatrix::residual_levels(
            &m,
            Grouping::PerColumn,
            QuantMethod::KMeans,
            &opts(),
            &[2, 1],
            0.0,
        )
        .unwrap();
        let s = qm.stats();
        assert_eq!(s.n, 30 * 6);
        // Every group ran both levels (norm_tol = 0): 2 + 1 packed bits.
        assert_eq!(s.bits_per_idx_packed, 3);
        assert_eq!(s.bits_per_idx_stored, 3, "packed planes store the packed width");
        assert_eq!(s.dense_bytes, 30 * 6 * 8);
        assert!(s.compact_bytes < s.dense_bytes);
        assert_eq!(qm.compact_bytes(), s.compact_bytes);
    }

    #[test]
    fn from_parts_validates_shape() {
        let m = demo_matrix(5, 4, 19);
        let qm =
            QMatrix::quantize(&m, Grouping::PerColumn, QuantMethod::KMeans, &opts(), 2)
                .unwrap();
        let planes = qm.groups().to_vec();
        assert!(QMatrix::from_parts(0, 4, Grouping::PerColumn, planes.clone()).is_err());
        assert!(QMatrix::from_parts(5, 3, Grouping::PerColumn, planes.clone()).is_err());
        assert!(QMatrix::from_parts(6, 4, Grouping::PerColumn, planes.clone()).is_err());
        let mut empty_group = planes.clone();
        empty_group[0].clear();
        assert!(QMatrix::from_parts(5, 4, Grouping::PerColumn, empty_group).is_err());
        assert!(QMatrix::from_parts(5, 4, Grouping::PerColumn, planes).is_ok());
    }

    #[test]
    fn matmul_matches_per_row_matvec() {
        let m = demo_matrix(9, 4, 23);
        let qm =
            QMatrix::quantize(&m, Grouping::PerRow, QuantMethod::KMeans, &opts(), 2)
                .unwrap();
        let a = demo_matrix(3, 9, 29);
        let out = qm.matmul(&a);
        assert_eq!((out.rows(), out.cols()), (3, 4));
        for i in 0..3 {
            let want = qm.matvec(a.row(i));
            for (x, y) in out.row(i).iter().zip(&want) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
