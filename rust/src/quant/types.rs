//! Public types for the quantization API.

use crate::linalg::scalar::Scalar;
use crate::linalg::stats;

/// Element precision a quantization request runs at.
///
/// `F64` is the bitwise-reproducible reference lane. `F32` narrows the
/// input once at the lane boundary, runs prepare + solve in single
/// precision (halving the memory traffic of the CD hot loop), and widens
/// the output at the end; CD solvers on the f32 lane floor their
/// convergence tolerance at `1e-6` (see [`crate::linalg::scalar`] for the
/// full precision contract). Methods without a native f32 kernel (the
/// clustering baselines, l0, tv_exact) transparently widen the prepared
/// input and run their f64 solver — correct, but without the bandwidth
/// win.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// Double precision (the default, bitwise-stable reference lane).
    #[default]
    F64,
    /// Single precision (the NN-weight fast path).
    F32,
}

impl Precision {
    /// Stable string id (CLI, manifests, reports).
    pub fn id(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    /// Parse from the stable id.
    pub fn from_id(s: &str) -> Option<Self> {
        match s {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }
}

/// Which quantization algorithm to run. These are exactly the methods the
/// paper's §4 experiments compare.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMethod {
    /// eq 6: plain l1 LASSO over the difference basis ("l1 w/o LS").
    L1,
    /// Algorithm 1: l1 then exact least-square refit on the support.
    L1LeastSquare,
    /// eq 13: l1 + negative-l2 relaxation (no refit, as in Fig 4).
    L1L2,
    /// eq 16: l0 best-subset (upper-bounded value count).
    L0,
    /// Algorithm 2: iterative l1 with growing λ₁ to hit a target count.
    IterativeL1,
    /// Algorithm 3: k-means partition + exact least-square values.
    ClusterLs,
    /// Baseline: k-means (Lloyd, k-means++ init, multi-restart).
    KMeans,
    /// Baseline: Mixture-of-Gaussians (EM) quantization.
    Gmm,
    /// Baseline: data-transformation clustering (Azimi et al. 2017).
    DataTransform,
    /// Extension/ablation: exact 1-d k-means by dynamic programming.
    KMeansExact,
    /// Extension/ablation: exact eq-6 optimum by fused-lasso DP.
    TvExact,
    /// Extension baseline: agglomerative (Ward) quantization [11].
    Agglomerative,
    /// Extension baseline: fuzzy c-means [13][14].
    FuzzyCMeans,
}

impl QuantMethod {
    /// Stable string id (CLI, manifests, reports).
    pub fn id(self) -> &'static str {
        match self {
            QuantMethod::L1 => "l1",
            QuantMethod::L1LeastSquare => "l1_ls",
            QuantMethod::L1L2 => "l1_l2",
            QuantMethod::L0 => "l0",
            QuantMethod::IterativeL1 => "iter_l1",
            QuantMethod::ClusterLs => "cluster_ls",
            QuantMethod::KMeans => "kmeans",
            QuantMethod::Gmm => "gmm",
            QuantMethod::DataTransform => "data_transform",
            QuantMethod::KMeansExact => "kmeans_exact",
            QuantMethod::TvExact => "tv_exact",
            QuantMethod::Agglomerative => "agglom",
            QuantMethod::FuzzyCMeans => "fcm",
        }
    }

    /// Parse from the stable id.
    pub fn from_id(s: &str) -> Option<Self> {
        Some(match s {
            "l1" => QuantMethod::L1,
            "l1_ls" => QuantMethod::L1LeastSquare,
            "l1_l2" => QuantMethod::L1L2,
            "l0" => QuantMethod::L0,
            "iter_l1" => QuantMethod::IterativeL1,
            "cluster_ls" => QuantMethod::ClusterLs,
            "kmeans" => QuantMethod::KMeans,
            "gmm" => QuantMethod::Gmm,
            "data_transform" => QuantMethod::DataTransform,
            "kmeans_exact" => QuantMethod::KMeansExact,
            "tv_exact" => QuantMethod::TvExact,
            "agglom" => QuantMethod::Agglomerative,
            "fcm" => QuantMethod::FuzzyCMeans,
            _ => return None,
        })
    }

    /// Resolve the registered [`super::pipeline::QuantSolver`] for this
    /// method (the method→solver table lives in [`super::pipeline`]).
    pub fn solver(self) -> &'static dyn super::pipeline::QuantSolver {
        super::pipeline::solver_for(self)
    }

    /// Methods that take a target value count `l` (as opposed to a λ).
    pub fn takes_target_count(self) -> bool {
        matches!(
            self,
            QuantMethod::L0
                | QuantMethod::IterativeL1
                | QuantMethod::ClusterLs
                | QuantMethod::KMeans
                | QuantMethod::Gmm
                | QuantMethod::DataTransform
                | QuantMethod::KMeansExact
                | QuantMethod::Agglomerative
                | QuantMethod::FuzzyCMeans
        )
    }

    /// All methods, for sweep harnesses.
    pub const ALL: [QuantMethod; 13] = [
        QuantMethod::L1,
        QuantMethod::L1LeastSquare,
        QuantMethod::L1L2,
        QuantMethod::L0,
        QuantMethod::IterativeL1,
        QuantMethod::ClusterLs,
        QuantMethod::KMeans,
        QuantMethod::Gmm,
        QuantMethod::DataTransform,
        QuantMethod::KMeansExact,
        QuantMethod::TvExact,
        QuantMethod::Agglomerative,
        QuantMethod::FuzzyCMeans,
    ];
}

/// Options shared by all methods; method-specific fields are ignored by
/// methods that do not use them.
#[derive(Debug, Clone)]
pub struct QuantOptions {
    /// l1 penalty λ₁ (L1 / L1LeastSquare / L1L2 / IterativeL1 start).
    pub lambda1: f64,
    /// Negative-l2 coefficient λ₂ (L1L2). The paper's Fig 4 ties it to λ₁
    /// as |λ₂| = 4e-3·λ₁; callers can do the same.
    pub lambda2: f64,
    /// Target number of distinct values `l` (count-taking methods).
    pub target_values: usize,
    /// Epoch budget for coordinate-descent solvers.
    pub max_epochs: usize,
    /// CD convergence tolerance.
    pub tol: f64,
    /// k-means: number of restarts (the paper's "5 to 10 times"; sklearn
    /// default 10).
    pub kmeans_restarts: usize,
    /// k-means / GMM / EM iteration budget.
    pub max_iters: usize,
    /// RNG seed for the randomized baselines.
    pub seed: u64,
    /// Apply the LS refit after L1 (Algorithm 1 vs bare eq 6) — already
    /// encoded in the method enum, but IterativeL1 also refits internally
    /// per the paper; this gates it.
    pub refit: bool,
    /// Iterative-l1 (Algorithm 2): maximum λ-growth iterations.
    pub max_lambda_steps: usize,
    /// Optional hard-sigmoid clamp range applied to the output (eq 21).
    pub clamp: Option<(f64, f64)>,
    /// Element precision for `quantize`/`quantize_batch` (the staged
    /// `PreparedInput` entry points choose the lane by the prepared input's
    /// own type instead, and payload-typed coordinator submissions by the
    /// payload's). See [`Precision`].
    pub precision: Precision,
    /// Optional entropy budget in bits per value: after the solve, adjacent
    /// output levels are greedily merged (trading importance-weighted
    /// distortion against coded bits, per "Towards the Limit of Network
    /// Quantization") until the index entropy of the result is at or below
    /// this many bits. `None` (the default) disables the pass entirely.
    pub entropy_budget: Option<f64>,
}

impl Default for QuantOptions {
    fn default() -> Self {
        QuantOptions {
            lambda1: 1e-2,
            lambda2: 0.0,
            target_values: 16,
            max_epochs: 1000,
            tol: 1e-10,
            kmeans_restarts: 10,
            max_iters: 300,
            seed: 0,
            refit: true,
            max_lambda_steps: 5000,
            clamp: None,
            precision: Precision::F64,
            entropy_budget: None,
        }
    }
}

/// Output of a quantization run, generic over the lane precision.
/// [`QuantOutput`] (the f64 default) is the type the f64 API and the
/// coordinator surface; [`QuantOutputF32`] is what the f32-native entry
/// points return, avoiding a widening pass the caller may not want.
#[derive(Debug, Clone)]
pub struct QuantOutputT<T: Scalar = f64> {
    /// Quantized vector, same length/order as the input.
    pub values: Vec<T>,
    /// The distinct levels used (sorted ascending).
    pub levels: Vec<T>,
    /// Squared-l2 information loss vs the (lane-precision) input, always
    /// accumulated in f64.
    pub l2_loss: f64,
    /// Number of values clamped by the hard sigmoid (out-of-range count).
    pub clamped: usize,
    /// Method-specific diagnostics.
    pub diag: QuantDiag,
}

/// Double-precision output (the historical `QuantOutput` type).
pub type QuantOutput = QuantOutputT<f64>;
/// Single-precision output of the f32-native entry points.
pub type QuantOutputF32 = QuantOutputT<f32>;

impl<T: Scalar> QuantOutputT<T> {
    /// Achieved number of distinct values.
    pub fn distinct_values(&self) -> usize {
        self.levels.len()
    }
}

impl QuantOutputF32 {
    /// Widen to the f64 output type (for f64-surface callers such as the
    /// coordinator's job results). Loss/diagnostics carry over unchanged —
    /// the loss was measured against the f32 input the lane actually
    /// quantized.
    pub fn widen(&self) -> QuantOutput {
        QuantOutput {
            values: self.values.iter().map(|&x| f64::from(x)).collect(),
            levels: self.levels.iter().map(|&x| f64::from(x)).collect(),
            l2_loss: self.l2_loss,
            clamped: self.clamped,
            diag: self.diag.clone(),
        }
    }
}

/// Solver diagnostics surfaced to the evaluation harness.
#[derive(Debug, Clone, Default)]
pub struct QuantDiag {
    /// CD epochs / EM iterations / Lloyd iterations consumed (total).
    pub iterations: usize,
    /// Converged within budget?
    pub converged: bool,
    /// λ₁ actually used (IterativeL1 reports the final λ).
    pub lambda1: f64,
    /// ‖α‖₀ of the sparse solution (l1/l0 family).
    pub nnz: usize,
    /// Numerical-instability flag (λ₂ too large, l0 failure, ...).
    pub unstable: bool,
    /// k-means restarts that produced empty clusters (paper's claim 1).
    pub empty_cluster_events: usize,
}

/// Compute levels + loss bookkeeping for a reconstructed full vector.
/// This is the full-vector (O(n log n)) path used by the runtime-lane
/// dispatchers, which already hold a recovered vector; the staged native
/// pipeline finalizes in level space instead
/// ([`super::pipeline::PreparedInput::finish`]), which is O(m log m) and
/// produces identical results.
pub(crate) fn finalize(
    original: &[f64],
    mut values: Vec<f64>,
    clamp: Option<(f64, f64)>,
    diag: QuantDiag,
) -> QuantOutput {
    let clamped = match clamp {
        Some((a, b)) => super::hard_sigmoid::clamp_slice(&mut values, a, b),
        None => 0,
    };
    let mut levels: Vec<f64> = values.clone();
    levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    levels.dedup();
    let l2_loss = stats::l2_loss(original, &values);
    QuantOutput { values, levels, l2_loss, clamped, diag }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_id_roundtrip() {
        for m in QuantMethod::ALL {
            assert_eq!(QuantMethod::from_id(m.id()), Some(m));
        }
        assert_eq!(QuantMethod::from_id("nope"), None);
    }

    #[test]
    fn precision_id_roundtrip_and_default() {
        for p in [Precision::F64, Precision::F32] {
            assert_eq!(Precision::from_id(p.id()), Some(p));
        }
        assert_eq!(Precision::from_id("f16"), None);
        assert_eq!(QuantOptions::default().precision, Precision::F64);
    }

    #[test]
    fn f32_output_widens_losslessly() {
        let out32 = QuantOutputF32 {
            values: vec![0.5f32, 1.5, 0.5],
            levels: vec![0.5f32, 1.5],
            l2_loss: 0.25,
            clamped: 1,
            diag: QuantDiag::default(),
        };
        let wide = out32.widen();
        assert_eq!(wide.values, vec![0.5f64, 1.5, 0.5]);
        assert_eq!(wide.levels, vec![0.5f64, 1.5]);
        assert_eq!(wide.l2_loss, 0.25);
        assert_eq!(wide.clamped, 1);
        assert_eq!(wide.distinct_values(), 2);
    }

    #[test]
    fn finalize_computes_levels_and_loss() {
        let out = finalize(&[1.0, 2.0, 3.0], vec![1.5, 1.5, 3.0], None, QuantDiag::default());
        assert_eq!(out.levels, vec![1.5, 3.0]);
        assert_eq!(out.distinct_values(), 2);
        assert!((out.l2_loss - 0.5).abs() < 1e-12);
        assert_eq!(out.clamped, 0);
    }

    #[test]
    fn finalize_clamps() {
        let out = finalize(
            &[0.0, 1.0],
            vec![-0.5, 1.5],
            Some((0.0, 1.0)),
            QuantDiag::default(),
        );
        assert_eq!(out.values, vec![0.0, 1.0]);
        assert_eq!(out.clamped, 2);
        assert_eq!(out.l2_loss, 0.0);
    }
}
