//! The unified request/response front door for quantization.
//!
//! The library grew one entry-point family per capability — one-shot vs
//! batch vs λ-sweep vs timed, times two precision lanes, times vector vs
//! matrix — and every new lane multiplied the surface again. This module
//! collapses them behind three types:
//!
//! * [`QuantRequest`] — a builder describing *what* to quantize (an owned
//!   or shared vector, a batch, or a matrix with a [`Grouping`]), *how*
//!   (method + options + precision lane), under which [`Plan`] (one-shot,
//!   exact target count, or a λ sweep), and in which [`OutputForm`].
//! * [`Quantizer`] — the facade whose single [`Quantizer::run`] serves
//!   every request shape. Batches and matrix groupings fan across the
//!   scoped-thread batch executor; sweeps amortize one prepared input
//!   across the λ grid with warm starts.
//! * [`QuantResponse`] — **codebook-first** results: each [`QuantItem`]
//!   carries a [`Codebook`] (levels + `u32` indices, in the lane's own
//!   precision — f32 results are never widened early) plus loss,
//!   diagnostics and per-stage timings. Full-length vectors are *not*
//!   built unless the request asked for [`OutputForm::Values`]; callers
//!   that need one later materialize lazily via [`QuantItem::materialize`]
//!   (an O(n) table lookup).
//!
//! Every legacy entry point (`quantize`, `quantize_batch`,
//! `quantize_sweep*`, `quantize_timed*`, `tensor::quantize_matrix`, the
//! coordinator's `submit*` family) is a thin shim over the cores in this
//! module and is regression-tested bitwise-identical to its pre-redesign
//! output (`tests/api_equivalence.rs`).
//!
//! # Quickstart
//!
//! ```
//! use sqlsq::quant::{QuantMethod, QuantRequest, Quantizer};
//!
//! let data = vec![0.1, 0.12, 0.5, 0.52, 0.9, 0.1];
//! let req = QuantRequest::vector(data)
//!     .method(QuantMethod::KMeans)
//!     .target_count(3);
//! let resp = Quantizer::new().run(&req).unwrap();
//! let item = resp.into_single().unwrap();
//! // Compact by default: a few levels + one small index per element.
//! assert!(item.distinct_values() <= 3);
//! let full = item.materialize_f64(); // lazy, only when you need it
//! assert_eq!(full.len(), 6);
//! ```
//!
//! # Migrating a legacy call
//!
//! Every row of the README migration table reduces to the same move:
//! the legacy arguments become builder calls, and the output comes back
//! compact. The shims are bitwise-identical, so migration is a pure
//! refactor:
//!
//! ```
//! use sqlsq::quant::{self, QuantMethod, QuantOptions, QuantRequest, Quantizer};
//!
//! let w: Vec<f64> = (0..60).map(|i| ((i % 7) as f64) / 7.0).collect();
//! let opts = QuantOptions { target_values: 4, ..Default::default() };
//!
//! // Legacy: quantize(&w, m, &opts) — full-vector output.
//! let legacy = quant::quantize(&w, QuantMethod::KMeans, &opts).unwrap();
//!
//! // Request API: same method/options, codebook-first output.
//! let req = QuantRequest::slice(&w).method(QuantMethod::KMeans).options(opts);
//! let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
//! assert_eq!(item.materialize_f64(), legacy.values);
//! assert_eq!(item.l2_loss().to_bits(), legacy.l2_loss.to_bits());
//! ```
//!
//! # Batch × sweep
//!
//! The sweep plan composes with batch (and matrix) inputs: `B` vectors ×
//! `K` λs through one request, group-major item order, one warm-start
//! chain per vector:
//!
//! ```
//! use sqlsq::quant::{QuantMethod, QuantRequest, Quantizer};
//!
//! let vectors: Vec<Vec<f64>> = (0..3)
//!     .map(|s| (0..40).map(|i| ((i * (s + 2)) % 11) as f64 / 11.0).collect())
//!     .collect();
//! let lambdas = vec![1e-3, 1e-2];
//! let req = QuantRequest::batch(vectors)
//!     .method(QuantMethod::L1LeastSquare)
//!     .sweep(lambdas);
//! let resp = Quantizer::new().run(&req).unwrap();
//! assert_eq!(resp.len(), 3 * 2); // B × K items, vector-major
//! ```

use super::codebook::{Codebook, CompressionStats};
use super::merge;
use super::pipeline::{
    batch_map, solver_for, LaneSolve, PreparedInput, StageTimings, SweepState,
};
use super::tensor::Grouping;
use super::unique::UniqueDecomp;
use super::types::{
    Precision, QuantDiag, QuantMethod, QuantOptions, QuantOutput, QuantOutputT,
};
use crate::linalg::kernels;
use crate::linalg::matrix::Matrix;
use crate::linalg::scalar::Scalar;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Request types
// ---------------------------------------------------------------------

/// What a request returns per item.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputForm {
    /// Codebook only (levels + indices) — the compact serve payload.
    /// Full vectors materialize lazily via [`QuantItem::materialize`].
    #[default]
    Codebook,
    /// Codebook plus eagerly materialized full-length values
    /// ([`QuantItem::values`] is populated).
    Values,
}

/// The solve plan a request runs under.
#[derive(Debug, Clone, PartialEq)]
pub enum Plan {
    /// One solve per input group with the request's options as given.
    OneShot,
    /// One solve per input group targeting exactly `l` distinct values
    /// (overrides `QuantOptions::target_values`; pair with a count-taking
    /// method — see `QuantMethod::takes_target_count`).
    TargetCount(usize),
    /// A λ₁ grid, one response item per (input group, λ) pair.
    ///
    /// Over a single-vector input the prepare stage runs once and
    /// lasso/iterative solvers warm-start along the path. Over a batch or
    /// matrix input (**batch×sweep**) every group gets its own prepared
    /// input and its own warm-start chain, and the groups fan across the
    /// scoped-thread batch executor: `B` groups × `K` λs produce `B·K`
    /// items in **group-major order** (group 0's λs in grid order, then
    /// group 1's, …). A group whose prepare/solve fails yields `K` error
    /// items so the `B·K` shape is preserved. `warm_start = false` solves
    /// every grid point cold (bitwise-identical to independent one-shot
    /// calls).
    Sweep {
        /// The λ₁ grid, one response item per entry, in order.
        lambdas: Vec<f64>,
        /// Reuse the previous grid point's coefficients as a warm start.
        warm_start: bool,
    },
    /// A multi-level residual cascade (the quantized-compute plan —
    /// lm-nslsqr's successive-bit-levels scheme): quantize the input at
    /// `2^bits[0]` target levels, then re-quantize the *residual*
    /// `w − decode(level₀)` at `2^bits[1]`, and so on, stopping early once
    /// the relative l2 norm of the residual (`‖r‖₂ / ‖w‖₂`; Frobenius over
    /// a matrix group) drops to `norm_tol`. One response item per level
    /// actually built, in cascade order; over a batch/matrix input the
    /// items are group-major and each group stops independently, so
    /// per-group level counts may differ (a failed group contributes one
    /// error item). Pair with a count-taking method
    /// (`QuantMethod::takes_target_count`) so `2^bits` is honored;
    /// `quant::qmatrix::QMatrix` assembles the per-group planes into a
    /// matrix that computes matvec without decoding.
    Cascade {
        /// Index bit-widths per level, in cascade order (level `l` targets
        /// `2^bits[l]` codebook levels). Must be non-empty, each in 1..=16.
        bits: Vec<u32>,
        /// Relative residual-norm stop; `0.0` always runs every level.
        norm_tol: f64,
    },
}

/// The input a request quantizes. Vectors are held behind `Arc`, so
/// cloning a request never copies data.
#[derive(Debug, Clone)]
pub enum RequestInput {
    /// One f64 vector (shared storage).
    VectorF64(Arc<[f64]>),
    /// One f32 vector; runs the native single-precision lane end to end.
    VectorF32(Arc<[f32]>),
    /// Independent f64 vectors, fanned across the batch executor.
    BatchF64(Vec<Vec<f64>>),
    /// Independent f32 vectors, fanned across the batch executor.
    BatchF32(Vec<Vec<f32>>),
    /// A matrix quantized per the [`Grouping`]; per-row / per-column
    /// groups fan across the batch executor like a batch.
    Matrix(Matrix, Grouping),
}

/// Per-element importance weights attached to a request
/// ([`QuantRequest::weights`] / [`QuantRequest::batch_weights`]). Held
/// behind `Arc` like the inputs, so cloning a request never copies the
/// weight buffers.
#[derive(Debug, Clone)]
pub enum RequestWeights {
    /// One weight per element of a vector or matrix input (matrix
    /// weights are row-major and split per group like the data).
    Vector(Arc<[f64]>),
    /// One weight vector per batch slot, zipped with the batch inputs.
    Batch(Vec<Arc<[f64]>>),
}

/// A quantization request: input + method + options + plan + output form,
/// optionally weighted per element ([`QuantRequest::weights`]).
///
/// Build with one of the input constructors ([`QuantRequest::vector`],
/// [`QuantRequest::shared`], [`QuantRequest::batch`],
/// [`QuantRequest::matrix`], or their `_f32` twins), then chain setters.
/// Defaults: [`QuantMethod::L1LeastSquare`] (the paper's Algorithm 1),
/// `QuantOptions::default()`, [`Plan::OneShot`], [`OutputForm::Codebook`].
#[derive(Debug, Clone)]
pub struct QuantRequest {
    pub(crate) input: RequestInput,
    pub(crate) method: QuantMethod,
    pub(crate) opts: QuantOptions,
    pub(crate) plan: Plan,
    pub(crate) output: OutputForm,
    pub(crate) weights: Option<RequestWeights>,
}

impl QuantRequest {
    fn with_input(input: RequestInput) -> QuantRequest {
        QuantRequest {
            input,
            method: QuantMethod::L1LeastSquare,
            opts: QuantOptions::default(),
            plan: Plan::OneShot,
            output: OutputForm::default(),
            weights: None,
        }
    }

    /// Quantize one owned f64 vector (the buffer is taken as-is; no data
    /// copy beyond the one-time move into shared storage).
    pub fn vector(w: Vec<f64>) -> QuantRequest {
        Self::with_input(RequestInput::VectorF64(Arc::from(w)))
    }

    /// Quantize one owned f32 vector on the native single-precision lane.
    pub fn vector_f32(w: Vec<f32>) -> QuantRequest {
        Self::with_input(RequestInput::VectorF32(Arc::from(w)))
    }

    /// Quantize an already-shared f64 vector without copying it.
    pub fn shared(w: Arc<[f64]>) -> QuantRequest {
        Self::with_input(RequestInput::VectorF64(w))
    }

    /// Quantize an already-shared f32 vector without copying it.
    pub fn shared_f32(w: Arc<[f32]>) -> QuantRequest {
        Self::with_input(RequestInput::VectorF32(w))
    }

    /// Quantize a borrowed f64 slice (copies once into shared storage —
    /// prefer [`QuantRequest::vector`] / [`QuantRequest::shared`] when you
    /// own the buffer).
    pub fn slice(w: &[f64]) -> QuantRequest {
        Self::with_input(RequestInput::VectorF64(Arc::from(w)))
    }

    /// Quantize a borrowed f32 slice (copies once into shared storage).
    pub fn slice_f32(w: &[f32]) -> QuantRequest {
        Self::with_input(RequestInput::VectorF32(Arc::from(w)))
    }

    /// Quantize many independent f64 vectors (scoped-thread fan-out; one
    /// response item per input, in order, failures isolated per slot).
    pub fn batch(inputs: Vec<Vec<f64>>) -> QuantRequest {
        Self::with_input(RequestInput::BatchF64(inputs))
    }

    /// Quantize many independent f32 vectors on the native f32 lane.
    pub fn batch_f32(inputs: Vec<Vec<f32>>) -> QuantRequest {
        Self::with_input(RequestInput::BatchF32(inputs))
    }

    /// Quantize a matrix with the given grouping (one response item per
    /// group: 1 for per-tensor, `rows` for per-row, `cols` for
    /// per-column). Per-row/per-column groups run through the batch
    /// fan-out.
    pub fn matrix(m: Matrix, grouping: Grouping) -> QuantRequest {
        Self::with_input(RequestInput::Matrix(m, grouping))
    }

    /// Set the quantization method.
    pub fn method(mut self, method: QuantMethod) -> QuantRequest {
        self.method = method;
        self
    }

    /// Replace the full option set (including precision). Chain the
    /// narrower setters after this to tweak individual fields.
    pub fn options(mut self, opts: QuantOptions) -> QuantRequest {
        self.opts = opts;
        self
    }

    /// Select the precision lane (`F32` narrows f64 inputs once at the
    /// boundary; f32 inputs always run natively regardless).
    pub fn precision(mut self, precision: Precision) -> QuantRequest {
        self.opts.precision = precision;
        self
    }

    /// Set the l1 penalty λ₁.
    pub fn lambda1(mut self, lambda1: f64) -> QuantRequest {
        self.opts.lambda1 = lambda1;
        self
    }

    /// Plan for an exact distinct-value count (sets [`Plan::TargetCount`]).
    pub fn target_count(mut self, l: usize) -> QuantRequest {
        self.plan = Plan::TargetCount(l);
        self
    }

    /// Plan a warm-started λ sweep (sets [`Plan::Sweep`]). Composes with
    /// every input shape: over a batch or matrix input this is the
    /// **batch×sweep** plan — `B` groups × `K` λs through one request,
    /// each group's λ path warm-started independently while the groups
    /// fan across the batch executor (see [`Plan::Sweep`] for the item
    /// order).
    pub fn sweep(mut self, lambdas: Vec<f64>) -> QuantRequest {
        self.plan = Plan::Sweep { lambdas, warm_start: true };
        self
    }

    /// Plan a cold λ sweep: every grid point solved independently
    /// (bitwise-identical to per-λ one-shot runs).
    pub fn sweep_cold(mut self, lambdas: Vec<f64>) -> QuantRequest {
        self.plan = Plan::Sweep { lambdas, warm_start: false };
        self
    }

    /// Plan a multi-level residual cascade (sets [`Plan::Cascade`]): one
    /// quantization per bit width, each over the previous level's
    /// residual, stopping early at `norm_tol` relative residual norm.
    pub fn residual_levels(mut self, bits: Vec<u32>, norm_tol: f64) -> QuantRequest {
        self.plan = Plan::Cascade { bits, norm_tol };
        self
    }

    /// Attach per-element importance weights: the solve minimizes
    /// `Σᵢ wᵢ·(xᵢ − qᵢ)²` instead of the plain squared error, on both
    /// precision lanes. Applies to vector and matrix inputs (matrix
    /// weights are row-major and split per group exactly like the
    /// data); use [`QuantRequest::batch_weights`] for batches. Weights
    /// must be finite, non-negative, sum to a positive total, and match
    /// the input length. A uniform weight vector (all entries
    /// bit-identical) only scales the objective, so it is dropped to
    /// the unweighted path — uniform-weight results are
    /// **bitwise-identical** to unweighted ones. [`Plan::Cascade`] does
    /// not compose with weights (residuals have no per-element
    /// identity), and [`QuantMethod::L0`] / [`QuantMethod::TvExact`]
    /// reject weighted inputs (their DP recurrences are count-based).
    ///
    /// ```
    /// use sqlsq::quant::{QuantMethod, QuantRequest, Quantizer};
    ///
    /// let data = vec![0.0, 0.55, 1.0];
    /// let wts = vec![1.0, 10.0, 1.0]; // the middle value matters 10x
    /// let run = |req: QuantRequest| {
    ///     Quantizer::new().run(&req).unwrap().into_single().unwrap().materialize_f64()
    /// };
    /// let base = || {
    ///     QuantRequest::vector(data.clone())
    ///         .method(QuantMethod::KMeansExact)
    ///         .target_count(2)
    /// };
    /// let plain = run(base());
    /// let weighted = run(base().weights(wts.clone()));
    /// let wloss = |q: &[f64]| -> f64 {
    ///     data.iter().zip(q).zip(&wts).map(|((x, q), w)| w * (x - q) * (x - q)).sum()
    /// };
    /// // On the weighted objective, the weighted solve strictly wins here.
    /// assert!(wloss(&weighted) < wloss(&plain));
    /// ```
    pub fn weights(mut self, w: Vec<f64>) -> QuantRequest {
        self.weights = Some(RequestWeights::Vector(Arc::from(w)));
        self
    }

    /// Attach one importance-weight vector per batch slot (zipped with
    /// the batch inputs in order; lengths must match slot for slot).
    /// Slots whose weights are uniform run the unweighted path, exactly
    /// as [`QuantRequest::weights`] does for a single vector.
    pub fn batch_weights(mut self, ws: Vec<Vec<f64>>) -> QuantRequest {
        self.weights = Some(RequestWeights::Batch(ws.into_iter().map(Arc::from).collect()));
        self
    }

    /// Opt into the entropy-constrained level-merge pass (sets
    /// `QuantOptions::entropy_budget`): after the solve, codebook
    /// levels are greedily merged — minimum (weighted) distortion
    /// increase per coded bit saved — until the index entropy fits
    /// `bits_per_value` bits per element. Composes with every plan and
    /// method; a result already inside the budget is returned
    /// bitwise-untouched. `CompressionStats::entropy_coded_bytes`
    /// reports the achievable coded size.
    pub fn entropy_budget(mut self, bits_per_value: f64) -> QuantRequest {
        self.opts.entropy_budget = Some(bits_per_value);
        self
    }

    /// Choose the output form.
    pub fn output(mut self, form: OutputForm) -> QuantRequest {
        self.output = form;
        self
    }

    /// Eagerly materialize full-length vectors (sets
    /// [`OutputForm::Values`]).
    pub fn with_values(mut self) -> QuantRequest {
        self.output = OutputForm::Values;
        self
    }

    /// The request's plan.
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The options the run will actually use: the request's options with
    /// the plan folded in ([`Plan::TargetCount`] overrides
    /// `target_values`; sweep λ overrides happen per grid point).
    pub fn effective_options(&self) -> QuantOptions {
        let mut opts = self.opts.clone();
        if let Plan::TargetCount(l) = self.plan {
            opts.target_values = l;
        }
        opts
    }

    /// The request's weights, validated against the input shape, with
    /// uniform vectors dropped to `None` — the normalization that pins
    /// uniform-weight requests bitwise-identical to unweighted ones (the
    /// weighted solver's arithmetic differs bitwise even at `w ≡ 1`, so
    /// the drop must happen before dispatch, not inside the solver).
    pub(crate) fn normalized_weights(&self) -> Result<Option<NormWeights>> {
        let Some(weights) = &self.weights else {
            return Ok(None);
        };
        match (weights, &self.input) {
            (RequestWeights::Vector(uw), RequestInput::VectorF64(w)) => {
                validate_weights(uw, w.len())?;
                Ok(nonuniform(uw).map(NormWeights::Vector))
            }
            (RequestWeights::Vector(uw), RequestInput::VectorF32(w)) => {
                validate_weights(uw, w.len())?;
                Ok(nonuniform(uw).map(NormWeights::Vector))
            }
            (RequestWeights::Vector(uw), RequestInput::Matrix(m, _)) => {
                validate_weights(uw, m.rows() * m.cols())?;
                Ok(nonuniform(uw).map(NormWeights::Vector))
            }
            (RequestWeights::Batch(ws), RequestInput::BatchF64(vs)) => {
                normalize_batch_weights(ws, vs.iter().map(Vec::len))
            }
            (RequestWeights::Batch(ws), RequestInput::BatchF32(vs)) => {
                normalize_batch_weights(ws, vs.iter().map(Vec::len))
            }
            _ => Err(Error::InvalidInput(
                "weights: form does not match the input shape (use `weights` for \
                 vector/matrix inputs, `batch_weights` for batches)"
                    .into(),
            )),
        }
    }
}

/// The request's weights after validation and uniform-drop
/// normalization: per-slot `None` marks a batch slot whose weights were
/// uniform (it runs the unweighted path bitwise).
#[derive(Debug, Clone)]
pub(crate) enum NormWeights {
    Vector(Arc<[f64]>),
    Batch(Vec<Option<Arc<[f64]>>>),
}

/// Validate one importance-weight vector against its input length:
/// every weight finite and non-negative, at least one strictly
/// positive. The [`Error::InvalidInput`] shapes here are what malformed
/// weighted requests surface everywhere (facade, coordinator, wire).
pub fn validate_weights(w: &[f64], n: usize) -> Result<()> {
    if w.len() != n {
        return Err(Error::InvalidInput(format!(
            "weights: expected {n} entries, got {}",
            w.len()
        )));
    }
    if let Some(bad) = w.iter().find(|x| !x.is_finite() || **x < 0.0) {
        return Err(Error::InvalidInput(format!(
            "weights: entries must be finite and non-negative, got {bad}"
        )));
    }
    if !w.iter().any(|&x| x > 0.0) {
        return Err(Error::InvalidInput(
            "weights: at least one entry must be positive".into(),
        ));
    }
    Ok(())
}

/// Validate `QuantOptions::entropy_budget`: `None` or a finite
/// non-negative bits-per-value number. Shared by the facade
/// ([`Quantizer::run`]) and the coordinator's admission path, so the
/// error shape is identical wherever a bad budget enters.
pub fn validate_entropy_budget(opts: &QuantOptions) -> Result<()> {
    if let Some(b) = opts.entropy_budget {
        if !(b.is_finite() && b >= 0.0) {
            return Err(Error::InvalidParam(format!(
                "entropy_budget: bits per value must be a non-negative number, got {b}"
            )));
        }
    }
    Ok(())
}

/// True when every weight shares one bit pattern — the uniform case the
/// facade drops to the unweighted path (a uniform vector scales the
/// weighted objective by a positive constant, which has the same
/// minimizer; dropping it is what makes uniform ≡ unweighted bitwise).
pub fn weights_are_uniform(w: &[f64]) -> bool {
    w.windows(2).all(|p| p[0].to_bits() == p[1].to_bits())
}

/// `Some(w)` when the (already validated) weights are non-uniform.
fn nonuniform(w: &Arc<[f64]>) -> Option<Arc<[f64]>> {
    (!weights_are_uniform(w)).then(|| Arc::clone(w))
}

/// Validate + normalize one batch's weight vectors against the slot
/// lengths (count must match, then each slot validates independently).
fn normalize_batch_weights(
    ws: &[Arc<[f64]>],
    lens: impl ExactSizeIterator<Item = usize>,
) -> Result<Option<NormWeights>> {
    if ws.len() != lens.len() {
        return Err(Error::InvalidInput(format!(
            "weights: expected {} weight vectors (one per batch slot), got {}",
            lens.len(),
            ws.len()
        )));
    }
    let mut slots = Vec::with_capacity(ws.len());
    for (uw, n) in ws.iter().zip(lens) {
        validate_weights(uw, n)?;
        slots.push(nonuniform(uw));
    }
    // A batch whose every slot is uniform is an unweighted batch.
    if slots.iter().all(Option::is_none) {
        return Ok(None);
    }
    Ok(Some(NormWeights::Batch(slots)))
}

// ---------------------------------------------------------------------
// Response types
// ---------------------------------------------------------------------

/// One quantized unit (a vector, batch element, matrix group, or sweep
/// grid point) in its lane precision. Codebook-first: the full-length
/// vector exists only if the request asked for [`OutputForm::Values`] or
/// a caller materializes it.
#[derive(Debug, Clone)]
pub struct QuantItem<T: Scalar = f64> {
    /// Compact result: shared levels + one `u32` index per element.
    pub codebook: Codebook<T>,
    /// Squared-l2 information loss vs the lane-precision input (always
    /// accumulated in f64, bitwise-identical to the legacy pipeline).
    pub l2_loss: f64,
    /// Number of values moved by the hard-sigmoid clamp.
    pub clamped: usize,
    /// Solver diagnostics.
    pub diag: QuantDiag,
    /// Per-stage wall times for this item (prepare is attributed to the
    /// first item of a sweep; later grid points reuse the prepared input).
    pub timings: StageTimings,
    /// Populated only under [`OutputForm::Values`].
    values: Option<Vec<T>>,
}

impl<T: Scalar> QuantItem<T> {
    /// Eagerly materialized values, if the request asked for them.
    pub fn values(&self) -> Option<&[T]> {
        self.values.as_deref()
    }

    /// The full-length quantized vector: returns the eager copy when
    /// present, otherwise decodes the codebook (O(n) table lookup).
    pub fn materialize(&self) -> Vec<T> {
        match &self.values {
            Some(v) => v.clone(),
            None => self.codebook.decode(),
        }
    }

    /// Achieved number of distinct values.
    pub fn distinct_values(&self) -> usize {
        self.codebook.k()
    }

    /// Compression accounting for this item's codebook (bits/value, index
    /// entropy, achieved-vs-requested levels, compact-vs-dense bytes).
    /// `levels_requested` is the request's `target_values`; the dense
    /// baseline is the lane's element width.
    pub fn compression(&self, levels_requested: usize) -> CompressionStats {
        self.codebook.stats(levels_requested)
    }

    /// Convert into the legacy full-vector output type (materializes).
    pub fn into_output(self) -> QuantOutputT<T> {
        let QuantItem { codebook, l2_loss, clamped, diag, values, .. } = self;
        let values = values.unwrap_or_else(|| codebook.decode());
        QuantOutputT { values, levels: codebook.levels, l2_loss, clamped, diag }
    }
}

/// A lane-erased response item. The request's input lane (and, for f64
/// inputs, `QuantOptions::precision`) decides which variant you get; f32
/// results stay narrow until a caller explicitly widens.
#[derive(Debug, Clone)]
pub enum Item {
    /// Double-precision result.
    F64(QuantItem<f64>),
    /// Single-precision result (native f32 lane).
    F32(QuantItem<f32>),
}

impl Item {
    /// The item's lane.
    pub fn precision(&self) -> Precision {
        match self {
            Item::F64(_) => Precision::F64,
            Item::F32(_) => Precision::F32,
        }
    }

    /// Per-stage wall times.
    pub fn timings(&self) -> StageTimings {
        match self {
            Item::F64(i) => i.timings,
            Item::F32(i) => i.timings,
        }
    }

    /// Solver diagnostics.
    pub fn diag(&self) -> &QuantDiag {
        match self {
            Item::F64(i) => &i.diag,
            Item::F32(i) => &i.diag,
        }
    }

    /// Squared-l2 information loss.
    pub fn l2_loss(&self) -> f64 {
        match self {
            Item::F64(i) => i.l2_loss,
            Item::F32(i) => i.l2_loss,
        }
    }

    /// Number of values moved by the clamp.
    pub fn clamped(&self) -> usize {
        match self {
            Item::F64(i) => i.clamped,
            Item::F32(i) => i.clamped,
        }
    }

    /// Achieved number of distinct values.
    pub fn distinct_values(&self) -> usize {
        match self {
            Item::F64(i) => i.distinct_values(),
            Item::F32(i) => i.distinct_values(),
        }
    }

    /// Borrow the f64 item, if this is the f64 lane.
    pub fn as_f64(&self) -> Option<&QuantItem<f64>> {
        match self {
            Item::F64(i) => Some(i),
            Item::F32(_) => None,
        }
    }

    /// Borrow the f32 item, if this is the f32 lane.
    pub fn as_f32(&self) -> Option<&QuantItem<f32>> {
        match self {
            Item::F64(_) => None,
            Item::F32(i) => Some(i),
        }
    }

    /// Compression accounting on either lane (the dense baseline follows
    /// the lane's element width: 8 bytes/value for f64, 4 for f32).
    pub fn compression(&self, levels_requested: usize) -> CompressionStats {
        match self {
            Item::F64(i) => i.compression(levels_requested),
            Item::F32(i) => i.compression(levels_requested),
        }
    }

    /// The codebook on the f64 surface (f32 levels widen; indices are
    /// shared unchanged). The compact wire format for f64 consumers.
    pub fn codebook_f64(&self) -> Codebook<f64> {
        match self {
            Item::F64(i) => i.codebook.clone(),
            Item::F32(i) => i.codebook.widen(),
        }
    }

    /// Materialize the full vector on the f64 surface.
    pub fn materialize_f64(&self) -> Vec<f64> {
        match self {
            Item::F64(i) => i.materialize(),
            Item::F32(i) => i.materialize().iter().map(|&x| f64::from(x)).collect(),
        }
    }

    /// Convert into the legacy f64 [`QuantOutput`] (widening f32 results),
    /// exactly as the historical f64-surface entry points did.
    pub fn into_output64(self) -> QuantOutput {
        match self {
            Item::F64(i) => i.into_output(),
            Item::F32(i) => i.into_output().widen(),
        }
    }
}

/// The response to one [`Quantizer::run`]: one item per unit of work
/// (single → 1, batch → one per input, matrix → one per group, sweep →
/// one per λ, in request order). Item failures are isolated per slot —
/// one bad batch element does not fail its siblings.
#[derive(Debug)]
pub struct QuantResponse {
    /// Per-item results, in request order.
    pub items: Vec<Result<Item>>,
}

impl QuantResponse {
    fn from_items(items: Vec<Result<Item>>) -> QuantResponse {
        QuantResponse { items }
    }

    /// Number of items.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True for an empty (zero-input batch) response.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Consume a single-item response (single-vector one-shot requests),
    /// propagating the item's own error if it failed.
    pub fn into_single(mut self) -> Result<Item> {
        if self.items.len() != 1 {
            return Err(Error::InvalidInput(format!(
                "expected a single-item response, got {} items",
                self.items.len()
            )));
        }
        self.items.pop().expect("len checked above")
    }

    /// Materialize every item onto the legacy f64 output surface.
    pub fn into_outputs64(self) -> Vec<Result<QuantOutput>> {
        self.items.into_iter().map(|r| r.map(Item::into_output64)).collect()
    }

    /// Aggregate per-stage wall times over the successful items.
    pub fn timings(&self) -> StageTimings {
        let mut prepare = Duration::ZERO;
        let mut solve = Duration::ZERO;
        for item in self.items.iter().flatten() {
            let t = item.timings();
            prepare += t.prepare;
            solve += t.solve;
        }
        StageTimings { prepare, solve }
    }

    /// Total squared-l2 loss over the successful items.
    pub fn total_l2_loss(&self) -> f64 {
        self.items.iter().flatten().map(Item::l2_loss).sum()
    }

    /// Aggregate compression accounting over the successful items (see
    /// [`CompressionStats::aggregate`] for the aggregation rules).
    /// `levels_requested` is the request's effective `target_values`
    /// ([`QuantRequest::effective_options`]). `None` when no item
    /// succeeded.
    pub fn compression(&self, levels_requested: usize) -> Option<CompressionStats> {
        let per: Vec<CompressionStats> = self
            .items
            .iter()
            .flatten()
            .map(|i| i.compression(levels_requested))
            .collect();
        CompressionStats::aggregate(per.iter())
    }

    /// Stacked compression accounting for a single-group
    /// [`Plan::Cascade`] response: the items are successive planes over
    /// the **same** elements, so their stats fold through
    /// [`CompressionStats::stack`] (per-index bits add, one dense
    /// baseline) instead of [`CompressionStats::aggregate`]'s
    /// parallel-payload rules. Each level's `levels_requested` is its own
    /// achieved count (a cascade has no single request-level target).
    /// `None` when no item succeeded. For batch/matrix cascades, slice the
    /// items per group before stacking — stacking across groups panics on
    /// the element-count mismatch.
    pub fn compression_cascade(&self) -> Option<CompressionStats> {
        let mut acc: Option<CompressionStats> = None;
        for item in self.items.iter().flatten() {
            let s = item.compression(item.distinct_values());
            acc = Some(match acc {
                Some(a) => a.stack(&s),
                None => s,
            });
        }
        acc
    }
}

// ---------------------------------------------------------------------
// The facade
// ---------------------------------------------------------------------

/// The quantization facade: one [`Quantizer::run`] for every request
/// shape. [`Quantizer::new`] is the historical stateless facade (the
/// prepared-input and workspace reuse live per-run); [`Quantizer::caching`]
/// adds bounded cross-run memos keyed by content [`Fingerprint`] —
/// repeated vectors skip the prepare stage and warm λ sweeps extending a
/// previously solved grid resume from the last solved point. Either way
/// the facade is constructed once and shared freely (clones share memos).
#[derive(Debug, Clone, Default)]
pub struct Quantizer {
    /// Cross-run memo tables ([`Quantizer::caching`]); `None` — the
    /// default — is the stateless facade.
    memo: Option<Arc<Mutex<QuantizerMemo>>>,
}

impl Quantizer {
    /// A new stateless facade.
    pub fn new() -> Quantizer {
        Quantizer { memo: None }
    }

    /// A memoizing facade: repeated single-vector requests skip the
    /// sort/decomposition (the [`PreparedInput`] memo, keyed by the input
    /// bytes + lane), and a warm λ sweep whose grid extends a previously
    /// solved one resumes the chain from the nearest (last) solved point
    /// instead of re-solving the shared prefix — a grid that is a prefix
    /// of a solved chain replays entirely from the memo without solving.
    ///
    /// Results are **bitwise-identical** to the stateless facade: memo
    /// keys are full content fingerprints verified bit-for-bit on every
    /// hit (a hash collision degrades to a miss), and the resumed chain
    /// state is exactly what the full-grid warm sweep would have carried
    /// ([`SweepState::resume`]). Memoization covers single-vector one-shot
    /// / target-count / warm-sweep plans on both lanes; batch, matrix,
    /// cold-sweep and cascade plans run stateless. Each memo table is LRU
    /// bounded to `max_entries`.
    pub fn caching(max_entries: usize) -> Quantizer {
        Quantizer {
            memo: Some(Arc::new(Mutex::new(QuantizerMemo::new(max_entries.max(1))))),
        }
    }

    /// Serve one request. Returns `Err` only for request-shape errors
    /// (e.g. an empty matrix); per-item solve failures land in
    /// [`QuantResponse::items`] so batch siblings survive. Sweep plans
    /// compose with every input: over a batch/matrix this is the
    /// batch×sweep plan — B groups × K λs ⇒ B·K items, group-major, one
    /// warm-start chain per group, groups fanned across the batch
    /// executor.
    pub fn run(&self, req: &QuantRequest) -> Result<QuantResponse> {
        let opts = req.effective_options();
        validate_entropy_budget(&opts)?;
        if let Some(weights) = req.normalized_weights()? {
            return run_weighted(req, &opts, &weights);
        }
        match (&req.input, &req.plan) {
            (RequestInput::VectorF64(w), Plan::Sweep { lambdas, warm_start }) => {
                if let (Some(memo), true) = (&self.memo, *warm_start) {
                    let items: Vec<Result<Item>> = match opts.precision {
                        Precision::F64 => sweep_memo_lane::<f64>(
                            memo,
                            Arc::clone(w),
                            req.method,
                            lambdas,
                            &opts,
                            req.output,
                            Duration::ZERO,
                        )?
                        .into_iter()
                        .map(|i| Ok(Item::F64(i)))
                        .collect(),
                        Precision::F32 => {
                            let t0 = Instant::now();
                            let narrow: Arc<[f32]> =
                                w.iter().map(|&x| x as f32).collect::<Vec<f32>>().into();
                            let narrowing = t0.elapsed();
                            sweep_memo_lane::<f32>(
                                memo, narrow, req.method, lambdas, &opts, req.output, narrowing,
                            )?
                            .into_iter()
                            .map(|i| Ok(Item::F32(i)))
                            .collect()
                        }
                    };
                    return Ok(QuantResponse::from_items(items));
                }
                let items = sweep_shared_f64(
                    Arc::clone(w),
                    req.method,
                    lambdas,
                    &opts,
                    *warm_start,
                    req.output,
                )?;
                Ok(QuantResponse::from_items(items.into_iter().map(Ok).collect()))
            }
            (RequestInput::VectorF32(w), Plan::Sweep { lambdas, warm_start }) => {
                if let (Some(memo), true) = (&self.memo, *warm_start) {
                    let items = sweep_memo_lane::<f32>(
                        memo,
                        Arc::clone(w),
                        req.method,
                        lambdas,
                        &opts,
                        req.output,
                        Duration::ZERO,
                    )?;
                    return Ok(QuantResponse::from_items(
                        items.into_iter().map(|i| Ok(Item::F32(i))).collect(),
                    ));
                }
                let t0 = Instant::now();
                let prep = PreparedInput::from_shared(Arc::clone(w))?;
                let prepare = t0.elapsed();
                let items = sweep_prepared_core(
                    &prep, req.method, lambdas, &opts, *warm_start, req.output, prepare,
                )?;
                Ok(QuantResponse::from_items(
                    items.into_iter().map(|i| Ok(Item::F32(i))).collect(),
                ))
            }
            // Batch×sweep: fan the groups across the batch executor, each
            // group running its own warm-started λ path. B groups × K λs
            // ⇒ B·K items, group-major; a failed group yields K error
            // items so the shape is preserved.
            (RequestInput::BatchF64(inputs), Plan::Sweep { lambdas, warm_start }) => {
                let per = batch_map(inputs, |w| {
                    sweep_shared_f64(
                        Arc::from(w.as_slice()),
                        req.method,
                        lambdas,
                        &opts,
                        *warm_start,
                        req.output,
                    )
                });
                Ok(QuantResponse::from_items(flatten_sweep(per, lambdas.len())))
            }
            (RequestInput::BatchF32(inputs), Plan::Sweep { lambdas, warm_start }) => {
                let per = batch_map(inputs, |w| -> Result<Vec<Item>> {
                    let t0 = Instant::now();
                    let prep = PreparedInput::from_shared(Arc::from(w.as_slice()))?;
                    let prepare = t0.elapsed();
                    Ok(sweep_prepared_core(
                        &prep, req.method, lambdas, &opts, *warm_start, req.output, prepare,
                    )?
                    .into_iter()
                    .map(Item::F32)
                    .collect())
                });
                Ok(QuantResponse::from_items(flatten_sweep(per, lambdas.len())))
            }
            (RequestInput::Matrix(m, grouping), Plan::Sweep { lambdas, warm_start }) => {
                let groups = matrix_groups(m, *grouping)?;
                let per = batch_map(&groups, |w| {
                    sweep_shared_f64(
                        Arc::clone(w),
                        req.method,
                        lambdas,
                        &opts,
                        *warm_start,
                        req.output,
                    )
                });
                Ok(QuantResponse::from_items(flatten_sweep(per, lambdas.len())))
            }
            (RequestInput::VectorF64(w), Plan::Cascade { bits, norm_tol }) => {
                let items = cascade_shared_f64(
                    Arc::clone(w),
                    req.method,
                    bits,
                    *norm_tol,
                    &opts,
                    req.output,
                )?;
                Ok(QuantResponse::from_items(items.into_iter().map(Ok).collect()))
            }
            (RequestInput::VectorF32(w), Plan::Cascade { bits, norm_tol }) => {
                let items = cascade_shared_f32(
                    Arc::clone(w),
                    req.method,
                    bits,
                    *norm_tol,
                    &opts,
                    req.output,
                )?;
                Ok(QuantResponse::from_items(items.into_iter().map(Ok).collect()))
            }
            // Batch/matrix × cascade: groups fan across the batch executor,
            // each running its own residual cascade and stopping at its own
            // tolerance — items are group-major and per-group counts may
            // differ (a failed group contributes one error item).
            (RequestInput::BatchF64(inputs), Plan::Cascade { bits, norm_tol }) => {
                validate_cascade_bits(bits)?;
                let per = batch_map(inputs, |w| {
                    cascade_shared_f64(
                        Arc::from(w.as_slice()),
                        req.method,
                        bits,
                        *norm_tol,
                        &opts,
                        req.output,
                    )
                });
                Ok(QuantResponse::from_items(flatten_cascade(per)))
            }
            (RequestInput::BatchF32(inputs), Plan::Cascade { bits, norm_tol }) => {
                validate_cascade_bits(bits)?;
                let per = batch_map(inputs, |w| {
                    cascade_shared_f32(
                        Arc::from(w.as_slice()),
                        req.method,
                        bits,
                        *norm_tol,
                        &opts,
                        req.output,
                    )
                });
                Ok(QuantResponse::from_items(flatten_cascade(per)))
            }
            (RequestInput::Matrix(m, grouping), Plan::Cascade { bits, norm_tol }) => {
                validate_cascade_bits(bits)?;
                let groups = matrix_groups(m, *grouping)?;
                let per = batch_map(&groups, |w| {
                    cascade_shared_f64(
                        Arc::clone(w),
                        req.method,
                        bits,
                        *norm_tol,
                        &opts,
                        req.output,
                    )
                });
                Ok(QuantResponse::from_items(flatten_cascade(per)))
            }
            (RequestInput::VectorF64(w), _) => Ok(QuantResponse::from_items(vec![
                self.run_vec_f64(Arc::clone(w), req.method, &opts, req.output),
            ])),
            (RequestInput::VectorF32(w), _) => Ok(QuantResponse::from_items(vec![
                self.run_vec_f32(Arc::clone(w), req.method, &opts, req.output).map(Item::F32),
            ])),
            (RequestInput::BatchF64(inputs), _) => Ok(QuantResponse::from_items(
                batch_core_f64(inputs, req.method, &opts, req.output),
            )),
            (RequestInput::BatchF32(inputs), _) => Ok(QuantResponse::from_items(
                batch_core_f32(inputs, req.method, &opts, req.output),
            )),
            (RequestInput::Matrix(m, grouping), _) => {
                let groups = matrix_groups(m, *grouping)?;
                Ok(QuantResponse::from_items(batch_core_shared_f64(
                    &groups, req.method, &opts, req.output,
                )))
            }
        }
    }

    /// One-shot single f64-surface vector, consulting the prepare memo
    /// when this facade is caching. The memo only short-circuits the
    /// prepare stage, so results match [`run_shared_f64`] bitwise.
    fn run_vec_f64(
        &self,
        w: Arc<[f64]>,
        method: QuantMethod,
        opts: &QuantOptions,
        form: OutputForm,
    ) -> Result<Item> {
        let Some(memo) = &self.memo else {
            return run_shared_f64(w, method, opts, form);
        };
        match opts.precision {
            Precision::F64 => {
                let t0 = Instant::now();
                let prep = memo_prep::<f64>(memo, &w)?;
                let prepare = t0.elapsed();
                run_prepared_core(&prep, method, opts, form, prepare).map(Item::F64)
            }
            Precision::F32 => {
                let t0 = Instant::now();
                let narrow: Arc<[f32]> = w.iter().map(|&x| x as f32).collect::<Vec<f32>>().into();
                let prep = memo_prep::<f32>(memo, &narrow)?;
                let prepare = t0.elapsed();
                run_prepared_core(&prep, method, opts, form, prepare).map(Item::F32)
            }
        }
    }

    /// One-shot single f32 payload (native narrow lane), consulting the
    /// prepare memo when this facade is caching.
    fn run_vec_f32(
        &self,
        w: Arc<[f32]>,
        method: QuantMethod,
        opts: &QuantOptions,
        form: OutputForm,
    ) -> Result<QuantItem<f32>> {
        let Some(memo) = &self.memo else {
            return run_shared_f32(w, method, opts, form);
        };
        let t0 = Instant::now();
        let prep = memo_prep::<f32>(memo, &w)?;
        let prepare = t0.elapsed();
        run_prepared_core(&prep, method, opts, form, prepare)
    }
}

/// Weighted dispatch: every plan except the cascade, always on the
/// stateless path — weights are not part of the memo keys, so the
/// caching facade's prepare/chain memos are bypassed and a weighted
/// request is solved fresh every time (uniform weights never reach
/// here; [`QuantRequest::normalized_weights`] drops them upstream).
fn run_weighted(
    req: &QuantRequest,
    opts: &QuantOptions,
    weights: &NormWeights,
) -> Result<QuantResponse> {
    if let Plan::Cascade { .. } = req.plan {
        return Err(Error::InvalidInput(
            "cascade: per-element importance weights are not supported (cascade levels \
             re-quantize residuals, which have no per-element identity)"
                .into(),
        ));
    }
    match (&req.input, weights) {
        (RequestInput::VectorF64(w), NormWeights::Vector(uw)) => match &req.plan {
            Plan::Sweep { lambdas, warm_start } => {
                let items = sweep_shared_f64_weighted(
                    Arc::clone(w),
                    Some(uw.as_ref()),
                    req.method,
                    lambdas,
                    opts,
                    *warm_start,
                    req.output,
                )?;
                Ok(QuantResponse::from_items(items.into_iter().map(Ok).collect()))
            }
            _ => Ok(QuantResponse::from_items(vec![run_shared_f64_weighted(
                Arc::clone(w),
                Some(uw.as_ref()),
                req.method,
                opts,
                req.output,
            )])),
        },
        (RequestInput::VectorF32(w), NormWeights::Vector(uw)) => match &req.plan {
            Plan::Sweep { lambdas, warm_start } => {
                let t0 = Instant::now();
                let prep =
                    PreparedInput::from_shared(Arc::clone(w))?.with_user_weights(uw)?;
                let prepare = t0.elapsed();
                let items = sweep_prepared_core(
                    &prep, req.method, lambdas, opts, *warm_start, req.output, prepare,
                )?;
                Ok(QuantResponse::from_items(
                    items.into_iter().map(|i| Ok(Item::F32(i))).collect(),
                ))
            }
            _ => Ok(QuantResponse::from_items(vec![run_shared_f32_weighted(
                Arc::clone(w),
                Some(uw.as_ref()),
                req.method,
                opts,
                req.output,
            )
            .map(Item::F32)])),
        },
        (RequestInput::BatchF64(inputs), NormWeights::Batch(ws)) => {
            let slots: Vec<(&[f64], Option<&[f64]>)> = inputs
                .iter()
                .zip(ws)
                .map(|(v, u)| (v.as_slice(), u.as_deref()))
                .collect();
            match &req.plan {
                Plan::Sweep { lambdas, warm_start } => {
                    let per = batch_map(&slots, |&(v, u)| {
                        sweep_shared_f64_weighted(
                            Arc::from(v),
                            u,
                            req.method,
                            lambdas,
                            opts,
                            *warm_start,
                            req.output,
                        )
                    });
                    Ok(QuantResponse::from_items(flatten_sweep(per, lambdas.len())))
                }
                _ => Ok(QuantResponse::from_items(batch_map(&slots, |&(v, u)| {
                    run_shared_f64_weighted(Arc::from(v), u, req.method, opts, req.output)
                }))),
            }
        }
        (RequestInput::BatchF32(inputs), NormWeights::Batch(ws)) => {
            let slots: Vec<(&[f32], Option<&[f64]>)> = inputs
                .iter()
                .zip(ws)
                .map(|(v, u)| (v.as_slice(), u.as_deref()))
                .collect();
            match &req.plan {
                Plan::Sweep { lambdas, warm_start } => {
                    let per = batch_map(&slots, |&(v, u)| -> Result<Vec<Item>> {
                        let t0 = Instant::now();
                        let mut prep = PreparedInput::from_shared(Arc::from(v))?;
                        if let Some(u) = u {
                            prep = prep.with_user_weights(u)?;
                        }
                        let prepare = t0.elapsed();
                        Ok(sweep_prepared_core(
                            &prep, req.method, lambdas, opts, *warm_start, req.output,
                            prepare,
                        )?
                        .into_iter()
                        .map(Item::F32)
                        .collect())
                    });
                    Ok(QuantResponse::from_items(flatten_sweep(per, lambdas.len())))
                }
                _ => Ok(QuantResponse::from_items(batch_map(&slots, |&(v, u)| {
                    run_shared_f32_weighted(Arc::from(v), u, req.method, opts, req.output)
                        .map(Item::F32)
                }))),
            }
        }
        (RequestInput::Matrix(m, grouping), NormWeights::Vector(uw)) => {
            let groups = matrix_groups(m, *grouping)?;
            let wgroups = matrix_weight_groups(m.rows(), m.cols(), *grouping, uw);
            // Per-group validation (a group must carry positive weight on
            // its own) and per-group uniform drop, mirroring the batch
            // slots: a uniformly weighted row/column runs unweighted.
            let mut slots: Vec<(&Arc<[f64]>, Option<&[f64]>)> =
                Vec::with_capacity(groups.len());
            for (g, wg) in groups.iter().zip(&wgroups) {
                validate_weights(wg, g.len())?;
                slots.push((g, (!weights_are_uniform(wg)).then(|| wg.as_slice())));
            }
            match &req.plan {
                Plan::Sweep { lambdas, warm_start } => {
                    let per = batch_map(&slots, |&(g, u)| {
                        sweep_shared_f64_weighted(
                            Arc::clone(g),
                            u,
                            req.method,
                            lambdas,
                            opts,
                            *warm_start,
                            req.output,
                        )
                    });
                    Ok(QuantResponse::from_items(flatten_sweep(per, lambdas.len())))
                }
                _ => Ok(QuantResponse::from_items(batch_map(&slots, |&(g, u)| {
                    run_shared_f64_weighted(Arc::clone(g), u, req.method, opts, req.output)
                }))),
            }
        }
        // normalized_weights only produces shape-matched pairs; anything
        // else is a logic error surfaced as a request error, not a panic.
        _ => Err(Error::InvalidInput(
            "weights: form does not match the input shape".into(),
        )),
    }
}

/// Split a matrix's per-element (row-major) weight vector into the same
/// groups [`matrix_groups`] splits the data into, so every weight
/// follows its element through the fan-out.
fn matrix_weight_groups(
    rows: usize,
    cols: usize,
    grouping: Grouping,
    w: &[f64],
) -> Vec<Vec<f64>> {
    match grouping {
        Grouping::PerTensor => vec![w.to_vec()],
        Grouping::PerRow => {
            (0..rows).map(|i| w[i * cols..(i + 1) * cols].to_vec()).collect()
        }
        Grouping::PerColumn => (0..cols)
            .map(|j| (0..rows).map(|i| w[i * cols + j]).collect())
            .collect(),
    }
}

// ---------------------------------------------------------------------
// Content fingerprints — the cross-request cache key
// ---------------------------------------------------------------------

/// A 128-bit content fingerprint of `(input bytes, precision lane,
/// method, plan, options)` — the key the coordinator's serve-path result
/// cache and the [`Quantizer::caching`] memos dedup repeated work under.
///
/// Two requests share a fingerprint only when every bit that can
/// influence the solve is identical: the payload's element bit patterns
/// (`to_bits`, so `-0.0` ≠ `0.0` and NaN payloads never alias anything),
/// the lane, the method id, the plan shape, any per-element importance
/// weights (uniform weights hash as unweighted — they run the identical
/// solve), and all thirteen option fields.
/// The hash is two parallel 64-bit FNV-1a streams over the same byte
/// sequence with distinct offset bases; consumers that must be
/// collision-proof additionally retain the full key and verify it
/// bit-for-bit on every hit, so a collision degrades to a cache miss,
/// never a wrong answer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    hi: u64,
    lo: u64,
}

impl Fingerprint {
    /// Admission key for one f64 payload solved with `method` under
    /// `opts` (the coordinator folds `Plan::TargetCount` into
    /// `opts.target_values` before admission, so one-shot and
    /// target-count requests that run the same solve share a key — which
    /// is exactly the dedup the cache wants).
    pub fn vector_f64(w: &[f64], method: QuantMethod, opts: &QuantOptions) -> Fingerprint {
        Self::vector_f64_weighted(w, None, method, opts)
    }

    /// Admission key for one f32 payload (the native narrow lane).
    pub fn vector_f32(w: &[f32], method: QuantMethod, opts: &QuantOptions) -> Fingerprint {
        Self::vector_f32_weighted(w, None, method, opts)
    }

    /// [`Fingerprint::vector_f64`] for an importance-weighted payload:
    /// non-uniform weights salt the key (behind a domain tag, so a
    /// weighted request can never alias an unweighted one), while `None`
    /// or uniform weights hash exactly as the unweighted key — mirroring
    /// the facade, which runs uniform weights down the unweighted path
    /// bitwise.
    pub fn vector_f64_weighted(
        w: &[f64],
        weights: Option<&[f64]>,
        method: QuantMethod,
        opts: &QuantOptions,
    ) -> Fingerprint {
        let mut h = FpHasher::new();
        h.elems::<f64>(w);
        h.weights(weights);
        h.str(method.id());
        h.opts(opts);
        h.finish()
    }

    /// [`Fingerprint::vector_f64_weighted`] for the native f32 lane
    /// (weights stay f64 — the wire carries them double-precision).
    pub fn vector_f32_weighted(
        w: &[f32],
        weights: Option<&[f64]>,
        method: QuantMethod,
        opts: &QuantOptions,
    ) -> Fingerprint {
        let mut h = FpHasher::new();
        h.elems::<f32>(w);
        h.weights(weights);
        h.str(method.id());
        h.opts(opts);
        h.finish()
    }

    /// Salt this fingerprint with a tenant id — the coordinator's
    /// per-tenant cache partitioning (`Config::cache_shared = false`)
    /// folds the tenant into the key so partitioned tenants can never
    /// alias each other's entries, even before the full-key bit check.
    /// Salting with distinct tenants yields distinct fingerprints with
    /// the same collision bounds as the base hash; the un-salted
    /// fingerprint is the shared-cache key.
    pub fn with_tenant(self, tenant: &str) -> Fingerprint {
        let mut h = FpHasher { hi: self.hi, lo: self.lo };
        h.str(tenant);
        h.finish()
    }

    /// Fingerprint of a full request: input bytes + lane + method +
    /// effective options + plan. Defined for every input shape (batches
    /// and matrices hash all their groups), so any request can be
    /// dedup-keyed by content.
    pub fn of_request(req: &QuantRequest) -> Fingerprint {
        let mut h = FpHasher::new();
        match &req.input {
            RequestInput::VectorF64(w) => h.elems::<f64>(w),
            RequestInput::VectorF32(w) => h.elems::<f32>(w),
            RequestInput::BatchF64(vs) => {
                h.byte(2);
                h.usize(vs.len());
                for v in vs {
                    h.elems::<f64>(v);
                }
            }
            RequestInput::BatchF32(vs) => {
                h.byte(3);
                h.usize(vs.len());
                for v in vs {
                    h.elems::<f32>(v);
                }
            }
            RequestInput::Matrix(m, g) => {
                h.byte(4);
                h.usize(m.rows());
                h.usize(m.cols());
                h.elems::<f64>(m.data());
                h.byte(match g {
                    Grouping::PerTensor => 0,
                    Grouping::PerRow => 1,
                    Grouping::PerColumn => 2,
                });
            }
        }
        h.str(req.method.id());
        h.opts(&req.effective_options());
        match &req.plan {
            // TargetCount folds into the effective options above, so it
            // hashes identically to the equivalent one-shot — by design.
            Plan::OneShot | Plan::TargetCount(_) => h.byte(0),
            Plan::Sweep { lambdas, warm_start } => {
                h.byte(1);
                h.byte(u8::from(*warm_start));
                h.elems::<f64>(lambdas);
            }
            Plan::Cascade { bits, norm_tol } => {
                h.byte(2);
                h.usize(bits.len());
                for &b in bits {
                    h.u64(u64::from(b));
                }
                h.u64(norm_tol.to_bits());
            }
        }
        // Importance weights, normalized first so uniform-weight
        // requests alias the unweighted key they bitwise-reproduce
        // (malformed weights hash as unweighted — they error before any
        // cache could be consulted).
        match req.normalized_weights().ok().flatten() {
            None => {}
            Some(NormWeights::Vector(w)) => h.weights(Some(w.as_ref())),
            Some(NormWeights::Batch(ws)) => {
                h.byte(0x57);
                h.usize(ws.len());
                for slot in &ws {
                    match slot {
                        None => h.byte(0),
                        Some(w) => {
                            h.byte(1);
                            h.elems::<f64>(w);
                        }
                    }
                }
            }
        }
        h.finish()
    }
}

/// Two parallel FNV-1a streams over one byte sequence, with distinct
/// offset bases (the second stream also perturbs each byte) so the two
/// 64-bit halves decorrelate.
struct FpHasher {
    hi: u64,
    lo: u64,
}

impl FpHasher {
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    fn new() -> FpHasher {
        FpHasher { hi: 0xcbf2_9ce4_8422_2325, lo: 0x6c62_272e_07bb_0142 }
    }

    fn byte(&mut self, b: u8) {
        self.hi = (self.hi ^ u64::from(b)).wrapping_mul(Self::PRIME);
        self.lo = (self.lo ^ u64::from(b ^ 0x5a)).wrapping_mul(Self::PRIME);
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn str(&mut self, s: &str) {
        self.usize(s.len());
        for &b in s.as_bytes() {
            self.byte(b);
        }
    }

    /// Lane tag + length + element bit patterns.
    fn elems<T: MemoLane>(&mut self, xs: &[T]) {
        self.byte(T::LANE_TAG);
        self.usize(xs.len());
        for &x in xs {
            self.u64(T::elem_bits(x));
        }
    }

    /// Optional importance weights. Nothing is hashed for `None` or a
    /// uniform vector — those run (and must alias) the unweighted solve;
    /// non-uniform weights append a domain tag plus their bit patterns.
    fn weights(&mut self, w: Option<&[f64]>) {
        if let Some(w) = w.filter(|w| !weights_are_uniform(w)) {
            self.byte(0x57); // 'W' — weighted keys never alias unweighted ones
            self.elems::<f64>(w);
        }
    }

    /// Every option field, in declaration order, bit patterns for floats.
    fn opts(&mut self, o: &QuantOptions) {
        self.u64(o.lambda1.to_bits());
        self.u64(o.lambda2.to_bits());
        self.usize(o.target_values);
        self.usize(o.max_epochs);
        self.u64(o.tol.to_bits());
        self.usize(o.kmeans_restarts);
        self.usize(o.max_iters);
        self.u64(o.seed);
        self.byte(u8::from(o.refit));
        self.usize(o.max_lambda_steps);
        match o.clamp {
            None => self.byte(0),
            Some((lo, hi)) => {
                self.byte(1);
                self.u64(lo.to_bits());
                self.u64(hi.to_bits());
            }
        }
        self.byte(match o.precision {
            Precision::F64 => 0,
            Precision::F32 => 1,
        });
        match o.entropy_budget {
            None => self.byte(0),
            Some(b) => {
                self.byte(1);
                self.u64(b.to_bits());
            }
        }
    }

    fn finish(self) -> Fingerprint {
        Fingerprint { hi: self.hi, lo: self.lo }
    }
}

/// Bit-exact option equality — the cache-key comparison. `PartialEq` on
/// floats would conflate `-0.0`/`0.0` and un-equal NaN options; keys
/// compare bit patterns so "identical request" means identical bits.
pub(crate) fn opts_bits_eq(a: &QuantOptions, b: &QuantOptions) -> bool {
    a.lambda1.to_bits() == b.lambda1.to_bits()
        && a.lambda2.to_bits() == b.lambda2.to_bits()
        && a.target_values == b.target_values
        && a.max_epochs == b.max_epochs
        && a.tol.to_bits() == b.tol.to_bits()
        && a.kmeans_restarts == b.kmeans_restarts
        && a.max_iters == b.max_iters
        && a.seed == b.seed
        && a.refit == b.refit
        && a.max_lambda_steps == b.max_lambda_steps
        && match (a.clamp, b.clamp) {
            (None, None) => true,
            (Some((al, ah)), Some((bl, bh))) => {
                al.to_bits() == bl.to_bits() && ah.to_bits() == bh.to_bits()
            }
            _ => false,
        }
        && a.precision == b.precision
        && match (a.entropy_budget, b.entropy_budget) {
            (None, None) => true,
            (Some(x), Some(y)) => x.to_bits() == y.to_bits(),
            _ => false,
        }
}

// ---------------------------------------------------------------------
// Quantizer memos (Quantizer::caching)
// ---------------------------------------------------------------------

/// Lane plumbing for the memo tables, which are concrete per element
/// type: the fingerprint lane tag, element bit patterns for hashing and
/// hit verification, and the typed slots inside the shared memo.
pub(crate) trait MemoLane: LaneSolve {
    /// Fingerprint lane tag (0 = f64, 1 = f32).
    const LANE_TAG: u8;
    /// The element's bit pattern, widened to u64.
    fn elem_bits(x: Self) -> u64;
    /// This lane's prepared-input memo table.
    fn prep_slot(m: &mut QuantizerMemo) -> &mut MemoTable<PreparedInput<Self>>;
    /// Borrow this lane's chain out of the lane-erased slot.
    fn chain_ref(c: &SweepChain) -> Option<&SweepChainT<Self>>;
    /// Unwrap this lane's chain out of the lane-erased slot.
    fn unwrap_chain(c: SweepChain) -> Option<SweepChainT<Self>>;
    /// Wrap this lane's chain into the lane-erased slot.
    fn wrap_chain(c: SweepChainT<Self>) -> SweepChain;
}

impl MemoLane for f64 {
    const LANE_TAG: u8 = 0;
    fn elem_bits(x: f64) -> u64 {
        x.to_bits()
    }
    fn prep_slot(m: &mut QuantizerMemo) -> &mut MemoTable<PreparedInput<f64>> {
        &mut m.prep64
    }
    fn chain_ref(c: &SweepChain) -> Option<&SweepChainT<f64>> {
        match c {
            SweepChain::F64(c) => Some(c),
            SweepChain::F32(_) => None,
        }
    }
    fn unwrap_chain(c: SweepChain) -> Option<SweepChainT<f64>> {
        match c {
            SweepChain::F64(c) => Some(c),
            SweepChain::F32(_) => None,
        }
    }
    fn wrap_chain(c: SweepChainT<f64>) -> SweepChain {
        SweepChain::F64(c)
    }
}

impl MemoLane for f32 {
    const LANE_TAG: u8 = 1;
    fn elem_bits(x: f32) -> u64 {
        u64::from(x.to_bits())
    }
    fn prep_slot(m: &mut QuantizerMemo) -> &mut MemoTable<PreparedInput<f32>> {
        &mut m.prep32
    }
    fn chain_ref(c: &SweepChain) -> Option<&SweepChainT<f32>> {
        match c {
            SweepChain::F64(_) => None,
            SweepChain::F32(c) => Some(c),
        }
    }
    fn unwrap_chain(c: SweepChain) -> Option<SweepChainT<f32>> {
        match c {
            SweepChain::F64(_) => None,
            SweepChain::F32(c) => Some(c),
        }
    }
    fn wrap_chain(c: SweepChainT<f32>) -> SweepChain {
        SweepChain::F32(c)
    }
}

/// Bit-exact slice equality on a lane (the memo's hit verification).
fn bits_eq<T: MemoLane>(a: &[T], b: &[T]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(&x, &y)| T::elem_bits(x) == T::elem_bits(y))
}

/// Input-only fingerprint (the prepared-input memo key — the
/// decomposition depends only on the payload bits and the lane).
fn input_fp<T: MemoLane>(w: &[T]) -> Fingerprint {
    let mut h = FpHasher::new();
    h.elems::<T>(w);
    h.finish()
}

/// Chain-table key: input + method + base options with λ₁ canonicalized
/// to zero (the grid overrides it per point, so the base value is inert),
/// plus a domain separator so chain and prep keys never alias.
fn chain_fp<T: MemoLane>(w: &[T], method: QuantMethod, canon: &QuantOptions) -> Fingerprint {
    let mut h = FpHasher::new();
    h.elems::<T>(w);
    h.str(method.id());
    h.opts(canon);
    h.byte(0xca);
    h.finish()
}

/// A tiny stamped LRU map: `put` beyond `max` entries evicts the least
/// recently touched key (an O(n) scan — memo tables are small by
/// construction).
#[derive(Debug)]
pub(crate) struct MemoTable<V> {
    max: usize,
    clock: u64,
    map: HashMap<Fingerprint, (u64, V)>,
}

impl<V> MemoTable<V> {
    fn new(max: usize) -> MemoTable<V> {
        MemoTable { max, clock: 0, map: HashMap::new() }
    }

    fn get(&mut self, k: Fingerprint) -> Option<&V> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(&k).map(|(stamp, v)| {
            *stamp = clock;
            &*v
        })
    }

    fn take(&mut self, k: Fingerprint) -> Option<V> {
        self.map.remove(&k).map(|(_, v)| v)
    }

    fn put(&mut self, k: Fingerprint, v: V) {
        self.clock += 1;
        self.map.insert(k, (self.clock, v));
        while self.map.len() > self.max {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(&fp, _)| fp)
                .expect("non-empty map over capacity");
            self.map.remove(&oldest);
        }
    }
}

/// The caching facade's cross-run state: prepared inputs per lane and
/// solved warm-sweep chains, each LRU-bounded.
#[derive(Debug)]
pub(crate) struct QuantizerMemo {
    prep64: MemoTable<PreparedInput<f64>>,
    prep32: MemoTable<PreparedInput<f32>>,
    chains: MemoTable<SweepChain>,
}

impl QuantizerMemo {
    fn new(max_entries: usize) -> QuantizerMemo {
        QuantizerMemo {
            prep64: MemoTable::new(max_entries),
            prep32: MemoTable::new(max_entries),
            chains: MemoTable::new(max_entries),
        }
    }
}

/// A solved warm-start λ chain, lane-erased for the shared memo table.
#[derive(Debug)]
pub(crate) enum SweepChain {
    F64(SweepChainT<f64>),
    F32(SweepChainT<f32>),
}

/// One lane's solved chain: the verified key (input + method + canonical
/// options), the grid prefix solved so far with its compact items, and
/// the warm-start coefficients an extension resumes from.
#[derive(Debug)]
pub(crate) struct SweepChainT<T: Scalar> {
    original: Arc<[T]>,
    method: QuantMethod,
    /// Base options with λ₁ zeroed (the canonical chain key form).
    opts: QuantOptions,
    /// Solved λ grid prefix, as bit patterns in grid order.
    lambdas: Vec<u64>,
    /// One compact item per solved grid point (values stripped; cloned
    /// out on reuse and re-formed per request).
    items: Vec<QuantItem<T>>,
    /// Chain state after the last solved point (both lane slots — the f32
    /// lane's CD solvers warm through `warm_alpha32`).
    warm_alpha: Option<Vec<f64>>,
    warm_alpha32: Option<Vec<f32>>,
}

/// Prepare `w` through the memo: a verified hit skips the
/// sort/decomposition entirely; a miss builds and stores. Either way the
/// returned input's contents are identical (the build is deterministic),
/// so downstream solves are bitwise-unchanged.
fn memo_prep<T: MemoLane>(
    memo: &Mutex<QuantizerMemo>,
    w: &Arc<[T]>,
) -> Result<PreparedInput<T>> {
    let fp = input_fp::<T>(w);
    {
        let mut m = memo.lock().expect("quantizer memo poisoned");
        if let Some(prep) = T::prep_slot(&mut m).get(fp) {
            if bits_eq::<T>(prep.original(), w) {
                return Ok(prep.clone());
            }
        }
    }
    let prep = PreparedInput::from_shared(Arc::clone(w))?;
    let mut m = memo.lock().expect("quantizer memo poisoned");
    T::prep_slot(&mut m).put(fp, prep.clone());
    Ok(prep)
}

/// Re-form a memoized compact item for the requesting output form
/// (decode is deterministic, so eager values match what a fresh
/// `OutputForm::Values` run would have produced).
fn with_form<T: Scalar>(mut item: QuantItem<T>, form: OutputForm) -> QuantItem<T> {
    item.values = match form {
        OutputForm::Values => Some(item.codebook.decode()),
        OutputForm::Codebook => None,
    };
    item
}

/// A warm λ sweep through the chain memo. Three cases, all bitwise-equal
/// to the stateless warm sweep of the full requested grid:
///
/// * the requested grid is a prefix of (or equal to) a solved chain —
///   replay the memoized items, zero solves;
/// * a solved chain is a proper prefix of the requested grid — resume
///   from the chain's tail state ([`SweepState::resume`]) and solve only
///   the extension;
/// * no usable chain — solve the full grid fresh (through the prepare
///   memo) and remember it.
fn sweep_memo_lane<T: MemoLane>(
    memo: &Mutex<QuantizerMemo>,
    w: Arc<[T]>,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    form: OutputForm,
    narrowing: Duration,
) -> Result<Vec<QuantItem<T>>> {
    let canon = QuantOptions { lambda1: 0.0, ..base.clone() };
    let key = chain_fp::<T>(&w, method, &canon);
    let grid: Vec<u64> = lambdas.iter().map(|l| l.to_bits()).collect();

    // Probe under the lock: a covering chain answers immediately with
    // zero solves; a proper prefix is taken out so its items and α move
    // into the continuation; anything else (different grid head, failed
    // verification) is a miss and gets replaced below.
    enum Probe {
        Cover(usize),
        Extend,
        Miss,
    }
    let resumed: Option<SweepChainT<T>> = {
        let mut m = memo.lock().expect("quantizer memo poisoned");
        let probe = match m.chains.get(key).and_then(T::chain_ref) {
            Some(c)
                if bits_eq::<T>(&c.original, &w)
                    && c.method == method
                    && opts_bits_eq(&c.opts, &canon) =>
            {
                if c.lambdas.len() >= grid.len() && c.lambdas[..grid.len()] == grid[..] {
                    Probe::Cover(grid.len())
                } else if c.lambdas.len() < grid.len()
                    && grid[..c.lambdas.len()] == c.lambdas[..]
                {
                    Probe::Extend
                } else {
                    Probe::Miss
                }
            }
            _ => Probe::Miss,
        };
        match probe {
            Probe::Cover(k) => {
                let c = m.chains.get(key).and_then(T::chain_ref).expect("probed above");
                return Ok(c.items[..k].iter().map(|i| with_form(i.clone(), form)).collect());
            }
            Probe::Extend => m.chains.take(key).and_then(T::unwrap_chain),
            Probe::Miss => None,
        }
    };

    let (mut items, mut state, solved) = match resumed {
        Some(chain) => {
            let state = SweepState::resume(chain.warm_alpha, chain.warm_alpha32);
            (chain.items, state, chain.lambdas.len())
        }
        None => (Vec::new(), SweepState::default(), 0),
    };
    let t0 = Instant::now();
    let prep = memo_prep::<T>(memo, &w)?;
    let prepare = narrowing + t0.elapsed();
    sweep_steps(
        &prep,
        method,
        &lambdas[solved..],
        base,
        true,
        OutputForm::Codebook,
        prepare,
        &mut state,
        &mut items,
    )?;
    // Remember the extended chain (tail α included) for the next
    // extension, then shape the response for this request's output form.
    let (warm_alpha, warm_alpha32) = state.into_warm();
    let chain = SweepChainT {
        original: Arc::clone(&w),
        method,
        opts: canon,
        lambdas: grid,
        items: items.clone(),
        warm_alpha,
        warm_alpha32,
    };
    memo.lock().expect("quantizer memo poisoned").chains.put(key, T::wrap_chain(chain));
    Ok(items.into_iter().map(|i| with_form(i, form)).collect())
}
fn replicate_err(e: &Error) -> Error {
    match e {
        Error::InvalidInput(m) => Error::InvalidInput(m.clone()),
        Error::InvalidParam(m) => Error::InvalidParam(m.clone()),
        Error::NoConvergence(m) => Error::NoConvergence(m.clone()),
        Error::Linalg(m) => Error::Linalg(m.clone()),
        Error::Runtime(m) => Error::Runtime(m.clone()),
        Error::Coordinator(m) => Error::Coordinator(m.clone()),
        Error::Saturated(m) => Error::Saturated(m.clone()),
        Error::Shutdown(m) => Error::Shutdown(m.clone()),
        Error::Config(m) => Error::Config(m.clone()),
        Error::Io(io) => Error::Io(std::io::Error::new(io.kind(), io.to_string())),
    }
}

/// Flatten per-group sweep results into the response's group-major item
/// order, replicating a failed group's error across its `k` λ slots so a
/// B-group × K-λ request always yields B·K items.
fn flatten_sweep(per_group: Vec<Result<Vec<Item>>>, k: usize) -> Vec<Result<Item>> {
    let mut items = Vec::with_capacity(per_group.len() * k);
    for group in per_group {
        match group {
            Ok(v) => items.extend(v.into_iter().map(Ok)),
            Err(e) => items.extend((0..k).map(|_| Err(replicate_err(&e)))),
        }
    }
    items
}

// ---------------------------------------------------------------------
// Cores — everything below is what the legacy entry points shim over.
// ---------------------------------------------------------------------

/// Compact finalize: clamp in level space, build the codebook through the
/// unique decomposition's inverse map, and accumulate the l2 loss over the
/// full vector in input order — the exact arithmetic sequence of the
/// historical full-vector finalize, so losses are bitwise-identical, but
/// without ever materializing the full-length output vector
/// (O(n + m log m) instead of a second full-vector pass + sort).
/// [`PreparedInput::finish`] is a thin wrapper over this; the independent
/// bitwise anchor is `types::finalize` (still used by the runtime lane),
/// which the regression tests compare against.
pub(crate) fn finish_compact<T: Scalar>(
    prep: &PreparedInput<T>,
    level_values: &[T],
    clamp: Option<(f64, f64)>,
    diag: QuantDiag,
) -> Result<QuantItem<T>> {
    finish_compact_parts(prep.original(), prep.unique(), level_values, clamp, diag)
}

/// [`finish_compact`] over raw parts — the original vector and its unique
/// decomposition — for callers that never build a full [`PreparedInput`]
/// (the coordinator's runtime lane holds only the decomposition: the
/// difference basis and cached sums are solver-side state it doesn't
/// need). Same arithmetic, same bitwise guarantees.
pub(crate) fn finish_compact_parts<T: Scalar>(
    original: &[T],
    unique: &UniqueDecomp<T>,
    level_values: &[T],
    clamp: Option<(f64, f64)>,
    diag: QuantDiag,
) -> Result<QuantItem<T>> {
    let m = unique.m();
    if level_values.len() != m {
        return Err(Error::InvalidInput(format!(
            "finish: expected {m} level values, got {}",
            level_values.len()
        )));
    }
    // Clamp in level space — mirrors hard_sigmoid semantics (only strictly
    // out-of-range values move, counted once per original occurrence).
    let mut lv = level_values.to_vec();
    let mut clamped = 0usize;
    if let Some((lo, hi)) = clamp {
        let (lo, hi) = (T::from_f64(lo), T::from_f64(hi));
        for (v, &c) in lv.iter_mut().zip(&unique.counts) {
            if *v < lo {
                *v = lo;
                clamped += c;
            } else if *v > hi {
                *v = hi;
                clamped += c;
            }
        }
    }
    // A NaN level would panic the `partial_cmp().unwrap()` sort/search
    // below (the same class of bug `Codebook::from_values` guards
    // against); surface it as an error instead — the clamp above never
    // moves a NaN (both range comparisons are false), so scan after it.
    if lv.iter().any(|v| v.partial_cmp(v).is_none()) {
        return Err(Error::InvalidInput("finish: NaN level value".into()));
    }
    // Sorted distinct levels — the same construction the legacy finalize
    // uses, so the level table is identical.
    let mut levels = lv.clone();
    levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    levels.dedup();
    if levels.len() > u32::MAX as usize {
        return Err(Error::InvalidInput("codebook: too many levels".into()));
    }
    // Each unique value's level slot, then one u32 per element through the
    // inverse map.
    let level_of: Vec<u32> = lv
        .iter()
        .map(|v| {
            levels
                .binary_search_by(|l| l.partial_cmp(v).unwrap())
                .expect("every per-level value is in the level table") as u32
        })
        .collect();
    let indices = kernels::gather_indices(&level_of, &unique.inverse);
    // l2 loss over the full vector in input order: identical operation
    // sequence to the full-vector path (recover() replicates lv[inverse]);
    // the kernel accumulates strictly on both lanes for exactly this
    // reason.
    let l2_loss = kernels::gather_sq_loss(original, &unique.inverse, &lv);
    Ok(QuantItem {
        codebook: Codebook { levels, indices },
        l2_loss,
        clamped,
        diag,
        timings: StageTimings { prepare: Duration::ZERO, solve: Duration::ZERO },
        values: None,
    })
}

/// Post-solve hook for `QuantOptions::entropy_budget`: greedily merge
/// levels until the index entropy fits the budget
/// ([`merge::merge_to_entropy_budget`]). Distortion costs use the
/// prepared input's level weights — folded importance when the request
/// is weighted, multiplicities otherwise — so the merge trades off the
/// same weighted objective the solve minimized. No budget, or a result
/// already inside it, returns the levels bitwise-untouched.
fn apply_entropy_budget<T: LaneSolve>(
    prep: &PreparedInput<T>,
    lv: Vec<T>,
    opts: &QuantOptions,
) -> Vec<T> {
    match opts.entropy_budget {
        None => lv,
        Some(budget) => merge::merge_to_entropy_budget(
            &prep.unique().values,
            &lv,
            prep.level_weights(),
            &prep.unique().counts,
            budget,
        ),
    }
}

/// Solve one prepared input on its lane and finalize compactly.
pub(crate) fn run_prepared_core<T: LaneSolve>(
    prep: &PreparedInput<T>,
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
    prepare: Duration,
) -> Result<QuantItem<T>> {
    let t = Instant::now();
    let (lv, diag) = T::lane_solve(solver_for(method), prep, opts)?;
    let lv = apply_entropy_budget(prep, lv, opts);
    let mut item = finish_compact(prep, &lv, opts.clamp, diag)?;
    if form == OutputForm::Values {
        item.values = Some(item.codebook.decode());
    }
    item.timings = StageTimings { prepare, solve: t.elapsed() };
    Ok(item)
}

/// λ path over one prepared input, warm-starting capable solvers between
/// grid points. The prepare time is attributed to the first item.
pub(crate) fn sweep_prepared_core<T: LaneSolve>(
    prep: &PreparedInput<T>,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    warm_start: bool,
    form: OutputForm,
    prepare: Duration,
) -> Result<Vec<QuantItem<T>>> {
    let mut state = SweepState::default();
    let mut items = Vec::with_capacity(lambdas.len());
    sweep_steps(prep, method, lambdas, base, warm_start, form, prepare, &mut state, &mut items)?;
    Ok(items)
}

/// The λ-step loop over an explicit `(state, items)` pair, so callers can
/// *resume* a previously solved chain ([`SweepState::resume`] — the
/// memoizing facade's λ-grid extension) as well as start one cold. The
/// chain state entering each step depends only on the preceding grid
/// points, so a resumed extension is bitwise-identical to re-running the
/// full grid warm. `prepare` is attributed to the first item pushed when
/// `items` starts empty (i.e. only on a fresh chain).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_steps<T: LaneSolve>(
    prep: &PreparedInput<T>,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    warm_start: bool,
    form: OutputForm,
    prepare: Duration,
    state: &mut SweepState,
    items: &mut Vec<QuantItem<T>>,
) -> Result<()> {
    let solver = solver_for(method);
    for &lambda in lambdas {
        let opts = QuantOptions { lambda1: lambda, ..base.clone() };
        let t = Instant::now();
        let (lv, diag) = if warm_start {
            T::lane_solve_path_step(solver, prep, &opts, state)?
        } else {
            T::lane_solve(solver, prep, &opts)?
        };
        // The entropy merge shapes only this grid point's output; the
        // warm-start chain state carries the unmerged coefficients.
        let lv = apply_entropy_budget(prep, lv, &opts);
        let mut item = finish_compact(prep, &lv, opts.clamp, diag)?;
        if form == OutputForm::Values {
            item.values = Some(item.codebook.decode());
        }
        item.timings = StageTimings {
            prepare: if items.is_empty() { prepare } else { Duration::ZERO },
            solve: t.elapsed(),
        };
        items.push(item);
    }
    Ok(())
}

/// Single-vector core on the f64 surface: honors `opts.precision` (the
/// `F32` lane narrows once here, runs natively, and stays narrow in the
/// response). Shared storage in, so callers that own or share their
/// buffer never copy it.
pub(crate) fn run_shared_f64(
    w: Arc<[f64]>,
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
) -> Result<Item> {
    run_shared_f64_weighted(w, None, method, opts, form)
}

/// [`run_shared_f64`] with optional per-element importance weights
/// folded into the prepared input. `None` runs exactly the unweighted
/// code path (same operations, same bits) — the weighted facade only
/// dispatches here with `Some` for non-uniform weights.
pub(crate) fn run_shared_f64_weighted(
    w: Arc<[f64]>,
    user_weights: Option<&[f64]>,
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
) -> Result<Item> {
    match opts.precision {
        Precision::F64 => {
            let t0 = Instant::now();
            let mut prep = PreparedInput::from_shared(w)?;
            if let Some(u) = user_weights {
                prep = prep.with_user_weights(u)?;
            }
            let prepare = t0.elapsed();
            run_prepared_core(&prep, method, opts, form, prepare).map(Item::F64)
        }
        Precision::F32 => {
            // The one-time lane narrowing is part of the prepare stage.
            let t0 = Instant::now();
            let narrow: Vec<f32> = w.iter().map(|&x| x as f32).collect();
            let mut prep = PreparedInput::from_vec(narrow)?;
            if let Some(u) = user_weights {
                prep = prep.with_user_weights(u)?;
            }
            let prepare = t0.elapsed();
            run_prepared_core(&prep, method, opts, form, prepare).map(Item::F32)
        }
    }
}

/// Single-vector core for f32 payloads: always the native f32 lane
/// (narrowing never happens — the data is already single precision), as
/// the legacy `quantize_f32` did.
pub(crate) fn run_shared_f32(
    w: Arc<[f32]>,
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
) -> Result<QuantItem<f32>> {
    run_shared_f32_weighted(w, None, method, opts, form)
}

/// [`run_shared_f32`] with optional importance weights; `None` is
/// exactly the unweighted path.
pub(crate) fn run_shared_f32_weighted(
    w: Arc<[f32]>,
    user_weights: Option<&[f64]>,
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
) -> Result<QuantItem<f32>> {
    let t0 = Instant::now();
    let mut prep = PreparedInput::from_shared(w)?;
    if let Some(u) = user_weights {
        prep = prep.with_user_weights(u)?;
    }
    let prepare = t0.elapsed();
    run_prepared_core(&prep, method, opts, form, prepare)
}

/// λ sweep on the f64 surface, honoring `opts.precision` like
/// [`run_shared_f64`].
fn sweep_shared_f64(
    w: Arc<[f64]>,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    warm_start: bool,
    form: OutputForm,
) -> Result<Vec<Item>> {
    sweep_shared_f64_weighted(w, None, method, lambdas, base, warm_start, form)
}

/// [`sweep_shared_f64`] with optional importance weights attached to the
/// prepared input before the λ path runs; `None` is exactly the
/// unweighted path.
#[allow(clippy::too_many_arguments)]
fn sweep_shared_f64_weighted(
    w: Arc<[f64]>,
    user_weights: Option<&[f64]>,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    warm_start: bool,
    form: OutputForm,
) -> Result<Vec<Item>> {
    match base.precision {
        Precision::F64 => {
            let t0 = Instant::now();
            let mut prep = PreparedInput::from_shared(w)?;
            if let Some(u) = user_weights {
                prep = prep.with_user_weights(u)?;
            }
            let prepare = t0.elapsed();
            Ok(sweep_prepared_core(&prep, method, lambdas, base, warm_start, form, prepare)?
                .into_iter()
                .map(Item::F64)
                .collect())
        }
        Precision::F32 => {
            let t0 = Instant::now();
            let narrow: Vec<f32> = w.iter().map(|&x| x as f32).collect();
            let mut prep = PreparedInput::from_vec(narrow)?;
            if let Some(u) = user_weights {
                prep = prep.with_user_weights(u)?;
            }
            let prepare = t0.elapsed();
            Ok(sweep_prepared_core(&prep, method, lambdas, base, warm_start, form, prepare)?
                .into_iter()
                .map(Item::F32)
                .collect())
        }
    }
}

/// Shape-check a cascade's bit list (shared by every input arm).
fn validate_cascade_bits(bits: &[u32]) -> Result<()> {
    if bits.is_empty() {
        return Err(Error::InvalidParam("cascade: bit list must be non-empty".into()));
    }
    if let Some(&b) = bits.iter().find(|&&b| !(1..=16).contains(&b)) {
        return Err(Error::InvalidParam(format!("cascade: bits must be in 1..=16, got {b}")));
    }
    Ok(())
}

/// Residual cascade over one f64-surface vector ([`Plan::Cascade`]):
/// level `l` quantizes the running residual at `2^bits[l]` target levels
/// through [`run_shared_f64`] (so `opts.precision` picks the lane per
/// level exactly as a one-shot would), subtracts the decoded level, and
/// stops once `‖r‖₂ ≤ norm_tol · ‖w‖₂`. Items come back in cascade order;
/// `quant::qmatrix` packs them into compute-ready planes.
pub(crate) fn cascade_shared_f64(
    w: Arc<[f64]>,
    method: QuantMethod,
    bits: &[u32],
    norm_tol: f64,
    base: &QuantOptions,
    form: OutputForm,
) -> Result<Vec<Item>> {
    validate_cascade_bits(bits)?;
    if !(norm_tol >= 0.0) {
        return Err(Error::InvalidParam(format!(
            "cascade: norm_tol must be a non-negative number, got {norm_tol}"
        )));
    }
    let base_norm = kernels::nrm2(&w[..]);
    let mut residual: Vec<f64> = w.to_vec();
    let mut items = Vec::with_capacity(bits.len());
    for (l, &b) in bits.iter().enumerate() {
        let opts = QuantOptions { target_values: 1usize << b, ..base.clone() };
        // Level 0 reuses the caller's shared buffer; later levels copy the
        // running residual once into shared storage.
        let src: Arc<[f64]> =
            if l == 0 { Arc::clone(&w) } else { Arc::from(residual.as_slice()) };
        let item = run_shared_f64(src, method, &opts, form)?;
        let decoded = item.materialize_f64();
        for (r, d) in residual.iter_mut().zip(&decoded) {
            *r -= d;
        }
        items.push(item);
        if base_norm == 0.0 || kernels::nrm2(&residual) <= norm_tol * base_norm {
            break;
        }
    }
    Ok(items)
}

/// [`cascade_shared_f64`] for native f32 payloads: the residual arithmetic
/// stays single-precision end to end, like `quantize_f32` itself.
pub(crate) fn cascade_shared_f32(
    w: Arc<[f32]>,
    method: QuantMethod,
    bits: &[u32],
    norm_tol: f64,
    base: &QuantOptions,
    form: OutputForm,
) -> Result<Vec<Item>> {
    validate_cascade_bits(bits)?;
    if !(norm_tol >= 0.0) {
        return Err(Error::InvalidParam(format!(
            "cascade: norm_tol must be a non-negative number, got {norm_tol}"
        )));
    }
    let base_norm = f64::from(kernels::nrm2(&w[..]));
    let mut residual: Vec<f32> = w.to_vec();
    let mut items = Vec::with_capacity(bits.len());
    for (l, &b) in bits.iter().enumerate() {
        let opts = QuantOptions { target_values: 1usize << b, ..base.clone() };
        let src: Arc<[f32]> =
            if l == 0 { Arc::clone(&w) } else { Arc::from(residual.as_slice()) };
        let item = run_shared_f32(src, method, &opts, form)?;
        let decoded = item.materialize();
        for (r, d) in residual.iter_mut().zip(&decoded) {
            *r -= d;
        }
        items.push(Item::F32(item));
        if base_norm == 0.0 || f64::from(kernels::nrm2(&residual)) <= norm_tol * base_norm {
            break;
        }
    }
    Ok(items)
}

/// Flatten per-group cascade results into group-major item order. Unlike
/// [`flatten_sweep`] the per-group item count is not fixed (groups stop at
/// their own tolerance), so a failed group contributes exactly one error
/// item rather than a replicated block.
fn flatten_cascade(per_group: Vec<Result<Vec<Item>>>) -> Vec<Result<Item>> {
    let mut items = Vec::new();
    for group in per_group {
        match group {
            Ok(v) => items.extend(v.into_iter().map(Ok)),
            Err(e) => items.push(Err(e)),
        }
    }
    items
}

/// Batch core on the f64 surface: independent inputs fanned across the
/// scoped-thread batch executor, failures isolated per slot.
pub(crate) fn batch_core_f64(
    inputs: &[Vec<f64>],
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
) -> Vec<Result<Item>> {
    batch_map(inputs, |w| run_shared_f64(Arc::from(w.as_slice()), method, opts, form))
}

/// Batch core for f32 payloads (native f32 lane per slot).
pub(crate) fn batch_core_f32(
    inputs: &[Vec<f32>],
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
) -> Vec<Result<Item>> {
    batch_map(inputs, |w| {
        run_shared_f32(Arc::from(w.as_slice()), method, opts, form).map(Item::F32)
    })
}

/// Batch core over already-shared groups: each slot clones an `Arc`, so
/// callers that build their groups directly into shared storage (the
/// matrix fan-out) pay exactly one copy per group end to end.
pub(crate) fn batch_core_shared_f64(
    inputs: &[Arc<[f64]>],
    method: QuantMethod,
    opts: &QuantOptions,
    form: OutputForm,
) -> Vec<Result<Item>> {
    batch_map(inputs, |w| run_shared_f64(Arc::clone(w), method, opts, form))
}

/// Split a matrix into its quantization groups (the batch the fan-out
/// runs over), each copied **once** into shared storage. Group order is
/// the response item order: row index for per-row, column index for
/// per-column.
pub(crate) fn matrix_groups(m: &Matrix, grouping: Grouping) -> Result<Vec<Arc<[f64]>>> {
    if m.rows() == 0 || m.cols() == 0 {
        return Err(Error::InvalidInput("quantize_matrix: empty matrix".into()));
    }
    Ok(match grouping {
        Grouping::PerTensor => vec![Arc::from(m.data())],
        Grouping::PerRow => (0..m.rows()).map(|i| Arc::from(m.row(i))).collect(),
        Grouping::PerColumn => (0..m.cols()).map(|j| Arc::from(m.col(j))).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;

    fn clustered(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            let center = [0.1, 0.35, 0.6, 0.9][i % 4];
            v.push(((center + rng.normal_with(0.0, 0.02)) * 200.0).round() / 200.0);
        }
        v
    }

    #[test]
    fn builder_defaults_and_setters() {
        let req = QuantRequest::vector(vec![1.0, 2.0]);
        assert_eq!(req.method, QuantMethod::L1LeastSquare);
        assert_eq!(req.output, OutputForm::Codebook);
        assert_eq!(*req.plan(), Plan::OneShot);
        let req = req
            .method(QuantMethod::KMeans)
            .target_count(3)
            .precision(Precision::F32)
            .with_values();
        assert_eq!(req.method, QuantMethod::KMeans);
        assert_eq!(*req.plan(), Plan::TargetCount(3));
        assert_eq!(req.effective_options().target_values, 3);
        assert_eq!(req.effective_options().precision, Precision::F32);
        assert_eq!(req.output, OutputForm::Values);
    }

    #[test]
    fn codebook_form_does_not_materialize_values() {
        let data = clustered(60, 1);
        let req = QuantRequest::vector(data.clone())
            .method(QuantMethod::KMeans)
            .target_count(4);
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        let q = item.as_f64().expect("f64 lane");
        assert!(q.values().is_none(), "codebook form must stay compact");
        assert_eq!(q.codebook.indices.len(), data.len());
        assert!(q.distinct_values() <= 4);
        // Lazy materialization reproduces the full vector.
        assert_eq!(q.materialize().len(), data.len());
    }

    #[test]
    fn values_form_materializes_eagerly() {
        let data = clustered(40, 2);
        let req = QuantRequest::vector(data.clone())
            .method(QuantMethod::KMeans)
            .target_count(4)
            .with_values();
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        let q = item.as_f64().unwrap();
        let vals = q.values().expect("values form is eager");
        assert_eq!(vals.len(), data.len());
        assert_eq!(vals, q.materialize().as_slice());
    }

    #[test]
    fn run_matches_legacy_quantize() {
        let data = clustered(80, 3);
        for method in [QuantMethod::KMeans, QuantMethod::L1LeastSquare, QuantMethod::ClusterLs] {
            let opts = QuantOptions { lambda1: 0.02, target_values: 4, ..Default::default() };
            let req = QuantRequest::slice(&data).method(method).options(opts.clone());
            let got =
                Quantizer::new().run(&req).unwrap().into_single().unwrap().into_output64();
            let want = super::super::quantize(&data, method, &opts).unwrap();
            assert_eq!(got.values, want.values, "{method:?}");
            assert_eq!(got.levels, want.levels, "{method:?}");
            assert_eq!(got.l2_loss.to_bits(), want.l2_loss.to_bits(), "{method:?}");
        }
    }

    #[test]
    fn f32_input_stays_narrow() {
        let data32: Vec<f32> = clustered(50, 4).iter().map(|&x| x as f32).collect();
        let req = QuantRequest::vector_f32(data32.clone()).lambda1(0.02);
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        assert_eq!(item.precision(), Precision::F32);
        let q = item.as_f32().expect("f32 lane");
        assert_eq!(q.codebook.indices.len(), data32.len());
    }

    #[test]
    fn f64_input_with_f32_precision_runs_the_narrow_lane() {
        let data = clustered(50, 5);
        let req = QuantRequest::vector(data).lambda1(0.02).precision(Precision::F32);
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        assert_eq!(item.precision(), Precision::F32, "never widened early");
    }

    #[test]
    fn batch_isolates_per_slot_failures() {
        let req = QuantRequest::batch(vec![clustered(30, 6), vec![], clustered(30, 7)])
            .method(QuantMethod::KMeans)
            .target_count(3);
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), 3);
        assert!(resp.items[0].is_ok());
        assert!(resp.items[1].is_err(), "empty vector fails its own slot only");
        assert!(resp.items[2].is_ok());
    }

    #[test]
    fn sweep_plan_yields_one_item_per_lambda() {
        let data = clustered(60, 8);
        let lambdas = vec![1e-4, 1e-3, 1e-2, 1e-1];
        let req = QuantRequest::vector(data)
            .method(QuantMethod::L1)
            .sweep(lambdas.clone());
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), lambdas.len());
        for (r, &l) in resp.items.iter().zip(&lambdas) {
            let item = r.as_ref().unwrap();
            assert_eq!(item.diag().lambda1, l);
        }
        // Only the first grid point pays the prepare stage.
        assert_eq!(resp.items[1].as_ref().unwrap().timings().prepare, Duration::ZERO);
    }

    #[test]
    fn batch_sweep_yields_group_major_bxk_items_matching_per_vector_sweeps() {
        let vectors = vec![clustered(50, 40), clustered(60, 41), clustered(40, 42)];
        let lambdas = vec![1e-3, 1e-2, 1e-1];
        let req = QuantRequest::batch(vectors.clone())
            .method(QuantMethod::L1LeastSquare)
            .sweep(lambdas.clone());
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), vectors.len() * lambdas.len(), "B×K items");
        for (b, w) in vectors.iter().enumerate() {
            // Reference: the same vector through a single-vector sweep
            // request (its own warm-start chain).
            let single = QuantRequest::vector(w.clone())
                .method(QuantMethod::L1LeastSquare)
                .sweep(lambdas.clone());
            let want = Quantizer::new().run(&single).unwrap();
            for (k, want_item) in want.items.iter().enumerate() {
                let got = resp.items[b * lambdas.len() + k].as_ref().unwrap();
                let want_item = want_item.as_ref().unwrap();
                let (g, w_) = (got.as_f64().unwrap(), want_item.as_f64().unwrap());
                assert_eq!(g.codebook.levels, w_.codebook.levels, "vec {b} λ#{k}");
                assert_eq!(g.codebook.indices, w_.codebook.indices, "vec {b} λ#{k}");
                assert_eq!(g.l2_loss.to_bits(), w_.l2_loss.to_bits(), "vec {b} λ#{k}");
                assert_eq!(got.diag().lambda1, lambdas[k], "vec {b} λ#{k}");
            }
        }
    }

    #[test]
    fn batch_sweep_replicates_a_failed_groups_errors() {
        let lambdas = vec![1e-3, 1e-2];
        let req = QuantRequest::batch(vec![clustered(30, 43), vec![], clustered(30, 44)])
            .method(QuantMethod::L1)
            .sweep(lambdas.clone());
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), 3 * lambdas.len(), "shape preserved despite the failure");
        for k in 0..lambdas.len() {
            assert!(resp.items[k].is_ok(), "vec 0 λ#{k}");
            assert!(resp.items[lambdas.len() + k].is_err(), "empty vec λ#{k}");
            assert!(resp.items[2 * lambdas.len() + k].is_ok(), "vec 2 λ#{k}");
        }
    }

    #[test]
    fn matrix_sweep_fans_groups_over_the_lambda_grid() {
        let m = Matrix::from_fn(4, 16, |i, j| ((i * 16 + j) % 9) as f64 / 9.0);
        let lambdas = vec![1e-3, 1e-2];
        let req = QuantRequest::matrix(m, Grouping::PerRow)
            .method(QuantMethod::L1LeastSquare)
            .sweep(lambdas.clone());
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), 4 * lambdas.len());
        for (i, r) in resp.items.iter().enumerate() {
            let item = r.as_ref().unwrap();
            assert_eq!(item.diag().lambda1, lambdas[i % lambdas.len()]);
        }
    }

    #[test]
    fn f32_batch_sweep_stays_narrow_and_matches_single_vector_sweeps() {
        let vecs32: Vec<Vec<f32>> = (0..2)
            .map(|s| clustered(40, 45 + s).iter().map(|&x| x as f32).collect())
            .collect();
        let lambdas = vec![1e-3, 1e-2];
        let req = QuantRequest::batch_f32(vecs32.clone())
            .method(QuantMethod::L1LeastSquare)
            .sweep(lambdas.clone());
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), vecs32.len() * lambdas.len());
        for (b, w) in vecs32.iter().enumerate() {
            let single = QuantRequest::vector_f32(w.clone())
                .method(QuantMethod::L1LeastSquare)
                .sweep(lambdas.clone());
            let want = Quantizer::new().run(&single).unwrap();
            for (k, want_item) in want.items.iter().enumerate() {
                let got = resp.items[b * lambdas.len() + k].as_ref().unwrap();
                assert_eq!(got.precision(), Precision::F32, "never widened");
                let (g, w_) = (
                    got.as_f32().unwrap(),
                    want_item.as_ref().unwrap().as_f32().unwrap(),
                );
                assert_eq!(g.codebook.levels, w_.codebook.levels, "vec {b} λ#{k}");
                assert_eq!(g.l2_loss.to_bits(), w_.l2_loss.to_bits(), "vec {b} λ#{k}");
            }
        }
    }

    #[test]
    fn response_compression_aggregates_over_items() {
        let req = QuantRequest::batch(vec![clustered(200, 46), clustered(100, 47)])
            .method(QuantMethod::KMeans)
            .target_count(4);
        let resp = Quantizer::new().run(&req).unwrap();
        let agg = resp.compression(4).expect("successful items");
        assert_eq!(agg.n, 300);
        assert_eq!(agg.levels_requested, 4);
        assert!(agg.levels_achieved <= 4);
        assert!(agg.bits_per_value < 64.0);
        assert!(agg.byte_ratio > 1.0);
        // Per-item stats agree with a direct codebook computation.
        let item = resp.items[0].as_ref().unwrap();
        let direct = item.as_f64().unwrap().codebook.stats(4);
        assert_eq!(item.compression(4), direct);
    }

    #[test]
    fn matrix_request_yields_one_item_per_group() {
        let m = Matrix::from_fn(6, 10, |i, j| ((i * 10 + j) % 7) as f64);
        let req = QuantRequest::matrix(m, Grouping::PerRow)
            .method(QuantMethod::KMeans)
            .target_count(3);
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), 6);
        for r in &resp.items {
            assert!(r.as_ref().unwrap().distinct_values() <= 3);
        }
        let empty = QuantRequest::matrix(Matrix::zeros(0, 0), Grouping::PerTensor);
        assert!(Quantizer::new().run(&empty).is_err());
    }

    #[test]
    fn finish_compact_matches_historical_full_vector_finalize() {
        // The compact finalize must agree with the independent historical
        // full-vector path (recover + types::finalize, still used by the
        // runtime lane) on values, levels, loss bits and clamp counts.
        // `PreparedInput::finish` is compact-backed now, so this is the
        // non-tautological anchor.
        let data = clustered(70, 10);
        let prep = PreparedInput::new(&data).unwrap();
        let m = prep.m();
        let lv: Vec<f64> = (0..m).map(|j| ((j * 13 % 7) as f64) * 0.3 - 0.4).collect();
        for clamp in [None, Some((0.0, 1.0))] {
            let compact = finish_compact(&prep, &lv, clamp, QuantDiag::default()).unwrap();
            let full = prep.unique().recover(&lv).unwrap();
            let want = crate::quant::types::finalize(&data, full, clamp, QuantDiag::default());
            assert_eq!(compact.codebook.decode(), want.values);
            assert_eq!(compact.codebook.levels, want.levels);
            assert_eq!(compact.l2_loss.to_bits(), want.l2_loss.to_bits());
            assert_eq!(compact.clamped, want.clamped);
        }
        // Wrong level count errors instead of panicking.
        assert!(finish_compact(&prep, &lv[..m - 1], None, QuantDiag::default()).is_err());
    }

    #[test]
    fn response_aggregates_timings_and_loss() {
        let req = QuantRequest::batch(vec![clustered(40, 11), clustered(40, 12)])
            .method(QuantMethod::KMeans)
            .target_count(4);
        let resp = Quantizer::new().run(&req).unwrap();
        let total: f64 = resp
            .items
            .iter()
            .flatten()
            .map(Item::l2_loss)
            .sum();
        assert_eq!(resp.total_l2_loss().to_bits(), total.to_bits());
        assert!(resp.timings().solve >= Duration::ZERO);
        assert!(!resp.is_empty());
    }

    #[test]
    fn cascade_plan_runs_levels_over_the_residual() {
        let data = clustered(120, 21);
        let req = QuantRequest::vector(data.clone())
            .method(QuantMethod::KMeans)
            .residual_levels(vec![2, 2, 2], 0.0);
        let resp = Quantizer::new().run(&req).unwrap();
        assert!(!resp.is_empty() && resp.len() <= 3);
        // Reconstructions stack: summing the decoded levels must shrink the
        // residual monotonically (each level fits the previous residual).
        let mut recon = vec![0.0f64; data.len()];
        let mut prev = f64::INFINITY;
        for item in resp.items.iter().map(|r| r.as_ref().unwrap()) {
            for (acc, d) in recon.iter_mut().zip(item.materialize_f64()) {
                *acc += d;
            }
            let err: f64 =
                data.iter().zip(&recon).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
            assert!(err <= prev + 1e-12, "residual grew: {err} > {prev}");
            prev = err;
        }
        // The stacked accounting adds index bits across levels.
        let stacked = resp.compression_cascade().unwrap();
        let per_level: Vec<CompressionStats> = resp
            .items
            .iter()
            .flatten()
            .map(|i| i.compression(i.distinct_values()))
            .collect();
        assert_eq!(
            stacked.bits_per_idx_packed,
            per_level.iter().map(|s| s.bits_per_idx_packed).sum::<u32>()
        );
        assert_eq!(stacked.n, data.len());
    }

    #[test]
    fn cascade_norm_tol_stops_early() {
        // 4 distinct values: a 2-bit (4-level) k-means level is exact, so
        // any positive tolerance must stop the cascade after one level.
        let data: Vec<f64> = (0..100).map(|i| (i % 4) as f64).collect();
        let req = QuantRequest::vector(data)
            .method(QuantMethod::KMeans)
            .residual_levels(vec![2, 2, 2], 1e-9);
        let resp = Quantizer::new().run(&req).unwrap();
        assert_eq!(resp.len(), 1);
    }

    #[test]
    fn cascade_rejects_bad_bit_lists() {
        let mk = |bits: Vec<u32>| {
            QuantRequest::vector(clustered(30, 5))
                .method(QuantMethod::KMeans)
                .residual_levels(bits, 0.0)
        };
        assert!(Quantizer::new().run(&mk(vec![])).is_err());
        assert!(Quantizer::new().run(&mk(vec![0])).is_err());
        assert!(Quantizer::new().run(&mk(vec![17])).is_err());
        let bad_tol = QuantRequest::vector(clustered(30, 5))
            .method(QuantMethod::KMeans)
            .residual_levels(vec![2], f64::NAN);
        assert!(Quantizer::new().run(&bad_tol).is_err());
    }

    #[test]
    fn cascade_composes_with_matrix_groups() {
        let m = Matrix::from_fn(8, 5, |i, j| ((i * 5 + j) % 6) as f64 * 0.2);
        let req = QuantRequest::matrix(m, Grouping::PerColumn)
            .method(QuantMethod::KMeans)
            .residual_levels(vec![1, 1], 0.0);
        let resp = Quantizer::new().run(&req).unwrap();
        // 5 groups × up to 2 levels, group-major; every item covers one
        // column's 8 elements.
        assert!(resp.len() >= 5 && resp.len() <= 10);
        for item in resp.items.iter().flatten() {
            assert_eq!(item.codebook_f64().len(), 8);
        }
    }

    #[test]
    fn cascade_f32_lane_stays_narrow() {
        let data: Vec<f32> = clustered(80, 31).iter().map(|&x| x as f32).collect();
        let req = QuantRequest::vector_f32(data)
            .method(QuantMethod::KMeans)
            .residual_levels(vec![2, 2], 0.0);
        let resp = Quantizer::new().run(&req).unwrap();
        for item in resp.items.iter().flatten() {
            assert_eq!(item.precision(), Precision::F32);
        }
    }

    #[test]
    fn finish_compact_nan_level_is_an_error_not_a_panic_both_lanes() {
        // Regression: a NaN level value used to panic the
        // `partial_cmp().unwrap()` sort inside the compact finalize.
        let data = clustered(50, 60);
        let prep = PreparedInput::new(&data).unwrap();
        let mut lv = vec![0.5f64; prep.m()];
        lv[prep.m() / 2] = f64::NAN;
        match finish_compact(&prep, &lv, None, QuantDiag::default()) {
            Err(Error::InvalidInput(_)) => {}
            other => panic!("expected InvalidInput for NaN level, got {other:?}"),
        }
        // Clamping must not mask the NaN (comparisons against it are false).
        assert!(finish_compact(&prep, &lv, Some((0.0, 1.0)), QuantDiag::default()).is_err());

        let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
        let prep32 = PreparedInput::new(&data32).unwrap();
        let mut lv32 = vec![0.5f32; prep32.m()];
        lv32[0] = f32::NAN;
        match finish_compact(&prep32, &lv32, None, QuantDiag::default()) {
            Err(Error::InvalidInput(_)) => {}
            other => panic!("expected InvalidInput for f32 NaN level, got {other:?}"),
        }
    }

    #[test]
    fn fingerprint_changes_with_every_key_component() {
        let w = clustered(20, 61);
        let opts = QuantOptions::default();
        let base = Fingerprint::vector_f64(&w, QuantMethod::L1LeastSquare, &opts);
        // Deterministic: same bytes, same key.
        assert_eq!(base, Fingerprint::vector_f64(&w, QuantMethod::L1LeastSquare, &opts));
        let mut seen = vec![base];
        let mut check = |fp: Fingerprint| {
            assert!(!seen.contains(&fp), "distinct keys collided");
            seen.push(fp);
        };
        // Payload bits, method, and lane each perturb the key.
        let mut w2 = w.clone();
        w2[0] = -w2[0];
        check(Fingerprint::vector_f64(&w2, QuantMethod::L1LeastSquare, &opts));
        check(Fingerprint::vector_f64(&w, QuantMethod::KMeans, &opts));
        let w32: Vec<f32> = w.iter().map(|&x| x as f32).collect();
        check(Fingerprint::vector_f32(&w32, QuantMethod::L1LeastSquare, &opts));
        // Every option field perturbs both the key and the bit-exact
        // comparison the cache verifies hits with.
        for o in [
            QuantOptions { lambda1: 0.5, ..opts.clone() },
            QuantOptions { lambda2: 0.5, ..opts.clone() },
            QuantOptions { target_values: 7, ..opts.clone() },
            QuantOptions { max_epochs: 7, ..opts.clone() },
            QuantOptions { tol: 0.5, ..opts.clone() },
            QuantOptions { kmeans_restarts: 3, ..opts.clone() },
            QuantOptions { max_iters: 7, ..opts.clone() },
            QuantOptions { seed: 9, ..opts.clone() },
            QuantOptions { refit: false, ..opts.clone() },
            QuantOptions { max_lambda_steps: 7, ..opts.clone() },
            QuantOptions { clamp: Some((0.0, 1.0)), ..opts.clone() },
            QuantOptions { precision: Precision::F32, ..opts.clone() },
            QuantOptions { entropy_budget: Some(2.0), ..opts.clone() },
        ] {
            check(Fingerprint::vector_f64(&w, QuantMethod::L1LeastSquare, &o));
            assert!(!opts_bits_eq(&o, &opts));
        }
        assert!(opts_bits_eq(&opts, &opts.clone()));
        // Non-uniform importance weights salt the key; distinct weight
        // vectors are distinct keys.
        let wn: Vec<f64> = (0..w.len()).map(|i| 1.0 + (i % 3) as f64).collect();
        let mut wn2 = wn.clone();
        wn2[0] += 1.0;
        check(Fingerprint::vector_f64_weighted(
            &w,
            Some(wn.as_slice()),
            QuantMethod::L1LeastSquare,
            &opts,
        ));
        check(Fingerprint::vector_f64_weighted(
            &w,
            Some(wn2.as_slice()),
            QuantMethod::L1LeastSquare,
            &opts,
        ));
        // Uniform weights alias the unweighted key — they run (and must
        // cache as) the identical solve.
        let uniform = vec![3.0; w.len()];
        assert_eq!(
            Fingerprint::vector_f64_weighted(
                &w,
                Some(uniform.as_slice()),
                QuantMethod::L1LeastSquare,
                &opts,
            ),
            Fingerprint::vector_f64(&w, QuantMethod::L1LeastSquare, &opts),
        );
        assert_eq!(
            Fingerprint::of_request(&QuantRequest::vector(w.clone()).weights(uniform)),
            Fingerprint::of_request(&QuantRequest::vector(w.clone())),
        );
        check(Fingerprint::of_request(&QuantRequest::vector(w.clone()).weights(wn)));
        // Plans separate through the request key; a target-count request
        // aliases the one-shot that runs the same solve — by design.
        let one = Fingerprint::of_request(&QuantRequest::vector(w.clone()));
        let tc = Fingerprint::of_request(&QuantRequest::vector(w.clone()).target_count(16));
        assert_eq!(one, tc);
        check(one);
        check(Fingerprint::of_request(
            &QuantRequest::vector(w.clone()).sweep(vec![1e-3, 1e-2]),
        ));
        check(Fingerprint::of_request(
            &QuantRequest::vector(w.clone()).sweep(vec![1e-3, 1e-1]),
        ));
        check(Fingerprint::of_request(
            &QuantRequest::vector(w.clone()).residual_levels(vec![2, 2], 0.0),
        ));
    }

    fn assert_f64_bitwise(got: &Item, want: &Item, tag: &str) {
        let (g, w) = (got.as_f64().unwrap(), want.as_f64().unwrap());
        let bits = |q: &QuantItem<f64>| -> Vec<u64> {
            q.codebook.levels.iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(bits(g), bits(w), "{tag}: levels");
        assert_eq!(g.codebook.indices, w.codebook.indices, "{tag}: indices");
        assert_eq!(g.l2_loss.to_bits(), w.l2_loss.to_bits(), "{tag}: loss");
        assert_eq!(g.clamped, w.clamped, "{tag}: clamp count");
    }

    #[test]
    fn caching_facade_one_shot_hits_match_stateless_bitwise() {
        let q = Quantizer::caching(8);
        for method in [QuantMethod::L1LeastSquare, QuantMethod::KMeans, QuantMethod::ClusterLs] {
            let data = clustered(60, 62);
            let mk = || {
                QuantRequest::vector(data.clone()).method(method).options(QuantOptions {
                    lambda1: 0.02,
                    target_values: 4,
                    ..Default::default()
                })
            };
            let want = Quantizer::new().run(&mk()).unwrap().into_single().unwrap();
            let cold = q.run(&mk()).unwrap().into_single().unwrap();
            let warm = q.run(&mk()).unwrap().into_single().unwrap();
            assert_f64_bitwise(&cold, &want, "cold");
            assert_f64_bitwise(&warm, &want, "prep-memo hit");
        }
        // f32 payloads ride the narrow-lane memo.
        let data32: Vec<f32> = clustered(50, 63).iter().map(|&x| x as f32).collect();
        let req32 = || QuantRequest::vector_f32(data32.clone()).lambda1(0.02);
        let want32 = Quantizer::new().run(&req32()).unwrap().into_single().unwrap();
        q.run(&req32()).unwrap();
        let warm32 = q.run(&req32()).unwrap().into_single().unwrap();
        let (g, w) = (warm32.as_f32().unwrap(), want32.as_f32().unwrap());
        assert_eq!(
            g.codebook.levels.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            w.codebook.levels.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
        assert_eq!(g.codebook.indices, w.codebook.indices);
        assert_eq!(g.l2_loss.to_bits(), w.l2_loss.to_bits());
    }

    #[test]
    fn caching_facade_sweep_extension_matches_cold_full_grid() {
        let data = clustered(60, 64);
        let grid = [1e-4, 1e-3, 1e-2, 5e-2, 1e-1];
        let q = Quantizer::caching(8);
        let sweep =
            |ls: &[f64]| QuantRequest::vector(data.clone()).method(QuantMethod::L1).sweep(ls.to_vec());
        // Solve a prefix, then extend the grid: only the new points are
        // solved, and the full response must be bitwise what a cold warm
        // sweep of the whole grid produces.
        q.run(&sweep(&grid[..2])).unwrap();
        let extended = q.run(&sweep(&grid)).unwrap();
        let cold = Quantizer::new().run(&sweep(&grid)).unwrap();
        assert_eq!(extended.len(), cold.len());
        for (i, (g, w)) in extended.items.iter().zip(&cold.items).enumerate() {
            assert_f64_bitwise(g.as_ref().unwrap(), w.as_ref().unwrap(), &format!("extend λ#{i}"));
        }
        // A replay covered by the solved chain does zero solves and stays
        // bitwise-identical, including eager-values re-forming.
        let replay = q.run(&sweep(&grid[..3]).with_values()).unwrap();
        let cold_vals = Quantizer::new().run(&sweep(&grid[..3]).with_values()).unwrap();
        for (i, (g, w)) in replay.items.iter().zip(&cold_vals.items).enumerate() {
            let (g, w) = (g.as_ref().unwrap(), w.as_ref().unwrap());
            assert_f64_bitwise(g, w, &format!("replay λ#{i}"));
            assert_eq!(
                g.as_f64().unwrap().values(),
                w.as_f64().unwrap().values(),
                "replay λ#{i}: eager values"
            );
        }
        // A grid with a different head is a miss, never a wrong answer.
        let other = [2e-3, 1e-2];
        let fresh = q.run(&sweep(&other)).unwrap();
        let want = Quantizer::new().run(&sweep(&other)).unwrap();
        for (i, (g, w)) in fresh.items.iter().zip(&want.items).enumerate() {
            assert_f64_bitwise(g.as_ref().unwrap(), w.as_ref().unwrap(), &format!("miss λ#{i}"));
        }
    }

    #[test]
    fn caching_facade_eviction_churn_stays_correct() {
        // Capacity 1: every alternating request evicts the other's entries;
        // correctness must never depend on what the memo still holds.
        let q = Quantizer::caching(1);
        let (a, b) = (clustered(40, 65), clustered(40, 66));
        let mk = |d: &[f64]| QuantRequest::vector(d.to_vec()).lambda1(0.02);
        let want_a = Quantizer::new().run(&mk(&a)).unwrap().into_single().unwrap();
        let want_b = Quantizer::new().run(&mk(&b)).unwrap().into_single().unwrap();
        for round in 0..3 {
            let ga = q.run(&mk(&a)).unwrap().into_single().unwrap();
            let gb = q.run(&mk(&b)).unwrap().into_single().unwrap();
            assert_f64_bitwise(&ga, &want_a, &format!("churn a#{round}"));
            assert_f64_bitwise(&gb, &want_b, &format!("churn b#{round}"));
        }
    }

    /// A deterministic non-uniform weight vector for the weighted tests.
    fn ramp_weights(n: usize) -> Vec<f64> {
        (0..n).map(|i| 0.5 + (i % 5) as f64).collect()
    }

    #[test]
    fn uniform_weights_run_the_unweighted_path_bitwise() {
        let data = clustered(70, 80);
        for method in [QuantMethod::L1LeastSquare, QuantMethod::KMeans, QuantMethod::ClusterLs] {
            let plain = QuantRequest::vector(data.clone()).method(method).target_count(4);
            let weighted = plain.clone().weights(vec![2.5; data.len()]);
            let want = Quantizer::new().run(&plain).unwrap().into_single().unwrap();
            let got = Quantizer::new().run(&weighted).unwrap().into_single().unwrap();
            assert_f64_bitwise(&got, &want, &format!("{method:?} uniform"));
        }
    }

    #[test]
    fn weighted_requests_reject_malformed_weights() {
        let data = clustered(40, 81);
        let q = Quantizer::new();
        let base = || QuantRequest::vector(data.clone());
        let expect_invalid = |req: QuantRequest, tag: &str| match q.run(&req) {
            Err(Error::InvalidInput(_)) => {}
            other => panic!("{tag}: expected InvalidInput, got {other:?}"),
        };
        expect_invalid(base().weights(vec![1.0; data.len() - 1]), "length mismatch");
        let mut w = vec![1.0; data.len()];
        w[3] = f64::NAN;
        expect_invalid(base().weights(w), "NaN weight");
        let mut w = vec![1.0; data.len()];
        w[3] = -0.5;
        expect_invalid(base().weights(w), "negative weight");
        let mut w = vec![1.0; data.len()];
        w[3] = f64::INFINITY;
        expect_invalid(base().weights(w), "infinite weight");
        expect_invalid(base().weights(vec![0.0; data.len()]), "zero-sum weights");
        expect_invalid(base().batch_weights(vec![vec![1.0; data.len()]]), "batch form on vector");
        expect_invalid(
            QuantRequest::batch(vec![data.clone()]).weights(ramp_weights(data.len())),
            "vector form on batch",
        );
        expect_invalid(
            base().weights(ramp_weights(data.len())).residual_levels(vec![2, 2], 0.0),
            "cascade with weights",
        );
        // The entropy budget must be a non-negative finite number.
        for bad in [f64::NAN, -1.0, f64::INFINITY] {
            match q.run(&base().entropy_budget(bad)) {
                Err(Error::InvalidParam(_)) => {}
                other => panic!("budget {bad}: expected InvalidParam, got {other:?}"),
            }
        }
    }

    #[test]
    fn weighted_one_shot_runs_every_shape_and_lane() {
        let data = clustered(60, 82);
        let uw = ramp_weights(data.len());

        // Vector, both lanes.
        for precision in [Precision::F64, Precision::F32] {
            let item = Quantizer::new()
                .run(
                    &QuantRequest::vector(data.clone())
                        .method(QuantMethod::KMeans)
                        .target_count(4)
                        .precision(precision)
                        .weights(uw.clone()),
                )
                .unwrap()
                .into_single()
                .unwrap();
            assert_eq!(item.precision(), precision);
            assert!(item.distinct_values() <= 4);
        }

        // Native f32 payload.
        let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
        let item = Quantizer::new()
            .run(
                &QuantRequest::vector_f32(data32)
                    .method(QuantMethod::KMeans)
                    .target_count(4)
                    .weights(uw.clone()),
            )
            .unwrap()
            .into_single()
            .unwrap();
        assert_eq!(item.precision(), Precision::F32);

        // Batch: a uniform slot runs the unweighted path bitwise while a
        // non-uniform sibling runs weighted in the same request.
        let other = clustered(50, 83);
        let resp = Quantizer::new()
            .run(
                &QuantRequest::batch(vec![data.clone(), other.clone()])
                    .method(QuantMethod::KMeans)
                    .target_count(4)
                    .batch_weights(vec![uw.clone(), vec![1.0; other.len()]]),
            )
            .unwrap();
        assert_eq!(resp.len(), 2);
        let want_other = Quantizer::new()
            .run(&QuantRequest::vector(other).method(QuantMethod::KMeans).target_count(4))
            .unwrap()
            .into_single()
            .unwrap();
        assert_f64_bitwise(
            resp.items[1].as_ref().unwrap(),
            &want_other,
            "uniform batch slot",
        );

        // Matrix per-row: row-major weights split like the data, and a
        // weighted row matches the same row as a weighted vector request.
        let m = Matrix::from_fn(3, 20, |i, j| ((i * 20 + j) % 7) as f64 / 7.0);
        let mw: Vec<f64> = (0..60).map(|i| 0.5 + (i % 4) as f64).collect();
        let resp = Quantizer::new()
            .run(
                &QuantRequest::matrix(m.clone(), Grouping::PerRow)
                    .method(QuantMethod::KMeans)
                    .target_count(3)
                    .weights(mw.clone()),
            )
            .unwrap();
        assert_eq!(resp.len(), 3);
        let want_row = Quantizer::new()
            .run(
                &QuantRequest::vector(m.row(1).to_vec())
                    .method(QuantMethod::KMeans)
                    .target_count(3)
                    .weights(mw[20..40].to_vec()),
            )
            .unwrap()
            .into_single()
            .unwrap();
        assert_f64_bitwise(resp.items[1].as_ref().unwrap(), &want_row, "matrix row 1");
    }

    #[test]
    fn weighted_cold_sweep_matches_per_lambda_one_shots_bitwise() {
        let data = clustered(60, 84);
        let uw = ramp_weights(data.len());
        let lambdas = vec![1e-3, 1e-2, 1e-1];
        let resp = Quantizer::new()
            .run(
                &QuantRequest::vector(data.clone())
                    .method(QuantMethod::L1LeastSquare)
                    .weights(uw.clone())
                    .sweep_cold(lambdas.clone()),
            )
            .unwrap();
        assert_eq!(resp.len(), lambdas.len());
        for (k, &l) in lambdas.iter().enumerate() {
            let want = Quantizer::new()
                .run(
                    &QuantRequest::vector(data.clone())
                        .method(QuantMethod::L1LeastSquare)
                        .lambda1(l)
                        .weights(uw.clone()),
                )
                .unwrap()
                .into_single()
                .unwrap();
            assert_f64_bitwise(resp.items[k].as_ref().unwrap(), &want, &format!("λ#{k}"));
        }
        // The warm sweep yields the same item count and λ tagging.
        let warm = Quantizer::new()
            .run(
                &QuantRequest::vector(data)
                    .method(QuantMethod::L1LeastSquare)
                    .weights(uw)
                    .sweep(lambdas.clone()),
            )
            .unwrap();
        assert_eq!(warm.len(), lambdas.len());
        for (r, &l) in warm.items.iter().zip(&lambdas) {
            assert_eq!(r.as_ref().unwrap().diag().lambda1, l);
        }
    }

    #[test]
    fn entropy_budget_merges_into_the_budget_and_nops_when_generous() {
        let data = clustered(200, 85);
        let mk = || {
            QuantRequest::vector(data.clone()).method(QuantMethod::KMeans).target_count(8)
        };
        let plain = Quantizer::new().run(&mk()).unwrap().into_single().unwrap();
        // A tight budget forces merges until the index entropy fits.
        let tight = Quantizer::new()
            .run(&mk().entropy_budget(1.0))
            .unwrap()
            .into_single()
            .unwrap();
        let stats = tight.compression(8);
        assert!(
            stats.index_entropy <= 1.0 + 1e-9,
            "index entropy {} exceeds the 1.0-bit budget",
            stats.index_entropy
        );
        assert!(tight.distinct_values() < plain.distinct_values());
        assert!(stats.entropy_coded_bytes <= stats.compact_bytes);
        // A generous budget is a bitwise no-op relative to no budget.
        let generous = Quantizer::new()
            .run(&mk().entropy_budget(64.0))
            .unwrap()
            .into_single()
            .unwrap();
        assert_f64_bitwise(&generous, &plain, "generous budget");
        // Budget zero collapses to a single level on every method that
        // reaches the finalize.
        let one = Quantizer::new()
            .run(&mk().entropy_budget(0.0))
            .unwrap()
            .into_single()
            .unwrap();
        assert_eq!(one.distinct_values(), 1);
    }

    #[test]
    fn caching_facade_bypasses_memos_for_weighted_requests() {
        let data = clustered(60, 86);
        let uw = ramp_weights(data.len());
        let q = Quantizer::caching(8);
        let weighted = || {
            QuantRequest::vector(data.clone())
                .method(QuantMethod::L1LeastSquare)
                .lambda1(0.02)
                .weights(uw.clone())
        };
        let plain = || {
            QuantRequest::vector(data.clone())
                .method(QuantMethod::L1LeastSquare)
                .lambda1(0.02)
        };
        let want_w = Quantizer::new().run(&weighted()).unwrap().into_single().unwrap();
        let want_p = Quantizer::new().run(&plain()).unwrap().into_single().unwrap();
        // Interleave: weighted results never pollute the unweighted memo
        // and vice versa; every run is bitwise what the stateless facade
        // produces.
        for round in 0..2 {
            let gw = q.run(&weighted()).unwrap().into_single().unwrap();
            let gp = q.run(&plain()).unwrap().into_single().unwrap();
            assert_f64_bitwise(&gw, &want_w, &format!("weighted #{round}"));
            assert_f64_bitwise(&gp, &want_p, &format!("plain #{round}"));
        }
    }
}
