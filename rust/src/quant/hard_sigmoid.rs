//! The paper's hard-sigmoid range clamp (eq 21).
//!
//! Post-quantization outputs are clamped into a valid range before the l2
//! information loss is computed: "MNIST quantization values must be in
//! [0,1] … applying the function could avoid out-of-range values that might
//! reduce the l2 loss in a prohibited way." The same clamp exposes the
//! paper's claim 6: k-means with bad initializations can emit out-of-range
//! centroids, while the least-square methods do not.

/// `H(x, a, b)` of eq 21.
#[inline]
pub fn hard_sigmoid(x: f64, a: f64, b: f64) -> f64 {
    debug_assert!(a <= b);
    if x <= a {
        a
    } else if x >= b {
        b
    } else {
        x
    }
}

/// Apply the clamp in place; returns how many values were out of range
/// (the §4 out-of-range incidence metric).
pub fn clamp_slice(xs: &mut [f64], a: f64, b: f64) -> usize {
    let mut clipped = 0;
    for x in xs.iter_mut() {
        let h = hard_sigmoid(*x, a, b);
        if h != *x {
            clipped += 1;
            *x = h;
        }
    }
    clipped
}

/// Count out-of-range values without modifying.
pub fn count_out_of_range(xs: &[f64], a: f64, b: f64) -> usize {
    xs.iter().filter(|&&x| x < a || x > b).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_at_boundaries() {
        assert_eq!(hard_sigmoid(-0.5, 0.0, 1.0), 0.0);
        assert_eq!(hard_sigmoid(1.5, 0.0, 1.0), 1.0);
        assert_eq!(hard_sigmoid(0.3, 0.0, 1.0), 0.3);
        assert_eq!(hard_sigmoid(0.0, 0.0, 1.0), 0.0);
        assert_eq!(hard_sigmoid(1.0, 0.0, 1.0), 1.0);
    }

    #[test]
    fn clamp_slice_counts() {
        let mut xs = vec![-1.0, 0.5, 2.0, 0.0];
        let n = clamp_slice(&mut xs, 0.0, 1.0);
        assert_eq!(n, 2);
        assert_eq!(xs, vec![0.0, 0.5, 1.0, 0.0]);
    }

    #[test]
    fn count_matches_clamp() {
        let xs = vec![-1.0, 0.5, 2.0];
        assert_eq!(count_out_of_range(&xs, 0.0, 1.0), 2);
    }
}
