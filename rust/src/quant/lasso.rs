//! Coordinate-descent LASSO over the difference basis (paper eq 6, 13–15).
//!
//! Solves
//!
//! ```text
//! min_α  ½‖ŵ − Vα‖² + λ₁‖α‖₁ − λ₂‖α‖₂²
//! ```
//!
//! with cyclic (Gauss-Seidel) coordinate descent. With the ½ least-square
//! scaling the coordinate update is exactly the paper's eq 14 (λ₂ = 0) and
//! eq 15 (λ₂ > 0, the *negative-l2 relaxation* of §3.3):
//!
//! ```text
//! α_k ← S_{λ₁ / (c_k − 2λ₂)} ( ρ_k / (c_k − 2λ₂) ),   c_k = ‖V_{·k}‖²,
//! ρ_k = V_{·k}ᵀ (ŵ − V α_{/k})
//! ```
//!
//! §3.2.1 of the paper proves the λ₂ = 0 objective strongly convex (eq 12:
//! the Gram of `V` is PD because every `d_j ≠ 0`), so CD converges linearly
//! to the unique global optimum; initializing at `α = 𝟙` starts from zero
//! least-square loss.
//!
//! ## Structured vs dense epochs
//!
//! [`solve`] runs the **O(m)-per-epoch structured** schedule derived in
//! DESIGN §3: coordinates are processed descending (m−1 → 0); a single lazy
//! scalar `s = Σ_{i≥j} r_i` is maintained, because an update at coordinate j
//! only touches residual rows `i ≥ j`, which are *fully contained* in the
//! suffix the scalar tracks — rows below the cursor are never stale. Every
//! quantity the update needs has a closed form (`ρ_j = d_j s + c_j α_j`,
//! `c_j = d_j²(m−j)`), so one full epoch costs O(m) flops and touches O(m)
//! memory.
//!
//! [`solve_dense`] is the textbook O(m²)-per-epoch implementation over the
//! dense `V`; it exists as the correctness oracle and as the §Perf
//! "before" baseline.
//!
//! ## Precision lanes
//!
//! The solvers are generic over the element precision ([`Scalar`]): the
//! default `f64` instantiation is the bitwise-reference lane; `T = f32`
//! halves the memory traffic of the O(m)-per-epoch kernel, which is what
//! the epoch loop is bound by on 10k+-element NN-weight workloads.
//! Penalties and tolerances stay `f64` in [`LassoConfig`] and are narrowed
//! once at solve entry. Two lane-specific rules (see
//! [`crate::linalg::scalar`] for the full contract):
//!
//! * the convergence tolerance is floored at [`Scalar::TOL_FLOOR`]
//!   (0 for f64, 1e-6 for f32) — an f32 coordinate move below ~1e-6 is
//!   rounding noise, and waiting for the f64 default of 1e-10 would only
//!   burn epochs until the support-patience stop fires;
//! * `support_patience` is therefore the *primary* stop for the f32 lane
//!   at small λ: quantization consumes the support, and the support
//!   stabilizes well before α converges in norm in either precision.
//!
//! ## Workspaces
//!
//! [`solve_ws`] takes a caller-owned [`Workspace`] holding the residual and
//! reconstruction buffers, so λ-sweeps and Algorithm-2 λ-ladders reuse one
//! allocation across hundreds of solves instead of allocating two fresh
//! vectors per call. [`solve`] is the allocating convenience wrapper and is
//! bitwise-identical to it.

use super::vmatrix::VBasis;
use crate::linalg::kernels;
use crate::linalg::scalar::Scalar;
use crate::{Error, Result};

/// Soft-thresholding operator `S_λ(x)` — defined in
/// [`crate::linalg::kernels`] (the CD arithmetic floor), re-exported here
/// under its historical path.
pub use crate::linalg::kernels::shrink;

/// What to do when the negative-l2 relaxation makes a coordinate's
/// denominator `c_k − 2λ₂` non-positive (the instability the paper reports
/// for large λ₂).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Instability {
    /// Skip the coordinate (leave its α untouched) and flag the solution.
    #[default]
    Skip,
    /// Abort with [`Error::InvalidParam`].
    Error,
}

/// Solver configuration. Penalties/tolerances are always `f64` regardless
/// of the solve lane; they are narrowed once at solve entry.
#[derive(Debug, Clone)]
pub struct LassoConfig {
    /// l1 penalty λ₁ ≥ 0.
    pub lambda1: f64,
    /// Negative-l2 relaxation coefficient λ₂ ≥ 0 (eq 13; 0 disables).
    pub lambda2: f64,
    /// Epoch budget.
    pub max_epochs: usize,
    /// Convergence threshold on the largest coordinate move per epoch,
    /// scaled by `d_j` (i.e. measured in reconstruction units). The
    /// effective threshold is `tol.max(Scalar::TOL_FLOOR)` — identical to
    /// `tol` on the f64 lane, floored at 1e-6 on the f32 lane where
    /// smaller moves are below single-precision resolution.
    pub tol: f64,
    /// Behaviour when `c_k − 2λ₂ ≤ 0`.
    pub on_instability: Instability,
    /// Early-stop when the support (the zero pattern of α) is unchanged
    /// for this many consecutive epochs (0 disables). Quantization only
    /// consumes the support — Algorithm 1 refits the values exactly — so
    /// waiting for α to converge in norm wastes epochs (§Perf: ~10×
    /// fewer epochs at small λ with identical refit loss). On the f32
    /// lane this is the stop that usually fires (see module docs).
    pub support_patience: usize,
}

impl Default for LassoConfig {
    fn default() -> Self {
        LassoConfig {
            lambda1: 1e-3,
            lambda2: 0.0,
            max_epochs: 1000,
            tol: 1e-10,
            on_instability: Instability::Skip,
            support_patience: 10,
        }
    }
}

/// Solver output (lane-generic; `LassoSolution<f64>` is the default).
#[derive(Debug, Clone)]
pub struct LassoSolution<T: Scalar = f64> {
    /// The optimized coefficient vector (exact zeros from shrinkage).
    pub alpha: Vec<T>,
    /// Epochs actually run.
    pub epochs: usize,
    /// Whether the tolerance was met within the epoch budget.
    pub converged: bool,
    /// Final objective value (½LS + λ₁‖α‖₁ − λ₂‖α‖₂²), accumulated in f64
    /// on both lanes.
    pub objective: f64,
    /// True if any coordinate hit the λ₂ instability and was skipped.
    pub unstable: bool,
}

impl<T: Scalar> LassoSolution<T> {
    /// Indices of the non-zero coefficients (the support, eq 7).
    pub fn support(&self) -> Vec<usize> {
        self.alpha
            .iter()
            .enumerate()
            .filter(|(_, &a)| a != T::ZERO)
            .map(|(i, _)| i)
            .collect()
    }

    /// `‖α‖₀`.
    pub fn nnz(&self) -> usize {
        self.alpha.iter().filter(|&&a| a != T::ZERO).count()
    }
}

/// Reusable CD solve buffers — residual, reconstruction, and the per-solve
/// column-norm cache — sized lazily to the basis dimension. Owning one
/// across a λ path removes the per-solve allocations from the hot loop;
/// buffers are fully overwritten before every read, so reuse cannot change
/// results.
///
/// All three buffers are kept **contiguous and exactly `m` long** (the
/// layout contract the [`crate::linalg::kernels`] layer assumes: plain
/// `&[T]` slices, no strides, no interleaving), and [`Workspace::reset`]
/// never reallocates when the prior capacity suffices — a size *decrease*
/// followed by an increase back reuses the old allocation instead of
/// round-tripping through the allocator.
#[derive(Debug, Clone, Default)]
pub struct Workspace<T: Scalar = f64> {
    rec: Vec<T>,
    r: Vec<T>,
    /// Cached `‖V_{·j}‖² = d_j²(m−j)` for the current basis — filled once
    /// per solve ([`VBasis::col_norms_into`]) instead of recomputed per
    /// coordinate per epoch.
    c: Vec<T>,
    /// Suffix-weight sums `Σ_{i≥j} W_i` for the weighted solvers
    /// ([`solve_ws_weighted`]); untouched (and unsized) on the unweighted
    /// path so the hot unweighted reset stays three buffers.
    sw: Vec<T>,
}

impl<T: Scalar> Workspace<T> {
    /// Size every buffer for an m-dimensional solve, reusing capacity.
    /// `clear` + `resize` (rather than a bare `resize`) guarantees a grow
    /// never copies stale contents into the new allocation; all buffers
    /// are fully overwritten before every read, so the zero-fill cannot
    /// change results.
    fn reset(&mut self, m: usize) {
        self.rec.clear();
        self.rec.resize(m, T::ZERO);
        self.r.clear();
        self.r.resize(m, T::ZERO);
        self.c.clear();
        self.c.resize(m, T::ZERO);
    }

    /// [`Workspace::reset`] plus the suffix-weight buffer used only by the
    /// weighted solvers — kept separate so unweighted solves never pay for
    /// (or allocate) the fourth buffer.
    fn reset_weighted(&mut self, m: usize) {
        self.reset(m);
        self.sw.clear();
        self.sw.resize(m, T::ZERO);
    }

    /// Buffer capacities `(rec, r, c)` — exposed for the no-reallocation
    /// regression test.
    #[cfg(test)]
    fn capacities(&self) -> (usize, usize, usize) {
        (self.rec.capacity(), self.r.capacity(), self.c.capacity())
    }
}

/// Objective value ½‖ŵ − Vα‖² + λ₁‖α‖₁ − λ₂‖α‖₂², accumulated in f64.
pub fn objective<T: Scalar>(basis: &VBasis<T>, w: &[T], alpha: &[T], cfg: &LassoConfig) -> f64 {
    let rec = basis.apply(alpha);
    let ls: f64 = w
        .iter()
        .zip(&rec)
        .map(|(a, b)| {
            let d = (*a - *b).to_f64();
            d * d
        })
        .sum();
    let l1: f64 = alpha.iter().map(|a| a.abs().to_f64()).sum();
    let l2: f64 = alpha.iter().map(|a| (*a * *a).to_f64()).sum();
    0.5 * ls + cfg.lambda1 * l1 - cfg.lambda2 * l2
}

/// Importance-weighted objective ½Σⱼ Wⱼ(ŵⱼ − (Vα)ⱼ)² + λ₁‖α‖₁ − λ₂‖α‖₂²,
/// accumulated in f64. With `W ≡ 𝟙` this equals [`objective`] exactly.
pub fn objective_weighted<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    importance: &[T],
    alpha: &[T],
    cfg: &LassoConfig,
) -> f64 {
    let rec = basis.apply(alpha);
    let ls: f64 = w
        .iter()
        .zip(&rec)
        .zip(importance)
        .map(|((a, b), wi)| {
            let d = (*a - *b).to_f64();
            wi.to_f64() * d * d
        })
        .sum();
    let l1: f64 = alpha.iter().map(|a| a.abs().to_f64()).sum();
    let l2: f64 = alpha.iter().map(|a| (*a * *a).to_f64()).sum();
    0.5 * ls + cfg.lambda1 * l1 - cfg.lambda2 * l2
}

/// Per-level importance weights must align with the basis and be finite
/// and non-negative (the api layer validates *user* weights; folding
/// preserves both properties, so this is a cheap internal invariant check).
fn validate_importance<T: Scalar>(basis: &VBasis<T>, importance: &[T]) -> Result<()> {
    if importance.len() != basis.m() {
        return Err(Error::InvalidInput(format!(
            "lasso: importance dim {} vs basis dim {}",
            importance.len(),
            basis.m()
        )));
    }
    if let Some(bad) = importance.iter().find(|x| !x.is_finite() || **x < T::ZERO) {
        return Err(Error::InvalidInput(format!(
            "lasso: importance weights must be finite and non-negative (got {bad})"
        )));
    }
    Ok(())
}

fn validate<T: Scalar>(basis: &VBasis<T>, w: &[T], cfg: &LassoConfig) -> Result<()> {
    if w.len() != basis.m() {
        return Err(Error::InvalidInput(format!(
            "lasso: basis dim {} vs target dim {}",
            basis.m(),
            w.len()
        )));
    }
    if basis.m() == 0 {
        return Err(Error::InvalidInput("lasso: empty basis".into()));
    }
    if cfg.lambda1 < 0.0 || cfg.lambda2 < 0.0 {
        return Err(Error::InvalidParam(format!(
            "lasso: λ must be non-negative (λ1={}, λ2={})",
            cfg.lambda1, cfg.lambda2
        )));
    }
    Ok(())
}

/// Validate and materialize the starting α (warm copy or the paper's
/// `α = 𝟙`), with null columns (`d_j = 0`) forced to zero.
fn init_alpha<T: Scalar>(basis: &VBasis<T>, warm: Option<&[T]>, who: &str) -> Result<Vec<T>> {
    let m = basis.m();
    let mut alpha: Vec<T> = match warm {
        Some(a) => {
            if a.len() != m {
                return Err(Error::InvalidInput(format!(
                    "{who}: warm start dim {} vs {}",
                    a.len(),
                    m
                )));
            }
            a.to_vec()
        }
        None => vec![T::ONE; m],
    };
    // Null columns (d_j = 0, possible at j = 0 when v_0 = 0) can never
    // affect the reconstruction; force their α to 0 so they never pollute
    // the support.
    for (a, dj) in alpha.iter_mut().zip(basis.diffs()) {
        if *dj == T::ZERO {
            *a = T::ZERO;
        }
    }
    Ok(alpha)
}

/// Structured CD solve — O(m) per epoch. `warm` optionally warm-starts α
/// (Algorithm 2 relies on this); the default start is the paper's `α = 𝟙`.
/// Allocating wrapper over [`solve_ws`].
pub fn solve<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    cfg: &LassoConfig,
    warm: Option<&[T]>,
) -> Result<LassoSolution<T>> {
    let mut ws = Workspace::default();
    solve_ws(basis, w, cfg, warm, &mut ws)
}

/// [`solve`] with a caller-owned [`Workspace`] so repeated solves (λ
/// sweeps, Algorithm 2 ladders) do not allocate per call. Results are
/// bitwise-identical to [`solve`].
pub fn solve_ws<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    cfg: &LassoConfig,
    warm: Option<&[T]>,
    ws: &mut Workspace<T>,
) -> Result<LassoSolution<T>> {
    validate(basis, w, cfg)?;
    let m = basis.m();
    let d = basis.diffs();
    let mut alpha = init_alpha(basis, warm, "lasso")?;

    let lambda1 = T::from_f64(cfg.lambda1);
    let two_lambda2 = T::from_f64(2.0 * cfg.lambda2);
    let tol = T::from_f64(cfg.tol.max(T::TOL_FLOOR));

    // Residual r = ŵ − Vα, rebuilt exactly once per epoch in O(m); column
    // norms cached once per solve (pure per-entry expression — bitwise
    // neutral vs recomputing inside the loop).
    ws.reset(m);
    let Workspace { rec, r, c, .. } = ws;
    basis.col_norms_into(c);
    let mut unstable = false;
    let mut epochs = 0;
    let mut converged = false;
    // Support-stability early stop: FNV-1a hash over the zero pattern.
    let mut last_sig = 0u64;
    let mut stable_epochs = 0usize;

    for _ in 0..cfg.max_epochs {
        epochs += 1;
        basis.apply_into(&alpha, rec);
        kernels::sub(w, rec, r);

        // Descending pass with the lazy suffix scalar (see module docs).
        let mut s = T::ZERO; // Σ_{i≥j} r_i, exact under all updates so far this epoch
        let mut max_move = T::ZERO;
        for j in (0..m).rev() {
            s += r[j];
            let dj = d[j];
            if dj == T::ZERO {
                continue; // only possible at j=0 when v_0 == 0
            }
            let cj = c[j];
            let mut denom = cj - two_lambda2;
            if denom <= T::EPSILON * cj.max(T::ONE) {
                match cfg.on_instability {
                    Instability::Skip => {
                        // Per-coordinate fallback: the relaxation is
                        // non-convex here, so update this coordinate with
                        // the plain-l1 rule (λ₂ = 0 locally) and flag it.
                        unstable = true;
                        denom = cj;
                    }
                    Instability::Error => {
                        return Err(Error::InvalidParam(format!(
                            "lasso: λ2={} makes coordinate {} non-convex (c={})",
                            cfg.lambda2, j, cj
                        )));
                    }
                }
            }
            // ρ_j = V_jᵀ(r + V_j α_j) = d_j·s + c_j·α_j
            let rho = dj * s + cj * alpha[j];
            let new = shrink(rho, lambda1) / denom;
            let delta = new - alpha[j];
            if delta != T::ZERO {
                alpha[j] = new;
                // The update subtracts d_j·δ from every residual row i ≥ j —
                // all inside the suffix the scalar tracks.
                s -= T::from_usize(m - j) * dj * delta;
                max_move = max_move.max((dj * delta).abs());
            }
        }

        if max_move < tol {
            converged = true;
            break;
        }
        if cfg.support_patience > 0 {
            let sig = support_signature(&alpha);
            if sig == last_sig {
                stable_epochs += 1;
                if stable_epochs >= cfg.support_patience {
                    converged = true;
                    break;
                }
            } else {
                last_sig = sig;
                stable_epochs = 0;
            }
        }
    }

    let objective = objective(basis, w, &alpha, cfg);
    Ok(LassoSolution { alpha, epochs, converged, objective, unstable })
}

/// Importance-weighted structured CD solve — allocating wrapper over
/// [`solve_ws_weighted`].
pub fn solve_weighted<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    importance: &[T],
    cfg: &LassoConfig,
    warm: Option<&[T]>,
) -> Result<LassoSolution<T>> {
    let mut ws = Workspace::default();
    solve_ws_weighted(basis, w, importance, cfg, warm, &mut ws)
}

/// Importance-weighted structured CD solve — O(m) per epoch, minimizing
///
/// ```text
/// ½ Σⱼ Wⱼ(ŵⱼ − (Vα)ⱼ)² + λ₁‖α‖₁ − λ₂‖α‖₂²
/// ```
///
/// for per-level weights `W` (folded user importance, or multiplicities).
/// The diagonal-weighted normal equations keep the same suffix structure as
/// the unweighted solve: the weighted column norm is
/// `c_j = d_j²·SW_j` with `SW_j = Σ_{i≥j} W_i`
/// ([`VBasis::col_norm_sq_weighted`]), and the lazy scalar becomes the
/// *weighted* residual suffix `s = Σ_{i≥j} W_i r_i`, so
/// `ρ_j = V_{·j}ᵀ diag(W) (r + V_{·j}α_j) = d_j·s + c_j·α_j` and an update
/// at `j` shifts the scalar by `SW_j·d_j·δ`. One epoch is still O(m).
///
/// Coordinates whose *entire* weight suffix is zero (`c_j = 0`) cannot
/// affect the weighted loss; their α is forced to 0 (the λ₁-minimal
/// choice) instead of dividing by zero.
///
/// With `W ≡ 𝟙` every intermediate equals the unweighted solver's
/// bit-for-bit **except** the column norms (`d_j²·Σ1 = d_j²·(m−j)` by a
/// different summation order) — callers wanting the pinned unweighted path
/// must call [`solve_ws`] directly, which is why the pipeline drops
/// uniform weights to `None` upstream.
pub fn solve_ws_weighted<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    importance: &[T],
    cfg: &LassoConfig,
    warm: Option<&[T]>,
    ws: &mut Workspace<T>,
) -> Result<LassoSolution<T>> {
    validate(basis, w, cfg)?;
    validate_importance(basis, importance)?;
    let m = basis.m();
    let d = basis.diffs();
    let mut alpha = init_alpha(basis, warm, "lasso (weighted)")?;

    let lambda1 = T::from_f64(cfg.lambda1);
    let two_lambda2 = T::from_f64(2.0 * cfg.lambda2);
    let tol = T::from_f64(cfg.tol.max(T::TOL_FLOOR));

    ws.reset_weighted(m);
    let Workspace { rec, r, c, sw } = ws;
    // Suffix-weight sums SW_j = Σ_{i≥j} W_i, descending accumulation in
    // lane precision (deterministic), then the weighted column norms.
    let mut acc = T::ZERO;
    for j in (0..m).rev() {
        acc += importance[j];
        sw[j] = acc;
    }
    for (j, cj) in c.iter_mut().enumerate() {
        *cj = basis.col_norm_sq_weighted(j, sw);
    }

    let mut unstable = false;
    let mut epochs = 0;
    let mut converged = false;
    let mut last_sig = 0u64;
    let mut stable_epochs = 0usize;

    for _ in 0..cfg.max_epochs {
        epochs += 1;
        basis.apply_into(&alpha, rec);
        kernels::sub(w, rec, r);

        // Descending pass with the *weighted* lazy suffix scalar.
        let mut s = T::ZERO; // Σ_{i≥j} W_i·r_i
        let mut max_move = T::ZERO;
        for j in (0..m).rev() {
            s += importance[j] * r[j];
            let dj = d[j];
            if dj == T::ZERO {
                continue;
            }
            let cj = c[j];
            if cj == T::ZERO {
                // Zero-weight suffix: the coordinate is invisible to the
                // weighted loss. α_j = 0 minimizes the λ₁ term; the scalar
                // shift SW_j·d_j·δ is exactly zero, so s stays valid.
                alpha[j] = T::ZERO;
                continue;
            }
            let mut denom = cj - two_lambda2;
            if denom <= T::EPSILON * cj.max(T::ONE) {
                match cfg.on_instability {
                    Instability::Skip => {
                        unstable = true;
                        denom = cj;
                    }
                    Instability::Error => {
                        return Err(Error::InvalidParam(format!(
                            "lasso: λ2={} makes coordinate {} non-convex (c={})",
                            cfg.lambda2, j, cj
                        )));
                    }
                }
            }
            let rho = dj * s + cj * alpha[j];
            let new = shrink(rho, lambda1) / denom;
            let delta = new - alpha[j];
            if delta != T::ZERO {
                alpha[j] = new;
                s -= sw[j] * dj * delta;
                max_move = max_move.max((dj * delta).abs());
            }
        }

        if max_move < tol {
            converged = true;
            break;
        }
        if cfg.support_patience > 0 {
            let sig = support_signature(&alpha);
            if sig == last_sig {
                stable_epochs += 1;
                if stable_epochs >= cfg.support_patience {
                    converged = true;
                    break;
                }
            } else {
                last_sig = sig;
                stable_epochs = 0;
            }
        }
    }

    let objective = objective_weighted(basis, w, importance, &alpha, cfg);
    Ok(LassoSolution { alpha, epochs, converged, objective, unstable })
}

/// FNV-1a hash of α's zero pattern (the support signature).
fn support_signature<T: Scalar>(alpha: &[T]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for (i, &a) in alpha.iter().enumerate() {
        if a != T::ZERO {
            h = (h ^ i as u64).wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Dense (naïve) CD solve — O(m²) per epoch over the dense `V`.
/// Correctness oracle for [`solve`] and the §Perf baseline. Validates the
/// warm start exactly like [`solve`] (a wrong-length warm start is an
/// error, not a silent truncation).
pub fn solve_dense<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    cfg: &LassoConfig,
    warm: Option<&[T]>,
) -> Result<LassoSolution<T>> {
    validate(basis, w, cfg)?;
    let m = basis.m();
    let d = basis.diffs();
    let mut alpha = init_alpha(basis, warm, "lasso (dense)")?;

    let lambda1 = T::from_f64(cfg.lambda1);
    let two_lambda2 = T::from_f64(2.0 * cfg.lambda2);
    let tol = T::from_f64(cfg.tol.max(T::TOL_FLOOR));

    // r = ŵ − Vα maintained incrementally; the initial reconstruction is
    // the naïve O(m²) row-by-row dense product (a growing-prefix dot).
    let mut r: Vec<T> = Vec::with_capacity(m);
    for (i, wi) in w.iter().enumerate() {
        r.push(*wi - kernels::dot(&d[..=i], &alpha[..=i]));
    }

    let mut col_norms = vec![T::ZERO; m];
    basis.col_norms_into(&mut col_norms);
    let mut unstable = false;
    let mut epochs = 0;
    let mut converged = false;
    let mut last_sig = 0u64;
    let mut stable_epochs = 0usize;

    for _ in 0..cfg.max_epochs {
        epochs += 1;
        let mut max_move = T::ZERO;
        for j in (0..m).rev() {
            let dj = d[j];
            if dj == T::ZERO {
                continue;
            }
            let cj = col_norms[j];
            let mut denom = cj - two_lambda2;
            if denom <= T::EPSILON * cj.max(T::ONE) {
                match cfg.on_instability {
                    Instability::Skip => {
                        unstable = true;
                        denom = cj; // plain-l1 fallback, mirrors `solve`
                    }
                    Instability::Error => {
                        return Err(Error::InvalidParam("lasso: unstable λ2".into()));
                    }
                }
            }
            // Fused coordinate update over the dense column (rows j..m all
            // equal d_j): suffix-sum V_jᵀr, soft-threshold, apply the
            // residual correction — one kernel call.
            let (new, delta) =
                kernels::shrink_axpy(&mut r[j..], dj, cj, alpha[j], lambda1, denom);
            if delta != T::ZERO {
                alpha[j] = new;
                max_move = max_move.max((dj * delta).abs());
            }
        }
        if max_move < tol {
            converged = true;
            break;
        }
        if cfg.support_patience > 0 {
            let sig = support_signature(&alpha);
            if sig == last_sig {
                stable_epochs += 1;
                if stable_epochs >= cfg.support_patience {
                    converged = true;
                    break;
                }
            } else {
                last_sig = sig;
                stable_epochs = 0;
            }
        }
    }

    let objective = objective(basis, w, &alpha, cfg);
    Ok(LassoSolution { alpha, epochs, converged, objective, unstable })
}

/// Dense (naïve) importance-weighted CD solve — O(m²) per epoch, the
/// correctness oracle for [`solve_ws_weighted`]. Recomputes the weighted
/// column correlation `V_{·j}ᵀ diag(W) r = d_j Σ_{i≥j} W_i r_i` by an
/// explicit suffix loop each coordinate and maintains the residual
/// incrementally, so it shares no structure with the fast path beyond the
/// update rule itself.
pub fn solve_dense_weighted<T: Scalar>(
    basis: &VBasis<T>,
    w: &[T],
    importance: &[T],
    cfg: &LassoConfig,
    warm: Option<&[T]>,
) -> Result<LassoSolution<T>> {
    validate(basis, w, cfg)?;
    validate_importance(basis, importance)?;
    let m = basis.m();
    let d = basis.diffs();
    let mut alpha = init_alpha(basis, warm, "lasso (dense weighted)")?;

    let lambda1 = T::from_f64(cfg.lambda1);
    let two_lambda2 = T::from_f64(2.0 * cfg.lambda2);
    let tol = T::from_f64(cfg.tol.max(T::TOL_FLOOR));

    let mut r: Vec<T> = Vec::with_capacity(m);
    for (i, wi) in w.iter().enumerate() {
        r.push(*wi - kernels::dot(&d[..=i], &alpha[..=i]));
    }

    // Weighted column norms c_j = d_j² Σ_{i≥j} W_i.
    let mut col_norms = vec![T::ZERO; m];
    let mut acc = T::ZERO;
    for j in (0..m).rev() {
        acc += importance[j];
        col_norms[j] = d[j] * d[j] * acc;
    }

    let mut unstable = false;
    let mut epochs = 0;
    let mut converged = false;
    let mut last_sig = 0u64;
    let mut stable_epochs = 0usize;

    for _ in 0..cfg.max_epochs {
        epochs += 1;
        let mut max_move = T::ZERO;
        for j in (0..m).rev() {
            let dj = d[j];
            if dj == T::ZERO {
                continue;
            }
            let cj = col_norms[j];
            if cj == T::ZERO {
                // Zero-weight suffix (see solve_ws_weighted): force α_j = 0
                // and keep the residual exact.
                let delta = T::ZERO - alpha[j];
                if delta != T::ZERO {
                    alpha[j] = T::ZERO;
                    for ri in &mut r[j..] {
                        *ri = *ri - dj * delta;
                    }
                }
                continue;
            }
            let mut denom = cj - two_lambda2;
            if denom <= T::EPSILON * cj.max(T::ONE) {
                match cfg.on_instability {
                    Instability::Skip => {
                        unstable = true;
                        denom = cj;
                    }
                    Instability::Error => {
                        return Err(Error::InvalidParam("lasso: unstable λ2".into()));
                    }
                }
            }
            let mut sj = T::ZERO;
            for (ri, wi) in r[j..].iter().zip(&importance[j..]) {
                sj += *wi * *ri;
            }
            let rho = dj * sj + cj * alpha[j];
            let new = shrink(rho, lambda1) / denom;
            let delta = new - alpha[j];
            if delta != T::ZERO {
                alpha[j] = new;
                for ri in &mut r[j..] {
                    *ri = *ri - dj * delta;
                }
                max_move = max_move.max((dj * delta).abs());
            }
        }
        if max_move < tol {
            converged = true;
            break;
        }
        if cfg.support_patience > 0 {
            let sig = support_signature(&alpha);
            if sig == last_sig {
                stable_epochs += 1;
                if stable_epochs >= cfg.support_patience {
                    converged = true;
                    break;
                }
            } else {
                last_sig = sig;
                stable_epochs = 0;
            }
        }
    }

    let objective = objective_weighted(basis, w, importance, &alpha, cfg);
    Ok(LassoSolution { alpha, epochs, converged, objective, unstable })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;

    fn random_values(m: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(-3.0, 5.0)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        v
    }

    #[test]
    fn shrink_operator() {
        assert_eq!(shrink(3.0, 1.0), 2.0);
        assert_eq!(shrink(-3.0, 1.0), -2.0);
        assert_eq!(shrink(0.5, 1.0), 0.0);
        assert_eq!(shrink(-0.5, 1.0), 0.0);
        assert_eq!(shrink(1.0, 1.0), 0.0);
        assert_eq!(shrink(3.0f32, 1.0f32), 2.0f32);
    }

    #[test]
    fn zero_lambda_recovers_ones() {
        // With λ1 = 0 the optimum is exactly α = 𝟙 (zero loss), and the
        // solver starts there, so it must stay.
        let v = random_values(32, 1);
        let b = VBasis::new(&v);
        let sol = solve(&b, &v, &LassoConfig { lambda1: 0.0, ..Default::default() }, None).unwrap();
        for a in &sol.alpha {
            assert!((a - 1.0).abs() < 1e-9);
        }
        assert!(sol.objective < 1e-12);
        assert!(sol.converged);
    }

    #[test]
    fn structured_matches_dense() {
        for seed in [2u64, 3, 4] {
            let v = random_values(48, seed);
            let b = VBasis::new(&v);
            let cfg = LassoConfig { lambda1: 0.3, max_epochs: 5000, ..Default::default() };
            let fast = solve(&b, &v, &cfg, None).unwrap();
            let slow = solve_dense(&b, &v, &cfg, None).unwrap();
            assert!(
                (fast.objective - slow.objective).abs() < 1e-8,
                "objective mismatch: {} vs {}",
                fast.objective,
                slow.objective
            );
            for (a, b2) in fast.alpha.iter().zip(&slow.alpha) {
                assert!((a - b2).abs() < 1e-6, "{a} vs {b2}");
            }
        }
    }

    #[test]
    fn workspace_reuse_is_bitwise_identical() {
        let v = random_values(64, 11);
        let b = VBasis::new(&v);
        let mut ws = Workspace::default();
        for lambda in [0.01, 0.1, 1.0] {
            let cfg = LassoConfig { lambda1: lambda, ..Default::default() };
            let fresh = solve(&b, &v, &cfg, None).unwrap();
            let reused = solve_ws(&b, &v, &cfg, None, &mut ws).unwrap();
            assert_eq!(fresh.alpha, reused.alpha, "λ={lambda}");
            assert_eq!(fresh.epochs, reused.epochs, "λ={lambda}");
            assert_eq!(fresh.objective.to_bits(), reused.objective.to_bits(), "λ={lambda}");
        }
    }

    #[test]
    fn workspace_reset_reuses_capacity_across_sweep() {
        // Regression: `reset` must not round-trip through the allocator on
        // repeated same-size solves, nor when the dimension shrinks and
        // grows back within prior capacity.
        let v = random_values(96, 13);
        let b = VBasis::new(&v);
        let v_small = random_values(24, 14);
        let b_small = VBasis::new(&v_small);
        let cfg = LassoConfig::default();
        let mut ws = Workspace::default();

        solve_ws(&b, &v, &cfg, None, &mut ws).unwrap();
        let caps = ws.capacities();
        let ptrs = (ws.rec.as_ptr(), ws.r.as_ptr(), ws.c.as_ptr());
        // Same-size sweep: capacity AND the allocations themselves stable.
        for lambda in [0.01, 0.1, 1.0, 10.0] {
            let cfg = LassoConfig { lambda1: lambda, ..Default::default() };
            solve_ws(&b, &v, &cfg, None, &mut ws).unwrap();
            assert_eq!(ws.capacities(), caps, "λ={lambda}: capacity changed");
            assert_eq!(
                (ws.rec.as_ptr(), ws.r.as_ptr(), ws.c.as_ptr()),
                ptrs,
                "λ={lambda}: buffer reallocated"
            );
        }
        // Shrink then grow back: still no growth past the original caps.
        solve_ws(&b_small, &v_small, &cfg, None, &mut ws).unwrap();
        solve_ws(&b, &v, &cfg, None, &mut ws).unwrap();
        assert_eq!(ws.capacities(), caps, "shrink/grow cycle reallocated");
    }

    #[test]
    fn f32_lane_tracks_f64_objective() {
        let v = random_values(64, 12);
        // Narrowing can merge near-equal neighbours; dedup to keep the
        // f32 basis strictly ascending (the lane's own prepare stage does
        // the same through UniqueDecomp).
        let mut v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        v32.dedup();
        let b = VBasis::new(&v);
        let b32 = VBasis::new(&v32);
        let cfg = LassoConfig { lambda1: 0.3, max_epochs: 5000, ..Default::default() };
        let s64 = solve(&b, &v, &cfg, None).unwrap();
        let s32 = solve(&b32, &v32, &cfg, None).unwrap();
        let denom = s64.objective.abs().max(1e-9);
        assert!(
            (s32.objective - s64.objective).abs() / denom < 1e-3,
            "f32 objective {} vs f64 {}",
            s32.objective,
            s64.objective
        );
    }

    #[test]
    fn larger_lambda_more_sparsity() {
        let v = random_values(64, 5);
        let b = VBasis::new(&v);
        let mut last_nnz = usize::MAX;
        for lambda in [0.01, 0.1, 1.0, 10.0, 100.0] {
            let sol = solve(
                &b,
                &v,
                &LassoConfig { lambda1: lambda, max_epochs: 5000, ..Default::default() },
                None,
            )
            .unwrap();
            assert!(sol.nnz() <= last_nnz, "λ={lambda}: nnz went up");
            last_nnz = sol.nnz();
        }
        assert!(last_nnz < 64);
    }

    #[test]
    fn objective_monotone_over_epochs() {
        let v = random_values(40, 6);
        let b = VBasis::new(&v);
        let cfg = LassoConfig { lambda1: 0.5, ..Default::default() };
        let mut prev = f64::INFINITY;
        let mut alpha: Option<Vec<f64>> = None;
        // Run one epoch at a time, checking the objective never rises.
        for _ in 0..20 {
            let one = LassoConfig { max_epochs: 1, tol: 0.0, ..cfg.clone() };
            let sol = solve(&b, &v, &one, alpha.as_deref()).unwrap();
            assert!(sol.objective <= prev + 1e-9, "objective rose: {prev} -> {}", sol.objective);
            prev = sol.objective;
            alpha = Some(sol.alpha);
        }
    }

    #[test]
    fn negative_l2_sparser_than_plain_l1() {
        // §3.3/Fig 4: same λ1, adding −λ2‖α‖² yields ≤ distinct values.
        let v = random_values(64, 7);
        let b = VBasis::new(&v);
        let l1_only = solve(
            &b,
            &v,
            &LassoConfig { lambda1: 0.5, max_epochs: 5000, ..Default::default() },
            None,
        )
        .unwrap();
        // λ2 scaled relative to the smallest column norm for stability.
        let cmin = (0..b.m()).map(|j| b.col_norm_sq(j)).fold(f64::INFINITY, f64::min);
        let l1_l2 = solve(
            &b,
            &v,
            &LassoConfig {
                lambda1: 0.5,
                lambda2: 0.2 * cmin,
                max_epochs: 5000,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        assert!(
            l1_l2.nnz() <= l1_only.nnz(),
            "l1+l2 nnz {} > l1 nnz {}",
            l1_l2.nnz(),
            l1_only.nnz()
        );
    }

    #[test]
    fn unstable_lambda2_flags_or_errors() {
        let v = random_values(16, 8);
        let b = VBasis::new(&v);
        let huge = (0..b.m()).map(|j| b.col_norm_sq(j)).fold(0.0, f64::max);
        let cfg = LassoConfig { lambda1: 0.1, lambda2: huge, ..Default::default() };
        let sol = solve(&b, &v, &cfg, None).unwrap();
        assert!(sol.unstable);
        let cfg_err = LassoConfig { on_instability: Instability::Error, ..cfg };
        assert!(solve(&b, &v, &cfg_err, None).is_err());
    }

    #[test]
    fn warm_start_converges_faster() {
        let v = random_values(128, 9);
        let b = VBasis::new(&v);
        let cfg = LassoConfig { lambda1: 0.4, max_epochs: 10_000, tol: 1e-12, ..Default::default() };
        let cold = solve(&b, &v, &cfg, None).unwrap();
        let warm = solve(&b, &v, &cfg, Some(&cold.alpha)).unwrap();
        assert!(warm.epochs <= cold.epochs);
        // Under support-patience stopping, a warm restart at a stabilized
        // support re-confirms stability within `patience + 1` epochs.
        assert!(
            warm.epochs <= cfg.support_patience + 2,
            "restart at a stabilized solution should stop quickly, took {}",
            warm.epochs
        );
    }

    #[test]
    fn rejects_bad_inputs() {
        let b = VBasis::new(&[1.0, 2.0]);
        assert!(solve(&b, &[1.0], &LassoConfig::default(), None).is_err());
        assert!(solve(
            &b,
            &[1.0, 2.0],
            &LassoConfig { lambda1: -1.0, ..Default::default() },
            None
        )
        .is_err());
        assert!(solve(&b, &[1.0, 2.0], &LassoConfig::default(), Some(&[1.0])).is_err());
    }

    #[test]
    fn dense_rejects_bad_warm_start_like_structured() {
        // Regression: solve_dense used to accept a wrong-length warm start
        // (silent `to_vec()`), diverging from `solve` and courting an
        // out-of-bounds panic in the epoch loop.
        let b = VBasis::new(&[1.0, 2.0, 4.0]);
        let w = [1.0, 2.0, 4.0];
        let cfg = LassoConfig::default();
        assert!(solve_dense(&b, &w, &cfg, Some(&[1.0])).is_err());
        assert!(solve_dense(&b, &w, &cfg, Some(&[1.0, 1.0, 1.0, 1.0])).is_err());
        assert!(solve_dense(&b, &w, &cfg, Some(&[1.0, 1.0, 1.0])).is_ok());
    }

    fn random_weights(m: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        (0..m).map(|_| rng.uniform(0.1, 4.0)).collect()
    }

    #[test]
    fn unit_weights_match_unweighted_objective() {
        // W ≡ 𝟙 is the same optimization problem as the unweighted solve;
        // the paths differ only in summation order of the column norms, so
        // compare objectives and supports, not bits (the pipeline handles
        // the bitwise pin by dropping uniform weights upstream).
        for seed in [21u64, 22, 23] {
            let v = random_values(48, seed);
            let b = VBasis::new(&v);
            let ones = vec![1.0; b.m()];
            let cfg = LassoConfig { lambda1: 0.2, max_epochs: 5000, ..Default::default() };
            let plain = solve(&b, &v, &cfg, None).unwrap();
            let weighted = solve_weighted(&b, &v, &ones, &cfg, None).unwrap();
            assert!(
                (plain.objective - weighted.objective).abs() < 1e-8,
                "objective mismatch: {} vs {}",
                plain.objective,
                weighted.objective
            );
            assert_eq!(plain.support(), weighted.support(), "seed {seed}");
        }
    }

    #[test]
    fn weighted_structured_matches_weighted_dense() {
        for seed in [31u64, 32, 33] {
            let v = random_values(40, seed);
            let b = VBasis::new(&v);
            let imp = random_weights(b.m(), seed + 100);
            let cfg = LassoConfig { lambda1: 0.3, max_epochs: 5000, ..Default::default() };
            let fast = solve_weighted(&b, &v, &imp, &cfg, None).unwrap();
            let slow = solve_dense_weighted(&b, &v, &imp, &cfg, None).unwrap();
            assert!(
                (fast.objective - slow.objective).abs() < 1e-8,
                "objective mismatch: {} vs {}",
                fast.objective,
                slow.objective
            );
            for (a, b2) in fast.alpha.iter().zip(&slow.alpha) {
                assert!((a - b2).abs() < 1e-6, "{a} vs {b2}");
            }
        }
    }

    #[test]
    fn weighted_beats_unweighted_on_weighted_objective() {
        // The weighted solver minimizes the weighted objective directly, so
        // at equal λ its weighted objective can't lose to evaluating the
        // unweighted solution under the same weights (up to CD tolerance).
        for seed in [41u64, 42, 43] {
            let v = random_values(64, seed);
            let b = VBasis::new(&v);
            let imp = random_weights(b.m(), seed + 200);
            let cfg = LassoConfig { lambda1: 0.5, max_epochs: 5000, ..Default::default() };
            let weighted = solve_weighted(&b, &v, &imp, &cfg, None).unwrap();
            let plain = solve(&b, &v, &cfg, None).unwrap();
            let plain_under_w = objective_weighted(&b, &v, &imp, &plain.alpha, &cfg);
            assert!(
                weighted.objective <= plain_under_w + 1e-7,
                "seed {seed}: weighted {} vs unweighted-under-W {}",
                weighted.objective,
                plain_under_w
            );
        }
    }

    #[test]
    fn weighted_objective_monotone_over_epochs() {
        let v = random_values(40, 44);
        let b = VBasis::new(&v);
        let imp = random_weights(b.m(), 244);
        let cfg = LassoConfig { lambda1: 0.4, ..Default::default() };
        let mut prev = f64::INFINITY;
        let mut alpha: Option<Vec<f64>> = None;
        for _ in 0..20 {
            let one = LassoConfig { max_epochs: 1, tol: 0.0, ..cfg.clone() };
            let sol = solve_weighted(&b, &v, &imp, &one, alpha.as_deref()).unwrap();
            assert!(sol.objective <= prev + 1e-9, "objective rose: {prev} -> {}", sol.objective);
            prev = sol.objective;
            alpha = Some(sol.alpha);
        }
    }

    #[test]
    fn zero_weight_suffix_zeroes_coordinates() {
        // Give the top two levels zero importance: every coordinate whose
        // suffix is all-zero must end at α = 0, and the weighted loss only
        // sees the prefix.
        let v = random_values(16, 45);
        let b = VBasis::new(&v);
        let m = b.m();
        let mut imp = vec![1.0; m];
        imp[m - 1] = 0.0;
        imp[m - 2] = 0.0;
        let cfg = LassoConfig { lambda1: 0.05, max_epochs: 5000, ..Default::default() };
        let sol = solve_weighted(&b, &v, &imp, &cfg, None).unwrap();
        assert_eq!(sol.alpha[m - 1], 0.0);
        assert_eq!(sol.alpha[m - 2], 0.0);
        assert!(sol.objective.is_finite());
        let dense = solve_dense_weighted(&b, &v, &imp, &cfg, None).unwrap();
        assert_eq!(dense.alpha[m - 1], 0.0);
        assert_eq!(dense.alpha[m - 2], 0.0);
    }

    #[test]
    fn weighted_rejects_bad_importance() {
        let b = VBasis::new(&[1.0, 2.0, 4.0]);
        let w = [1.0, 2.0, 4.0];
        let cfg = LassoConfig::default();
        assert!(solve_weighted(&b, &w, &[1.0, 1.0], &cfg, None).is_err());
        assert!(solve_weighted(&b, &w, &[1.0, -1.0, 1.0], &cfg, None).is_err());
        assert!(solve_weighted(&b, &w, &[1.0, f64::NAN, 1.0], &cfg, None).is_err());
        assert!(solve_dense_weighted(&b, &w, &[1.0, f64::INFINITY, 1.0], &cfg, None).is_err());
    }

    #[test]
    fn weighted_workspace_reuse_is_bitwise_identical() {
        let v = random_values(64, 46);
        let b = VBasis::new(&v);
        let imp = random_weights(b.m(), 246);
        let mut ws = Workspace::default();
        for lambda in [0.01, 0.1, 1.0] {
            let cfg = LassoConfig { lambda1: lambda, ..Default::default() };
            let fresh = solve_weighted(&b, &v, &imp, &cfg, None).unwrap();
            let reused = solve_ws_weighted(&b, &v, &imp, &cfg, None, &mut ws).unwrap();
            assert_eq!(fresh.alpha, reused.alpha, "λ={lambda}");
            assert_eq!(fresh.objective.to_bits(), reused.objective.to_bits(), "λ={lambda}");
        }
    }

    #[test]
    fn weighted_f32_lane_tracks_f64() {
        let v = random_values(48, 47);
        let mut v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        v32.dedup();
        let b = VBasis::new(&v);
        let b32 = VBasis::new(&v32);
        let imp = random_weights(b.m(), 247);
        let imp32: Vec<f32> = imp.iter().take(b32.m()).map(|&x| x as f32).collect();
        let cfg = LassoConfig { lambda1: 0.3, max_epochs: 5000, ..Default::default() };
        let s64 = solve_weighted(&b, &v, &imp[..b.m()], &cfg, None).unwrap();
        let s32 = solve_weighted(&b32, &v32, &imp32, &cfg, None).unwrap();
        let denom = s64.objective.abs().max(1e-9);
        assert!(
            (s32.objective - s64.objective).abs() / denom < 2e-3,
            "f32 weighted objective {} vs f64 {}",
            s32.objective,
            s64.objective
        );
    }

    #[test]
    fn sparsity_shares_values_in_reconstruction() {
        let v = random_values(32, 10);
        let b = VBasis::new(&v);
        let sol = solve(
            &b,
            &v,
            &LassoConfig { lambda1: 2.0, max_epochs: 5000, ..Default::default() },
            None,
        )
        .unwrap();
        let rec = b.apply(&sol.alpha);
        let distinct = crate::linalg::stats::distinct_count_exact(&rec);
        assert!(distinct <= sol.nnz() + 1, "distinct {} vs nnz {}", distinct, sol.nnz());
    }
}
