//! Codebook encoding — the engineering payoff the paper's introduction
//! motivates ("reduce the number of distinct values to the nearest 2^k to
//! reduce memory cost").
//!
//! A quantized vector is stored as a small codebook of levels plus one
//! index per element; this module measures and performs that encoding:
//! bits/value, total compressed size, index entropy (the Huffman-coding
//! bound Deep Compression exploits), and lossless round-tripping.
//!
//! [`Codebook`] is generic over the lane precision
//! ([`crate::linalg::scalar::Scalar`]): `Codebook<f64>` (the default) is
//! what the f64 surface ships, and `Codebook<f32>` ([`CodebookF32`]) lets
//! the single-precision lane stay narrow end to end — the request API
//! ([`crate::quant::api`]) never widens an f32 result before the caller
//! asks for it.

use crate::linalg::kernels;
use crate::linalg::scalar::Scalar;
use crate::quant::types::QuantOutputT;
use crate::{Error, Result};

/// Codebook + per-element indices: the compact representation of a
/// quantized vector (`k` shared levels, one `u32` index per element).
#[derive(Debug, Clone, PartialEq)]
pub struct Codebook<T: Scalar = f64> {
    /// The distinct levels, sorted ascending.
    pub levels: Vec<T>,
    /// Index into `levels` per original element.
    pub indices: Vec<u32>,
}

/// Single-precision codebook (the f32 lane's compact output).
pub type CodebookF32 = Codebook<f32>;

impl<T: Scalar> Codebook<T> {
    /// Build from a quantized vector.
    ///
    /// Matching is **exact** (bitwise value identity up to `-0.0 == 0.0`),
    /// with no tolerance: every element must equal one of the distinct
    /// values of the input, which holds by construction for any quantizer
    /// output. Values that are merely close to a level are *not* snapped —
    /// callers wanting tolerant re-encoding should quantize again instead.
    ///
    /// Errors on empty input and on NaN (a NaN can be neither sorted into
    /// the level table nor looked up in it).
    pub fn from_values(values: &[T]) -> Result<Codebook<T>> {
        if values.is_empty() {
            return Err(Error::InvalidInput("codebook: empty input".into()));
        }
        // NaN would panic the sort / lookup comparators below; reject it
        // up front (NaN is the only value unordered against itself).
        if values.iter().any(|v| v.partial_cmp(v).is_none()) {
            return Err(Error::InvalidInput("codebook: NaN in input".into()));
        }
        let mut levels: Vec<T> = values.to_vec();
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup();
        if levels.len() > u32::MAX as usize {
            return Err(Error::InvalidInput("codebook: too many levels".into()));
        }
        let indices = values
            .iter()
            .map(|v| {
                levels
                    .binary_search_by(|l| l.partial_cmp(v).unwrap())
                    .map(|i| i as u32)
                    .map_err(|_| Error::InvalidInput("codebook: value not a level".into()))
            })
            .collect::<Result<Vec<u32>>>()?;
        Ok(Codebook { levels, indices })
    }

    /// Build from a quantization output (either lane).
    pub fn from_output(out: &QuantOutputT<T>) -> Result<Codebook<T>> {
        Self::from_values(&out.values)
    }

    /// Number of levels.
    pub fn k(&self) -> usize {
        self.levels.len()
    }

    /// Number of encoded elements.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// True when no elements are encoded (cannot happen via
    /// [`Codebook::from_values`]).
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Fixed-width bits per index (`⌈log₂ k⌉`, minimum 1).
    pub fn bits_per_index(&self) -> u32 {
        kernels::bits_per_index_for(self.k())
    }

    /// Total compressed bytes: fixed-width indices at the packed width
    /// ([`kernels::packed_bits_for`] — a single-level codebook pays zero
    /// index bits, since every index is 0) + the codebook stored as f32
    /// (the Deep-Compression wire convention, on both lanes).
    pub fn compressed_bytes(&self) -> usize {
        let idx_bits = self.indices.len() * kernels::packed_bits_for(self.k()) as usize;
        idx_bits.div_ceil(8) + self.k() * 4
    }

    /// Compression ratio vs dense f32 storage.
    pub fn compression_ratio_f32(&self) -> f64 {
        (self.indices.len() * 4) as f64 / self.compressed_bytes() as f64
    }

    /// Shannon entropy of the index stream (bits/index) — the Huffman
    /// bound on variable-length coding.
    pub fn index_entropy(&self) -> f64 {
        let counts = kernels::gather_counts(&self.indices, self.k());
        let n = self.indices.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }

    /// Reconstruct the full vector (the lazy-materialization primitive of
    /// the request API).
    pub fn decode(&self) -> Vec<T> {
        kernels::gather_levels(&self.levels, &self.indices)
    }

    /// Pack the index plane to `⌈log₂ k⌉` bits per index — the opt-in
    /// compact storage ([`PackedCodebook`]). Lossless:
    /// `self.pack().to_codebook() == *self`.
    pub fn pack(&self) -> PackedCodebook<T> {
        PackedCodebook {
            levels: self.levels.clone(),
            indices: PackedIndices::pack(&self.indices, self.k()),
        }
    }
}

impl Codebook<f32> {
    /// Widen to the f64 codebook type (for f64-surface consumers; the
    /// indices are shared unchanged).
    pub fn widen(&self) -> Codebook<f64> {
        Codebook {
            levels: self.levels.iter().map(|&x| f64::from(x)).collect(),
            indices: self.indices.clone(),
        }
    }
}

/// A tightly bit-packed index plane: `len` indices of `bits` bits each
/// (`bits = ⌈log₂ k⌉`, 0..=32 — a single-level plane is the degenerate
/// `bits = 0` case storing no words at all), laid out LSB-first in
/// little-endian `u64` words, straddling word boundaries — index `i`
/// occupies bits `[i·bits, (i+1)·bits)` of the plane. The storage
/// actually *is* the packed width, so compression accounting over it is
/// honest rather than hypothetical
/// (`CompressionStats::bits_per_idx_stored` equals
/// `bits_per_idx_packed`). Packing/unpacking run on the
/// [`crate::linalg::kernels`] bit-plane kernels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedIndices {
    words: Vec<u64>,
    bits: u32,
    len: usize,
}

impl PackedIndices {
    /// Pack an index stream for a `k`-level codebook
    /// (`bits = ⌈log₂ k⌉`; `k ≤ 1` packs to the zero-bit degenerate
    /// plane). All indices must be `< k`, which holds by construction for
    /// any [`Codebook`]; wider values would be truncated by the bit mask,
    /// so this debug-asserts the range.
    pub fn pack(indices: &[u32], k: usize) -> PackedIndices {
        let bits = kernels::packed_bits_for(k);
        debug_assert!(
            indices.iter().all(|&i| (i as usize) < k.max(1)),
            "PackedIndices::pack: index out of range for k={k}"
        );
        PackedIndices { words: kernels::pack_indices(indices, bits), bits, len: indices.len() }
    }

    /// Rebuild a plane from raw parts (the jsonio decode path), validating
    /// shape: `bits ∈ 0..=32` (0 is the single-level degenerate plane) and
    /// the word count exactly matches `len` indices of `bits` bits.
    pub fn from_raw(words: Vec<u64>, bits: u32, len: usize) -> Result<PackedIndices> {
        if bits > 32 {
            return Err(Error::InvalidInput(format!(
                "packed indices: bits must be in 0..=32, got {bits}"
            )));
        }
        let want_words = (len * bits as usize).div_ceil(64);
        if words.len() != want_words {
            return Err(Error::InvalidInput(format!(
                "packed indices: {} words, expected {want_words} for {len} × {bits}-bit indices",
                words.len()
            )));
        }
        Ok(PackedIndices { words, bits, len })
    }

    /// Unpack back to the dense `u32` stream. Exact inverse of
    /// [`PackedIndices::pack`].
    pub fn unpack(&self) -> Vec<u32> {
        kernels::unpack_indices(&self.words, self.bits, self.len)
    }

    /// The index at position `i` (random access without unpacking). On a
    /// zero-bit plane every position reads 0.
    pub fn get(&self, i: usize) -> u32 {
        assert!(i < self.len, "PackedIndices::get: {i} out of range (len {})", self.len);
        if self.bits == 0 {
            return 0;
        }
        let bits = self.bits as usize;
        let bitpos = i * bits;
        let (w, off) = (bitpos / 64, bitpos % 64);
        let mut v = self.words[w] >> off;
        if off + bits > 64 {
            v |= self.words[w + 1] << (64 - off);
        }
        (v & ((1u64 << bits) - 1)) as u32
    }

    /// Bits per index.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Number of packed indices.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no indices are packed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exact packed payload size in bytes (`⌈len·bits / 8⌉` — the final
    /// word's slack is not counted).
    pub fn packed_bytes(&self) -> usize {
        (self.len * self.bits as usize).div_ceil(8)
    }

    /// The raw little-endian word plane (the jsonio encode path).
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

/// [`Codebook`] with the index plane stored bit-packed — the opt-in
/// compact storage the compression accounting reports on honestly.
/// Construct via [`Codebook::pack`] or [`PackedCodebook::from_codebook`];
/// round-trips losslessly through [`PackedCodebook::to_codebook`] and
/// through jsonio (`jsonio::packed_codebook_to_json`).
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodebook<T: Scalar = f64> {
    /// The distinct levels, sorted ascending (same table as [`Codebook`]).
    pub levels: Vec<T>,
    /// The bit-packed per-element index plane.
    pub indices: PackedIndices,
}

impl<T: Scalar> PackedCodebook<T> {
    /// Pack a dense codebook (lossless).
    pub fn from_codebook(cb: &Codebook<T>) -> PackedCodebook<T> {
        cb.pack()
    }

    /// Unpack to the dense form. Exact inverse of [`Codebook::pack`].
    pub fn to_codebook(&self) -> Codebook<T> {
        Codebook { levels: self.levels.clone(), indices: self.indices.unpack() }
    }

    /// Number of levels.
    pub fn k(&self) -> usize {
        self.levels.len()
    }

    /// Number of encoded elements.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// True when no elements are encoded.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Bits per index as stored (the packed width, `⌈log₂ k⌉`).
    pub fn bits_per_index(&self) -> u32 {
        self.indices.bits()
    }

    /// Reconstruct the full vector directly from the packed plane.
    pub fn decode(&self) -> Vec<T> {
        kernels::gather_levels(&self.levels, &self.indices.unpack())
    }

    /// Compression accounting. Identical to the dense codebook's stats
    /// except `bits_per_idx_stored`, which reflects the packed in-memory
    /// width instead of 32.
    pub fn stats(&self, levels_requested: usize) -> CompressionStats {
        let mut s = self.to_codebook().stats(levels_requested);
        s.bits_per_idx_stored = self.indices.bits();
        s
    }
}

/// Compression accounting for one quantized payload — the numbers that
/// decide whether a bit-width reduction actually won ("Towards the Limit
/// of Network Quantization", Choi et al.: the entropy/bits-per-value view
/// is the metric, not the level count alone).
///
/// Produced by [`Codebook::stats`] and surfaced on every response item
/// ([`crate::quant::api::QuantItem::compression`] /
/// [`crate::quant::api::Item::compression`]) and on coordinator results
/// ([`crate::coordinator::job::JobOutput::compression`]).
///
/// ```
/// use sqlsq::quant::{QuantMethod, QuantRequest, Quantizer};
///
/// let data: Vec<f64> = (0..1000).map(|i| ((i % 17) as f64).sin()).collect();
/// let req = QuantRequest::vector(data).method(QuantMethod::KMeans).target_count(8);
/// let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
/// let stats = item.compression(8);
/// assert!(stats.levels_achieved <= stats.levels_requested);
/// assert!(stats.bits_per_value < 64.0, "compact beats dense f64");
/// assert!(stats.index_entropy <= stats.bits_per_index as f64 + 1e-9);
/// assert!(stats.entropy_coded_bytes <= stats.compact_bytes,
///         "the Shannon bound can only undercut fixed-width packing");
/// assert!(stats.byte_ratio > 1.0, "{} compact vs {} dense bytes",
///         stats.compact_bytes, stats.dense_bytes);
/// // Dense codebooks store u32 indices; the packed width is what the
/// // compact wire form pays (and what `bits_per_index` has always meant).
/// assert_eq!(stats.bits_per_idx_stored, 32);
/// assert_eq!(stats.bits_per_idx_packed, stats.bits_per_index);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionStats {
    /// Number of encoded elements `n`.
    pub n: usize,
    /// Distinct levels the quantizer actually produced (`k`).
    pub levels_achieved: usize,
    /// Levels the request asked for (`QuantOptions::target_values`; for
    /// λ-driven methods this is the standing option, not a constraint).
    pub levels_requested: usize,
    /// Fixed-width bits per index, `⌈log₂ k⌉` (minimum 1 — the dense-form
    /// convention). Equal to [`CompressionStats::bits_per_idx_packed`]
    /// for every multi-level codebook; a single-level (`k = 1`) codebook
    /// keeps the 1-bit minimum here while the packed accounting honestly
    /// reports 0. Kept under its historical name because the jsonio wire
    /// spec is normative.
    pub bits_per_index: u32,
    /// Bits per index as actually stored by the representation the stats
    /// were taken from: 32 for a dense [`Codebook`] (`Vec<u32>` plane),
    /// `⌈log₂ k⌉` (0 at `k = 1`) for a [`PackedCodebook`].
    pub bits_per_idx_stored: u32,
    /// Bits per index after ⌈log₂ k⌉-bit packing — what the compact wire
    /// form pays per index regardless of in-memory storage. Zero for a
    /// single-level codebook: a constant group needs no index bits
    /// ([`crate::linalg::kernels::packed_bits_for`]).
    pub bits_per_idx_packed: u32,
    /// Total compact bits (indices + codebook) amortized per element —
    /// the headline "bits/value" number.
    pub bits_per_value: f64,
    /// Shannon entropy of the index stream (bits/index): the Huffman
    /// bound a variable-length coder could still reach below
    /// `bits_per_index`.
    pub index_entropy: f64,
    /// Achievable entropy-coded size in bytes: `⌈n·H/8⌉` for the index
    /// stream (first-order Shannon bound, the coded-size model of
    /// "Towards the Limit of Network Quantization") plus the f32 codebook.
    /// Always ≤ `compact_bytes` — the gap is what a variable-length coder
    /// would still recover over ⌈log₂ k⌉-bit packing. Sums under
    /// [`CompressionStats::aggregate`] and per-plane under
    /// [`CompressionStats::stack`].
    pub entropy_coded_bytes: usize,
    /// Compact wire bytes: fixed-width indices + the codebook stored as
    /// f32 (the Deep-Compression convention, on both lanes).
    pub compact_bytes: usize,
    /// Dense baseline bytes: `n` elements at the lane's element width
    /// (8 for f64 payloads, 4 for f32).
    pub dense_bytes: usize,
    /// `dense_bytes / compact_bytes` — the compact-vs-dense ratio.
    pub byte_ratio: f64,
}

impl CompressionStats {
    /// Aggregate accounting over several payloads (a batch, a sweep, a
    /// serve run). Byte and element counts sum; `bits_per_value` and
    /// `byte_ratio` are recomputed from the totals; `index_entropy` is
    /// the element-weighted mean; the level counts and `bits_per_index`
    /// take the per-item maximum (for a homogeneous batch these are just
    /// the per-item values). Returns `None` on an empty iterator.
    pub fn aggregate<'a, I>(items: I) -> Option<CompressionStats>
    where
        I: IntoIterator<Item = &'a CompressionStats>,
    {
        let mut n = 0usize;
        let mut compact = 0usize;
        let mut dense = 0usize;
        let mut entropy_coded = 0usize;
        let mut entropy_weighted = 0.0f64;
        let mut levels_achieved = 0usize;
        let mut levels_requested = 0usize;
        let mut bits_per_index = 0u32;
        let mut bits_per_idx_stored = 0u32;
        let mut bits_per_idx_packed = 0u32;
        let mut any = false;
        for s in items {
            any = true;
            n += s.n;
            compact += s.compact_bytes;
            dense += s.dense_bytes;
            entropy_coded += s.entropy_coded_bytes;
            entropy_weighted += s.index_entropy * s.n as f64;
            levels_achieved = levels_achieved.max(s.levels_achieved);
            levels_requested = levels_requested.max(s.levels_requested);
            bits_per_index = bits_per_index.max(s.bits_per_index);
            bits_per_idx_stored = bits_per_idx_stored.max(s.bits_per_idx_stored);
            bits_per_idx_packed = bits_per_idx_packed.max(s.bits_per_idx_packed);
        }
        if !any {
            return None;
        }
        Some(CompressionStats {
            n,
            levels_achieved,
            levels_requested,
            bits_per_index,
            bits_per_idx_stored,
            bits_per_idx_packed,
            bits_per_value: if n > 0 { compact as f64 * 8.0 / n as f64 } else { 0.0 },
            index_entropy: if n > 0 { entropy_weighted / n as f64 } else { 0.0 },
            entropy_coded_bytes: entropy_coded,
            compact_bytes: compact,
            dense_bytes: dense,
            byte_ratio: if compact > 0 { dense as f64 / compact as f64 } else { 0.0 },
        })
    }

    /// Stack the accounting of a residual-cascade plane on top of `self`.
    ///
    /// A cascade stores several index planes over the **same** `n`
    /// elements, so [`CompressionStats::aggregate`]'s rules (element
    /// counts sum, per-index bit widths take the max — right for parallel
    /// payloads like a batch) would misreport it: an element of a
    /// 4-bit + 2-bit cascade pays 6 index bits, not 4, and there is only
    /// one dense baseline, not two. Here `n` and `dense_bytes` stay fixed,
    /// the per-index bit widths (`bits_per_index`, stored, packed) **add**,
    /// compact bytes add, `bits_per_value`/`byte_ratio` are recomputed
    /// from the stacked totals, `index_entropy` adds (the planes' joint
    /// entropy is at most the sum), and the level counts multiply
    /// (saturating — an L-plane cascade resolves up to `Π kₗ` distinct
    /// reconstruction values). Panics if the planes disagree on `n`.
    pub fn stack(&self, next: &CompressionStats) -> CompressionStats {
        assert_eq!(self.n, next.n, "stack: cascade planes must cover the same elements");
        let compact = self.compact_bytes + next.compact_bytes;
        CompressionStats {
            n: self.n,
            levels_achieved: self.levels_achieved.saturating_mul(next.levels_achieved),
            levels_requested: self.levels_requested.saturating_mul(next.levels_requested),
            bits_per_index: self.bits_per_index + next.bits_per_index,
            bits_per_idx_stored: self.bits_per_idx_stored + next.bits_per_idx_stored,
            bits_per_idx_packed: self.bits_per_idx_packed + next.bits_per_idx_packed,
            bits_per_value: if self.n > 0 { compact as f64 * 8.0 / self.n as f64 } else { 0.0 },
            index_entropy: self.index_entropy + next.index_entropy,
            // Each plane codes its own index stream and ships its own
            // codebook, so the achievable coded sizes add.
            entropy_coded_bytes: self.entropy_coded_bytes + next.entropy_coded_bytes,
            compact_bytes: compact,
            dense_bytes: self.dense_bytes,
            byte_ratio: if compact > 0 { self.dense_bytes as f64 / compact as f64 } else { 0.0 },
        }
    }

    /// One-line human summary (CLI, serve reports).
    pub fn summary(&self) -> String {
        format!(
            "levels={}/{} bits/value={:.3} entropy={:.3} bits/idx \
             idx-bits={}→{} (stored→packed) compact={}B coded≤{}B dense={}B ratio={:.2}x",
            self.levels_achieved,
            self.levels_requested,
            self.bits_per_value,
            self.index_entropy,
            self.bits_per_idx_stored,
            self.bits_per_idx_packed,
            self.compact_bytes,
            self.entropy_coded_bytes,
            self.dense_bytes,
            self.byte_ratio
        )
    }
}

impl<T: Scalar> Codebook<T> {
    /// Compression accounting for this codebook. `levels_requested` is
    /// the request's target level count (achieved-vs-requested is part of
    /// the accounting); the dense baseline is `n` elements at this lane's
    /// element width (`size_of::<T>()`).
    pub fn stats(&self, levels_requested: usize) -> CompressionStats {
        let compact = self.compressed_bytes();
        let dense = self.len() * std::mem::size_of::<T>();
        let entropy = self.index_entropy();
        // Achievable coded bytes: Shannon bound on the index stream plus
        // the same f32 codebook the compact form ships.
        let entropy_coded =
            (self.len() as f64 * entropy / 8.0).ceil() as usize + self.k() * 4;
        CompressionStats {
            n: self.len(),
            levels_achieved: self.k(),
            levels_requested,
            bits_per_index: self.bits_per_index(),
            // The dense codebook stores its plane as Vec<u32>; only the
            // packed representation actually pays ⌈log₂ k⌉ — and a
            // single-level codebook pays nothing at all.
            bits_per_idx_stored: 32,
            bits_per_idx_packed: kernels::packed_bits_for(self.k()),
            bits_per_value: if self.is_empty() {
                0.0
            } else {
                compact as f64 * 8.0 / self.len() as f64
            },
            index_entropy: entropy,
            entropy_coded_bytes: entropy_coded,
            compact_bytes: compact,
            dense_bytes: dense,
            byte_ratio: if compact > 0 { dense as f64 / compact as f64 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, QuantMethod, QuantOptions};

    #[test]
    fn roundtrip_exact() {
        let values = vec![0.5, 0.5, 1.0, -2.0, 1.0, 0.5];
        let cb = Codebook::from_values(&values).unwrap();
        assert_eq!(cb.k(), 3);
        assert_eq!(cb.len(), values.len());
        assert!(!cb.is_empty());
        assert_eq!(cb.decode(), values);
        assert_eq!(cb.levels, vec![-2.0, 0.5, 1.0]);
    }

    #[test]
    fn f32_roundtrip_and_widen() {
        let values = vec![0.5f32, 0.5, 1.0, -2.0, 1.0, 0.5];
        let cb = CodebookF32::from_values(&values).unwrap();
        assert_eq!(cb.k(), 3);
        assert_eq!(cb.decode(), values);
        let wide = cb.widen();
        assert_eq!(wide.levels, vec![-2.0f64, 0.5, 1.0]);
        assert_eq!(wide.indices, cb.indices);
        assert_eq!(
            wide.decode(),
            values.iter().map(|&x| f64::from(x)).collect::<Vec<f64>>()
        );
    }

    #[test]
    fn bits_per_index_steps() {
        let mk = |k: usize| {
            let values: Vec<f64> = (0..k).map(|i| i as f64).collect();
            Codebook::from_values(&values).unwrap().bits_per_index()
        };
        assert_eq!(mk(1), 1);
        assert_eq!(mk(2), 1);
        assert_eq!(mk(3), 2);
        assert_eq!(mk(4), 2);
        assert_eq!(mk(5), 3);
        assert_eq!(mk(16), 4);
        assert_eq!(mk(17), 5);
    }

    #[test]
    fn compression_ratio_grows_with_fewer_levels() {
        let n = 10_000;
        let mk = |k: usize| {
            let values: Vec<f64> = (0..n).map(|i| (i % k) as f64).collect();
            Codebook::from_values(&values).unwrap().compression_ratio_f32()
        };
        assert!(mk(4) > mk(64));
        assert!(mk(4) > 10.0, "4 levels over 10k values should beat 10x");
    }

    #[test]
    fn entropy_bounds() {
        // Uniform over 4 levels → exactly 2 bits.
        let values: Vec<f64> = (0..1000).map(|i| (i % 4) as f64).collect();
        let cb = Codebook::from_values(&values).unwrap();
        assert!((cb.index_entropy() - 2.0).abs() < 1e-9);
        // Heavily skewed → far below the fixed-width 2 bits.
        let mut skewed = vec![0.0; 990];
        skewed.extend([1.0, 2.0, 3.0].iter().cycle().take(10).cloned());
        let cb2 = Codebook::from_values(&skewed).unwrap();
        assert!(cb2.index_entropy() < 0.2, "entropy {}", cb2.index_entropy());
    }

    #[test]
    fn end_to_end_with_quantizer() {
        let data: Vec<f64> = (0..500).map(|i| ((i % 17) as f64).sin()).collect();
        let out = quant::quantize(
            &data,
            QuantMethod::KMeans,
            &QuantOptions { target_values: 8, ..Default::default() },
        )
        .unwrap();
        let cb = Codebook::from_output(&out).unwrap();
        assert!(cb.k() <= 8);
        assert_eq!(cb.decode(), out.values);
        assert!(cb.compression_ratio_f32() > 5.0);
    }

    #[test]
    fn rejects_empty() {
        assert!(Codebook::<f64>::from_values(&[]).is_err());
    }

    #[test]
    fn nan_input_errors_instead_of_panicking() {
        // Regression: `partial_cmp(..).unwrap()` used to abort the process
        // on NaN; it must surface as Error::InvalidInput on both lanes.
        let r64 = Codebook::from_values(&[1.0f64, f64::NAN, 2.0]);
        match r64 {
            Err(Error::InvalidInput(msg)) => assert!(msg.contains("NaN"), "{msg}"),
            other => panic!("expected InvalidInput, got {other:?}"),
        }
        assert!(Codebook::from_values(&[f32::NAN]).is_err());
    }

    #[test]
    fn stats_match_manual_computation() {
        let n = 1000usize;
        let values: Vec<f64> = (0..n).map(|i| (i % 4) as f64).collect();
        let cb = Codebook::from_values(&values).unwrap();
        let s = cb.stats(4);
        assert_eq!(s.n, n);
        assert_eq!(s.levels_achieved, 4);
        assert_eq!(s.levels_requested, 4);
        assert_eq!(s.bits_per_index, 2);
        // 2 bits × 1000 indices = 250 bytes + 4 levels × 4 bytes.
        assert_eq!(s.compact_bytes, 250 + 16);
        assert_eq!(s.dense_bytes, n * 8);
        assert!((s.bits_per_value - (266.0 * 8.0 / 1000.0)).abs() < 1e-12);
        assert!((s.index_entropy - 2.0).abs() < 1e-9, "uniform 4 levels = 2 bits");
        assert!((s.byte_ratio - 8000.0 / 266.0).abs() < 1e-12);
        // Uniform indices: the entropy bound equals fixed-width packing,
        // ⌈1000·2/8⌉ + 16 codebook bytes.
        assert_eq!(s.entropy_coded_bytes, 250 + 16);
        assert_eq!(s.entropy_coded_bytes, s.compact_bytes);
    }

    #[test]
    fn entropy_coded_bytes_undercut_packing_on_skew() {
        // 990 of one level, 10 spread over three more: H ≈ 0.1 bits, far
        // under the 2-bit packed width — the coded-size model shows the
        // win a Huffman pass would deliver.
        let mut skewed = vec![0.0f64; 990];
        skewed.extend([1.0, 2.0, 3.0].iter().cycle().take(10).cloned());
        let s = Codebook::from_values(&skewed).unwrap().stats(4);
        assert!(s.entropy_coded_bytes < s.compact_bytes);
        let idx_bytes = s.entropy_coded_bytes - 4 * 4;
        assert!(
            idx_bytes <= 20,
            "≈0.1 bits × 1000 elements should code in ≲15 bytes, got {idx_bytes}"
        );
        // Aggregate sums the coded sizes; stack adds them per plane.
        let agg = CompressionStats::aggregate([&s, &s]).unwrap();
        assert_eq!(agg.entropy_coded_bytes, 2 * s.entropy_coded_bytes);
        let stacked = s.stack(&s);
        assert_eq!(stacked.entropy_coded_bytes, 2 * s.entropy_coded_bytes);
        assert!(s.summary().contains("coded≤"), "{}", s.summary());
    }

    #[test]
    fn stack_adds_cascade_bits_where_aggregate_would_max() {
        // Regression (cascade accounting): two planes over the SAME 1000
        // elements — a 4-level base and a 2-level residual. The honest
        // per-element index cost is 2+1 = 3 packed bits; `aggregate`'s
        // parallel-payload rules would report max(2,1) = 2 bits over
        // 2n elements and double the dense baseline.
        let n = 1000usize;
        let base: Vec<f64> = (0..n).map(|i| (i % 4) as f64).collect();
        let resid: Vec<f64> = (0..n).map(|i| (i % 2) as f64 * 0.1).collect();
        let s0 = Codebook::from_values(&base).unwrap().pack().stats(4);
        let s1 = Codebook::from_values(&resid).unwrap().pack().stats(2);
        let stacked = s0.stack(&s1);
        assert_eq!(stacked.n, n);
        assert_eq!(stacked.bits_per_idx_packed, 3);
        assert_eq!(stacked.bits_per_idx_stored, 3, "packed planes store the packed width");
        assert_eq!(stacked.bits_per_index, 3);
        assert_eq!(stacked.levels_achieved, 8, "4 base × 2 residual reconstructions");
        assert_eq!(stacked.compact_bytes, s0.compact_bytes + s1.compact_bytes);
        assert_eq!(stacked.dense_bytes, n * 8, "one dense baseline, not two");
        assert!(
            (stacked.bits_per_value - stacked.compact_bytes as f64 * 8.0 / n as f64).abs() < 1e-12
        );
        let agg = CompressionStats::aggregate([&s0, &s1]).unwrap();
        assert_eq!(agg.bits_per_idx_packed, 2, "aggregate maxes — wrong for a cascade");
        assert_eq!(agg.n, 2 * n);
    }

    #[test]
    fn stats_dense_baseline_is_lane_width() {
        let v64: Vec<f64> = (0..100).map(|i| (i % 3) as f64).collect();
        let v32: Vec<f32> = v64.iter().map(|&x| x as f32).collect();
        let s64 = Codebook::from_values(&v64).unwrap().stats(3);
        let s32 = Codebook::from_values(&v32).unwrap().stats(3);
        assert_eq!(s64.dense_bytes, 800);
        assert_eq!(s32.dense_bytes, 400);
        // Compact side is identical (f32 codebook convention on both lanes).
        assert_eq!(s64.compact_bytes, s32.compact_bytes);
        assert!(s64.byte_ratio > s32.byte_ratio);
    }

    #[test]
    fn stats_aggregate_sums_bytes_and_weights_entropy() {
        let a = Codebook::from_values(&(0..400).map(|i| (i % 2) as f64).collect::<Vec<_>>())
            .unwrap()
            .stats(2);
        let b = Codebook::from_values(&(0..100).map(|i| (i % 8) as f64).collect::<Vec<_>>())
            .unwrap()
            .stats(8);
        let agg = CompressionStats::aggregate([&a, &b]).unwrap();
        assert_eq!(agg.n, 500);
        assert_eq!(agg.compact_bytes, a.compact_bytes + b.compact_bytes);
        assert_eq!(agg.dense_bytes, a.dense_bytes + b.dense_bytes);
        assert_eq!(agg.levels_achieved, 8);
        assert_eq!(agg.bits_per_index, 3);
        let want_entropy = (a.index_entropy * 400.0 + b.index_entropy * 100.0) / 500.0;
        assert!((agg.index_entropy - want_entropy).abs() < 1e-12);
        assert!(
            (agg.bits_per_value - agg.compact_bytes as f64 * 8.0 / 500.0).abs() < 1e-12
        );
        assert!(CompressionStats::aggregate(std::iter::empty()).is_none());
        assert!(!agg.summary().is_empty());
    }

    #[test]
    fn negative_zero_matches_positive_zero_level() {
        let cb = Codebook::from_values(&[-0.0f64, 0.0, 1.0]).unwrap();
        assert_eq!(cb.k(), 2, "-0.0 and 0.0 share one level");
        assert_eq!(cb.decode().len(), 3);
    }

    #[test]
    fn pack_roundtrips_losslessly() {
        for k in [1usize, 2, 3, 5, 17, 300] {
            let values: Vec<f64> = (0..1000).map(|i| ((i * 7) % k) as f64).collect();
            let cb = Codebook::from_values(&values).unwrap();
            let packed = cb.pack();
            // The packed width drops to 0 for the single-level plane; the
            // dense form keeps its historical 1-bit minimum.
            assert_eq!(packed.bits_per_index(), kernels::packed_bits_for(k), "k={k}");
            assert_eq!(packed.to_codebook(), cb, "k={k}");
            assert_eq!(packed.decode(), cb.decode(), "k={k}");
            assert_eq!(PackedCodebook::from_codebook(&cb), packed);
            assert_eq!(packed.k(), cb.k());
            assert_eq!(packed.len(), cb.len());
            assert!(!packed.is_empty());
        }
    }

    #[test]
    fn constant_group_reports_zero_packed_index_bits() {
        // Regression: k=1 used to report 1 bit/idx packed and pay index
        // bytes it never needs — a constant group's compact payload is the
        // level table alone.
        let values = vec![0.25f64; 512];
        let cb = Codebook::from_values(&values).unwrap();
        assert_eq!(cb.k(), 1);
        assert_eq!(cb.bits_per_index(), 1, "dense-form minimum is unchanged");
        assert_eq!(cb.compressed_bytes(), 4, "one f32 level, zero index bytes");
        let s = cb.stats(1);
        assert_eq!(s.bits_per_idx_packed, 0);
        assert_eq!(s.bits_per_index, 1);
        assert_eq!(s.compact_bytes, 4);
        assert!((s.bits_per_value - 4.0 * 8.0 / 512.0).abs() < 1e-12);
        // The packed form stores exactly that: no words, all-zero reads.
        let packed = cb.pack();
        assert_eq!(packed.bits_per_index(), 0);
        assert_eq!(packed.indices.words(), &[] as &[u64]);
        assert_eq!(packed.indices.packed_bytes(), 0);
        assert_eq!(packed.indices.get(100), 0);
        assert_eq!(packed.decode(), values);
        let ps = packed.stats(1);
        assert_eq!(ps.bits_per_idx_stored, 0);
        assert_eq!(ps.bits_per_idx_packed, 0);
        assert_eq!(ps.compact_bytes, 4);
    }

    #[test]
    fn packed_indices_random_access_and_raw_parts() {
        let idx: Vec<u32> = (0..97).map(|i| (i * 13) % 300).collect();
        let p = PackedIndices::pack(&idx, 300); // 9 bits — straddles words
        assert_eq!(p.bits(), 9);
        assert_eq!(p.len(), idx.len());
        assert_eq!(p.packed_bytes(), (97 * 9usize).div_ceil(8));
        for (i, &want) in idx.iter().enumerate() {
            assert_eq!(p.get(i), want, "get({i})");
        }
        let rebuilt =
            PackedIndices::from_raw(p.words().to_vec(), p.bits(), p.len()).unwrap();
        assert_eq!(rebuilt, p);
        assert_eq!(rebuilt.unpack(), idx);
        // Shape validation on the raw path.
        assert!(PackedIndices::from_raw(vec![0; 3], 9, 97).is_err());
        assert!(PackedIndices::from_raw(vec![], 33, 0).is_err());
        // The zero-bit degenerate plane round-trips through raw parts:
        // no words for any length, every index 0.
        let zero = PackedIndices::from_raw(vec![], 0, 42).unwrap();
        assert_eq!(zero.unpack(), vec![0u32; 42]);
        assert_eq!(zero.packed_bytes(), 0);
        assert!(PackedIndices::from_raw(vec![0], 0, 42).is_err(), "0-bit plane has no words");
    }

    #[test]
    fn packed_stats_report_stored_width_honestly() {
        let values: Vec<f64> = (0..1000).map(|i| (i % 4) as f64).collect();
        let cb = Codebook::from_values(&values).unwrap();
        let dense = cb.stats(4);
        let packed = cb.pack().stats(4);
        assert_eq!(dense.bits_per_idx_stored, 32);
        assert_eq!(dense.bits_per_idx_packed, 2);
        assert_eq!(dense.bits_per_index, dense.bits_per_idx_packed);
        assert_eq!(packed.bits_per_idx_stored, 2);
        assert_eq!(packed.bits_per_idx_packed, 2);
        // Everything except the stored width is identical — the wire form
        // was already packed.
        assert_eq!(packed.compact_bytes, dense.compact_bytes);
        assert_eq!(packed.bits_per_value, dense.bits_per_value);
        let line = packed.summary();
        assert!(line.contains("idx-bits=2→2"), "{line}");
        assert!(dense.summary().contains("idx-bits=32→2"), "{}", dense.summary());
    }

    #[test]
    fn packed_empty_plane() {
        let p = PackedIndices::pack(&[], 7);
        assert!(p.is_empty());
        assert_eq!(p.packed_bytes(), 0);
        assert_eq!(p.unpack(), Vec::<u32>::new());
    }
}
