//! Codebook encoding utilities — the engineering payoff the paper's
//! introduction motivates ("reduce the number of distinct values to the
//! nearest 2^k to reduce memory cost").
//!
//! A quantized vector is stored as a small codebook of levels plus one
//! index per element; this module measures and performs that encoding:
//! bits/value, total compressed size, index entropy (the Huffman-coding
//! bound Deep Compression exploits), and lossless round-tripping.

use crate::quant::QuantOutput;
use crate::{Error, Result};

/// Codebook + per-element indices.
#[derive(Debug, Clone, PartialEq)]
pub struct Codebook {
    /// The distinct levels, sorted ascending.
    pub levels: Vec<f64>,
    /// Index into `levels` per original element.
    pub indices: Vec<u32>,
}

impl Codebook {
    /// Build from a quantized vector (exact value matching).
    pub fn from_values(values: &[f64]) -> Result<Codebook> {
        if values.is_empty() {
            return Err(Error::InvalidInput("codebook: empty input".into()));
        }
        let mut levels: Vec<f64> = values.to_vec();
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup();
        if levels.len() > u32::MAX as usize {
            return Err(Error::InvalidInput("codebook: too many levels".into()));
        }
        let indices = values
            .iter()
            .map(|v| {
                levels
                    .binary_search_by(|l| l.partial_cmp(v).unwrap())
                    .map(|i| i as u32)
                    .map_err(|_| Error::InvalidInput("codebook: value not a level".into()))
            })
            .collect::<Result<Vec<u32>>>()?;
        Ok(Codebook { levels, indices })
    }

    /// Build from a [`QuantOutput`].
    pub fn from_output(out: &QuantOutput) -> Result<Codebook> {
        Self::from_values(&out.values)
    }

    /// Number of levels.
    pub fn k(&self) -> usize {
        self.levels.len()
    }

    /// Fixed-width bits per index (`⌈log₂ k⌉`, minimum 1).
    pub fn bits_per_index(&self) -> u32 {
        (usize::BITS - (self.k() - 1).leading_zeros()).max(1)
    }

    /// Total compressed bytes: fixed-width indices + f32 codebook.
    pub fn compressed_bytes(&self) -> usize {
        let idx_bits = self.indices.len() * self.bits_per_index() as usize;
        idx_bits.div_ceil(8) + self.k() * 4
    }

    /// Compression ratio vs dense f32 storage.
    pub fn compression_ratio_f32(&self) -> f64 {
        (self.indices.len() * 4) as f64 / self.compressed_bytes() as f64
    }

    /// Shannon entropy of the index stream (bits/index) — the Huffman
    /// bound on variable-length coding.
    pub fn index_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.k()];
        for &i in &self.indices {
            counts[i as usize] += 1;
        }
        let n = self.indices.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }

    /// Reconstruct the full vector.
    pub fn decode(&self) -> Vec<f64> {
        self.indices.iter().map(|&i| self.levels[i as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, QuantMethod, QuantOptions};

    #[test]
    fn roundtrip_exact() {
        let values = vec![0.5, 0.5, 1.0, -2.0, 1.0, 0.5];
        let cb = Codebook::from_values(&values).unwrap();
        assert_eq!(cb.k(), 3);
        assert_eq!(cb.decode(), values);
        assert_eq!(cb.levels, vec![-2.0, 0.5, 1.0]);
    }

    #[test]
    fn bits_per_index_steps() {
        let mk = |k: usize| {
            let values: Vec<f64> = (0..k).map(|i| i as f64).collect();
            Codebook::from_values(&values).unwrap().bits_per_index()
        };
        assert_eq!(mk(1), 1);
        assert_eq!(mk(2), 1);
        assert_eq!(mk(3), 2);
        assert_eq!(mk(4), 2);
        assert_eq!(mk(5), 3);
        assert_eq!(mk(16), 4);
        assert_eq!(mk(17), 5);
    }

    #[test]
    fn compression_ratio_grows_with_fewer_levels() {
        let n = 10_000;
        let mk = |k: usize| {
            let values: Vec<f64> = (0..n).map(|i| (i % k) as f64).collect();
            Codebook::from_values(&values).unwrap().compression_ratio_f32()
        };
        assert!(mk(4) > mk(64));
        assert!(mk(4) > 10.0, "4 levels over 10k values should beat 10x");
    }

    #[test]
    fn entropy_bounds() {
        // Uniform over 4 levels → exactly 2 bits.
        let values: Vec<f64> = (0..1000).map(|i| (i % 4) as f64).collect();
        let cb = Codebook::from_values(&values).unwrap();
        assert!((cb.index_entropy() - 2.0).abs() < 1e-9);
        // Heavily skewed → far below the fixed-width 2 bits.
        let mut skewed = vec![0.0; 990];
        skewed.extend([1.0, 2.0, 3.0].iter().cycle().take(10).cloned());
        let cb2 = Codebook::from_values(&skewed).unwrap();
        assert!(cb2.index_entropy() < 0.2, "entropy {}", cb2.index_entropy());
    }

    #[test]
    fn end_to_end_with_quantizer() {
        let data: Vec<f64> = (0..500).map(|i| ((i % 17) as f64).sin()).collect();
        let out = quant::quantize(
            &data,
            QuantMethod::KMeans,
            &QuantOptions { target_values: 8, ..Default::default() },
        )
        .unwrap();
        let cb = Codebook::from_output(&out).unwrap();
        assert!(cb.k() <= 8);
        assert_eq!(cb.decode(), out.values);
        assert!(cb.compression_ratio_f32() > 5.0);
    }

    #[test]
    fn rejects_empty() {
        assert!(Codebook::from_values(&[]).is_err());
    }
}
