//! The staged quantization pipeline: prepare once, solve many.
//!
//! Every method in the paper shares an expensive *prepare* stage — the
//! unique decomposition `ŵ = unique(w)` (a full sort) plus the difference
//! basis `V` — followed by a method-specific *solve* stage. The historical
//! `quantize()` fused the two, rebuilding the decomposition on every call
//! and dispatching through a 500-line `match`. This module splits them:
//!
//! * [`PreparedInput`] — built once per vector; owns the
//!   [`UniqueDecomp`], the [`VBasis`], the multiplicity weights, and
//!   cached prefix/suffix sums. The sums are part of the prepared-input
//!   contract (O(1) segment statistics for weighted solvers and external
//!   consumers); they cost two O(m) passes next to the O(n log n) sort.
//! * [`QuantSolver`] — one trait impl per [`QuantMethod`], registered in a
//!   method→solver table ([`solver_for`]); `QuantMethod::solver()`
//!   resolves it. Replaces the thirteen `run_*` free functions.
//! * [`quantize_prepared`] — one solve over a prepared input.
//! * [`quantize_batch`] — many vectors, fanned across scoped threads.
//! * [`quantize_sweep`] — a λ path over ONE prepared input, warm-starting
//!   lasso/iterative solves from the previous λ's coefficients
//!   ([`SweepState`]); [`quantize_sweep_with`] exposes the cold variant,
//!   which is bitwise-identical to per-call [`quantize`](super::quantize).
//! * [`quantize_timed`] — the coordinator's entry point, reporting
//!   per-stage wall times ([`StageTimings`]) for the metrics surface.
//!
//! Since the request/response redesign, every entry point above is a
//! **legacy shim** over the unified front door in [`super::api`]
//! ([`super::api::Quantizer`]): this module keeps the solver
//! implementations, the method→solver table, [`PreparedInput`] and the
//! scoped-thread batch executor, while the api module owns request
//! dispatch and the codebook-first finalize. The shims are
//! regression-tested bitwise-identical to their pre-redesign outputs
//! (`tests/api_equivalence.rs`).
//!
//! ## Precision lanes
//!
//! The pipeline is generic over the element precision
//! ([`crate::linalg::scalar::Scalar`]): `PreparedInput<f64>` (the default)
//! is the bitwise-reference lane, and [`PreparedInputF32`] is the
//! single-precision fast path for NN-weight-shaped workloads — roughly
//! half the memory traffic through the sort, the O(m)-per-epoch CD kernel
//! and the O(n) recovery. Lane selection:
//!
//! * [`QuantOptions::precision`] switches [`quantize`](super::quantize) /
//!   [`quantize_batch`] (input narrowed once at entry, output widened at
//!   exit);
//! * the f32-native entry points ([`quantize_f32`], [`quantize_sweep_f32`],
//!   [`quantize_batch_f32`]) take and return `f32` end to end;
//! * coordinator jobs carry a typed payload and pick the lane from it.
//!
//! CD-family methods (l1, l1+LS, l1+l2, iterative-l1) have native f32
//! kernels; every other method falls back to widening the prepared input
//! ([`PreparedInput::widen`]) and running its f64 solver — correct, but
//! without the bandwidth win. On the f32 lane, CD tolerances are floored
//! at `1e-6` (see `linalg::scalar` for the precision contract).
//!
//! ## Allocation discipline
//!
//! The original input is held behind an `Arc`, so cloning a prepared input
//! or building one from an owned vector ([`PreparedInput::from_vec`] /
//! [`PreparedInput::from_shared`]) never copies the data; finalization
//! computes the output levels in level space (O(m log m), no full-vector
//! clone-and-sort); and [`SweepState`] owns reusable CD workspaces
//! ([`lasso::Workspace`]) so a λ path allocates its solve buffers once,
//! not per grid point.

use super::api::{self, OutputForm};
use super::types::{
    QuantDiag, QuantMethod, QuantOptions, QuantOutput, QuantOutputF32, QuantOutputT,
};
use super::unique::UniqueDecomp;
use super::vmatrix::VBasis;
use super::{cluster_ls, iterative, l0, lasso, merge, refit, tv_exact};
use crate::cluster::data_transform::{data_transform_cluster, DataTransformConfig};
use crate::cluster::gmm::{gmm_1d, GmmConfig};
use crate::cluster::kmeans::{assign_sorted, KMeansConfig};
use crate::cluster::kmeans_dp::kmeans_dp;
use crate::linalg::scalar::Scalar;
use crate::linalg::stats::distinct_count_exact;
use crate::Result;
use std::sync::Arc;
use std::time::Duration;

/// The prepare-stage product: everything a solver needs that depends only
/// on the input vector, not on the method or its options. Generic over the
/// lane precision; `PreparedInput<f64>` is the default reference lane.
#[derive(Debug, Clone)]
pub struct PreparedInput<T: Scalar = f64> {
    /// The original input, shared (never deep-copied by `clone`/`finish`).
    original: Arc<[T]>,
    unique: UniqueDecomp<T>,
    basis: VBasis<T>,
    /// Multiplicity of each unique value, in lane precision (weighted LS
    /// variants).
    weights: Vec<T>,
    /// `weight_suffix[j] = Σ_{i≥j} weights[i]` (m+1 entries, last 0).
    weight_suffix: Vec<T>,
    /// `value_prefix[j] = Σ_{i<j} ŵ_i` (m+1 entries, first 0).
    value_prefix: Vec<T>,
    /// Per-level importance: user-supplied per-element weights folded into
    /// the unique decomposition (`importance[j] = Σ user[i]` over the
    /// elements of level `j`). `None` for unweighted requests — the
    /// multiplicity `weights` above then play that role, keeping the
    /// unweighted path bitwise-unchanged.
    importance: Option<Vec<T>>,
}

/// The single-precision prepared input (the f32 fast lane).
pub type PreparedInputF32 = PreparedInput<f32>;

impl<T: Scalar> PreparedInput<T> {
    /// Derive the basis, weights and cached sums from an existing
    /// decomposition (shared by the prepare stage and the f32→f64 widen).
    fn from_parts(original: Arc<[T]>, unique: UniqueDecomp<T>) -> PreparedInput<T> {
        let basis = VBasis::new(&unique.values);
        let weights = unique.weights();
        let m = unique.m();
        let mut weight_suffix = vec![T::ZERO; m + 1];
        for j in (0..m).rev() {
            weight_suffix[j] = weight_suffix[j + 1] + weights[j];
        }
        let mut value_prefix = vec![T::ZERO; m + 1];
        for j in 0..m {
            value_prefix[j + 1] = value_prefix[j] + unique.values[j];
        }
        PreparedInput {
            original,
            unique,
            basis,
            weights,
            weight_suffix,
            value_prefix,
            importance: None,
        }
    }

    fn build(original: Arc<[T]>) -> Result<PreparedInput<T>> {
        let unique = UniqueDecomp::new(&original)?;
        Ok(Self::from_parts(original, unique))
    }

    /// Run the prepare stage on `w` (sort + decompose + basis + sums).
    /// Copies the slice once into shared storage; callers that own their
    /// vector should prefer [`PreparedInput::from_vec`], which does not.
    pub fn new(w: &[T]) -> Result<PreparedInput<T>> {
        Self::build(Arc::from(w))
    }

    /// Prepare an owned vector without copying the data.
    pub fn from_vec(w: Vec<T>) -> Result<PreparedInput<T>> {
        Self::build(Arc::from(w))
    }

    /// Prepare an already-shared vector without copying the data.
    pub fn from_shared(w: Arc<[T]>) -> Result<PreparedInput<T>> {
        Self::build(w)
    }

    /// The original (full-length) input vector.
    pub fn original(&self) -> &[T] {
        &self.original
    }

    /// The unique decomposition.
    pub fn unique(&self) -> &UniqueDecomp<T> {
        &self.unique
    }

    /// The difference basis over the unique values.
    pub fn basis(&self) -> &VBasis<T> {
        &self.basis
    }

    /// Multiplicity weights (lane precision) per unique value.
    pub fn weights(&self) -> &[T] {
        &self.weights
    }

    /// Attach per-element importance weights (folded into per-level sums —
    /// see [`UniqueDecomp::fold_importance`]). Weighted solvers then
    /// minimize `Σᵢ userᵢ(xᵢ − qᵢ)²` instead of plain MSE. Length must
    /// match the original vector; content validation (finite, ≥ 0,
    /// positive sum) is the request layer's job.
    pub fn with_user_weights(mut self, user: &[f64]) -> Result<Self> {
        self.importance = Some(self.unique.fold_importance(user)?);
        Ok(self)
    }

    /// The folded per-level importance, when this input is weighted.
    pub fn importance(&self) -> Option<&[T]> {
        self.importance.as_deref()
    }

    /// The per-level weights the cluster-family solvers should minimize
    /// against: folded importance when present, multiplicity counts
    /// otherwise (with `importance == None` this is exactly
    /// [`PreparedInput::weights`], keeping unweighted runs bitwise-stable).
    pub fn level_weights(&self) -> &[T] {
        self.importance.as_deref().unwrap_or(&self.weights)
    }

    /// Cached suffix weight `Σ_{i≥j} counts[i]` in O(1).
    pub fn weight_suffix(&self, j: usize) -> T {
        self.weight_suffix[j]
    }

    /// Cached segment sum `Σ_{a≤i<b} ŵ_i` in O(1).
    pub fn segment_sum(&self, a: usize, b: usize) -> T {
        self.value_prefix[b] - self.value_prefix[a]
    }

    /// Unweighted mean of the unique values over `[a, b)` in O(1).
    pub fn segment_mean(&self, a: usize, b: usize) -> T {
        if b > a {
            self.segment_sum(a, b) / T::from_usize(b - a)
        } else {
            T::ZERO
        }
    }

    /// Number of distinct values `m`.
    pub fn m(&self) -> usize {
        self.unique.m()
    }

    /// Length of the original vector.
    pub fn len(&self) -> usize {
        self.original.len()
    }

    /// Always false after a successful [`PreparedInput::new`].
    pub fn is_empty(&self) -> bool {
        self.original.is_empty()
    }

    /// Recover the full-length vector from per-level values and finalize
    /// (clamp + levels + loss bookkeeping).
    ///
    /// Finalization works in *level space*: the clamp and the distinct-level
    /// extraction run over the `m` per-level values before recovery, which
    /// is equivalent to the historical full-vector path (recovery replicates
    /// level values, and every level occurs at least once) while replacing
    /// the O(n log n) clone-and-sort with an O(m log m) one. The l2 loss is
    /// still accumulated over the full vector in input order, so f64
    /// results stay bitwise-identical.
    ///
    /// Since the request/response redesign this is a thin wrapper over the
    /// codebook-first compact finalize (one implementation, not two):
    /// build the codebook, then materialize. The regression anchor against
    /// the historical full-vector arithmetic is `types::finalize`
    /// (`finish_level_space_matches_full_vector_finalize`).
    pub fn finish(
        &self,
        level_values: &[T],
        clamp: Option<(f64, f64)>,
        diag: QuantDiag,
    ) -> Result<QuantOutputT<T>> {
        Ok(api::finish_compact(self, level_values, clamp, diag)?.into_output())
    }
}

impl PreparedInput<f32> {
    /// Widen to a double-precision prepared input. Reuses the sort: f32 →
    /// f64 conversion is exact and order-preserving, so the decomposition
    /// is rebuilt from the already-sorted unique values in O(n + m) without
    /// re-sorting. Backs the f64 fallback for methods without a native f32
    /// kernel.
    pub fn widen(&self) -> PreparedInput<f64> {
        let unique = UniqueDecomp {
            values: self.unique.values.iter().map(|&x| f64::from(x)).collect(),
            inverse: self.unique.inverse.clone(),
            counts: self.unique.counts.clone(),
        };
        let original: Arc<[f64]> =
            self.original.iter().map(|&x| f64::from(x)).collect::<Vec<f64>>().into();
        let mut wide = PreparedInput::from_parts(original, unique);
        // Importance carries over exactly: the f32-accumulated per-level
        // sums widen losslessly, so the f64 fallback solvers see the same
        // weighting the f32 lane folded.
        wide.importance = self
            .importance
            .as_ref()
            .map(|imp| imp.iter().map(|&x| f64::from(x)).collect());
        wide
    }
}

/// Reusable state carried along a λ sweep ([`quantize_sweep`]): solvers
/// that can warm-start store their coefficients here between steps, and
/// the CD workspaces live here so path solves don't allocate per step.
/// The workspaces reuse capacity across steps even when the problem size
/// changes ([`lasso::Workspace::reset`] is clear+resize, never a
/// reallocation when prior capacity suffices — regression-tested by
/// `workspace_reset_reuses_capacity_across_sweep` in `quant::lasso`), so
/// a same-size sweep is allocation-free in the epoch loop.
#[derive(Debug, Default)]
pub struct SweepState {
    /// α from the previous step (lasso-family warm start, f64 lane).
    pub warm_alpha: Option<Vec<f64>>,
    /// α from the previous step (lasso-family warm start, f32 lane).
    pub warm_alpha32: Option<Vec<f32>>,
    /// Reusable CD buffers for the f64 lane.
    ws64: lasso::Workspace<f64>,
    /// Reusable CD buffers for the f32 lane.
    ws32: lasso::Workspace<f32>,
    /// Cached f64 widening of the swept f32 input, built on first use by
    /// the widen-fallback path so non-CD methods don't re-widen per λ.
    /// Keyed by the source buffer so a state reused across different
    /// inputs rebuilds instead of serving the wrong widening.
    widened: Option<(Arc<[f32]>, PreparedInput<f64>)>,
}

impl SweepState {
    /// Resume a λ path from coefficients captured at the end of an
    /// earlier sweep over the same prepared input ([`SweepState::into_warm`]).
    /// The chain state entering a grid point depends only on the points
    /// before it, so a path continued from here is bitwise-identical to
    /// re-running the whole extended grid warm from scratch — the
    /// λ-grid-extension cache (`Quantizer::caching`) relies on exactly
    /// this. The CD workspaces start empty (they are scratch buffers;
    /// solver results never depend on their prior contents).
    pub fn resume(warm_alpha: Option<Vec<f64>>, warm_alpha32: Option<Vec<f32>>) -> SweepState {
        SweepState { warm_alpha, warm_alpha32, ..Default::default() }
    }

    /// Capture the chain state (both lane α slots) for a later
    /// [`SweepState::resume`], consuming the state.
    pub fn into_warm(self) -> (Option<Vec<f64>>, Option<Vec<f32>>) {
        (self.warm_alpha, self.warm_alpha32)
    }
}

/// Shared λ-path warm-start bookkeeping for the CD-family solvers: take
/// the previous step's α out of its lane slot, solve with the lane's
/// reusable workspace, and store the new α back. One point of change for
/// both lanes and all three path-capable solvers.
fn path_step_warm<T: Scalar, F>(
    warm_slot: &mut Option<Vec<T>>,
    ws: &mut lasso::Workspace<T>,
    solve: F,
) -> Result<(Vec<T>, QuantDiag)>
where
    F: FnOnce(Option<&[T]>, &mut lasso::Workspace<T>) -> Result<(Vec<T>, QuantDiag, Vec<T>)>,
{
    let warm = warm_slot.take();
    let (levels, diag, alpha) = solve(warm.as_deref(), ws)?;
    *warm_slot = Some(alpha);
    Ok((levels, diag))
}

/// The solve stage: one impl per [`QuantMethod`]. Solvers return the
/// per-level values (length `m`) plus diagnostics; full-length recovery
/// and finalization happen in [`PreparedInput::finish`].
///
/// The `*_f32` methods are the single-precision lane. Their default
/// implementations widen the prepared input and run the f64 solver, so
/// every method is f32-callable; the CD-family solvers override them with
/// native f32 kernels.
pub trait QuantSolver: Sync {
    /// The method this solver implements (table registration key).
    fn method(&self) -> QuantMethod;

    /// Solve over a prepared input.
    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)>;

    /// One step of a λ path. Solvers that can reuse cross-step state
    /// (lasso warm starts) override this; the default is stateless and
    /// therefore bitwise-identical to [`QuantSolver::solve`].
    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        _state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        self.solve(prep, opts)
    }

    /// Solve on the f32 lane. Default: widen and run the f64 solver (no
    /// bandwidth win, but correct for every method).
    fn solve_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        let wide = prep.widen();
        let (levels, diag) = self.solve(&wide, opts)?;
        Ok((levels.iter().map(|&x| x as f32).collect(), diag))
    }

    /// One step of a λ path on the f32 lane. The default is stateless in
    /// the solver sense but caches the f64 widening of the prepared input
    /// in [`SweepState`], so widen-fallback methods pay the O(n + m)
    /// conversion once per sweep instead of once per λ. Results are
    /// identical to [`QuantSolver::solve_f32`] (widening is
    /// deterministic).
    fn solve_path_step_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        let stale = match &state.widened {
            Some((src, _)) => !Arc::ptr_eq(src, &prep.original),
            None => true,
        };
        if stale {
            state.widened = Some((Arc::clone(&prep.original), prep.widen()));
        }
        let (_, wide) = state.widened.as_ref().expect("widened cache just filled");
        let (levels, diag) = self.solve(wide, opts)?;
        Ok((levels.iter().map(|&x| x as f32).collect(), diag))
    }
}

/// Static lane dispatch for generic pipeline code: maps an element type
/// to the matching concrete [`QuantSolver`] lane methods, so the request
/// front door ([`super::api`]) and the sweep core are written once over
/// `T` instead of once per precision lane.
pub trait LaneSolve: Scalar {
    /// Solve over a prepared input on this lane.
    fn lane_solve(
        solver: &dyn QuantSolver,
        prep: &PreparedInput<Self>,
        opts: &QuantOptions,
    ) -> Result<(Vec<Self>, QuantDiag)>;

    /// One λ-path step on this lane (warm-start-capable solvers reuse
    /// `state` between grid points).
    fn lane_solve_path_step(
        solver: &dyn QuantSolver,
        prep: &PreparedInput<Self>,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<Self>, QuantDiag)>;
}

impl LaneSolve for f64 {
    fn lane_solve(
        solver: &dyn QuantSolver,
        prep: &PreparedInput<f64>,
        opts: &QuantOptions,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        solver.solve(prep, opts)
    }

    fn lane_solve_path_step(
        solver: &dyn QuantSolver,
        prep: &PreparedInput<f64>,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        solver.solve_path_step(prep, opts, state)
    }
}

impl LaneSolve for f32 {
    fn lane_solve(
        solver: &dyn QuantSolver,
        prep: &PreparedInput<f32>,
        opts: &QuantOptions,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        solver.solve_f32(prep, opts)
    }

    fn lane_solve_path_step(
        solver: &dyn QuantSolver,
        prep: &PreparedInput<f32>,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        solver.solve_path_step_f32(prep, opts, state)
    }
}

fn lasso_cfg(opts: &QuantOptions) -> lasso::LassoConfig {
    lasso::LassoConfig {
        lambda1: opts.lambda1,
        lambda2: 0.0,
        max_epochs: opts.max_epochs,
        tol: opts.tol,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// Lasso family (eq 6 / Algorithm 1 / eq 13)
// ---------------------------------------------------------------------

struct L1Solver {
    with_refit: bool,
}

impl L1Solver {
    fn solve_with<T: Scalar>(
        &self,
        prep: &PreparedInput<T>,
        opts: &QuantOptions,
        warm: Option<&[T]>,
        ws: &mut lasso::Workspace<T>,
    ) -> Result<(Vec<T>, QuantDiag, Vec<T>)> {
        let basis = prep.basis();
        let w = &prep.unique().values;
        let sol = match prep.importance() {
            Some(imp) => lasso::solve_ws_weighted(basis, w, imp, &lasso_cfg(opts), warm, ws)?,
            None => lasso::solve_ws(basis, w, &lasso_cfg(opts), warm, ws)?,
        };
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: sol.converged,
            lambda1: opts.lambda1,
            nnz: sol.nnz(),
            unstable: sol.unstable,
            empty_cluster_events: 0,
        };
        let levels = if self.with_refit {
            let support = sol.support();
            refit::refit_fast(basis, w, &support, prep.importance())?.reconstruction
        } else {
            basis.apply(&sol.alpha)
        };
        Ok((levels, diag, sol.alpha))
    }
}

impl QuantSolver for L1Solver {
    fn method(&self) -> QuantMethod {
        if self.with_refit {
            QuantMethod::L1LeastSquare
        } else {
            QuantMethod::L1
        }
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let mut ws = lasso::Workspace::default();
        let (levels, diag, _) = self.solve_with(prep, opts, None, &mut ws)?;
        Ok((levels, diag))
    }

    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        path_step_warm(&mut state.warm_alpha, &mut state.ws64, |warm, ws| {
            self.solve_with(prep, opts, warm, ws)
        })
    }

    fn solve_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        let mut ws = lasso::Workspace::default();
        let (levels, diag, _) = self.solve_with(prep, opts, None, &mut ws)?;
        Ok((levels, diag))
    }

    fn solve_path_step_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        path_step_warm(&mut state.warm_alpha32, &mut state.ws32, |warm, ws| {
            self.solve_with(prep, opts, warm, ws)
        })
    }
}

struct L1L2Solver;

impl L1L2Solver {
    fn solve_with<T: Scalar>(
        &self,
        prep: &PreparedInput<T>,
        opts: &QuantOptions,
        warm: Option<&[T]>,
        ws: &mut lasso::Workspace<T>,
    ) -> Result<(Vec<T>, QuantDiag, Vec<T>)> {
        let basis = prep.basis();
        let w = &prep.unique().values;
        let cfg = lasso::LassoConfig { lambda2: opts.lambda2, ..lasso_cfg(opts) };
        let sol = match prep.importance() {
            Some(imp) => lasso::solve_ws_weighted(basis, w, imp, &cfg, warm, ws)?,
            None => lasso::solve_ws(basis, w, &cfg, warm, ws)?,
        };
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: sol.converged,
            lambda1: opts.lambda1,
            nnz: sol.nnz(),
            unstable: sol.unstable,
            empty_cluster_events: 0,
        };
        // Fig 4 compares l1 vs l1+l2 without the LS refit; honor opts.refit
        // for users who want Algorithm-1 style output.
        let levels = if opts.refit {
            refit::refit_fast(basis, w, &sol.support(), prep.importance())?.reconstruction
        } else {
            basis.apply(&sol.alpha)
        };
        Ok((levels, diag, sol.alpha))
    }
}

impl QuantSolver for L1L2Solver {
    fn method(&self) -> QuantMethod {
        QuantMethod::L1L2
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let mut ws = lasso::Workspace::default();
        let (levels, diag, _) = self.solve_with(prep, opts, None, &mut ws)?;
        Ok((levels, diag))
    }

    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        path_step_warm(&mut state.warm_alpha, &mut state.ws64, |warm, ws| {
            self.solve_with(prep, opts, warm, ws)
        })
    }

    fn solve_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        let mut ws = lasso::Workspace::default();
        let (levels, diag, _) = self.solve_with(prep, opts, None, &mut ws)?;
        Ok((levels, diag))
    }

    fn solve_path_step_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        path_step_warm(&mut state.warm_alpha32, &mut state.ws32, |warm, ws| {
            self.solve_with(prep, opts, warm, ws)
        })
    }
}

// ---------------------------------------------------------------------
// l0 best-subset (eq 16)
// ---------------------------------------------------------------------

struct L0Solver;

impl QuantSolver for L0Solver {
    fn method(&self) -> QuantMethod {
        QuantMethod::L0
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        if prep.importance().is_some() {
            return Err(crate::Error::InvalidInput(
                "l0: importance weights are not supported (best-subset search is unweighted)"
                    .into(),
            ));
        }
        let basis = prep.basis();
        let cfg = l0::L0Config {
            max_nnz: opts.target_values,
            max_epochs: opts.max_epochs,
            tol: opts.tol,
            ..Default::default()
        };
        let sol = l0::solve_l0(basis, &prep.unique().values, &cfg)?;
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: !sol.unstable,
            lambda1: sol.lambda0,
            nnz: sol.nnz,
            unstable: sol.unstable,
            empty_cluster_events: 0,
        };
        Ok((basis.apply(&sol.alpha), diag))
    }
}

// ---------------------------------------------------------------------
// Iterative l1 (Algorithm 2)
// ---------------------------------------------------------------------

struct IterativeSolver;

impl IterativeSolver {
    fn solve_warm<T: Scalar>(
        &self,
        prep: &PreparedInput<T>,
        opts: &QuantOptions,
        warm: Option<&[T]>,
        ws: &mut lasso::Workspace<T>,
    ) -> Result<(Vec<T>, QuantDiag, Vec<T>)> {
        let basis = prep.basis();
        let cfg = iterative::IterativeConfig {
            target_nnz: opts.target_values,
            lambda_start: opts.lambda1.max(1e-9),
            max_steps: opts.max_lambda_steps,
            cd: lasso_cfg(opts),
            accelerate: 1.0,
        };
        let sol = iterative::solve_iterative_weighted_ws(
            basis,
            &prep.unique().values,
            prep.importance(),
            &cfg,
            warm,
            ws,
        )?;
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: sol.reached_target,
            lambda1: sol.lambda1,
            nnz: sol.nnz,
            unstable: !sol.reached_target,
            empty_cluster_events: 0,
        };
        let mut rec = basis.apply(&sol.alpha);
        if !sol.reached_target {
            // The λ path can jump past the requested count (paper: "might
            // fail to optimize to exact l values"). Enforce the library's
            // contract with a Ward merge of the surplus levels.
            rec = merge::merge_to_target(&rec, prep.importance(), opts.target_values);
        }
        Ok((rec, diag, sol.alpha))
    }
}

impl QuantSolver for IterativeSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::IterativeL1
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let mut ws = lasso::Workspace::default();
        let (levels, diag, _) = self.solve_warm(prep, opts, None, &mut ws)?;
        Ok((levels, diag))
    }

    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        path_step_warm(&mut state.warm_alpha, &mut state.ws64, |warm, ws| {
            self.solve_warm(prep, opts, warm, ws)
        })
    }

    fn solve_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        let mut ws = lasso::Workspace::default();
        let (levels, diag, _) = self.solve_warm(prep, opts, None, &mut ws)?;
        Ok((levels, diag))
    }

    fn solve_path_step_f32(
        &self,
        prep: &PreparedInputF32,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f32>, QuantDiag)> {
        path_step_warm(&mut state.warm_alpha32, &mut state.ws32, |warm, ws| {
            self.solve_warm(prep, opts, warm, ws)
        })
    }
}

// ---------------------------------------------------------------------
// Cluster-based least squares (Algorithm 3) and clustering baselines
// ---------------------------------------------------------------------

struct ClusterLsSolver;

impl QuantSolver for ClusterLsSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::ClusterLs
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let cfg = cluster_ls::ClusterLsConfig {
            l: opts.target_values,
            kmeans: KMeansConfig {
                k: opts.target_values,
                restarts: opts.kmeans_restarts,
                max_iters: opts.max_iters,
                tol: 1e-10,
                seed: opts.seed,
                ..Default::default()
            },
            // Weighted: the paper's eq 19 is written over ŵ unweighted, but
            // its experimental claim (Alg 3 ≥ k-means on the full-vector
            // loss) only holds when multiplicities weight both the
            // partition and the LS values; the paper-literal unweighted
            // variant stays available via ClusterLsConfig. See
            // EXPERIMENTS.md Fig 5 notes.
            weighted: true,
        };
        let sol = cluster_ls::solve_cluster_ls(
            basis,
            &prep.unique().values,
            Some(prep.level_weights()),
            &cfg,
        )?;
        let diag = QuantDiag {
            iterations: sol.iterations,
            converged: true,
            lambda1: 0.0,
            nnz: sol.levels.len(),
            unstable: false,
            empty_cluster_events: sol.empty_cluster_events,
        };
        Ok((sol.reconstruction, diag))
    }
}

struct KMeansSolver;

impl QuantSolver for KMeansSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::KMeans
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let cfg = KMeansConfig {
            k: opts.target_values,
            restarts: opts.kmeans_restarts,
            max_iters: opts.max_iters,
            tol: 1e-10,
            seed: opts.seed,
            ..Default::default()
        };
        let (rec, iters, empty) =
            cluster_ls::kmeans_quantize_levels(prep.basis(), Some(prep.level_weights()), &cfg)?;
        let diag = QuantDiag {
            iterations: iters,
            converged: true,
            lambda1: 0.0,
            // Report the achieved level count, not the request: clusters
            // can collapse to fewer distinct centroids.
            nnz: distinct_count_exact(&rec),
            unstable: empty > 0,
            empty_cluster_events: empty,
        };
        Ok((rec, diag))
    }
}

struct KMeansExactSolver;

impl QuantSolver for KMeansExactSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::KMeansExact
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let r = kmeans_dp(basis.values(), Some(prep.level_weights()), opts.target_values)?;
        let rec: Vec<f64> = basis
            .values()
            .iter()
            .zip(&r.assignment)
            .map(|(_, &a)| r.centroids[a])
            .collect();
        let diag = QuantDiag {
            iterations: 1,
            converged: true,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct GmmSolver;

impl QuantSolver for GmmSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::Gmm
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let cfg = GmmConfig {
            k: opts.target_values,
            max_iters: opts.max_iters,
            tol: 1e-9,
            seed: opts.seed,
        };
        let r = gmm_1d(prep.basis().values(), Some(prep.level_weights()), &cfg)?;
        let rec: Vec<f64> = r.assignment.iter().map(|&a| r.means[a]).collect();
        let diag = QuantDiag {
            iterations: r.iterations,
            converged: r.converged,
            lambda1: 0.0,
            nnz: r.means.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct DataTransformSolver;

impl QuantSolver for DataTransformSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::DataTransform
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let cfg = DataTransformConfig {
            k: opts.target_values,
            restarts: opts.kmeans_restarts,
            max_iters: opts.max_iters,
            seed: opts.seed,
            ..Default::default()
        };
        let r = data_transform_cluster(basis.values(), Some(prep.level_weights()), &cfg)?;
        let rec: Vec<f64> = basis
            .values()
            .iter()
            .map(|&v| r.centroids[assign_sorted(v, &r.centroids)])
            .collect();
        let diag = QuantDiag {
            iterations: r.iterations,
            converged: true,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct TvExactSolver;

impl QuantSolver for TvExactSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::TvExact
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        if prep.importance().is_some() {
            return Err(crate::Error::InvalidInput(
                "tv_exact: importance weights are not supported (the fused-lasso DP is \
                 unweighted)"
                    .into(),
            ));
        }
        let basis = prep.basis();
        let rec = tv_exact::solve_tv_exact(basis, &prep.unique().values, opts.lambda1)?;
        let nnz = {
            // Count level jumps (α support) for diagnostics.
            let mut prev = 0.0;
            let mut c = 0usize;
            for (&x, &d) in rec.iter().zip(basis.diffs()) {
                if d != 0.0 && (x - prev).abs() > 1e-12 {
                    c += 1;
                }
                prev = x;
            }
            c
        };
        let diag = QuantDiag {
            iterations: 1, // exact, single pass
            converged: true,
            lambda1: opts.lambda1,
            nnz,
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct AgglomerativeSolver;

impl QuantSolver for AgglomerativeSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::Agglomerative
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let r = crate::cluster::agglomerative::agglomerative_1d(
            basis.values(),
            Some(prep.level_weights()),
            opts.target_values,
        )?;
        let rec: Vec<f64> = basis
            .values()
            .iter()
            .zip(&r.assignment)
            .map(|(_, &a)| r.centroids[a])
            .collect();
        let diag = QuantDiag {
            iterations: basis.m().saturating_sub(r.centroids.len()),
            converged: true,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct FcmSolver;

impl QuantSolver for FcmSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::FuzzyCMeans
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let cfg = crate::cluster::fuzzy_cmeans::FcmConfig {
            k: opts.target_values,
            max_iters: opts.max_iters,
            seed: opts.seed,
            ..Default::default()
        };
        let r = crate::cluster::fuzzy_cmeans::fuzzy_cmeans_1d(
            prep.basis().values(),
            Some(prep.level_weights()),
            &cfg,
        )?;
        let rec: Vec<f64> = r.assignment.iter().map(|&a| r.centroids[a]).collect();
        let diag = QuantDiag {
            iterations: r.iterations,
            converged: r.converged,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

// ---------------------------------------------------------------------
// Method → solver table
// ---------------------------------------------------------------------

/// Registration table: one entry per [`QuantMethod`], same order as
/// [`QuantMethod::ALL`].
static SOLVERS: [&dyn QuantSolver; 13] = [
    &L1Solver { with_refit: false },
    &L1Solver { with_refit: true },
    &L1L2Solver,
    &L0Solver,
    &IterativeSolver,
    &ClusterLsSolver,
    &KMeansSolver,
    &GmmSolver,
    &DataTransformSolver,
    &KMeansExactSolver,
    &TvExactSolver,
    &AgglomerativeSolver,
    &FcmSolver,
];

/// Resolve the solver registered for `method`.
pub fn solver_for(method: QuantMethod) -> &'static dyn QuantSolver {
    SOLVERS
        .iter()
        .copied()
        .find(|s| s.method() == method)
        .expect("every QuantMethod has a registered solver")
}

// ---------------------------------------------------------------------
// Pipeline entry points (legacy shims over the request-API core)
// ---------------------------------------------------------------------

/// Solve stage only: quantize a prepared input with the chosen method.
///
/// **Legacy**: thin shim over the [`super::api`] core; prefer
/// [`super::api::Quantizer`] for new code. Results are bitwise-identical
/// to the pre-redesign implementation.
pub fn quantize_prepared(
    prep: &PreparedInput,
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<QuantOutput> {
    Ok(api::run_prepared_core(prep, method, opts, OutputForm::Codebook, Duration::ZERO)?
        .into_output())
}

/// Solve stage only, f32 lane: quantize a single-precision prepared input.
///
/// **Legacy**: thin shim over the [`super::api`] core.
pub fn quantize_prepared_f32(
    prep: &PreparedInputF32,
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<QuantOutputF32> {
    Ok(api::run_prepared_core(prep, method, opts, OutputForm::Codebook, Duration::ZERO)?
        .into_output())
}

/// One-shot f32-native quantize: prepare + solve in single precision,
/// returning an f32 output (no widening pass). The f64 API's
/// [`QuantOptions::precision`] routes through this lane and widens.
///
/// **Legacy**: thin shim over the [`super::api`] core; prefer
/// [`super::api::QuantRequest::vector_f32`] for new code.
pub fn quantize_f32(
    w: &[f32],
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<QuantOutputF32> {
    Ok(api::run_shared_f32(Arc::from(w), method, opts, OutputForm::Codebook)?.into_output())
}

/// Per-stage wall times of one pipeline run (coordinator metrics).
#[derive(Debug, Clone, Copy)]
pub struct StageTimings {
    /// Prepare stage (unique decomposition + basis + cached sums; on the
    /// f32 lane this includes the one-time input narrowing, if any).
    pub prepare: Duration,
    /// Solve stage (method solver + recovery + finalize).
    pub solve: Duration,
}

/// One-shot quantize that reports per-stage timings. Honors
/// [`QuantOptions::precision`] like [`quantize`](super::quantize).
///
/// **Legacy**: thin shim over the [`super::api`] core, which carries the
/// same timings on every [`super::api::QuantItem`].
pub fn quantize_timed(
    w: &[f64],
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<(QuantOutput, StageTimings)> {
    quantize_timed_vec(w.to_vec(), method, opts)
}

/// [`quantize_timed`] over an owned vector: the prepared input takes the
/// buffer as-is instead of copying it (the coordinator's serve path).
///
/// **Legacy**: thin shim over the [`super::api`] core.
pub fn quantize_timed_vec(
    w: Vec<f64>,
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<(QuantOutput, StageTimings)> {
    let item = api::run_shared_f64(Arc::from(w), method, opts, OutputForm::Codebook)?;
    let timings = item.timings();
    Ok((item.into_output64(), timings))
}

/// Timed quantize of an owned f32 payload on the f32 lane; the output is
/// widened for the coordinator's f64 result surface. Narrowing never
/// happens here — the payload is already single precision.
///
/// **Legacy**: thin shim over the [`super::api`] core.
pub fn quantize_timed_f32_vec(
    w: Vec<f32>,
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<(QuantOutput, StageTimings)> {
    let item = api::run_shared_f32(Arc::from(w), method, opts, OutputForm::Codebook)?;
    let timings = item.timings;
    Ok((item.into_output().widen(), timings))
}

/// How many threads a batch of `n` independent inputs should fan across.
fn batch_threads(n: usize) -> usize {
    let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
    cores.min(n).min(8)
}

/// Shared scoped-thread fan-out for both precision lanes' batch entry
/// points (and the request API's batch/matrix fan-out): apply `f` to
/// every input, in input order, chunked across [`batch_threads`] workers.
pub(crate) fn batch_map<In, Out, F>(inputs: &[In], f: F) -> Vec<Out>
where
    In: Sync,
    Out: Send,
    F: Fn(&In) -> Out + Sync,
{
    let threads = batch_threads(inputs.len());
    if threads <= 1 {
        return inputs.iter().map(&f).collect();
    }
    let mut results: Vec<Option<Out>> = Vec::with_capacity(inputs.len());
    results.resize_with(inputs.len(), || None);
    let chunk = inputs.len().div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        for (slots, ins) in results.chunks_mut(chunk).zip(inputs.chunks(chunk)) {
            s.spawn(move || {
                for (slot, w) in slots.iter_mut().zip(ins) {
                    *slot = Some(f(w));
                }
            });
        }
    });
    results
        .into_iter()
        .map(|r| r.expect("batch worker filled every slot"))
        .collect()
}

/// Quantize many vectors with the same method/options. Inputs are
/// independent, so the batch fans across scoped threads; results come
/// back in input order and are bitwise-identical to per-call
/// [`quantize`](super::quantize) (including its
/// [`QuantOptions::precision`] routing).
///
/// **Legacy**: delegates to the [`super::api`] core through
/// [`quantize`](super::quantize); prefer [`super::api::QuantRequest::batch`]
/// for new code.
pub fn quantize_batch(
    inputs: &[Vec<f64>],
    method: QuantMethod,
    opts: &QuantOptions,
) -> Vec<Result<QuantOutput>> {
    batch_map(inputs, |w| super::quantize(w, method, opts))
}

/// f32-native batch quantize: many single-precision vectors fanned across
/// scoped threads, each through the f32 lane end to end. Results are
/// bitwise-identical to per-call [`quantize_f32`].
///
/// **Legacy**: delegates to the [`super::api`] core through
/// [`quantize_f32`]; prefer [`super::api::QuantRequest::batch_f32`] for
/// new code.
pub fn quantize_batch_f32(
    inputs: &[Vec<f32>],
    method: QuantMethod,
    opts: &QuantOptions,
) -> Vec<Result<QuantOutputF32>> {
    batch_map(inputs, |w| quantize_f32(w, method, opts))
}

/// λ sweep over one prepared input with warm starts along the path
/// (lasso-family and iterative solvers reuse the previous α). `base`
/// supplies every option except `lambda1`, which each grid point
/// overrides.
///
/// **Legacy**: thin shim over the [`super::api`] sweep core; prefer
/// [`super::api::QuantRequest::sweep`] for new code.
pub fn quantize_sweep(
    prep: &PreparedInput,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
) -> Result<Vec<QuantOutput>> {
    quantize_sweep_with(prep, method, lambdas, base, true)
}

/// λ sweep with explicit warm-start control. `warm_start = false` runs
/// every grid point cold, which is bitwise-identical to calling
/// [`quantize`](super::quantize) per λ (minus the repeated prepare).
/// The lane is fixed by the prepared input's own precision (f64 here);
/// `base.precision` is ignored — use [`quantize_sweep_f32`] with a
/// [`PreparedInputF32`] for the single-precision lane.
///
/// **Legacy**: thin shim over the [`super::api`] sweep core.
pub fn quantize_sweep_with(
    prep: &PreparedInput,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    warm_start: bool,
) -> Result<Vec<QuantOutput>> {
    Ok(api::sweep_prepared_core(
        prep,
        method,
        lambdas,
        base,
        warm_start,
        OutputForm::Codebook,
        Duration::ZERO,
    )?
    .into_iter()
    .map(api::QuantItem::into_output)
    .collect())
}

/// f32-lane λ sweep with warm starts (see [`quantize_sweep`]).
///
/// **Legacy**: thin shim over the [`super::api`] sweep core; prefer
/// [`super::api::QuantRequest::vector_f32`] + `.sweep(..)` for new code.
pub fn quantize_sweep_f32(
    prep: &PreparedInputF32,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
) -> Result<Vec<QuantOutputF32>> {
    quantize_sweep_f32_with(prep, method, lambdas, base, true)
}

/// f32-lane λ sweep with explicit warm-start control. The cold variant is
/// bitwise-identical to per-λ [`quantize_f32`] (minus the repeated
/// prepare). The λ grid itself stays f64 so both lanes walk the same
/// penalty schedule.
///
/// **Legacy**: thin shim over the [`super::api`] sweep core.
pub fn quantize_sweep_f32_with(
    prep: &PreparedInputF32,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    warm_start: bool,
) -> Result<Vec<QuantOutputF32>> {
    Ok(api::sweep_prepared_core(
        prep,
        method,
        lambdas,
        base,
        warm_start,
        OutputForm::Codebook,
        Duration::ZERO,
    )?
    .into_iter()
    .map(api::QuantItem::into_output)
    .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;
    use crate::quant::types::Precision;

    fn clustered(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            let center = [0.1, 0.35, 0.6, 0.9][i % 4];
            // Round so repeats occur (multiplicities > 1).
            v.push(((center + rng.normal_with(0.0, 0.02)) * 200.0).round() / 200.0);
        }
        v
    }

    fn narrowed(xs: &[f64]) -> Vec<f32> {
        xs.iter().map(|&x| x as f32).collect()
    }

    #[test]
    fn every_method_resolves_to_its_own_solver() {
        for m in QuantMethod::ALL {
            assert_eq!(solver_for(m).method(), m, "{m:?}");
            assert_eq!(m.solver().method(), m, "{m:?}");
        }
    }

    #[test]
    fn prepared_pipeline_matches_one_shot() {
        let data = clustered(80, 1);
        let prep = PreparedInput::new(&data).unwrap();
        for m in QuantMethod::ALL {
            let opts = QuantOptions {
                lambda1: 0.01,
                lambda2: 4e-5,
                target_values: 4,
                ..Default::default()
            };
            let staged = quantize_prepared(&prep, m, &opts).unwrap();
            let one_shot = super::super::quantize(&data, m, &opts).unwrap();
            assert_eq!(staged.values, one_shot.values, "{m:?}");
            assert_eq!(staged.levels, one_shot.levels, "{m:?}");
            assert_eq!(staged.l2_loss.to_bits(), one_shot.l2_loss.to_bits(), "{m:?}");
        }
    }

    #[test]
    fn prepared_input_caches_are_consistent() {
        let data = clustered(60, 2);
        let prep = PreparedInput::new(&data).unwrap();
        let m = prep.m();
        assert_eq!(prep.len(), data.len());
        assert!(!prep.is_empty());
        // Suffix weights against a naive recomputation.
        for j in 0..=m {
            let naive: f64 = prep.weights()[j..].iter().sum();
            assert!((prep.weight_suffix(j) - naive).abs() < 1e-9);
        }
        // Segment means against naive means.
        let vals = &prep.unique().values;
        for (a, b) in [(0, m), (0, m / 2), (m / 3, m)] {
            let naive = vals[a..b].iter().sum::<f64>() / (b - a) as f64;
            assert!((prep.segment_mean(a, b) - naive).abs() < 1e-9);
        }
        assert_eq!(prep.segment_mean(3, 3), 0.0);
    }

    #[test]
    fn from_vec_and_from_shared_match_new() {
        let data = clustered(50, 21);
        let a = PreparedInput::new(&data).unwrap();
        let b = PreparedInput::from_vec(data.clone()).unwrap();
        let c = PreparedInput::from_shared(Arc::from(&data[..])).unwrap();
        assert_eq!(a.original(), b.original());
        assert_eq!(a.original(), c.original());
        assert_eq!(a.unique().values, b.unique().values);
        assert_eq!(a.m(), c.m());
    }

    #[test]
    fn finish_level_space_matches_full_vector_finalize() {
        // Regression for the level-space finalize: identical values,
        // levels, loss bits and clamp counts vs the historical
        // recover-then-finalize path, with and without clamping.
        let data = clustered(70, 22);
        let prep = PreparedInput::new(&data).unwrap();
        let m = prep.m();
        // A deliberately non-monotone level assignment with out-of-range
        // values at both ends.
        let lv: Vec<f64> =
            (0..m).map(|j| ((j * 13 % 7) as f64) * 0.3 - 0.4).collect();
        for clamp in [None, Some((0.0, 1.0))] {
            let got = prep.finish(&lv, clamp, QuantDiag::default()).unwrap();
            let full = prep.unique().recover(&lv).unwrap();
            let want = crate::quant::types::finalize(&data, full, clamp, QuantDiag::default());
            assert_eq!(got.values, want.values);
            assert_eq!(got.levels, want.levels);
            assert_eq!(got.l2_loss.to_bits(), want.l2_loss.to_bits());
            assert_eq!(got.clamped, want.clamped);
        }
    }

    #[test]
    fn kmeans_diag_reports_achieved_levels_not_request() {
        // Two tight value groups but target_values = 5: clusters collapse,
        // and nnz must report the achieved count.
        let mut data = vec![1.0; 10];
        data.extend(vec![9.0; 10]);
        let opts = QuantOptions { target_values: 5, ..Default::default() };
        let out = super::super::quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        assert_eq!(out.diag.nnz, out.distinct_values());
        assert!(out.diag.nnz <= 2, "two-level data, nnz={}", out.diag.nnz);
    }

    #[test]
    fn batch_handles_bad_inputs_per_slot() {
        let inputs = vec![clustered(30, 3), vec![], clustered(30, 4)];
        let opts = QuantOptions { target_values: 3, ..Default::default() };
        let rs = quantize_batch(&inputs, QuantMethod::KMeans, &opts);
        assert_eq!(rs.len(), 3);
        assert!(rs[0].is_ok());
        assert!(rs[1].is_err(), "empty vector must fail its own slot only");
        assert!(rs[2].is_ok());
    }

    #[test]
    fn sweep_outputs_one_per_lambda_in_order() {
        let data = clustered(50, 5);
        let prep = PreparedInput::new(&data).unwrap();
        let lambdas = [1e-4, 1e-3, 1e-2, 1e-1];
        let outs =
            quantize_sweep(&prep, QuantMethod::L1, &lambdas, &QuantOptions::default()).unwrap();
        assert_eq!(outs.len(), lambdas.len());
        for (o, &l) in outs.iter().zip(&lambdas) {
            assert_eq!(o.diag.lambda1, l);
            assert_eq!(o.values.len(), data.len());
        }
        // Three decades of λ ⇒ the path ends much sparser than it starts.
        assert!(
            outs.last().unwrap().distinct_values() <= outs.first().unwrap().distinct_values(),
            "λ path did not sparsify"
        );
    }

    #[test]
    fn timed_quantize_reports_stages() {
        let data = clustered(64, 6);
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let (out, t) = quantize_timed(&data, QuantMethod::ClusterLs, &opts).unwrap();
        assert_eq!(out.values.len(), data.len());
        // Durations are non-negative by construction; just make sure the
        // call returns something sane.
        assert!(t.prepare + t.solve < Duration::from_secs(60));
    }

    #[test]
    fn precision_option_routes_through_f32_lane() {
        let data = clustered(60, 7);
        let opts = QuantOptions {
            lambda1: 0.02,
            precision: Precision::F32,
            ..Default::default()
        };
        let via_opts = super::super::quantize(&data, QuantMethod::L1LeastSquare, &opts).unwrap();
        let direct =
            quantize_f32(&narrowed(&data), QuantMethod::L1LeastSquare, &opts).unwrap().widen();
        assert_eq!(via_opts.values, direct.values);
        assert_eq!(via_opts.levels, direct.levels);
        assert_eq!(via_opts.l2_loss.to_bits(), direct.l2_loss.to_bits());
    }

    #[test]
    fn f32_lane_covers_every_method_via_widen_fallback() {
        let data32 = narrowed(&clustered(60, 8));
        for m in QuantMethod::ALL {
            let opts = QuantOptions {
                lambda1: 0.01,
                lambda2: 4e-5,
                target_values: 4,
                ..Default::default()
            };
            let out = quantize_f32(&data32, m, &opts)
                .unwrap_or_else(|e| panic!("{m:?} failed on the f32 lane: {e}"));
            assert_eq!(out.values.len(), data32.len(), "{m:?}");
            assert!(out.l2_loss.is_finite(), "{m:?}");
            assert!(out.distinct_values() >= 1, "{m:?}");
        }
    }

    #[test]
    fn f32_prepared_pipeline_matches_one_shot_f32() {
        let data32 = narrowed(&clustered(70, 9));
        let prep = PreparedInputF32::new(&data32).unwrap();
        for m in [
            QuantMethod::L1,
            QuantMethod::L1LeastSquare,
            QuantMethod::L1L2,
            QuantMethod::IterativeL1,
        ] {
            let opts =
                QuantOptions { lambda1: 0.02, target_values: 4, ..Default::default() };
            let staged = quantize_prepared_f32(&prep, m, &opts).unwrap();
            let one_shot = quantize_f32(&data32, m, &opts).unwrap();
            assert_eq!(staged.values, one_shot.values, "{m:?}");
            assert_eq!(staged.l2_loss.to_bits(), one_shot.l2_loss.to_bits(), "{m:?}");
        }
    }

    #[test]
    fn f32_sweep_sparsifies_like_f64() {
        let data = clustered(64, 10);
        let lambdas = [1e-4, 1e-3, 1e-2, 1e-1];
        let opts = QuantOptions::default();
        let prep64 = PreparedInput::new(&data).unwrap();
        let outs64 = quantize_sweep(&prep64, QuantMethod::L1LeastSquare, &lambdas, &opts).unwrap();
        let prep32 = PreparedInputF32::new(&narrowed(&data)).unwrap();
        let outs32 =
            quantize_sweep_f32(&prep32, QuantMethod::L1LeastSquare, &lambdas, &opts).unwrap();
        assert_eq!(outs32.len(), outs64.len());
        for (o32, o64) in outs32.iter().zip(&outs64) {
            // Same order of magnitude of sparsity along the path.
            assert!(
                o32.distinct_values().abs_diff(o64.distinct_values())
                    <= 2 + o64.distinct_values() / 4,
                "f32 {} vs f64 {} levels",
                o32.distinct_values(),
                o64.distinct_values()
            );
        }
    }

    #[test]
    fn f32_widen_fallback_sweep_caches_but_matches_cold() {
        // Non-CD methods on an f32 sweep go through the cached-widen
        // default path step; results must equal the cold (per-λ widen)
        // reference exactly, since widening is deterministic.
        let data32 = narrowed(&clustered(50, 12));
        let prep = PreparedInputF32::new(&data32).unwrap();
        let lambdas = [1e-3, 1e-2];
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let warm = quantize_sweep_f32(&prep, QuantMethod::KMeans, &lambdas, &opts).unwrap();
        let cold =
            quantize_sweep_f32_with(&prep, QuantMethod::KMeans, &lambdas, &opts, false).unwrap();
        for (w, c) in warm.iter().zip(&cold) {
            assert_eq!(w.values, c.values);
            assert_eq!(w.l2_loss.to_bits(), c.l2_loss.to_bits());
        }
    }

    #[test]
    fn widen_cache_rebuilds_for_a_different_input() {
        // Reusing one SweepState across different f32 inputs must not
        // serve the first input's cached widening for the second.
        let a32 = narrowed(&clustered(40, 13));
        let b32 = narrowed(&clustered(40, 14));
        let pa = PreparedInputF32::new(&a32).unwrap();
        let pb = PreparedInputF32::new(&b32).unwrap();
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let solver = solver_for(QuantMethod::KMeans);
        let mut st = SweepState::default();
        let _ = solver.solve_path_step_f32(&pa, &opts, &mut st).unwrap();
        let (lv_b, _diag) = solver.solve_path_step_f32(&pb, &opts, &mut st).unwrap();
        let (lv_ref, _diag_ref) = solver.solve_f32(&pb, &opts).unwrap();
        assert_eq!(lv_b, lv_ref);
    }

    #[test]
    fn widened_prepared_input_is_consistent() {
        let data32 = narrowed(&clustered(40, 11));
        let prep32 = PreparedInputF32::new(&data32).unwrap();
        let wide = prep32.widen();
        assert_eq!(wide.m(), prep32.m());
        assert_eq!(wide.len(), prep32.len());
        for (w64, w32) in wide.unique().values.iter().zip(&prep32.unique().values) {
            assert_eq!(*w64, f64::from(*w32));
        }
        assert_eq!(wide.unique().counts, prep32.unique().counts);
    }
}
