//! The staged quantization pipeline: prepare once, solve many.
//!
//! Every method in the paper shares an expensive *prepare* stage — the
//! unique decomposition `ŵ = unique(w)` (a full sort) plus the difference
//! basis `V` — followed by a method-specific *solve* stage. The historical
//! `quantize()` fused the two, rebuilding the decomposition on every call
//! and dispatching through a 500-line `match`. This module splits them:
//!
//! * [`PreparedInput`] — built once per vector; owns the
//!   [`UniqueDecomp`], the [`VBasis`], the multiplicity weights, and
//!   cached prefix/suffix sums. The sums are part of the prepared-input
//!   contract (O(1) segment statistics for weighted solvers and external
//!   consumers); they cost two O(m) passes next to the O(n log n) sort.
//! * [`QuantSolver`] — one trait impl per [`QuantMethod`], registered in a
//!   method→solver table ([`solver_for`]); `QuantMethod::solver()`
//!   resolves it. Replaces the thirteen `run_*` free functions.
//! * [`quantize_prepared`] — one solve over a prepared input.
//! * [`quantize_batch`] — many vectors, fanned across scoped threads.
//! * [`quantize_sweep`] — a λ path over ONE prepared input, warm-starting
//!   lasso/iterative solves from the previous λ's coefficients
//!   ([`SweepState`]); [`quantize_sweep_with`] exposes the cold variant,
//!   which is bitwise-identical to per-call [`quantize`](super::quantize).
//! * [`quantize_timed`] — the coordinator's entry point, reporting
//!   per-stage wall times ([`StageTimings`]) for the metrics surface.

use super::types::{self, QuantDiag, QuantMethod, QuantOptions, QuantOutput};
use super::unique::UniqueDecomp;
use super::vmatrix::VBasis;
use super::{cluster_ls, iterative, l0, lasso, merge, refit, tv_exact};
use crate::cluster::data_transform::{data_transform_cluster, DataTransformConfig};
use crate::cluster::gmm::{gmm_1d, GmmConfig};
use crate::cluster::kmeans::{assign_sorted, KMeansConfig};
use crate::cluster::kmeans_dp::kmeans_dp;
use crate::linalg::stats::distinct_count_exact;
use crate::Result;
use std::time::{Duration, Instant};

/// The prepare-stage product: everything a solver needs that depends only
/// on the input vector, not on the method or its options.
#[derive(Debug, Clone)]
pub struct PreparedInput {
    original: Vec<f64>,
    unique: UniqueDecomp,
    basis: VBasis,
    /// Multiplicity of each unique value, as f64 (weighted LS variants).
    weights: Vec<f64>,
    /// `weight_suffix[j] = Σ_{i≥j} weights[i]` (m+1 entries, last 0).
    weight_suffix: Vec<f64>,
    /// `value_prefix[j] = Σ_{i<j} ŵ_i` (m+1 entries, first 0).
    value_prefix: Vec<f64>,
}

impl PreparedInput {
    /// Run the prepare stage on `w` (sort + decompose + basis + sums).
    pub fn new(w: &[f64]) -> Result<PreparedInput> {
        let unique = UniqueDecomp::new(w)?;
        let basis = VBasis::new(&unique.values);
        let weights = unique.weights();
        let m = unique.m();
        let mut weight_suffix = vec![0.0; m + 1];
        for j in (0..m).rev() {
            weight_suffix[j] = weight_suffix[j + 1] + weights[j];
        }
        let mut value_prefix = vec![0.0; m + 1];
        for j in 0..m {
            value_prefix[j + 1] = value_prefix[j] + unique.values[j];
        }
        Ok(PreparedInput {
            original: w.to_vec(),
            unique,
            basis,
            weights,
            weight_suffix,
            value_prefix,
        })
    }

    /// The original (full-length) input vector.
    pub fn original(&self) -> &[f64] {
        &self.original
    }

    /// The unique decomposition.
    pub fn unique(&self) -> &UniqueDecomp {
        &self.unique
    }

    /// The difference basis over the unique values.
    pub fn basis(&self) -> &VBasis {
        &self.basis
    }

    /// Multiplicity weights (f64) per unique value.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Cached suffix weight `Σ_{i≥j} counts[i]` in O(1).
    pub fn weight_suffix(&self, j: usize) -> f64 {
        self.weight_suffix[j]
    }

    /// Cached segment sum `Σ_{a≤i<b} ŵ_i` in O(1).
    pub fn segment_sum(&self, a: usize, b: usize) -> f64 {
        self.value_prefix[b] - self.value_prefix[a]
    }

    /// Unweighted mean of the unique values over `[a, b)` in O(1).
    pub fn segment_mean(&self, a: usize, b: usize) -> f64 {
        if b > a {
            self.segment_sum(a, b) / (b - a) as f64
        } else {
            0.0
        }
    }

    /// Number of distinct values `m`.
    pub fn m(&self) -> usize {
        self.unique.m()
    }

    /// Length of the original vector.
    pub fn len(&self) -> usize {
        self.original.len()
    }

    /// Always false after a successful [`PreparedInput::new`].
    pub fn is_empty(&self) -> bool {
        self.original.is_empty()
    }

    /// Recover the full-length vector from per-level values and finalize
    /// (clamp + levels + loss bookkeeping).
    pub fn finish(
        &self,
        level_values: &[f64],
        clamp: Option<(f64, f64)>,
        diag: QuantDiag,
    ) -> Result<QuantOutput> {
        let full = self.unique.recover(level_values)?;
        Ok(types::finalize(&self.original, full, clamp, diag))
    }
}

/// Reusable state carried along a λ sweep ([`quantize_sweep`]): solvers
/// that can warm-start store their coefficients here between steps.
#[derive(Debug, Default)]
pub struct SweepState {
    /// α from the previous step (lasso-family warm start).
    pub warm_alpha: Option<Vec<f64>>,
}

/// The solve stage: one impl per [`QuantMethod`]. Solvers return the
/// per-level values (length `m`) plus diagnostics; full-length recovery
/// and finalization happen in [`PreparedInput::finish`].
pub trait QuantSolver: Sync {
    /// The method this solver implements (table registration key).
    fn method(&self) -> QuantMethod;

    /// Solve over a prepared input.
    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)>;

    /// One step of a λ path. Solvers that can reuse cross-step state
    /// (lasso warm starts) override this; the default is stateless and
    /// therefore bitwise-identical to [`QuantSolver::solve`].
    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        _state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        self.solve(prep, opts)
    }
}

/// Shared warm-start bookkeeping for path-capable solvers: feed the
/// previous step's α in, store the new one back.
fn step_with_warm<F>(state: &mut SweepState, solve: F) -> Result<(Vec<f64>, QuantDiag)>
where
    F: FnOnce(Option<&[f64]>) -> Result<(Vec<f64>, QuantDiag, Vec<f64>)>,
{
    let (levels, diag, alpha) = solve(state.warm_alpha.as_deref())?;
    state.warm_alpha = Some(alpha);
    Ok((levels, diag))
}

fn lasso_cfg(opts: &QuantOptions) -> lasso::LassoConfig {
    lasso::LassoConfig {
        lambda1: opts.lambda1,
        lambda2: 0.0,
        max_epochs: opts.max_epochs,
        tol: opts.tol,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// Lasso family (eq 6 / Algorithm 1 / eq 13)
// ---------------------------------------------------------------------

struct L1Solver {
    with_refit: bool,
}

impl L1Solver {
    fn solve_with(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        warm: Option<&[f64]>,
    ) -> Result<(Vec<f64>, QuantDiag, Vec<f64>)> {
        let basis = prep.basis();
        let w = &prep.unique().values;
        let sol = lasso::solve(basis, w, &lasso_cfg(opts), warm)?;
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: sol.converged,
            lambda1: opts.lambda1,
            nnz: sol.nnz(),
            unstable: sol.unstable,
            empty_cluster_events: 0,
        };
        let levels = if self.with_refit {
            let support = sol.support();
            refit::refit_fast(basis, w, &support, None)?.reconstruction
        } else {
            basis.apply(&sol.alpha)
        };
        Ok((levels, diag, sol.alpha))
    }
}

impl QuantSolver for L1Solver {
    fn method(&self) -> QuantMethod {
        if self.with_refit {
            QuantMethod::L1LeastSquare
        } else {
            QuantMethod::L1
        }
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let (levels, diag, _) = self.solve_with(prep, opts, None)?;
        Ok((levels, diag))
    }

    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        step_with_warm(state, |warm| self.solve_with(prep, opts, warm))
    }
}

struct L1L2Solver;

impl L1L2Solver {
    fn solve_with(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        warm: Option<&[f64]>,
    ) -> Result<(Vec<f64>, QuantDiag, Vec<f64>)> {
        let basis = prep.basis();
        let w = &prep.unique().values;
        let cfg = lasso::LassoConfig { lambda2: opts.lambda2, ..lasso_cfg(opts) };
        let sol = lasso::solve(basis, w, &cfg, warm)?;
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: sol.converged,
            lambda1: opts.lambda1,
            nnz: sol.nnz(),
            unstable: sol.unstable,
            empty_cluster_events: 0,
        };
        // Fig 4 compares l1 vs l1+l2 without the LS refit; honor opts.refit
        // for users who want Algorithm-1 style output.
        let levels = if opts.refit {
            refit::refit_fast(basis, w, &sol.support(), None)?.reconstruction
        } else {
            basis.apply(&sol.alpha)
        };
        Ok((levels, diag, sol.alpha))
    }
}

impl QuantSolver for L1L2Solver {
    fn method(&self) -> QuantMethod {
        QuantMethod::L1L2
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let (levels, diag, _) = self.solve_with(prep, opts, None)?;
        Ok((levels, diag))
    }

    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        step_with_warm(state, |warm| self.solve_with(prep, opts, warm))
    }
}

// ---------------------------------------------------------------------
// l0 best-subset (eq 16)
// ---------------------------------------------------------------------

struct L0Solver;

impl QuantSolver for L0Solver {
    fn method(&self) -> QuantMethod {
        QuantMethod::L0
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let cfg = l0::L0Config {
            max_nnz: opts.target_values,
            max_epochs: opts.max_epochs,
            tol: opts.tol,
            ..Default::default()
        };
        let sol = l0::solve_l0(basis, &prep.unique().values, &cfg)?;
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: !sol.unstable,
            lambda1: sol.lambda0,
            nnz: sol.nnz,
            unstable: sol.unstable,
            empty_cluster_events: 0,
        };
        Ok((basis.apply(&sol.alpha), diag))
    }
}

// ---------------------------------------------------------------------
// Iterative l1 (Algorithm 2)
// ---------------------------------------------------------------------

struct IterativeSolver;

impl IterativeSolver {
    fn solve_warm(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        warm: Option<&[f64]>,
    ) -> Result<(Vec<f64>, QuantDiag, Vec<f64>)> {
        let basis = prep.basis();
        let cfg = iterative::IterativeConfig {
            target_nnz: opts.target_values,
            lambda_start: opts.lambda1.max(1e-9),
            max_steps: opts.max_lambda_steps,
            cd: lasso_cfg(opts),
            accelerate: 1.0,
        };
        let sol = iterative::solve_iterative_warm(basis, &prep.unique().values, &cfg, warm)?;
        let diag = QuantDiag {
            iterations: sol.epochs,
            converged: sol.reached_target,
            lambda1: sol.lambda1,
            nnz: sol.nnz,
            unstable: !sol.reached_target,
            empty_cluster_events: 0,
        };
        let mut rec = basis.apply(&sol.alpha);
        if !sol.reached_target {
            // The λ path can jump past the requested count (paper: "might
            // fail to optimize to exact l values"). Enforce the library's
            // contract with a Ward merge of the surplus levels.
            rec = merge::merge_to_target(&rec, None, opts.target_values);
        }
        Ok((rec, diag, sol.alpha))
    }
}

impl QuantSolver for IterativeSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::IterativeL1
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let (levels, diag, _) = self.solve_warm(prep, opts, None)?;
        Ok((levels, diag))
    }

    fn solve_path_step(
        &self,
        prep: &PreparedInput,
        opts: &QuantOptions,
        state: &mut SweepState,
    ) -> Result<(Vec<f64>, QuantDiag)> {
        step_with_warm(state, |warm| self.solve_warm(prep, opts, warm))
    }
}

// ---------------------------------------------------------------------
// Cluster-based least squares (Algorithm 3) and clustering baselines
// ---------------------------------------------------------------------

struct ClusterLsSolver;

impl QuantSolver for ClusterLsSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::ClusterLs
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let cfg = cluster_ls::ClusterLsConfig {
            l: opts.target_values,
            kmeans: KMeansConfig {
                k: opts.target_values,
                restarts: opts.kmeans_restarts,
                max_iters: opts.max_iters,
                tol: 1e-10,
                seed: opts.seed,
                ..Default::default()
            },
            // Weighted: the paper's eq 19 is written over ŵ unweighted, but
            // its experimental claim (Alg 3 ≥ k-means on the full-vector
            // loss) only holds when multiplicities weight both the
            // partition and the LS values; the paper-literal unweighted
            // variant stays available via ClusterLsConfig. See
            // EXPERIMENTS.md Fig 5 notes.
            weighted: true,
        };
        let sol = cluster_ls::solve_cluster_ls(
            basis,
            &prep.unique().values,
            Some(prep.weights()),
            &cfg,
        )?;
        let diag = QuantDiag {
            iterations: sol.iterations,
            converged: true,
            lambda1: 0.0,
            nnz: sol.levels.len(),
            unstable: false,
            empty_cluster_events: sol.empty_cluster_events,
        };
        Ok((sol.reconstruction, diag))
    }
}

struct KMeansSolver;

impl QuantSolver for KMeansSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::KMeans
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let cfg = KMeansConfig {
            k: opts.target_values,
            restarts: opts.kmeans_restarts,
            max_iters: opts.max_iters,
            tol: 1e-10,
            seed: opts.seed,
            ..Default::default()
        };
        let (rec, iters, empty) =
            cluster_ls::kmeans_quantize_levels(prep.basis(), Some(prep.weights()), &cfg)?;
        let diag = QuantDiag {
            iterations: iters,
            converged: true,
            lambda1: 0.0,
            // Report the achieved level count, not the request: clusters
            // can collapse to fewer distinct centroids.
            nnz: distinct_count_exact(&rec),
            unstable: empty > 0,
            empty_cluster_events: empty,
        };
        Ok((rec, diag))
    }
}

struct KMeansExactSolver;

impl QuantSolver for KMeansExactSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::KMeansExact
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let r = kmeans_dp(basis.values(), Some(prep.weights()), opts.target_values)?;
        let rec: Vec<f64> = basis
            .values()
            .iter()
            .zip(&r.assignment)
            .map(|(_, &a)| r.centroids[a])
            .collect();
        let diag = QuantDiag {
            iterations: 1,
            converged: true,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct GmmSolver;

impl QuantSolver for GmmSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::Gmm
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let cfg = GmmConfig {
            k: opts.target_values,
            max_iters: opts.max_iters,
            tol: 1e-9,
            seed: opts.seed,
        };
        let r = gmm_1d(prep.basis().values(), Some(prep.weights()), &cfg)?;
        let rec: Vec<f64> = r.assignment.iter().map(|&a| r.means[a]).collect();
        let diag = QuantDiag {
            iterations: r.iterations,
            converged: r.converged,
            lambda1: 0.0,
            nnz: r.means.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct DataTransformSolver;

impl QuantSolver for DataTransformSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::DataTransform
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let cfg = DataTransformConfig {
            k: opts.target_values,
            restarts: opts.kmeans_restarts,
            max_iters: opts.max_iters,
            seed: opts.seed,
            ..Default::default()
        };
        let r = data_transform_cluster(basis.values(), Some(prep.weights()), &cfg)?;
        let rec: Vec<f64> = basis
            .values()
            .iter()
            .map(|&v| r.centroids[assign_sorted(v, &r.centroids)])
            .collect();
        let diag = QuantDiag {
            iterations: r.iterations,
            converged: true,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct TvExactSolver;

impl QuantSolver for TvExactSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::TvExact
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let rec = tv_exact::solve_tv_exact(basis, &prep.unique().values, opts.lambda1)?;
        let nnz = {
            // Count level jumps (α support) for diagnostics.
            let mut prev = 0.0;
            let mut c = 0usize;
            for (&x, &d) in rec.iter().zip(basis.diffs()) {
                if d != 0.0 && (x - prev).abs() > 1e-12 {
                    c += 1;
                }
                prev = x;
            }
            c
        };
        let diag = QuantDiag {
            iterations: 1, // exact, single pass
            converged: true,
            lambda1: opts.lambda1,
            nnz,
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct AgglomerativeSolver;

impl QuantSolver for AgglomerativeSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::Agglomerative
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let basis = prep.basis();
        let r = crate::cluster::agglomerative::agglomerative_1d(
            basis.values(),
            Some(prep.weights()),
            opts.target_values,
        )?;
        let rec: Vec<f64> = basis
            .values()
            .iter()
            .zip(&r.assignment)
            .map(|(_, &a)| r.centroids[a])
            .collect();
        let diag = QuantDiag {
            iterations: basis.m().saturating_sub(r.centroids.len()),
            converged: true,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

struct FcmSolver;

impl QuantSolver for FcmSolver {
    fn method(&self) -> QuantMethod {
        QuantMethod::FuzzyCMeans
    }

    fn solve(&self, prep: &PreparedInput, opts: &QuantOptions) -> Result<(Vec<f64>, QuantDiag)> {
        let cfg = crate::cluster::fuzzy_cmeans::FcmConfig {
            k: opts.target_values,
            max_iters: opts.max_iters,
            seed: opts.seed,
            ..Default::default()
        };
        let r = crate::cluster::fuzzy_cmeans::fuzzy_cmeans_1d(
            prep.basis().values(),
            Some(prep.weights()),
            &cfg,
        )?;
        let rec: Vec<f64> = r.assignment.iter().map(|&a| r.centroids[a]).collect();
        let diag = QuantDiag {
            iterations: r.iterations,
            converged: r.converged,
            lambda1: 0.0,
            nnz: r.centroids.len(),
            unstable: false,
            empty_cluster_events: 0,
        };
        Ok((rec, diag))
    }
}

// ---------------------------------------------------------------------
// Method → solver table
// ---------------------------------------------------------------------

/// Registration table: one entry per [`QuantMethod`], same order as
/// [`QuantMethod::ALL`].
static SOLVERS: [&dyn QuantSolver; 13] = [
    &L1Solver { with_refit: false },
    &L1Solver { with_refit: true },
    &L1L2Solver,
    &L0Solver,
    &IterativeSolver,
    &ClusterLsSolver,
    &KMeansSolver,
    &GmmSolver,
    &DataTransformSolver,
    &KMeansExactSolver,
    &TvExactSolver,
    &AgglomerativeSolver,
    &FcmSolver,
];

/// Resolve the solver registered for `method`.
pub fn solver_for(method: QuantMethod) -> &'static dyn QuantSolver {
    SOLVERS
        .iter()
        .copied()
        .find(|s| s.method() == method)
        .expect("every QuantMethod has a registered solver")
}

// ---------------------------------------------------------------------
// Pipeline entry points
// ---------------------------------------------------------------------

/// Solve stage only: quantize a prepared input with the chosen method.
pub fn quantize_prepared(
    prep: &PreparedInput,
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<QuantOutput> {
    let (levels, diag) = solver_for(method).solve(prep, opts)?;
    prep.finish(&levels, opts.clamp, diag)
}

/// Per-stage wall times of one pipeline run (coordinator metrics).
#[derive(Debug, Clone, Copy)]
pub struct StageTimings {
    /// Prepare stage (unique decomposition + basis + cached sums).
    pub prepare: Duration,
    /// Solve stage (method solver + recovery + finalize).
    pub solve: Duration,
}

/// One-shot quantize that reports per-stage timings.
pub fn quantize_timed(
    w: &[f64],
    method: QuantMethod,
    opts: &QuantOptions,
) -> Result<(QuantOutput, StageTimings)> {
    let t0 = Instant::now();
    let prep = PreparedInput::new(w)?;
    let prepare = t0.elapsed();
    let t1 = Instant::now();
    let out = quantize_prepared(&prep, method, opts)?;
    let solve = t1.elapsed();
    Ok((out, StageTimings { prepare, solve }))
}

/// How many threads a batch of `n` independent inputs should fan across.
fn batch_threads(n: usize) -> usize {
    let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
    cores.min(n).min(8)
}

/// Quantize many vectors with the same method/options. Inputs are
/// independent, so the batch fans across scoped threads; results come
/// back in input order and are bitwise-identical to per-call
/// [`quantize`](super::quantize).
pub fn quantize_batch(
    inputs: &[Vec<f64>],
    method: QuantMethod,
    opts: &QuantOptions,
) -> Vec<Result<QuantOutput>> {
    let threads = batch_threads(inputs.len());
    if threads <= 1 {
        return inputs.iter().map(|w| super::quantize(w, method, opts)).collect();
    }
    let mut results: Vec<Option<Result<QuantOutput>>> = Vec::with_capacity(inputs.len());
    results.resize_with(inputs.len(), || None);
    let chunk = inputs.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (slots, ins) in results.chunks_mut(chunk).zip(inputs.chunks(chunk)) {
            s.spawn(move || {
                for (slot, w) in slots.iter_mut().zip(ins) {
                    *slot = Some(super::quantize(w, method, opts));
                }
            });
        }
    });
    results
        .into_iter()
        .map(|r| r.expect("batch worker filled every slot"))
        .collect()
}

/// λ sweep over one prepared input with warm starts along the path
/// (lasso-family and iterative solvers reuse the previous α). `base`
/// supplies every option except `lambda1`, which each grid point
/// overrides.
pub fn quantize_sweep(
    prep: &PreparedInput,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
) -> Result<Vec<QuantOutput>> {
    quantize_sweep_with(prep, method, lambdas, base, true)
}

/// λ sweep with explicit warm-start control. `warm_start = false` runs
/// every grid point cold, which is bitwise-identical to calling
/// [`quantize`](super::quantize) per λ (minus the repeated prepare).
pub fn quantize_sweep_with(
    prep: &PreparedInput,
    method: QuantMethod,
    lambdas: &[f64],
    base: &QuantOptions,
    warm_start: bool,
) -> Result<Vec<QuantOutput>> {
    let solver = solver_for(method);
    let mut state = SweepState::default();
    let mut outs = Vec::with_capacity(lambdas.len());
    for &lambda in lambdas {
        let opts = QuantOptions { lambda1: lambda, ..base.clone() };
        let (levels, diag) = if warm_start {
            solver.solve_path_step(prep, &opts, &mut state)?
        } else {
            solver.solve(prep, &opts)?
        };
        outs.push(prep.finish(&levels, opts.clamp, diag)?);
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;

    fn clustered(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            let center = [0.1, 0.35, 0.6, 0.9][i % 4];
            // Round so repeats occur (multiplicities > 1).
            v.push(((center + rng.normal_with(0.0, 0.02)) * 200.0).round() / 200.0);
        }
        v
    }

    #[test]
    fn every_method_resolves_to_its_own_solver() {
        for m in QuantMethod::ALL {
            assert_eq!(solver_for(m).method(), m, "{m:?}");
            assert_eq!(m.solver().method(), m, "{m:?}");
        }
    }

    #[test]
    fn prepared_pipeline_matches_one_shot() {
        let data = clustered(80, 1);
        let prep = PreparedInput::new(&data).unwrap();
        for m in QuantMethod::ALL {
            let opts = QuantOptions {
                lambda1: 0.01,
                lambda2: 4e-5,
                target_values: 4,
                ..Default::default()
            };
            let staged = quantize_prepared(&prep, m, &opts).unwrap();
            let one_shot = super::super::quantize(&data, m, &opts).unwrap();
            assert_eq!(staged.values, one_shot.values, "{m:?}");
            assert_eq!(staged.levels, one_shot.levels, "{m:?}");
            assert_eq!(staged.l2_loss.to_bits(), one_shot.l2_loss.to_bits(), "{m:?}");
        }
    }

    #[test]
    fn prepared_input_caches_are_consistent() {
        let data = clustered(60, 2);
        let prep = PreparedInput::new(&data).unwrap();
        let m = prep.m();
        assert_eq!(prep.len(), data.len());
        assert!(!prep.is_empty());
        // Suffix weights against a naive recomputation.
        for j in 0..=m {
            let naive: f64 = prep.weights()[j..].iter().sum();
            assert!((prep.weight_suffix(j) - naive).abs() < 1e-9);
        }
        // Segment means against naive means.
        let vals = &prep.unique().values;
        for (a, b) in [(0, m), (0, m / 2), (m / 3, m)] {
            let naive = vals[a..b].iter().sum::<f64>() / (b - a) as f64;
            assert!((prep.segment_mean(a, b) - naive).abs() < 1e-9);
        }
        assert_eq!(prep.segment_mean(3, 3), 0.0);
    }

    #[test]
    fn kmeans_diag_reports_achieved_levels_not_request() {
        // Two tight value groups but target_values = 5: clusters collapse,
        // and nnz must report the achieved count.
        let mut data = vec![1.0; 10];
        data.extend(vec![9.0; 10]);
        let opts = QuantOptions { target_values: 5, ..Default::default() };
        let out = super::super::quantize(&data, QuantMethod::KMeans, &opts).unwrap();
        assert_eq!(out.diag.nnz, out.distinct_values());
        assert!(out.diag.nnz <= 2, "two-level data, nnz={}", out.diag.nnz);
    }

    #[test]
    fn batch_handles_bad_inputs_per_slot() {
        let inputs = vec![clustered(30, 3), vec![], clustered(30, 4)];
        let opts = QuantOptions { target_values: 3, ..Default::default() };
        let rs = quantize_batch(&inputs, QuantMethod::KMeans, &opts);
        assert_eq!(rs.len(), 3);
        assert!(rs[0].is_ok());
        assert!(rs[1].is_err(), "empty vector must fail its own slot only");
        assert!(rs[2].is_ok());
    }

    #[test]
    fn sweep_outputs_one_per_lambda_in_order() {
        let data = clustered(50, 5);
        let prep = PreparedInput::new(&data).unwrap();
        let lambdas = [1e-4, 1e-3, 1e-2, 1e-1];
        let outs =
            quantize_sweep(&prep, QuantMethod::L1, &lambdas, &QuantOptions::default()).unwrap();
        assert_eq!(outs.len(), lambdas.len());
        for (o, &l) in outs.iter().zip(&lambdas) {
            assert_eq!(o.diag.lambda1, l);
            assert_eq!(o.values.len(), data.len());
        }
        // Three decades of λ ⇒ the path ends much sparser than it starts.
        assert!(
            outs.last().unwrap().distinct_values() <= outs.first().unwrap().distinct_values(),
            "λ path did not sparsify"
        );
    }

    #[test]
    fn timed_quantize_reports_stages() {
        let data = clustered(64, 6);
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let (out, t) = quantize_timed(&data, QuantMethod::ClusterLs, &opts).unwrap();
        assert_eq!(out.values.len(), data.len());
        // Durations are non-negative by construction; just make sure the
        // call returns something sane.
        assert!(t.prepare + t.solve < Duration::from_secs(60));
    }
}
