//! Matrix/tensor quantization wrappers (paper §3.1: "If the data is coded
//! in a matrix … we can simply 'flatten' the matrix into a vector to
//! perform quantization, and then turn it back to the original shape").
//!
//! Beyond the paper's per-tensor flattening, per-row and per-column
//! grouping are provided — the standard practice for neural-network layers
//! (per-output-channel codebooks), and the natural first step toward the
//! paper's stated future work on higher-dimensional quantization.

use super::{api, QuantMethod, QuantOptions, QuantOutput};
use crate::linalg::matrix::Matrix;
use crate::Result;

/// How to group matrix entries into quantization problems.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Grouping {
    /// One codebook for the whole matrix (the paper's flattening).
    #[default]
    PerTensor,
    /// One codebook per row.
    PerRow,
    /// One codebook per column.
    PerColumn,
}

/// Result of a matrix quantization.
#[derive(Debug, Clone)]
pub struct MatrixQuant {
    /// The quantized matrix (original shape).
    pub matrix: Matrix,
    /// Total squared-l2 loss across all groups.
    pub l2_loss: f64,
    /// Distinct values per group.
    pub group_levels: Vec<usize>,
    /// Per-group outputs (diagnostics).
    pub outputs: Vec<QuantOutput>,
}

/// Quantize a matrix with the chosen method and grouping. Groups are
/// independent, so per-row and per-column runs fan across the scoped
/// batch executor (the same fan-out [`super::quantize_batch`] uses)
/// instead of a serial loop; results are identical to quantizing each
/// group one by one.
///
/// **Legacy**: thin shim over the [`super::api`] core; prefer
/// [`super::api::QuantRequest::matrix`] for new code — it returns the
/// compact per-group codebooks without materializing a full matrix.
pub fn quantize_matrix(
    m: &Matrix,
    method: QuantMethod,
    opts: &QuantOptions,
    grouping: Grouping,
) -> Result<MatrixQuant> {
    let groups = api::matrix_groups(m, grouping)?;
    let items = api::batch_core_shared_f64(&groups, method, opts, api::OutputForm::Codebook);
    // Propagate the first failing group's error in group order, matching
    // the historical serial loop's early return.
    let mut outputs = Vec::with_capacity(items.len());
    for item in items {
        outputs.push(item?.into_output64());
    }
    let mut out = Matrix::zeros(m.rows(), m.cols());
    match grouping {
        Grouping::PerTensor => out.data_mut().copy_from_slice(&outputs[0].values),
        Grouping::PerRow => {
            for (i, q) in outputs.iter().enumerate() {
                out.row_mut(i).copy_from_slice(&q.values);
            }
        }
        Grouping::PerColumn => {
            for (j, q) in outputs.iter().enumerate() {
                for i in 0..m.rows() {
                    out[(i, j)] = q.values[i];
                }
            }
        }
    }
    let l2_loss = outputs.iter().map(|o| o.l2_loss).sum();
    let group_levels = outputs.iter().map(|o| o.distinct_values()).collect();
    Ok(MatrixQuant { matrix: out, l2_loss, group_levels, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg32;
    use crate::quant::quantize;

    fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal_with(0.0, 1.0))
    }

    fn opts(k: usize) -> QuantOptions {
        QuantOptions { target_values: k, ..Default::default() }
    }

    #[test]
    fn per_tensor_matches_flatten() {
        let m = sample_matrix(8, 5, 1);
        let mq = quantize_matrix(&m, QuantMethod::KMeans, &opts(4), Grouping::PerTensor).unwrap();
        let direct = quantize(m.data(), QuantMethod::KMeans, &opts(4)).unwrap();
        assert_eq!(mq.matrix.data(), direct.values.as_slice());
        assert_eq!(mq.group_levels, vec![direct.distinct_values()]);
    }

    #[test]
    fn per_row_respects_target_per_row() {
        let m = sample_matrix(6, 20, 2);
        let mq = quantize_matrix(&m, QuantMethod::KMeans, &opts(3), Grouping::PerRow).unwrap();
        assert_eq!(mq.group_levels.len(), 6);
        for (i, &g) in mq.group_levels.iter().enumerate() {
            assert!(g <= 3, "row {i} has {g} levels");
            let row_distinct =
                crate::linalg::stats::distinct_count_exact(mq.matrix.row(i));
            assert!(row_distinct <= 3);
        }
    }

    #[test]
    fn per_column_shape_preserved() {
        let m = sample_matrix(10, 4, 3);
        let mq = quantize_matrix(&m, QuantMethod::ClusterLs, &opts(2), Grouping::PerColumn).unwrap();
        assert_eq!((mq.matrix.rows(), mq.matrix.cols()), (10, 4));
        assert_eq!(mq.group_levels.len(), 4);
        for j in 0..4 {
            let col = mq.matrix.col(j);
            assert!(crate::linalg::stats::distinct_count_exact(&col) <= 2);
        }
    }

    #[test]
    fn finer_grouping_never_hurts_much() {
        // Per-row codebooks have at least as much expressive power in
        // total; with equal per-group budgets the summed loss should
        // usually drop (always for exact methods on this data).
        let m = sample_matrix(8, 64, 4);
        let per_tensor =
            quantize_matrix(&m, QuantMethod::KMeansExact, &opts(4), Grouping::PerTensor).unwrap();
        let per_row =
            quantize_matrix(&m, QuantMethod::KMeansExact, &opts(4), Grouping::PerRow).unwrap();
        assert!(per_row.l2_loss <= per_tensor.l2_loss + 1e-9);
    }

    #[test]
    fn rejects_empty() {
        let m = Matrix::zeros(0, 0);
        assert!(quantize_matrix(&m, QuantMethod::KMeans, &opts(2), Grouping::PerTensor).is_err());
    }
}
