//! Greedy level merging (Ward-style agglomeration on the level axis).
//!
//! Used as the documented fallback when Algorithm 2's λ path cannot land
//! on the requested count (the paper acknowledges it "might fail to
//! optimize to exact l values"): adjacent levels of the piecewise-constant
//! reconstruction are merged — cheapest weighted-SSE increase first —
//! until the count bound holds. Also exposed as a standalone agglomerative
//! quantizer building block (cf. Xiang & Joy 1994, the paper's ref [11]).

use crate::linalg::scalar::Scalar;

/// Merge the levels of a piecewise-constant reconstruction (over sorted
/// unique values) down to at most `target` distinct levels. `weights` are
/// per-position multiplicities (None = 1 each). Returns the new
/// reconstruction. Lane-generic ([`Scalar`]): the f32 instantiation is the
/// count-enforcement fallback of the single-precision fast path.
pub fn merge_to_target<T: Scalar>(
    reconstruction: &[T],
    weights: Option<&[T]>,
    target: usize,
) -> Vec<T> {
    assert!(target >= 1);
    let m = reconstruction.len();
    if m == 0 {
        return Vec::new();
    }
    // Segment list: (start, end_exclusive, weight, weighted mean).
    let mut segs: Vec<(usize, usize, T, T)> = Vec::new();
    let mut start = 0usize;
    for i in 1..=m {
        if i == m || reconstruction[i] != reconstruction[start] {
            let (mut wsum, mut xsum) = (T::ZERO, T::ZERO);
            for j in start..i {
                let w = weights.map_or(T::ONE, |ws| ws[j]);
                wsum += w;
                xsum += w * reconstruction[j];
            }
            let mean = if wsum > T::ZERO { xsum / wsum } else { reconstruction[start] };
            segs.push((start, i, wsum, mean));
            start = i;
        }
    }

    // Greedy adjacent merges: Ward cost = W1·W2/(W1+W2)·(m1−m2)².
    while segs.len() > target {
        let mut best = 0usize;
        let mut best_cost = T::INFINITY;
        for i in 0..segs.len() - 1 {
            let (_, _, w1, m1) = segs[i];
            let (_, _, w2, m2) = segs[i + 1];
            let denom = w1 + w2;
            let cost =
                if denom > T::ZERO { w1 * w2 / denom * (m1 - m2) * (m1 - m2) } else { T::ZERO };
            if cost < best_cost {
                best_cost = cost;
                best = i;
            }
        }
        let (s1, _, w1, m1) = segs[best];
        let (_, e2, w2, m2) = segs[best + 1];
        let w = w1 + w2;
        let mean = if w > T::ZERO { (w1 * m1 + w2 * m2) / w } else { m1 };
        segs[best] = (s1, e2, w, mean);
        segs.remove(best + 1);
    }

    let mut out = vec![T::ZERO; m];
    for &(s, e, _, mean) in &segs {
        for o in &mut out[s..e] {
            *o = mean;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::stats::distinct_count_exact;

    #[test]
    fn already_under_target_is_identity() {
        let rec = vec![1.0, 1.0, 2.0, 2.0];
        assert_eq!(merge_to_target(&rec, None, 2), rec);
        assert_eq!(merge_to_target(&rec, None, 5), rec);
    }

    #[test]
    fn merges_to_exact_count() {
        let rec = vec![0.0, 1.0, 1.1, 5.0, 9.0];
        for target in [1usize, 2, 3, 4] {
            let merged = merge_to_target(&rec, None, target);
            assert!(distinct_count_exact(&merged) <= target, "target {target}");
            assert_eq!(merged.len(), rec.len());
        }
    }

    #[test]
    fn merges_closest_pair_first() {
        let rec = vec![0.0, 1.0, 1.05, 10.0];
        let merged = merge_to_target(&rec, None, 3);
        // 1.0 and 1.05 merge; 0.0 and 10.0 survive.
        assert_eq!(merged[0], 0.0);
        assert_eq!(merged[3], 10.0);
        assert!((merged[1] - 1.025).abs() < 1e-12);
        assert_eq!(merged[1], merged[2]);
    }

    #[test]
    fn respects_weights() {
        // Heavily weighted level pulls the merged mean.
        let rec = vec![0.0, 10.0];
        let merged = merge_to_target(&rec, Some(&[99.0, 1.0]), 1);
        assert!(merged[0] < 0.2, "mean should sit near the heavy level, got {}", merged[0]);
    }

    #[test]
    fn target_one_gives_global_mean() {
        let rec = vec![1.0, 2.0, 3.0, 6.0];
        let merged = merge_to_target(&rec, None, 1);
        for v in &merged {
            assert!((v - 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_input() {
        assert!(merge_to_target::<f64>(&[], None, 3).is_empty());
    }

    #[test]
    fn f32_lane_merges_like_f64() {
        let rec = vec![0.0f32, 1.0, 1.05, 10.0];
        let merged = merge_to_target(&rec, None, 3);
        assert_eq!(merged[0], 0.0);
        assert_eq!(merged[3], 10.0);
        assert_eq!(merged[1], merged[2]);
        assert!((merged[1] - 1.025).abs() < 1e-5);
    }
}
