//! Greedy level merging (Ward-style agglomeration on the level axis).
//!
//! Used as the documented fallback when Algorithm 2's λ path cannot land
//! on the requested count (the paper acknowledges it "might fail to
//! optimize to exact l values"): adjacent levels of the piecewise-constant
//! reconstruction are merged — cheapest weighted-SSE increase first —
//! until the count bound holds. Also exposed as a standalone agglomerative
//! quantizer building block (cf. Xiang & Joy 1994, the paper's ref [11]).
//!
//! [`merge_to_entropy_budget`] is the entropy-constrained variant (ECSQ,
//! after "Towards the Limit of Network Quantization", arXiv 1612.01543):
//! instead of a level-count bound it enforces a *coded-size* bound — merge
//! the pair with the smallest weighted-distortion increase **per coded bit
//! saved** until the index entropy drops to the requested bits/element.

use crate::linalg::scalar::Scalar;

/// Merge the levels of a piecewise-constant reconstruction (over sorted
/// unique values) down to at most `target` distinct levels. `weights` are
/// per-position multiplicities (None = 1 each). Returns the new
/// reconstruction. Lane-generic ([`Scalar`]): the f32 instantiation is the
/// count-enforcement fallback of the single-precision fast path.
pub fn merge_to_target<T: Scalar>(
    reconstruction: &[T],
    weights: Option<&[T]>,
    target: usize,
) -> Vec<T> {
    assert!(target >= 1);
    let m = reconstruction.len();
    if m == 0 {
        return Vec::new();
    }
    // Segment list: (start, end_exclusive, weight, weighted mean).
    let mut segs: Vec<(usize, usize, T, T)> = Vec::new();
    let mut start = 0usize;
    for i in 1..=m {
        if i == m || reconstruction[i] != reconstruction[start] {
            let (mut wsum, mut xsum) = (T::ZERO, T::ZERO);
            for j in start..i {
                let w = weights.map_or(T::ONE, |ws| ws[j]);
                wsum += w;
                xsum += w * reconstruction[j];
            }
            let mean = if wsum > T::ZERO { xsum / wsum } else { reconstruction[start] };
            segs.push((start, i, wsum, mean));
            start = i;
        }
    }

    // Greedy adjacent merges: Ward cost = W1·W2/(W1+W2)·(m1−m2)².
    while segs.len() > target {
        let mut best = 0usize;
        let mut best_cost = T::INFINITY;
        for i in 0..segs.len() - 1 {
            let (_, _, w1, m1) = segs[i];
            let (_, _, w2, m2) = segs[i + 1];
            let denom = w1 + w2;
            let cost =
                if denom > T::ZERO { w1 * w2 / denom * (m1 - m2) * (m1 - m2) } else { T::ZERO };
            if cost < best_cost {
                best_cost = cost;
                best = i;
            }
        }
        let (s1, _, w1, m1) = segs[best];
        let (_, e2, w2, m2) = segs[best + 1];
        let w = w1 + w2;
        let mean = if w > T::ZERO { (w1 * m1 + w2 * m2) / w } else { m1 };
        segs[best] = (s1, e2, w, mean);
        segs.remove(best + 1);
    }

    let mut out = vec![T::ZERO; m];
    for &(s, e, _, mean) in &segs {
        for o in &mut out[s..e] {
            *o = mean;
        }
    }
    out
}

/// Index entropy of a per-level reconstruction in **bits per element**:
/// runs of equal reconstructed values form the codebook entries, and each
/// original element (level multiplicities `counts`) draws one index, so
/// `H = −Σ_k p_k log₂ p_k` with `p_k = n_k / n`. This is the first-order
/// achievable coded size of the index stream and the quantity
/// [`merge_to_entropy_budget`] constrains. Accumulated in f64 on both
/// lanes.
pub fn index_entropy_bits<T: Scalar>(reconstruction: &[T], counts: &[usize]) -> f64 {
    debug_assert_eq!(reconstruction.len(), counts.len());
    let m = reconstruction.len();
    if m == 0 {
        return 0.0;
    }
    let n: f64 = counts.iter().map(|&c| c as f64).sum();
    if n <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0f64;
    let mut start = 0usize;
    for i in 1..=m {
        if i == m || reconstruction[i] != reconstruction[start] {
            let nk: f64 = counts[start..i].iter().map(|&c| c as f64).sum();
            if nk > 0.0 {
                let p = nk / n;
                h -= p * p.log2();
            }
            start = i;
        }
    }
    h
}

/// Entropy-constrained level merge (ECSQ greedy, arXiv 1612.01543 §3).
///
/// Merges adjacent levels of a piecewise-constant reconstruction over the
/// sorted unique values `values` until the index entropy
/// ([`index_entropy_bits`]) is at most `budget_bits` bits/element. The
/// merge order is distortion-rate greedy: at each step the adjacent pair
/// with the smallest **weighted-SSE increase per coded bit saved** merges,
/// and the merged segment is re-represented by its weighted mean (the
/// distortion-optimal representative). Distortion is measured against
/// `values` under `level_weights` (importance or multiplicities); coded
/// size uses the element multiplicities `counts`.
///
/// Properties the test suite pins:
/// * if the current entropy already meets the budget the input is returned
///   **unchanged** (bitwise) — the pass is a no-op for generous budgets;
/// * every merge strictly reduces the total coded size (log-sum
///   concavity), so the greedy terminates and the result's entropy never
///   exceeds the budget (a single level has entropy 0, the floor);
/// * the merge sequence does not depend on the budget — a tighter budget
///   runs a longer prefix of the *same* sequence, so the achieved entropy
///   is monotone in the budget.
///
/// All cost/rate arithmetic is f64 on both lanes (the f32 lane narrows the
/// representatives once at the end), so the two lanes walk the same merge
/// sequence.
pub fn merge_to_entropy_budget<T: Scalar>(
    values: &[T],
    reconstruction: &[T],
    level_weights: &[T],
    counts: &[usize],
    budget_bits: f64,
) -> Vec<T> {
    let m = reconstruction.len();
    debug_assert_eq!(values.len(), m);
    debug_assert_eq!(level_weights.len(), m);
    debug_assert_eq!(counts.len(), m);
    if m == 0 {
        return Vec::new();
    }
    if index_entropy_bits(reconstruction, counts) <= budget_bits {
        return reconstruction.to_vec();
    }

    // Segment list over runs of equal reconstructed values:
    // (start, end_exclusive, n elements, W=Σw, M1=Σw·v, M2=Σw·v², rep q).
    // Distortion of a segment at representative q is the exact weighted
    // SSE against the data: D(q) = M2 − 2q·M1 + q²·W.
    struct Seg {
        start: usize,
        end: usize,
        n: f64,
        w: f64,
        m1: f64,
        m2: f64,
        rep: f64,
    }
    let mut segs: Vec<Seg> = Vec::new();
    let mut start = 0usize;
    for i in 1..=m {
        if i == m || reconstruction[i] != reconstruction[start] {
            let (mut n, mut w, mut m1, mut m2) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
            for j in start..i {
                let wj = level_weights[j].to_f64();
                let vj = values[j].to_f64();
                n += counts[j] as f64;
                w += wj;
                m1 += wj * vj;
                m2 += wj * vj * vj;
            }
            segs.push(Seg { start, end: i, n, w, m1, m2, rep: reconstruction[start].to_f64() });
            start = i;
        }
    }
    let n_total: f64 = segs.iter().map(|s| s.n).sum();

    let entropy = |segs: &[Seg]| -> f64 {
        if n_total <= 0.0 {
            return 0.0;
        }
        segs.iter()
            .filter(|s| s.n > 0.0)
            .map(|s| {
                let p = s.n / n_total;
                -p * p.log2()
            })
            .sum()
    };
    let seg_distortion = |s: &Seg| s.m2 - 2.0 * s.rep * s.m1 + s.rep * s.rep * s.w;
    // Merged representative: the weighted mean (falls back to the
    // element-count mean of the two reps for zero-importance pairs).
    let merged_rep = |a: &Seg, b: &Seg| -> f64 {
        let w = a.w + b.w;
        if w > 0.0 {
            (a.m1 + b.m1) / w
        } else if a.n + b.n > 0.0 {
            (a.n * a.rep + b.n * b.rep) / (a.n + b.n)
        } else {
            a.rep
        }
    };

    while segs.len() > 1 && entropy(&segs) > budget_bits {
        // ΔD / ΔR over adjacent pairs: ΔD from the exact moments, ΔR the
        // coded bits saved n₁log₂(n/n₁) + n₂log₂(n/n₂) − n₁₂log₂(n/n₁₂)
        // (> 0 whenever both sides carry elements).
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for i in 0..segs.len() - 1 {
            let (a, b) = (&segs[i], &segs[i + 1]);
            let q = merged_rep(a, b);
            let d_new = (a.m2 + b.m2) - 2.0 * q * (a.m1 + b.m1) + q * q * (a.w + b.w);
            let dd = d_new - seg_distortion(a) - seg_distortion(b);
            let bits = |n: f64| if n > 0.0 { n * (n_total / n).log2() } else { 0.0 };
            let dr = bits(a.n) + bits(b.n) - bits(a.n + b.n);
            let score = if dr > 0.0 { dd / dr } else { dd };
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        let b = segs.remove(best + 1);
        let a = &mut segs[best];
        a.rep = merged_rep(a, &b);
        a.end = b.end;
        a.n += b.n;
        a.w += b.w;
        a.m1 += b.m1;
        a.m2 += b.m2;
    }

    let mut out = vec![T::ZERO; m];
    for s in &segs {
        let rep = T::from_f64(s.rep);
        for o in &mut out[s.start..s.end] {
            *o = rep;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::stats::distinct_count_exact;

    #[test]
    fn already_under_target_is_identity() {
        let rec = vec![1.0, 1.0, 2.0, 2.0];
        assert_eq!(merge_to_target(&rec, None, 2), rec);
        assert_eq!(merge_to_target(&rec, None, 5), rec);
    }

    #[test]
    fn merges_to_exact_count() {
        let rec = vec![0.0, 1.0, 1.1, 5.0, 9.0];
        for target in [1usize, 2, 3, 4] {
            let merged = merge_to_target(&rec, None, target);
            assert!(distinct_count_exact(&merged) <= target, "target {target}");
            assert_eq!(merged.len(), rec.len());
        }
    }

    #[test]
    fn merges_closest_pair_first() {
        let rec = vec![0.0, 1.0, 1.05, 10.0];
        let merged = merge_to_target(&rec, None, 3);
        // 1.0 and 1.05 merge; 0.0 and 10.0 survive.
        assert_eq!(merged[0], 0.0);
        assert_eq!(merged[3], 10.0);
        assert!((merged[1] - 1.025).abs() < 1e-12);
        assert_eq!(merged[1], merged[2]);
    }

    #[test]
    fn respects_weights() {
        // Heavily weighted level pulls the merged mean.
        let rec = vec![0.0, 10.0];
        let merged = merge_to_target(&rec, Some(&[99.0, 1.0]), 1);
        assert!(merged[0] < 0.2, "mean should sit near the heavy level, got {}", merged[0]);
    }

    #[test]
    fn target_one_gives_global_mean() {
        let rec = vec![1.0, 2.0, 3.0, 6.0];
        let merged = merge_to_target(&rec, None, 1);
        for v in &merged {
            assert!((v - 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_input() {
        assert!(merge_to_target::<f64>(&[], None, 3).is_empty());
    }

    #[test]
    fn f32_lane_merges_like_f64() {
        let rec = vec![0.0f32, 1.0, 1.05, 10.0];
        let merged = merge_to_target(&rec, None, 3);
        assert_eq!(merged[0], 0.0);
        assert_eq!(merged[3], 10.0);
        assert_eq!(merged[1], merged[2]);
        assert!((merged[1] - 1.025).abs() < 1e-5);
    }

    #[test]
    fn entropy_of_uniform_four_levels_is_two_bits() {
        let rec = vec![1.0, 2.0, 3.0, 4.0];
        let h = index_entropy_bits(&rec, &[5, 5, 5, 5]);
        assert!((h - 2.0).abs() < 1e-12);
        // One level = zero bits; skewed distribution < log2(m).
        assert_eq!(index_entropy_bits(&[7.0, 7.0], &[3, 9]), 0.0);
        let skew = index_entropy_bits(&rec, &[97, 1, 1, 1]);
        assert!(skew < 2.0 && skew > 0.0);
    }

    #[test]
    fn generous_budget_is_bitwise_identity() {
        let values = vec![0.0, 1.0, 2.5, 7.0];
        let rec = vec![0.1, 1.1, 2.4, 6.9];
        let w = vec![1.0, 2.0, 1.0, 3.0];
        let counts = vec![1usize, 2, 1, 4];
        let h = index_entropy_bits(&rec, &counts);
        let out = merge_to_entropy_budget(&values, &rec, &w, &counts, h + 0.01);
        assert_eq!(out, rec);
        let out2 = merge_to_entropy_budget(&values, &rec, &w, &counts, 64.0);
        assert_eq!(out2, rec);
    }

    #[test]
    fn zero_budget_collapses_to_one_level() {
        let values = vec![1.0, 2.0, 3.0, 6.0];
        let rec = values.clone();
        let w = vec![1.0; 4];
        let counts = vec![1usize; 4];
        let out = merge_to_entropy_budget(&values, &rec, &w, &counts, 0.0);
        assert_eq!(index_entropy_bits(&out, &counts), 0.0);
        // Single representative = the weighted mean of the data.
        for v in &out {
            assert!((v - 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn budget_is_respected_and_monotone() {
        // Deterministic pseudo-random input; budgets descending. The
        // achieved entropy must stay under each budget and be monotone
        // non-increasing as the budget tightens (nested greedy prefix).
        let mut rng = crate::data::rng::Pcg32::seeded(42);
        let mut values: Vec<f64> = (0..24).map(|_| rng.uniform(-5.0, 5.0)).collect();
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rec = values.clone();
        let w: Vec<f64> = (0..24).map(|_| rng.uniform(0.1, 3.0)).collect();
        let counts: Vec<usize> = (0..24).map(|_| rng.uniform(1.0, 9.0) as usize + 1).collect();
        let full = index_entropy_bits(&rec, &counts);
        assert!(full > 3.0);
        let mut prev_h = f64::INFINITY;
        let mut prev_levels = usize::MAX;
        for budget in [4.0, 3.0, 2.0, 1.0, 0.5, 0.0] {
            let out = merge_to_entropy_budget(&values, &rec, &w, &counts, budget);
            let h = index_entropy_bits(&out, &counts);
            assert!(h <= budget + 1e-9, "budget {budget}: entropy {h}");
            assert!(h <= prev_h + 1e-12, "entropy rose as budget tightened");
            let levels = distinct_count_exact(&out);
            assert!(levels <= prev_levels, "levels rose as budget tightened");
            prev_h = h;
            prev_levels = levels;
        }
    }

    #[test]
    fn heavy_importance_pins_the_merged_representative() {
        // Two close levels with lopsided importance: the merged rep sits at
        // the importance-weighted mean, not the midpoint.
        let values = vec![0.0, 1.0, 50.0];
        let rec = values.clone();
        let w = vec![99.0, 1.0, 1.0];
        let counts = vec![1usize, 1, 1];
        // log2(3) ≈ 1.585; force exactly one merge.
        let out = merge_to_entropy_budget(&values, &rec, &w, &counts, 1.0);
        assert_eq!(out[2], 50.0, "far level must survive");
        assert_eq!(out[0], out[1]);
        assert!((out[0] - 0.01).abs() < 1e-12, "rep {} should be the weighted mean", out[0]);
    }

    #[test]
    fn weighted_distortion_drives_merge_order() {
        // Pair (0,1) is closer in value than (10,13), but carries enormous
        // importance — merging it is costlier per bit, so the wide
        // low-importance pair merges first.
        let values = vec![0.0, 1.0, 10.0, 13.0];
        let rec = values.clone();
        let w = vec![500.0, 500.0, 0.1, 0.1];
        let counts = vec![1usize, 1, 1, 1];
        let out = merge_to_entropy_budget(&values, &rec, &w, &counts, 1.6);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 1.0);
        assert_eq!(out[2], out[3]);
    }

    #[test]
    fn entropy_merge_f32_lane_walks_the_f64_sequence() {
        let values64 = vec![0.0f64, 1.0, 1.05, 10.0, 11.0];
        let values32: Vec<f32> = values64.iter().map(|&x| x as f32).collect();
        let w64 = vec![1.0f64, 2.0, 1.0, 1.0, 3.0];
        let w32: Vec<f32> = w64.iter().map(|&x| x as f32).collect();
        let counts = vec![2usize, 1, 1, 3, 1];
        let out64 = merge_to_entropy_budget(&values64, &values64, &w64, &counts, 1.2);
        let out32 = merge_to_entropy_budget(&values32, &values32, &w32, &counts, 1.2);
        assert_eq!(distinct_count_exact(&out64), distinct_count_exact(&out32));
        for (a, b) in out64.iter().zip(&out32) {
            assert!((*a - f64::from(*b)).abs() < 1e-5);
        }
    }

    #[test]
    fn entropy_merge_empty_input() {
        assert!(merge_to_entropy_budget::<f64>(&[], &[], &[], &[], 1.0).is_empty());
    }
}
