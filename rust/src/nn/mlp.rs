//! From-scratch MLP (S17): the paper's 784-256-128-64-10 fully-connected
//! network (§4.1), with manual backprop. f64 throughout — the quantization
//! experiments care about weight-value distributions, not training speed.

use crate::data::rng::Pcg32;
use crate::linalg::matrix::Matrix;
use crate::quant::qmatrix::QMatrix;
use crate::quant::tensor::Grouping;
use crate::quant::types::{QuantMethod, QuantOptions};
use crate::{Error, Result};

/// One dense layer `y = x W + b` with optional ReLU.
#[derive(Debug, Clone)]
pub struct Dense {
    /// Weights, `in_dim × out_dim`.
    pub w: Matrix,
    /// Bias, `out_dim`.
    pub b: Vec<f64>,
    /// Apply ReLU after the affine map?
    pub relu: bool,
}

impl Dense {
    /// He-initialized layer.
    pub fn new(in_dim: usize, out_dim: usize, relu: bool, rng: &mut Pcg32) -> Dense {
        let std = (2.0 / in_dim as f64).sqrt();
        let w = Matrix::from_fn(in_dim, out_dim, |_, _| rng.normal_with(0.0, std));
        Dense { w, b: vec![0.0; out_dim], relu }
    }
}

/// A feed-forward network.
#[derive(Debug, Clone)]
pub struct Mlp {
    /// The layers, input to output.
    pub layers: Vec<Dense>,
}

/// Cached activations from a forward pass (for backprop).
pub struct ForwardCache {
    /// `acts[0]` is the input batch; `acts[i+1]` the output of layer i
    /// (post-ReLU where applicable).
    pub acts: Vec<Matrix>,
    /// Pre-activation outputs per layer (for the ReLU mask).
    pub pre: Vec<Matrix>,
}

/// Per-layer gradients.
pub struct Gradients {
    /// dL/dW per layer.
    pub dw: Vec<Matrix>,
    /// dL/db per layer.
    pub db: Vec<Vec<f64>>,
}

impl Mlp {
    /// Build the paper's 784-256-128-64-10 network.
    pub fn paper_arch(seed: u64) -> Mlp {
        Mlp::new(&[784, 256, 128, 64, 10], seed)
    }

    /// Build an MLP with the given layer dims (ReLU on all but the last).
    pub fn new(dims: &[usize], seed: u64) -> Mlp {
        assert!(dims.len() >= 2, "need at least input and output dims");
        let mut rng = Pcg32::new(seed, 5150);
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, d)| Dense::new(d[0], d[1], i + 2 < dims.len(), &mut rng))
            .collect();
        Mlp { layers }
    }

    /// Input dimension.
    pub fn in_dim(&self) -> usize {
        self.layers.first().map_or(0, |l| l.w.rows())
    }

    /// Output dimension (number of classes).
    pub fn out_dim(&self) -> usize {
        self.layers.last().map_or(0, |l| l.w.cols())
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.w.rows() * l.w.cols() + l.b.len())
            .sum()
    }

    /// Forward pass over a batch `x` (`B × in_dim`), returning logits and
    /// the activation cache.
    pub fn forward(&self, x: &Matrix) -> Result<(Matrix, ForwardCache)> {
        if x.cols() != self.in_dim() {
            return Err(Error::InvalidInput(format!(
                "mlp: input dim {} vs expected {}",
                x.cols(),
                self.in_dim()
            )));
        }
        let mut acts = vec![x.clone()];
        let mut pre = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let mut z = acts.last().unwrap().matmul(&layer.w)?;
            for i in 0..z.rows() {
                let row = z.row_mut(i);
                for (zj, bj) in row.iter_mut().zip(&layer.b) {
                    *zj += bj;
                }
            }
            pre.push(z.clone());
            if layer.relu {
                for v in z.data_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(z);
        }
        let logits = acts.last().unwrap().clone();
        Ok((logits, ForwardCache { acts, pre }))
    }

    /// Forward without caching (inference).
    pub fn infer(&self, x: &Matrix) -> Result<Matrix> {
        Ok(self.forward(x)?.0)
    }

    /// Softmax cross-entropy loss + gradients for integer labels.
    /// Returns (mean loss, gradients).
    pub fn loss_and_grad(
        &self,
        cache: &ForwardCache,
        logits: &Matrix,
        labels: &[usize],
    ) -> Result<(f64, Gradients)> {
        let b = logits.rows();
        let c = logits.cols();
        if labels.len() != b {
            return Err(Error::InvalidInput("mlp: labels/batch mismatch".into()));
        }
        // Softmax + CE, numerically stable.
        let mut delta = Matrix::zeros(b, c); // dL/dlogits
        let mut loss = 0.0;
        for i in 0..b {
            let row = logits.row(i);
            let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let exps: Vec<f64> = row.iter().map(|&z| (z - mx).exp()).collect();
            let sum: f64 = exps.iter().sum();
            let y = labels[i];
            if y >= c {
                return Err(Error::InvalidInput(format!("mlp: label {y} out of range")));
            }
            loss += -(exps[y] / sum).max(1e-300).ln();
            let drow = delta.row_mut(i);
            for j in 0..c {
                drow[j] = (exps[j] / sum - if j == y { 1.0 } else { 0.0 }) / b as f64;
            }
        }
        loss /= b as f64;

        // Backprop.
        let n_layers = self.layers.len();
        let mut dw = Vec::with_capacity(n_layers);
        let mut db = Vec::with_capacity(n_layers);
        let mut grad = delta; // dL/d(post-activation of current layer)
        for li in (0..n_layers).rev() {
            let layer = &self.layers[li];
            if layer.relu {
                // Mask by pre-activation sign.
                let pre = &cache.pre[li];
                for (g, p) in grad.data_mut().iter_mut().zip(pre.data()) {
                    if *p <= 0.0 {
                        *g = 0.0;
                    }
                }
            }
            let a_in = &cache.acts[li];
            // dW = a_inᵀ grad; db = column sums of grad.
            let dwi = a_in.transpose().matmul(&grad)?;
            let mut dbi = vec![0.0; layer.w.cols()];
            for i in 0..grad.rows() {
                for (s, g) in dbi.iter_mut().zip(grad.row(i)) {
                    *s += g;
                }
            }
            // Propagate: grad_prev = grad Wᵀ.
            if li > 0 {
                grad = grad.matmul(&layer.w.transpose())?;
            }
            dw.push(dwi);
            db.push(dbi);
        }
        dw.reverse();
        db.reverse();
        Ok((loss, Gradients { dw, db }))
    }

    /// Classification accuracy over a batch.
    pub fn accuracy(&self, x: &Matrix, labels: &[usize]) -> Result<f64> {
        let logits = self.infer(x)?;
        let mut correct = 0usize;
        for i in 0..logits.rows() {
            let row = logits.row(i);
            let pred = (0..row.len())
                .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                .unwrap();
            if pred == labels[i] {
                correct += 1;
            }
        }
        Ok(correct as f64 / logits.rows().max(1) as f64)
    }

    /// Flattened copy of one layer's weight matrix (for quantization).
    pub fn layer_weights(&self, li: usize) -> &[f64] {
        self.layers[li].w.data()
    }

    /// Replace one layer's weights from a flattened vector (the paper's
    /// "weights are replaced by the post-quantization matrix").
    pub fn set_layer_weights(&mut self, li: usize, flat: &[f64]) -> Result<()> {
        let w = &mut self.layers[li].w;
        if flat.len() != w.rows() * w.cols() {
            return Err(Error::InvalidInput(format!(
                "set_layer_weights: {} values for {}x{}",
                flat.len(),
                w.rows(),
                w.cols()
            )));
        }
        w.data_mut().copy_from_slice(flat);
        Ok(())
    }

    /// Quantize every layer's weight matrix into a packed residual
    /// cascade ([`QMatrix::residual_levels`]) — the serve-side handoff:
    /// the returned network computes its forward pass straight off the
    /// index planes. Biases stay dense (they are `out_dim` values per
    /// layer, noise next to `in_dim × out_dim` weights).
    pub fn quantize_weights(
        &self,
        grouping: Grouping,
        method: QuantMethod,
        opts: &QuantOptions,
        bit_list: &[u32],
        norm_tol: f64,
    ) -> Result<QuantizedMlp> {
        let mut weights = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            weights.push(QMatrix::residual_levels(
                &layer.w, grouping, method, opts, bit_list, norm_tol,
            )?);
        }
        Ok(QuantizedMlp {
            weights,
            biases: self.layers.iter().map(|l| l.b.clone()).collect(),
            relus: self.layers.iter().map(|l| l.relu).collect(),
        })
    }
}

/// An [`Mlp`] whose weight matrices are packed [`QMatrix`] cascades: the
/// forward pass runs directly on the ⌈log₂k⌉-bit index planes, so serving
/// never materializes a dense weight matrix. With a single-level
/// per-layer cascade the f64 logits are bit-for-bit identical to running
/// [`Mlp::infer`] on the decoded weights (the kernels reproduce the dense
/// ikj arithmetic order); multi-level cascades sum per-level matvecs in
/// cascade order.
#[derive(Debug, Clone)]
pub struct QuantizedMlp {
    /// Per-layer quantized weights, input to output.
    pub weights: Vec<QMatrix<f64>>,
    /// Per-layer dense biases (copied from the source network).
    pub biases: Vec<Vec<f64>>,
    /// Per-layer ReLU flags.
    pub relus: Vec<bool>,
}

impl QuantizedMlp {
    /// Input dimension.
    pub fn in_dim(&self) -> usize {
        self.weights.first().map_or(0, |w| w.rows())
    }

    /// Quantized inference: affine maps off the packed planes, dense
    /// biases, ReLU masks — [`Mlp::infer`] shape for shape.
    pub fn infer(&self, x: &Matrix) -> Result<Matrix> {
        if x.cols() != self.in_dim() {
            return Err(Error::InvalidInput(format!(
                "quantized mlp: input dim {} vs expected {}",
                x.cols(),
                self.in_dim()
            )));
        }
        let mut a = x.clone();
        for ((w, b), &relu) in self.weights.iter().zip(&self.biases).zip(&self.relus) {
            let mut z = w.matmul(&a);
            for i in 0..z.rows() {
                for (zj, bj) in z.row_mut(i).iter_mut().zip(b) {
                    *zj += bj;
                }
            }
            if relu {
                for v in z.data_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            a = z;
        }
        Ok(a)
    }

    /// Classification accuracy over a batch, served from quantized compute.
    pub fn accuracy(&self, x: &Matrix, labels: &[usize]) -> Result<f64> {
        let logits = self.infer(x)?;
        let mut correct = 0usize;
        for i in 0..logits.rows() {
            let row = logits.row(i);
            let pred = (0..row.len())
                .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                .unwrap();
            if pred == labels[i] {
                correct += 1;
            }
        }
        Ok(correct as f64 / logits.rows().max(1) as f64)
    }

    /// Compact payload bytes across all weight cascades (packed index
    /// planes + f32 level tables; biases excluded — dense in both nets).
    pub fn weight_bytes(&self) -> usize {
        self.weights.iter().map(QMatrix::compact_bytes).sum()
    }

    /// Dense f64 bytes of the same weights, for the compression ratio.
    pub fn dense_weight_bytes(&self) -> usize {
        self.weights.iter().map(|w| w.rows() * w.cols() * 8).sum()
    }

    /// Worst per-layer relative Frobenius reconstruction error vs the
    /// source network the weights were quantized from.
    pub fn max_layer_error(&self, src: &Mlp) -> f64 {
        self.weights
            .iter()
            .zip(&src.layers)
            .map(|(qw, l)| qw.approx_error(&l.w))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Mlp {
        Mlp::new(&[4, 8, 3], 1)
    }

    #[test]
    fn shapes_and_counts() {
        let m = Mlp::paper_arch(0);
        assert_eq!(m.in_dim(), 784);
        assert_eq!(m.out_dim(), 10);
        assert_eq!(
            m.param_count(),
            784 * 256 + 256 + 256 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10
        );
        assert!(m.layers[0].relu && !m.layers[3].relu);
    }

    #[test]
    fn forward_shape() {
        let m = tiny();
        let x = Matrix::from_fn(5, 4, |i, j| (i + j) as f64 * 0.1);
        let (logits, cache) = m.forward(&x).unwrap();
        assert_eq!((logits.rows(), logits.cols()), (5, 3));
        assert_eq!(cache.acts.len(), 3);
    }

    #[test]
    fn rejects_wrong_input_dim() {
        let m = tiny();
        assert!(m.forward(&Matrix::zeros(2, 5)).is_err());
    }

    #[test]
    fn loss_decreases_with_manual_sgd_step() {
        let m0 = tiny();
        let x = Matrix::from_fn(8, 4, |i, j| ((i * 3 + j) as f64).sin());
        let labels: Vec<usize> = (0..8).map(|i| i % 3).collect();
        let (logits, cache) = m0.forward(&x).unwrap();
        let (loss0, g) = m0.loss_and_grad(&cache, &logits, &labels).unwrap();
        let mut m1 = m0.clone();
        let lr = 0.5;
        for (li, layer) in m1.layers.iter_mut().enumerate() {
            for (w, dw) in layer.w.data_mut().iter_mut().zip(g.dw[li].data()) {
                *w -= lr * dw;
            }
            for (b, db) in layer.b.iter_mut().zip(&g.db[li]) {
                *b -= lr * db;
            }
        }
        let (logits1, cache1) = m1.forward(&x).unwrap();
        let (loss1, _) = m1.loss_and_grad(&cache1, &logits1, &labels).unwrap();
        assert!(loss1 < loss0, "loss did not decrease: {loss0} -> {loss1}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let m = Mlp::new(&[3, 4, 2], 7);
        let x = Matrix::from_fn(4, 3, |i, j| ((i + 2 * j) as f64).cos());
        let labels = vec![0usize, 1, 0, 1];
        let (logits, cache) = m.forward(&x).unwrap();
        let (_, g) = m.loss_and_grad(&cache, &logits, &labels).unwrap();

        let eps = 1e-6;
        let mut m2 = m.clone();
        // Probe a handful of weights in each layer.
        for li in 0..m.layers.len() {
            for &idx in &[0usize, 3, 5] {
                let orig = m.layers[li].w.data()[idx];
                m2.layers[li].w.data_mut()[idx] = orig + eps;
                let (l_p, c_p) = m2.forward(&x).unwrap();
                let (lp, _) = m2.loss_and_grad(&c_p, &l_p, &labels).unwrap();
                m2.layers[li].w.data_mut()[idx] = orig - eps;
                let (l_m, c_m) = m2.forward(&x).unwrap();
                let (lm, _) = m2.loss_and_grad(&c_m, &l_m, &labels).unwrap();
                m2.layers[li].w.data_mut()[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = g.dw[li].data()[idx];
                assert!(
                    (fd - an).abs() < 1e-5 * (1.0 + fd.abs()),
                    "layer {li} idx {idx}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn accuracy_bounds() {
        let m = tiny();
        let x = Matrix::from_fn(6, 4, |i, j| (i * j) as f64 * 0.01);
        let labels = vec![0usize; 6];
        let acc = m.accuracy(&x, &labels).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn quantized_forward_single_level_is_bitwise_decoded_dense() {
        let m = tiny();
        let qnet = m
            .quantize_weights(
                Grouping::PerColumn,
                QuantMethod::KMeans,
                &QuantOptions { kmeans_restarts: 2, ..QuantOptions::default() },
                &[3],
                0.0,
            )
            .unwrap();
        // A dense copy carrying the decoded (reconstructed) weights.
        let mut dense = m.clone();
        for (li, qw) in qnet.weights.iter().enumerate() {
            dense.set_layer_weights(li, qw.decode().data()).unwrap();
        }
        let x = Matrix::from_fn(6, 4, |i, j| ((i * 3 + j) as f64 * 0.37).sin());
        let want = dense.infer(&x).unwrap();
        let got = qnet.infer(&x).unwrap();
        assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()));
        for (a, b) in got.data().iter().zip(want.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn quantized_accuracy_and_bytes_report() {
        let m = tiny();
        let qnet = m
            .quantize_weights(
                Grouping::PerColumn,
                QuantMethod::KMeans,
                &QuantOptions { kmeans_restarts: 2, ..QuantOptions::default() },
                &[4, 3],
                0.0,
            )
            .unwrap();
        let x = Matrix::from_fn(10, 4, |i, j| ((i + 2 * j) as f64 * 0.21).cos());
        let labels: Vec<usize> = (0..10).map(|i| i % 3).collect();
        let acc = qnet.accuracy(&x, &labels).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(qnet.weight_bytes() < qnet.dense_weight_bytes());
        assert_eq!(qnet.dense_weight_bytes(), (4 * 8 + 8 * 3) * 8);
        assert!(qnet.max_layer_error(&m).is_finite());
        assert!(qnet.infer(&Matrix::zeros(2, 5)).is_err(), "dim mismatch must error");
    }

    #[test]
    fn set_layer_weights_roundtrip() {
        let mut m = tiny();
        let flat: Vec<f64> = (0..4 * 8).map(|i| i as f64).collect();
        m.set_layer_weights(0, &flat).unwrap();
        assert_eq!(m.layer_weights(0), flat.as_slice());
        assert!(m.set_layer_weights(0, &[1.0]).is_err());
    }
}
